"""Layer-2 model tests: shapes, determinism, sparsity invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import pack


@pytest.fixture(scope="module")
def tiny_params():
    return M.bert_params(M.BERT_TINY, sparsity=8, seed=0)


def test_bert_forward_shape(tiny_params):
    ids = jnp.zeros((2, 128), jnp.int32)
    logits = M.bert_forward(tiny_params, ids, M.BERT_TINY)
    assert logits.shape == (2, M.BERT_TINY.classes)
    assert np.isfinite(np.asarray(logits)).all()


def test_bert_forward_deterministic(tiny_params):
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(0, M.BERT_TINY.vocab, (1, 128)), jnp.int32)
    a = np.asarray(M.bert_forward(tiny_params, ids, M.BERT_TINY))
    b = np.asarray(M.bert_forward(tiny_params, ids, M.BERT_TINY))
    np.testing.assert_array_equal(a, b)


def test_bert_params_are_block_balanced():
    params = M.bert_params(M.BERT_TINY, sparsity=8, seed=1)
    for lp in params["layers"]:
        for key in ("q", "k", "v", "o", "ffn_up", "ffn_down"):
            p = lp[key]
            k = {"ffn_down": M.BERT_TINY.ffn}.get(key, M.BERT_TINY.hidden)
            dense = pack.unpack(p["values"], p["indices"], k)
            assert pack.is_block_balanced(dense, 8)


def test_bert_sparsity_changes_output():
    ids = jnp.asarray(np.random.default_rng(1).integers(0, 1024, (1, 128)), jnp.int32)
    y1 = np.asarray(M.bert_forward(M.bert_params(M.BERT_TINY, 1), ids, M.BERT_TINY))
    y8 = np.asarray(M.bert_forward(M.bert_params(M.BERT_TINY, 8), ids, M.BERT_TINY))
    assert not np.allclose(y1, y8)  # pruning actually removed weights


def test_bert_hidden_states_count(tiny_params):
    ids = jnp.zeros((1, 128), jnp.int32)
    logits, hs = M.bert_hidden_states(tiny_params, ids, M.BERT_TINY)
    assert len(hs) == M.BERT_TINY.layers + 1  # embeddings + each layer
    assert logits.shape == (1, 2)
    for h in hs:
        assert h.shape == (1, 128, M.BERT_TINY.hidden)


def test_bert_param_count_formula():
    # BERT-base ~ 85.6M encoder weights + 23.4M embeddings
    c = M.BERT_BASE.param_count()
    assert 100e6 < c < 115e6
    assert M.BERT_LARGE.param_count() > 2.5 * M.BERT_BASE.param_count()


def test_resnet_forward_shape():
    params = M.resnet_params(M.RESNET_MINI, sparsity=8, seed=0)
    imgs = jnp.asarray(
        np.random.default_rng(0).standard_normal((2, 32, 32, 3)), jnp.float32)
    logits = M.resnet_forward(params, imgs, M.RESNET_MINI)
    assert logits.shape == (2, M.RESNET_MINI.classes)
    assert np.isfinite(np.asarray(logits)).all()


def test_resnet_residual_nonnegative_prepool():
    # final block output passes through relu → pooled mean of a relu'd map
    # can still be any sign after the head matmul; just check finiteness
    params = M.resnet_params(M.RESNET_MINI, sparsity=2, seed=3)
    imgs = jnp.zeros((1, 32, 32, 3), jnp.float32)
    logits = M.resnet_forward(params, imgs, M.RESNET_MINI)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("sparsity", [1, 4, 32])
def test_bert_flops_scale(sparsity):
    f = M.bert_flops(M.BERT_BASE, batch=1, seq=128, sparsity=sparsity)
    f1 = M.bert_flops(M.BERT_BASE, batch=1, seq=128, sparsity=1)
    # sparse part scales exactly 1/s; dense attention part constant
    assert f["spu_sparse"] * sparsity == pytest.approx(f1["spu_sparse"])
    assert f["spu_dense"] == f1["spu_dense"]
    assert f["total"] < f1["total"] or sparsity == 1


def test_bert_flops_bert_base_magnitude():
    # ~22.5 GFLOP for dense BERT-base at seq 128 (2 * 11.2G MACs)
    f = M.bert_flops(M.BERT_BASE, batch=1, seq=128, sparsity=1)
    assert 15e9 < f["total"] < 30e9
