"""Layer-1 correctness: Pallas sparse matmul vs the pure-jnp oracle.

This is the CORE correctness signal of the stack: everything above (the L2
models, the AOT artifacts, the rust runtime) computes through this kernel.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pack, sparse_matmul, vmem_footprint
from compile.kernels.ref import sparse_matmul_ref
from compile.kernels.sparse_matmul import ACTIVATIONS

RNG = np.random.default_rng(1234)


def make_case(m, k, n, sparsity, dtype=np.float32, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, k)).astype(dtype)
    w = rng.standard_normal((k, n)).astype(dtype)
    b = rng.standard_normal((n,)).astype(dtype)
    v, i = pack.pack_dense(w, sparsity)
    return x, v, i, b


def run_both(x, v, i, b, act="none", **kw):
    y = sparse_matmul(jnp.asarray(x), jnp.asarray(v), jnp.asarray(i),
                      jnp.asarray(b), act=act, **kw)
    yr = sparse_matmul_ref(jnp.asarray(x), jnp.asarray(v), jnp.asarray(i),
                           jnp.asarray(b), act=act)
    return np.asarray(y), np.asarray(yr)


@pytest.mark.parametrize("sparsity", pack.SUPPORTED_SPARSITIES)
def test_matmul_all_sparsities(sparsity):
    x, v, i, b = make_case(128, 256, 128, sparsity)
    y, yr = run_both(x, v, i, b)
    np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("act", ACTIVATIONS)
def test_matmul_fused_activations(act):
    x, v, i, b = make_case(128, 128, 128, 4, seed=7)
    y, yr = run_both(x, v, i, b, act=act)
    np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)


def test_matmul_multi_tile_grid():
    # M and N both larger than one tile: exercises the BlockSpec index maps.
    x, v, i, b = make_case(384, 128, 256, 2, seed=3)
    y, yr = run_both(x, v, i, b)
    np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)


def test_matmul_custom_tiles():
    x, v, i, b = make_case(64, 128, 64, 4, seed=5)
    y, yr = run_both(x, v, i, b, tile_m=32, tile_n=64)
    np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)


def test_dense_degenerate_s1_matches_plain_matmul():
    # s=1 packs every weight: kernel must equal an ordinary dense matmul.
    x, v, i, b = make_case(128, 128, 128, 1, seed=9)
    y, _ = run_both(x, v, i, b)
    w = pack.unpack(v, i, 128)
    expect = x @ w + b[None, :]
    np.testing.assert_allclose(y, expect, rtol=1e-4, atol=1e-4)


def test_sparsity_reduces_nonzeros_kept():
    _, v, i, _ = make_case(128, 256, 128, 8)
    assert v.shape == (256 // 8, 128)
    assert i.shape == v.shape
    assert i.dtype == np.int32


def test_bias_none_is_zero_bias():
    x, v, i, b = make_case(128, 128, 128, 2)
    y = np.asarray(sparse_matmul(jnp.asarray(x), jnp.asarray(v), jnp.asarray(i)))
    yr = np.asarray(sparse_matmul_ref(jnp.asarray(x), jnp.asarray(v),
                                      jnp.asarray(i), jnp.zeros(128, np.float32)))
    np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)


def test_bf16_inputs():
    x, v, i, b = make_case(128, 128, 128, 4)
    y = sparse_matmul(jnp.asarray(x, jnp.bfloat16), jnp.asarray(v, jnp.bfloat16),
                      jnp.asarray(i), jnp.asarray(b, jnp.bfloat16))
    yr = sparse_matmul_ref(jnp.asarray(x, jnp.bfloat16), jnp.asarray(v, jnp.bfloat16),
                           jnp.asarray(i), jnp.asarray(b, jnp.bfloat16))
    # bf16 accumulate happens in f32 inside the kernel; compare loosely.
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_rejects_bad_tiling():
    # M=100 with an explicit 40-row tile: 100 % 40 != 0 even after the
    # clamp-to-problem step → must raise. (M smaller than the default tile
    # is fine: the tile clamps down to M.)
    x, v, i, b = make_case(100, 128, 128, 2)
    with pytest.raises(ValueError, match="tile"):
        sparse_matmul(jnp.asarray(x), jnp.asarray(v), jnp.asarray(i),
                      jnp.asarray(b), tile_m=40)


def test_small_m_clamps_tile_and_works():
    x, v, i, b = make_case(100, 128, 128, 2, seed=13)
    y, yr = run_both(x, v, i, b)
    np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)


def test_rejects_bad_activation():
    x, v, i, b = make_case(128, 128, 128, 2)
    with pytest.raises(ValueError, match="activation"):
        sparse_matmul(jnp.asarray(x), jnp.asarray(v), jnp.asarray(i),
                      jnp.asarray(b), act="swish")


def test_rejects_mismatched_indices():
    x, v, i, b = make_case(128, 128, 128, 2)
    with pytest.raises(ValueError, match="indices"):
        sparse_matmul(jnp.asarray(x), jnp.asarray(v), jnp.asarray(i[:-1]),
                      jnp.asarray(b))


# ---------------------------------------------------------------------------
# Hypothesis sweep over shapes / sparsities / dtypes — the brief's required
# property pass for L1.
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    mt=st.integers(1, 3),
    nt=st.integers(1, 2),
    kb=st.integers(1, 4),
    sparsity=st.sampled_from(pack.SUPPORTED_SPARSITIES),
    act=st.sampled_from(ACTIVATIONS),
    seed=st.integers(0, 2**16),
)
def test_matmul_property_sweep(mt, nt, kb, sparsity, act, seed):
    m, n, k = 32 * mt, 32 * nt, 32 * kb
    x, v, i, b = make_case(m, k, n, sparsity, seed=seed)
    y, yr = run_both(x, v, i, b, act=act, tile_m=32, tile_n=32)
    np.testing.assert_allclose(y, yr, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    k=st.sampled_from([32, 64, 128, 256]),
    sparsity=st.sampled_from(pack.SUPPORTED_SPARSITIES),
    seed=st.integers(0, 2**16),
)
def test_pack_unpack_roundtrip_is_projection(k, sparsity, seed):
    """unpack(pack(w)) == w * mask — packing is the magnitude projection."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((k, 64)).astype(np.float32)
    v, i = pack.pack_dense(w, sparsity)
    dense = pack.unpack(v, i, k)
    mask = pack.block_balanced_mask(w, sparsity)
    np.testing.assert_array_equal(dense, w * mask)
    assert pack.is_block_balanced(dense, sparsity)
    # exactly B/s kept per (block, col)
    nz = (dense.reshape(k // pack.BLOCK, pack.BLOCK, 64) != 0).sum(axis=1)
    # ties/zeros in w may reduce the count; never exceed.
    assert (nz <= pack.BLOCK // sparsity).all()


def test_pack_keeps_largest_magnitudes():
    w = np.arange(1, 65, dtype=np.float32).reshape(64, 1)  # strictly increasing
    v, i = pack.pack_dense(w, 4)  # keep 8 of each 32-block
    # block 0 keeps rows 24..31 (values 25..32), block 1 rows 56..63.
    np.testing.assert_array_equal(i[:, 0], np.r_[24:32, 56:64].astype(np.int32))


def test_pack_jax_matches_numpy():
    rng = np.random.default_rng(11)
    w = rng.standard_normal((128, 64)).astype(np.float32)
    v, i = pack.pack_dense(w, 8)
    vj, ij = pack.pack_dense_jax(jnp.asarray(w), 8)
    np.testing.assert_allclose(np.asarray(vj), v, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(ij), i)


def test_pack_rejects_bad_args():
    with pytest.raises(ValueError):
        pack.pack_dense(np.zeros((100, 8), np.float32), 8)  # K % 32 != 0
    with pytest.raises(ValueError):
        pack.pack_dense(np.zeros((64, 8), np.float32), 3)  # unsupported s
    with pytest.raises(ValueError):
        pack.pack_dense(np.zeros((64,), np.float32), 2)  # not 2-D


def test_vmem_footprint_scales_with_sparsity():
    d = {s: vmem_footprint(128, 4096, 4096, s)["total"] for s in (1, 8, 32)}
    assert d[1] > d[8] > d[32]
    f = vmem_footprint(128, 1024, 1024, 4)
    assert f["sparse_macs_per_tile"] * 4 == f["dense_macs_per_tile"]
    assert f["fits_16mb"]
