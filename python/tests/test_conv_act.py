"""Layer-1 correctness: sparse conv and the activation-engine kernels."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    act_engine,
    pack,
    pack_conv_weight,
    softmax_engine,
    sparse_conv2d,
)
from compile.kernels.act import ENGINE_OPS
from compile.kernels.ref import apply_act_ref, conv2d_ref, softmax_ref
from compile.kernels.sparse_conv import conv_reduction_dim


def make_conv_case(b, h, w, cin, cout, kh, kw, sparsity, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((b, h, w, cin)).astype(np.float32)
    wt = rng.standard_normal((kh, kw, cin, cout)).astype(np.float32)
    bias = rng.standard_normal((cout,)).astype(np.float32)
    v, i = pack_conv_weight(wt, sparsity)
    # oracle runs the *pruned* dense weight
    dense = pack.unpack(np.asarray(v), np.asarray(i), kh * kw * cin)
    return x, v, i, bias, dense.reshape(kh, kw, cin, cout)


@pytest.mark.parametrize("sparsity", [1, 2, 4, 8])
def test_conv3x3_sparsities(sparsity):
    x, v, i, bias, wd = make_conv_case(2, 8, 8, 32, 128, 3, 3, sparsity)
    y = sparse_conv2d(jnp.asarray(x), jnp.asarray(v), jnp.asarray(i),
                      jnp.asarray(bias), kh=3, kw=3, padding=1)
    yr = conv2d_ref(jnp.asarray(x), jnp.asarray(wd), jnp.asarray(bias), padding=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4, atol=1e-4)


def test_conv1x1_is_pointwise_matmul():
    x, v, i, bias, wd = make_conv_case(1, 8, 8, 64, 128, 1, 1, 4, seed=2)
    y = sparse_conv2d(jnp.asarray(x), jnp.asarray(v), jnp.asarray(i),
                      jnp.asarray(bias), kh=1, kw=1)
    yr = conv2d_ref(jnp.asarray(x), jnp.asarray(wd), jnp.asarray(bias))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4, atol=1e-4)


def test_conv_strided():
    x, v, i, bias, wd = make_conv_case(1, 16, 16, 32, 128, 3, 3, 2, seed=3)
    y = sparse_conv2d(jnp.asarray(x), jnp.asarray(v), jnp.asarray(i),
                      jnp.asarray(bias), kh=3, kw=3, stride=2, padding=1)
    yr = conv2d_ref(jnp.asarray(x), jnp.asarray(wd), jnp.asarray(bias),
                    stride=2, padding=1)
    assert y.shape == (1, 8, 8, 128)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4, atol=1e-4)


def test_conv_fused_relu():
    x, v, i, bias, wd = make_conv_case(1, 8, 8, 32, 128, 3, 3, 4, seed=4)
    y = sparse_conv2d(jnp.asarray(x), jnp.asarray(v), jnp.asarray(i),
                      jnp.asarray(bias), kh=3, kw=3, padding=1, act="relu")
    yr = conv2d_ref(jnp.asarray(x), jnp.asarray(wd), jnp.asarray(bias),
                    padding=1, act="relu")
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4, atol=1e-4)
    assert (np.asarray(y) >= 0).all()


def test_conv_odd_spatial_padding_of_gemm_m():
    # 7x7 output → M = 49, not a tile multiple; kernel pads internally.
    x, v, i, bias, wd = make_conv_case(1, 7, 7, 32, 128, 3, 3, 2, seed=5)
    y = sparse_conv2d(jnp.asarray(x), jnp.asarray(v), jnp.asarray(i),
                      jnp.asarray(bias), kh=3, kw=3, padding=1)
    yr = conv2d_ref(jnp.asarray(x), jnp.asarray(wd), jnp.asarray(bias), padding=1)
    assert y.shape == (1, 7, 7, 128)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4, atol=1e-4)


def test_conv_reduction_dim_helper():
    assert conv_reduction_dim(3, 3, 64) == 576
    assert conv_reduction_dim(1, 1, 32) == 32


@settings(max_examples=10, deadline=None)
@given(
    cin=st.sampled_from([32, 64]),
    sparsity=st.sampled_from([1, 2, 4, 8]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**16),
)
def test_conv_property_sweep(cin, sparsity, stride, seed):
    x, v, i, bias, wd = make_conv_case(1, 8, 8, cin, 64, 3, 3, sparsity, seed=seed)
    y = sparse_conv2d(jnp.asarray(x), jnp.asarray(v), jnp.asarray(i),
                      jnp.asarray(bias), kh=3, kw=3, stride=stride, padding=1,
                      tile_m=32, tile_n=32)
    yr = conv2d_ref(jnp.asarray(x), jnp.asarray(wd), jnp.asarray(bias),
                    stride=stride, padding=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4, atol=2e-4)


# --------------------------- activation engine ----------------------------

@pytest.mark.parametrize("op", ENGINE_OPS)
def test_act_engine_ops(op):
    rng = np.random.default_rng(6)
    # positive domain so log/sqrt/rsqrt/reciprocal are well-defined
    x = (rng.random((5, 333)) + 0.1).astype(np.float32)
    y = np.asarray(act_engine(jnp.asarray(x), op=op))
    import jax
    ref = {
        "gelu": lambda t: apply_act_ref(t, "gelu"),
        "relu": lambda t: apply_act_ref(t, "relu"),
        "exp": jnp.exp, "log": jnp.log, "reciprocal": lambda t: 1.0 / t,
        "sigmoid": lambda t: 1 / (1 + jnp.exp(-t)), "tanh": jnp.tanh,
        "sqrt": jnp.sqrt, "rsqrt": jax.lax.rsqrt,
    }[op](jnp.asarray(x))
    np.testing.assert_allclose(y, np.asarray(ref), rtol=1e-5, atol=1e-6)


def test_act_engine_preserves_shape_and_pads():
    x = np.linspace(-2, 2, 1000, dtype=np.float32).reshape(10, 100)
    y = act_engine(jnp.asarray(x), op="gelu")
    assert y.shape == x.shape


def test_act_engine_rejects_unknown_op():
    with pytest.raises(ValueError, match="engine"):
        act_engine(jnp.zeros((4,)), op="selu")


def test_softmax_engine_matches_ref():
    rng = np.random.default_rng(8)
    x = rng.standard_normal((4, 128)).astype(np.float32) * 5
    y = np.asarray(softmax_engine(jnp.asarray(x)))
    yr = np.asarray(softmax_ref(jnp.asarray(x)))
    np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(y.sum(-1), 1.0, rtol=1e-5)


def test_softmax_engine_translation_invariant():
    x = jnp.asarray(np.random.default_rng(9).standard_normal((2, 64)), jnp.float32)
    a = np.asarray(softmax_engine(x))
    b = np.asarray(softmax_engine(x + 100.0))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
