"""Tests for the sparsification stack: data generation, masks, schedules,
distillation trainer plumbing (kept to tiny step budgets)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile import prune as P
from compile import train as T
from compile.kernels import pack


# ------------------------------- data --------------------------------------

def test_tasks_have_unique_specs():
    names = [t.name for t in D.TASKS]
    assert len(names) == len(set(names)) == 5
    analogs = {t.glue_analog for t in D.TASKS}
    assert {"MNLI-m", "QNLI", "MRPC", "RTE", "CoLA"} == analogs


def test_task_generation_shapes_and_balance():
    spec = D.TASK_BY_NAME["proxy_rte"]
    x_tr, y_tr, x_te, y_te = D.make_task(spec)
    assert x_tr.shape == (spec.train, spec.seq)
    assert x_te.shape == (spec.test, spec.seq)
    assert set(np.unique(y_tr)) <= {0, 1}
    # median split ⇒ roughly balanced labels
    assert 0.3 < y_tr.mean() < 0.7
    assert (x_tr >= 0).all() and (x_tr < spec.vocab).all()


def test_task_generation_deterministic():
    spec = D.TASK_BY_NAME["proxy_cola"]
    a = D.make_task(spec)
    b = D.make_task(spec)
    for xa, xb in zip(a, b):
        np.testing.assert_array_equal(xa, xb)


def test_train_test_disjoint_generation():
    spec = D.TASK_BY_NAME["proxy_mrpc"]
    x_tr, _, x_te, _ = D.make_task(spec)
    # different seeds → (overwhelmingly) different rows
    assert not np.array_equal(x_tr[: x_te.shape[0]], x_te)


def test_batches_cover_epoch():
    x = np.arange(100).reshape(50, 2)
    y = np.arange(50)
    seen = 0
    for xb, yb in D.batches(x, y, batch=8, seed=0, epochs=2):
        assert xb.shape == (8, 2)
        seen += 8
    assert seen == 2 * (50 // 8) * 8


# ------------------------------- prune -------------------------------------

def test_mask_matches_pack_pattern():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((128, 64)), jnp.float32)
    m = np.asarray(P.block_balanced_mask_jax(w, 8))
    ref = pack.block_balanced_mask(np.asarray(w), 8)
    np.testing.assert_array_equal(m.astype(bool), ref)


def test_mask_sparsity_fractions():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal((256, 32)), jnp.float32)
    for s in (1, 2, 4, 8, 16, 32):
        m = np.asarray(P.block_balanced_mask_jax(w, s))
        assert m.mean() == pytest.approx(1.0 / s)


def test_gradual_schedule_mirror_of_rust():
    # same cubic as rust sparse::prune::PruneSchedule (values pinned)
    assert P.gradual_fraction(0, 100, 1000, 0.96875) == 0.0
    assert P.gradual_fraction(1000, 100, 1000, 0.96875) == pytest.approx(0.96875)
    mid = P.gradual_fraction(550, 100, 1000, 0.96875)
    assert 0.5 * 0.96875 < mid < 0.96875  # cubic front-loads pruning


def test_factor_at_progression():
    fs = [P.factor_at(t, 0, 100, 32) for t in range(0, 101, 10)]
    assert fs[0] == 1
    assert fs[-1] == 32
    assert all(b >= a for a, b in zip(fs, fs[1:]))
    assert all(f in pack.SUPPORTED_SPARSITIES for f in fs)


def test_apply_masks_zeroes_weights():
    params = T.init_model(0, vocab=64, seq=16, classes=2,
                          layers=1, hidden=32, ffn=64, heads=2)
    p, _ = params, None
    masks = {("layers", 0, "q"): jnp.zeros_like(params["layers"][0]["q"])}
    out = P.apply_masks(params, masks)
    assert float(jnp.abs(out["layers"][0]["q"]).sum()) == 0.0
    # original untouched
    assert float(jnp.abs(params["layers"][0]["q"]).sum()) > 0.0


# ------------------------------- train -------------------------------------

ARCH = {"layers": 1, "hidden": 32, "ffn": 64, "heads": 2}


def test_forward_shapes():
    params = T.init_model(0, vocab=64, seq=16, classes=2, **ARCH)
    p, cfg = T._strip_cfg(params)
    masks = T.ones_masks(p)
    x = jnp.zeros((3, 16), jnp.int32)
    logits, hiddens = T.forward(p, masks, x, heads=2)
    assert logits.shape == (3, 2)
    assert len(hiddens) == 2  # embedding + 1 layer
    assert hiddens[0].shape == (3, 16, 32)


def test_masked_forward_differs_from_dense():
    params = T.init_model(0, vocab=64, seq=16, classes=2, **ARCH)
    p, _ = T._strip_cfg(params)
    x = jnp.asarray(np.random.default_rng(0).integers(0, 64, (2, 16)), jnp.int32)
    dense, _ = T.forward(p, T.ones_masks(p), x, heads=2)
    sparse, _ = T.forward(p, T.masks_at({"layers": p["layers"]}, 8), x, heads=2)
    assert not np.allclose(np.asarray(dense), np.asarray(sparse))


def test_training_reduces_loss_and_learns():
    spec = D.TaskSpec("t", "T", vocab=128, seq=32, classes=2,
                      train=600, test=300, noise=0.02, salient=12, seed=9)
    _, _, acc = T.train_model(spec, ARCH, steps=120, lr=1e-3, seed=0)
    assert acc > 0.6, f"tiny model should beat chance, got {acc}"


def test_sparse_training_produces_hardware_pattern():
    spec = D.TaskSpec("t2", "T", vocab=128, seq=32, classes=2,
                      train=300, test=200, noise=0.05, salient=8, seed=10)
    params, masks, _ = T.train_model(spec, ARCH, steps=40, sparsity=8, seed=0)
    # every prunable weight is block-balanced at 8x after projection
    for li, layer_masks in enumerate(masks):
        for name, m in layer_masks.items():
            w = np.asarray(params["layers"][li][name] * m)
            assert pack.is_block_balanced(w, 8), f"layer {li} {name}"
    frac = P.sparsity_achieved(
        {"layers": params["layers"]},
        {("layers", i, n): masks[i][n] for i in range(len(masks)) for n in masks[i]},
    )
    assert frac == pytest.approx(1 - 1 / 8, abs=1e-6)


def test_distillation_plumbing_runs():
    spec = D.TaskSpec("t3", "T", vocab=128, seq=32, classes=2,
                      train=300, test=200, noise=0.05, salient=8, seed=11)
    teacher, _, _ = T.train_model(spec, ARCH, steps=30, seed=1)
    _, _, acc = T.train_model(spec, ARCH, steps=30, sparsity=16, teacher=teacher,
                              distill_logits=1.0, distill_hidden=0.5, seed=2)
    assert 0.0 <= acc <= 1.0


def test_encoder_size_reduction_bookkeeping():
    t = T.encoder_size(T.TEACHER_ARCH)
    assert t / T.encoder_size(T.DEPTH_ARCH) == pytest.approx(2.0)
    assert t / T.encoder_size(T.WIDTH_ARCH) == pytest.approx(4.0)
