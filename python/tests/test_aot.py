"""AOT pipeline tests: HLO text validity, manifest schema, size scaling.

These run the lowering in-process on the tiniest variants (no artifact
directory needed) and, when ``artifacts/manifest.json`` exists from a
``make artifacts`` run, validate the shipped artifact set too.
"""

import json
import pathlib

import pytest

from compile import aot
from compile.aot import Variant, lower_variant

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


def test_lower_bert_tiny_has_full_constants():
    text, meta = lower_variant(Variant("t", "bert", "bert_tiny", 8, 1, seq=128))
    assert "ENTRY" in text
    # weights must be materialized, not elided
    assert "constant({...})" not in text
    assert meta["hlo_bytes"] == len(text)
    assert meta["inputs"][0]["shape"] == [1, 128]
    assert meta["inputs"][0]["dtype"] == "s32"


def test_lower_size_scales_with_sparsity():
    t1, _ = lower_variant(Variant("a", "bert", "bert_tiny", 1, 1, seq=128))
    t8, _ = lower_variant(Variant("b", "bert", "bert_tiny", 8, 1, seq=128))
    # compressed weights shrink the artifact; embeddings are a fixed floor
    assert len(t8) < 0.6 * len(t1)


def test_lower_rejects_unknown_family():
    with pytest.raises(ValueError, match="family"):
        lower_variant(Variant("x", "mlp", "bert_tiny", 1, 1))


def test_golden_outputs_deterministic():
    v = Variant("t", "bert", "bert_tiny", 8, 1, seq=128)
    a = aot.golden_outputs(v)
    b = aot.golden_outputs(v)
    assert a == b
    assert len(a["input"]) == 128
    assert len(a["output"]) == 2


def test_default_variant_names_unique():
    names = [v.name for v in aot.default_variants()]
    assert len(names) == len(set(names))
    assert any(v.family == "resnet" for v in aot.default_variants())


@pytest.mark.skipif(not (ARTIFACTS / "manifest.json").exists(),
                    reason="run `make artifacts` first")
class TestShippedArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        return json.loads((ARTIFACTS / "manifest.json").read_text())

    def test_all_files_exist(self, manifest):
        for a in manifest["artifacts"]:
            f = ARTIFACTS / a["file"]
            assert f.exists(), a["file"]
            assert f.stat().st_size == a["hlo_bytes"]

    def test_goldens_exist(self, manifest):
        for a in manifest["artifacts"]:
            g = json.loads((ARTIFACTS / a["golden"]).read_text())
            n_in = 1
            for d in a["inputs"][0]["shape"]:
                n_in *= d
            assert len(g["input"]) == n_in

    def test_sparsity_footprint_ordering(self, manifest):
        """Fig. 2's memory-footprint premise: artifact bytes fall with s."""
        bert_b1 = {a["sparsity"]: a["hlo_bytes"] for a in manifest["artifacts"]
                   if a["model"] == "bert_tiny" and a["batch"] == 1}
        ss = sorted(bert_b1)
        for lo, hi in zip(ss, ss[1:]):
            assert bert_b1[hi] < bert_b1[lo]
