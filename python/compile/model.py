"""Layer-2: JAX model definitions built on the L1 sparse kernels.

Two model families, matching the paper's two benchmark workloads:

* **BERT-style transformer encoder** (`bert_forward`) — every weighted
  projection (QKV, attention output, FFN up/down, classifier) runs through
  the Pallas block-balanced `sparse_matmul`; softmax runs through the
  activation engine; embedding lookup models the dedicated
  embedding-lookup unit (a gather).
* **ResNet-style CNN** (`resnet_forward`) — every conv runs through
  `sparse_conv2d` (same SPU kernel via im2col).

These functions are *build-time only*: `aot.py` lowers them to HLO text
once per (model, sparsity, batch) variant; the rust runtime executes the
artifacts. They are also the training graph for the sparsification
experiments (`train.py`), where the packed weights are re-projected every
step (straight-through magnitude pruning).

Sizing note: `sparse_matmul` tiles at 128×128, so every matmul dim here is
a multiple of 128 (seq·batch included). Tiny configs exist for artifacts
that must *execute* fast on the CPU interpret path; base/large configs are
for shape/workload accounting and lowering tests.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import pack
from .kernels.act import softmax_engine
from .kernels.ref import layernorm_ref
from .kernels.sparse_conv import sparse_conv2d
from .kernels.sparse_matmul import sparse_matmul


# =========================== configurations ===============================

@dataclasses.dataclass(frozen=True)
class BertConfig:
    """Transformer encoder hyperparameters (paper: BERT-base / BERT-large)."""

    name: str
    vocab: int
    hidden: int
    layers: int
    heads: int
    ffn: int
    max_seq: int = 128
    classes: int = 2

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    def param_count(self) -> int:
        """Dense (unpruned) weight parameter count of the encoder stack."""
        per_layer = 4 * self.hidden * self.hidden + 2 * self.hidden * self.ffn
        return self.layers * per_layer + self.vocab * self.hidden


BERT_TINY = BertConfig("bert_tiny", vocab=1024, hidden=128, layers=2, heads=2, ffn=512)
BERT_MINI = BertConfig("bert_mini", vocab=2048, hidden=256, layers=4, heads=4, ffn=1024)
BERT_BASE = BertConfig("bert_base", vocab=30522, hidden=768, layers=12, heads=12, ffn=3072)
BERT_LARGE = BertConfig("bert_large", vocab=30522, hidden=1024, layers=24, heads=16, ffn=4096)

BERT_CONFIGS = {c.name: c for c in (BERT_TINY, BERT_MINI, BERT_BASE, BERT_LARGE)}


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    """A small ResNet-ish stack: stem + N sparse residual blocks.

    Full ResNet-50/152 *workload accounting* lives in the rust graph IR
    (`graph/models.rs`); this JAX model is the executable kernel-level
    equivalent, sized so the interpret-mode artifact runs in seconds.
    """

    name: str
    channels: int
    blocks: int
    image: int = 32
    classes: int = 10


RESNET_MINI = ResNetConfig("resnet_mini", channels=64, blocks=3)
RESNET_CONFIGS = {RESNET_MINI.name: RESNET_MINI}


# ============================ BERT encoder =================================

def _pack_linear(rng: np.random.Generator, k: int, n: int, sparsity: int,
                 scale: float | None = None) -> dict[str, np.ndarray]:
    """Init a dense [k, n] projection and pack it block-balanced."""
    scale = scale if scale is not None else 1.0 / np.sqrt(k)
    w = (rng.standard_normal((k, n)) * scale).astype(np.float32)
    v, i = pack.pack_dense(w, sparsity)
    return {"values": v, "indices": i, "bias": np.zeros((n,), np.float32)}


def bert_params(cfg: BertConfig, sparsity: int, seed: int = 0) -> dict[str, Any]:
    """Random-init BERT parameters with every projection packed at `sparsity`."""
    rng = np.random.default_rng(seed)
    h, f = cfg.hidden, cfg.ffn
    params: dict[str, Any] = {
        "embed": (rng.standard_normal((cfg.vocab, h)) * 0.02).astype(np.float32),
        "pos": (rng.standard_normal((cfg.max_seq, h)) * 0.02).astype(np.float32),
        "cls_w": (rng.standard_normal((h, cfg.classes)) * 0.02).astype(np.float32),
        "cls_b": np.zeros((cfg.classes,), np.float32),
        "layers": [],
    }
    for _ in range(cfg.layers):
        params["layers"].append({
            "q": _pack_linear(rng, h, h, sparsity),
            "k": _pack_linear(rng, h, h, sparsity),
            "v": _pack_linear(rng, h, h, sparsity),
            "o": _pack_linear(rng, h, h, sparsity),
            "ffn_up": _pack_linear(rng, h, f, sparsity),
            "ffn_down": _pack_linear(rng, f, h, sparsity),
            "ln1_g": np.ones((h,), np.float32), "ln1_b": np.zeros((h,), np.float32),
            "ln2_g": np.ones((h,), np.float32), "ln2_b": np.zeros((h,), np.float32),
        })
    return params


def _proj(x2d: jax.Array, p: dict, act: str = "none") -> jax.Array:
    """One packed projection through the SPU kernel. x2d: [M, K]."""
    return sparse_matmul(x2d, jnp.asarray(p["values"]), jnp.asarray(p["indices"]),
                         jnp.asarray(p["bias"]), act=act)


def bert_encoder_layer(x: jax.Array, lp: dict, cfg: BertConfig) -> jax.Array:
    """One post-LN encoder layer. x: [B, S, H] → [B, S, H].

    SPU: q/k/v/o + FFN projections (sparse). VPU: attention einsums,
    residual adds, layernorm moments. Activation engine: softmax, GELU
    (GELU fused into the FFN-up matmul epilogue — paper §2 item iii).
    """
    b, s, h = x.shape
    x2 = x.reshape(b * s, h)
    q = _proj(x2, lp["q"]).reshape(b, s, cfg.heads, cfg.head_dim)
    k = _proj(x2, lp["k"]).reshape(b, s, cfg.heads, cfg.head_dim)
    v = _proj(x2, lp["v"]).reshape(b, s, cfg.heads, cfg.head_dim)
    # activation×activation matmuls: dense work (no weights to prune) — the
    # paper's source of sublinear BERT scaling.
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(cfg.head_dim)
    probs = softmax_engine(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b * s, h)
    attn = _proj(ctx, lp["o"])
    x = layernorm_ref((x2 + attn).reshape(b, s, h), lp["ln1_g"], lp["ln1_b"])
    x2 = x.reshape(b * s, h)
    ff = _proj(_proj(x2, lp["ffn_up"], act="gelu"), lp["ffn_down"])
    return layernorm_ref((x2 + ff).reshape(b, s, h), lp["ln2_g"], lp["ln2_b"])


def bert_forward(params: dict, token_ids: jax.Array, cfg: BertConfig) -> jax.Array:
    """Full encoder: token ids [B, S] → classifier logits [B, classes].

    The embedding gather is the paper's dedicated embedding-lookup engine.
    """
    b, s = token_ids.shape
    x = jnp.take(jnp.asarray(params["embed"]), token_ids, axis=0)
    x = x + jnp.asarray(params["pos"])[None, :s, :]
    for lp in params["layers"]:
        x = bert_encoder_layer(x, lp, cfg)
    pooled = x[:, 0, :]  # [CLS]
    return pooled @ jnp.asarray(params["cls_w"]) + jnp.asarray(params["cls_b"])


def bert_hidden_states(params: dict, token_ids: jax.Array, cfg: BertConfig):
    """Per-layer hidden states — used by the distillation pruning objective."""
    b, s = token_ids.shape
    x = jnp.take(jnp.asarray(params["embed"]), token_ids, axis=0)
    x = x + jnp.asarray(params["pos"])[None, :s, :]
    hs = [x]
    for lp in params["layers"]:
        x = bert_encoder_layer(x, lp, cfg)
        hs.append(x)
    logits = x[:, 0, :] @ jnp.asarray(params["cls_w"]) + jnp.asarray(params["cls_b"])
    return logits, hs


# ============================ ResNet stack =================================

def resnet_params(cfg: ResNetConfig, sparsity: int, seed: int = 0) -> dict[str, Any]:
    """Random-init the mini ResNet with every conv packed at `sparsity`."""
    rng = np.random.default_rng(seed)
    c = cfg.channels

    def conv(kh, kw, cin, cout):
        w = (rng.standard_normal((kh, kw, cin, cout)) / np.sqrt(kh * kw * cin)
             ).astype(np.float32)
        v, i = pack.pack_dense(w.reshape(kh * kw * cin, cout), sparsity)
        return {"values": v, "indices": i, "bias": np.zeros((cout,), np.float32),
                "kh": kh, "kw": kw}

    return {
        # stem reduction dim = 3·3·32 after channel-pad of RGB to 32 (=BLOCK)
        "stem": conv(3, 3, 32, c),
        "blocks": [
            {"c1": conv(3, 3, c, c), "c2": conv(3, 3, c, c)}
            for _ in range(cfg.blocks)
        ],
        "head_w": (rng.standard_normal((c, cfg.classes)) * 0.05).astype(np.float32),
        "head_b": np.zeros((cfg.classes,), np.float32),
    }


def _conv(x: jax.Array, p: dict, stride: int = 1, act: str = "none") -> jax.Array:
    return sparse_conv2d(x, jnp.asarray(p["values"]), jnp.asarray(p["indices"]),
                         jnp.asarray(p["bias"]), kh=p["kh"], kw=p["kw"],
                         stride=stride, padding=p["kh"] // 2, act=act)


def resnet_forward(params: dict, images: jax.Array, cfg: ResNetConfig) -> jax.Array:
    """images [B, H, W, 3] → logits [B, classes]; channel-pads RGB to 32."""
    b, h, w, cin = images.shape
    x = jnp.pad(images, ((0, 0), (0, 0), (0, 0), (0, 32 - cin)))
    x = _conv(x, params["stem"], act="relu")
    for blk in params["blocks"]:
        y = _conv(x, blk["c1"], act="relu")
        y = _conv(y, blk["c2"])
        x = jnp.maximum(x + y, 0.0)  # residual + relu (VPU elementwise)
    pooled = jnp.mean(x, axis=(1, 2))  # global average pool
    return pooled @ jnp.asarray(params["head_w"]) + jnp.asarray(params["head_b"])


# ======================= workload accounting ==============================

def bert_flops(cfg: BertConfig, batch: int, seq: int, sparsity: int) -> dict[str, float]:
    """FLOPs of one forward pass, split by engine — mirrored in rust
    `graph::models` (keep in sync; asserted equal in integration tests)."""
    h, f, l = cfg.hidden, cfg.ffn, cfg.layers
    m = batch * seq
    proj = 2 * m * h * h * 4 / sparsity          # q,k,v,o
    ffn = 2 * m * h * f * 2 / sparsity           # up, down
    attn = 2 * batch * cfg.heads * seq * seq * cfg.head_dim * 2  # qk^T, pv
    other = m * h * 20.0                          # LN, residual, softmax misc
    return {
        "spu_sparse": l * (proj + ffn),
        "spu_dense": l * attn,
        "vpu": l * other,
        "total": l * (proj + ffn + attn + other),
    }
