"""AOT compile path: lower each (model, sparsity, batch) variant to HLO text.

This is the ONLY bridge between Python and the rust runtime.  It runs once
(`make artifacts`); afterwards the rust binary is self-contained.

Interchange format is **HLO text**, never a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly.  Lowering goes through
``return_tuple=True`` so the rust side unwraps with ``to_tuple1()``.

Weights are embedded as HLO constants: one executable per model variant,
fed only runtime inputs (token ids / images).  A pleasant side effect is
that the artifact *file size* scales ~1/s with sparsity — the paper's
memory-footprint claim, checked by ``tests/test_aot.py`` and reported in
the manifest.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

DTYPE_NAMES = {np.dtype(np.int32): "s32", np.dtype(np.float32): "f32"}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the embedded weights ARE the model — without it
    # the text elides them as `constant({...})` and the rust parser fails.
    return comp.as_hlo_text(print_large_constants=True)


@dataclasses.dataclass
class Variant:
    """One compiled model variant = one artifact = one rust executable."""

    name: str
    family: str  # "bert" | "resnet"
    model: str
    sparsity: int
    batch: int
    seq: int = 0  # bert only
    image: int = 0  # resnet only


def default_variants() -> list[Variant]:
    """The artifact set the rust examples/benches/tests expect.

    bert_tiny covers the full sparsity sweep (the serving e2e executes it
    on the CPU interpret path, so it must run in milliseconds); bert_mini
    at two sparsities exercises a second size point.
    """
    vs: list[Variant] = []
    for s in (1, 2, 8, 32):
        vs.append(Variant(f"bert_tiny_s{s}_b1", "bert", "bert_tiny", s, 1, seq=128))
    for s in (1, 8):
        vs.append(Variant(f"bert_tiny_s{s}_b8", "bert", "bert_tiny", s, 8, seq=128))
    for s in (1, 8):
        vs.append(Variant(f"resnet_mini_s{s}_b1", "resnet", "resnet_mini", s, 1, image=32))
    return vs


def lower_variant(v: Variant, seed: int = 0):
    """Build params, close over them, lower. Returns (hlo_text, meta)."""
    if v.family == "bert":
        cfg = M.BERT_CONFIGS[v.model]
        params = M.bert_params(cfg, v.sparsity, seed=seed)

        def fn(token_ids):
            return (M.bert_forward(params, token_ids, cfg),)

        spec = jax.ShapeDtypeStruct((v.batch, v.seq), jnp.int32)
        inputs = [{"name": "token_ids", "shape": [v.batch, v.seq], "dtype": "s32"}]
        outputs = [{"shape": [v.batch, cfg.classes], "dtype": "f32"}]
        flops = M.bert_flops(cfg, v.batch, v.seq, v.sparsity)
        dense_params = cfg.param_count()
    elif v.family == "resnet":
        cfg = M.RESNET_CONFIGS[v.model]
        params = M.resnet_params(cfg, v.sparsity, seed=seed)

        def fn(images):
            return (M.resnet_forward(params, images, cfg),)

        spec = jax.ShapeDtypeStruct((v.batch, cfg.image, cfg.image, 3), jnp.float32)
        inputs = [{
            "name": "images",
            "shape": [v.batch, cfg.image, cfg.image, 3],
            "dtype": "f32",
        }]
        outputs = [{"shape": [v.batch, cfg.classes], "dtype": "f32"}]
        flops = {}
        dense_params = 0
    else:
        raise ValueError(f"unknown family {v.family!r}")

    lowered = jax.jit(fn).lower(spec)
    text = to_hlo_text(lowered)
    meta = {
        "name": v.name, "file": f"{v.name}.hlo.txt",
        "family": v.family, "model": v.model,
        "sparsity": v.sparsity, "batch": v.batch,
        "seq": v.seq, "image": v.image,
        "inputs": inputs, "outputs": outputs,
        "flops": flops, "dense_params": dense_params,
        "hlo_bytes": len(text),
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
    }
    return text, meta


def golden_outputs(v: Variant, seed: int = 0) -> dict:
    """Reference outputs for the rust integration tests: run the same jitted
    function on a deterministic input and record input + output values."""
    rng = np.random.default_rng(42)
    if v.family == "bert":
        cfg = M.BERT_CONFIGS[v.model]
        params = M.bert_params(cfg, v.sparsity, seed=seed)
        x = rng.integers(0, cfg.vocab, size=(v.batch, v.seq), dtype=np.int32)
        y = np.asarray(M.bert_forward(params, jnp.asarray(x), cfg))
        return {"input": x.reshape(-1).tolist(), "output": y.reshape(-1).tolist()}
    cfg = M.RESNET_CONFIGS[v.model]
    params = M.resnet_params(cfg, v.sparsity, seed=seed)
    x = rng.standard_normal((v.batch, cfg.image, cfg.image, 3)).astype(np.float32)
    y = np.asarray(M.resnet_forward(params, jnp.asarray(x), cfg))
    return {"input": x.reshape(-1).tolist(), "output": y.reshape(-1).tolist()}


def build_all(outdir: pathlib.Path, with_golden: bool = True,
              variants: list[Variant] | None = None) -> dict:
    outdir.mkdir(parents=True, exist_ok=True)
    variants = variants if variants is not None else default_variants()
    manifest = {"version": 1, "built_unix": int(time.time()), "artifacts": []}
    for v in variants:
        t0 = time.time()
        text, meta = lower_variant(v)
        (outdir / meta["file"]).write_text(text)
        if with_golden:
            golden = golden_outputs(v)
            gfile = f"{v.name}.golden.json"
            (outdir / gfile).write_text(json.dumps(golden))
            meta["golden"] = gfile
        meta["lower_seconds"] = round(time.time() - t0, 2)
        manifest["artifacts"].append(meta)
        print(f"  {v.name}: {meta['hlo_bytes']/1e6:.2f} MB HLO, "
              f"{meta['lower_seconds']}s")
    (outdir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts", help="artifact directory")
    ap.add_argument("--no-golden", action="store_true",
                    help="skip executing golden-output reference runs")
    args = ap.parse_args()
    out = pathlib.Path(args.outdir)
    print(f"AOT-lowering {len(default_variants())} variants -> {out}")
    manifest = build_all(out, with_golden=not args.no_golden)
    total = sum(a["hlo_bytes"] for a in manifest["artifacts"])
    print(f"done: {len(manifest['artifacts'])} artifacts, {total/1e6:.1f} MB total")


if __name__ == "__main__":
    main()
