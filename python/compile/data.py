"""Synthetic GLUE-proxy tasks for the sparsification experiments.

DESIGN.md §Substitutions item 3: we cannot ship GLUE or a pretrained BERT,
so Table 1 / Fig. 3 accuracies are reproduced on synthetic sequence
classification tasks whose *relative* difficulty ordering mirrors the GLUE
dev sets the paper uses: a large entailment-ish task (proxy-MNLI), a QA-ish
one (proxy-QNLI), two small paraphrase/entailment sets that overfit easily
(proxy-MRPC, proxy-RTE), and a small noisy acceptability set (proxy-CoLA).

Generation: each task has a hidden "teacher rule" — a set of salient token
patterns whose (order-sensitive) co-occurrence statistics determine the
label — plus label noise. Tasks are learnable by a small transformer but
not saturable, leaving headroom for pruning methods to differentiate.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    name: str
    glue_analog: str
    vocab: int
    seq: int
    classes: int
    train: int
    test: int
    #: fraction of labels flipped (caps achievable accuracy)
    noise: float
    #: number of salient tokens in the hidden rule (difficulty)
    salient: int
    seed: int


# Tuned so a 2-layer/128-hidden transformer lands in the 75–92% band and
# the small tasks show an overfitting gap — the Table 1 dynamics.
TASKS = [
    TaskSpec("proxy_mnli", "MNLI-m", vocab=1024, seq=64, classes=2,
             train=6000, test=1500, noise=0.08, salient=24, seed=11),
    TaskSpec("proxy_qnli", "QNLI", vocab=1024, seq=64, classes=2,
             train=5000, test=1200, noise=0.06, salient=16, seed=22),
    TaskSpec("proxy_mrpc", "MRPC", vocab=1024, seq=64, classes=2,
             train=1200, test=600, noise=0.10, salient=12, seed=33),
    TaskSpec("proxy_rte", "RTE", vocab=1024, seq=64, classes=2,
             train=800, test=500, noise=0.12, salient=10, seed=44),
    TaskSpec("proxy_cola", "CoLA", vocab=1024, seq=64, classes=2,
             train=1500, test=700, noise=0.15, salient=8, seed=55),
]

TASK_BY_NAME = {t.name: t for t in TASKS}


def make_task(spec: TaskSpec):
    """Generate (x_train, y_train, x_test, y_test) for a task.

    Rule: draw `salient` special tokens with signed weights; the label is
    the sign of the position-weighted salient-token score (tokens in the
    first half count 2×, so the model must use positions, not just
    bag-of-words), then flipped with prob `noise`.
    """
    rng = np.random.default_rng(spec.seed)
    salient = rng.choice(spec.vocab, size=spec.salient, replace=False)
    weights = rng.standard_normal(spec.salient)
    weights += 0.5 * np.sign(weights)  # keep weights away from 0 (margin)

    def gen(n: int, seed: int):
        r = np.random.default_rng(seed)
        x = r.integers(0, spec.vocab, size=(n, spec.seq), dtype=np.int32)
        # plant a healthy density of salient tokens so the signal is strong
        n_plant = max(6, spec.seq // 6)
        planted = r.integers(0, spec.salient, size=(n, n_plant))
        for i in range(n):
            pos = r.choice(spec.seq, size=n_plant, replace=False)
            x[i, pos] = salient[planted[i]]
        half = spec.seq // 2
        score = np.zeros(n)
        for tok, w in zip(salient, weights):
            first = (x[:, :half] == tok).sum(axis=1)
            second = (x[:, half:] == tok).sum(axis=1)
            score += w * (2.0 * first + second)
        y = (score > np.median(score)).astype(np.int32)
        flip = r.random(n) < spec.noise
        y = np.where(flip, 1 - y, y)
        return x, y

    x_tr, y_tr = gen(spec.train, spec.seed * 7 + 1)
    x_te, y_te = gen(spec.test, spec.seed * 7 + 2)
    return x_tr, y_tr, x_te, y_te


def batches(x, y, batch: int, seed: int, epochs: int = 1):
    """Shuffled minibatch iterator (drops the ragged tail)."""
    n = x.shape[0]
    rng = np.random.default_rng(seed)
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            idx = order[i : i + batch]
            yield x[idx], y[idx]
