"""Sparse 2-D convolution on the SPU — im2col onto the sparse matmul kernel.

The paper (§2, item iii) says the SPU "natively supports convolution and
matrix multiplication"; architecturally Antoum's conv path is the same
sparse MAC array fed by an address generator that walks input patches.  We
express that exactly: an im2col patch extraction (the address generator,
plain jnp data movement that XLA fuses) feeding `sparse_matmul` (the MAC
array).  The weight tensor is packed along its *flattened reduction dim*
``kh·kw·Cin``, so the same block-balanced format covers conv and matmul —
one compressed layout for the whole chip, as the paper claims.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import pack
from .sparse_matmul import sparse_matmul


def conv_reduction_dim(kh: int, kw: int, cin: int) -> int:
    """The packed reduction dim of a conv weight (must tile by pack.BLOCK)."""
    return kh * kw * cin


def pack_conv_weight(w, sparsity: int, block: int = pack.BLOCK):
    """Pack an HWIO conv weight [kh, kw, Cin, Cout] to block-balanced form.

    Returns (values, indices) of shape [kh·kw·Cin / s, Cout].
    """
    kh, kw, cin, cout = w.shape
    return pack.pack_dense(
        jnp.asarray(w).reshape(kh * kw * cin, cout), sparsity, block
    )


def _im2col(x: jax.Array, kh: int, kw: int, stride: int, padding: int):
    """Extract patches: NHWC [B,H,W,C] → [B·Ho·Wo, kh·kw·C] (+ out spatial).

    This is the SPU's address-generator stage; XLA lowers it to strided
    slices/pads that fuse with the surrounding program.
    """
    b, h, w, c = x.shape
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    ho = (h + 2 * padding - kh) // stride + 1
    wo = (w + 2 * padding - kw) // stride + 1
    # Gather kh·kw shifted views; cheaper to trace than conv_general_dilated
    # patch extraction and keeps the reduction-dim order (kh, kw, C) aligned
    # with pack_conv_weight's flattening.
    cols = []
    for i in range(kh):
        for j in range(kw):
            v = jax.lax.slice(
                x,
                (0, i, j, 0),
                (b, i + (ho - 1) * stride + 1, j + (wo - 1) * stride + 1, c),
                (1, stride, stride, 1),
            )  # [B, Ho, Wo, C]
            cols.append(v)
    patches = jnp.stack(cols, axis=3)  # [B, Ho, Wo, kh·kw, C]
    return patches.reshape(b * ho * wo, kh * kw * c), ho, wo


@functools.partial(
    jax.jit,
    static_argnames=("kh", "kw", "stride", "padding", "act", "tile_m", "tile_n"),
)
def sparse_conv2d(
    x: jax.Array,
    values: jax.Array,
    indices: jax.Array,
    bias: jax.Array | None = None,
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    padding: int = 0,
    act: str = "none",
    tile_m: int = 128,
    tile_n: int = 128,
):
    """Sparse conv: act(conv2d(x, unpack(w)) + bias), NHWC in/out.

    x: [B, H, W, Cin]; (values, indices): packed [kh·kw·Cin/s, Cout].
    B·Ho·Wo must tile by tile_m and Cout by tile_n (model.py pads batch).
    """
    b = x.shape[0]
    cout = values.shape[1]
    patches, ho, wo = _im2col(x, kh, kw, stride, padding)
    m = patches.shape[0]
    # Pad the GEMM M-dim up to the tile; sliced away after.
    m_pad = (-m) % tile_m
    if m_pad:
        patches = jnp.pad(patches, ((0, m_pad), (0, 0)))
    y = sparse_matmul(
        patches, values, indices, bias,
        act=act, tile_m=tile_m, tile_n=tile_n,
    )
    if m_pad:
        y = y[:m]
    return y.reshape(b, ho, wo, cout)
