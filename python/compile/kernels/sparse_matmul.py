"""Pallas block-balanced sparse matmul — the SPU hot path (Layer 1).

Computes ``y = act(x @ W + b)`` where ``W`` [K, N] is stored compressed as
``(values, indices)`` per ``pack.py``.  The kernel only touches the stored
non-zeros, so compute *and* weight traffic scale ~``1/s`` — the property
the S4 paper's Fig. 2 measures.

Hardware mapping (DESIGN.md §Hardware-Adaptation)
-------------------------------------------------
The paper's SPU is a systolic array whose weight buffer holds only
non-zeros plus in-block offsets; each MAC lane gathers the activation
operand through a small crossbar indexed by the offset.  On TPU we express
the same schedule as:

* grid = (M/TM, N/TN): one program instance per output tile — the
  HBM↔VMEM schedule the GPU/ASIC design did with threadblocks/banks is a
  BlockSpec here;
* per instance, the ``[TM, K]`` activation slab and the ``[K/s, TN]``
  compressed weight slab are VMEM-resident;
* the inner ``fori_loop`` over the ``K/s`` non-zero slots performs a
  row-gather of ``x`` (the crossbar) and a rank-1-style multiply-accumulate
  (the MAC lanes) — ``K/s`` iterations of O(TM·TN) work = exactly the
  sparse FLOP count.

``interpret=True`` always (CPU PJRT cannot run Mosaic custom-calls); the
kernel still lowers into the surrounding jax program's HLO so the rust
runtime executes one fused module.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default output tile. TM×TN accumulator (f32) = 128·128·4 = 64 KiB, far
# under VMEM; the dominant VMEM tenant is the x slab (TM×K) — see
# vmem_footprint() which aot.py checks per variant.
TILE_M = 128
TILE_N = 128

ACTIVATIONS = ("none", "relu", "gelu")


def _apply_act(y: jax.Array, act: str) -> jax.Array:
    """Fused activation-engine epilogue (paper §2 item iii)."""
    if act == "none":
        return y
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "gelu":
        # tanh approximation — what a LUT-based activation engine evaluates.
        c = jnp.sqrt(2.0 / jnp.pi).astype(y.dtype)
        return 0.5 * y * (1.0 + jnp.tanh(c * (y + 0.044715 * y * y * y)))
    raise ValueError(f"unknown activation {act!r}; expected one of {ACTIVATIONS}")


def _spmm_kernel(x_ref, vals_ref, idx_ref, b_ref, o_ref, *, act: str):
    """One (TM, TN) output tile.

    x_ref:    [TM, K]    activations (VMEM slab)
    vals_ref: [Kc, TN]   compressed weights, Kc = K/s
    idx_ref:  [Kc, TN]   absolute K-row index of each weight (int32)
    b_ref:    [1, TN]    bias
    o_ref:    [TM, TN]   output tile
    """
    x = x_ref[...]  # load the slab once; gathers below hit VMEM
    vals = vals_ref[...]
    idx = idx_ref[...]
    kc = vals.shape[0]

    def body(r, acc):
        cols = idx[r, :]  # [TN] — per-output-column gather addresses
        xg = jnp.take(x, cols, axis=1)  # [TM, TN] activation crossbar
        return acc + xg * vals[r, :][None, :]  # MAC lanes

    acc = jax.lax.fori_loop(
        0, kc, body, jnp.zeros(o_ref.shape, dtype=jnp.float32)
    )
    acc = acc + b_ref[0, :][None, :].astype(jnp.float32)
    o_ref[...] = _apply_act(acc, act).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("act", "tile_m", "tile_n", "out_dtype")
)
def sparse_matmul(
    x: jax.Array,
    values: jax.Array,
    indices: jax.Array,
    bias: jax.Array | None = None,
    *,
    act: str = "none",
    tile_m: int = TILE_M,
    tile_n: int = TILE_N,
    out_dtype=None,
):
    """``act(x @ unpack(values, indices) + bias)`` touching only non-zeros.

    Args:
      x:       [M, K] activations (any float dtype).
      values:  [Kc, N] kept weights (Kc = K/s).
      indices: [Kc, N] absolute row ids into K (int32).
      bias:    [N] or None.
      act:     "none" | "relu" | "gelu" — fused epilogue.

    Shapes must tile evenly: M % tile_m == 0, N % tile_n == 0 (callers pad;
    `model.py` sizes everything to multiples of 128).
    """
    m, k = x.shape
    kc, n = values.shape
    if indices.shape != (kc, n):
        raise ValueError(f"indices {indices.shape} != values {values.shape}")
    # Clamp tiles to the problem (small conv channel counts, tiny heads);
    # divisibility is still required after clamping.
    tile_m = min(tile_m, m)
    tile_n = min(tile_n, n)
    if m % tile_m or n % tile_n:
        raise ValueError(f"M={m}, N={n} must tile by ({tile_m}, {tile_n})")
    if act not in ACTIVATIONS:
        raise ValueError(f"unknown activation {act!r}")
    if bias is None:
        bias = jnp.zeros((n,), dtype=x.dtype)
    out_dtype = out_dtype or x.dtype
    bias2d = bias.reshape(1, n)
    indices = indices.astype(jnp.int32)

    grid = (m // tile_m, n // tile_n)
    return pl.pallas_call(
        functools.partial(_spmm_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, k), lambda i, j: (i, 0)),  # x slab
            pl.BlockSpec((kc, tile_n), lambda i, j: (0, j)),  # weights
            pl.BlockSpec((kc, tile_n), lambda i, j: (0, j)),  # indices
            pl.BlockSpec((1, tile_n), lambda i, j: (0, j)),  # bias
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, values, indices, bias2d)


def vmem_footprint(
    m: int, k: int, n: int, sparsity: int, *,
    tile_m: int = TILE_M, tile_n: int = TILE_N,
    act_bytes: int = 2, weight_bytes: int = 2,
) -> dict:
    """Static VMEM budget of one grid step (bytes) — the L1 perf metric.

    interpret=True gives no hardware timing, so the perf pass analyses the
    kernel structurally: slab sizes per program instance and the MXU-work
    estimate. Mirrored by rust `arch::spu` for the simulator's tile model.
    """
    kc = k // sparsity
    x_slab = tile_m * k * act_bytes
    w_slab = kc * tile_n * weight_bytes
    i_slab = kc * tile_n * 4  # int32 on TPU; ASIC stores u8 offsets
    acc = tile_m * tile_n * 4
    out = tile_m * tile_n * act_bytes
    total = x_slab + w_slab + i_slab + acc + out
    return {
        "x_slab": x_slab,
        "w_slab": w_slab,
        "idx_slab": i_slab,
        "acc": acc,
        "out": out,
        "total": total,
        "fits_16mb": total <= 16 * 1024 * 1024,
        "sparse_macs_per_tile": tile_m * tile_n * kc,
        "dense_macs_per_tile": tile_m * tile_n * k,
    }
