"""Pure-jnp oracles for every Layer-1 kernel.

Nothing here touches Pallas; these are the ground truth the pytest suite
(`python/tests/`) compares the kernels against, and the numerics the rust
integration tests assert on (golden values are generated from these).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def unpack_jnp(values: jax.Array, indices: jax.Array, k: int) -> jax.Array:
    """Dense [K, N] from compressed (values, indices) — jnp twin of pack.unpack."""
    kc, n = values.shape
    dense = jnp.zeros((k, n), dtype=values.dtype)
    cols = jnp.broadcast_to(jnp.arange(n), (kc, n))
    return dense.at[indices, cols].set(values)


def gelu_ref(x: jax.Array) -> jax.Array:
    """tanh-approximation GELU, matching the kernel's activation engine."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def apply_act_ref(y: jax.Array, act: str) -> jax.Array:
    if act == "none":
        return y
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "gelu":
        return gelu_ref(y)
    raise ValueError(f"unknown activation {act!r}")


def sparse_matmul_ref(
    x: jax.Array,
    values: jax.Array,
    indices: jax.Array,
    bias: jax.Array | None = None,
    *,
    act: str = "none",
) -> jax.Array:
    """Oracle for kernels.sparse_matmul: decompress then dense matmul in f32."""
    k = x.shape[1]
    w = unpack_jnp(values.astype(jnp.float32), indices, k)
    y = x.astype(jnp.float32) @ w
    if bias is not None:
        y = y + bias.astype(jnp.float32)[None, :]
    return apply_act_ref(y, act).astype(x.dtype)


def conv2d_ref(
    x: jax.Array, w: jax.Array, bias: jax.Array | None = None,
    *, stride: int = 1, padding: int = 0, act: str = "none",
) -> jax.Array:
    """NHWC/HWIO conv oracle (dense) for kernels.sparse_conv."""
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return apply_act_ref(y, act).astype(x.dtype)


def softmax_ref(x: jax.Array, axis: int = -1) -> jax.Array:
    x32 = x.astype(jnp.float32)
    m = jnp.max(x32, axis=axis, keepdims=True)
    e = jnp.exp(x32 - m)
    return (e / jnp.sum(e, axis=axis, keepdims=True)).astype(x.dtype)


def layernorm_ref(x: jax.Array, gamma: jax.Array, beta: jax.Array,
                  eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)
