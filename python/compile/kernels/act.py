"""Activation-engine kernel — the paper's §2 item (ii).

Antoum ships a dedicated activation engine that evaluates "complex
activation functions such as GELU, and basic mathematic operators such as
exponential, log, reciprocal".  This Pallas kernel is that engine: a tiled
elementwise unit evaluating any of the supported ops, used by the L2 model
for the pieces that do NOT fuse into a matmul epilogue (e.g. softmax's exp,
layernorm's reciprocal-sqrt path when run on-engine).

The simulator twin is ``rust/src/arch/activation.rs`` — keep the op list
in sync with `arch::activation::ActOp`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ENGINE_OPS = ("gelu", "relu", "exp", "log", "reciprocal", "sigmoid", "tanh", "sqrt", "rsqrt")

# Engine lane width: one VPU/ActEngine vector register worth of lanes.
TILE = 512


def _engine_fn(x: jax.Array, op: str) -> jax.Array:
    if op == "gelu":
        c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
        return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))
    if op == "relu":
        return jnp.maximum(x, 0.0)
    if op == "exp":
        return jnp.exp(x)
    if op == "log":
        return jnp.log(x)
    if op == "reciprocal":
        return 1.0 / x
    if op == "sigmoid":
        return 1.0 / (1.0 + jnp.exp(-x))
    if op == "tanh":
        return jnp.tanh(x)
    if op == "sqrt":
        return jnp.sqrt(x)
    if op == "rsqrt":
        return jax.lax.rsqrt(x)
    raise ValueError(f"activation engine has no op {op!r}; supports {ENGINE_OPS}")


def _act_kernel(x_ref, o_ref, *, op: str):
    o_ref[...] = _engine_fn(x_ref[...], op)


@functools.partial(jax.jit, static_argnames=("op", "tile"))
def act_engine(x: jax.Array, *, op: str, tile: int = TILE) -> jax.Array:
    """Apply one activation-engine op elementwise over a flat-tileable array.

    Works on any shape; internally flattens, pads to the lane width, tiles.
    """
    if op not in ENGINE_OPS:
        raise ValueError(f"activation engine has no op {op!r}; supports {ENGINE_OPS}")
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    npad = (-n) % tile
    if npad:
        # Pad with ones: valid input for every engine op incl. log/recip.
        flat = jnp.concatenate([flat, jnp.ones((npad,), dtype=flat.dtype)])
    total = flat.shape[0]
    y = pl.pallas_call(
        functools.partial(_act_kernel, op=op),
        grid=(total // tile,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((total,), x.dtype),
        interpret=True,
    )(flat)
    if npad:
        y = y[:n]
    return y.reshape(shape)


@functools.partial(jax.jit, static_argnames=("axis",))
def softmax_engine(x: jax.Array, axis: int = -1) -> jax.Array:
    """Softmax routed through the activation engine's exp + reciprocal ops.

    The max-subtract and the row-sum run on the VPU (plain vector ops); the
    transcendentals hit the engine — matching how the simulator costs it.
    """
    x32 = x.astype(jnp.float32)
    m = jnp.max(x32, axis=axis, keepdims=True)
    e = act_engine(x32 - m, op="exp")
    s = jnp.sum(e, axis=axis, keepdims=True)
    return (e * act_engine(s, op="reciprocal")).astype(x.dtype)
