# L1: Pallas kernels for the S4/Antoum compute hot-spots.
from . import pack, ref  # noqa: F401
from .act import ENGINE_OPS, act_engine, softmax_engine  # noqa: F401
from .sparse_conv import pack_conv_weight, sparse_conv2d  # noqa: F401
from .sparse_matmul import sparse_matmul, vmem_footprint  # noqa: F401
