"""Block-balanced sparse weight packing.

This module defines the compressed weight layout shared by every layer of
the stack: the Pallas kernels (L1), the JAX models (L2), the pruning code,
and the rust substrate (``rust/src/sparse/format.rs`` mirrors it exactly —
keep the two in sync).

Layout
------
A dense weight matrix ``W`` of shape ``[K, N]`` (``K`` = reduction dim) is
*block-balanced sparse* with factor ``s`` and block size ``B`` when every
contiguous block of ``B`` rows keeps exactly ``B // s`` non-zeros per
column.  The compressed representation is two ``[K // s, N]`` arrays:

* ``values`` — the kept weights, in block order (block 0's kept rows first,
  then block 1's, ...), sorted by row index inside each block;
* ``indices`` — the **absolute** row index in ``[0, K)`` of each kept
  weight (int32).  Absolute rather than block-relative indices keep the
  kernel's gather addressing trivial; the rust side stores block-relative
  u8 offsets for footprint accounting and converts on load.

``s = 1`` degenerates to dense (indices are just ``arange(K)`` broadcast),
so a single kernel serves the whole sparsity sweep ``s ∈ {1,2,4,8,16,32}``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# Default hardware block size: one SPU weight-buffer row.  32 admits every
# sparsity factor the paper claims (up to 32x => 1 non-zero per block).
BLOCK = 32

SUPPORTED_SPARSITIES = (1, 2, 4, 8, 16, 32)


def check_pack_args(k: int, sparsity: int, block: int = BLOCK) -> None:
    """Validate (K, s, B) before packing; raises ValueError on misuse."""
    if sparsity not in SUPPORTED_SPARSITIES:
        raise ValueError(
            f"sparsity {sparsity} unsupported; SPU supports {SUPPORTED_SPARSITIES}"
        )
    if block % sparsity != 0:
        raise ValueError(f"block {block} not divisible by sparsity {sparsity}")
    if k % block != 0:
        raise ValueError(f"reduction dim {k} not divisible by block {block}")


def pack_dense(w: np.ndarray, sparsity: int, block: int = BLOCK):
    """Prune ``w`` [K, N] to block-balanced sparsity and pack it.

    Keeps the ``block // sparsity`` largest-magnitude entries of every
    (block, column) group — magnitude pruning straight into the hardware
    pattern, the paper's §4 "training from scratch" projection step.

    Returns ``(values, indices)``, both ``[K // sparsity, N]``; ``indices``
    is int32 with absolute row ids, ascending within each block.
    """
    w = np.asarray(w)
    if w.ndim != 2:
        raise ValueError(f"expected 2-D weight, got shape {w.shape}")
    k, n = w.shape
    check_pack_args(k, sparsity, block)
    keep = block // sparsity
    nblocks = k // block
    # [nblocks, block, N] view of the rows.
    wb = w.reshape(nblocks, block, n)
    # Top-`keep` magnitude rows per (block, col). argsort ascending =>
    # take the last `keep`, then re-sort by row index for coalesced loads.
    order = np.argsort(np.abs(wb), axis=1)  # [nblocks, block, n]
    kept = np.sort(order[:, block - keep :, :], axis=1)  # [nblocks, keep, n]
    values = np.take_along_axis(wb, kept, axis=1)  # [nblocks, keep, n]
    base = (np.arange(nblocks, dtype=np.int32) * block)[:, None, None]
    indices = kept.astype(np.int32) + base
    return (
        values.reshape(k // sparsity, n).astype(w.dtype),
        indices.reshape(k // sparsity, n),
    )


def unpack(values: np.ndarray, indices: np.ndarray, k: int) -> np.ndarray:
    """Decompress ``(values, indices)`` back to a dense ``[K, N]`` matrix."""
    values = np.asarray(values)
    indices = np.asarray(indices)
    if values.shape != indices.shape:
        raise ValueError(f"shape mismatch {values.shape} vs {indices.shape}")
    kc, n = values.shape
    dense = np.zeros((k, n), dtype=values.dtype)
    np.put_along_axis(dense, indices.astype(np.int64), values, axis=0)
    return dense


@partial(jax.jit, static_argnames=("sparsity", "block"))
def pack_dense_jax(w: jax.Array, sparsity: int, block: int = BLOCK):
    """JAX (differentiable-input, jit-able) variant of :func:`pack_dense`.

    Used inside the pruning training loop (straight-through projection);
    numerics match ``pack_dense`` except for tie-breaking on equal
    magnitudes.
    """
    k, n = w.shape
    check_pack_args(k, sparsity, block)
    keep = block // sparsity
    nblocks = k // block
    wb = w.reshape(nblocks, block, n)
    order = jnp.argsort(jnp.abs(wb), axis=1)
    kept = jnp.sort(order[:, block - keep :, :], axis=1)
    values = jnp.take_along_axis(wb, kept, axis=1)
    base = (jnp.arange(nblocks, dtype=jnp.int32) * block)[:, None, None]
    indices = kept.astype(jnp.int32) + base
    return values.reshape(k // sparsity, n), indices.reshape(k // sparsity, n)


def block_balanced_mask(w: np.ndarray, sparsity: int, block: int = BLOCK) -> np.ndarray:
    """Boolean keep-mask of the block-balanced pattern for ``w`` [K, N]."""
    values, indices = pack_dense(w, sparsity, block)
    mask = np.zeros(w.shape, dtype=bool)
    np.put_along_axis(mask, indices.astype(np.int64), True, axis=0)
    return mask


def is_block_balanced(w: np.ndarray, sparsity: int, block: int = BLOCK) -> bool:
    """True iff every (block, column) group of ``w`` has ≤ B/s non-zeros."""
    k, n = w.shape
    check_pack_args(k, sparsity, block)
    nz = (w.reshape(k // block, block, n) != 0).sum(axis=1)
    return bool((nz <= block // sparsity).all())
