"""Sparsification methods (paper §4).

Two regimes, matching the paper's taxonomy:

* **training from scratch** — magnitude projection onto the hardware
  pattern with a gradual (Zhu & Gupta) sparsity schedule: the optimizer
  solves the task under a sparsity constraint, using the dense model only
  as initialization (straight-through projection each step);
* **pretrain–finetune** — prune while distilling both logits and
  intermediate feature maps from the dense teacher (the method of Xu et
  al. [17] the paper adopts for SparseBERT), which preserves "transferred
  knowledge" and resolves the overfit-vs-underfit tension of §4.

Training differentiates through *masked dense* ops (mathematically
identical to the compressed kernel; see tests) — the Pallas kernel is the
inference path, packed from the trained masks at export time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import pack


def block_balanced_mask_jax(w: jax.Array, sparsity: int) -> jax.Array:
    """0/1 keep-mask of the block-balanced top-|w| pattern (jit-able)."""
    if sparsity <= 1:
        return jnp.ones_like(w)
    k, n = w.shape
    values, indices = pack.pack_dense_jax(w, sparsity)
    mask = jnp.zeros((k, n), dtype=w.dtype)
    cols = jnp.broadcast_to(jnp.arange(n), indices.shape)
    return mask.at[indices, cols].set(1.0)


def gradual_fraction(step: int, begin: int, end: int, target: float) -> float:
    """Zhu–Gupta cubic ramp (python mirror of rust `PruneSchedule`)."""
    if step <= begin:
        return 0.0
    if step >= end:
        return target
    p = (step - begin) / (end - begin)
    return target + (0.0 - target) * (1.0 - p) ** 3


def factor_at(step: int, begin: int, end: int, final_factor: int) -> int:
    """Largest supported hardware factor whose fraction ≤ the ramp value."""
    f = gradual_fraction(step, begin, end, 1.0 - 1.0 / final_factor)
    best = 1
    for s in pack.SUPPORTED_SPARSITIES:
        if s <= final_factor and 1.0 - 1.0 / s <= f + 1e-12:
            best = s
    return best


def prunable_keys(params: dict) -> list[tuple]:
    """Paths of weight matrices that get pruned (encoder projections only —
    embeddings and the tiny classifier head stay dense, like the paper)."""
    keys = []
    for li, _ in enumerate(params["layers"]):
        for name in ("q", "k", "v", "o", "ffn_up", "ffn_down"):
            keys.append(("layers", li, name))
    return keys


def get_path(params: dict, path: tuple):
    x = params
    for p in path:
        x = x[p]
    return x


def compute_masks(params: dict, sparsity: int) -> dict[tuple, jax.Array]:
    """Fresh block-balanced masks for every prunable weight at `sparsity`."""
    return {
        path: block_balanced_mask_jax(get_path(params, path), sparsity)
        for path in prunable_keys(params)
    }


def apply_masks(params: dict, masks: dict[tuple, jax.Array] | None) -> dict:
    """Return params with masked weights (non-destructive)."""
    if not masks:
        return params
    import copy

    out = copy.copy(params)
    out["layers"] = [dict(l) for l in params["layers"]]
    for (root, li, name), m in masks.items():
        assert root == "layers"
        out["layers"][li] = dict(out["layers"][li])
        out["layers"][li][name] = out["layers"][li][name] * m
    return out


def sparsity_achieved(params: dict, masks: dict[tuple, jax.Array]) -> float:
    """Fraction of pruned weights across all prunable matrices."""
    kept = sum(float(m.sum()) for m in masks.values())
    total = sum(m.size for m in masks.values())
    return 1.0 - kept / total


def encoder_params_count(params: dict, masks: dict | None = None) -> int:
    """Non-zero encoder weights (the Table 1 'size reduction' basis)."""
    n = 0
    for path in prunable_keys(params):
        w = get_path(params, path)
        if masks and path in masks:
            n += int(float(masks[path].sum()))
        else:
            n += w.size
    return n
