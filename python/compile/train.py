"""Training driver for the sparsification experiments (Table 1, Fig. 3).

Self-contained small-transformer trainer in pure jnp (the Pallas kernel is
the inference path; training differentiates through masked dense ops that
are numerically identical — asserted in tests). Reproduces:

* **Table 1** (``--table1``): on five GLUE-proxy tasks, compare
  - the dense teacher ("BERT-base" row),
  - structured DEPTH reduction (half the layers, logit-distilled — the
    PKD/Theseus/MiniLM/TinyBERT family's proxy),
  - structured WIDTH reduction (half the hidden size, logit-distilled),
  - SPARSE pruning at 16× with gradual magnitude pruning + logit AND
    intermediate-layer distillation (SparseBERT, method of [17]).
  The reproduced *claim* is the ranking: sparse-16× ≥ structured baselines
  in average accuracy at far larger size reduction.

* **Fig. 3 accuracy points** (``--fig3``): two model sizes trained dense,
  then prune-finetuned at s ∈ {2,4,8,16,32}; exported to
  ``artifacts/accuracy.json`` for the rust ``accuracy_frontier`` example
  (which pairs them with simulated S4/T4 throughput).

Budget: full run ≈ minutes on CPU; ``--quick`` cuts steps ~4× for CI.
"""

from __future__ import annotations

import argparse
import functools
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import prune as P


# ----------------------------- model ---------------------------------------

def init_model(seed: int, *, vocab: int, seq: int, classes: int,
               layers: int, hidden: int, ffn: int, heads: int) -> dict:
    rng = np.random.default_rng(seed)

    def mat(k, n, scale=None):
        s = scale if scale is not None else 1.0 / np.sqrt(k)
        return jnp.asarray(rng.standard_normal((k, n)) * s, jnp.float32)

    return {
        "cfg": {"vocab": vocab, "seq": seq, "classes": classes,
                "layers": layers, "hidden": hidden, "ffn": ffn, "heads": heads},
        "embed": mat(vocab, hidden, 0.05),
        "pos": mat(seq, hidden, 0.05),
        "layers": [
            {
                "q": mat(hidden, hidden), "k": mat(hidden, hidden),
                "v": mat(hidden, hidden), "o": mat(hidden, hidden),
                "ffn_up": mat(hidden, ffn), "ffn_down": mat(ffn, hidden),
                "b_q": jnp.zeros(hidden), "b_k": jnp.zeros(hidden),
                "b_v": jnp.zeros(hidden), "b_o": jnp.zeros(hidden),
                "b_up": jnp.zeros(ffn), "b_down": jnp.zeros(hidden),
                "ln1_g": jnp.ones(hidden), "ln1_b": jnp.zeros(hidden),
                "ln2_g": jnp.ones(hidden), "ln2_b": jnp.zeros(hidden),
            }
            for _ in range(layers)
        ],
        "cls_w": mat(hidden, classes, 0.05),
        "cls_b": jnp.zeros(classes),
    }


def ones_masks(params: dict) -> list[dict]:
    """Mask pytree (per layer) of ones — the dense case."""
    return [
        {n: jnp.ones_like(l[n]) for n in ("q", "k", "v", "o", "ffn_up", "ffn_down")}
        for l in params["layers"]
    ]


def masks_at(params: dict, sparsity: int) -> list[dict]:
    """Block-balanced masks for every prunable weight at `sparsity`."""
    if sparsity <= 1:
        return ones_masks(params)
    return [
        {n: P.block_balanced_mask_jax(l[n], sparsity)
         for n in ("q", "k", "v", "o", "ffn_up", "ffn_down")}
        for l in params["layers"]
    ]


def _ln(x, g, b, eps=1e-5):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def forward(params: dict, masks: list[dict], x: jax.Array, heads: int):
    """Token ids [B, S] → (logits [B, C], hidden states list)."""
    h = params["embed"][x] + params["pos"][None, : x.shape[1], :]
    b, s, hd = h.shape
    dh = hd // heads
    hiddens = [h]
    for l, m in zip(params["layers"], masks):
        x2 = h.reshape(b * s, hd)
        q = (x2 @ (l["q"] * m["q"]) + l["b_q"]).reshape(b, s, heads, dh)
        k = (x2 @ (l["k"] * m["k"]) + l["b_k"]).reshape(b, s, heads, dh)
        v = (x2 @ (l["v"] * m["v"]) + l["b_v"]).reshape(b, s, heads, dh)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(dh)
        att = jax.nn.softmax(att, axis=-1)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b * s, hd)
        o = ctx @ (l["o"] * m["o"]) + l["b_o"]
        h1 = _ln((x2 + o).reshape(b, s, hd), l["ln1_g"], l["ln1_b"])
        x3 = h1.reshape(b * s, hd)
        up = jax.nn.gelu(x3 @ (l["ffn_up"] * m["ffn_up"]) + l["b_up"])
        down = up @ (l["ffn_down"] * m["ffn_down"]) + l["b_down"]
        h = _ln((x3 + down).reshape(b, s, hd), l["ln2_g"], l["ln2_b"])
        hiddens.append(h)
    logits = h[:, 0, :] @ params["cls_w"] + params["cls_b"]
    return logits, hiddens


# --------------------------- training --------------------------------------

def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def make_step(heads: int, lr: float, distill_logits: float = 0.0,
              distill_hidden: float = 0.0, teacher_heads: int = 0,
              hidden_map: str = "none"):
    """Build a jitted train step.

    hidden_map: "same" when teacher/student hidden dims match (sparse
    pruning) — enables intermediate-layer distillation; "none" otherwise.
    """

    def loss_fn(params, masks, xb, yb, teacher, tmasks):
        logits, hiddens = forward(params, masks, xb, heads)
        ce = -jnp.mean(
            jax.nn.log_softmax(logits)[jnp.arange(yb.shape[0]), yb]
        )
        loss = ce
        if distill_logits > 0.0 and teacher is not None:
            tlogits, thiddens = forward(teacher, tmasks, xb, teacher_heads)
            t = 2.0  # distillation temperature
            kl = jnp.mean(
                jnp.sum(
                    jax.nn.softmax(tlogits / t)
                    * (jax.nn.log_softmax(tlogits / t) - jax.nn.log_softmax(logits / t)),
                    axis=-1,
                )
            )
            loss = loss + distill_logits * (t * t) * kl
            if distill_hidden > 0.0 and hidden_map == "same":
                # intermediate feature-map distillation (method of [17]):
                # match every layer's hidden states (dims identical).
                hm = sum(
                    jnp.mean((hs - ht) ** 2)
                    for hs, ht in zip(hiddens[1:], thiddens[1:])
                )
                loss = loss + distill_hidden * hm / max(1, len(hiddens) - 1)
        return loss

    @jax.jit
    def step(params, opt, masks, xb, yb, teacher, tmasks):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, masks, xb, yb, teacher, tmasks)
        )(params)
        t = opt["t"] + 1
        b1, b2, eps = 0.9, 0.999, 1e-8
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
        mh = jax.tree.map(lambda x: x / (1 - b1**t), m)
        vh = jax.tree.map(lambda x: x / (1 - b2**t), v)
        params = jax.tree.map(
            lambda p, mm, vv: p - lr * mm / (jnp.sqrt(vv) + eps), params, mh, vh
        )
        return params, {"m": m, "v": v, "t": t}, loss

    return step


def _strip_cfg(params):
    out = dict(params)
    cfg = out.pop("cfg")
    return out, cfg


def evaluate(params: dict, masks: list[dict], heads: int, x, y, batch=256) -> float:
    correct = 0
    p, _ = _strip_cfg(params) if "cfg" in params else (params, None)
    for i in range(0, x.shape[0], batch):
        xb = jnp.asarray(x[i : i + batch])
        logits, _ = forward(p, masks, xb, heads)
        correct += int((np.asarray(jnp.argmax(logits, -1)) == y[i : i + batch]).sum())
    return correct / x.shape[0]


def train_model(
    spec: D.TaskSpec,
    arch: dict,
    *,
    steps: int,
    lr: float = 3e-4,
    batch: int = 64,
    sparsity: int = 1,
    gradual_end: float = 0.6,
    teacher: dict | None = None,
    distill_logits: float = 0.0,
    distill_hidden: float = 0.0,
    seed: int = 0,
) -> tuple[dict, list[dict], float]:
    """Train one model; returns (params, final masks, test accuracy)."""
    x_tr, y_tr, x_te, y_te = D.make_task(spec)
    params = init_model(seed, vocab=spec.vocab, seq=spec.seq,
                        classes=spec.classes, **arch)
    p, cfg = _strip_cfg(params)
    heads = cfg["heads"]
    tp, tcfg = (None, None)
    tmasks = None
    hidden_map = "none"
    if teacher is not None:
        tp, tcfg = _strip_cfg(teacher)
        tmasks = ones_masks(teacher)
        if tcfg["hidden"] == cfg["hidden"] and tcfg["layers"] == cfg["layers"]:
            hidden_map = "same"
    step_fn = make_step(heads, lr, distill_logits, distill_hidden,
                        teacher_heads=tcfg["heads"] if tcfg else 0,
                        hidden_map=hidden_map)
    opt = adam_init(p)
    masks = masks_at({"layers": p["layers"]}, 1 if sparsity > 1 else sparsity)
    # epochs to cover `steps`
    epochs = max(1, (steps * batch) // max(1, x_tr.shape[0]) + 1)
    it = D.batches(x_tr, y_tr, batch, seed=seed + 1, epochs=epochs)
    prune_begin, prune_end = int(steps * 0.1), int(steps * gradual_end)
    for t, (xb, yb) in enumerate(it):
        if t >= steps:
            break
        if sparsity > 1 and t % 20 == 0:
            f = P.factor_at(t, prune_begin, prune_end, sparsity)
            masks = masks_at({"layers": p["layers"]}, f)
        p, opt, _ = step_fn(p, opt, masks, jnp.asarray(xb), jnp.asarray(yb),
                            tp, tmasks)
    if sparsity > 1:
        masks = masks_at({"layers": p["layers"]}, sparsity)
    acc = evaluate(p, masks, heads, x_te, y_te)
    p["cfg"] = cfg
    return p, masks, acc


# ------------------------------ experiments --------------------------------

TEACHER_ARCH = {"layers": 4, "hidden": 128, "ffn": 512, "heads": 4}
DEPTH_ARCH = {"layers": 2, "hidden": 128, "ffn": 512, "heads": 4}   # 2x
WIDTH_ARCH = {"layers": 4, "hidden": 64, "ffn": 256, "heads": 4}    # 4x


def encoder_size(arch: dict) -> int:
    h, f, l = arch["hidden"], arch["ffn"], arch["layers"]
    return l * (4 * h * h + 2 * h * f)


def run_table1(outdir: pathlib.Path, quick: bool = False) -> dict:
    steps = 150 if quick else 500
    rows = {}
    t_size = encoder_size(TEACHER_ARCH)
    methods = {}
    for spec in D.TASKS:
        print(f"[table1] task {spec.name} ({spec.glue_analog})")
        t0 = time.time()
        teacher, _, t_acc = train_model(spec, TEACHER_ARCH, steps=steps, seed=1)
        depth, _, d_acc = train_model(
            spec, DEPTH_ARCH, steps=steps, teacher=teacher,
            distill_logits=1.0, seed=2)
        width, _, w_acc = train_model(
            spec, WIDTH_ARCH, steps=steps, teacher=teacher,
            distill_logits=1.0, seed=3)
        sparse, smasks, s_acc = train_model(
            spec, TEACHER_ARCH, steps=steps, sparsity=16, teacher=teacher,
            distill_logits=1.0, distill_hidden=0.5, seed=4)
        frac = P.sparsity_achieved({"layers": sparse["layers"]},
                                   {("layers", i, n): smasks[i][n]
                                    for i in range(len(smasks))
                                    for n in smasks[i]})
        rows[spec.name] = {
            "glue_analog": spec.glue_analog,
            "teacher": t_acc, "depth2x": d_acc, "width4x": w_acc,
            "sparse16x": s_acc, "sparse_fraction": frac,
            "seconds": round(time.time() - t0, 1),
        }
        print(f"  teacher {t_acc:.3f} | depth2x {d_acc:.3f} | "
              f"width4x {w_acc:.3f} | sparse16x {s_acc:.3f} "
              f"({time.time()-t0:.0f}s)")
    methods = {
        "teacher": {"size_reduction": 1.0},
        "depth2x": {"size_reduction": t_size / encoder_size(DEPTH_ARCH)},
        "width4x": {"size_reduction": t_size / encoder_size(WIDTH_ARCH)},
        "sparse16x": {"size_reduction": 16.0},
    }
    avg = {m: float(np.mean([rows[t][m] for t in rows]))
           for m in ("teacher", "depth2x", "width4x", "sparse16x")}
    out = {"experiment": "table1", "tasks": rows, "methods": methods, "avg": avg}
    outdir.mkdir(parents=True, exist_ok=True)
    (outdir / "table1.json").write_text(json.dumps(out, indent=1))
    print("\nTable 1 (proxy) — average accuracy:")
    for m, a in avg.items():
        print(f"  {m:<10} {a:.3f}  (size reduction "
              f"{methods[m]['size_reduction']:.1f}x)")
    return out


FIG3_SIZES = {
    "bert_proxy_small": {"layers": 2, "hidden": 128, "ffn": 512, "heads": 4},
    "bert_proxy_large": {"layers": 4, "hidden": 256, "ffn": 1024, "heads": 4},
}
FIG3_SPARSITIES = [1, 2, 4, 8, 16]


def run_fig3(outdir: pathlib.Path, quick: bool = False) -> dict:
    steps = 150 if quick else 500
    spec = D.TASK_BY_NAME["proxy_mnli"]
    points = []
    for name, arch in FIG3_SIZES.items():
        teacher, _, dense_acc = train_model(spec, arch, steps=steps, seed=5)
        points.append({"model": name, "sparsity": 1, "accuracy": dense_acc})
        print(f"[fig3] {name} dense: {dense_acc:.3f}")
        for s in FIG3_SPARSITIES[1:]:
            _, _, acc = train_model(
                spec, arch, steps=steps, sparsity=s, teacher=teacher,
                distill_logits=1.0, distill_hidden=0.5, seed=6 + s)
            points.append({"model": name, "sparsity": s, "accuracy": acc})
            print(f"[fig3] {name} s={s}: {acc:.3f}")
    out = {"experiment": "fig3_accuracy", "task": spec.name, "points": points}
    outdir.mkdir(parents=True, exist_ok=True)
    (outdir / "accuracy.json").write_text(json.dumps(out, indent=1))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--table1", action="store_true")
    ap.add_argument("--fig3", action="store_true")
    ap.add_argument("--quick", action="store_true", help="~4x fewer steps")
    ap.add_argument("--outdir", default="../artifacts")
    args = ap.parse_args()
    out = pathlib.Path(args.outdir)
    if not (args.table1 or args.fig3):
        ap.error("pick --table1 and/or --fig3")
    if args.table1:
        run_table1(out, quick=args.quick)
    if args.fig3:
        run_fig3(out, quick=args.quick)


if __name__ == "__main__":
    main()
