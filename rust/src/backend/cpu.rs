//! CPU sparse backend: real block-balanced sparse compute on the serving
//! path — the "CPU fallback path of the coordinator" the sparse substrate
//! always promised, now implementing [`InferenceBackend`].
//!
//! Per artifact it builds a *distilled sparse network*: deterministic
//! weights sized from the artifact's model graph (`graph::models`),
//! magnitude-pruned to the manifest's sparsity via
//! [`BlockBalanced::from_dense`], packed once with
//! [`BlockBalanced::pack`], and executed batch-by-batch through the
//! parallel tiled kernel [`spmm_tiled_into`] with its fused
//! bias+activation epilogue. Unlike [`SimBackend`](crate::backend::SimBackend)'s hashed
//! pseudo-outputs, logits here are the product of actual sparse
//! matmuls — so end-to-end tests exercise the numeric hot path, and the
//! serving benches measure real compute.
//!
//! Shape of the distilled network (per artifact):
//! 1. *featurize* — every input tensor is folded into a `hidden`-wide
//!    feature row through a deterministic embedding table (token ids
//!    gather rows; f32 payloads take value-weighted rows), mirroring the
//!    Embed op that fronts the real graphs;
//! 2. *trunk* — `DEPTH` block-balanced sparse layers `hidden → hidden`
//!    with fused Gelu, pruned at the artifact's sparsity tier;
//! 3. *heads* — one sparse layer `hidden → sample_elems` per output
//!    spec, no activation (classifier logits).
//!
//! `hidden` is taken from the model graph's final MatMul reduction width
//! (BERT's hidden size, ResNet's pooled feature width), capped so
//! construction stays cheap; weights are seeded from the model name, so
//! every batch/sparsity variant of a model shares the same dense weights
//! and differs only by pruning tier — exactly the artifact-variant
//! relationship the router assumes.
//!
//! Everything is deterministic: same manifest → same weights → bitwise
//! identical logits, for any thread count (the tiled kernel reduces in a
//! fixed order). The backend-conformance suite runs against this type in
//! `rust/tests/backend_conformance.rs`.
//!
//! **Precision**: every layer carries both the f32 packed weights and
//! their INT8 quantized twin (same pruned matrix through
//! `prune → per-channel calibrate → pack`). [`Precision::Int8`] serves
//! through [`qspmm_tiled_into`] — i32 accumulation, fused
//! `dequant → bias → activation` epilogue — which is the paper's
//! headline sparsity×quantization composition. The mode is chosen per
//! artifact by the manifest's `"precision"` field and can be forced
//! process-wide with [`CpuSparseBackend::with_precision`]
//! (`s4 serve --precision int8`). Int8 logits stay within the
//! [`CpuSparseBackend::int8_tolerance`] bound of the f32 logits and are
//! just as deterministic (integer accumulation is order-independent).
//!
//! **Hot-path execution** (the PR-5 dispatch rework): every layer runs
//! through ONE long-lived [`ExecPool`] held by the backend — constructed
//! once per backend (or injected via [`CpuSparseBackend::with_pool`] and
//! shared between backends, e.g. an F32 and an Int8 instance) — instead
//! of spawning fresh threads per layer call. The forward pass itself is
//! **zero-alloc in steady state**: each forward leases a ping-pong
//! activation arena (two [`Dense2`] buffers plus an int8 staging
//! buffer, grown monotonically to the max layer width × batch capacity)
//! off a free-list, replacing the per-layer `Dense2::zeros` the trunk
//! used to allocate; only the returned output [`Value`]s are freshly
//! allocated. Concurrent coordinator workers each lease their own arena
//! (the list grows to peak concurrency, then everything is reuse), so
//! small-batch forwards still overlap across workers while large-batch
//! compute parallelizes across pool stripes. Arena pointer stability
//! across calls is pinned by the `arena_pointers_stable...` reuse test
//! below.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};

use crate::backend::{validate_inputs, InferenceBackend, TensorSpec, Value};
use crate::graph::op::OpKind;
use crate::runtime::manifest::{ArtifactIndex, ArtifactMeta, Manifest, Precision};
use crate::sparse::matmul::Act;
use crate::sparse::pack::{
    qspmm_tiled_into, spmm_tiled_into, PackedBlockBalanced, QPackedBlockBalanced,
};
use crate::sparse::pool::ExecPool;
use crate::sparse::tensor::Dense2;
use crate::sparse::{BlockBalanced, BLOCK, SUPPORTED_SPARSITIES};

/// Rows in the deterministic embedding table (token ids and element
/// positions are folded modulo this).
const EMBED_ROWS: usize = 512;

/// Sparse trunk depth of the distilled network.
const DEPTH: usize = 2;

/// `hidden` cap: keeps per-artifact construction (dense randn + prune)
/// in the low milliseconds even for ResNet-width (2048) feature layers.
const MAX_HIDDEN: usize = 512;

/// One fused sparse layer: packed f32 weights, optionally their INT8
/// twin, + bias + activation epilogue. The INT8 side comes from the same
/// pruned matrix through the `prune → per-channel calibrate → pack`
/// pipeline, so F32/Int8 serving differ only in kernel + quantization
/// noise. `qw` is built only when the backend can actually serve Int8
/// (f32-only construction skips the quantize+pack cost and the ~25%
/// extra weight memory).
struct SparseLayer {
    w: PackedBlockBalanced,
    qw: Option<QPackedBlockBalanced>,
    bias: Vec<f32>,
    act: Act,
}

impl SparseLayer {
    /// Deterministic layer `[k, n]` pruned to `sparsity`, seeded by `tag`.
    /// Weight scale 1/√k keeps activations O(1) through the trunk.
    fn new(k: usize, n: usize, sparsity: usize, act: Act, tag: &str, int8: bool) -> SparseLayer {
        let mut wd = Dense2::randn(k, n, fnv1a(tag));
        let scale = 1.0 / (k as f32).sqrt();
        for v in &mut wd.data {
            *v *= scale;
        }
        let bb = BlockBalanced::from_dense(&wd, sparsity)
            .expect("distilled layer dims are BLOCK-aligned");
        let mut brng = crate::util::rng::Xoshiro256::seed_from_u64(fnv1a(tag) ^ 0xB1A5);
        let bias = (0..n).map(|_| brng.next_gaussian() as f32 * 0.1).collect();
        let qw = int8.then(|| bb.quantize().pack());
        SparseLayer { w: bb.pack(), qw, bias, act }
    }

    /// Execute the layer at `prec` through the tiled engine, dispatching
    /// on `pool` and writing into the arena buffer `out` (`qbuf` stages
    /// quantized activations on the Int8 path) — no allocation once the
    /// arena has grown to the layer's footprint.
    fn run_into(
        &self,
        pool: &ExecPool,
        x: &Dense2,
        prec: Precision,
        threads: usize,
        qbuf: &mut Vec<i8>,
        out: &mut Dense2,
    ) {
        match prec {
            Precision::F32 => {
                spmm_tiled_into(pool, x, &self.w, Some(&self.bias), self.act, threads, out)
            }
            Precision::Int8 => {
                // constructors build qw whenever any artifact can resolve
                // to Int8, so this is reachable only with it present
                let qw = self.qw.as_ref().expect("net built without int8 weights");
                qspmm_tiled_into(pool, x, qw, Some(&self.bias), self.act, threads, qbuf, out)
            }
        }
    }
}

/// The ping-pong activation arena: layer `i` reads one buffer and writes
/// the other, so a whole forward pass touches exactly two activation
/// allocations (plus the int8 staging buffer), each grown monotonically
/// to the largest `batch × width` seen and then reused forever.
#[derive(Default)]
struct ActivationArena {
    ping: Dense2,
    pong: Dense2,
    /// quantized-activation staging for [`qspmm_tiled_into`]
    qbuf: Vec<i8>,
}

/// The distilled sparse network for one artifact.
struct SparseNet {
    hidden: usize,
    embed: Dense2,
    trunk: Vec<SparseLayer>,
    /// one head per output spec
    heads: Vec<SparseLayer>,
}

impl SparseNet {
    fn build(model: &str, sparsity: usize, outputs: &[TensorSpec], int8: bool) -> SparseNet {
        let hidden = model_hidden(model);
        let embed = Dense2::randn(EMBED_ROWS, hidden, fnv1a(&format!("{model}/embed")));
        let trunk = (0..DEPTH)
            .map(|l| {
                SparseLayer::new(
                    hidden,
                    hidden,
                    sparsity,
                    Act::Gelu,
                    &format!("{model}/trunk{l}"),
                    int8,
                )
            })
            .collect();
        let heads = outputs
            .iter()
            .enumerate()
            .map(|(i, o)| {
                SparseLayer::new(
                    hidden,
                    o.sample_elems(),
                    sparsity,
                    Act::None,
                    &format!("{model}/head{i}"),
                    int8,
                )
            })
            .collect();
        SparseNet { hidden, embed, trunk, heads }
    }
}

pub struct CpuSparseBackend {
    /// nets are shared across artifact variants: weights depend only on
    /// (model, clamped sparsity, output sample widths), so `_b1`/`_b8`
    /// variants of one model reference the same network
    nets: ArtifactIndex<Arc<SparseNet>>,
    threads: usize,
    /// `Some` forces every artifact to this precision (`s4 serve
    /// --precision`); `None` follows each artifact's manifest field.
    precision: Option<Precision>,
    /// the ONE dispatch pool every layer of every artifact runs on —
    /// held for the backend's lifetime (shared F32/Int8, shareable
    /// across backends via [`CpuSparseBackend::with_pool`])
    pool: Arc<ExecPool>,
    /// free-list of ping-pong activation arenas: a forward *leases* one
    /// (popping under a short lock, never holding it during compute), so
    /// concurrent coordinator workers overlap fully; the list grows to
    /// the peak forward concurrency and is then reused forever
    arenas: Mutex<Vec<ActivationArena>>,
}

/// Largest SPU-supported sparsity ≤ the manifest's tier (manifests may
/// carry 0 or off-grid values; clamping keeps construction total).
fn clamp_sparsity(s: usize) -> usize {
    SUPPORTED_SPARSITIES
        .iter()
        .copied()
        .filter(|&t| t <= s.max(1))
        .max()
        .unwrap_or(1)
}

/// Feature width for a model: the reduction width of the final MatMul in
/// its graph (hidden size for BERT, pooled channels for ResNet), rounded
/// to the hardware block and capped. Unknown models get the default.
fn model_hidden(model: &str) -> usize {
    let from_graph = crate::graph::models::by_name(model, 1).ok().and_then(|g| {
        g.ops.iter().rev().find_map(|o| match o.kind {
            OpKind::MatMul { k, .. } => Some(k),
            _ => None,
        })
    });
    let h = from_graph.unwrap_or(128).min(MAX_HIDDEN).max(BLOCK);
    (h + BLOCK - 1) / BLOCK * BLOCK
}

/// FNV-1a (64-bit) over a tag string — stable weight seeding across
/// runs/platforms.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

impl CpuSparseBackend {
    /// Default ceiling on per-layer stripe parallelism when constructors
    /// derive the thread count from the machine (beyond ~8 stripes the
    /// distilled layers are dispatch-bound, not compute-bound). Shared
    /// with the serving bench so recorded `host.effective_workers`
    /// metadata cannot drift from what the backend dispatches.
    pub const DEFAULT_THREAD_CAP: usize = 8;

    /// Build distilled sparse networks for every artifact in `m`.
    /// Threads default to the machine's parallelism (capped at
    /// [`DEFAULT_THREAD_CAP`](Self::DEFAULT_THREAD_CAP)); the kernel
    /// stays deterministic at any setting.
    pub fn from_manifest(m: &Manifest) -> CpuSparseBackend {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(Self::DEFAULT_THREAD_CAP);
        Self::with_threads(m, threads)
    }

    pub fn with_threads(m: &Manifest, threads: usize) -> CpuSparseBackend {
        Self::with_threads_precision(m, threads, None)
    }

    /// Serve every artifact at `precision`, ignoring the manifest field
    /// (the `s4 serve --precision` override).
    pub fn with_precision(m: &Manifest, precision: Precision) -> CpuSparseBackend {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(Self::DEFAULT_THREAD_CAP);
        Self::with_threads_precision(m, threads, Some(precision))
    }

    /// [`with_threads_precision`](CpuSparseBackend::with_threads_precision)
    /// on the process-wide [`ExecPool::global`] pool.
    pub fn with_threads_precision(
        m: &Manifest,
        threads: usize,
        precision: Option<Precision>,
    ) -> CpuSparseBackend {
        Self::with_pool(m, threads, precision, ExecPool::global().clone())
    }

    /// Full constructor: explicit thread count, optional precision
    /// override (`None` = per-artifact from the manifest), and the
    /// dispatch pool — pass one `Arc<ExecPool>` to several backends to
    /// share a single worker set (e.g. an F32 and an Int8 backend on one
    /// machine; the pool serializes their dispatches instead of
    /// oversubscribing cores).
    pub fn with_pool(
        m: &Manifest,
        threads: usize,
        precision: Option<Precision>,
        pool: Arc<ExecPool>,
    ) -> CpuSparseBackend {
        type NetKey = (String, usize, Vec<usize>);
        let net_key = |a: &ArtifactMeta| -> NetKey {
            (
                a.model.clone(),
                clamp_sparsity(a.sparsity),
                a.outputs.iter().map(|o| o.sample_elems()).collect(),
            )
        };
        // a net carries the quantized twin only if one of its artifacts
        // can resolve to Int8 under the effective precision policy —
        // f32-only nets skip the quantize+pack cost and extra memory
        let int8_nets: HashSet<NetKey> = m
            .artifacts
            .iter()
            .filter(|a| precision.unwrap_or(a.precision) == Precision::Int8)
            .map(|a| net_key(a))
            .collect();
        let mut cache: HashMap<NetKey, Arc<SparseNet>> = HashMap::new();
        let nets = ArtifactIndex::build(m, |a| {
            let key = net_key(a);
            let int8 = int8_nets.contains(&key);
            cache
                .entry(key)
                .or_insert_with(|| {
                    let s = clamp_sparsity(a.sparsity);
                    Arc::new(SparseNet::build(&a.model, s, &a.outputs, int8))
                })
                .clone()
        });
        CpuSparseBackend {
            nets,
            threads: threads.max(1),
            precision,
            pool,
            arenas: Mutex::new(Vec::new()),
        }
    }

    /// Raw data addresses of the parked arena's three buffers `(ping,
    /// pong, qbuf)` — the probe the zero-alloc reuse tests pin: after
    /// one warm-up forward, sequential calls lease the same arena and
    /// these must not change.
    #[cfg(test)]
    fn arena_ptrs(&self) -> (usize, usize, usize) {
        let arenas = self.arenas.lock().unwrap_or_else(|p| p.into_inner());
        let a = arenas.last().expect("no forward has run yet");
        (
            a.ping.data.as_ptr() as usize,
            a.pong.data.as_ptr() as usize,
            a.qbuf.as_ptr() as usize,
        )
    }

    fn net(&self, artifact: &str) -> anyhow::Result<&(ArtifactMeta, Arc<SparseNet>)> {
        self.nets
            .get(artifact)
            .ok_or_else(|| anyhow::anyhow!("CpuSparseBackend: unknown artifact `{artifact}`"))
    }

    /// Effective serving precision of `artifact`: the process-wide
    /// override if set, else the artifact's manifest precision.
    pub fn precision_of(&self, artifact: &str) -> anyhow::Result<Precision> {
        Ok(self.precision.unwrap_or(self.net(artifact)?.0.precision))
    }

    /// Relative-L2 tolerance for this artifact's Int8 logits vs its F32
    /// logits, derived from the per-layer quantization error bounds: a
    /// logit crosses every trunk layer plus one head, and each quantized
    /// layer contributes at most [`QPackedBlockBalanced::rel_error_bound`]
    /// (½ LSB relative) weight noise plus the same ½-LSB relative noise
    /// from per-tensor activation quantization. `CANCEL_SLACK` covers the
    /// amplification when a dot product's terms partially cancel
    /// (empirically < 4× on the gaussian-ish distilled weights — cf. the
    /// 2% single-layer `qgemm_close_to_f32_gemm` bound vs the ~0.8%
    /// noise floor). The conformance suite asserts against this bound.
    pub fn int8_tolerance(&self, artifact: &str) -> anyhow::Result<f32> {
        const CANCEL_SLACK: f32 = 8.0;
        const ACT_REL: f32 = 0.5 / 127.0;
        let (_, net) = self.net(artifact)?;
        let rel = |l: &SparseLayer| -> anyhow::Result<f32> {
            let qw = l.qw.as_ref().ok_or_else(|| {
                anyhow::anyhow!("{artifact}: backend was built without the int8 path")
            })?;
            Ok(qw.rel_error_bound() + ACT_REL)
        };
        let mut trunk = 0.0f32;
        for l in &net.trunk {
            trunk += rel(l)?;
        }
        let mut head = 0.0f32;
        for l in &net.heads {
            head = head.max(rel(l)?);
        }
        Ok(CANCEL_SLACK * (trunk + head))
    }
}

/// Fold a batch's input tensors into `[capacity, hidden]` feature rows
/// through the embedding table, written into the arena buffer `feat`
/// (zeroed by its `reset` — accumulation starts clean, no allocation in
/// steady state). Position-salted so reorderings of the same tokens
/// produce distinct features; zero f32 elements (the coordinator's
/// padding) contribute nothing.
fn featurize_into(
    net: &SparseNet,
    specs: &[TensorSpec],
    inputs: &[Value],
    capacity: usize,
    feat: &mut Dense2,
) {
    let h = net.hidden;
    feat.reset(capacity, h);
    for (v, spec) in inputs.iter().zip(specs) {
        let per = spec.sample_elems();
        if per == 0 {
            continue;
        }
        let inv = 1.0 / per as f32;
        for b in 0..spec.batch_dim().min(capacity) {
            let frow = &mut feat.data[b * h..(b + 1) * h];
            match v {
                Value::I32(x) => {
                    for (t, &tok) in x[b * per..(b + 1) * per].iter().enumerate() {
                        let row = ((tok as i64).rem_euclid(EMBED_ROWS as i64) as usize + t)
                            % EMBED_ROWS;
                        for (f, &e) in frow.iter_mut().zip(net.embed.row(row)) {
                            *f += e * inv;
                        }
                    }
                }
                Value::F32(x) => {
                    for (t, &xv) in x[b * per..(b + 1) * per].iter().enumerate() {
                        if xv == 0.0 {
                            continue;
                        }
                        for (f, &e) in frow.iter_mut().zip(net.embed.row(t % EMBED_ROWS)) {
                            *f += e * xv * inv;
                        }
                    }
                }
            }
        }
    }
}

impl InferenceBackend for CpuSparseBackend {
    fn input_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        Ok(&self.net(artifact)?.0.inputs)
    }

    fn output_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        Ok(&self.net(artifact)?.0.outputs)
    }

    fn run_batch(&self, artifact: &str, inputs: &[Value]) -> anyhow::Result<Vec<Value>> {
        let (meta, net) = self.net(artifact)?;
        validate_inputs(artifact, &meta.inputs, inputs)?;
        let prec = self.precision.unwrap_or(meta.precision);
        let capacity = meta.inputs.first().map(|s| s.batch_dim()).unwrap_or(1);
        // modest batches don't amortize parallel dispatch — run serial
        let threads = if capacity * net.hidden >= 2048 { self.threads } else { 1 };
        // steady-state zero-alloc forward: lease an arena off the
        // free-list (a fresh one only when concurrency exceeds anything
        // seen before), featurize into its ping buffer, then ping-pong
        // through the trunk and heads — the only fresh allocations below
        // are the returned output Values. The lock is held only for the
        // pop/push, so concurrent forwards overlap; a poisoned lock is
        // recovered (a panicked forward must not brick the backend), and
        // an arena dropped by a panicking forward is simply re-grown.
        let mut arena = self
            .arenas
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .pop()
            .unwrap_or_default();
        let result = forward(net, meta, inputs, prec, threads, &self.pool, &mut arena);
        // the lease goes back even when the forward errors — an early
        // `?` must not leak a grown arena into per-call allocation
        self.arenas.lock().unwrap_or_else(|p| p.into_inner()).push(arena);
        result
    }
}

/// One forward pass through an artifact's distilled net, entirely inside
/// the leased `arena` (see [`CpuSparseBackend::run_batch`] for the
/// lease/return discipline — keeping this a separate function means
/// every exit path, including errors, flows back through the caller's
/// arena return).
fn forward(
    net: &SparseNet,
    meta: &ArtifactMeta,
    inputs: &[Value],
    prec: Precision,
    threads: usize,
    pool: &ExecPool,
    arena: &mut ActivationArena,
) -> anyhow::Result<Vec<Value>> {
    let capacity = meta.inputs.first().map(|s| s.batch_dim()).unwrap_or(1);
    let ActivationArena { ping, pong, qbuf } = arena;
    let (mut cur, mut nxt) = (ping, pong);
    featurize_into(net, &meta.inputs, inputs, capacity, cur);
    for layer in &net.trunk {
        layer.run_into(pool, cur, prec, threads, qbuf, nxt);
        std::mem::swap(&mut cur, &mut nxt);
    }
    let mut out = Vec::with_capacity(meta.outputs.len());
    for (spec, head) in meta.outputs.iter().zip(&net.heads) {
        let per = spec.sample_elems();
        // every head reads the trunk output in `cur` and reuses the
        // free half of the arena for its logits
        head.run_into(pool, cur, prec, threads, qbuf, nxt);
        let y = &*nxt;
        let mut v = Value::empty(&spec.dtype)?;
        for b in 0..spec.batch_dim() {
            if b < capacity {
                let row = y.row(b);
                match &mut v {
                    Value::F32(vec) => vec.extend_from_slice(row),
                    // s32 outputs carry logits quantized at 1/256
                    Value::I32(vec) => {
                        vec.extend(row.iter().map(|&x| (x * 256.0).round() as i32))
                    }
                }
            } else {
                v.push_zeros(per);
            }
        }
        out.push(v);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn manifest() -> Manifest {
        let text = r#"{"artifacts": [
          {"name": "bert_tiny_s8_b2", "file": "x", "family": "bert",
           "model": "bert_tiny", "sparsity": 8, "batch": 2, "seq": 4,
           "inputs": [{"name": "ids", "shape": [2, 4], "dtype": "s32"}],
           "outputs": [{"name": "logits", "shape": [2, 3], "dtype": "f32"}]},
          {"name": "bert_tiny_s1_b2", "file": "y", "family": "bert",
           "model": "bert_tiny", "sparsity": 1, "batch": 2, "seq": 4,
           "inputs": [{"name": "ids", "shape": [2, 4], "dtype": "s32"}],
           "outputs": [{"name": "logits", "shape": [2, 3], "dtype": "f32"}]}
        ]}"#;
        Manifest::parse(Path::new("/tmp"), text).unwrap()
    }

    #[test]
    fn unknown_artifact_is_err_not_panic() {
        let b = CpuSparseBackend::from_manifest(&manifest());
        assert!(b.input_specs("nope").is_err());
        assert!(b.run_batch("nope", &[]).is_err());
    }

    #[test]
    fn logits_deterministic_and_input_sensitive() {
        let b = CpuSparseBackend::from_manifest(&manifest());
        let inputs = vec![Value::I32(vec![1, 2, 3, 4, 9, 9, 9, 9])];
        let o1 = b.run_batch("bert_tiny_s8_b2", &inputs).unwrap();
        let o2 = b.run_batch("bert_tiny_s8_b2", &inputs).unwrap();
        assert_eq!(o1, o2);
        let l = o1[0].as_f32().unwrap();
        assert_eq!(l.len(), 6);
        // distinct samples produce distinct logits
        assert_ne!(&l[0..3], &l[3..6]);
        // token order matters (position salt)
        let swapped = vec![Value::I32(vec![2, 1, 3, 4, 9, 9, 9, 9])];
        let o3 = b.run_batch("bert_tiny_s8_b2", &swapped).unwrap();
        assert_ne!(o1, o3);
    }

    #[test]
    fn deterministic_across_thread_counts_and_instances() {
        let m = manifest();
        let b1 = CpuSparseBackend::with_threads(&m, 1);
        let b4 = CpuSparseBackend::with_threads(&m, 4);
        let inputs = vec![Value::I32(vec![5, 6, 7, 8, 1, 2, 3, 4])];
        assert_eq!(
            b1.run_batch("bert_tiny_s8_b2", &inputs).unwrap(),
            b4.run_batch("bert_tiny_s8_b2", &inputs).unwrap()
        );
    }

    #[test]
    fn sparsity_tiers_share_weights_but_differ_in_pruning() {
        let b = CpuSparseBackend::from_manifest(&manifest());
        let inputs = vec![Value::I32(vec![1, 2, 3, 4, 0, 0, 0, 0])];
        let dense = b.run_batch("bert_tiny_s1_b2", &inputs).unwrap();
        let sparse = b.run_batch("bert_tiny_s8_b2", &inputs).unwrap();
        // same dense seed, different tier → close but not identical
        assert_ne!(dense, sparse);
    }

    #[test]
    fn rejects_malformed_batches() {
        let b = CpuSparseBackend::from_manifest(&manifest());
        assert!(b.run_batch("bert_tiny_s8_b2", &[Value::I32(vec![1; 7])]).is_err());
        assert!(b.run_batch("bert_tiny_s8_b2", &[Value::F32(vec![0.0; 8])]).is_err());
    }

    fn rel_l2(a: &[f32], b: &[f32]) -> f32 {
        let num: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
        let den: f32 = b.iter().map(|v| v * v).sum();
        if den == 0.0 {
            0.0
        } else {
            (num / den).sqrt()
        }
    }

    #[test]
    fn int8_mode_is_deterministic_and_close_to_f32() {
        let m = manifest();
        let f = CpuSparseBackend::from_manifest(&m);
        let q = CpuSparseBackend::with_precision(&m, Precision::Int8);
        let inputs = vec![Value::I32(vec![1, 2, 3, 4, 9, 9, 9, 9])];
        let of = f.run_batch("bert_tiny_s8_b2", &inputs).unwrap();
        let oq1 = q.run_batch("bert_tiny_s8_b2", &inputs).unwrap();
        let oq2 = q.run_batch("bert_tiny_s8_b2", &inputs).unwrap();
        assert_eq!(oq1, oq2, "int8 must be deterministic");
        assert_ne!(of, oq1, "int8 must actually run the quantized kernel");
        let tol = q.int8_tolerance("bert_tiny_s8_b2").unwrap();
        assert!(tol > 0.0 && tol < 0.5, "tolerance sane: {tol}");
        let rel = rel_l2(oq1[0].as_f32().unwrap(), of[0].as_f32().unwrap());
        assert!(rel <= tol, "int8 rel err {rel} exceeds tolerance {tol}");
    }

    #[test]
    fn int8_deterministic_across_thread_counts() {
        let m = manifest();
        let q1 = CpuSparseBackend::with_threads_precision(&m, 1, Some(Precision::Int8));
        let q4 = CpuSparseBackend::with_threads_precision(&m, 4, Some(Precision::Int8));
        let inputs = vec![Value::I32(vec![5, 6, 7, 8, 1, 2, 3, 4])];
        assert_eq!(
            q1.run_batch("bert_tiny_s8_b2", &inputs).unwrap(),
            q4.run_batch("bert_tiny_s8_b2", &inputs).unwrap()
        );
    }

    #[test]
    fn precision_follows_manifest_unless_overridden() {
        let text = r#"{"artifacts": [
          {"name": "q8", "file": "x", "family": "bert",
           "model": "bert_tiny", "sparsity": 8, "batch": 1, "seq": 4,
           "precision": "int8",
           "inputs": [{"name": "ids", "shape": [1, 4], "dtype": "s32"}],
           "outputs": [{"name": "logits", "shape": [1, 3], "dtype": "f32"}]}
        ]}"#;
        let m = Manifest::parse(std::path::Path::new("/tmp"), text).unwrap();
        let b = CpuSparseBackend::from_manifest(&m);
        assert_eq!(b.precision_of("q8").unwrap(), Precision::Int8);
        let forced = CpuSparseBackend::with_precision(&m, Precision::F32);
        assert_eq!(forced.precision_of("q8").unwrap(), Precision::F32);
        // manifest-selected int8 == override-selected int8, bitwise
        let inputs = vec![Value::I32(vec![4, 3, 2, 1])];
        let via_manifest = b.run_batch("q8", &inputs).unwrap();
        let via_override = CpuSparseBackend::with_precision(&m, Precision::Int8)
            .run_batch("q8", &inputs)
            .unwrap();
        assert_eq!(via_manifest, via_override);
        assert_ne!(via_manifest, forced.run_batch("q8", &inputs).unwrap());
    }

    #[test]
    fn arena_pointers_stable_across_calls_pool_zero_alloc() {
        // the steady-state zero-alloc contract: after one warm-up
        // forward per precision, the ping-pong arena (and the int8
        // staging buffer) never reallocates — pointer-stable across
        // calls, at both precisions, through the SAME backend arena
        let text = r#"{"artifacts": [
          {"name": "f32_art", "file": "x", "family": "bert",
           "model": "bert_tiny", "sparsity": 8, "batch": 2, "seq": 4,
           "inputs": [{"name": "ids", "shape": [2, 4], "dtype": "s32"}],
           "outputs": [{"name": "logits", "shape": [2, 3], "dtype": "f32"}]},
          {"name": "q8_art", "file": "y", "family": "bert",
           "model": "bert_tiny", "sparsity": 8, "batch": 2, "seq": 4,
           "precision": "int8",
           "inputs": [{"name": "ids", "shape": [2, 4], "dtype": "s32"}],
           "outputs": [{"name": "logits", "shape": [2, 3], "dtype": "f32"}]}
        ]}"#;
        let m = Manifest::parse(Path::new("/tmp"), text).unwrap();
        let b = CpuSparseBackend::from_manifest(&m);
        let inputs = vec![Value::I32(vec![1, 2, 3, 4, 5, 6, 7, 8])];
        // warm-up: grows the arena to the max footprint of both paths
        let f_ref = b.run_batch("f32_art", &inputs).unwrap();
        let q_ref = b.run_batch("q8_art", &inputs).unwrap();
        let ptrs = b.arena_ptrs();
        for _ in 0..4 {
            assert_eq!(b.run_batch("f32_art", &inputs).unwrap(), f_ref);
            assert_eq!(b.run_batch("q8_art", &inputs).unwrap(), q_ref);
            assert_eq!(b.arena_ptrs(), ptrs, "arena reallocated in steady state");
        }
    }

    #[test]
    fn two_backends_share_one_pool_interleaved_precisions() {
        // pool-reuse across backends: an F32 and an Int8 backend
        // dispatching on ONE ExecPool, interleaved, must match solo
        // backends exactly (the pool adds scheduling, never numerics)
        let m = manifest();
        let pool = Arc::new(ExecPool::new(3));
        let f = CpuSparseBackend::with_pool(&m, 4, None, pool.clone());
        let q = CpuSparseBackend::with_pool(&m, 4, Some(Precision::Int8), pool.clone());
        let f_solo = CpuSparseBackend::with_threads(&m, 4);
        let q_solo = CpuSparseBackend::with_threads_precision(&m, 4, Some(Precision::Int8));
        for i in 0..4 {
            let inputs = vec![Value::I32(vec![i, 2, 3, 4, 9, 8, 7, 6])];
            assert_eq!(
                f.run_batch("bert_tiny_s8_b2", &inputs).unwrap(),
                f_solo.run_batch("bert_tiny_s8_b2", &inputs).unwrap(),
                "shared-pool f32 diverged (i={i})"
            );
            assert_eq!(
                q.run_batch("bert_tiny_s8_b2", &inputs).unwrap(),
                q_solo.run_batch("bert_tiny_s8_b2", &inputs).unwrap(),
                "shared-pool int8 diverged (i={i})"
            );
        }
        assert_eq!(pool.workers(), 3, "backends must not resize a shared pool");
    }

    #[test]
    fn hidden_and_sparsity_derivation() {
        assert_eq!(model_hidden("bert_tiny"), 128);
        assert_eq!(model_hidden("resnet50"), MAX_HIDDEN);
        assert_eq!(model_hidden("__no_such_model__"), 128);
        assert_eq!(clamp_sparsity(8), 8);
        assert_eq!(clamp_sparsity(0), 1);
        assert_eq!(clamp_sparsity(3), 2);
        assert_eq!(clamp_sparsity(999), 32);
    }
}
