//! CPU sparse backend: real block-balanced sparse compute on the serving
//! path — the "CPU fallback path of the coordinator" the sparse substrate
//! always promised, now implementing [`InferenceBackend`].
//!
//! Per artifact it builds a *distilled sparse network*: deterministic
//! weights sized from the artifact's model graph (`graph::models`),
//! magnitude-pruned to the manifest's sparsity via
//! [`BlockBalanced::from_dense`], packed once with
//! [`BlockBalanced::pack`], and executed batch-by-batch through the
//! parallel tiled kernel [`spmm_tiled`] with its fused bias+activation
//! epilogue. Unlike [`SimBackend`](crate::backend::SimBackend)'s hashed
//! pseudo-outputs, logits here are the product of actual sparse
//! matmuls — so end-to-end tests exercise the numeric hot path, and the
//! serving benches measure real compute.
//!
//! Shape of the distilled network (per artifact):
//! 1. *featurize* — every input tensor is folded into a `hidden`-wide
//!    feature row through a deterministic embedding table (token ids
//!    gather rows; f32 payloads take value-weighted rows), mirroring the
//!    Embed op that fronts the real graphs;
//! 2. *trunk* — `DEPTH` block-balanced sparse layers `hidden → hidden`
//!    with fused Gelu, pruned at the artifact's sparsity tier;
//! 3. *heads* — one sparse layer `hidden → sample_elems` per output
//!    spec, no activation (classifier logits).
//!
//! `hidden` is taken from the model graph's final MatMul reduction width
//! (BERT's hidden size, ResNet's pooled feature width), capped so
//! construction stays cheap; weights are seeded from the model name, so
//! every batch/sparsity variant of a model shares the same dense weights
//! and differs only by pruning tier — exactly the artifact-variant
//! relationship the router assumes.
//!
//! Everything is deterministic: same manifest → same weights → bitwise
//! identical logits, for any thread count (the tiled kernel reduces in a
//! fixed order). The backend-conformance suite runs against this type in
//! `rust/tests/backend_conformance.rs`.

use std::collections::HashMap;
use std::sync::Arc;

use crate::backend::{validate_inputs, InferenceBackend, TensorSpec, Value};
use crate::graph::op::OpKind;
use crate::runtime::manifest::{ArtifactMeta, Manifest};
use crate::sparse::matmul::Act;
use crate::sparse::pack::{spmm_tiled, PackedBlockBalanced};
use crate::sparse::tensor::Dense2;
use crate::sparse::{BlockBalanced, BLOCK, SUPPORTED_SPARSITIES};

/// Rows in the deterministic embedding table (token ids and element
/// positions are folded modulo this).
const EMBED_ROWS: usize = 512;

/// Sparse trunk depth of the distilled network.
const DEPTH: usize = 2;

/// `hidden` cap: keeps per-artifact construction (dense randn + prune)
/// in the low milliseconds even for ResNet-width (2048) feature layers.
const MAX_HIDDEN: usize = 512;

/// One fused sparse layer: packed weights + bias + activation epilogue.
struct SparseLayer {
    w: PackedBlockBalanced,
    bias: Vec<f32>,
    act: Act,
}

impl SparseLayer {
    /// Deterministic layer `[k, n]` pruned to `sparsity`, seeded by `tag`.
    /// Weight scale 1/√k keeps activations O(1) through the trunk.
    fn new(k: usize, n: usize, sparsity: usize, act: Act, tag: &str) -> SparseLayer {
        let mut wd = Dense2::randn(k, n, fnv1a(tag));
        let scale = 1.0 / (k as f32).sqrt();
        for v in &mut wd.data {
            *v *= scale;
        }
        let bb = BlockBalanced::from_dense(&wd, sparsity)
            .expect("distilled layer dims are BLOCK-aligned");
        let mut brng = crate::util::rng::Xoshiro256::seed_from_u64(fnv1a(tag) ^ 0xB1A5);
        let bias = (0..n).map(|_| brng.next_gaussian() as f32 * 0.1).collect();
        SparseLayer { w: bb.pack(), bias, act }
    }
}

/// The distilled sparse network for one artifact.
struct SparseNet {
    hidden: usize,
    embed: Dense2,
    trunk: Vec<SparseLayer>,
    /// one head per output spec
    heads: Vec<SparseLayer>,
}

impl SparseNet {
    fn build(model: &str, sparsity: usize, outputs: &[TensorSpec]) -> SparseNet {
        let hidden = model_hidden(model);
        let embed = Dense2::randn(EMBED_ROWS, hidden, fnv1a(&format!("{model}/embed")));
        let trunk = (0..DEPTH)
            .map(|l| {
                SparseLayer::new(hidden, hidden, sparsity, Act::Gelu, &format!("{model}/trunk{l}"))
            })
            .collect();
        let heads = outputs
            .iter()
            .enumerate()
            .map(|(i, o)| {
                SparseLayer::new(
                    hidden,
                    o.sample_elems(),
                    sparsity,
                    Act::None,
                    &format!("{model}/head{i}"),
                )
            })
            .collect();
        SparseNet { hidden, embed, trunk, heads }
    }
}

pub struct CpuSparseBackend {
    /// nets are shared across artifact variants: weights depend only on
    /// (model, clamped sparsity, output sample widths), so `_b1`/`_b8`
    /// variants of one model reference the same network
    nets: Vec<(ArtifactMeta, Arc<SparseNet>)>,
    threads: usize,
}

/// Largest SPU-supported sparsity ≤ the manifest's tier (manifests may
/// carry 0 or off-grid values; clamping keeps construction total).
fn clamp_sparsity(s: usize) -> usize {
    SUPPORTED_SPARSITIES
        .iter()
        .copied()
        .filter(|&t| t <= s.max(1))
        .max()
        .unwrap_or(1)
}

/// Feature width for a model: the reduction width of the final MatMul in
/// its graph (hidden size for BERT, pooled channels for ResNet), rounded
/// to the hardware block and capped. Unknown models get the default.
fn model_hidden(model: &str) -> usize {
    let from_graph = crate::graph::models::by_name(model, 1).ok().and_then(|g| {
        g.ops.iter().rev().find_map(|o| match o.kind {
            OpKind::MatMul { k, .. } => Some(k),
            _ => None,
        })
    });
    let h = from_graph.unwrap_or(128).min(MAX_HIDDEN).max(BLOCK);
    (h + BLOCK - 1) / BLOCK * BLOCK
}

/// FNV-1a (64-bit) over a tag string — stable weight seeding across
/// runs/platforms.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

impl CpuSparseBackend {
    /// Build distilled sparse networks for every artifact in `m`.
    /// Threads default to the machine's parallelism (capped at 8); the
    /// kernel stays deterministic at any setting.
    pub fn from_manifest(m: &Manifest) -> CpuSparseBackend {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(8);
        Self::with_threads(m, threads)
    }

    pub fn with_threads(m: &Manifest, threads: usize) -> CpuSparseBackend {
        let mut cache: HashMap<(String, usize, Vec<usize>), Arc<SparseNet>> = HashMap::new();
        let nets = m
            .artifacts
            .iter()
            .map(|a| {
                let s = clamp_sparsity(a.sparsity);
                let widths: Vec<usize> = a.outputs.iter().map(|o| o.sample_elems()).collect();
                let net = cache
                    .entry((a.model.clone(), s, widths))
                    .or_insert_with(|| Arc::new(SparseNet::build(&a.model, s, &a.outputs)))
                    .clone();
                (a.clone(), net)
            })
            .collect();
        CpuSparseBackend { nets, threads: threads.max(1) }
    }

    fn net(&self, artifact: &str) -> anyhow::Result<&(ArtifactMeta, Arc<SparseNet>)> {
        self.nets
            .iter()
            .find(|(a, _)| a.name == artifact)
            .ok_or_else(|| anyhow::anyhow!("CpuSparseBackend: unknown artifact `{artifact}`"))
    }
}

/// Fold a batch's input tensors into `[capacity, hidden]` feature rows
/// through the embedding table. Position-salted so reorderings of the
/// same tokens produce distinct features; zero f32 elements (the
/// coordinator's padding) contribute nothing.
fn featurize(
    net: &SparseNet,
    specs: &[TensorSpec],
    inputs: &[Value],
    capacity: usize,
) -> Dense2 {
    let h = net.hidden;
    let mut feat = Dense2::zeros(capacity, h);
    for (v, spec) in inputs.iter().zip(specs) {
        let per = spec.sample_elems();
        if per == 0 {
            continue;
        }
        let inv = 1.0 / per as f32;
        for b in 0..spec.batch_dim().min(capacity) {
            let frow = &mut feat.data[b * h..(b + 1) * h];
            match v {
                Value::I32(x) => {
                    for (t, &tok) in x[b * per..(b + 1) * per].iter().enumerate() {
                        let row = ((tok as i64).rem_euclid(EMBED_ROWS as i64) as usize + t)
                            % EMBED_ROWS;
                        for (f, &e) in frow.iter_mut().zip(net.embed.row(row)) {
                            *f += e * inv;
                        }
                    }
                }
                Value::F32(x) => {
                    for (t, &xv) in x[b * per..(b + 1) * per].iter().enumerate() {
                        if xv == 0.0 {
                            continue;
                        }
                        for (f, &e) in frow.iter_mut().zip(net.embed.row(t % EMBED_ROWS)) {
                            *f += e * xv * inv;
                        }
                    }
                }
            }
        }
    }
    feat
}

impl InferenceBackend for CpuSparseBackend {
    fn input_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        Ok(&self.net(artifact)?.0.inputs)
    }

    fn output_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        Ok(&self.net(artifact)?.0.outputs)
    }

    fn run_batch(&self, artifact: &str, inputs: &[Value]) -> anyhow::Result<Vec<Value>> {
        let (meta, net) = self.net(artifact)?;
        validate_inputs(artifact, &meta.inputs, inputs)?;
        let capacity = meta.inputs.first().map(|s| s.batch_dim()).unwrap_or(1);
        // modest batches don't amortize thread spawns — run those serial
        let threads = if capacity * net.hidden >= 2048 { self.threads } else { 1 };
        let mut hrows = featurize(net, &meta.inputs, inputs, capacity);
        for layer in &net.trunk {
            hrows = spmm_tiled(&hrows, &layer.w, Some(&layer.bias), layer.act, threads);
        }
        let mut out = Vec::with_capacity(meta.outputs.len());
        for (spec, head) in meta.outputs.iter().zip(&net.heads) {
            let per = spec.sample_elems();
            let y = spmm_tiled(&hrows, &head.w, Some(&head.bias), head.act, threads);
            let mut v = Value::empty(&spec.dtype)?;
            for b in 0..spec.batch_dim() {
                if b < capacity {
                    let row = y.row(b);
                    match &mut v {
                        Value::F32(vec) => vec.extend_from_slice(row),
                        // s32 outputs carry logits quantized at 1/256
                        Value::I32(vec) => {
                            vec.extend(row.iter().map(|&x| (x * 256.0).round() as i32))
                        }
                    }
                } else {
                    v.push_zeros(per);
                }
            }
            out.push(v);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn manifest() -> Manifest {
        let text = r#"{"artifacts": [
          {"name": "bert_tiny_s8_b2", "file": "x", "family": "bert",
           "model": "bert_tiny", "sparsity": 8, "batch": 2, "seq": 4,
           "inputs": [{"name": "ids", "shape": [2, 4], "dtype": "s32"}],
           "outputs": [{"name": "logits", "shape": [2, 3], "dtype": "f32"}]},
          {"name": "bert_tiny_s1_b2", "file": "y", "family": "bert",
           "model": "bert_tiny", "sparsity": 1, "batch": 2, "seq": 4,
           "inputs": [{"name": "ids", "shape": [2, 4], "dtype": "s32"}],
           "outputs": [{"name": "logits", "shape": [2, 3], "dtype": "f32"}]}
        ]}"#;
        Manifest::parse(Path::new("/tmp"), text).unwrap()
    }

    #[test]
    fn unknown_artifact_is_err_not_panic() {
        let b = CpuSparseBackend::from_manifest(&manifest());
        assert!(b.input_specs("nope").is_err());
        assert!(b.run_batch("nope", &[]).is_err());
    }

    #[test]
    fn logits_deterministic_and_input_sensitive() {
        let b = CpuSparseBackend::from_manifest(&manifest());
        let inputs = vec![Value::I32(vec![1, 2, 3, 4, 9, 9, 9, 9])];
        let o1 = b.run_batch("bert_tiny_s8_b2", &inputs).unwrap();
        let o2 = b.run_batch("bert_tiny_s8_b2", &inputs).unwrap();
        assert_eq!(o1, o2);
        let l = o1[0].as_f32().unwrap();
        assert_eq!(l.len(), 6);
        // distinct samples produce distinct logits
        assert_ne!(&l[0..3], &l[3..6]);
        // token order matters (position salt)
        let swapped = vec![Value::I32(vec![2, 1, 3, 4, 9, 9, 9, 9])];
        let o3 = b.run_batch("bert_tiny_s8_b2", &swapped).unwrap();
        assert_ne!(o1, o3);
    }

    #[test]
    fn deterministic_across_thread_counts_and_instances() {
        let m = manifest();
        let b1 = CpuSparseBackend::with_threads(&m, 1);
        let b4 = CpuSparseBackend::with_threads(&m, 4);
        let inputs = vec![Value::I32(vec![5, 6, 7, 8, 1, 2, 3, 4])];
        assert_eq!(
            b1.run_batch("bert_tiny_s8_b2", &inputs).unwrap(),
            b4.run_batch("bert_tiny_s8_b2", &inputs).unwrap()
        );
    }

    #[test]
    fn sparsity_tiers_share_weights_but_differ_in_pruning() {
        let b = CpuSparseBackend::from_manifest(&manifest());
        let inputs = vec![Value::I32(vec![1, 2, 3, 4, 0, 0, 0, 0])];
        let dense = b.run_batch("bert_tiny_s1_b2", &inputs).unwrap();
        let sparse = b.run_batch("bert_tiny_s8_b2", &inputs).unwrap();
        // same dense seed, different tier → close but not identical
        assert_ne!(dense, sparse);
    }

    #[test]
    fn rejects_malformed_batches() {
        let b = CpuSparseBackend::from_manifest(&manifest());
        assert!(b.run_batch("bert_tiny_s8_b2", &[Value::I32(vec![1; 7])]).is_err());
        assert!(b.run_batch("bert_tiny_s8_b2", &[Value::F32(vec![0.0; 8])]).is_err());
    }

    #[test]
    fn hidden_and_sparsity_derivation() {
        assert_eq!(model_hidden("bert_tiny"), 128);
        assert_eq!(model_hidden("resnet50"), MAX_HIDDEN);
        assert_eq!(model_hidden("__no_such_model__"), 128);
        assert_eq!(clamp_sparsity(8), 8);
        assert_eq!(clamp_sparsity(0), 1);
        assert_eq!(clamp_sparsity(3), 2);
        assert_eq!(clamp_sparsity(999), 32);
    }
}
