//! CPU sparse backend: real block-balanced sparse compute on the serving
//! path — the "CPU fallback path of the coordinator" the sparse substrate
//! always promised, now implementing [`InferenceBackend`].
//!
//! Per artifact it builds a *distilled sparse network*: deterministic
//! weights sized from the artifact's model graph (`graph::models`),
//! magnitude-pruned to the manifest's sparsity via
//! [`BlockBalanced::from_dense`], packed once with
//! [`BlockBalanced::pack`], and executed batch-by-batch through the
//! parallel tiled kernel
//! [`spmm_tiled_into`](crate::sparse::pack::spmm_tiled_into) with its
//! fused bias+activation epilogue. Unlike [`SimBackend`](crate::backend::SimBackend)'s hashed
//! pseudo-outputs, logits here are the product of actual sparse
//! matmuls — so end-to-end tests exercise the numeric hot path, and the
//! serving benches measure real compute.
//!
//! Shape of the distilled network (per artifact):
//! 1. *featurize* — every input tensor is folded into a `hidden`-wide
//!    feature row through a deterministic embedding table (token ids
//!    gather rows; f32 payloads take value-weighted rows), mirroring the
//!    Embed op that fronts the real graphs;
//! 2. *trunk* — `DEPTH` block-balanced sparse layers `hidden → hidden`
//!    with fused Gelu, pruned at the artifact's sparsity tier;
//! 3. *heads* — one sparse layer `hidden → sample_elems` per output
//!    spec, no activation (classifier logits).
//!
//! `hidden` is taken from the model graph's final MatMul reduction width
//! (BERT's hidden size, ResNet's pooled feature width), capped so
//! construction stays cheap; weights are seeded from the model name, so
//! every batch/sparsity variant of a model shares the same dense weights
//! and differs only by pruning tier — exactly the artifact-variant
//! relationship the router assumes.
//!
//! Everything is deterministic: same manifest → same weights → bitwise
//! identical logits, for any thread count (the tiled kernel reduces in a
//! fixed order). The backend-conformance suite runs against this type in
//! `rust/tests/backend_conformance.rs`.
//!
//! **Precision**: every layer carries both the f32 packed weights and
//! their INT8 quantized twin (same pruned matrix through
//! `prune → per-channel calibrate → pack`). [`Precision::Int8`] serves
//! through [`qspmm_tiled_into`](crate::sparse::pack::qspmm_tiled_into) —
//! i32 accumulation, fused
//! `dequant → bias → activation` epilogue — which is the paper's
//! headline sparsity×quantization composition. The mode is chosen per
//! artifact by the manifest's `"precision"` field and can be forced
//! process-wide with [`CpuSparseBackend::with_precision`]
//! (`s4 serve --precision int8`). Int8 logits stay within the
//! [`CpuSparseBackend::int8_tolerance`] bound of the f32 logits and are
//! just as deterministic (integer accumulation is order-independent).
//!
//! **Autotuned dispatch** (PR 10): instead of one fixed tile width and
//! one fixed `m·k ≥ 2048` worker heuristic for every layer, the backend
//! can own a per-shape [`TunePlan`] — measured by
//! [`crate::sparse::tune`]'s grid search over `(tile_n, max_stripes)`,
//! keyed by `(m-bucket, k, n, keep, precision)`. [`TuneMode::Startup`]
//! tunes every artifact's layers at construction;
//! [`TuneMode::Lazy`] tunes a shape class the first time a batch
//! produces it (single-flighted, memoized); [`TuneMode::Off`] — the
//! default everywhere except `s4 serve --tune` — reproduces the legacy
//! fixed dispatch exactly. Plans vary only bitwise-invariant parameters,
//! so logits are identical at any plan; chosen tile variants are
//! repacked once at tune time and cached per layer, never on the hot
//! path. `--tune-plan <path>` persists the plan as JSON so restarts skip
//! recalibration.
//!
//! **Hot-path execution** (the PR-5 dispatch rework): every layer runs
//! through ONE long-lived [`ExecPool`] held by the backend — constructed
//! once per backend (or injected via [`CpuSparseBackend::with_pool`] and
//! shared between backends, e.g. an F32 and an Int8 instance) — instead
//! of spawning fresh threads per layer call. The forward pass itself is
//! **zero-alloc in steady state**: each forward leases a ping-pong
//! activation arena (two [`Dense2`] buffers plus an int8 staging
//! buffer, grown monotonically to the max layer width × batch capacity)
//! off a free-list, replacing the per-layer `Dense2::zeros` the trunk
//! used to allocate; only the returned output [`Value`]s are freshly
//! allocated. Concurrent coordinator workers each lease their own arena
//! (the list grows to peak concurrency, then everything is reuse), so
//! small-batch forwards still overlap across workers while large-batch
//! compute parallelizes across pool stripes. Arena pointer stability
//! across calls is pinned by the `arena_pointers_stable...` reuse test
//! below.

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::backend::{validate_inputs, InferenceBackend, TensorSpec, Value};
use crate::graph::op::OpKind;
use crate::runtime::manifest::{ArtifactIndex, ArtifactMeta, Manifest, Precision};
use crate::sparse::matmul::Act;
use crate::sparse::pack::{
    qspmm_tiled_into_plan, spmm_tiled_into_plan, PackedBlockBalanced, QPackedBlockBalanced,
};
use crate::sparse::pool::ExecPool;
use crate::sparse::tensor::{DType, Dense2};
use crate::sparse::tune::{bucket_m, DispatchPlan, ShapeClass, TuneConfig, TunePlan, Tuner};
use crate::sparse::{BlockBalanced, BLOCK, SUPPORTED_SPARSITIES};

/// Rows in the deterministic embedding table (token ids and element
/// positions are folded modulo this).
const EMBED_ROWS: usize = 512;

/// Sparse trunk depth of the distilled network.
const DEPTH: usize = 2;

/// `hidden` cap: keeps per-artifact construction (dense randn + prune)
/// in the low milliseconds even for ResNet-width (2048) feature layers.
const MAX_HIDDEN: usize = 512;

/// When the backend measures its dispatch plans (`s4 serve --tune`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TuneMode {
    /// legacy fixed dispatch: default tile, `m·k ≥ 2048` heuristic
    #[default]
    Off,
    /// tune every artifact's layer shapes at backend construction —
    /// pays the full calibration cost up front, serves tuned from the
    /// first request
    Startup,
    /// tune a shape class the first time a batch produces it
    /// (single-flighted; later requests hit the memoized plan)
    Lazy,
}

impl TuneMode {
    /// Parse a `--tune` argument value.
    pub fn parse(s: &str) -> Option<TuneMode> {
        match s {
            "off" => Some(TuneMode::Off),
            "startup" => Some(TuneMode::Startup),
            "lazy" => Some(TuneMode::Lazy),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TuneMode::Off => "off",
            TuneMode::Startup => "startup",
            TuneMode::Lazy => "lazy",
        }
    }
}

/// Autotuning policy for one backend: mode, measurement effort, and the
/// optional plan file (`--tune-plan <path>`) that is loaded at
/// construction (skipping recalibration of already-tuned classes) and
/// rewritten whenever new classes are tuned.
#[derive(Clone, Debug, Default)]
pub struct TuneOptions {
    pub mode: TuneMode,
    pub config: TuneConfig,
    pub plan_path: Option<PathBuf>,
}

impl TuneOptions {
    /// No tuning — the legacy fixed dispatch (the default everywhere).
    pub fn off() -> TuneOptions {
        TuneOptions::default()
    }
}

/// One fused sparse layer: packed f32 weights, optionally their INT8
/// twin, + bias + activation epilogue. The INT8 side comes from the same
/// pruned matrix through the `prune → per-channel calibrate → pack`
/// pipeline, so F32/Int8 serving differ only in kernel + quantization
/// noise. `qw` is built only when the backend can actually serve Int8
/// (f32-only construction skips the quantize+pack cost and the ~25%
/// extra weight memory).
struct SparseLayer {
    w: PackedBlockBalanced,
    qw: Option<QPackedBlockBalanced>,
    bias: Vec<f32>,
    act: Act,
    /// tile-width variants of `w` a [`TunePlan`] selected — materialized
    /// once (at tune time, or on first dispatch of a loaded plan) and
    /// reused forever; the default tile never enters this cache, so the
    /// untuned path takes no lock
    variants: Mutex<HashMap<usize, Arc<PackedBlockBalanced>>>,
    /// the INT8 twin of `variants`
    qvariants: Mutex<HashMap<usize, Arc<QPackedBlockBalanced>>>,
}

impl SparseLayer {
    /// Deterministic layer `[k, n]` pruned to `sparsity`, seeded by `tag`.
    /// Weight scale 1/√k keeps activations O(1) through the trunk.
    fn new(k: usize, n: usize, sparsity: usize, act: Act, tag: &str, int8: bool) -> SparseLayer {
        let mut wd = Dense2::randn(k, n, fnv1a(tag));
        let scale = 1.0 / (k as f32).sqrt();
        for v in &mut wd.data {
            *v *= scale;
        }
        let bb = BlockBalanced::from_dense(&wd, sparsity)
            .expect("distilled layer dims are BLOCK-aligned");
        let mut brng = crate::util::rng::Xoshiro256::seed_from_u64(fnv1a(tag) ^ 0xB1A5);
        let bias = (0..n).map(|_| brng.next_gaussian() as f32 * 0.1).collect();
        let qw = int8.then(|| bb.quantize().pack());
        SparseLayer {
            w: bb.pack(),
            qw,
            bias,
            act,
            variants: Mutex::new(HashMap::new()),
            qvariants: Mutex::new(HashMap::new()),
        }
    }

    /// The layer's shape class for plan lookup at batch rows `m`.
    fn shape_class(&self, m: usize, prec: Precision) -> ShapeClass {
        ShapeClass::of(m, self.w.k, self.w.n, self.w.keep(), dtype_of(prec))
    }

    /// Fetch (materializing on first touch) the f32 weights repacked at
    /// `tile_n`. A repack is a one-time pure permute per (layer, tile);
    /// the lock is uncontended in steady state.
    fn variant(&self, tile_n: usize) -> Arc<PackedBlockBalanced> {
        let mut cache = self.variants.lock().unwrap_or_else(|p| p.into_inner());
        cache
            .entry(tile_n)
            .or_insert_with(|| Arc::new(self.w.repacked(tile_n)))
            .clone()
    }

    /// The INT8 twin of [`variant`](SparseLayer::variant).
    fn qvariant(&self, tile_n: usize) -> Arc<QPackedBlockBalanced> {
        let qw = self.qw.as_ref().expect("net built without int8 weights");
        let mut cache = self.qvariants.lock().unwrap_or_else(|p| p.into_inner());
        cache
            .entry(tile_n)
            .or_insert_with(|| Arc::new(qw.repacked(tile_n)))
            .clone()
    }

    /// Execute the layer at `prec` through the tiled engine on `plan`'s
    /// dispatch parameters, writing into the arena buffer `out` (`qbuf`
    /// stages quantized activations on the Int8 path) — no allocation
    /// once the arena has grown to the layer's footprint. A plan at the
    /// default tile (every untuned dispatch) runs straight on `self.w`;
    /// tuned tiles hit the variant cache.
    fn run_into(
        &self,
        pool: &ExecPool,
        x: &Dense2,
        prec: Precision,
        plan: DispatchPlan,
        qbuf: &mut Vec<i8>,
        out: &mut Dense2,
    ) {
        match prec {
            Precision::F32 => {
                if plan.tile_n == self.w.n_tile {
                    spmm_tiled_into_plan(pool, x, &self.w, Some(&self.bias), self.act, plan, out)
                } else {
                    let wt = self.variant(plan.tile_n);
                    spmm_tiled_into_plan(pool, x, &wt, Some(&self.bias), self.act, plan, out)
                }
            }
            Precision::Int8 => {
                // constructors build qw whenever any artifact can resolve
                // to Int8, so this is reachable only with it present
                let qw = self.qw.as_ref().expect("net built without int8 weights");
                if plan.tile_n == qw.n_tile {
                    qspmm_tiled_into_plan(
                        pool, x, qw, Some(&self.bias), self.act, plan, qbuf, out,
                    )
                } else {
                    let qwt = self.qvariant(plan.tile_n);
                    qspmm_tiled_into_plan(
                        pool, x, &qwt, Some(&self.bias), self.act, plan, qbuf, out,
                    )
                }
            }
        }
    }
}

/// Kernel element type a serving precision runs on (the [`TunePlan`]
/// key's dtype axis).
fn dtype_of(prec: Precision) -> DType {
    match prec {
        Precision::F32 => DType::F32,
        Precision::Int8 => DType::Int8,
    }
}

/// The ping-pong activation arena: layer `i` reads one buffer and writes
/// the other, so a whole forward pass touches exactly two activation
/// allocations (plus the int8 staging buffer), each grown monotonically
/// to the largest `batch × width` seen and then reused forever.
#[derive(Default)]
struct ActivationArena {
    ping: Dense2,
    pong: Dense2,
    /// quantized-activation staging for
    /// [`qspmm_tiled_into_plan`](crate::sparse::pack::qspmm_tiled_into_plan)
    qbuf: Vec<i8>,
}

/// The distilled sparse network for one artifact.
struct SparseNet {
    hidden: usize,
    embed: Dense2,
    trunk: Vec<SparseLayer>,
    /// one head per output spec
    heads: Vec<SparseLayer>,
}

impl SparseNet {
    fn build(model: &str, sparsity: usize, outputs: &[TensorSpec], int8: bool) -> SparseNet {
        let hidden = model_hidden(model);
        let embed = Dense2::randn(EMBED_ROWS, hidden, fnv1a(&format!("{model}/embed")));
        let trunk = (0..DEPTH)
            .map(|l| {
                SparseLayer::new(
                    hidden,
                    hidden,
                    sparsity,
                    Act::Gelu,
                    &format!("{model}/trunk{l}"),
                    int8,
                )
            })
            .collect();
        let heads = outputs
            .iter()
            .enumerate()
            .map(|(i, o)| {
                SparseLayer::new(
                    hidden,
                    o.sample_elems(),
                    sparsity,
                    Act::None,
                    &format!("{model}/head{i}"),
                    int8,
                )
            })
            .collect();
        SparseNet { hidden, embed, trunk, heads }
    }
}

pub struct CpuSparseBackend {
    /// nets are shared across artifact variants: weights depend only on
    /// (model, clamped sparsity, output sample widths), so `_b1`/`_b8`
    /// variants of one model reference the same network
    nets: ArtifactIndex<Arc<SparseNet>>,
    threads: usize,
    /// `Some` forces every artifact to this precision (`s4 serve
    /// --precision`); `None` follows each artifact's manifest field.
    precision: Option<Precision>,
    /// the ONE dispatch pool every layer of every artifact runs on —
    /// held for the backend's lifetime (shared F32/Int8, shareable
    /// across backends via [`CpuSparseBackend::with_pool`])
    pool: Arc<ExecPool>,
    /// free-list of ping-pong activation arenas: a forward *leases* one
    /// (popping under a short lock, never holding it during compute), so
    /// concurrent coordinator workers overlap fully; the list grows to
    /// the peak forward concurrency and is then reused forever
    arenas: Mutex<Vec<ActivationArena>>,
    /// autotuning policy (mode / grid / plan file); `TuneMode::Off`
    /// everywhere except `s4 serve --tune` and [`with_tuning`]
    /// constructions
    ///
    /// [`with_tuning`]: CpuSparseBackend::with_tuning
    tune: TuneOptions,
    /// the measured shape-class → dispatch-plan table; consulted (briefly
    /// locked, plans copied out) per batch when tuning is on
    plan: Mutex<TunePlan>,
    /// single-flights lazy tuning so concurrent first-sights of a shape
    /// class microbenchmark once, not once per worker (lock order:
    /// `tune_gate` before `plan`)
    tune_gate: Mutex<()>,
}

/// Largest SPU-supported sparsity ≤ the manifest's tier (manifests may
/// carry 0 or off-grid values; clamping keeps construction total).
fn clamp_sparsity(s: usize) -> usize {
    SUPPORTED_SPARSITIES
        .iter()
        .copied()
        .filter(|&t| t <= s.max(1))
        .max()
        .unwrap_or(1)
}

/// Feature width for a model: the reduction width of the final MatMul in
/// its graph (hidden size for BERT, pooled channels for ResNet), rounded
/// to the hardware block and capped. Unknown models get the default.
fn model_hidden(model: &str) -> usize {
    let from_graph = crate::graph::models::by_name(model, 1).ok().and_then(|g| {
        g.ops.iter().rev().find_map(|o| match o.kind {
            OpKind::MatMul { k, .. } => Some(k),
            _ => None,
        })
    });
    let h = from_graph.unwrap_or(128).min(MAX_HIDDEN).max(BLOCK);
    (h + BLOCK - 1) / BLOCK * BLOCK
}

/// FNV-1a (64-bit) over a tag string — stable weight seeding across
/// runs/platforms.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

impl CpuSparseBackend {
    /// Default ceiling on per-layer stripe parallelism when constructors
    /// derive the thread count from the machine (beyond ~8 stripes the
    /// distilled layers are dispatch-bound, not compute-bound). Shared
    /// with the serving bench so recorded `host.effective_workers`
    /// metadata cannot drift from what the backend dispatches.
    pub const DEFAULT_THREAD_CAP: usize = 8;

    /// Build distilled sparse networks for every artifact in `m`.
    /// Threads default to the machine's parallelism (capped at
    /// [`DEFAULT_THREAD_CAP`](Self::DEFAULT_THREAD_CAP)); the kernel
    /// stays deterministic at any setting.
    pub fn from_manifest(m: &Manifest) -> CpuSparseBackend {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(Self::DEFAULT_THREAD_CAP);
        Self::with_threads(m, threads)
    }

    pub fn with_threads(m: &Manifest, threads: usize) -> CpuSparseBackend {
        Self::with_threads_precision(m, threads, None)
    }

    /// Serve every artifact at `precision`, ignoring the manifest field
    /// (the `s4 serve --precision` override).
    pub fn with_precision(m: &Manifest, precision: Precision) -> CpuSparseBackend {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(Self::DEFAULT_THREAD_CAP);
        Self::with_threads_precision(m, threads, Some(precision))
    }

    /// [`with_threads_precision`](CpuSparseBackend::with_threads_precision)
    /// on the process-wide [`ExecPool::global`] pool.
    pub fn with_threads_precision(
        m: &Manifest,
        threads: usize,
        precision: Option<Precision>,
    ) -> CpuSparseBackend {
        Self::with_pool(m, threads, precision, ExecPool::global().clone())
    }

    /// Autotuned construction at default threads on the global pool:
    /// per-artifact manifest precision, dispatch plans per `tune`
    /// (`s4 serve --tune {off,startup,lazy} [--tune-plan <path>]`).
    pub fn with_tuning(m: &Manifest, tune: TuneOptions) -> CpuSparseBackend {
        Self::with_tuning_precision(m, None, tune)
    }

    /// [`with_tuning`](CpuSparseBackend::with_tuning) with an optional
    /// process-wide precision override. Precision is *never* a tuned
    /// parameter — it changes numerics, so it stays manifest-driven (or
    /// explicitly forced here); the tuner only picks bitwise-invariant
    /// dispatch shapes within whichever precision serves.
    pub fn with_tuning_precision(
        m: &Manifest,
        precision: Option<Precision>,
        tune: TuneOptions,
    ) -> CpuSparseBackend {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(Self::DEFAULT_THREAD_CAP);
        Self::with_pool_tuning(m, threads, precision, ExecPool::global().clone(), tune)
    }

    /// Explicit thread count, optional precision override (`None` =
    /// per-artifact from the manifest), and the dispatch pool — pass one
    /// `Arc<ExecPool>` to several backends to share a single worker set
    /// (e.g. an F32 and an Int8 backend on one machine; the pool
    /// serializes their dispatches instead of oversubscribing cores).
    /// Tuning off.
    pub fn with_pool(
        m: &Manifest,
        threads: usize,
        precision: Option<Precision>,
        pool: Arc<ExecPool>,
    ) -> CpuSparseBackend {
        Self::with_pool_tuning(m, threads, precision, pool, TuneOptions::off())
    }

    /// Full constructor: [`with_pool`](CpuSparseBackend::with_pool) plus
    /// the autotuning policy. Loads `tune.plan_path` if the file exists
    /// (already-tuned classes skip recalibration); under
    /// [`TuneMode::Startup`] every artifact's layer shapes are then
    /// measured here, and the merged plan is written back.
    pub fn with_pool_tuning(
        m: &Manifest,
        threads: usize,
        precision: Option<Precision>,
        pool: Arc<ExecPool>,
        tune: TuneOptions,
    ) -> CpuSparseBackend {
        type NetKey = (String, usize, Vec<usize>);
        let net_key = |a: &ArtifactMeta| -> NetKey {
            (
                a.model.clone(),
                clamp_sparsity(a.sparsity),
                a.outputs.iter().map(|o| o.sample_elems()).collect(),
            )
        };
        // a net carries the quantized twin only if one of its artifacts
        // can resolve to Int8 under the effective precision policy —
        // f32-only nets skip the quantize+pack cost and extra memory
        let int8_nets: HashSet<NetKey> = m
            .artifacts
            .iter()
            .filter(|a| precision.unwrap_or(a.precision) == Precision::Int8)
            .map(|a| net_key(a))
            .collect();
        let mut cache: HashMap<NetKey, Arc<SparseNet>> = HashMap::new();
        let nets = ArtifactIndex::build(m, |a| {
            let key = net_key(a);
            let int8 = int8_nets.contains(&key);
            cache
                .entry(key)
                .or_insert_with(|| {
                    let s = clamp_sparsity(a.sparsity);
                    Arc::new(SparseNet::build(&a.model, s, &a.outputs, int8))
                })
                .clone()
        });
        let mut initial = TunePlan::new();
        if let Some(path) = &tune.plan_path {
            if path.exists() {
                match TunePlan::load(path) {
                    Ok(p) => initial = p,
                    // a stale/corrupt plan file must not stop serving —
                    // fall through to retuning from scratch
                    Err(e) => eprintln!("s4: ignoring tune plan: {e}"),
                }
            }
        }
        let backend = CpuSparseBackend {
            nets,
            threads: threads.max(1),
            precision,
            pool,
            arenas: Mutex::new(Vec::new()),
            tune,
            plan: Mutex::new(initial),
            tune_gate: Mutex::new(()),
        };
        if backend.tune.mode == TuneMode::Startup {
            let mut tuned_any = false;
            for (meta, net) in backend.nets.iter() {
                let prec = backend.precision.unwrap_or(meta.precision);
                let capacity = meta.inputs.first().map(|s| s.batch_dim()).unwrap_or(1);
                tuned_any |= backend.ensure_net_tuned(net, prec, bucket_m(capacity));
            }
            if tuned_any {
                backend.save_plan();
            }
        }
        backend
    }

    /// Tune every not-yet-planned shape class of `net` at batch-row
    /// bucket `m` (single-flighted; concurrent callers of the same
    /// classes measure once). Returns whether anything new was tuned.
    fn ensure_net_tuned(&self, net: &SparseNet, prec: Precision, m: usize) -> bool {
        let layers: Vec<&SparseLayer> = net.trunk.iter().chain(&net.heads).collect();
        let any_missing = {
            let plan = self.plan.lock().unwrap_or_else(|p| p.into_inner());
            layers.iter().any(|l| plan.get(&l.shape_class(m, prec)).is_none())
        };
        if !any_missing {
            return false;
        }
        // single-flight: the losers of this race re-check per class below
        // and find the winner's entries (lock order: tune_gate → plan)
        let _flight = self.tune_gate.lock().unwrap_or_else(|p| p.into_inner());
        let mut tuned_any = false;
        for layer in layers {
            let class = layer.shape_class(m, prec);
            let have = {
                let plan = self.plan.lock().unwrap_or_else(|p| p.into_inner());
                plan.get(&class).is_some()
            };
            if have {
                continue;
            }
            let chosen = self.tune_layer(layer, prec, m);
            self.plan
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .insert(class, chosen);
            tuned_any = true;
        }
        tuned_any
    }

    /// Microbenchmark one layer's candidate grid at batch rows `m` and
    /// return the winner. The grid always contains the incumbent default
    /// configuration (`ensure_tile`/`ensure_stripe` below), so a tuned
    /// plan can never lose to the fixed dispatch by more than timing
    /// noise; the chosen tile variant is materialized into the layer's
    /// cache here so the hot path never repacks.
    fn tune_layer(&self, layer: &SparseLayer, prec: Precision, m: usize) -> DispatchPlan {
        let mut cfg = self.tune.config.clone();
        cfg.ensure_tile(layer.w.n_tile);
        cfg.ensure_stripe(1);
        cfg.ensure_stripe(self.threads);
        let tuner = Tuner::new(&self.pool, cfg);
        let chosen = match prec {
            Precision::F32 => tuner.tune_f32(&layer.w, Some(&layer.bias), layer.act, m),
            Precision::Int8 => {
                let qw = layer.qw.as_ref().expect("net built without int8 weights");
                tuner.tune_int8(qw, Some(&layer.bias), layer.act, m)
            }
        };
        match prec {
            Precision::F32 => {
                if chosen.tile_n != layer.w.n_tile {
                    layer.variant(chosen.tile_n);
                }
            }
            Precision::Int8 => {
                let qw = layer.qw.as_ref().expect("net built without int8 weights");
                if chosen.tile_n != qw.n_tile {
                    layer.qvariant(chosen.tile_n);
                }
            }
        }
        chosen
    }

    /// Copy each layer's dispatch plan out of the table (trunk order,
    /// then heads) for one forward at batch rows `m` — cloned under a
    /// short lock so compute never runs with the table locked. Untuned
    /// classes fall back to the legacy fixed dispatch.
    fn dispatch_plans(&self, net: &SparseNet, prec: Precision, m: usize) -> Vec<DispatchPlan> {
        let plan = self.plan.lock().unwrap_or_else(|p| p.into_inner());
        net.trunk
            .iter()
            .chain(&net.heads)
            .map(|l| {
                plan.get(&l.shape_class(m, prec))
                    .unwrap_or_else(|| DispatchPlan::fixed_default(m, l.w.k, self.threads))
            })
            .collect()
    }

    /// Write the current plan table to `tune.plan_path` (no-op without a
    /// path). Failures are reported, not fatal — a read-only plan
    /// directory must not take serving down.
    fn save_plan(&self) {
        if let Some(path) = &self.tune.plan_path {
            let snapshot = self.plan.lock().unwrap_or_else(|p| p.into_inner()).clone();
            if let Err(e) = snapshot.save(path) {
                eprintln!("s4: tune plan save failed: {e}");
            }
        }
    }

    /// A copy of the current shape-class → plan table (tests pin
    /// save/load round trips and lazy memoization through this).
    pub fn plan_snapshot(&self) -> TunePlan {
        self.plan.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }

    /// Raw data addresses of the parked arena's three buffers `(ping,
    /// pong, qbuf)` — the probe the zero-alloc reuse tests pin: after
    /// one warm-up forward, sequential calls lease the same arena and
    /// these must not change.
    #[cfg(test)]
    fn arena_ptrs(&self) -> (usize, usize, usize) {
        let arenas = self.arenas.lock().unwrap_or_else(|p| p.into_inner());
        let a = arenas.last().expect("no forward has run yet");
        (
            a.ping.data.as_ptr() as usize,
            a.pong.data.as_ptr() as usize,
            a.qbuf.as_ptr() as usize,
        )
    }

    fn net(&self, artifact: &str) -> anyhow::Result<&(ArtifactMeta, Arc<SparseNet>)> {
        self.nets
            .get(artifact)
            .ok_or_else(|| anyhow::anyhow!("CpuSparseBackend: unknown artifact `{artifact}`"))
    }

    /// Effective serving precision of `artifact`: the process-wide
    /// override if set, else the artifact's manifest precision.
    pub fn precision_of(&self, artifact: &str) -> anyhow::Result<Precision> {
        Ok(self.precision.unwrap_or(self.net(artifact)?.0.precision))
    }

    /// Relative-L2 tolerance for this artifact's Int8 logits vs its F32
    /// logits, derived from the per-layer quantization error bounds: a
    /// logit crosses every trunk layer plus one head, and each quantized
    /// layer contributes at most [`QPackedBlockBalanced::rel_error_bound`]
    /// (½ LSB relative) weight noise plus the same ½-LSB relative noise
    /// from per-tensor activation quantization. `CANCEL_SLACK` covers the
    /// amplification when a dot product's terms partially cancel
    /// (empirically < 4× on the gaussian-ish distilled weights — cf. the
    /// 2% single-layer `qgemm_close_to_f32_gemm` bound vs the ~0.8%
    /// noise floor). The conformance suite asserts against this bound.
    pub fn int8_tolerance(&self, artifact: &str) -> anyhow::Result<f32> {
        const CANCEL_SLACK: f32 = 8.0;
        const ACT_REL: f32 = 0.5 / 127.0;
        let (_, net) = self.net(artifact)?;
        let rel = |l: &SparseLayer| -> anyhow::Result<f32> {
            let qw = l.qw.as_ref().ok_or_else(|| {
                anyhow::anyhow!("{artifact}: backend was built without the int8 path")
            })?;
            Ok(qw.rel_error_bound() + ACT_REL)
        };
        let mut trunk = 0.0f32;
        for l in &net.trunk {
            trunk += rel(l)?;
        }
        let mut head = 0.0f32;
        for l in &net.heads {
            head = head.max(rel(l)?);
        }
        Ok(CANCEL_SLACK * (trunk + head))
    }
}

/// Fold a batch's input tensors into `[capacity, hidden]` feature rows
/// through the embedding table, written into the arena buffer `feat`
/// (zeroed by its `reset` — accumulation starts clean, no allocation in
/// steady state). Position-salted so reorderings of the same tokens
/// produce distinct features; zero f32 elements (the coordinator's
/// padding) contribute nothing.
fn featurize_into(
    net: &SparseNet,
    specs: &[TensorSpec],
    inputs: &[Value],
    capacity: usize,
    feat: &mut Dense2,
) {
    let h = net.hidden;
    feat.reset(capacity, h);
    for (v, spec) in inputs.iter().zip(specs) {
        let per = spec.sample_elems();
        if per == 0 {
            continue;
        }
        let inv = 1.0 / per as f32;
        for b in 0..spec.batch_dim().min(capacity) {
            let frow = &mut feat.data[b * h..(b + 1) * h];
            match v {
                Value::I32(x) => {
                    for (t, &tok) in x[b * per..(b + 1) * per].iter().enumerate() {
                        let row = ((tok as i64).rem_euclid(EMBED_ROWS as i64) as usize + t)
                            % EMBED_ROWS;
                        for (f, &e) in frow.iter_mut().zip(net.embed.row(row)) {
                            *f += e * inv;
                        }
                    }
                }
                Value::F32(x) => {
                    for (t, &xv) in x[b * per..(b + 1) * per].iter().enumerate() {
                        if xv == 0.0 {
                            continue;
                        }
                        for (f, &e) in frow.iter_mut().zip(net.embed.row(t % EMBED_ROWS)) {
                            *f += e * xv * inv;
                        }
                    }
                }
            }
        }
    }
}

impl InferenceBackend for CpuSparseBackend {
    fn input_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        Ok(&self.net(artifact)?.0.inputs)
    }

    fn output_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        Ok(&self.net(artifact)?.0.outputs)
    }

    fn run_batch(&self, artifact: &str, inputs: &[Value]) -> anyhow::Result<Vec<Value>> {
        let (meta, net) = self.net(artifact)?;
        validate_inputs(artifact, &meta.inputs, inputs)?;
        let prec = self.precision.unwrap_or(meta.precision);
        let capacity = meta.inputs.first().map(|s| s.batch_dim()).unwrap_or(1);
        // per-layer dispatch plans: Off reproduces the legacy fixed
        // heuristic inside forward (no plan-table lock at all); Startup
        // reads the table tuned at construction; Lazy tunes this batch's
        // shape classes first if they're new (single-flighted, memoized,
        // persisted when a plan file is configured)
        let plans = match self.tune.mode {
            TuneMode::Off => None,
            TuneMode::Startup => Some(self.dispatch_plans(net, prec, capacity)),
            TuneMode::Lazy => {
                if self.ensure_net_tuned(net, prec, bucket_m(capacity)) {
                    self.save_plan();
                }
                Some(self.dispatch_plans(net, prec, capacity))
            }
        };
        // steady-state zero-alloc forward: lease an arena off the
        // free-list (a fresh one only when concurrency exceeds anything
        // seen before), featurize into its ping buffer, then ping-pong
        // through the trunk and heads — the only fresh allocations below
        // are the returned output Values. The lock is held only for the
        // pop/push, so concurrent forwards overlap; a poisoned lock is
        // recovered (a panicked forward must not brick the backend), and
        // an arena dropped by a panicking forward is simply re-grown.
        let mut arena = self
            .arenas
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .pop()
            .unwrap_or_default();
        let result = forward(
            net,
            meta,
            inputs,
            prec,
            self.threads,
            &self.pool,
            &mut arena,
            plans.as_deref(),
        );
        // the lease goes back even when the forward errors — an early
        // `?` must not leak a grown arena into per-call allocation
        self.arenas.lock().unwrap_or_else(|p| p.into_inner()).push(arena);
        result
    }
}

/// One forward pass through an artifact's distilled net, entirely inside
/// the leased `arena` (see [`CpuSparseBackend::run_batch`] for the
/// lease/return discipline — keeping this a separate function means
/// every exit path, including errors, flows back through the caller's
/// arena return). `plans` carries one tuned [`DispatchPlan`] per layer
/// (trunk order, then heads); `None` — tuning off — dispatches every
/// layer on [`DispatchPlan::fixed_default`], which is bit-for-bit the
/// legacy `m·k ≥ 2048` heuristic at the default tile.
#[allow(clippy::too_many_arguments)]
fn forward(
    net: &SparseNet,
    meta: &ArtifactMeta,
    inputs: &[Value],
    prec: Precision,
    threads: usize,
    pool: &ExecPool,
    arena: &mut ActivationArena,
    plans: Option<&[DispatchPlan]>,
) -> anyhow::Result<Vec<Value>> {
    let capacity = meta.inputs.first().map(|s| s.batch_dim()).unwrap_or(1);
    let plan_at = |i: usize, l: &SparseLayer| -> DispatchPlan {
        match plans {
            Some(p) => p[i],
            None => DispatchPlan::fixed_default(capacity, l.w.k, threads),
        }
    };
    let ActivationArena { ping, pong, qbuf } = arena;
    let (mut cur, mut nxt) = (ping, pong);
    featurize_into(net, &meta.inputs, inputs, capacity, cur);
    for (i, layer) in net.trunk.iter().enumerate() {
        layer.run_into(pool, cur, prec, plan_at(i, layer), qbuf, nxt);
        std::mem::swap(&mut cur, &mut nxt);
    }
    let mut out = Vec::with_capacity(meta.outputs.len());
    for (hi, (spec, head)) in meta.outputs.iter().zip(&net.heads).enumerate() {
        let per = spec.sample_elems();
        // every head reads the trunk output in `cur` and reuses the
        // free half of the arena for its logits
        head.run_into(pool, cur, prec, plan_at(net.trunk.len() + hi, head), qbuf, nxt);
        let y = &*nxt;
        let mut v = Value::empty(&spec.dtype)?;
        for b in 0..spec.batch_dim() {
            if b < capacity {
                let row = y.row(b);
                match &mut v {
                    Value::F32(vec) => vec.extend_from_slice(row),
                    // s32 outputs carry logits quantized at 1/256
                    Value::I32(vec) => {
                        vec.extend(row.iter().map(|&x| (x * 256.0).round() as i32))
                    }
                }
            } else {
                v.push_zeros(per);
            }
        }
        out.push(v);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn manifest() -> Manifest {
        let text = r#"{"artifacts": [
          {"name": "bert_tiny_s8_b2", "file": "x", "family": "bert",
           "model": "bert_tiny", "sparsity": 8, "batch": 2, "seq": 4,
           "inputs": [{"name": "ids", "shape": [2, 4], "dtype": "s32"}],
           "outputs": [{"name": "logits", "shape": [2, 3], "dtype": "f32"}]},
          {"name": "bert_tiny_s1_b2", "file": "y", "family": "bert",
           "model": "bert_tiny", "sparsity": 1, "batch": 2, "seq": 4,
           "inputs": [{"name": "ids", "shape": [2, 4], "dtype": "s32"}],
           "outputs": [{"name": "logits", "shape": [2, 3], "dtype": "f32"}]}
        ]}"#;
        Manifest::parse(Path::new("/tmp"), text).unwrap()
    }

    #[test]
    fn unknown_artifact_is_err_not_panic() {
        let b = CpuSparseBackend::from_manifest(&manifest());
        assert!(b.input_specs("nope").is_err());
        assert!(b.run_batch("nope", &[]).is_err());
    }

    #[test]
    fn logits_deterministic_and_input_sensitive() {
        let b = CpuSparseBackend::from_manifest(&manifest());
        let inputs = vec![Value::I32(vec![1, 2, 3, 4, 9, 9, 9, 9])];
        let o1 = b.run_batch("bert_tiny_s8_b2", &inputs).unwrap();
        let o2 = b.run_batch("bert_tiny_s8_b2", &inputs).unwrap();
        assert_eq!(o1, o2);
        let l = o1[0].as_f32().unwrap();
        assert_eq!(l.len(), 6);
        // distinct samples produce distinct logits
        assert_ne!(&l[0..3], &l[3..6]);
        // token order matters (position salt)
        let swapped = vec![Value::I32(vec![2, 1, 3, 4, 9, 9, 9, 9])];
        let o3 = b.run_batch("bert_tiny_s8_b2", &swapped).unwrap();
        assert_ne!(o1, o3);
    }

    #[test]
    fn deterministic_across_thread_counts_and_instances() {
        let m = manifest();
        let b1 = CpuSparseBackend::with_threads(&m, 1);
        let b4 = CpuSparseBackend::with_threads(&m, 4);
        let inputs = vec![Value::I32(vec![5, 6, 7, 8, 1, 2, 3, 4])];
        assert_eq!(
            b1.run_batch("bert_tiny_s8_b2", &inputs).unwrap(),
            b4.run_batch("bert_tiny_s8_b2", &inputs).unwrap()
        );
    }

    #[test]
    fn sparsity_tiers_share_weights_but_differ_in_pruning() {
        let b = CpuSparseBackend::from_manifest(&manifest());
        let inputs = vec![Value::I32(vec![1, 2, 3, 4, 0, 0, 0, 0])];
        let dense = b.run_batch("bert_tiny_s1_b2", &inputs).unwrap();
        let sparse = b.run_batch("bert_tiny_s8_b2", &inputs).unwrap();
        // same dense seed, different tier → close but not identical
        assert_ne!(dense, sparse);
    }

    #[test]
    fn rejects_malformed_batches() {
        let b = CpuSparseBackend::from_manifest(&manifest());
        assert!(b.run_batch("bert_tiny_s8_b2", &[Value::I32(vec![1; 7])]).is_err());
        assert!(b.run_batch("bert_tiny_s8_b2", &[Value::F32(vec![0.0; 8])]).is_err());
    }

    fn rel_l2(a: &[f32], b: &[f32]) -> f32 {
        let num: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
        let den: f32 = b.iter().map(|v| v * v).sum();
        if den == 0.0 {
            0.0
        } else {
            (num / den).sqrt()
        }
    }

    #[test]
    fn int8_mode_is_deterministic_and_close_to_f32() {
        let m = manifest();
        let f = CpuSparseBackend::from_manifest(&m);
        let q = CpuSparseBackend::with_precision(&m, Precision::Int8);
        let inputs = vec![Value::I32(vec![1, 2, 3, 4, 9, 9, 9, 9])];
        let of = f.run_batch("bert_tiny_s8_b2", &inputs).unwrap();
        let oq1 = q.run_batch("bert_tiny_s8_b2", &inputs).unwrap();
        let oq2 = q.run_batch("bert_tiny_s8_b2", &inputs).unwrap();
        assert_eq!(oq1, oq2, "int8 must be deterministic");
        assert_ne!(of, oq1, "int8 must actually run the quantized kernel");
        let tol = q.int8_tolerance("bert_tiny_s8_b2").unwrap();
        assert!(tol > 0.0 && tol < 0.5, "tolerance sane: {tol}");
        let rel = rel_l2(oq1[0].as_f32().unwrap(), of[0].as_f32().unwrap());
        assert!(rel <= tol, "int8 rel err {rel} exceeds tolerance {tol}");
    }

    #[test]
    fn int8_deterministic_across_thread_counts() {
        let m = manifest();
        let q1 = CpuSparseBackend::with_threads_precision(&m, 1, Some(Precision::Int8));
        let q4 = CpuSparseBackend::with_threads_precision(&m, 4, Some(Precision::Int8));
        let inputs = vec![Value::I32(vec![5, 6, 7, 8, 1, 2, 3, 4])];
        assert_eq!(
            q1.run_batch("bert_tiny_s8_b2", &inputs).unwrap(),
            q4.run_batch("bert_tiny_s8_b2", &inputs).unwrap()
        );
    }

    #[test]
    fn precision_follows_manifest_unless_overridden() {
        let text = r#"{"artifacts": [
          {"name": "q8", "file": "x", "family": "bert",
           "model": "bert_tiny", "sparsity": 8, "batch": 1, "seq": 4,
           "precision": "int8",
           "inputs": [{"name": "ids", "shape": [1, 4], "dtype": "s32"}],
           "outputs": [{"name": "logits", "shape": [1, 3], "dtype": "f32"}]}
        ]}"#;
        let m = Manifest::parse(std::path::Path::new("/tmp"), text).unwrap();
        let b = CpuSparseBackend::from_manifest(&m);
        assert_eq!(b.precision_of("q8").unwrap(), Precision::Int8);
        let forced = CpuSparseBackend::with_precision(&m, Precision::F32);
        assert_eq!(forced.precision_of("q8").unwrap(), Precision::F32);
        // manifest-selected int8 == override-selected int8, bitwise
        let inputs = vec![Value::I32(vec![4, 3, 2, 1])];
        let via_manifest = b.run_batch("q8", &inputs).unwrap();
        let via_override = CpuSparseBackend::with_precision(&m, Precision::Int8)
            .run_batch("q8", &inputs)
            .unwrap();
        assert_eq!(via_manifest, via_override);
        assert_ne!(via_manifest, forced.run_batch("q8", &inputs).unwrap());
    }

    #[test]
    fn arena_pointers_stable_across_calls_pool_zero_alloc() {
        // the steady-state zero-alloc contract: after one warm-up
        // forward per precision, the ping-pong arena (and the int8
        // staging buffer) never reallocates — pointer-stable across
        // calls, at both precisions, through the SAME backend arena
        let text = r#"{"artifacts": [
          {"name": "f32_art", "file": "x", "family": "bert",
           "model": "bert_tiny", "sparsity": 8, "batch": 2, "seq": 4,
           "inputs": [{"name": "ids", "shape": [2, 4], "dtype": "s32"}],
           "outputs": [{"name": "logits", "shape": [2, 3], "dtype": "f32"}]},
          {"name": "q8_art", "file": "y", "family": "bert",
           "model": "bert_tiny", "sparsity": 8, "batch": 2, "seq": 4,
           "precision": "int8",
           "inputs": [{"name": "ids", "shape": [2, 4], "dtype": "s32"}],
           "outputs": [{"name": "logits", "shape": [2, 3], "dtype": "f32"}]}
        ]}"#;
        let m = Manifest::parse(Path::new("/tmp"), text).unwrap();
        let b = CpuSparseBackend::from_manifest(&m);
        let inputs = vec![Value::I32(vec![1, 2, 3, 4, 5, 6, 7, 8])];
        // warm-up: grows the arena to the max footprint of both paths
        let f_ref = b.run_batch("f32_art", &inputs).unwrap();
        let q_ref = b.run_batch("q8_art", &inputs).unwrap();
        let ptrs = b.arena_ptrs();
        for _ in 0..4 {
            assert_eq!(b.run_batch("f32_art", &inputs).unwrap(), f_ref);
            assert_eq!(b.run_batch("q8_art", &inputs).unwrap(), q_ref);
            assert_eq!(b.arena_ptrs(), ptrs, "arena reallocated in steady state");
        }
    }

    #[test]
    fn two_backends_share_one_pool_interleaved_precisions() {
        // pool-reuse across backends: an F32 and an Int8 backend
        // dispatching on ONE ExecPool, interleaved, must match solo
        // backends exactly (the pool adds scheduling, never numerics)
        let m = manifest();
        let pool = Arc::new(ExecPool::new(3));
        let f = CpuSparseBackend::with_pool(&m, 4, None, pool.clone());
        let q = CpuSparseBackend::with_pool(&m, 4, Some(Precision::Int8), pool.clone());
        let f_solo = CpuSparseBackend::with_threads(&m, 4);
        let q_solo = CpuSparseBackend::with_threads_precision(&m, 4, Some(Precision::Int8));
        for i in 0..4 {
            let inputs = vec![Value::I32(vec![i, 2, 3, 4, 9, 8, 7, 6])];
            assert_eq!(
                f.run_batch("bert_tiny_s8_b2", &inputs).unwrap(),
                f_solo.run_batch("bert_tiny_s8_b2", &inputs).unwrap(),
                "shared-pool f32 diverged (i={i})"
            );
            assert_eq!(
                q.run_batch("bert_tiny_s8_b2", &inputs).unwrap(),
                q_solo.run_batch("bert_tiny_s8_b2", &inputs).unwrap(),
                "shared-pool int8 diverged (i={i})"
            );
        }
        assert_eq!(pool.workers(), 3, "backends must not resize a shared pool");
    }

    #[test]
    fn tune_mode_parse_grammar() {
        assert_eq!(TuneMode::parse("off"), Some(TuneMode::Off));
        assert_eq!(TuneMode::parse("startup"), Some(TuneMode::Startup));
        assert_eq!(TuneMode::parse("lazy"), Some(TuneMode::Lazy));
        assert_eq!(TuneMode::parse("eager"), None);
        assert_eq!(TuneMode::parse(""), None);
        for m in [TuneMode::Off, TuneMode::Startup, TuneMode::Lazy] {
            assert_eq!(TuneMode::parse(m.name()), Some(m));
        }
    }

    fn quick_tune(mode: TuneMode, plan_path: Option<std::path::PathBuf>) -> TuneOptions {
        TuneOptions { mode, config: TuneConfig::quick(), plan_path }
    }

    #[test]
    fn tuned_startup_backend_serves_bitwise_identical_logits() {
        // the whole point of restricting tuning to bitwise-invariant
        // parameters: a tuned backend and the untuned default must agree
        // exactly, at both precisions
        let m = manifest();
        let plain = CpuSparseBackend::from_manifest(&m);
        let tuned = CpuSparseBackend::with_tuning(&m, quick_tune(TuneMode::Startup, None));
        assert!(!tuned.plan_snapshot().is_empty(), "startup mode must have tuned");
        let qplain = CpuSparseBackend::with_precision(&m, Precision::Int8);
        let qtuned = CpuSparseBackend::with_tuning_precision(
            &m,
            Some(Precision::Int8),
            quick_tune(TuneMode::Startup, None),
        );
        for i in 0..3 {
            let inputs = vec![Value::I32(vec![i, 2, 3, 4, 9, 8, 7, 6])];
            for art in ["bert_tiny_s8_b2", "bert_tiny_s1_b2"] {
                assert_eq!(
                    plain.run_batch(art, &inputs).unwrap(),
                    tuned.run_batch(art, &inputs).unwrap(),
                    "tuned f32 logits diverged ({art}, i={i})"
                );
                assert_eq!(
                    qplain.run_batch(art, &inputs).unwrap(),
                    qtuned.run_batch(art, &inputs).unwrap(),
                    "tuned int8 logits diverged ({art}, i={i})"
                );
            }
        }
    }

    #[test]
    fn tune_lazy_memoizes_on_first_batch() {
        let m = manifest();
        let b = CpuSparseBackend::with_tuning(&m, quick_tune(TuneMode::Lazy, None));
        assert!(b.plan_snapshot().is_empty(), "lazy tunes nothing at construction");
        let plain = CpuSparseBackend::from_manifest(&m);
        let inputs = vec![Value::I32(vec![1, 2, 3, 4, 5, 6, 7, 8])];
        let first = b.run_batch("bert_tiny_s8_b2", &inputs).unwrap();
        let after_first = b.plan_snapshot();
        assert!(!after_first.is_empty(), "first sight of a shape class must tune it");
        assert_eq!(first, plain.run_batch("bert_tiny_s8_b2", &inputs).unwrap());
        // second batch of the same shape: memoized, table unchanged
        assert_eq!(b.run_batch("bert_tiny_s8_b2", &inputs).unwrap(), first);
        assert_eq!(b.plan_snapshot(), after_first, "re-tuned an already-planned class");
    }

    #[test]
    fn tune_plan_file_round_trips_through_a_backend() {
        // --tune-plan: a freshly tuned backend persists its plan; a
        // backend constructed from that file reloads an identical table
        // (bucket boundaries included) WITHOUT retuning, and serves
        // bitwise-identical logits
        let m = manifest();
        let path = std::env::temp_dir()
            .join(format!("s4_backend_tune_plan_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let fresh = CpuSparseBackend::with_tuning(
            &m,
            quick_tune(TuneMode::Startup, Some(path.clone())),
        );
        let saved = TunePlan::load(&path).expect("startup tuning must write the plan file");
        assert_eq!(saved, fresh.plan_snapshot(), "file differs from the in-memory plan");
        let mtime = std::fs::metadata(&path).unwrap().modified().unwrap();
        let reloaded = CpuSparseBackend::with_tuning(
            &m,
            quick_tune(TuneMode::Startup, Some(path.clone())),
        );
        assert_eq!(
            reloaded.plan_snapshot(),
            fresh.plan_snapshot(),
            "reloaded plan table differs"
        );
        assert_eq!(
            std::fs::metadata(&path).unwrap().modified().unwrap(),
            mtime,
            "fully-covered plan file must not be rewritten (recalibration skipped)"
        );
        for i in 0..3 {
            let inputs = vec![Value::I32(vec![i, 7, 5, 3, 2, 4, 6, 8])];
            assert_eq!(
                fresh.run_batch("bert_tiny_s8_b2", &inputs).unwrap(),
                reloaded.run_batch("bert_tiny_s8_b2", &inputs).unwrap(),
                "plan-file backend diverged from freshly-tuned backend (i={i})"
            );
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tuned_plans_come_from_the_candidate_grid() {
        // every recorded plan must be a member of the (extended) grid and
        // honor the pool's participant bound
        let m = manifest();
        let b = CpuSparseBackend::with_tuning(&m, quick_tune(TuneMode::Startup, None));
        let mut cfg = TuneConfig::quick();
        cfg.ensure_tile(crate::sparse::N_TILE);
        cfg.ensure_stripe(1);
        cfg.ensure_stripe(b.threads);
        let grid = cfg.candidates();
        for (class, plan) in b.plan_snapshot().iter() {
            assert!(
                grid.iter().any(|c| c.tile_n == plan.tile_n),
                "{class:?}: tile {} not in grid",
                plan.tile_n
            );
            assert!(
                plan.max_stripes <= b.pool.participants(),
                "{class:?}: stripes {} exceed pool", plan.max_stripes
            );
        }
    }

    #[test]
    fn hidden_and_sparsity_derivation() {
        assert_eq!(model_hidden("bert_tiny"), 128);
        assert_eq!(model_hidden("resnet50"), MAX_HIDDEN);
        assert_eq!(model_hidden("__no_such_model__"), 128);
        assert_eq!(clamp_sparsity(8), 8);
        assert_eq!(clamp_sparsity(0), 1);
        assert_eq!(clamp_sparsity(3), 2);
        assert_eq!(clamp_sparsity(999), 32);
    }
}
