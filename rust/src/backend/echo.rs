//! Instant test backend: reflects inputs back as recognizable outputs.
//!
//! For every sample, each output tensor carries `[first element of the
//! sample's first input, batch capacity, 0, 0, ...]` — enough structure
//! for tests to assert that padding, routing and demux preserved their
//! payload, with zero service time (isolates coordinator overhead in
//! benches).

use crate::backend::{validate_inputs, InferenceBackend, TensorSpec, Value};
use crate::runtime::manifest::{ArtifactIndex, ArtifactMeta, Manifest};

pub struct EchoBackend {
    metas: ArtifactIndex<()>,
}

impl EchoBackend {
    pub fn from_manifest(m: &Manifest) -> EchoBackend {
        EchoBackend { metas: ArtifactIndex::build(m, |_| ()) }
    }

    fn meta(&self, artifact: &str) -> anyhow::Result<&ArtifactMeta> {
        self.metas
            .get(artifact)
            .map(|(a, _)| a)
            .ok_or_else(|| anyhow::anyhow!("EchoBackend: unknown artifact `{artifact}`"))
    }
}

impl InferenceBackend for EchoBackend {
    fn input_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        Ok(&self.meta(artifact)?.inputs)
    }

    fn output_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        Ok(&self.meta(artifact)?.outputs)
    }

    fn run_batch(&self, artifact: &str, inputs: &[Value]) -> anyhow::Result<Vec<Value>> {
        let meta = self.meta(artifact)?;
        validate_inputs(artifact, &meta.inputs, inputs)?;
        let capacity = meta.inputs.first().map(|s| s.batch_dim()).unwrap_or(1);
        // first element of sample `b` of the first input, as f64
        let first = |b: usize| -> f64 {
            let per = meta.inputs.first().map(|s| s.sample_elems()).unwrap_or(0);
            if per == 0 || b >= capacity {
                return 0.0;
            }
            match inputs.first() {
                Some(Value::I32(x)) => x[b * per] as f64,
                Some(Value::F32(x)) => x[b * per] as f64,
                None => 0.0,
            }
        };
        let mut out = Vec::with_capacity(meta.outputs.len());
        for o in &meta.outputs {
            let per = o.sample_elems();
            let mut v = Value::empty(&o.dtype)?;
            for b in 0..o.batch_dim() {
                for c in 0..per {
                    let x = match c {
                        0 => first(b),
                        1 => capacity as f64,
                        _ => 0.0,
                    };
                    match &mut v {
                        Value::F32(vec) => vec.push(x as f32),
                        Value::I32(vec) => vec.push(x as i32),
                    }
                }
            }
            out.push(v);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn manifest() -> Manifest {
        let text = r#"{"artifacts": [
          {"name": "m_b2", "file": "x", "family": "bert", "model": "m",
           "sparsity": 8, "batch": 2, "seq": 3,
           "inputs": [{"name": "ids", "shape": [2, 3], "dtype": "s32"}],
           "outputs": [{"name": "logits", "shape": [2, 2], "dtype": "f32"}]}
        ]}"#;
        Manifest::parse(Path::new("/tmp"), text).unwrap()
    }

    #[test]
    fn echoes_first_element_and_capacity() {
        let b = EchoBackend::from_manifest(&manifest());
        let out = b
            .run_batch("m_b2", &[Value::I32(vec![7, 0, 0, 9, 0, 0])])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], Value::F32(vec![7.0, 2.0, 9.0, 2.0]));
    }

    #[test]
    fn unknown_artifact_is_err() {
        let b = EchoBackend::from_manifest(&manifest());
        assert!(b.run_batch("zz", &[]).is_err());
        assert!(b.input_specs("zz").is_err());
    }
}
