//! Typed tensor values — the payload type of the unified inference API.
//!
//! A [`Value`] is a flat, typed buffer; shape and dtype *contracts* come
//! from the [`TensorSpec`]s an artifact publishes through
//! [`InferenceBackend::input_specs`](crate::backend::InferenceBackend::input_specs).
//! The same type carries a single sample inside a
//! [`Request`](crate::coordinator::Request), a packed batch handed to a
//! backend, and a demuxed per-sample output inside a
//! [`Response`](crate::coordinator::Response).

use crate::runtime::manifest::TensorSpec;

/// A flat, typed tensor buffer (manifest dtypes: `s32`, `f32`).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    I32(Vec<i32>),
    F32(Vec<f32>),
}

impl Value {
    pub fn len(&self) -> usize {
        match self {
            Value::I32(v) => v.len(),
            Value::F32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Manifest dtype tag of this value (`"s32"` / `"f32"`).
    pub fn dtype(&self) -> &'static str {
        match self {
            Value::I32(_) => "s32",
            Value::F32(_) => "f32",
        }
    }

    /// Token-id payload for single-input text models (BERT-style) — the
    /// `Value`-level replacement for the retired
    /// `ServerHandle::submit_tokens`: submit with
    /// `submit(model, vec![Value::tokens(ids)])`.
    pub fn tokens(ids: Vec<i32>) -> Value {
        Value::I32(ids)
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            Value::I32(v) => Some(v),
            Value::F32(_) => None,
        }
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Value::F32(v) => Some(v),
            Value::I32(_) => None,
        }
    }

    /// Empty value of the given manifest dtype.
    pub fn empty(dtype: &str) -> anyhow::Result<Value> {
        match dtype {
            "s32" => Ok(Value::I32(Vec::new())),
            "f32" => Ok(Value::F32(Vec::new())),
            other => anyhow::bail!("unsupported dtype `{other}` (expected s32|f32)"),
        }
    }

    /// All-zeros value of `elems` elements.
    pub fn zeros(dtype: &str, elems: usize) -> anyhow::Result<Value> {
        let mut v = Value::empty(dtype)?;
        v.push_zeros(elems);
        Ok(v)
    }

    /// Whether this value's dtype can feed `spec` (lengths are checked
    /// separately: serving pads sample-shaped payloads up to spec size).
    pub fn matches_dtype(&self, spec: &TensorSpec) -> bool {
        self.dtype() == spec.dtype
    }

    /// Append `n` zero elements.
    pub fn push_zeros(&mut self, n: usize) {
        match self {
            Value::I32(v) => v.resize(v.len() + n, 0),
            Value::F32(v) => v.resize(v.len() + n, 0.0),
        }
    }

    /// Append one sample slot from `src`: copies up to `per_sample`
    /// elements (over-long payloads are truncated, matching the seed's
    /// token-resize behaviour) and zero-pads the remainder.
    pub fn push_padded(&mut self, src: &Value, per_sample: usize) -> anyhow::Result<()> {
        match (self, src) {
            (Value::I32(dst), Value::I32(s)) => {
                let n = s.len().min(per_sample);
                dst.extend_from_slice(&s[..n]);
                dst.resize(dst.len() + per_sample - n, 0);
                Ok(())
            }
            (Value::F32(dst), Value::F32(s)) => {
                let n = s.len().min(per_sample);
                dst.extend_from_slice(&s[..n]);
                dst.resize(dst.len() + per_sample - n, 0.0);
                Ok(())
            }
            (dst, src) => anyhow::bail!(
                "dtype mismatch: batch is {}, sample is {}",
                dst.dtype(),
                src.dtype()
            ),
        }
    }

    /// Copy out `len` elements starting at `start` as an owned value
    /// (batch demux). Callers validate bounds against the output spec.
    pub fn slice(&self, start: usize, len: usize) -> Value {
        match self {
            Value::I32(v) => Value::I32(v[start..start + len].to_vec()),
            Value::F32(v) => Value::F32(v[start..start + len].to_vec()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_tags_and_accessors() {
        let i = Value::I32(vec![1, 2]);
        let f = Value::F32(vec![0.5]);
        assert_eq!(i.dtype(), "s32");
        assert_eq!(f.dtype(), "f32");
        assert_eq!(i.as_i32(), Some(&[1, 2][..]));
        assert!(i.as_f32().is_none());
        assert_eq!(f.as_f32(), Some(&[0.5][..]));
        assert_eq!(i.len(), 2);
        assert!(!i.is_empty());
    }

    #[test]
    fn zeros_and_empty() {
        assert_eq!(Value::zeros("s32", 3).unwrap(), Value::I32(vec![0; 3]));
        assert_eq!(Value::zeros("f32", 2).unwrap(), Value::F32(vec![0.0; 2]));
        assert!(Value::empty("bf16").is_err());
    }

    #[test]
    fn push_padded_truncates_and_pads() {
        let mut b = Value::empty("s32").unwrap();
        b.push_padded(&Value::I32(vec![7, 8]), 4).unwrap();
        b.push_padded(&Value::I32(vec![1, 2, 3, 4, 5]), 4).unwrap();
        assert_eq!(b, Value::I32(vec![7, 8, 0, 0, 1, 2, 3, 4]));
        assert!(b.push_padded(&Value::F32(vec![1.0]), 4).is_err());
    }

    #[test]
    fn slice_extracts_samples() {
        let b = Value::F32(vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(b.slice(2, 2), Value::F32(vec![3.0, 4.0]));
    }
}
