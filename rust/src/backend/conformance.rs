//! Backend conformance suite: the behavioural contract of
//! [`InferenceBackend`], written as reusable assertion functions.
//!
//! These are plain `pub fn`s (not `#[test]`s) so any test crate can apply
//! them to any implementation — `rust/tests/backend_conformance.rs` runs
//! the suite against [`EchoBackend`](crate::backend::EchoBackend),
//! [`SimBackend`](crate::backend::SimBackend), and
//! [`CpuSparseBackend`](crate::backend::CpuSparseBackend). (No PJRT-backed
//! run exists yet — adding one once real artifacts are wired into CI is
//! an open item.) A new backend gets the whole contract checked with one
//! `run_all` call.

use crate::backend::{InferenceBackend, Value};
use crate::runtime::manifest::Manifest;

/// Specs round-trip the manifest: what the backend reports per artifact is
/// exactly what the manifest declared, and `batch_capacity` follows the
/// first input's leading dim.
pub fn check_spec_introspection(b: &dyn InferenceBackend, m: &Manifest) {
    for a in &m.artifacts {
        let ins = b.input_specs(&a.name).expect("input_specs on known artifact");
        let outs = b.output_specs(&a.name).expect("output_specs on known artifact");
        assert_eq!(ins, &a.inputs[..], "{}: input specs drifted", a.name);
        assert_eq!(outs, &a.outputs[..], "{}: output specs drifted", a.name);
        let want_cap = a.inputs.first().map(|s| s.batch_dim()).unwrap_or(1);
        assert_eq!(b.batch_capacity(&a.name).unwrap(), want_cap, "{}: capacity", a.name);
    }
}

/// Unknown artifacts surface as `Err` from every trait method — never a
/// panic (the seed's `SimBackend::spec` panicked here).
pub fn check_unknown_artifact_is_error(b: &dyn InferenceBackend) {
    let name = "__conformance_no_such_artifact__";
    assert!(b.input_specs(name).is_err(), "input_specs must Err on unknown artifact");
    assert!(b.output_specs(name).is_err(), "output_specs must Err on unknown artifact");
    assert!(b.run_batch(name, &[]).is_err(), "run_batch must Err on unknown artifact");
}

/// Spec-shaped inputs produce spec-shaped outputs: one value per output
/// spec, exact element count, matching dtype.
pub fn check_output_shapes(b: &dyn InferenceBackend, m: &Manifest) {
    for a in &m.artifacts {
        let inputs: Vec<Value> = a
            .inputs
            .iter()
            .map(|s| Value::zeros(&s.dtype, s.elems()).expect("spec dtype"))
            .collect();
        let outs = b
            .run_batch(&a.name, &inputs)
            .unwrap_or_else(|e| panic!("{}: valid batch rejected: {e}", a.name));
        assert_eq!(outs.len(), a.outputs.len(), "{}: output arity", a.name);
        for (v, s) in outs.iter().zip(&a.outputs) {
            assert_eq!(v.len(), s.elems(), "{}: output `{}` size", a.name, s.name);
            assert_eq!(v.dtype(), s.dtype, "{}: output `{}` dtype", a.name, s.name);
        }
    }
}

/// Malformed batches are rejected: wrong arity, wrong element count,
/// wrong dtype (checked on every artifact that declares inputs).
pub fn check_input_validation(b: &dyn InferenceBackend, m: &Manifest) {
    for a in m.artifacts.iter().filter(|a| !a.inputs.is_empty()) {
        let good = || -> Vec<Value> {
            a.inputs
                .iter()
                .map(|s| Value::zeros(&s.dtype, s.elems()).unwrap())
                .collect()
        };
        assert!(
            b.run_batch(&a.name, &[]).is_err(),
            "{}: empty input set must be rejected",
            a.name
        );
        let mut wrong_len = good();
        wrong_len[0].push_zeros(1);
        assert!(
            b.run_batch(&a.name, &wrong_len).is_err(),
            "{}: oversized input must be rejected",
            a.name
        );
        let mut wrong_dtype = good();
        wrong_dtype[0] = match wrong_dtype[0].dtype() {
            "s32" => Value::F32(vec![0.0; a.inputs[0].elems()]),
            _ => Value::I32(vec![0; a.inputs[0].elems()]),
        };
        assert!(
            b.run_batch(&a.name, &wrong_dtype).is_err(),
            "{}: wrong-dtype input must be rejected",
            a.name
        );
    }
}

/// Identical batches produce identical outputs (the coordinator's batch
/// demux and any response caching rely on this).
pub fn check_determinism(b: &dyn InferenceBackend, m: &Manifest) {
    for a in &m.artifacts {
        let inputs: Vec<Value> = a
            .inputs
            .iter()
            .enumerate()
            .map(|(i, s)| match s.dtype.as_str() {
                "s32" => Value::I32((0..s.elems() as i32).map(|x| x + i as i32).collect()),
                _ => Value::F32((0..s.elems()).map(|x| x as f32 * 0.5).collect()),
            })
            .collect();
        let o1 = b.run_batch(&a.name, &inputs).expect("run 1");
        let o2 = b.run_batch(&a.name, &inputs).expect("run 2");
        assert_eq!(o1, o2, "{}: nondeterministic outputs", a.name);
    }
}

/// The whole contract.
pub fn run_all(b: &dyn InferenceBackend, m: &Manifest) {
    check_spec_introspection(b, m);
    check_unknown_artifact_is_error(b);
    check_output_shapes(b, m);
    check_input_validation(b, m);
    check_determinism(b, m);
}
