//! The unified typed inference API.
//!
//! One trait, [`InferenceBackend`], fronts every way this repo can execute
//! a compiled artifact:
//!
//! * the PJRT executor
//!   ([`runtime::PjrtServingBackend`](crate::runtime::executor), feature
//!   `pjrt`) — real HLO execution;
//! * [`CpuSparseBackend`] — real block-balanced sparse compute through
//!   the parallel tiled SpMM engine (the coordinator's CPU execution
//!   path; deterministic logits, no artifacts needed);
//! * [`SimBackend`] — simulator-paced, deterministic pseudo-outputs
//!   (serving benchmarks and tests without artifacts);
//! * [`EchoBackend`] — instant, input-reflecting (unit tests, coordinator
//!   overhead benches).
//!
//! Callers speak `(artifact, Vec<Value>)` and read back `Vec<Value>`;
//! shape/dtype contracts come from the manifest [`TensorSpec`]s exposed by
//! [`InferenceBackend::input_specs`] / [`InferenceBackend::output_specs`].
//! This replaces the old token-matrix-only `coordinator::Backend` trait —
//! ResNet image batches and BERT token batches now flow through the same
//! surface (paper §3's SparseRT claim: one runtime for CV, NLP and
//! multimodal workloads).
//!
//! [`conformance`] holds the shared assertion suite every implementation
//! must pass; integration tests run it against each in-tree backend.

pub mod conformance;
pub mod cpu;
pub mod echo;
pub mod sim;
pub mod value;

pub use crate::runtime::manifest::{Precision, TensorSpec};
pub use cpu::{CpuSparseBackend, TuneMode, TuneOptions};
pub use echo::EchoBackend;
pub use sim::SimBackend;
pub use value::Value;

/// A uniform execution engine for compiled artifacts.
///
/// Implementations must be cheap to call concurrently (coordinator workers
/// share one instance behind an `Arc`).
pub trait InferenceBackend: Send + Sync + 'static {
    /// Input tensor specs for `artifact`, in positional order. `Err` on
    /// unknown artifacts — never panic. Borrowed (not cloned): spec
    /// introspection sits on the serving hot path.
    fn input_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]>;

    /// Output tensor specs for `artifact`, in positional order.
    fn output_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]>;

    /// Execute one full batch: `inputs` holds one [`Value`] per input
    /// spec, already batch-shaped (leading dim = the artifact's batch
    /// capacity; callers zero-pad short batches). Returns one [`Value`]
    /// per output spec, batch-shaped the same way.
    fn run_batch(&self, artifact: &str, inputs: &[Value]) -> anyhow::Result<Vec<Value>>;

    /// Batch capacity of `artifact`: the leading dim of its first input
    /// spec (1 when the artifact declares no inputs).
    fn batch_capacity(&self, artifact: &str) -> anyhow::Result<usize> {
        Ok(self
            .input_specs(artifact)?
            .first()
            .map(|s| s.batch_dim())
            .unwrap_or(1))
    }
}

/// Shared strict validation of a batch-shaped input set against specs:
/// arity, dtype, and exact element counts. Implementations call this at
/// the top of [`InferenceBackend::run_batch`].
pub fn validate_inputs(
    artifact: &str,
    specs: &[TensorSpec],
    inputs: &[Value],
) -> anyhow::Result<()> {
    anyhow::ensure!(
        inputs.len() == specs.len(),
        "{artifact}: expected {} inputs, got {}",
        specs.len(),
        inputs.len()
    );
    for (v, s) in inputs.iter().zip(specs) {
        anyhow::ensure!(
            v.matches_dtype(s),
            "{artifact}: input `{}` dtype mismatch (spec {}, value {})",
            s.name,
            s.dtype,
            v.dtype()
        );
        anyhow::ensure!(
            v.len() == s.elems(),
            "{artifact}: input `{}` needs {} elems, got {}",
            s.name,
            s.elems(),
            v.len()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, shape: &[usize], dtype: &str) -> TensorSpec {
        TensorSpec {
            name: name.to_string(),
            shape: shape.to_vec(),
            dtype: dtype.to_string(),
        }
    }

    #[test]
    fn validate_inputs_checks_arity_dtype_and_size() {
        let specs = vec![spec("ids", &[2, 4], "s32"), spec("mask", &[2, 4], "f32")];
        let ok = vec![Value::I32(vec![0; 8]), Value::F32(vec![0.0; 8])];
        assert!(validate_inputs("a", &specs, &ok).is_ok());
        // arity
        assert!(validate_inputs("a", &specs, &ok[..1]).is_err());
        // dtype
        let bad = vec![Value::F32(vec![0.0; 8]), Value::F32(vec![0.0; 8])];
        assert!(validate_inputs("a", &specs, &bad).is_err());
        // size
        let short = vec![Value::I32(vec![0; 7]), Value::F32(vec![0.0; 8])];
        assert!(validate_inputs("a", &specs, &short).is_err());
    }
}
