//! Simulator-paced backend: deterministic pseudo-outputs, service time
//! from the analytic cost model (scaled so tests run fast). Lets the full
//! serving stack be exercised and benchmarked without PJRT artifacts —
//! for any workload the manifest describes, vision or text.

use std::time::Duration;

use crate::backend::{validate_inputs, InferenceBackend, TensorSpec, Value};
use crate::runtime::manifest::{ArtifactIndex, ArtifactMeta, Manifest};

pub struct SimBackend {
    /// artifact metadata keyed by name, payload = simulated service time
    /// per batch
    specs: ArtifactIndex<Duration>,
}

impl SimBackend {
    /// Pace every artifact in `m` by simulating its model on the Antoum
    /// config at the artifact's sparsity; `time_scale` shrinks the
    /// simulated latency (1.0 = real pace, 0.01 = 100x faster).
    pub fn from_manifest(m: &Manifest, time_scale: f64) -> SimBackend {
        use crate::arch::AntoumConfig;
        use crate::graph::models;
        use crate::sim::{simulate, Target};
        let cfg = AntoumConfig::s4();
        let specs = ArtifactIndex::build(m, |a| {
            let g = models::by_name(&a.model, a.batch.max(1))
                .unwrap_or_else(|_| models::bert(models::BERT_TINY, a.batch.max(1), 128));
            let r = simulate(&g, Target::antoum(&cfg, a.sparsity.max(1)));
            let secs = (r.latency_ms / 1e3 * time_scale).max(1e-6);
            Duration::from_secs_f64(secs)
        });
        SimBackend { specs }
    }

    fn meta(&self, artifact: &str) -> anyhow::Result<&(ArtifactMeta, Duration)> {
        self.specs
            .get(artifact)
            .ok_or_else(|| anyhow::anyhow!("SimBackend: unknown artifact `{artifact}`"))
    }
}

impl InferenceBackend for SimBackend {
    fn input_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        Ok(&self.meta(artifact)?.0.inputs)
    }

    fn output_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        Ok(&self.meta(artifact)?.0.outputs)
    }

    fn run_batch(&self, artifact: &str, inputs: &[Value]) -> anyhow::Result<Vec<Value>> {
        let (meta, dt) = self.meta(artifact)?;
        validate_inputs(artifact, &meta.inputs, inputs)?;
        std::thread::sleep(*dt);
        let capacity = meta.inputs.first().map(|s| s.batch_dim()).unwrap_or(1);
        // deterministic pseudo-outputs: a per-sample hash over every input
        // tensor, so identical requests get identical answers regardless
        // of which batch they rode in
        let mut hashes = vec![0u64; capacity];
        for (v, spec) in inputs.iter().zip(&meta.inputs) {
            let per = spec.sample_elems();
            for (b, h) in hashes.iter_mut().enumerate().take(spec.batch_dim().min(capacity)) {
                match v {
                    Value::I32(x) => {
                        for &t in &x[b * per..(b + 1) * per] {
                            *h = h.wrapping_mul(31).wrapping_add(t as u64);
                        }
                    }
                    Value::F32(x) => {
                        for &t in &x[b * per..(b + 1) * per] {
                            *h = h.wrapping_mul(31).wrapping_add(t.to_bits() as u64);
                        }
                    }
                }
            }
        }
        let mut out = Vec::with_capacity(meta.outputs.len());
        for o in &meta.outputs {
            let per = o.sample_elems();
            let mut v = Value::empty(&o.dtype)?;
            for b in 0..o.batch_dim() {
                let h = hashes.get(b).copied().unwrap_or(0);
                match &mut v {
                    Value::F32(vec) => {
                        for c in 0..per {
                            vec.push(((h >> (c % 16)) & 0xff) as f32 / 255.0);
                        }
                    }
                    Value::I32(vec) => {
                        for c in 0..per {
                            vec.push(((h >> (c % 16)) & 0xff) as i32);
                        }
                    }
                }
            }
            out.push(v);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn manifest() -> Manifest {
        let text = r#"{"artifacts": [
          {"name": "bert_tiny_s8_b2", "file": "x", "family": "bert",
           "model": "bert_tiny", "sparsity": 8, "batch": 2, "seq": 4,
           "inputs": [{"name": "ids", "shape": [2, 4], "dtype": "s32"}],
           "outputs": [{"name": "logits", "shape": [2, 3], "dtype": "f32"}]}
        ]}"#;
        Manifest::parse(Path::new("/tmp"), text).unwrap()
    }

    #[test]
    fn unknown_artifact_is_err_not_panic() {
        let b = SimBackend::from_manifest(&manifest(), 1e-6);
        assert!(b.input_specs("nope").is_err());
        assert!(b.output_specs("nope").is_err());
        assert!(b.run_batch("nope", &[]).is_err());
    }

    #[test]
    fn outputs_are_deterministic_and_spec_shaped() {
        let b = SimBackend::from_manifest(&manifest(), 1e-6);
        let inputs = vec![Value::I32(vec![1, 2, 3, 4, 5, 6, 7, 8])];
        let o1 = b.run_batch("bert_tiny_s8_b2", &inputs).unwrap();
        let o2 = b.run_batch("bert_tiny_s8_b2", &inputs).unwrap();
        assert_eq!(o1, o2);
        assert_eq!(o1.len(), 1);
        assert_eq!(o1[0].len(), 6);
        assert_eq!(o1[0].dtype(), "f32");
        // different samples hash differently
        let l = o1[0].as_f32().unwrap();
        assert_ne!(&l[0..3], &l[3..6]);
    }

    #[test]
    fn rejects_malformed_batches() {
        let b = SimBackend::from_manifest(&manifest(), 1e-6);
        // wrong elem count
        assert!(b.run_batch("bert_tiny_s8_b2", &[Value::I32(vec![1; 7])]).is_err());
        // wrong dtype
        assert!(b.run_batch("bert_tiny_s8_b2", &[Value::F32(vec![0.0; 8])]).is_err());
    }
}
