//! The Antoum SoC model — every hardware block the paper describes.
//!
//! * [`config`] — chip parameter sets (the paper's §2 numbers).
//! * [`spu`] — sparse processing unit timing (up to 32× linear speedup).
//! * [`engines`] — VPU, activation engine, embedding lookup, reshape.
//! * [`memory`] — LPDDR4 channels + capacity/residency model.
//! * [`noc`] — 4-node bidirectional ring interconnect.
//! * [`codec`] — video decoder (64×1080p30) + JPEG (2320 FPS) engines.
//! * [`chip`] — resource assembly + energy/power model.
//! * [`event`] — the discrete-event core everything executes on.

pub mod chip;
pub mod codec;
pub mod config;
pub mod engines;
pub mod event;
pub mod memory;
pub mod noc;
pub mod spu;

pub use config::AntoumConfig;
pub use engines::Engine;
pub use event::{EventSim, ResourceId, TaskId};
