//! Chip parameter sets.
//!
//! The S4 numbers come straight from the paper's §2: 944 TOPS INT8 /
//! 472 TFLOPS BF16 *sparse-equivalent* (i.e. dense MAC throughput × the
//! 32× maximum sparsity), 20 GB LPDDR4 @ 72 GB/s, 70 W, four sparse
//! processing subsystems on a ring NoC, video codec 64×1080p30, JPEG
//! 2320 FPS 1080p. Microarchitectural parameters the paper does not state
//! (clock, buffer sizes, engine widths) are set to values consistent with
//! the stated aggregates and documented here; sensitivity to them is
//! exercised by the ablation benches.

use crate::sparse::tensor::DType;

/// Full chip configuration (the Antoum SoC on the S4 card).
#[derive(Clone, Debug)]
pub struct AntoumConfig {
    pub name: &'static str,
    /// number of sparse processing subsystems on the ring
    pub subsystems: usize,
    /// core clock (GHz)
    pub clock_ghz: f64,
    /// dense INT8 MACs per cycle per subsystem (so that chip dense TOPS
    /// × max sparsity 32 = the paper's 944 sparse-equivalent TOPS)
    pub spu_int8_macs_per_cycle: usize,
    /// maximum sparsity factor with linear speedup
    pub max_sparsity: usize,
    /// SPU weight buffer per subsystem (bytes) — compressed weights stream
    /// through this
    pub weight_buffer_bytes: usize,
    /// activation SRAM per subsystem (bytes)
    pub act_buffer_bytes: usize,
    /// fixed overhead per SPU tile dispatch (cycles): the non-scaling term
    /// that bends the speedup curve at 32× on small tiles
    pub spu_tile_overhead_cycles: f64,
    /// SPU tile dims (output rows × cols the array produces per pass)
    pub spu_tile_m: usize,
    pub spu_tile_n: usize,
    /// VPU: f32 lanes per cycle per subsystem
    pub vpu_lanes: usize,
    /// activation engine: transcendental evaluations per cycle per subsystem
    pub act_engine_lanes: usize,
    /// embedding lookup engine: peak rows/s is bandwidth-bound; this is its
    /// request overhead per row (cycles)
    pub lookup_row_overhead_cycles: f64,
    /// memory-reshape engine bytes per cycle per subsystem
    pub reshape_bytes_per_cycle: usize,
    /// LPDDR4: total capacity and bandwidth
    pub dram_bytes: usize,
    pub dram_gbps: f64,
    /// DRAM channels (bandwidth is split across them)
    pub dram_channels: usize,
    /// ring NoC: per-link bandwidth (GB/s) and per-hop latency (ns)
    pub noc_link_gbps: f64,
    pub noc_hop_ns: f64,
    /// video decode capability: concurrent 1080p30 streams
    pub video_streams_1080p30: usize,
    /// JPEG decode throughput, 1080p frames/s
    pub jpeg_fps_1080p: usize,
    /// board power envelope (W) and energy coefficients
    pub tdp_w: f64,
    /// pJ per INT8 MAC (dense-equivalent datapath energy)
    pub pj_per_mac_int8: f64,
    /// pJ per byte of DRAM traffic
    pub pj_per_dram_byte: f64,
}

impl AntoumConfig {
    /// The S4 card as shipped (paper §2).
    pub fn s4() -> AntoumConfig {
        // Derivation of MACs/cycle: dense INT8 = 944/32 = 29.5 TOPS.
        // TOPS = 2 (mul+add) × macs/cyc × subsystems × clock.
        // At 0.8 GHz, 4 subsystems: macs/cyc = 29.5e12 / (2·4·0.8e9) ≈ 4608.
        AntoumConfig {
            name: "moffett-s4",
            subsystems: 4,
            clock_ghz: 0.8,
            spu_int8_macs_per_cycle: 4608,
            max_sparsity: 32,
            weight_buffer_bytes: 8 << 20,
            act_buffer_bytes: 4 << 20,
            spu_tile_overhead_cycles: 8.0,
            spu_tile_m: 128,
            spu_tile_n: 128,
            vpu_lanes: 256,
            act_engine_lanes: 64,
            lookup_row_overhead_cycles: 4.0,
            reshape_bytes_per_cycle: 256,
            dram_bytes: 20 * (1 << 30),
            dram_gbps: 72.0,
            dram_channels: 4,
            noc_link_gbps: 128.0,
            noc_hop_ns: 10.0,
            video_streams_1080p30: 64,
            jpeg_fps_1080p: 2320,
            tdp_w: 70.0,
            pj_per_mac_int8: 0.4,
            pj_per_dram_byte: 20.0,
        }
    }

    /// Dense-equivalent chip-wide MAC throughput (MACs/s) at a dtype.
    /// BF16 runs the array at half the INT8 rate (paper: 472 vs 944).
    pub fn dense_macs_per_sec(&self, dt: DType) -> f64 {
        let per_cyc = self.spu_int8_macs_per_cycle as f64
            * match dt {
                DType::Int8 => 1.0,
                DType::Bf16 => 0.5,
                DType::F32 => 0.25,
                DType::Int32 => 0.25,
            };
        per_cyc * self.subsystems as f64 * self.clock_ghz * 1e9
    }

    /// Sparse-equivalent TOPS at `sparsity` (the marketing number when
    /// sparsity = 32 and dtype = INT8).
    pub fn equivalent_tops(&self, dt: DType, sparsity: usize) -> f64 {
        2.0 * self.dense_macs_per_sec(dt) * sparsity as f64 / 1e12
    }

    /// Validate internal consistency (also a documentation of intent).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.subsystems >= 1);
        anyhow::ensure!(self.max_sparsity <= 32);
        anyhow::ensure!(self.clock_ghz > 0.0 && self.clock_ghz < 5.0);
        let int8 = self.equivalent_tops(DType::Int8, self.max_sparsity);
        anyhow::ensure!(
            (900.0..1000.0).contains(&int8),
            "INT8 sparse-equivalent TOPS {int8:.0} out of the paper's ballpark (944)"
        );
        let bf16 = self.equivalent_tops(DType::Bf16, self.max_sparsity);
        anyhow::ensure!(
            (440.0..500.0).contains(&bf16),
            "BF16 sparse-equivalent TFLOPS {bf16:.0} vs paper's 472"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn s4_matches_paper_headline_numbers() {
        let c = AntoumConfig::s4();
        c.validate().unwrap();
        let int8 = c.equivalent_tops(DType::Int8, 32);
        assert!((int8 - 944.0).abs() / 944.0 < 0.05, "INT8 {int8}");
        let bf16 = c.equivalent_tops(DType::Bf16, 32);
        assert!((bf16 - 472.0).abs() / 472.0 < 0.05, "BF16 {bf16}");
        assert_eq!(c.dram_bytes, 20 << 30);
        assert!((c.dram_gbps - 72.0).abs() < 1e-9);
        assert!((c.tdp_w - 70.0).abs() < 1e-9);
    }

    #[test]
    fn sparse_equivalent_scales_linearly() {
        let c = AntoumConfig::s4();
        let t1 = c.equivalent_tops(DType::Int8, 1);
        let t8 = c.equivalent_tops(DType::Int8, 8);
        assert!((t8 / t1 - 8.0).abs() < 1e-9);
    }

    #[test]
    fn validate_catches_drift() {
        let mut c = AntoumConfig::s4();
        c.spu_int8_macs_per_cycle = 100; // way off 944 TOPS
        assert!(c.validate().is_err());
    }
}
