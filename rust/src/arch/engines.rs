//! Auxiliary function engines: VPU, activation engine, embedding lookup,
//! memory reshape — the heterogeneous units of paper §2 that make the
//! non-matmul portion of a network fast (and whose finite throughput is
//! exactly why BERT's Fig. 2 curve is sublinear).

use super::config::AntoumConfig;
use crate::graph::op::{ActFunc, OpKind};
use crate::sparse::tensor::DType;

/// Which engine an op executes on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Engine {
    Spu,
    Vpu,
    ActEngine,
    Lookup,
    Reshape,
}

impl Engine {
    pub fn name(self) -> &'static str {
        match self {
            Engine::Spu => "spu",
            Engine::Vpu => "vpu",
            Engine::ActEngine => "act",
            Engine::Lookup => "lookup",
            Engine::Reshape => "reshape",
        }
    }
}

/// Map an op kind to its executing engine (the `sim::mapper` policy).
pub fn engine_for(kind: &OpKind) -> Engine {
    match kind {
        OpKind::Conv2d { .. } | OpKind::MatMul { .. } | OpKind::BatchMatMul { .. } => {
            Engine::Spu
        }
        OpKind::Softmax { .. } => Engine::ActEngine, // exp+recip dominate
        OpKind::LayerNorm { .. } => Engine::Vpu,     // moments dominate
        OpKind::Activation { .. } => Engine::ActEngine,
        OpKind::Elementwise { .. } | OpKind::Pool { .. } => Engine::Vpu,
        OpKind::Embed { .. } => Engine::Lookup,
        OpKind::Reshape { .. } => Engine::Reshape,
    }
}

/// Cycles for a non-SPU op on one subsystem's engines.
pub fn engine_cycles(cfg: &AntoumConfig, kind: &OpKind) -> f64 {
    match *kind {
        OpKind::Softmax { rows, cols } => {
            // VPU: max + sub + sum + div passes; engine: exp (+1 recip/row)
            let elems = (rows * cols) as f64;
            let vpu = 3.0 * elems / cfg.vpu_lanes as f64;
            let act = (elems + rows as f64) / cfg.act_engine_lanes as f64;
            vpu + act
        }
        OpKind::LayerNorm { rows, cols } => {
            // mean+var+normalize+affine on VPU, rsqrt per row on the engine
            let elems = (rows * cols) as f64;
            4.0 * elems / cfg.vpu_lanes as f64
                + rows as f64 / cfg.act_engine_lanes as f64
        }
        OpKind::Activation { elems, func } => {
            let per = match func {
                // LUT-evaluated transcendentals: 1 lane-cycle each
                ActFunc::Gelu | ActFunc::Exp | ActFunc::Log | ActFunc::Sigmoid
                | ActFunc::Tanh | ActFunc::Reciprocal => 1.0,
                ActFunc::Relu => 0.25, // simple clamp, 4/lane/cycle
            };
            elems as f64 * per / cfg.act_engine_lanes as f64
        }
        OpKind::Elementwise { elems, arity } => {
            (elems * arity.max(1)) as f64 / cfg.vpu_lanes as f64
        }
        OpKind::Pool { elems_in, .. } => elems_in as f64 / cfg.vpu_lanes as f64,
        OpKind::Embed { tokens, .. } => {
            // per-row request overhead; actual bytes are DRAM-side
            tokens as f64 * cfg.lookup_row_overhead_cycles
        }
        OpKind::Reshape { bytes } => bytes as f64 / cfg.reshape_bytes_per_cycle as f64,
        OpKind::Conv2d { .. } | OpKind::MatMul { .. } | OpKind::BatchMatMul { .. } => {
            panic!("weighted op {kind:?} belongs to the SPU (arch::spu)")
        }
    }
}

/// Seconds on one subsystem for a non-SPU op.
pub fn engine_seconds(cfg: &AntoumConfig, kind: &OpKind) -> f64 {
    engine_cycles(cfg, kind) / (cfg.clock_ghz * 1e9)
}

/// DRAM bytes an op moves that are *not* captured by weight streaming:
/// embedding-table rows (lookup engine reads vocab rows on demand).
pub fn lookup_dram_bytes(kind: &OpKind, dt: DType) -> usize {
    match *kind {
        OpKind::Embed { tokens, dim, .. } => tokens * dim * dt.bytes(),
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AntoumConfig {
        AntoumConfig::s4()
    }

    #[test]
    fn mapping_covers_all_kinds() {
        let kinds = [
            OpKind::Conv2d { h: 8, w: 8, cin: 32, cout: 32, kh: 3, kw: 3, stride: 1, batch: 1 },
            OpKind::MatMul { m: 1, k: 1, n: 1 },
            OpKind::BatchMatMul { b: 1, m: 1, k: 1, n: 1 },
            OpKind::Softmax { rows: 1, cols: 1 },
            OpKind::LayerNorm { rows: 1, cols: 1 },
            OpKind::Activation { elems: 1, func: ActFunc::Gelu },
            OpKind::Elementwise { elems: 1, arity: 2 },
            OpKind::Pool { elems_in: 1, window: 1 },
            OpKind::Embed { tokens: 1, dim: 1, vocab: 1 },
            OpKind::Reshape { bytes: 1 },
        ];
        for k in &kinds {
            let _ = engine_for(k); // no panic
        }
        assert_eq!(engine_for(&kinds[0]), Engine::Spu);
        assert_eq!(engine_for(&kinds[3]), Engine::ActEngine);
        assert_eq!(engine_for(&kinds[8]), Engine::Lookup);
    }

    #[test]
    fn softmax_cost_scales_with_elems() {
        let a = engine_cycles(&cfg(), &OpKind::Softmax { rows: 128, cols: 128 });
        let b = engine_cycles(&cfg(), &OpKind::Softmax { rows: 256, cols: 128 });
        assert!((b / a - 2.0).abs() < 0.01);
    }

    #[test]
    fn relu_cheaper_than_gelu() {
        let relu = engine_cycles(&cfg(), &OpKind::Activation { elems: 1 << 20, func: ActFunc::Relu });
        let gelu = engine_cycles(&cfg(), &OpKind::Activation { elems: 1 << 20, func: ActFunc::Gelu });
        assert!(relu < gelu / 3.0);
    }

    #[test]
    fn embed_bytes_accounted() {
        let e = OpKind::Embed { tokens: 128, dim: 768, vocab: 30522 };
        assert_eq!(lookup_dram_bytes(&e, DType::Bf16), 128 * 768 * 2);
        assert_eq!(lookup_dram_bytes(&OpKind::Reshape { bytes: 10 }, DType::Bf16), 0);
    }

    #[test]
    #[should_panic(expected = "belongs to the SPU")]
    fn weighted_op_rejected() {
        engine_cycles(&cfg(), &OpKind::MatMul { m: 1, k: 1, n: 1 });
    }
}
