//! LPDDR4 memory system + on-chip buffer model.
//!
//! Paper §2 item (iv): "Antoum moves the computation units directly
//! adjacent to large capacity and large bandwidth memory banks." We model
//! a channelized DRAM (total 72 GB/s over 4 channels) with a per-transfer
//! fixed latency, plus capacity checks for model residency (20 GB means
//! even BERT-large dense fits; sparsity buys *bandwidth*, not residency —
//! which is why weight streaming time scales 1/s and compounds with the
//! compute speedup).

use super::config::AntoumConfig;
use crate::graph::Graph;
use crate::sparse::tensor::DType;

/// A DRAM transfer request cost.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct XferCost {
    pub seconds: f64,
    pub bytes: usize,
}

/// Channelized DRAM model.
#[derive(Clone, Debug)]
pub struct DramModel {
    pub channels: usize,
    /// per-channel bandwidth, bytes/s
    pub channel_bps: f64,
    /// fixed per-transfer latency (row activation + controller), seconds
    pub fixed_latency_s: f64,
    pub capacity_bytes: usize,
}

impl DramModel {
    pub fn from_config(cfg: &AntoumConfig) -> DramModel {
        DramModel {
            channels: cfg.dram_channels,
            channel_bps: cfg.dram_gbps * 1e9 / cfg.dram_channels as f64,
            fixed_latency_s: 100e-9,
            capacity_bytes: cfg.dram_bytes,
        }
    }

    /// Time to move `bytes` using `channels_used` channels in parallel.
    pub fn transfer(&self, bytes: usize, channels_used: usize) -> XferCost {
        let ch = channels_used.clamp(1, self.channels);
        let bw = self.channel_bps * ch as f64;
        XferCost { seconds: self.fixed_latency_s + bytes as f64 / bw, bytes }
    }

    /// Effective full-chip bandwidth (bytes/s).
    pub fn total_bps(&self) -> f64 {
        self.channel_bps * self.channels as f64
    }

    /// Does the model (weights at sparsity+dtype + workspace) fit?
    pub fn fits(&self, g: &Graph, sparsity: usize, dt: DType) -> bool {
        let weights: usize =
            g.ops.iter().map(|o| o.kind.storage_bytes(sparsity, dt)).sum();
        let workspace = g.activation_bytes(dt); // generous upper bound
        weights + workspace <= self.capacity_bytes
    }

    /// Residency report for capacity planning.
    pub fn residency(&self, g: &Graph, sparsity: usize, dt: DType) -> Residency {
        let weights: usize =
            g.ops.iter().map(|o| o.kind.storage_bytes(sparsity, dt)).sum();
        let acts = g.activation_bytes(dt);
        Residency {
            weight_bytes: weights,
            activation_bytes: acts,
            capacity_bytes: self.capacity_bytes,
            utilization: (weights + acts) as f64 / self.capacity_bytes as f64,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct Residency {
    pub weight_bytes: usize,
    pub activation_bytes: usize,
    pub capacity_bytes: usize,
    pub utilization: f64,
}

/// On-chip double-buffered weight streaming: can tile weights hide DRAM
/// latency behind compute? Returns the minimum compute seconds per buffer
/// refill for full overlap — the number the §Perf analysis checks per
/// layer.
pub fn overlap_threshold_secs(cfg: &AntoumConfig, buffer_fill_bytes: usize) -> f64 {
    let per_subsystem_bw = cfg.dram_gbps * 1e9 / cfg.subsystems as f64;
    buffer_fill_bytes as f64 / per_subsystem_bw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    fn dram() -> DramModel {
        DramModel::from_config(&AntoumConfig::s4())
    }

    #[test]
    fn bandwidth_adds_up() {
        let d = dram();
        assert!((d.total_bps() - 72e9).abs() < 1.0);
    }

    #[test]
    fn transfer_time_scales() {
        let d = dram();
        let small = d.transfer(1 << 10, 4);
        let big = d.transfer(1 << 30, 4);
        assert!(big.seconds > 100.0 * small.seconds);
        // fixed latency dominates tiny transfers
        assert!(small.seconds < 2.0 * d.fixed_latency_s);
    }

    #[test]
    fn channels_clamped() {
        let d = dram();
        assert_eq!(d.transfer(1 << 20, 99).seconds, d.transfer(1 << 20, 4).seconds);
        assert!(d.transfer(1 << 20, 1).seconds > d.transfer(1 << 20, 4).seconds);
    }

    #[test]
    fn bert_large_fits_dense_and_sparse() {
        let d = dram();
        let g = models::bert(models::BERT_LARGE, 8, 128);
        assert!(d.fits(&g, 1, DType::Bf16));
        assert!(d.fits(&g, 32, DType::Int8));
        let r1 = d.residency(&g, 1, DType::Bf16);
        let r32 = d.residency(&g, 32, DType::Bf16);
        // encoder shrinks ~32x; the (unpruned) embedding table is a floor
        assert!(r32.weight_bytes < r1.weight_bytes / 6);
    }

    #[test]
    fn overlap_threshold_sane() {
        let t = overlap_threshold_secs(&AntoumConfig::s4(), 8 << 20);
        // 8 MB at 18 GB/s ≈ 0.47 ms
        assert!((t - 8.0 * 1048576.0 / 18e9).abs() / t < 1e-6);
    }
}
