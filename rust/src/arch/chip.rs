//! Chip assembly: subsystem/engine resource layout for the event simulator
//! and the board-level energy/power model.

use super::config::AntoumConfig;
use super::engines::Engine;
use super::event::ResourceId;

/// Resource-id layout of one chip instance for `arch::event::EventSim`.
///
/// Per subsystem: SPU, VPU, ActEngine, Lookup, Reshape → 5 engines.
/// Shared: `dram_channels` DRAM channels and `2·subsystems` ring links.
#[derive(Clone, Debug)]
pub struct ChipResources {
    pub subsystems: usize,
    pub engines_per_subsystem: usize,
    pub dram_channels: usize,
    pub noc_links: usize,
}

pub const ENGINE_ORDER: [Engine; 5] = [
    Engine::Spu,
    Engine::Vpu,
    Engine::ActEngine,
    Engine::Lookup,
    Engine::Reshape,
];

impl ChipResources {
    pub fn from_config(cfg: &AntoumConfig) -> ChipResources {
        ChipResources {
            subsystems: cfg.subsystems,
            engines_per_subsystem: ENGINE_ORDER.len(),
            dram_channels: cfg.dram_channels,
            noc_links: 2 * cfg.subsystems,
        }
    }

    pub fn total(&self) -> usize {
        self.subsystems * self.engines_per_subsystem + self.dram_channels + self.noc_links
    }

    /// Resource id of `engine` on `subsystem`.
    pub fn engine(&self, subsystem: usize, engine: Engine) -> ResourceId {
        assert!(subsystem < self.subsystems, "subsystem {subsystem} out of range");
        let e = ENGINE_ORDER
            .iter()
            .position(|&x| x == engine)
            .expect("engine in ENGINE_ORDER");
        ResourceId(subsystem * self.engines_per_subsystem + e)
    }

    /// Resource id of DRAM channel `ch`.
    pub fn dram(&self, ch: usize) -> ResourceId {
        assert!(ch < self.dram_channels);
        ResourceId(self.subsystems * self.engines_per_subsystem + ch)
    }

    /// Resource id of ring link `l` (see `RingNoc::links_used`).
    pub fn noc_link(&self, l: usize) -> ResourceId {
        assert!(l < self.noc_links);
        ResourceId(self.subsystems * self.engines_per_subsystem + self.dram_channels + l)
    }

    /// Human-readable resource name (reports).
    pub fn name(&self, r: ResourceId) -> String {
        let eng_total = self.subsystems * self.engines_per_subsystem;
        if r.0 < eng_total {
            let ss = r.0 / self.engines_per_subsystem;
            let e = ENGINE_ORDER[r.0 % self.engines_per_subsystem];
            format!("ss{}/{}", ss, e.name())
        } else if r.0 < eng_total + self.dram_channels {
            format!("dram{}", r.0 - eng_total)
        } else {
            format!("link{}", r.0 - eng_total - self.dram_channels)
        }
    }
}

/// Energy accounting for one graph execution.
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyReport {
    pub mac_joules: f64,
    pub dram_joules: f64,
    /// static/leakage + non-modelled logic, charged as a constant floor
    pub static_joules: f64,
    pub total_joules: f64,
    pub avg_watts: f64,
}

/// Board power model: dynamic MAC + DRAM energy plus a static floor of
/// 30% TDP; average power is checked against the 70 W envelope by tests.
pub fn energy(cfg: &AntoumConfig, macs: f64, dram_bytes: f64, seconds: f64) -> EnergyReport {
    let mac_j = macs * cfg.pj_per_mac_int8 * 1e-12;
    let dram_j = dram_bytes * cfg.pj_per_dram_byte * 1e-12;
    let static_j = 0.3 * cfg.tdp_w * seconds;
    let total = mac_j + dram_j + static_j;
    EnergyReport {
        mac_joules: mac_j,
        dram_joules: dram_j,
        static_joules: static_j,
        total_joules: total,
        avg_watts: if seconds > 0.0 { total / seconds } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AntoumConfig {
        AntoumConfig::s4()
    }

    #[test]
    fn resource_layout_distinct() {
        let r = ChipResources::from_config(&cfg());
        let mut ids = std::collections::HashSet::new();
        for ss in 0..r.subsystems {
            for e in ENGINE_ORDER {
                assert!(ids.insert(r.engine(ss, e).0));
            }
        }
        for ch in 0..r.dram_channels {
            assert!(ids.insert(r.dram(ch).0));
        }
        for l in 0..r.noc_links {
            assert!(ids.insert(r.noc_link(l).0));
        }
        assert_eq!(ids.len(), r.total());
        assert_eq!(r.total(), 4 * 5 + 4 + 8);
    }

    #[test]
    fn names_roundtrip() {
        let r = ChipResources::from_config(&cfg());
        assert_eq!(r.name(r.engine(0, Engine::Spu)), "ss0/spu");
        assert_eq!(r.name(r.engine(3, Engine::Lookup)), "ss3/lookup");
        assert_eq!(r.name(r.dram(2)), "dram2");
        assert_eq!(r.name(r.noc_link(7)), "link7");
    }

    #[test]
    fn energy_within_envelope_at_peak() {
        // full-tilt second: dense-equivalent peak MACs + full bandwidth
        let c = cfg();
        let macs = c.dense_macs_per_sec(crate::sparse::tensor::DType::Int8);
        let rep = energy(&c, macs, 72e9, 1.0);
        assert!(
            rep.avg_watts < c.tdp_w,
            "avg {}W exceeds {}W TDP",
            rep.avg_watts,
            c.tdp_w
        );
        assert!(rep.avg_watts > 0.3 * c.tdp_w, "static floor present");
    }

    #[test]
    fn energy_zero_time() {
        let rep = energy(&cfg(), 0.0, 0.0, 0.0);
        assert_eq!(rep.avg_watts, 0.0);
    }
}
