//! On-chip ring interconnect.
//!
//! Paper §2: "Four sparse processing subsystems form a complete chip
//! through a high-bandwidth, on-chip ring interconnection network."
//! Bidirectional ring: a transfer takes the shorter arc; cost = per-hop
//! latency × hops + serialization over one link (stores-and-forwards are
//! pipelined, so bandwidth is single-link).

use super::config::AntoumConfig;

#[derive(Clone, Debug)]
pub struct RingNoc {
    pub nodes: usize,
    pub link_bps: f64,
    pub hop_s: f64,
}

impl RingNoc {
    pub fn from_config(cfg: &AntoumConfig) -> RingNoc {
        RingNoc {
            nodes: cfg.subsystems,
            link_bps: cfg.noc_link_gbps * 1e9,
            hop_s: cfg.noc_hop_ns * 1e-9,
        }
    }

    /// Shortest hop count between subsystems on the bidirectional ring.
    pub fn hops(&self, from: usize, to: usize) -> usize {
        assert!(from < self.nodes && to < self.nodes, "node out of range");
        let d = (from as isize - to as isize).unsigned_abs();
        d.min(self.nodes - d)
    }

    /// Transfer time of `bytes` from one subsystem to another.
    pub fn transfer_secs(&self, from: usize, to: usize, bytes: usize) -> f64 {
        let h = self.hops(from, to);
        if h == 0 {
            return 0.0; // same subsystem: through local SRAM
        }
        h as f64 * self.hop_s + bytes as f64 / self.link_bps
    }

    /// Time for an all-gather of `bytes` per node (ring algorithm:
    /// (n-1) steps of `bytes` each) — the collective used when running
    /// data-parallel with a shared classifier/reduction.
    pub fn allgather_secs(&self, bytes_per_node: usize) -> f64 {
        if self.nodes <= 1 {
            return 0.0;
        }
        (self.nodes - 1) as f64
            * (self.hop_s + bytes_per_node as f64 / self.link_bps)
    }

    /// Which link (by index) a hop occupies — used by the event simulator
    /// to model link contention. Links are numbered 0..nodes clockwise;
    /// a transfer occupies `hops` consecutive links starting at `from` in
    /// its travel direction.
    pub fn links_used(&self, from: usize, to: usize) -> Vec<usize> {
        let h = self.hops(from, to);
        if h == 0 {
            return vec![];
        }
        // clockwise distance
        let cw = (to + self.nodes - from) % self.nodes;
        let clockwise = cw == h;
        let mut links = Vec::with_capacity(h);
        let mut cur = from;
        for _ in 0..h {
            if clockwise {
                links.push(cur); // link cur → cur+1
                cur = (cur + 1) % self.nodes;
            } else {
                cur = (cur + self.nodes - 1) % self.nodes;
                links.push(self.nodes + cur); // counterclockwise links offset
            }
        }
        links
    }

    /// Total distinct links (both directions).
    pub fn link_count(&self) -> usize {
        2 * self.nodes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring() -> RingNoc {
        RingNoc::from_config(&AntoumConfig::s4())
    }

    #[test]
    fn hops_shortest_arc() {
        let r = ring(); // 4 nodes
        assert_eq!(r.hops(0, 0), 0);
        assert_eq!(r.hops(0, 1), 1);
        assert_eq!(r.hops(0, 2), 2);
        assert_eq!(r.hops(0, 3), 1); // wraps
        assert_eq!(r.hops(3, 1), 2);
    }

    #[test]
    fn local_transfer_free() {
        assert_eq!(ring().transfer_secs(2, 2, 1 << 20), 0.0);
    }

    #[test]
    fn transfer_time_components() {
        let r = ring();
        let t = r.transfer_secs(0, 2, 128 << 20);
        let expect = 2.0 * 10e-9 + (128 << 20) as f64 / 128e9;
        assert!((t - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn allgather_scales_with_nodes() {
        let r = ring();
        let t = r.allgather_secs(1 << 20);
        assert!(t > 0.0);
        let solo = RingNoc { nodes: 1, ..r };
        assert_eq!(solo.allgather_secs(1 << 20), 0.0);
    }

    #[test]
    fn links_used_no_overlap_between_directions() {
        let r = ring();
        let cw = r.links_used(0, 1);
        let ccw = r.links_used(1, 0);
        assert_eq!(cw.len(), 1);
        assert_eq!(ccw.len(), 1);
        assert_ne!(cw[0], ccw[0], "directions use distinct links");
        assert!(r.links_used(0, 0).is_empty());
        for l in r.links_used(0, 2) {
            assert!(l < r.link_count());
        }
    }
}
