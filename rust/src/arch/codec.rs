//! Video codec + JPEG decoder engine models.
//!
//! Paper §2: four video decoder engines + one encoder handle 64-way 1080p
//! decoding at 30 FPS; the JPEG decoder sustains 2320 FPS at 1080p. These
//! engines front the vision pipeline (`examples/video_pipeline.rs`):
//! decoded frames are resized and fed to the SPU as inference batches, so
//! end-to-end vision throughput is min(codec, inference).

use super::config::AntoumConfig;

/// Frame geometry (decode cost scales with pixel count relative to 1080p).
#[derive(Clone, Copy, Debug)]
pub struct FrameSpec {
    pub width: usize,
    pub height: usize,
}

impl FrameSpec {
    pub const FHD: FrameSpec = FrameSpec { width: 1920, height: 1080 };
    pub const UHD4K: FrameSpec = FrameSpec { width: 3840, height: 2160 };

    pub fn pixels(&self) -> usize {
        self.width * self.height
    }

    /// Cost multiplier vs 1080p.
    pub fn scale_vs_fhd(&self) -> f64 {
        self.pixels() as f64 / Self::FHD.pixels() as f64
    }
}

/// Video decode subsystem: aggregate decode throughput in 1080p30-stream
/// units, shared across streams (4K counts 4×).
#[derive(Clone, Debug)]
pub struct VideoDecoder {
    /// total capacity, measured in 1080p frames/s
    pub capacity_fps_fhd: f64,
    pub engines: usize,
}

impl VideoDecoder {
    pub fn from_config(cfg: &AntoumConfig) -> VideoDecoder {
        VideoDecoder {
            capacity_fps_fhd: (cfg.video_streams_1080p30 * 30) as f64,
            engines: 4,
        }
    }

    /// Max concurrent streams at (spec, fps) that the decoders sustain.
    pub fn max_streams(&self, spec: FrameSpec, fps: f64) -> usize {
        (self.capacity_fps_fhd / (fps * spec.scale_vs_fhd())).floor() as usize
    }

    /// Sustained frame rate when `streams` streams of `spec` are active
    /// (fair-shared; capped by per-stream requested fps).
    pub fn per_stream_fps(&self, streams: usize, spec: FrameSpec, requested_fps: f64) -> f64 {
        if streams == 0 {
            return 0.0;
        }
        let fair = self.capacity_fps_fhd / (streams as f64 * spec.scale_vs_fhd());
        fair.min(requested_fps)
    }
}

/// JPEG decoder: fixed-rate engine.
#[derive(Clone, Debug)]
pub struct JpegDecoder {
    pub fps_fhd: f64,
}

impl JpegDecoder {
    pub fn from_config(cfg: &AntoumConfig) -> JpegDecoder {
        JpegDecoder { fps_fhd: cfg.jpeg_fps_1080p as f64 }
    }

    /// Seconds to decode one image of `spec`.
    pub fn decode_secs(&self, spec: FrameSpec) -> f64 {
        spec.scale_vs_fhd() / self.fps_fhd
    }

    /// Images/s at `spec`.
    pub fn throughput(&self, spec: FrameSpec) -> f64 {
        1.0 / self.decode_secs(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AntoumConfig {
        AntoumConfig::s4()
    }

    #[test]
    fn paper_claims_hold() {
        let v = VideoDecoder::from_config(&cfg());
        // 64-way 1080p30
        assert_eq!(v.max_streams(FrameSpec::FHD, 30.0), 64);
        let j = JpegDecoder::from_config(&cfg());
        assert!((j.throughput(FrameSpec::FHD) - 2320.0).abs() < 1e-6);
    }

    #[test]
    fn uhd_counts_four_times() {
        let v = VideoDecoder::from_config(&cfg());
        assert_eq!(v.max_streams(FrameSpec::UHD4K, 30.0), 16);
    }

    #[test]
    fn oversubscription_degrades_fairly() {
        let v = VideoDecoder::from_config(&cfg());
        let fps = v.per_stream_fps(128, FrameSpec::FHD, 30.0);
        assert!((fps - 15.0).abs() < 1e-9, "128 streams → 15 fps each, got {fps}");
        // undersubscribed: capped by request
        assert_eq!(v.per_stream_fps(10, FrameSpec::FHD, 30.0), 30.0);
    }

    #[test]
    fn jpeg_scales_with_pixels() {
        let j = JpegDecoder::from_config(&cfg());
        let t4k = j.decode_secs(FrameSpec::UHD4K);
        let tf = j.decode_secs(FrameSpec::FHD);
        assert!((t4k / tf - 4.0).abs() < 1e-9);
    }
}
