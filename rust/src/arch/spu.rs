//! Sparse Processing Unit timing model.
//!
//! The SPU is a weight-stationary systolic array fed compressed
//! block-balanced weights: each MAC lane reads a (value, offset) pair and
//! gathers its activation operand through an in-tile crossbar — so cycles
//! scale with *stored non-zeros*, i.e. 1/s, which is the paper's central
//! linear-speedup claim. Two non-ideal terms keep the model honest:
//!
//! * a fixed per-tile dispatch overhead (`spu_tile_overhead_cycles`) that
//!   stops scaling at very high sparsity on small tiles (visible as the
//!   Fig. 2 curve bending at 32×);
//! * weight-buffer streaming: compressed weights must arrive from DRAM;
//!   the cost model (sim::cost) rooflines compute vs that traffic.

use super::config::AntoumConfig;
use crate::graph::op::OpKind;
use crate::sparse::tensor::DType;

/// Compute-side cost of one op on one subsystem's SPU.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpuCost {
    pub cycles: f64,
    /// MAC operations actually performed (post-sparsity)
    pub macs: f64,
    /// number of (tile_m × tile_n) output tiles dispatched
    pub tiles: f64,
}

/// MACs per cycle the array sustains at a dtype (BF16 halves, F32 quarters
/// the INT8 rate — wider accumulators occupy more lanes).
fn macs_per_cycle(cfg: &AntoumConfig, dt: DType) -> f64 {
    cfg.spu_int8_macs_per_cycle as f64
        * match dt {
            DType::Int8 => 1.0,
            DType::Bf16 => 0.5,
            DType::F32 | DType::Int32 => 0.25,
        }
}

/// Cost a weighted op (Conv2d or MatMul) at sparsity `s`.
/// `s` is clamped to the hardware max; dense BatchMatMul uses `s = 1`.
pub fn cost(cfg: &AntoumConfig, kind: &OpKind, s: usize, dt: DType) -> SpuCost {
    let s = s.min(cfg.max_sparsity).max(1);
    let (macs_dense, m, n) = match *kind {
        OpKind::Conv2d { cin, cout, kh, kw, batch, .. } => {
            let (ho, wo) = kind.conv_out_hw().unwrap();
            (
                (batch * ho * wo) as f64 * (kh * kw * cin) as f64 * cout as f64,
                batch * ho * wo,
                cout,
            )
        }
        OpKind::MatMul { m, k, n } => (m as f64 * k as f64 * n as f64, m, n),
        OpKind::BatchMatMul { b, m, k, n } => {
            ((b * m) as f64 * k as f64 * n as f64, b * m, n)
        }
        _ => panic!("SPU cannot execute {kind:?}"),
    };
    let eff_s = if kind.sparsifiable() { s as f64 } else { 1.0 };
    let macs = macs_dense / eff_s;
    let tiles = (m as f64 / cfg.spu_tile_m as f64).ceil()
        * (n as f64 / cfg.spu_tile_n as f64).ceil();
    let cycles = macs / macs_per_cycle(cfg, dt) + tiles * cfg.spu_tile_overhead_cycles;
    SpuCost { cycles, macs, tiles }
}

/// Seconds for the cost on one subsystem.
pub fn seconds(cfg: &AntoumConfig, c: &SpuCost) -> f64 {
    c.cycles / (cfg.clock_ghz * 1e9)
}

/// Structural speedup of the SPU alone at sparsity `s` for a given matmul
/// shape — the Fig. 2 "kernel-level" curve before memory effects.
pub fn kernel_speedup(cfg: &AntoumConfig, m: usize, k: usize, n: usize, s: usize) -> f64 {
    let kind = OpKind::MatMul { m, k, n };
    let dense = cost(cfg, &kind, 1, DType::Int8);
    let sparse = cost(cfg, &kind, s, DType::Int8);
    dense.cycles / sparse.cycles
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AntoumConfig {
        AntoumConfig::s4()
    }

    #[test]
    fn sparsity_scales_macs_linearly() {
        let kind = OpKind::MatMul { m: 1024, k: 4096, n: 4096 };
        let c1 = cost(&cfg(), &kind, 1, DType::Int8);
        let c8 = cost(&cfg(), &kind, 8, DType::Int8);
        assert!((c1.macs / c8.macs - 8.0).abs() < 1e-9);
        assert_eq!(c1.tiles, c8.tiles); // tiling unchanged
    }

    #[test]
    fn speedup_near_linear_on_large_tiles() {
        // big matmul: overhead negligible → speedup ≈ s
        for &s in &[2usize, 8, 32] {
            let sp = kernel_speedup(&cfg(), 4096, 8192, 4096, s);
            assert!(sp > 0.9 * s as f64 && sp <= 1.001 * s as f64, "s={s} sp={sp}");
        }
    }

    #[test]
    fn speedup_bends_on_small_tiles() {
        // tiny matmul at 32×: fixed overhead dominates, speedup < 0.8·s
        let sp = kernel_speedup(&cfg(), 128, 128, 128, 32);
        assert!(sp < 0.8 * 32.0, "sp={sp}");
        assert!(sp > 1.0);
    }

    #[test]
    fn bf16_twice_the_cycles_of_int8() {
        let kind = OpKind::MatMul { m: 2048, k: 2048, n: 2048 };
        let i8c = cost(&cfg(), &kind, 1, DType::Int8);
        let bfc = cost(&cfg(), &kind, 1, DType::Bf16);
        let ratio = bfc.cycles / i8c.cycles;
        assert!((1.8..2.05).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn batch_matmul_never_sparse() {
        let kind = OpKind::BatchMatMul { b: 12, m: 128, k: 64, n: 128 };
        let c1 = cost(&cfg(), &kind, 1, DType::Int8);
        let c8 = cost(&cfg(), &kind, 8, DType::Int8);
        assert_eq!(c1.macs, c8.macs);
    }

    #[test]
    fn sparsity_clamped_to_hw_max() {
        let kind = OpKind::MatMul { m: 4096, k: 4096, n: 4096 };
        let c32 = cost(&cfg(), &kind, 32, DType::Int8);
        let c64 = cost(&cfg(), &kind, 64, DType::Int8);
        assert_eq!(c32.macs, c64.macs);
    }

    #[test]
    #[should_panic(expected = "SPU cannot execute")]
    fn rejects_non_matmul() {
        cost(&cfg(), &OpKind::Softmax { rows: 1, cols: 1 }, 1, DType::Int8);
    }
}
