//! Discrete-event simulation core.
//!
//! A classic event-calendar simulator: resources with FIFO queues, tasks
//! with dependencies, time advances to the next completion. The chip model
//! (`arch::chip`) instantiates one resource per engine per subsystem plus
//! shared DRAM-channel and NoC-link resources; `sim::schedule` submits the
//! mapped graph as tasks.
//!
//! Performance target (EXPERIMENTS.md §Perf): ≥1M processed task-events/s,
//! since Fig. 2/3 sweeps simulate thousands of graph executions.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Resource handle (an engine, a DRAM channel, a NoC link).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ResourceId(pub usize);

/// Task handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TaskId(pub usize);

/// A unit of work: occupies `resource` exclusively for `service_secs` once
/// all `deps` have completed.
#[derive(Clone, Debug)]
pub struct Task {
    pub resource: ResourceId,
    pub service_secs: f64,
    pub deps: Vec<TaskId>,
    /// opaque tag for reporting (op index, engine kind, ...)
    pub tag: u64,
    /// scheduling priority: LOWER runs first among ready tasks. The
    /// pipeline scheduler sets this to the batch index so in-flight batches
    /// drain forward instead of round-robining in lockstep (which would
    /// collapse a stage pipeline into sequential stages).
    pub priority: u32,
}

/// Completion record.
#[derive(Clone, Copy, Debug)]
pub struct Completion {
    pub task: TaskId,
    pub start: f64,
    pub finish: f64,
}

/// Simulation outcome.
#[derive(Clone, Debug)]
pub struct SimTrace {
    pub completions: Vec<Completion>,
    pub makespan: f64,
    /// busy seconds per resource (utilization = busy / makespan)
    pub busy: Vec<f64>,
    pub events_processed: u64,
}

impl SimTrace {
    pub fn utilization(&self, r: ResourceId) -> f64 {
        if self.makespan <= 0.0 {
            0.0
        } else {
            self.busy[r.0] / self.makespan
        }
    }
}

/// Event-driven executor over a fixed task DAG.
pub struct EventSim {
    n_resources: usize,
    tasks: Vec<Task>,
}

/// f64 ordered wrapper for the event calendar.
#[derive(PartialEq, PartialOrd)]
struct Time(f64);
impl Eq for Time {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for Time {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).expect("NaN time")
    }
}

impl EventSim {
    pub fn new(n_resources: usize) -> EventSim {
        EventSim { n_resources, tasks: Vec::new() }
    }

    /// Add a task; returns its id. Dependencies may be any previously added
    /// task (forward refs are rejected to keep the DAG well-formed).
    pub fn add_task(
        &mut self,
        resource: ResourceId,
        service_secs: f64,
        deps: &[TaskId],
        tag: u64,
    ) -> TaskId {
        self.add_task_prio(resource, service_secs, deps, tag, 0)
    }

    /// Like [`add_task`](Self::add_task) with an explicit priority (lower
    /// runs first among simultaneously-ready tasks).
    pub fn add_task_prio(
        &mut self,
        resource: ResourceId,
        service_secs: f64,
        deps: &[TaskId],
        tag: u64,
        priority: u32,
    ) -> TaskId {
        assert!(resource.0 < self.n_resources, "unknown resource");
        assert!(
            service_secs.is_finite() && service_secs >= 0.0,
            "bad service time {service_secs}"
        );
        for d in deps {
            assert!(d.0 < self.tasks.len(), "dep on future task");
        }
        self.tasks.push(Task {
            resource,
            service_secs,
            deps: deps.to_vec(),
            tag,
            priority,
        });
        TaskId(self.tasks.len() - 1)
    }

    pub fn task_count(&self) -> usize {
        self.tasks.len()
    }

    /// Run to completion. Scheduling policy per resource: non-preemptive
    /// priority (lowest `priority` first, ties by submission order), chosen
    /// at the moment the resource frees up — a later-arriving high-priority
    /// task runs before an earlier-queued low-priority one.
    pub fn run(&self) -> SimTrace {
        let n = self.tasks.len();
        let mut remaining_deps: Vec<u32> = vec![0; n];
        let mut dependents: Vec<Vec<u32>> = vec![Vec::new(); n];
        for (i, t) in self.tasks.iter().enumerate() {
            remaining_deps[i] = t.deps.len() as u32;
            for d in &t.deps {
                dependents[d.0].push(i as u32);
            }
        }

        // per-resource ready queue: (priority, submission index) — every
        // queued task is ready *now* (it is pushed when its last dep
        // completes), so no ready-time in the key.
        let mut ready: Vec<BinaryHeap<Reverse<(u32, u32)>>> =
            (0..self.n_resources).map(|_| BinaryHeap::new()).collect();
        let mut idle = vec![true; self.n_resources];
        let mut busy = vec![0.0f64; self.n_resources];
        // event calendar: (finish_time, task)
        let mut calendar: BinaryHeap<Reverse<(Time, u32)>> = BinaryHeap::new();
        let mut completions = vec![
            Completion { task: TaskId(0), start: 0.0, finish: 0.0 };
            n
        ];
        let mut done = vec![false; n];
        let mut events: u64 = 0;
        let mut makespan = 0.0f64;

        // start the highest-priority ready task on `r` if idle
        macro_rules! try_start {
            ($r:expr, $now:expr) => {
                if idle[$r] {
                    if let Some(Reverse((_, ti))) = ready[$r].pop() {
                        let ti = ti as usize;
                        let t = &self.tasks[ti];
                        let finish = $now + t.service_secs;
                        idle[$r] = false;
                        busy[$r] += t.service_secs;
                        completions[ti] =
                            Completion { task: TaskId(ti), start: $now, finish };
                        calendar.push(Reverse((Time(finish), ti as u32)));
                        events += 1;
                    }
                }
            };
        }

        for (i, t) in self.tasks.iter().enumerate() {
            if t.deps.is_empty() {
                ready[t.resource.0].push(Reverse((t.priority, i as u32)));
            }
        }
        for r in 0..self.n_resources {
            try_start!(r, 0.0);
        }

        while let Some(Reverse((Time(now), ti))) = calendar.pop() {
            let ti = ti as usize;
            events += 1;
            done[ti] = true;
            makespan = makespan.max(now);
            let r = self.tasks[ti].resource.0;
            idle[r] = true;
            // release dependents that become ready now
            for &dep in &dependents[ti] {
                let dep = dep as usize;
                remaining_deps[dep] -= 1;
                if remaining_deps[dep] == 0 {
                    let dr = self.tasks[dep].resource.0;
                    ready[dr].push(Reverse((self.tasks[dep].priority, dep as u32)));
                    try_start!(dr, now);
                }
            }
            try_start!(r, now);
        }

        assert!(
            done.iter().all(|&d| d),
            "deadlock: cyclic dependencies or unreachable tasks"
        );
        SimTrace { completions, makespan, busy, events_processed: events }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_chain_sums() {
        let mut sim = EventSim::new(1);
        let a = sim.add_task(ResourceId(0), 1.0, &[], 0);
        let b = sim.add_task(ResourceId(0), 2.0, &[a], 0);
        sim.add_task(ResourceId(0), 3.0, &[b], 0);
        let t = sim.run();
        assert_eq!(t.makespan, 6.0);
        assert_eq!(t.utilization(ResourceId(0)), 1.0);
    }

    #[test]
    fn parallel_resources_overlap() {
        let mut sim = EventSim::new(2);
        sim.add_task(ResourceId(0), 5.0, &[], 0);
        sim.add_task(ResourceId(1), 3.0, &[], 0);
        let t = sim.run();
        assert_eq!(t.makespan, 5.0);
        assert!((t.utilization(ResourceId(1)) - 0.6).abs() < 1e-12);
    }

    #[test]
    fn contention_serializes() {
        let mut sim = EventSim::new(1);
        for _ in 0..4 {
            sim.add_task(ResourceId(0), 1.0, &[], 0);
        }
        assert_eq!(sim.run().makespan, 4.0);
    }

    #[test]
    fn diamond_dependency() {
        // a → (b, c) → d; b on r0, c on r1 → d starts at max(b,c)
        let mut sim = EventSim::new(2);
        let a = sim.add_task(ResourceId(0), 1.0, &[], 0);
        let b = sim.add_task(ResourceId(0), 2.0, &[a], 0);
        let c = sim.add_task(ResourceId(1), 5.0, &[a], 0);
        let d = sim.add_task(ResourceId(0), 1.0, &[b, c], 0);
        let t = sim.run();
        assert_eq!(t.completions[d.0].start, 6.0);
        assert_eq!(t.makespan, 7.0);
    }

    #[test]
    fn zero_service_tasks_ok() {
        let mut sim = EventSim::new(1);
        let a = sim.add_task(ResourceId(0), 0.0, &[], 0);
        sim.add_task(ResourceId(0), 1.0, &[a], 0);
        assert_eq!(sim.run().makespan, 1.0);
    }

    #[test]
    fn determinism() {
        let build = || {
            let mut sim = EventSim::new(3);
            let mut prev: Vec<TaskId> = vec![];
            for i in 0..50 {
                let deps: Vec<TaskId> =
                    prev.iter().copied().filter(|t| t.0 % 3 == i % 3).collect();
                let id = sim.add_task(
                    ResourceId(i % 3),
                    (i as f64 * 0.37) % 1.0 + 0.01,
                    &deps,
                    i as u64,
                );
                prev.push(id);
            }
            sim.run()
        };
        let t1 = build();
        let t2 = build();
        assert_eq!(t1.makespan, t2.makespan);
        assert_eq!(t1.events_processed, t2.events_processed);
    }

    #[test]
    #[should_panic(expected = "dep on future task")]
    fn forward_dep_rejected() {
        let mut sim = EventSim::new(1);
        sim.add_task(ResourceId(0), 1.0, &[TaskId(7)], 0);
    }

    #[test]
    fn priority_orders_ready_tasks() {
        // all ready at t=0 on one resource; low priority value runs first
        let mut sim = EventSim::new(1);
        let lo = sim.add_task_prio(ResourceId(0), 1.0, &[], 0, 9);
        let hi = sim.add_task_prio(ResourceId(0), 1.0, &[], 0, 0);
        let t = sim.run();
        assert!(t.completions[hi.0].start < t.completions[lo.0].start);
    }

    #[test]
    fn priority_enables_stage_pipelining() {
        // 2-stage pipeline, 3 batches: with batch-index priority the
        // makespan is (batches + stages - 1) × unit = 4, not 6.
        let mut sim = EventSim::new(2);
        for b in 0..3u32 {
            let s0 = sim.add_task_prio(ResourceId(0), 1.0, &[], b as u64, b);
            sim.add_task_prio(ResourceId(1), 1.0, &[s0], b as u64, b);
        }
        assert_eq!(sim.run().makespan, 4.0);
    }
}
