//! Router: pick the compiled variant a batch executes on.
//!
//! A model is served by a *set* of artifacts (sparsity × batch-size
//! variants). Policy picks the sparsity tier; the batch planner packs the
//! request batch into the fewest artifact executions (e.g. 5 requests with
//! {b1, b8} variants → one padded b8 call, not five b1 calls — padding is
//! cheaper than dispatch beyond a fill threshold).

use crate::runtime::manifest::{ArtifactMeta, Manifest};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// highest-sparsity variant available (max throughput; the S4 pitch)
    MaxSparsity,
    /// dense baseline (comparison runs)
    Dense,
    /// a specific sparsity tier (SLA-pinned accuracy)
    Fixed(usize),
}

/// One planned execution: an artifact plus how many real requests fill it.
#[derive(Clone, Debug, PartialEq)]
pub struct Placement {
    pub artifact: String,
    pub batch_capacity: usize,
    pub fill: usize,
}

#[derive(Clone, Debug)]
pub struct Router {
    policy: RoutingPolicy,
    /// minimum fill ratio before the planner chooses a bigger batch
    /// variant over multiple smaller ones
    pub min_fill: f64,
}

impl Router {
    pub fn new(policy: RoutingPolicy) -> Router {
        Router { policy, min_fill: 0.5 }
    }

    pub fn policy(&self) -> RoutingPolicy {
        self.policy
    }

    /// Sparsity tier for `model` under the policy, from what's available.
    pub fn pick_sparsity(&self, m: &Manifest, model: &str) -> anyhow::Result<usize> {
        let mut tiers: Vec<usize> = m
            .artifacts
            .iter()
            .filter(|a| a.model == model)
            .map(|a| a.sparsity)
            .collect();
        tiers.sort_unstable();
        tiers.dedup();
        anyhow::ensure!(!tiers.is_empty(), "no artifacts for model `{model}`");
        Ok(match self.policy {
            RoutingPolicy::MaxSparsity => *tiers.last().unwrap(),
            RoutingPolicy::Dense => *tiers.first().unwrap(),
            RoutingPolicy::Fixed(s) => {
                anyhow::ensure!(
                    tiers.contains(&s),
                    "model `{model}` has no sparsity-{s} artifact (have {tiers:?})"
                );
                s
            }
        })
    }

    /// Plan executions for `n` same-model requests: greedy largest-fit over
    /// the available batch capacities at the chosen sparsity tier.
    /// Invariants (property-tested): Σ fill == n; fill ≤ capacity; a
    /// capacity is only padded when no exact/smaller combination covers the
    /// remainder.
    pub fn plan(&self, m: &Manifest, model: &str, n: usize) -> anyhow::Result<Vec<Placement>> {
        anyhow::ensure!(n > 0, "empty batch");
        let s = self.pick_sparsity(m, model)?;
        let mut caps: Vec<&ArtifactMeta> = m
            .artifacts
            .iter()
            .filter(|a| a.model == model && a.sparsity == s)
            .collect();
        anyhow::ensure!(!caps.is_empty(), "no artifacts for `{model}` at s={s}");
        caps.sort_by_key(|a| a.batch); // ascending capacities
        let mut out = Vec::new();
        let mut left = n;
        while left > 0 {
            let largest = caps.last().unwrap();
            if left >= largest.batch {
                // fill whole large batches first
                out.push(Placement {
                    artifact: largest.name.clone(),
                    batch_capacity: largest.batch,
                    fill: largest.batch,
                });
                left -= largest.batch;
                continue;
            }
            // remainder: smallest capacity that covers it at ≥ min_fill
            // (padding beats extra dispatches)…
            if let Some(a) = caps
                .iter()
                .find(|a| a.batch >= left && left as f64 / a.batch as f64 >= self.min_fill)
            {
                out.push(Placement {
                    artifact: a.name.clone(),
                    batch_capacity: a.batch,
                    fill: left,
                });
                left = 0;
            } else if let Some(a) = caps.iter().rev().find(|a| a.batch <= left) {
                // …else exact-fit smaller batches…
                out.push(Placement {
                    artifact: a.name.clone(),
                    batch_capacity: a.batch,
                    fill: a.batch,
                });
                left -= a.batch;
            } else {
                // …else pad the smallest available capacity.
                let a = caps.first().unwrap();
                out.push(Placement {
                    artifact: a.name.clone(),
                    batch_capacity: a.batch,
                    fill: left,
                });
                left = 0;
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn manifest() -> Manifest {
        let text = r#"{"artifacts": [
          {"name": "m_s1_b1", "file": "a", "family": "bert", "model": "m",
           "sparsity": 1, "batch": 1, "inputs": [], "outputs": []},
          {"name": "m_s8_b1", "file": "b", "family": "bert", "model": "m",
           "sparsity": 8, "batch": 1, "inputs": [], "outputs": []},
          {"name": "m_s8_b8", "file": "c", "family": "bert", "model": "m",
           "sparsity": 8, "batch": 8, "inputs": [], "outputs": []},
          {"name": "m_s32_b1", "file": "d", "family": "bert", "model": "m",
           "sparsity": 32, "batch": 1, "inputs": [], "outputs": []}
        ]}"#;
        Manifest::parse(Path::new("/tmp"), text).unwrap()
    }

    #[test]
    fn policy_picks_tier() {
        let m = manifest();
        assert_eq!(Router::new(RoutingPolicy::MaxSparsity).pick_sparsity(&m, "m").unwrap(), 32);
        assert_eq!(Router::new(RoutingPolicy::Dense).pick_sparsity(&m, "m").unwrap(), 1);
        assert_eq!(Router::new(RoutingPolicy::Fixed(8)).pick_sparsity(&m, "m").unwrap(), 8);
        assert!(Router::new(RoutingPolicy::Fixed(16)).pick_sparsity(&m, "m").is_err());
        assert!(Router::new(RoutingPolicy::Dense).pick_sparsity(&m, "zz").is_err());
    }

    #[test]
    fn plan_exact_multiples() {
        let m = manifest();
        let r = Router::new(RoutingPolicy::Fixed(8));
        let p = r.plan(&m, "m", 16).unwrap();
        assert_eq!(p.len(), 2);
        assert!(p.iter().all(|x| x.artifact == "m_s8_b8" && x.fill == 8));
    }

    #[test]
    fn plan_remainder_pads_large_when_half_full() {
        let m = manifest();
        let r = Router::new(RoutingPolicy::Fixed(8));
        // 13 = b8 + 5 → 5/8 = 0.625 ≥ 0.5 → padded b8
        let p = r.plan(&m, "m", 13).unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(p[1].fill, 5);
        assert_eq!(p[1].batch_capacity, 8);
    }

    #[test]
    fn plan_small_remainder_uses_b1() {
        let m = manifest();
        let r = Router::new(RoutingPolicy::Fixed(8));
        // 9 = b8 + 1 → 1/8 < 0.5 → b1 exact
        let p = r.plan(&m, "m", 9).unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(p[1].batch_capacity, 1);
        assert_eq!(p[1].fill, 1);
    }

    #[test]
    fn plan_conserves_requests() {
        let m = manifest();
        let r = Router::new(RoutingPolicy::Fixed(8));
        for n in 1..=40 {
            let p = r.plan(&m, "m", n).unwrap();
            let total: usize = p.iter().map(|x| x.fill).sum();
            assert_eq!(total, n, "n={n}: {p:?}");
            for x in &p {
                assert!(x.fill <= x.batch_capacity);
            }
        }
    }

    #[test]
    fn tier_without_big_batches_still_plans() {
        let m = manifest();
        let r = Router::new(RoutingPolicy::MaxSparsity); // s=32 only has b1
        let p = r.plan(&m, "m", 3).unwrap();
        assert_eq!(p.len(), 3);
        assert!(p.iter().all(|x| x.batch_capacity == 1));
    }
}
