//! Staged ingress pipeline — the composable front door of the serving
//! stack.
//!
//! [`ServerHandle::submit_with`](super::server::ServerHandle) used to be
//! a hardcoded monolith (breaker gate → admission → id mint → enqueue);
//! every new front-door policy meant editing it in place. This module
//! factors the gating part into an explicit chain of [`IngressStage`]s,
//! each of which can:
//!
//! * **[`Shed`](StageOutcome::Shed)** the submission with a typed
//!   [`AdmissionDecision`] (breaker open, budget exhausted);
//! * **[`Answer`](StageOutcome::Answer)** it immediately with a
//!   [`Ticket`] that never touches admission or the batcher (a response
//!   cache hit, a coalesced attach to an in-flight leader);
//! * **[`Continue`](StageOutcome::Continue)** to the next stage,
//!   optionally installing a [`ReplyAttachment`] on the request that
//!   eventually enqueues (how the cache registers itself as the
//!   single-flight leader for a key).
//!
//! The default chain `[BreakerGate, AdmissionGate]` reproduces the
//! pre-refactor behavior bitwise — same outcomes, same metrics, same
//! ordering — so with no cache configured nothing observable changes.
//! [`ResponseCache`](super::cache::ResponseCache) slots in front as the
//! first stage when [`ServerConfig::cache`](super::server::ServerConfig)
//! is set.

use std::sync::Arc;

use super::admission::{Admission, AdmissionDecision};
use super::health::{Breaker, BreakerVerdict};
use super::metrics::Metrics;
use super::request::{SharedReply, SubmitOptions, Ticket};
use crate::backend::Value;

/// Borrowed view of one submission, handed to each stage in turn.
pub struct IngressRequest<'a> {
    pub model: &'a str,
    pub inputs: &'a [Value],
    pub opts: &'a SubmitOptions,
}

/// Side-car a stage installs on a submission that proceeds to enqueue:
/// the request becomes a coalescing *leader* whose reply fans out through
/// `fanout`, and `on_abort` runs if the submission fails to enqueue after
/// the chain passed (shutdown race), so the stage can unregister it and
/// answer any already-attached followers instead of stranding them.
pub struct ReplyAttachment {
    pub fanout: Arc<SharedReply>,
    pub on_abort: Box<dyn FnOnce() + Send>,
}

impl std::fmt::Debug for ReplyAttachment {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ReplyAttachment").field("fanout", &self.fanout).finish()
    }
}

/// What one [`IngressStage`] decided for a submission.
#[derive(Debug)]
pub enum StageOutcome {
    /// Reject now with this typed decision; later stages never run.
    Shed(AdmissionDecision),
    /// Answer now with this ticket; the request never reaches admission
    /// or the batcher (cache hit / coalesced attach).
    Answer(Ticket),
    /// Pass to the next stage, optionally installing a fan-out
    /// attachment on the request if it ultimately enqueues.
    Continue(Option<ReplyAttachment>),
}

/// One composable front-door policy. Stages are synchronous and cheap —
/// they run on the submitting thread, before the request exists.
pub trait IngressStage: Send + Sync {
    /// Stable name for diagnostics.
    fn name(&self) -> &'static str;
    /// Decide this submission's fate at this stage.
    fn admit(&self, req: &IngressRequest<'_>) -> StageOutcome;
}

/// Terminal result of running a whole [`IngressChain`].
#[derive(Debug)]
pub enum ChainOutcome {
    /// Some stage shed the submission.
    Shed(AdmissionDecision),
    /// Some stage answered it without enqueueing.
    Answer(Ticket),
    /// Every stage passed; enqueue, carrying at most one attachment.
    Proceed(Option<ReplyAttachment>),
}

/// An ordered chain of [`IngressStage`]s, run front to back.
pub struct IngressChain {
    stages: Vec<Box<dyn IngressStage>>,
}

impl IngressChain {
    pub fn new(stages: Vec<Box<dyn IngressStage>>) -> IngressChain {
        IngressChain { stages }
    }

    /// Run the chain. A `Shed` after an earlier stage installed an
    /// attachment fires that attachment's abort hook — the leader
    /// registration must not outlive a submission that never enqueued.
    pub fn run(&self, req: &IngressRequest<'_>) -> ChainOutcome {
        let mut attachment: Option<ReplyAttachment> = None;
        for stage in &self.stages {
            match stage.admit(req) {
                StageOutcome::Continue(None) => {}
                StageOutcome::Continue(Some(a)) => {
                    debug_assert!(
                        attachment.is_none(),
                        "at most one stage may install a ReplyAttachment"
                    );
                    attachment = Some(a);
                }
                StageOutcome::Answer(t) => return ChainOutcome::Answer(t),
                StageOutcome::Shed(d) => {
                    if let Some(a) = attachment.take() {
                        (a.on_abort)();
                    }
                    return ChainOutcome::Shed(d);
                }
            }
        }
        ChainOutcome::Proceed(attachment)
    }
}

/// The health gate, extracted verbatim from the old `submit_with`: a
/// breaker shed consumes neither an admission slot nor an `admitted`
/// count, so `answered() == admitted` holds straight through a degraded
/// window.
pub struct BreakerGate {
    breaker: Arc<Breaker>,
    metrics: Arc<Metrics>,
}

impl BreakerGate {
    pub fn new(breaker: Arc<Breaker>, metrics: Arc<Metrics>) -> BreakerGate {
        BreakerGate { breaker, metrics }
    }
}

impl IngressStage for BreakerGate {
    fn name(&self) -> &'static str {
        "breaker"
    }

    fn admit(&self, req: &IngressRequest<'_>) -> StageOutcome {
        let class = req.opts.priority;
        if self.breaker.admit(class) == BreakerVerdict::Shed {
            self.metrics.record_breaker_shed();
            return StageOutcome::Shed(AdmissionDecision::RejectUnhealthy(class));
        }
        StageOutcome::Continue(None)
    }
}

/// The per-class admission budget, extracted verbatim from the old
/// `submit_with`: a pass records `admitted` and holds a slot the serving
/// path must `complete` exactly once.
pub struct AdmissionGate {
    admission: Arc<Admission>,
    metrics: Arc<Metrics>,
}

impl AdmissionGate {
    pub fn new(admission: Arc<Admission>, metrics: Arc<Metrics>) -> AdmissionGate {
        AdmissionGate { admission, metrics }
    }
}

impl IngressStage for AdmissionGate {
    fn name(&self) -> &'static str {
        "admission"
    }

    fn admit(&self, req: &IngressRequest<'_>) -> StageOutcome {
        let class = req.opts.priority;
        match self.admission.try_admit(class) {
            AdmissionDecision::Admit => {
                self.metrics.record_admitted(class);
                StageOutcome::Continue(None)
            }
            other => {
                self.metrics.record_rejected();
                StageOutcome::Shed(other)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::health::BreakerConfig;
    use crate::coordinator::request::Priority;
    use std::sync::atomic::{AtomicBool, Ordering};

    fn req<'a>(opts: &'a SubmitOptions) -> IngressRequest<'a> {
        IngressRequest { model: "m", inputs: &[], opts }
    }

    #[test]
    fn breaker_gate_sheds_when_open_without_touching_admitted() {
        let breaker = Arc::new(Breaker::new(BreakerConfig {
            failure_threshold: 1,
            ..BreakerConfig::default()
        }));
        let metrics = Arc::new(Metrics::default());
        let gate = BreakerGate::new(breaker.clone(), metrics.clone());
        assert_eq!(gate.name(), "breaker");
        let opts = SubmitOptions::default();
        assert!(matches!(gate.admit(&req(&opts)), StageOutcome::Continue(None)));
        breaker.record_failure();
        match gate.admit(&req(&opts)) {
            StageOutcome::Shed(AdmissionDecision::RejectUnhealthy(Priority::Standard)) => {}
            other => panic!("expected RejectUnhealthy, got {other:?}"),
        }
        let s = metrics.snapshot();
        assert_eq!(s.breaker_shed, 1);
        assert_eq!(s.admitted, 0, "breaker sheds never count as admitted");
    }

    #[test]
    fn admission_gate_admits_then_rejects_at_capacity() {
        let admission = Arc::new(Admission::depth_only(1));
        let metrics = Arc::new(Metrics::default());
        let gate = AdmissionGate::new(admission.clone(), metrics.clone());
        assert_eq!(gate.name(), "admission");
        let opts = SubmitOptions::default();
        assert!(matches!(gate.admit(&req(&opts)), StageOutcome::Continue(None)));
        match gate.admit(&req(&opts)) {
            StageOutcome::Shed(AdmissionDecision::RejectQueueFull(Priority::Standard)) => {}
            other => panic!("expected RejectQueueFull, got {other:?}"),
        }
        let s = metrics.snapshot();
        assert_eq!((s.admitted, s.rejected), (1, 1));
        admission.complete(Priority::Standard);
        assert_eq!(admission.inflight(), 0);
    }

    struct FixedStage(StageOutcomeKind);
    enum StageOutcomeKind {
        Continue,
        Shed,
        Attach(Arc<SharedReply>, Arc<AtomicBool>),
    }

    impl IngressStage for FixedStage {
        fn name(&self) -> &'static str {
            "fixed"
        }
        fn admit(&self, req: &IngressRequest<'_>) -> StageOutcome {
            match &self.0 {
                StageOutcomeKind::Continue => StageOutcome::Continue(None),
                StageOutcomeKind::Shed => {
                    StageOutcome::Shed(AdmissionDecision::RejectQueueFull(req.opts.priority))
                }
                StageOutcomeKind::Attach(sr, aborted) => {
                    let (sr, aborted) = (sr.clone(), aborted.clone());
                    let fanout = sr.clone();
                    StageOutcome::Continue(Some(ReplyAttachment {
                        fanout,
                        on_abort: Box::new(move || {
                            aborted.store(true, Ordering::Release);
                            sr.abort("not enqueued");
                        }),
                    }))
                }
            }
        }
    }

    #[test]
    fn chain_carries_attachment_through_to_proceed() {
        let sr = Arc::new(SharedReply::new());
        let aborted = Arc::new(AtomicBool::new(false));
        let chain = IngressChain::new(vec![
            Box::new(FixedStage(StageOutcomeKind::Attach(sr, aborted.clone()))),
            Box::new(FixedStage(StageOutcomeKind::Continue)),
        ]);
        let opts = SubmitOptions::default();
        match chain.run(&req(&opts)) {
            ChainOutcome::Proceed(Some(_)) => {}
            other => panic!("expected Proceed(Some), got {other:?}"),
        }
        assert!(!aborted.load(Ordering::Acquire));
    }

    #[test]
    fn chain_shed_after_attach_runs_the_abort_hook() {
        let sr = Arc::new(SharedReply::new());
        let aborted = Arc::new(AtomicBool::new(false));
        let chain = IngressChain::new(vec![
            Box::new(FixedStage(StageOutcomeKind::Attach(sr.clone(), aborted.clone()))),
            Box::new(FixedStage(StageOutcomeKind::Shed)),
        ]);
        let opts = SubmitOptions::default();
        match chain.run(&req(&opts)) {
            ChainOutcome::Shed(AdmissionDecision::RejectQueueFull(_)) => {}
            other => panic!("expected Shed, got {other:?}"),
        }
        assert!(aborted.load(Ordering::Acquire), "leader registration torn down on shed");
        assert!(!sr.is_pending(), "followers would now see Aborted");
    }

    #[test]
    fn empty_chain_proceeds_bare() {
        let chain = IngressChain::new(Vec::new());
        let opts = SubmitOptions::default();
        assert!(matches!(chain.run(&req(&opts)), ChainOutcome::Proceed(None)));
    }
}
