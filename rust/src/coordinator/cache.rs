//! Exact response cache + single-flight coalescing — the first new
//! [`IngressStage`](super::ingress::IngressStage).
//!
//! Heavy traffic from millions of users is heavy-tailed: the same hot
//! inputs recur, and recomputing them wastes exactly the cycles the
//! sparse kernels saved. Deterministic logits (pinned by
//! `cpu_backend_e2e.rs`) make caching *exact*, not approximate — a rare
//! luxury — so the key is the bitwise content of the request:
//! `hash(model, dtype-tagged input payloads)`, with f32 elements keyed
//! by `to_bits()` (`0.0` and `-0.0` are different keys, NaNs compare by
//! payload; bitwise in, bitwise out).
//!
//! Two mechanisms share one map:
//!
//! * **Resolved hits** — a fresh `Ok` response for the same key is
//!   answered immediately from the submitting thread: no admission
//!   slot, no batch seat, no backend call. `served_by` is rewritten to
//!   `cache:<original>` so hits are observable end to end (including
//!   over the wire — the net layer copies `served_by` into the frame).
//! * **Single-flight coalescing** — while a key's *leader* is still in
//!   flight, concurrent identical submissions attach to its
//!   [`SharedReply`] and receive per-waiter clones of the leader's one
//!   reply. Followers hold ordinary [`Ticket`]s with independent cancel
//!   flags; a follower cancelling never disturbs the leader.
//!
//! A coalesced follower keeps its **own deadline**. The follower never
//! enters the batcher/worker pipeline, so *server-side* deadline
//! shedding cannot see it — instead its [`Ticket`] carries the
//! submission's absolute deadline and [`Ticket::wait`] /
//! [`Ticket::wait_timeout`] return a typed `Expired` at that instant if
//! the leader has not settled yet (data wins ties; a settle that
//! already landed is returned). A follower therefore no longer inherits
//! the leader's timeline — the PR 8 limitation this paragraph used to
//! document. The converse still holds: a leader shed for *its*
//! cancel/deadline settles followers with a distinct retryable error
//! rather than a `Cancelled`/`Expired` they did not cause (see
//! [`SharedReply::settle`]).
//!
//! Bounded by TTL + `max_entries` (stale entries and settled-non-`Ok`
//! flights are evicted first — a settled-`Ok` flight is *promoted* to a
//! resolved entry rather than discarded, then the **least-recently-hit**
//! resolved entry goes: every hit touches its entry's recency stamp, so
//! hot Zipf-head keys outlive colder-but-newer ones under a full map;
//! pending leaders are never evicted — when the map is full of
//! them, a newcomer simply proceeds uncoalesced). Only `Ok` responses
//! are ever served from the cache: errors, expirations, and
//! cancellations settle their followers but are dropped from the map, so
//! a fault never gets replayed to a later caller.
//!
//! Accounting: hits and coalesced attaches are answered **without**
//! being admitted, so the core invariant `answered() == admitted` is
//! untouched; the extended identity is
//! `served() == answered() + cache_hits + coalesced`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::ingress::{IngressRequest, IngressStage, ReplyAttachment, StageOutcome};
use super::metrics::Metrics;
use super::request::{AttachOutcome, RequestId, Response, SharedReply, Ticket};
use crate::backend::Value;

/// Size/age bounds for [`ResponseCache`].
#[derive(Clone, Debug)]
pub struct CacheConfig {
    /// Hard bound on map entries (resolved + in-flight). Clamped to ≥ 1.
    pub max_entries: usize,
    /// Resolved entries older than this are misses (and evicted on
    /// sight). `Duration::ZERO` disables reuse entirely — every
    /// submission re-executes — while coalescing of genuinely concurrent
    /// identical requests still applies.
    pub ttl: Duration,
}

impl Default for CacheConfig {
    fn default() -> CacheConfig {
        CacheConfig { max_entries: 1024, ttl: Duration::from_secs(60) }
    }
}

/// Bitwise-exact identity of a submission. Full payload is stored (not
/// just a hash), so distinct inputs can never collide into a wrong
/// answer.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct CacheKey {
    model: Box<str>,
    /// dtype-tagged flattened payload: per input `[tag, len, elems...]`
    /// with i32 elements zero-extended and f32 elements by `to_bits()`
    words: Box<[u64]>,
}

impl CacheKey {
    fn of(model: &str, inputs: &[Value]) -> CacheKey {
        let mut words = Vec::new();
        for v in inputs {
            match v {
                Value::I32(xs) => {
                    words.push(1);
                    words.push(xs.len() as u64);
                    words.extend(xs.iter().map(|&x| x as u32 as u64));
                }
                Value::F32(xs) => {
                    words.push(2);
                    words.push(xs.len() as u64);
                    words.extend(xs.iter().map(|&x| x.to_bits() as u64));
                }
            }
        }
        CacheKey { model: model.into(), words: words.into() }
    }
}

enum Entry {
    /// A leader is executing this key; followers attach here.
    InFlight(Arc<SharedReply>),
    /// A fresh `Ok` response, promoted after the leader settled.
    Resolved {
        resp: Response,
        /// When the leader settled — the TTL clock.
        at: Instant,
        /// When this entry last served a hit (settle time until then) —
        /// the LRU eviction clock.
        last_hit: Instant,
    },
}

struct CacheShared {
    cfg: CacheConfig,
    metrics: Arc<Metrics>,
    /// the server's id mint — hits and coalesced attaches get real,
    /// unique [`RequestId`]s from the same sequence as admitted requests
    next_id: Arc<AtomicU64>,
    map: Mutex<HashMap<CacheKey, Entry>>,
}

/// The cache stage. Cheap to clone; one instance is shared between the
/// ingress chain and any observer.
#[derive(Clone)]
pub struct ResponseCache {
    inner: Arc<CacheShared>,
}

impl ResponseCache {
    pub fn new(
        cfg: CacheConfig,
        metrics: Arc<Metrics>,
        next_id: Arc<AtomicU64>,
    ) -> ResponseCache {
        let cfg = CacheConfig { max_entries: cfg.max_entries.max(1), ..cfg };
        ResponseCache {
            inner: Arc::new(CacheShared {
                cfg,
                metrics,
                next_id,
                map: Mutex::new(HashMap::new()),
            }),
        }
    }

    /// Current entry count (resolved + in-flight), for tests/observers.
    pub fn len(&self) -> usize {
        self.lock_map().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn lock_map(&self) -> std::sync::MutexGuard<'_, HashMap<CacheKey, Entry>> {
        self.inner.map.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn mint_id(&self) -> RequestId {
        RequestId(self.inner.next_id.fetch_add(1, Ordering::Relaxed))
    }

    /// Answer immediately with a clone of `template` re-stamped for this
    /// caller: its own fresh id, `served_by` marked `cache:<origin>`,
    /// zero queue/latency (the whole point of a hit). The ticket still
    /// carries the caller's own deadline for uniformity — moot here,
    /// since the response is already in the channel and data wins.
    fn hit_ticket(&self, template: &Response, req: &IngressRequest<'_>) -> Ticket {
        let id = self.mint_id();
        let mut resp = template.clone();
        resp.id = id;
        let (tx, rx) = channel();
        let _ = tx.send(resp);
        Ticket::new(id, req.opts.priority, rx, Arc::new(AtomicBool::new(false)))
            .with_deadline(req.opts.deadline.map(|d| Instant::now() + d))
    }

    /// Rewrite a settled leader response into the resolved-entry
    /// template: cache-marked origin, no residual latency attribution.
    fn promote(resp: &Response) -> Response {
        let mut r = resp.clone();
        if !r.served_by.starts_with("cache:") {
            r.served_by = Arc::from(format!("cache:{}", r.served_by).as_str());
        }
        r.latency_us = 0;
        r.queue_us = 0;
        r
    }

    /// Evict entries to make room for one more: settled-`Ok` in-flight
    /// entries are *promoted* to `Resolved` (they are values the stack
    /// just paid to compute — discarding them would gut the hit rate;
    /// they stay TTL-bound and evictable like any resolved entry), while
    /// stale resolved entries and settled-non-`Ok`/aborted flights are
    /// dropped; then, if still full, the **least-recently-hit** resolved
    /// entry goes (LRU — a hot entry that keeps serving hits outlives a
    /// colder one that merely resolved later). Pending leaders are never
    /// evicted. Returns whether an insert now fits.
    fn make_room(map: &mut HashMap<CacheKey, Entry>, cfg: &CacheConfig, now: Instant) -> bool {
        if map.len() < cfg.max_entries {
            return true;
        }
        let mut promotions: Vec<(CacheKey, Response, Instant)> = Vec::new();
        map.retain(|k, e| match e {
            Entry::Resolved { at, .. } => now.duration_since(*at) < cfg.ttl,
            Entry::InFlight(sr) => {
                if sr.is_pending() {
                    return true;
                }
                match sr.settled() {
                    Some((resp, at))
                        if resp.is_ok() && now.duration_since(at) < cfg.ttl =>
                    {
                        promotions.push((k.clone(), resp, at));
                        true
                    }
                    _ => false,
                }
            }
        });
        for (k, resp, at) in promotions {
            // a promotion has never served a hit: recency = settle time
            map.insert(k, Entry::Resolved { resp: Self::promote(&resp), at, last_hit: at });
        }
        if map.len() < cfg.max_entries {
            return true;
        }
        let coldest = map
            .iter()
            .filter_map(|(k, e)| match e {
                Entry::Resolved { last_hit, .. } => Some((k.clone(), *last_hit)),
                Entry::InFlight(_) => None,
            })
            .min_by_key(|(_, last_hit)| *last_hit)
            .map(|(k, _)| k);
        if let Some(k) = coldest {
            map.remove(&k);
        }
        map.len() < cfg.max_entries
    }

    fn publish_size(&self, len: usize) {
        self.inner.metrics.set_cache_size(len as u64);
    }
}

impl IngressStage for ResponseCache {
    fn name(&self) -> &'static str {
        "cache"
    }

    fn admit(&self, req: &IngressRequest<'_>) -> StageOutcome {
        let key = CacheKey::of(req.model, req.inputs);
        let now = Instant::now();
        let mut map = self.lock_map();

        // Probe. A settled in-flight entry is promoted lazily here — no
        // background thread touches the map.
        match map.get_mut(&key) {
            Some(Entry::Resolved { resp, at, last_hit }) => {
                if now.duration_since(*at) < self.inner.cfg.ttl {
                    *last_hit = now; // LRU touch: hits keep entries warm
                    let t = self.hit_ticket(resp, req);
                    let len = map.len();
                    drop(map);
                    self.publish_size(len);
                    self.inner.metrics.record_cache_hit();
                    return StageOutcome::Answer(t);
                }
                map.remove(&key); // stale: fall through to miss
            }
            Some(Entry::InFlight(sr)) => {
                let sr = sr.clone();
                let id = self.mint_id();
                // attach() is atomic w.r.t. settle/abort: either we join
                // the in-flight wait or we see the final outcome here.
                match sr.attach(id) {
                    AttachOutcome::Attached(rx) => {
                        drop(map);
                        self.inner.metrics.record_coalesced();
                        // the follower's ticket enforces the follower's
                        // own deadline — it waits on the leader's
                        // schedule but never inherits the leader's
                        // timeline (see the module docs)
                        return StageOutcome::Answer(
                            Ticket::new(
                                id,
                                req.opts.priority,
                                rx,
                                Arc::new(AtomicBool::new(false)),
                            )
                            .with_deadline(req.opts.deadline.map(|d| now + d)),
                        );
                    }
                    AttachOutcome::Settled(resp, at) => {
                        // leader finished between enqueue and our probe
                        if resp.is_ok() && now.duration_since(at) < self.inner.cfg.ttl {
                            let promoted = Self::promote(&resp);
                            let t = self.hit_ticket(&promoted, req);
                            map.insert(
                                key,
                                Entry::Resolved { resp: promoted, at, last_hit: now },
                            );
                            let len = map.len();
                            drop(map);
                            self.publish_size(len);
                            self.inner.metrics.record_cache_hit();
                            return StageOutcome::Answer(t);
                        }
                        // stale Ok (e.g. ttl = 0), error, expired,
                        // cancelled: never replayed — drop the settled
                        // flight and fall through to a fresh miss.
                        map.remove(&key);
                    }
                    AttachOutcome::Aborted(_) => {
                        map.remove(&key);
                    }
                }
            }
            None => {}
        }

        // Miss: try to register this submission as the key's leader so
        // concurrent identical requests coalesce onto it.
        self.inner.metrics.record_cache_miss();
        if !Self::make_room(&mut map, &self.inner.cfg, now) {
            // map full of pending leaders — proceed uncoalesced
            let len = map.len();
            drop(map);
            self.publish_size(len);
            return StageOutcome::Continue(None);
        }
        let sr = Arc::new(SharedReply::new());
        map.insert(key.clone(), Entry::InFlight(sr.clone()));
        let len = map.len();
        drop(map);
        self.publish_size(len);

        let cache = self.clone();
        let abort_sr = sr.clone();
        let on_abort = Box::new(move || {
            // The leader never enqueued (post-chain shutdown race):
            // unregister the key — but only if it still holds *our*
            // SharedReply — then answer any already-attached followers.
            // Map lock is released before touching the SharedReply lock
            // (lock order: map → reply, never both held across settle).
            let mut map = cache.lock_map();
            if matches!(map.get(&key), Some(Entry::InFlight(e)) if Arc::ptr_eq(e, &abort_sr)) {
                map.remove(&key);
            }
            let len = map.len();
            drop(map);
            cache.publish_size(len);
            abort_sr.abort("request was not enqueued");
        });
        StageOutcome::Continue(Some(ReplyAttachment { fanout: sr, on_abort }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{ResponseStatus, SubmitOptions};

    fn cache(max_entries: usize, ttl: Duration) -> ResponseCache {
        ResponseCache::new(
            CacheConfig { max_entries, ttl },
            Arc::new(Metrics::default()),
            Arc::new(AtomicU64::new(1)),
        )
    }

    fn ireq<'a>(
        model: &'a str,
        inputs: &'a [Value],
        opts: &'a SubmitOptions,
    ) -> IngressRequest<'a> {
        IngressRequest { model, inputs, opts }
    }

    fn ok_response(id: u64, logits: Vec<f32>) -> Response {
        let mut r = Response::error(RequestId(id), "x");
        r.status = ResponseStatus::Ok;
        r.served_by = Arc::from("bert_tiny_s8_b1");
        r.outputs = vec![Value::F32(logits)];
        r.latency_us = 123;
        r.queue_us = 45;
        r
    }

    /// Drive a leader through the stage: miss → attachment installed.
    fn lead(c: &ResponseCache, model: &str, inputs: &[Value]) -> Arc<SharedReply> {
        let opts = SubmitOptions::default();
        match c.admit(&ireq(model, inputs, &opts)) {
            StageOutcome::Continue(Some(a)) => a.fanout,
            other => panic!("expected leader registration, got {other:?}"),
        }
    }

    #[test]
    fn cache_key_is_bitwise_exact() {
        let a = CacheKey::of("m", &[Value::F32(vec![0.0])]);
        let b = CacheKey::of("m", &[Value::F32(vec![-0.0])]);
        assert_ne!(a, b, "0.0 and -0.0 are different keys");
        let c = CacheKey::of("m", &[Value::I32(vec![1, 2])]);
        let d = CacheKey::of("m", &[Value::I32(vec![1]), Value::I32(vec![2])]);
        assert_ne!(c, d, "tensor boundaries are part of the key");
        let e = CacheKey::of("m2", &[Value::I32(vec![1, 2])]);
        assert_ne!(c, e, "model is part of the key");
        assert_eq!(c, CacheKey::of("m", &[Value::I32(vec![1, 2])]));
    }

    #[test]
    fn cache_hit_after_settle_is_promoted_and_restamped() {
        let metrics = Arc::new(Metrics::default());
        let c = ResponseCache::new(
            CacheConfig::default(),
            metrics.clone(),
            Arc::new(AtomicU64::new(100)),
        );
        let inputs = [Value::I32(vec![1, 2, 3])];
        let sr = lead(&c, "m", &inputs);
        sr.settle(&ok_response(1, vec![0.5, -0.25]));
        let opts = SubmitOptions::interactive();
        let t = match c.admit(&ireq("m", &inputs, &opts)) {
            StageOutcome::Answer(t) => t,
            other => panic!("expected Answer, got {other:?}"),
        };
        let r = t.wait().unwrap();
        assert!(r.is_ok());
        assert_eq!(r.id, t.id(), "hit carries the caller's own fresh id");
        assert_eq!(&*r.served_by, "cache:bert_tiny_s8_b1");
        assert_eq!((r.latency_us, r.queue_us), (0, 0));
        assert_eq!(
            r.logits().iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            [0.5f32, -0.25].iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "bitwise-identical logits"
        );
        let s = metrics.snapshot();
        assert_eq!((s.cache_hits, s.cache_misses), (1, 1));
        assert_eq!(s.admitted, 0, "hits never touch admission");
    }

    #[test]
    fn coalesced_attach_joins_the_inflight_leader() {
        let metrics = Arc::new(Metrics::default());
        let c = ResponseCache::new(
            CacheConfig::default(),
            metrics.clone(),
            Arc::new(AtomicU64::new(1)),
        );
        let inputs = [Value::I32(vec![7])];
        let sr = lead(&c, "m", &inputs);
        let opts = SubmitOptions::default();
        let follower = match c.admit(&ireq("m", &inputs, &opts)) {
            StageOutcome::Answer(t) => t,
            other => panic!("expected coalesced Answer, got {other:?}"),
        };
        assert!(follower.try_poll().is_none(), "leader still in flight");
        sr.settle(&ok_response(1, vec![1.0]));
        let r = follower.wait().unwrap();
        assert!(r.is_ok());
        assert_eq!(r.id, follower.id());
        assert_eq!(metrics.snapshot().coalesced, 1);
    }

    #[test]
    fn errors_are_settled_to_followers_but_never_cached() {
        let c = cache(16, Duration::from_secs(60));
        let inputs = [Value::I32(vec![9])];
        let sr = lead(&c, "m", &inputs);
        sr.settle(&Response::error(RequestId(1), "worker panicked"));
        // next identical submission is a fresh miss, not a replayed error
        let opts = SubmitOptions::default();
        match c.admit(&ireq("m", &inputs, &opts)) {
            StageOutcome::Continue(Some(_)) => {}
            other => panic!("expected fresh leader, got {other:?}"),
        }
    }

    #[test]
    fn ttl_zero_never_reuses_a_resolved_response() {
        let c = cache(16, Duration::ZERO);
        let inputs = [Value::I32(vec![4])];
        let sr = lead(&c, "m", &inputs);
        sr.settle(&ok_response(1, vec![2.0]));
        let opts = SubmitOptions::default();
        match c.admit(&ireq("m", &inputs, &opts)) {
            StageOutcome::Continue(Some(_)) => {}
            other => panic!("expected re-execution, got {other:?}"),
        }
    }

    #[test]
    fn abort_unregisters_the_key_and_answers_followers() {
        let c = cache(16, Duration::from_secs(60));
        let inputs = [Value::I32(vec![5])];
        let opts = SubmitOptions::default();
        let attachment = match c.admit(&ireq("m", &inputs, &opts)) {
            StageOutcome::Continue(Some(a)) => a,
            other => panic!("expected leader registration, got {other:?}"),
        };
        let rx = match attachment.fanout.attach(RequestId(50)) {
            AttachOutcome::Attached(rx) => rx,
            other => panic!("expected Attached, got {other:?}"),
        };
        (attachment.on_abort)();
        assert_eq!(c.len(), 0, "aborted leader unregistered");
        let r = rx.recv().unwrap();
        assert_eq!(r.error_message(), Some("request was not enqueued"));
        // the key is free again for a new leader
        match c.admit(&ireq("m", &inputs, &opts)) {
            StageOutcome::Continue(Some(_)) => {}
            other => panic!("expected fresh leader, got {other:?}"),
        }
    }

    #[test]
    fn eviction_bounds_the_map_and_spares_pending_leaders() {
        let c = cache(2, Duration::from_secs(60));
        let a = [Value::I32(vec![1])];
        let b = [Value::I32(vec![2])];
        let x = [Value::I32(vec![3])];
        let sr_a = lead(&c, "m", &a);
        sr_a.settle(&ok_response(1, vec![1.0]));
        let _sr_b = lead(&c, "m", &b); // still pending
        assert_eq!(c.len(), 2);
        // third key: map full → oldest resolved (a) evicted, pending b kept
        let _sr_x = lead(&c, "m", &x);
        assert_eq!(c.len(), 2);
        let opts = SubmitOptions::default();
        match c.admit(&ireq("m", &b, &opts)) {
            StageOutcome::Answer(_) => {} // b still coalescable
            other => panic!("pending leader must survive eviction, got {other:?}"),
        }
        match c.admit(&ireq("m", &a, &opts)) {
            StageOutcome::Continue(_) => {} // a was evicted → miss
            other => panic!("expected a evicted, got {other:?}"),
        }
    }

    #[test]
    fn full_map_promotes_settled_ok_flights_instead_of_discarding() {
        // two settled-Ok flights fill the map; a third key arriving must
        // not throw both just-computed values away — the newer one is
        // promoted to a resolved entry and still serves a hit
        let c = cache(2, Duration::from_secs(60));
        let a = [Value::I32(vec![1])];
        let b = [Value::I32(vec![2])];
        let x = [Value::I32(vec![3])];
        let sr_a = lead(&c, "m", &a);
        let sr_b = lead(&c, "m", &b);
        sr_a.settle(&ok_response(1, vec![1.0]));
        std::thread::sleep(Duration::from_millis(1)); // order the settle stamps
        sr_b.settle(&ok_response(2, vec![2.0]));
        let _sr_x = lead(&c, "m", &x);
        assert_eq!(c.len(), 2, "oldest promoted entry evicted, newest kept");
        let opts = SubmitOptions::default();
        match c.admit(&ireq("m", &b, &opts)) {
            StageOutcome::Answer(t) => {
                let r = t.wait().unwrap();
                assert!(r.is_ok());
                assert_eq!(&*r.served_by, "cache:bert_tiny_s8_b1");
                assert_eq!(r.logits(), &[2.0]);
            }
            other => panic!("settled-Ok flight must be promoted, got {other:?}"),
        }
    }

    #[test]
    fn coalesced_follower_expires_on_its_own_deadline() {
        let c = cache(16, Duration::from_secs(60));
        let inputs = [Value::I32(vec![11])];
        let sr = lead(&c, "m", &inputs);
        // follower with a 20ms deadline attaches to a leader that will
        // not settle for a long time: the old behavior blocked on the
        // leader's timeline; now the follower sheds itself, typed
        let opts = SubmitOptions::default().with_deadline(Duration::from_millis(20));
        let follower = match c.admit(&ireq("m", &inputs, &opts)) {
            StageOutcome::Answer(t) => t,
            other => panic!("expected coalesced Answer, got {other:?}"),
        };
        let start = Instant::now();
        let r = follower.wait().unwrap();
        assert_eq!(r.status, ResponseStatus::Expired, "follower sheds on its OWN deadline");
        assert_eq!(r.id, follower.id(), "shed keeps the follower's id");
        assert!(
            start.elapsed() < Duration::from_secs(5),
            "must not inherit the leader's timeline"
        );
        // the leader settling later is unaffected: the next identical
        // submission is served the promoted response
        sr.settle(&ok_response(1, vec![3.0]));
        let opts2 = SubmitOptions::default();
        match c.admit(&ireq("m", &inputs, &opts2)) {
            StageOutcome::Answer(t) => assert!(t.wait().unwrap().is_ok()),
            other => panic!("expected promoted hit, got {other:?}"),
        }
    }

    #[test]
    fn lru_keeps_a_repeatedly_hit_entry_over_a_colder_newer_one() {
        let c = cache(2, Duration::from_secs(60));
        let hot = [Value::I32(vec![1])];
        let cold = [Value::I32(vec![2])];
        let newcomer = [Value::I32(vec![3])];
        // hot resolves FIRST (it is the oldest by settle time)...
        let sr_hot = lead(&c, "m", &hot);
        sr_hot.settle(&ok_response(1, vec![1.0]));
        std::thread::sleep(Duration::from_millis(1));
        let sr_cold = lead(&c, "m", &cold);
        sr_cold.settle(&ok_response(2, vec![2.0]));
        std::thread::sleep(Duration::from_millis(1));
        // ...but keeps serving hits, so its recency stamp is the newest
        let opts = SubmitOptions::default();
        match c.admit(&ireq("m", &hot, &opts)) {
            StageOutcome::Answer(_) => {}
            other => panic!("expected hot hit, got {other:?}"),
        }
        // a new key forces eviction on the full map: the old
        // oldest-resolved policy would evict hot; LRU evicts cold
        let _sr_new = lead(&c, "m", &newcomer);
        assert_eq!(c.len(), 2);
        match c.admit(&ireq("m", &hot, &opts)) {
            StageOutcome::Answer(_) => {} // hot survived
            other => panic!("repeatedly-hit entry must outlive a colder newer one, got {other:?}"),
        }
        match c.admit(&ireq("m", &cold, &opts)) {
            StageOutcome::Continue(_) => {} // cold was evicted → miss
            other => panic!("expected cold evicted, got {other:?}"),
        }
    }

    #[test]
    fn full_map_of_pending_leaders_degrades_to_uncoalesced() {
        let c = cache(1, Duration::from_secs(60));
        let a = [Value::I32(vec![1])];
        let b = [Value::I32(vec![2])];
        let _sr_a = lead(&c, "m", &a); // occupies the single slot, pending
        let opts = SubmitOptions::default();
        match c.admit(&ireq("m", &b, &opts)) {
            StageOutcome::Continue(None) => {} // no registration, no coalescing
            other => panic!("expected uncoalesced Continue(None), got {other:?}"),
        }
        assert_eq!(c.len(), 1);
    }
}
