//! SparseRT serving coordinator (Layer 3).
//!
//! The serve-time system around the runtime: typed requests come in with
//! per-request QoS ([`SubmitOptions`]: priority class, deadline, client
//! tag), are admission-controlled per class, priority-batched, routed to
//! a compiled model variant, executed on any [`InferenceBackend`] (PJRT,
//! simulator, echo), and answered — all on std threads + channels,
//! Python never involved. Clients hold a [`Ticket`] per submission
//! (wait / poll / cancel); every ticket resolves to exactly one
//! [`Response`] whose [`ResponseStatus`] is `Ok`, `Error`, `Expired`, or
//! `Cancelled`.
//!
//! Submission runs a staged **ingress chain** ([`ingress`]): each
//! [`IngressStage`] can *shed* (typed rejection), *answer* immediately
//! (cache hit / coalesced attach — no admission slot, no batch seat), or
//! *continue*. The default chain `[breaker, admission]` is the
//! pre-cache behavior, bitwise; [`ServerConfig::cache`] prepends the
//! exact response cache ([`cache`]).
//!
//! ```text
//!            ServingService::submit_with(model, inputs, SubmitOptions)
//!            ┌───────── ingress chain ──────────┐
//! client ─▶ [cache?] ─▶ [breaker] ─▶ [admission] ─▶ queue ─▶ batcher ─▶ router ─▶ worker pool ─▶ InferenceBackend
//!    ▲        │  │       (health      (per-class       (priority seed,   │      (pre-exec shed:     │
//!    │  hit ──┘  │        shed)        budgets)         shed expired/    │       cancel/deadline    │
//!    │  (exact,  └─ coalesce: attach to               cancelled)       │       re-check)          │
//!  Ticket (bitwise)  identical in-flight leader;           metrics ◀───┴───────────┴──────────────┘
//!  wait/poll/cancel  leader's ReplySlot fans out       ▲
//!    ▲               one reply to all waiters          │ conns / frames / malformed
//!    │ Ticket::try_take (reply pump)                   │
//!  ┌─┴─────────────────────────────────────────────────┴─┐
//!  │ net::NetServer  (socket boundary)                   │   reader + reply pump per conn;
//!  │   TCP frames ⇄ submit_with/Ticket                   │   drain hook: srv.on_shutdown(
//!  └───▲───────────────────────────────────────────────┬─┘     move || net.shutdown())
//!      │ length-prefixed frames (wire)                 │
//!   net::NetClient / net::loadgen  ◀───────────────────┘   remote clients over TCP
//!      ▲
//!      │ the same wire protocol, one level up: a cluster router tier
//!      │ (crate::cluster) is itself a ServingService behind a NetServer,
//!      │ forwarding each submission to one of N such nodes
//!  ┌───┴──────────────────────────────────────────────────┐
//!  │ cluster::RouterServer   placement (hash-by-model, R) │
//!  │   rotate replicas ─▶ forward over pooled NetClient   │
//!  │   per-node Breaker ─▶ failover / typed retryable shed│
//!  └──────────────────────────────────────────────────────┘
//! ```
//!
//! Cache hits and coalesced attaches are answered without being
//! admitted, so the core accounting invariant `answered() == admitted`
//! is untouched; the extended identity is
//! `served() == answered() + cache_hits + coalesced`
//! ([`MetricsSnapshot::served`]). A hit's `served_by` reads
//! `cache:<artifact>` end to end, including over the wire.
//!
//! **Supervision (fault path).** Each worker executes every batch inside a
//! `catch_unwind` fence; a backend panic answers the batch's unanswered
//! tickets with a typed `ResponseStatus::Error`, releases their admission
//! slots, reports the failure to the health [`Breaker`], and then lets the
//! thread die — the supervisor wrapper respawns a replacement so capacity
//! never shrinks. The batch hand-off mutex recovers poison on acquisition,
//! so one panicked worker can no longer cascade-kill the rest:
//!
//! ```text
//!            ┌────────────── spawn_worker (supervisor) ──────────────┐
//!            │  worker_loop:                                         │
//!            │    batch_rx.lock()  ── poison-recovering acquisition  │
//!            │    catch_unwind(serve_batch)                          │
//!            │      Ok  ─▶ breaker.record_success/failure per        │
//!            │             placement; tickets answered by serve_batch│
//!            │      Err ─▶ answer unanswered tickets (typed Error),  │
//!            │             worker_panics++, breaker.record_failure,  │
//!            │             release slots, resume_unwind              │
//!            │  on panic && !stopping: worker_restarts++,            │
//!            │    respawn replacement thread ────────────────────────┼──▶ loop
//!            └───────────────────────────────────────────────────────┘
//!   breaker: Closed ─(N consecutive failures)─▶ Open ─(sheds)─▶ HalfOpen
//!            ▲  shed Bulk first; RejectUnhealthy is typed + retryable │
//!            └──────────────(probe successes)─────────────────────────┘
//! ```
//!
//! Requests carry `Vec<Value>` payloads (one sample-shaped tensor per
//! model input) and the padding/demux in the worker pool is driven by the
//! artifact's `TensorSpec`s, so BERT token batches and ResNet image
//! batches flow through the identical path. Scheduling differentiates
//! the three [`Priority`] classes: `Interactive` seeds batches first and
//! `Bulk` is budget-capped at admission, so latency-critical traffic
//! survives overload instead of queueing behind backfills.

pub mod admission;
pub mod batcher;
pub mod cache;
pub mod health;
pub mod ingress;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use admission::{Admission, AdmissionDecision};
pub use batcher::{Batch, BatcherConfig, DynamicBatcher};
pub use cache::{CacheConfig, ResponseCache};
pub use health::{Breaker, BreakerConfig, BreakerState, BreakerVerdict};
pub use ingress::{
    AdmissionGate, BreakerGate, ChainOutcome, IngressChain, IngressRequest, IngressStage,
    ReplyAttachment, StageOutcome,
};
pub use metrics::{ClassStats, Metrics, MetricsSnapshot, NetStats, NodeRouterStats, RouterStats};
pub use request::{
    AttachOutcome, Priority, ReplySlot, Request, RequestId, Response, ResponseStatus, SharedReply,
    SubmitOptions, Ticket, COALESCED_LEADER_CANCELLED, COALESCED_LEADER_EXPIRED,
};
pub use router::{Placement, Router, RoutingPolicy};
pub use server::{Server, ServerConfig, ServerHandle, ServingService};

// The execution surface lives in `crate::backend`; re-exported here for
// serving-centric call sites.
pub use crate::backend::{CpuSparseBackend, EchoBackend, InferenceBackend, Precision, SimBackend};
