//! SparseRT serving coordinator (Layer 3).
//!
//! The serve-time system around the runtime: typed requests come in, are
//! admission-controlled, dynamically batched, routed to a compiled model
//! variant, executed on any [`InferenceBackend`] (PJRT, simulator, echo),
//! and answered — all on std threads + channels, Python never involved.
//!
//! ```text
//! client ─▶ admission ─▶ queue ─▶ batcher ─▶ router ─▶ worker pool ─▶ InferenceBackend
//!                                                        │
//!                                  metrics ◀─────────────┘
//! ```
//!
//! Requests carry `Vec<Value>` payloads (one sample-shaped tensor per
//! model input) and the padding/demux in the worker pool is driven by the
//! artifact's `TensorSpec`s, so BERT token batches and ResNet image
//! batches flow through the identical path.

pub mod admission;
pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use admission::{Admission, AdmissionDecision};
pub use batcher::{Batch, BatcherConfig, DynamicBatcher};
pub use metrics::Metrics;
pub use request::{Request, RequestId, Response};
pub use router::{Placement, Router, RoutingPolicy};
pub use server::{Server, ServerConfig, ServerHandle};

// The execution surface lives in `crate::backend`; re-exported here for
// serving-centric call sites.
pub use crate::backend::{CpuSparseBackend, EchoBackend, InferenceBackend, Precision, SimBackend};
