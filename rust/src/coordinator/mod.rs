//! SparseRT serving coordinator (Layer 3).
//!
//! The serve-time system around the runtime: requests come in, are
//! admission-controlled, dynamically batched, routed to a compiled model
//! variant, executed on a backend (PJRT or simulator), and answered — all
//! on std threads + channels, Python never involved.
//!
//! ```text
//! client ─▶ admission ─▶ queue ─▶ batcher ─▶ router ─▶ worker pool ─▶ backend
//!                                                        │
//!                                  metrics ◀─────────────┘
//! ```

pub mod admission;
pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use admission::{Admission, AdmissionDecision};
pub use batcher::{Batch, BatcherConfig, DynamicBatcher};
pub use metrics::Metrics;
pub use request::{Request, RequestId, Response};
pub use router::{Router, RoutingPolicy};
pub use server::{Backend, Server, ServerConfig, SimBackend};
