//! Backend health state machine: a consecutive-failure circuit breaker
//! with graceful degradation.
//!
//! When the backend starts failing every call (bad artifact hot-swap,
//! resource exhaustion, a poisoned dependency), queueing more work behind
//! it only converts healthy clients into timed-out clients. The breaker
//! watches execution outcomes from the workers and trips after
//! [`failure_threshold`](BreakerConfig::failure_threshold) *consecutive*
//! failures:
//!
//! ```text
//!            failures >= failure_threshold
//!   Closed ───────────────────────────────▶ Open
//!     ▲                                      │ sheds_since_open >=
//!     │ probe_successes >=                   │ probe_after_sheds
//!     │ close_after_probes                   ▼
//!     └──────────────────────────────── HalfOpen
//!                  (any failure in HalfOpen re-opens)
//! ```
//!
//! While **Open**, every submission is shed at the front door with the
//! typed, retryable
//! [`AdmissionDecision::RejectUnhealthy`](super::admission::AdmissionDecision::RejectUnhealthy)
//! — the client learns immediately instead of holding a doomed ticket.
//! After [`probe_after_sheds`](BreakerConfig::probe_after_sheds) sheds the
//! breaker moves to **HalfOpen** and lets non-Bulk traffic through as
//! probes; [`close_after_probes`](BreakerConfig::close_after_probes)
//! consecutive probe successes close it again, any probe failure re-opens
//! it. `Bulk` is shed for the whole degraded window (Open *and* HalfOpen):
//! graceful degradation sacrifices throughput traffic first and recovers
//! latency-critical classes first.
//!
//! Every transition is driven by deterministic *counts* — consecutive
//! failures, shed counts, probe successes — never wall-clock timers, so a
//! seeded fault schedule (see [`crate::fault`]) produces the exact same
//! open/probe/close trace on every run. That determinism is what lets
//! `tests/chaos.rs` assert breaker behavior bit-for-bit.

use std::sync::Mutex;

use super::request::Priority;

/// Tuning knobs for the [`Breaker`]. All thresholds are counts (no
/// durations): deterministic under seeded fault injection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BreakerConfig {
    /// Consecutive backend failures that trip `Closed → Open`. Clamped to
    /// at least 1.
    pub failure_threshold: u32,
    /// Submissions shed while `Open` before the breaker moves to
    /// `HalfOpen` and starts probing. Bounds how much traffic is turned
    /// away before recovery is even attempted.
    pub probe_after_sheds: u32,
    /// Consecutive successful probes in `HalfOpen` that close the
    /// breaker. Clamped to at least 1.
    pub close_after_probes: u32,
}

impl Default for BreakerConfig {
    fn default() -> BreakerConfig {
        BreakerConfig {
            failure_threshold: 8,
            probe_after_sheds: 4,
            close_after_probes: 2,
        }
    }
}

/// Where the breaker currently is (observability + tests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: everything passes.
    Closed,
    /// Tripped: everything is shed until enough sheds trigger probing.
    Open,
    /// Probing: non-Bulk passes (each a probe), Bulk still shed.
    HalfOpen,
}

/// Per-submission decision from [`Breaker::admit`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerVerdict {
    /// Healthy path: admit normally.
    Pass,
    /// Degraded path: admit, and this request's outcome decides whether
    /// the breaker closes or re-opens.
    Probe,
    /// Shed with a typed retryable rejection; no admission slot consumed.
    Shed,
}

#[derive(Debug)]
struct Inner {
    state: BreakerState,
    consecutive_failures: u32,
    sheds_since_open: u32,
    probe_successes: u32,
}

/// Consecutive-failure circuit breaker shared between the submission
/// surface (which consults [`admit`](Breaker::admit)) and the workers
/// (which report [`record_success`](Breaker::record_success) /
/// [`record_failure`](Breaker::record_failure) per placement execution).
#[derive(Debug)]
pub struct Breaker {
    cfg: BreakerConfig,
    inner: Mutex<Inner>,
}

impl Breaker {
    pub fn new(cfg: BreakerConfig) -> Breaker {
        Breaker {
            cfg,
            inner: Mutex::new(Inner {
                state: BreakerState::Closed,
                consecutive_failures: 0,
                sheds_since_open: 0,
                probe_successes: 0,
            }),
        }
    }

    /// A breaker panic (impossible today: transitions don't panic) must
    /// not take down every submission path with it — recover the poison,
    /// same pattern as the arena locks in `backend/cpu.rs`.
    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    pub fn state(&self) -> BreakerState {
        self.lock().state
    }

    /// Decide the fate of one incoming `class` submission. Called before
    /// admission so a shed consumes neither an admission slot nor an
    /// `admitted` count — `answered() == admitted` stays an invariant
    /// through a breaker-open window.
    pub fn admit(&self, class: Priority) -> BreakerVerdict {
        let mut g = self.lock();
        match g.state {
            BreakerState::Closed => BreakerVerdict::Pass,
            BreakerState::Open => {
                // Bulk never probes: degraded capacity goes to the
                // latency-critical classes first.
                if class != Priority::Bulk && g.sheds_since_open >= self.cfg.probe_after_sheds {
                    g.state = BreakerState::HalfOpen;
                    g.probe_successes = 0;
                    BreakerVerdict::Probe
                } else {
                    g.sheds_since_open += 1;
                    BreakerVerdict::Shed
                }
            }
            BreakerState::HalfOpen => {
                if class == Priority::Bulk {
                    BreakerVerdict::Shed
                } else {
                    BreakerVerdict::Probe
                }
            }
        }
    }

    /// One placement executed cleanly.
    pub fn record_success(&self) {
        let mut g = self.lock();
        match g.state {
            BreakerState::Closed => g.consecutive_failures = 0,
            BreakerState::HalfOpen => {
                g.probe_successes += 1;
                if g.probe_successes >= self.cfg.close_after_probes.max(1) {
                    g.state = BreakerState::Closed;
                    g.consecutive_failures = 0;
                }
            }
            // stragglers admitted before the trip finishing now carry no
            // signal about post-trip health
            BreakerState::Open => {}
        }
    }

    /// One placement failed (backend error or worker panic). Returns
    /// `true` when this failure newly opened the breaker, so the caller
    /// can count `breaker_opens` exactly once per trip.
    pub fn record_failure(&self) -> bool {
        let mut g = self.lock();
        match g.state {
            BreakerState::Closed => {
                g.consecutive_failures += 1;
                if g.consecutive_failures >= self.cfg.failure_threshold.max(1) {
                    g.state = BreakerState::Open;
                    g.sheds_since_open = 0;
                    true
                } else {
                    false
                }
            }
            BreakerState::HalfOpen => {
                g.state = BreakerState::Open;
                g.sheds_since_open = 0;
                g.consecutive_failures = 0;
                true
            }
            BreakerState::Open => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(th: u32, sheds: u32, probes: u32) -> BreakerConfig {
        BreakerConfig {
            failure_threshold: th,
            probe_after_sheds: sheds,
            close_after_probes: probes,
        }
    }

    #[test]
    fn stays_closed_below_threshold_and_success_resets_the_streak() {
        let b = Breaker::new(cfg(3, 2, 1));
        assert!(!b.record_failure());
        assert!(!b.record_failure());
        b.record_success(); // streak broken
        assert!(!b.record_failure());
        assert!(!b.record_failure());
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(b.admit(Priority::Standard), BreakerVerdict::Pass);
        assert!(b.record_failure(), "third consecutive failure trips");
        assert_eq!(b.state(), BreakerState::Open);
    }

    #[test]
    fn open_sheds_then_probes_then_closes() {
        let b = Breaker::new(cfg(1, 2, 2));
        assert!(b.record_failure());
        // first two submissions shed, third becomes the probe
        assert_eq!(b.admit(Priority::Standard), BreakerVerdict::Shed);
        assert_eq!(b.admit(Priority::Interactive), BreakerVerdict::Shed);
        assert_eq!(b.admit(Priority::Standard), BreakerVerdict::Probe);
        assert_eq!(b.state(), BreakerState::HalfOpen);
        b.record_success();
        assert_eq!(b.state(), BreakerState::HalfOpen, "needs 2 probe successes");
        assert_eq!(b.admit(Priority::Standard), BreakerVerdict::Probe);
        b.record_success();
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(b.admit(Priority::Bulk), BreakerVerdict::Pass);
    }

    #[test]
    fn bulk_is_shed_for_the_whole_degraded_window() {
        let b = Breaker::new(cfg(1, 0, 1));
        assert!(b.record_failure());
        // probe_after_sheds = 0: the first non-Bulk submission probes, but
        // Bulk neither probes nor passes until the breaker closes
        assert_eq!(b.admit(Priority::Bulk), BreakerVerdict::Shed);
        assert_eq!(b.admit(Priority::Interactive), BreakerVerdict::Probe);
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert_eq!(b.admit(Priority::Bulk), BreakerVerdict::Shed);
        b.record_success();
        assert_eq!(b.admit(Priority::Bulk), BreakerVerdict::Pass);
    }

    #[test]
    fn probe_failure_reopens() {
        let b = Breaker::new(cfg(2, 1, 1));
        assert!(!b.record_failure());
        assert!(b.record_failure());
        assert_eq!(b.admit(Priority::Standard), BreakerVerdict::Shed);
        assert_eq!(b.admit(Priority::Standard), BreakerVerdict::Probe);
        assert!(b.record_failure(), "probe failure re-opens (counts as a new open)");
        assert_eq!(b.state(), BreakerState::Open);
        // the shed quota starts over
        assert_eq!(b.admit(Priority::Standard), BreakerVerdict::Shed);
        assert_eq!(b.admit(Priority::Standard), BreakerVerdict::Probe);
        b.record_success();
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn open_ignores_straggler_outcomes() {
        let b = Breaker::new(cfg(1, 5, 1));
        assert!(b.record_failure());
        // in-flight work admitted before the trip drains while Open;
        // neither outcome moves the state machine
        b.record_success();
        assert!(!b.record_failure());
        assert_eq!(b.state(), BreakerState::Open);
    }

    #[test]
    fn zero_thresholds_are_clamped_not_divergent() {
        let b = Breaker::new(cfg(0, 0, 0));
        assert!(b.record_failure(), "threshold 0 behaves like 1");
        assert_eq!(b.admit(Priority::Standard), BreakerVerdict::Probe);
        b.record_success();
        assert_eq!(b.state(), BreakerState::Closed, "close_after 0 behaves like 1");
    }

    #[test]
    fn deterministic_trace_under_a_fixed_schedule() {
        // same outcome schedule → same verdict trace, twice
        let trace = || {
            let b = Breaker::new(cfg(2, 1, 1));
            let mut v = Vec::new();
            for step in 0..12 {
                if step % 3 == 0 {
                    b.record_failure();
                } else if step % 7 == 0 {
                    b.record_success();
                }
                v.push((b.admit(Priority::Standard), b.state()));
            }
            v
        };
        assert_eq!(trace(), trace());
    }
}
