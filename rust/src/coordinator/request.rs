//! Request/response types on the serving hot path.
//!
//! Payloads are typed multi-tensor [`Value`]s: a request carries one
//! *sample-shaped* value per model input (token ids for BERT, image
//! pixels for ResNet), a response carries one sample-shaped value per
//! model output. The server pads samples to the routed artifact's
//! [`TensorSpec`](crate::backend::TensorSpec)s and demuxes batch outputs
//! back per request — nothing here assumes a token→logits shape.

use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

use crate::backend::Value;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

/// One inference request for a named model.
#[derive(Debug)]
pub struct Request {
    pub id: RequestId,
    /// shared, immutable model name — `Arc<str>` so batching/stash
    /// bookkeeping clones a refcount, not a heap string, per request
    pub model: Arc<str>,
    /// one sample-shaped value per model input; the server zero-pads (or
    /// truncates) each to the routed artifact's per-sample spec length
    pub inputs: Vec<Value>,
    pub submitted: Instant,
    /// where the response goes (per-client channel)
    pub reply: Sender<Response>,
}

/// The answer: typed output tensors plus serving telemetry.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    /// one sample-shaped value per model output
    pub outputs: Vec<Value>,
    /// which artifact variant served it (e.g. "bert_tiny_s8_b8")
    pub served_by: String,
    /// batch capacity it rode in
    pub batch_size: usize,
    /// end-to-end latency
    pub latency_us: u64,
    /// time spent queued before execution started
    pub queue_us: u64,
    pub ok: bool,
    pub error: Option<String>,
}

impl Response {
    pub fn error(id: RequestId, msg: impl Into<String>) -> Response {
        Response {
            id,
            outputs: Vec::new(),
            served_by: String::new(),
            batch_size: 0,
            latency_us: 0,
            queue_us: 0,
            ok: false,
            error: Some(msg.into()),
        }
    }

    /// The first f32 output — the classifier-logits convenience accessor
    /// (empty when the request failed or the model emits no f32 tensor).
    pub fn logits(&self) -> &[f32] {
        self.outputs
            .iter()
            .find_map(|v| v.as_f32())
            .unwrap_or(&[])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_response_is_marked_and_empty() {
        let r = Response::error(RequestId(7), "nope");
        assert!(!r.ok);
        assert_eq!(r.id, RequestId(7));
        assert!(r.outputs.is_empty());
        assert!(r.logits().is_empty());
        assert_eq!(r.error.as_deref(), Some("nope"));
    }

    #[test]
    fn logits_finds_first_f32_output() {
        let mut r = Response::error(RequestId(1), "x");
        r.outputs = vec![Value::I32(vec![3]), Value::F32(vec![0.25, 0.75])];
        assert_eq!(r.logits(), &[0.25, 0.75]);
    }
}
