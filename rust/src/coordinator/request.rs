//! Request/response types + the QoS-aware submission surface.
//!
//! Payloads are typed multi-tensor [`Value`]s: a request carries one
//! *sample-shaped* value per model input (token ids for BERT, image
//! pixels for ResNet), a response carries one sample-shaped value per
//! model output. The server pads samples to the routed artifact's
//! [`TensorSpec`](crate::backend::TensorSpec)s and demuxes batch outputs
//! back per request — nothing here assumes a token→logits shape.
//!
//! The v2 lifecycle surface lives here too:
//! * [`Priority`] — the three serving classes the batcher and admission
//!   controller differentiate on;
//! * [`SubmitOptions`] — per-request QoS knobs (priority, deadline,
//!   client tag);
//! * [`Ticket`] — the client-side handle a submission returns (wait /
//!   poll / cancel), replacing the PR 1-era raw
//!   `(RequestId, Receiver<Response>)` tuple;
//! * [`ResponseStatus`] — the typed outcome (`Ok`/`Error`/`Expired`/
//!   `Cancelled`) replacing the old `ok: bool` + `Option<String>` pair.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::backend::Value;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

/// Serving class of a request. Ordering is scheduling order: a
/// lower-valued class is drained first (`Interactive < Standard < Bulk`),
/// so `Priority` sorts from most to least latency-critical.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Priority {
    /// Latency-critical: seeded into batches before anything else and
    /// never starved by `Bulk` backlog.
    Interactive,
    /// The default class — PR 1-era `submit()` calls land here.
    #[default]
    Standard,
    /// Throughput traffic (offline scoring, backfills): capped to a
    /// fraction of `max_inflight` at admission so it cannot crowd out
    /// the other classes.
    Bulk,
}

impl Priority {
    /// All classes, in scheduling (drain) order.
    pub const ALL: [Priority; 3] = [Priority::Interactive, Priority::Standard, Priority::Bulk];

    /// Dense index for per-class counter arrays.
    pub fn idx(self) -> usize {
        self as usize
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Standard => "standard",
            Priority::Bulk => "bulk",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Priority> {
        match s {
            "interactive" => Ok(Priority::Interactive),
            "standard" => Ok(Priority::Standard),
            "bulk" => Ok(Priority::Bulk),
            other => anyhow::bail!(
                "unknown priority `{other}` (interactive | standard | bulk)"
            ),
        }
    }
}

/// Per-request QoS options for
/// [`ServingService::submit_with`](crate::coordinator::ServingService::submit_with).
///
/// `SubmitOptions::default()` is exactly the PR 1 behavior: `Standard`
/// priority, no deadline, no tag — which is why the two-arg
/// [`submit`](crate::coordinator::ServingService::submit) wrapper stays a
/// mechanical migration for old call sites.
#[derive(Clone, Debug, Default)]
pub struct SubmitOptions {
    pub priority: Priority,
    /// End-to-end budget measured from submission; a request still
    /// unexecuted when it elapses is shed with [`ResponseStatus::Expired`]
    /// instead of wasting backend time.
    pub deadline: Option<Duration>,
    /// Free-form client label carried on the request for observability.
    pub client_tag: Option<String>,
}

impl SubmitOptions {
    pub fn interactive() -> SubmitOptions {
        SubmitOptions { priority: Priority::Interactive, ..Default::default() }
    }

    pub fn bulk() -> SubmitOptions {
        SubmitOptions { priority: Priority::Bulk, ..Default::default() }
    }

    pub fn with_priority(mut self, p: Priority) -> SubmitOptions {
        self.priority = p;
        self
    }

    pub fn with_deadline(mut self, d: Duration) -> SubmitOptions {
        self.deadline = Some(d);
        self
    }

    pub fn with_client_tag(mut self, tag: impl Into<String>) -> SubmitOptions {
        self.client_tag = Some(tag.into());
        self
    }
}

/// One inference request for a named model.
#[derive(Debug)]
pub struct Request {
    pub id: RequestId,
    /// shared, immutable model name — `Arc<str>` so batching/stash
    /// bookkeeping clones a refcount, not a heap string, per request
    pub model: Arc<str>,
    /// one sample-shaped value per model input; the server zero-pads (or
    /// truncates) each to the routed artifact's per-sample spec length
    pub inputs: Vec<Value>,
    pub submitted: Instant,
    pub priority: Priority,
    /// absolute cutoff derived from [`SubmitOptions::deadline`]
    pub deadline: Option<Instant>,
    /// cooperative cancellation flag, shared with the client's [`Ticket`]
    pub cancelled: Arc<AtomicBool>,
    /// client label from [`SubmitOptions::client_tag`]
    pub client_tag: Option<Arc<str>>,
    /// where the response goes (per-client channel, exactly-once)
    pub reply: ReplySlot,
}

/// Exactly-once reply channel for one request.
///
/// The supervised worker loop answers a panicked batch *after* the fact,
/// from clones of the requests' reply handles captured before execution —
/// but `serve_batch` may already have answered some of those requests
/// (pre-execution shed, placement demux) before the panic hit. A bare
/// `Sender<Response>` would let the fence double-answer them, breaking the
/// one-response-per-[`Ticket`] contract that `wait()` relies on.
/// `ReplySlot` closes that race: the first [`send`](ReplySlot::send) wins,
/// every later send on any clone is a silent no-op, so fences and fallback
/// paths can always answer defensively without counting.
#[derive(Clone, Debug)]
pub struct ReplySlot {
    tx: Sender<Response>,
    answered: Arc<AtomicBool>,
    /// optional coalescing fan-out: when present, the winning send also
    /// settles the [`SharedReply`], delivering per-waiter clones of the
    /// same response to every attached follower (and recording it for
    /// the response cache to promote)
    fanout: Option<Arc<SharedReply>>,
}

impl ReplySlot {
    pub fn new(tx: Sender<Response>) -> ReplySlot {
        ReplySlot { tx, answered: Arc::new(AtomicBool::new(false)), fanout: None }
    }

    /// A slot whose winning send also settles `fanout` — how a coalescing
    /// leader's single reply reaches every attached follower.
    pub fn with_fanout(tx: Sender<Response>, fanout: Arc<SharedReply>) -> ReplySlot {
        ReplySlot { tx, answered: Arc::new(AtomicBool::new(false)), fanout: Some(fanout) }
    }

    /// Deliver the response if this slot (across all clones) has not
    /// answered yet. Returns `true` only for the winning call — callers
    /// use that to keep metrics accounting exactly-once too, so the
    /// return means "this was the answer", not "the client saw it": a
    /// disconnected client (dropped [`Ticket`]) still consumes the slot
    /// and still returns `true`, matching how the serving path has always
    /// counted answers regardless of delivery.
    pub fn send(&self, resp: Response) -> bool {
        if self.answered.swap(true, Ordering::AcqRel) {
            return false;
        }
        if let Some(fanout) = &self.fanout {
            fanout.settle(&resp);
        }
        let _ = self.tx.send(resp);
        true
    }

    /// Whether some clone of this slot already answered.
    pub fn is_answered(&self) -> bool {
        self.answered.load(Ordering::Acquire)
    }
}

/// Multi-waiter fan-out for one in-flight reply — the mechanism under
/// single-flight request coalescing (`coordinator::cache`).
///
/// One *leader* request executes; any number of *followers* [`attach`]
/// while it is in flight. The leader's [`ReplySlot::send`] settles this
/// object exactly once, delivering each follower a clone of the same
/// response stamped with the follower's own [`RequestId`]. Followers hold
/// ordinary [`Ticket`]s with **independent** `cancelled` flags, so a
/// follower cancelling or timing out never disturbs the leader (the flag
/// is simply not wired into the execution pipeline — coalesced cancel is
/// a no-op once attached, and the follower still receives the leader's
/// outcome, consistent with the cooperative-cancel contract: work that
/// completes anyway answers `Ok`).
///
/// The isolation is symmetric: when the *leader* is shed for its own
/// cancel or deadline, followers — who never cancelled and may hold
/// looser deadlines — are settled with a distinct retryable
/// [`ResponseStatus::Error`] ([`COALESCED_LEADER_CANCELLED`] /
/// [`COALESCED_LEADER_EXPIRED`]) instead of inheriting a
/// `Cancelled`/`Expired` they did not cause.
///
/// A leader whose submission fails to enqueue (post-registration shed,
/// channel closed at shutdown) [`abort`]s instead: every attached
/// follower is answered with a typed [`ResponseStatus::Error`], never
/// left hanging.
///
/// [`attach`]: SharedReply::attach
/// [`abort`]: SharedReply::abort
#[derive(Debug, Default)]
pub struct SharedReply {
    inner: Mutex<SharedInner>,
}

/// Typed error a coalesced follower receives when the leader's client
/// cancelled the flight: retryable, and distinct from the follower's own
/// [`ResponseStatus::Cancelled`] (which only its own [`Ticket::cancel`]
/// can cause).
pub const COALESCED_LEADER_CANCELLED: &str = "coalesced leader cancelled; retry";

/// Typed error a coalesced follower receives when the leader's (possibly
/// tighter) deadline expired before execution.
pub const COALESCED_LEADER_EXPIRED: &str = "coalesced leader deadline expired; retry";

#[derive(Debug, Default)]
struct SharedInner {
    waiters: Vec<(RequestId, Sender<Response>)>,
    /// the leader's response + when it settled (TTL anchor for the cache)
    settled: Option<(Response, Instant)>,
    aborted: Option<String>,
}

/// What [`SharedReply::attach`] found.
#[derive(Debug)]
pub enum AttachOutcome {
    /// Still in flight: the follower waits on this receiver.
    Attached(Receiver<Response>),
    /// Already settled with this response at this instant.
    Settled(Response, Instant),
    /// The leader never enqueued; the reason it was dropped.
    Aborted(String),
}

impl SharedReply {
    pub fn new() -> SharedReply {
        SharedReply::default()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, SharedInner> {
        // poison-recovering: a follower panicking mid-attach must not
        // strand every other waiter on this reply
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Register one follower (identified by its own fresh `id`), or
    /// report the already-settled/aborted outcome. Atomic with respect to
    /// [`settle`](SharedReply::settle): a follower either receives the
    /// response through its channel or sees it here — never neither.
    pub fn attach(&self, id: RequestId) -> AttachOutcome {
        let mut inner = self.lock();
        if let Some((resp, at)) = &inner.settled {
            return AttachOutcome::Settled(resp.clone(), *at);
        }
        if let Some(msg) = &inner.aborted {
            return AttachOutcome::Aborted(msg.clone());
        }
        let (tx, rx) = channel();
        inner.waiters.push((id, tx));
        AttachOutcome::Attached(rx)
    }

    /// Whether the leader is still in flight (not settled, not aborted).
    pub fn is_pending(&self) -> bool {
        let inner = self.lock();
        inner.settled.is_none() && inner.aborted.is_none()
    }

    /// The settled response, when there is one (cache promotion probe).
    pub fn settled(&self) -> Option<(Response, Instant)> {
        self.lock().settled.clone()
    }

    /// Deliver the leader's response to every attached follower (each
    /// clone re-stamped with the follower's own id) and record it.
    /// Idempotent; called by the winning [`ReplySlot::send`].
    ///
    /// A `Cancelled`/`Expired` settle is the *leader's* shed, not the
    /// followers': each follower gets a retryable typed error instead,
    /// so client code keying on [`ResponseStatus::Cancelled`] never
    /// misattributes someone else's cancel to itself. The recorded
    /// response keeps the leader's original status — it is non-`Ok`, so
    /// the cache drops the entry and the next identical submission
    /// re-executes.
    pub(crate) fn settle(&self, resp: &Response) {
        let mut inner = self.lock();
        if inner.settled.is_some() || inner.aborted.is_some() {
            return;
        }
        for (id, tx) in inner.waiters.drain(..) {
            let r = match &resp.status {
                ResponseStatus::Cancelled => Response::error(id, COALESCED_LEADER_CANCELLED),
                ResponseStatus::Expired => Response::error(id, COALESCED_LEADER_EXPIRED),
                _ => {
                    let mut r = resp.clone();
                    r.id = id;
                    r
                }
            };
            let _ = tx.send(r);
        }
        inner.settled = Some((resp.clone(), Instant::now()));
    }

    /// The leader's submission never enqueued: answer every attached
    /// follower with a typed error so no coalesced ticket hangs.
    pub(crate) fn abort(&self, msg: &str) {
        let mut inner = self.lock();
        if inner.settled.is_some() || inner.aborted.is_some() {
            return;
        }
        for (id, tx) in inner.waiters.drain(..) {
            let _ = tx.send(Response::error(id, msg));
        }
        inner.aborted = Some(msg.to_string());
    }
}

impl Request {
    /// If this request should be shed (cancelled by the client, or past
    /// its deadline at `now`), the response to answer it with.
    /// Cancellation wins over expiry: it is explicit client intent.
    pub fn shed_response(&self, now: Instant) -> Option<Response> {
        if self.cancelled.load(Ordering::Acquire) {
            return Some(Response::cancelled(self.id));
        }
        if self.deadline.map_or(false, |d| now >= d) {
            return Some(Response::expired(self.id));
        }
        None
    }
}

/// Typed request outcome — replaces the `ok: bool` + `Option<String>`
/// pair, so expiry and cancellation are first-class results rather than
/// stringly-typed errors.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ResponseStatus {
    Ok,
    /// Routing/backend/payload failure, with the reason.
    Error(String),
    /// Shed before execution: the deadline elapsed while queued.
    Expired,
    /// Shed before execution: the client cancelled the [`Ticket`].
    Cancelled,
}

impl ResponseStatus {
    pub fn is_ok(&self) -> bool {
        matches!(self, ResponseStatus::Ok)
    }
}

/// The answer: typed output tensors plus serving telemetry.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    /// one sample-shaped value per model output
    pub outputs: Vec<Value>,
    /// which artifact variant served it (e.g. "bert_tiny_s8_b8"); shared
    /// across every response demuxed from the same placement
    pub served_by: Arc<str>,
    /// batch capacity it rode in
    pub batch_size: usize,
    /// end-to-end latency
    pub latency_us: u64,
    /// time spent queued before execution started
    pub queue_us: u64,
    pub status: ResponseStatus,
}

impl Response {
    fn unserved(id: RequestId, status: ResponseStatus) -> Response {
        Response {
            id,
            outputs: Vec::new(),
            served_by: Arc::from(""),
            batch_size: 0,
            latency_us: 0,
            queue_us: 0,
            status,
        }
    }

    pub fn error(id: RequestId, msg: impl Into<String>) -> Response {
        Response::unserved(id, ResponseStatus::Error(msg.into()))
    }

    /// Deadline elapsed before execution; no backend work was done.
    pub fn expired(id: RequestId) -> Response {
        Response::unserved(id, ResponseStatus::Expired)
    }

    /// Client cancelled before execution; no backend work was done.
    pub fn cancelled(id: RequestId) -> Response {
        Response::unserved(id, ResponseStatus::Cancelled)
    }

    pub fn is_ok(&self) -> bool {
        self.status.is_ok()
    }

    /// The error message, when `status` is [`ResponseStatus::Error`].
    pub fn error_message(&self) -> Option<&str> {
        match &self.status {
            ResponseStatus::Error(msg) => Some(msg),
            _ => None,
        }
    }

    /// The first f32 output — the classifier-logits convenience accessor
    /// (empty when the request failed or the model emits no f32 tensor).
    pub fn logits(&self) -> &[f32] {
        self.outputs
            .iter()
            .find_map(|v| v.as_f32())
            .unwrap_or(&[])
    }
}

/// Client-side handle for one submitted request — the v2 replacement for
/// the raw `(RequestId, Receiver<Response>)` tuple.
///
/// Exactly one [`Response`] is ever delivered per ticket, so
/// [`wait`](Ticket::wait) after a racing [`cancel`](Ticket::cancel) still
/// returns a single coherent outcome: either the completed response (the
/// cancel lost the race and the work was already done) or
/// [`ResponseStatus::Cancelled`].
///
/// **Own-deadline enforcement.** A ticket minted from a submission with
/// [`SubmitOptions::deadline`] carries that absolute deadline
/// ([`with_deadline`](Ticket::with_deadline)); [`wait`](Ticket::wait) and
/// [`wait_timeout`](Ticket::wait_timeout) then return a *typed*
/// [`Expired`](ResponseStatus::Expired) (or
/// [`Cancelled`](ResponseStatus::Cancelled), since cancel wins over
/// expiry everywhere in this stack) response at that deadline instead of
/// blocking on the server's timeline. This is what gives a coalesced
/// follower — whose server-side answer arrives on the *leader's*
/// schedule — its own deadline back. Data wins ties: a response already
/// delivered is returned even if the deadline has since passed. After a
/// deadline-synthesized return the ticket counts as answered; a late
/// server reply into the channel is dropped with the ticket.
#[derive(Debug)]
pub struct Ticket {
    id: RequestId,
    priority: Priority,
    rx: Receiver<Response>,
    cancelled: Arc<AtomicBool>,
    /// Absolute client-side deadline; `None` waits on the server alone.
    deadline: Option<Instant>,
}

impl Ticket {
    pub(crate) fn new(
        id: RequestId,
        priority: Priority,
        rx: Receiver<Response>,
        cancelled: Arc<AtomicBool>,
    ) -> Ticket {
        Ticket { id, priority, rx, cancelled, deadline: None }
    }

    /// Attach the submission's absolute deadline (builder-style; used by
    /// every ticket-minting path that has one).
    pub(crate) fn with_deadline(mut self, deadline: Option<Instant>) -> Ticket {
        self.deadline = deadline;
        self
    }

    /// The typed shed response synthesized when this ticket's own
    /// deadline fires before the server answers. Cancel wins over expiry
    /// (same precedence as the server-side pre-execution shed).
    fn deadline_shed(&self) -> Response {
        if self.is_cancelled() {
            Response::cancelled(self.id)
        } else {
            Response::expired(self.id)
        }
    }

    pub fn id(&self) -> RequestId {
        self.id
    }

    pub fn priority(&self) -> Priority {
        self.priority
    }

    /// Ask the server to drop this request before execution. Purely
    /// cooperative: the batcher checks the flag at batch formation and
    /// the worker re-checks it just before execution; work already
    /// executing completes normally. Always safe to call (idempotent,
    /// any time, from the thread holding the ticket).
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Acquire)
    }

    /// Block until the response arrives — or until this ticket's own
    /// deadline, which returns a typed [`Expired`](ResponseStatus::Expired)
    /// response (see the type docs). Errors only if the server was torn
    /// down without answering (a bug or a mid-shutdown submit).
    pub fn wait(&self) -> anyhow::Result<Response> {
        use std::sync::mpsc::TryRecvError;
        let Some(deadline) = self.deadline else {
            return self.rx.recv().map_err(|_| {
                anyhow::anyhow!("server dropped request {:?} without replying", self.id)
            });
        };
        loop {
            let now = Instant::now();
            if now >= deadline {
                // data wins: an answer already delivered beats the shed
                return match self.rx.try_recv() {
                    Ok(r) => Ok(r),
                    Err(TryRecvError::Empty) => Ok(self.deadline_shed()),
                    Err(TryRecvError::Disconnected) => Err(anyhow::anyhow!(
                        "server dropped request {:?} without replying",
                        self.id
                    )),
                };
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(r) => return Ok(r),
                Err(RecvTimeoutError::Timeout) => continue, // re-check at the deadline
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(anyhow::anyhow!(
                        "server dropped request {:?} without replying",
                        self.id
                    ))
                }
            }
        }
    }

    /// Like [`wait`](Ticket::wait), additionally bounded by `timeout`.
    /// The ticket's own deadline still applies: whichever bound fires
    /// first decides the outcome — the deadline yields the typed
    /// [`Expired`](ResponseStatus::Expired) response, the caller's
    /// timeout stays an error (the request may yet be answered).
    pub fn wait_timeout(&self, timeout: Duration) -> anyhow::Result<Response> {
        let limit = Instant::now() + timeout;
        if let Some(deadline) = self.deadline {
            if deadline <= limit {
                return self.wait(); // own-deadline bound is the tighter one
            }
        }
        self.rx.recv_timeout(timeout).map_err(|e| match e {
            RecvTimeoutError::Timeout => {
                anyhow::anyhow!("request {:?}: no response within {timeout:?}", self.id)
            }
            RecvTimeoutError::Disconnected => {
                anyhow::anyhow!("server dropped request {:?} without replying", self.id)
            }
        })
    }

    /// Non-blocking probe: the response if it already arrived.
    pub fn try_poll(&self) -> Option<Response> {
        self.rx.try_recv().ok()
    }

    /// Non-blocking probe that distinguishes *pending* from *abandoned*:
    /// `Ok(Some(r))` — the response arrived; `Ok(None)` — still in
    /// flight; `Err(_)` — the server was torn down without replying, so
    /// no response will ever come. Pollers that must terminate (the net
    /// reply pump draining a connection) need the third case;
    /// [`try_poll`](Ticket::try_poll) folds it into `None` and would spin
    /// forever.
    pub fn try_take(&self) -> anyhow::Result<Option<Response>> {
        use std::sync::mpsc::TryRecvError;
        match self.rx.try_recv() {
            Ok(r) => Ok(Some(r)),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(anyhow::anyhow!(
                "server dropped request {:?} without replying",
                self.id
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn error_response_is_marked_and_empty() {
        let r = Response::error(RequestId(7), "nope");
        assert!(!r.is_ok());
        assert_eq!(r.id, RequestId(7));
        assert!(r.outputs.is_empty());
        assert!(r.logits().is_empty());
        assert_eq!(r.error_message(), Some("nope"));
    }

    #[test]
    fn shed_constructors_are_typed() {
        assert_eq!(Response::expired(RequestId(1)).status, ResponseStatus::Expired);
        assert_eq!(Response::cancelled(RequestId(2)).status, ResponseStatus::Cancelled);
        assert_eq!(Response::expired(RequestId(1)).error_message(), None);
    }

    #[test]
    fn logits_finds_first_f32_output() {
        let mut r = Response::error(RequestId(1), "x");
        r.outputs = vec![Value::I32(vec![3]), Value::F32(vec![0.25, 0.75])];
        assert_eq!(r.logits(), &[0.25, 0.75]);
    }

    #[test]
    fn priority_orders_by_scheduling_urgency() {
        assert!(Priority::Interactive < Priority::Standard);
        assert!(Priority::Standard < Priority::Bulk);
        assert_eq!(Priority::default(), Priority::Standard);
        for (i, p) in Priority::ALL.iter().enumerate() {
            assert_eq!(p.idx(), i);
            assert_eq!(Priority::parse(p.as_str()).unwrap(), *p);
        }
        assert!(Priority::parse("urgent").is_err());
    }

    #[test]
    fn submit_options_builders() {
        let o = SubmitOptions::default();
        assert_eq!(o.priority, Priority::Standard);
        assert!(o.deadline.is_none() && o.client_tag.is_none());
        let o = SubmitOptions::interactive()
            .with_deadline(Duration::from_millis(5))
            .with_client_tag("cam-7");
        assert_eq!(o.priority, Priority::Interactive);
        assert_eq!(o.deadline, Some(Duration::from_millis(5)));
        assert_eq!(o.client_tag.as_deref(), Some("cam-7"));
        assert_eq!(SubmitOptions::bulk().priority, Priority::Bulk);
    }

    fn request(deadline: Option<Duration>) -> (Request, Receiver<Response>, Arc<AtomicBool>) {
        let (tx, rx) = channel();
        let now = Instant::now();
        let cancelled = Arc::new(AtomicBool::new(false));
        let r = Request {
            id: RequestId(1),
            model: Arc::from("m"),
            inputs: Vec::new(),
            submitted: now,
            priority: Priority::Standard,
            deadline: deadline.map(|d| now + d),
            cancelled: cancelled.clone(),
            client_tag: None,
            reply: ReplySlot::new(tx),
        };
        (r, rx, cancelled)
    }

    #[test]
    fn shed_response_checks_cancel_then_deadline() {
        let (r, _rx, cancelled) = request(None);
        assert!(r.shed_response(Instant::now()).is_none());
        cancelled.store(true, Ordering::Release);
        assert_eq!(r.shed_response(Instant::now()).unwrap().status, ResponseStatus::Cancelled);

        let (r, _rx, _) = request(Some(Duration::ZERO));
        let late = Instant::now() + Duration::from_millis(1);
        assert_eq!(r.shed_response(late).unwrap().status, ResponseStatus::Expired);

        let (r, _rx, _) = request(Some(Duration::from_secs(60)));
        assert!(r.shed_response(Instant::now()).is_none());
    }

    #[test]
    fn ticket_cancel_and_poll() {
        let (tx, rx) = channel();
        let cancelled = Arc::new(AtomicBool::new(false));
        let t = Ticket::new(RequestId(9), Priority::Interactive, rx, cancelled.clone());
        assert_eq!(t.id(), RequestId(9));
        assert_eq!(t.priority(), Priority::Interactive);
        assert!(t.try_poll().is_none());
        assert!(!t.is_cancelled());
        t.cancel();
        t.cancel(); // idempotent
        assert!(cancelled.load(Ordering::Acquire));
        tx.send(Response::cancelled(RequestId(9))).unwrap();
        assert_eq!(t.try_poll().unwrap().status, ResponseStatus::Cancelled);
        // exactly one response per ticket
        assert!(t.try_poll().is_none());
        drop(tx);
        assert!(t.wait_timeout(Duration::from_millis(10)).is_err());
        assert!(t.wait().is_err());
    }

    #[test]
    fn reply_slot_is_exactly_once_across_clones() {
        let (tx, rx) = channel();
        let slot = ReplySlot::new(tx);
        let fence_copy = slot.clone();
        assert!(!slot.is_answered());
        assert!(slot.send(Response::error(RequestId(1), "real answer")));
        assert!(slot.is_answered() && fence_copy.is_answered());
        // the fence's late defensive answer is a no-op, not a double reply
        assert!(!fence_copy.send(Response::error(RequestId(1), "fence answer")));
        assert_eq!(rx.recv().unwrap().error_message(), Some("real answer"));
        assert!(rx.try_recv().is_err(), "exactly one response delivered");
    }

    #[test]
    fn reply_slot_disconnected_client_still_counts_as_the_answer() {
        let (tx, rx) = channel();
        let slot = ReplySlot::new(tx);
        drop(rx); // client dropped its Ticket
        assert!(
            slot.send(Response::expired(RequestId(2))),
            "winning call answers (and is counted) even if nobody is listening"
        );
        assert!(slot.is_answered());
        assert!(!slot.send(Response::expired(RequestId(2))), "slot consumed");
    }

    #[test]
    fn shared_reply_settle_fans_out_with_per_waiter_ids() {
        let sr = Arc::new(SharedReply::new());
        assert!(sr.is_pending());
        let rx_a = match sr.attach(RequestId(10)) {
            AttachOutcome::Attached(rx) => rx,
            other => panic!("expected Attached, got {other:?}"),
        };
        let rx_b = match sr.attach(RequestId(11)) {
            AttachOutcome::Attached(rx) => rx,
            other => panic!("expected Attached, got {other:?}"),
        };
        let mut leader = Response::error(RequestId(1), "x");
        leader.status = ResponseStatus::Ok;
        leader.outputs = vec![Value::F32(vec![0.5, -0.5])];
        sr.settle(&leader);
        sr.settle(&leader); // idempotent
        assert!(!sr.is_pending());
        let a = rx_a.recv().unwrap();
        let b = rx_b.recv().unwrap();
        assert_eq!(a.id, RequestId(10), "follower keeps its own id");
        assert_eq!(b.id, RequestId(11));
        assert_eq!(a.logits(), leader.logits());
        assert_eq!(b.logits(), leader.logits());
        assert!(rx_a.try_recv().is_err(), "exactly one response per follower");
        let (resp, _at) = sr.settled().unwrap();
        assert_eq!(resp.id, RequestId(1), "recorded response keeps the leader id");
    }

    #[test]
    fn shared_reply_attach_after_settle_sees_the_response() {
        let sr = SharedReply::new();
        let mut leader = Response::error(RequestId(1), "x");
        leader.status = ResponseStatus::Ok;
        sr.settle(&leader);
        match sr.attach(RequestId(2)) {
            AttachOutcome::Settled(resp, _at) => assert!(resp.is_ok()),
            other => panic!("expected Settled, got {other:?}"),
        }
    }

    #[test]
    fn shared_reply_abort_answers_every_follower_typed() {
        let sr = SharedReply::new();
        let rx = match sr.attach(RequestId(5)) {
            AttachOutcome::Attached(rx) => rx,
            other => panic!("expected Attached, got {other:?}"),
        };
        sr.abort("request was not enqueued");
        let r = rx.recv().unwrap();
        assert_eq!(r.id, RequestId(5));
        assert_eq!(r.error_message(), Some("request was not enqueued"));
        match sr.attach(RequestId(6)) {
            AttachOutcome::Aborted(msg) => assert_eq!(msg, "request was not enqueued"),
            other => panic!("expected Aborted, got {other:?}"),
        }
        // abort after abort, settle after abort: both no-ops
        sr.abort("second");
        sr.settle(&Response::error(RequestId(1), "late"));
        assert!(sr.settled().is_none());
    }

    #[test]
    fn shared_reply_translates_leader_shed_into_retryable_errors() {
        // leader cancelled: the follower never cancelled, so it must not
        // see Cancelled — it gets the retryable typed error instead
        let sr = SharedReply::new();
        let rx = match sr.attach(RequestId(30)) {
            AttachOutcome::Attached(rx) => rx,
            other => panic!("expected Attached, got {other:?}"),
        };
        sr.settle(&Response::cancelled(RequestId(29)));
        let r = rx.recv().unwrap();
        assert_eq!(r.id, RequestId(30));
        assert_eq!(r.error_message(), Some(COALESCED_LEADER_CANCELLED));
        // the record keeps the leader's own status (non-Ok, so the cache
        // drops it and never replays the shed)
        let (resp, _at) = sr.settled().unwrap();
        assert_eq!(resp.status, ResponseStatus::Cancelled);

        // leader deadline expired: same translation, distinct message
        let sr = SharedReply::new();
        let rx = match sr.attach(RequestId(31)) {
            AttachOutcome::Attached(rx) => rx,
            other => panic!("expected Attached, got {other:?}"),
        };
        sr.settle(&Response::expired(RequestId(29)));
        assert_eq!(rx.recv().unwrap().error_message(), Some(COALESCED_LEADER_EXPIRED));
    }

    #[test]
    fn reply_slot_with_fanout_settles_followers_exactly_once() {
        let sr = Arc::new(SharedReply::new());
        let follower = match sr.attach(RequestId(21)) {
            AttachOutcome::Attached(rx) => rx,
            other => panic!("expected Attached, got {other:?}"),
        };
        let (tx, rx) = channel();
        let slot = ReplySlot::with_fanout(tx, sr.clone());
        let fence = slot.clone();
        let mut resp = Response::error(RequestId(20), "x");
        resp.status = ResponseStatus::Ok;
        assert!(slot.send(resp.clone()));
        assert!(!fence.send(Response::error(RequestId(20), "fence")), "still exactly-once");
        assert!(rx.recv().unwrap().is_ok(), "leader got the real answer");
        let f = follower.recv().unwrap();
        assert!(f.is_ok());
        assert_eq!(f.id, RequestId(21));
        assert!(sr.settled().is_some(), "cache can promote the settled response");
    }

    #[test]
    fn try_take_distinguishes_pending_from_abandoned() {
        let (tx, rx) = channel();
        let t = Ticket::new(
            RequestId(3),
            Priority::Standard,
            rx,
            Arc::new(AtomicBool::new(false)),
        );
        assert!(t.try_take().unwrap().is_none(), "pending is Ok(None)");
        tx.send(Response::expired(RequestId(3))).unwrap();
        assert_eq!(t.try_take().unwrap().unwrap().status, ResponseStatus::Expired);
        drop(tx);
        assert!(t.try_take().is_err(), "abandoned is Err, not a silent None");
    }

    fn deadline_ticket(id: u64, deadline: Duration) -> (Sender<Response>, Ticket) {
        let (tx, rx) = channel();
        let t = Ticket::new(RequestId(id), Priority::Standard, rx, Arc::new(AtomicBool::new(false)))
            .with_deadline(Some(Instant::now() + deadline));
        (tx, t)
    }

    #[test]
    fn wait_enforces_the_tickets_own_deadline_with_a_typed_expiry() {
        // no server answer ever: wait() must return Expired AT the
        // ticket's own deadline, not hang on the (absent) server timeline
        let (_tx, t) = deadline_ticket(40, Duration::from_millis(20));
        let start = Instant::now();
        let r = t.wait().unwrap();
        assert_eq!(r.status, ResponseStatus::Expired);
        assert_eq!(r.id, RequestId(40), "shed keeps the ticket's own id");
        let waited = start.elapsed();
        assert!(waited >= Duration::from_millis(15), "fired early: {waited:?}");
        assert!(waited < Duration::from_secs(5), "did not hang: {waited:?}");
        // an undeadlined ticket is untouched: wait_timeout still errors
        let (_tx2, rx) = channel::<Response>();
        let plain =
            Ticket::new(RequestId(41), Priority::Standard, rx, Arc::new(AtomicBool::new(false)));
        assert!(plain.wait_timeout(Duration::from_millis(5)).is_err());
    }

    #[test]
    fn data_wins_over_an_elapsed_deadline() {
        let (tx, t) = deadline_ticket(42, Duration::from_millis(1));
        tx.send(Response::error(RequestId(42), "real")).unwrap();
        std::thread::sleep(Duration::from_millis(5)); // deadline passes
        let r = t.wait().unwrap();
        assert_eq!(r.error_message(), Some("real"), "delivered answer beats the shed");
    }

    #[test]
    fn cancel_wins_over_own_deadline_expiry() {
        let (_tx, t) = deadline_ticket(43, Duration::from_millis(5));
        t.cancel();
        let r = t.wait().unwrap();
        assert_eq!(r.status, ResponseStatus::Cancelled, "cancel beats expiry, as everywhere");
    }

    #[test]
    fn wait_timeout_picks_the_tighter_bound() {
        // deadline tighter than the caller's timeout → typed Expired
        let (_tx, t) = deadline_ticket(44, Duration::from_millis(10));
        let r = t.wait_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(r.status, ResponseStatus::Expired);
        // caller's timeout tighter than the deadline → plain timeout
        // error (the request may still be answered later)
        let (_tx, t) = deadline_ticket(45, Duration::from_secs(30));
        assert!(t.wait_timeout(Duration::from_millis(5)).is_err());
    }

    #[test]
    fn deadlined_wait_still_errors_on_a_dropped_server() {
        let (tx, t) = deadline_ticket(46, Duration::from_secs(30));
        drop(tx);
        assert!(t.wait().is_err(), "torn-down server is an error, not an expiry");
    }
}
