//! Request/response types on the serving hot path.

use std::sync::mpsc::Sender;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

/// One inference request: a token sequence for a named model.
#[derive(Debug)]
pub struct Request {
    pub id: RequestId,
    pub model: String,
    /// token ids, length = the model's sequence length (router pads/rejects)
    pub tokens: Vec<i32>,
    pub submitted: Instant,
    /// where the response goes (per-client channel)
    pub reply: Sender<Response>,
}

/// The answer: classifier logits plus serving telemetry.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    pub logits: Vec<f32>,
    /// which artifact variant served it (e.g. "bert_tiny_s8_b8")
    pub served_by: String,
    /// batch size it rode in
    pub batch_size: usize,
    /// end-to-end latency
    pub latency_us: u64,
    /// time spent queued before execution started
    pub queue_us: u64,
    pub ok: bool,
    pub error: Option<String>,
}

impl Response {
    pub fn error(id: RequestId, msg: impl Into<String>) -> Response {
        Response {
            id,
            logits: Vec::new(),
            served_by: String::new(),
            batch_size: 0,
            latency_us: 0,
            queue_us: 0,
            ok: false,
            error: Some(msg.into()),
        }
    }
}
