//! The serving loop: batcher thread + worker pool over a [`Backend`].
//!
//! Wire-up (std threads, no async runtime in this environment):
//! * clients send [`Request`]s through [`ServerHandle::submit`] (admission
//!   happens there);
//! * one batcher thread forms [`Batch`]es;
//! * `workers` threads pull batches from a shared channel, ask the
//!   [`Router`] for placements, run them on the [`Backend`], and reply.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::admission::{Admission, AdmissionDecision};
use super::batcher::{Batch, BatcherConfig, DynamicBatcher};
use super::metrics::Metrics;
use super::request::{Request, RequestId, Response};
use super::router::Router;
use crate::runtime::manifest::Manifest;

/// Executes one planned placement. Implementations: PJRT (examples — owns
/// the compiled executables), simulator (tests/benches), echo (unit tests).
pub trait Backend: Send + Sync + 'static {
    /// Run `artifact` with a token matrix of `capacity × seq` (already
    /// padded); return per-sample logits (len = capacity × classes).
    fn run(
        &self,
        artifact: &str,
        capacity: usize,
        tokens: &[i32],
    ) -> anyhow::Result<Vec<f32>>;

    /// Sequence length the artifact expects (for padding).
    fn seq_len(&self, artifact: &str) -> usize;

    /// Classes per sample in the output.
    fn classes(&self, artifact: &str) -> usize;
}

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
    pub workers: usize,
    pub max_inflight: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batcher: BatcherConfig::default(),
            workers: 2,
            max_inflight: 256,
        }
    }
}

/// Running server; call [`shutdown`](Server::shutdown) to stop cleanly.
pub struct Server {
    handle: ServerHandle,
    threads: Vec<JoinHandle<()>>,
    stop: Arc<std::sync::atomic::AtomicBool>,
}

/// Cheap-to-clone submission handle.
#[derive(Clone)]
pub struct ServerHandle {
    tx: Sender<Request>,
    admission: Arc<Admission>,
    pub metrics: Arc<Metrics>,
    next_id: Arc<std::sync::atomic::AtomicU64>,
}

impl ServerHandle {
    /// Submit a request; returns the receiver for its response, or an
    /// immediate rejection.
    pub fn submit(
        &self,
        model: &str,
        tokens: Vec<i32>,
    ) -> Result<(RequestId, Receiver<Response>), AdmissionDecision> {
        match self.admission.try_admit() {
            AdmissionDecision::Admit => {}
            other => {
                self.metrics
                    .rejected
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                return Err(other);
            }
        }
        self.metrics
            .admitted
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let id = RequestId(
            self.next_id
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed),
        );
        let (rtx, rrx) = channel();
        let req = Request {
            id,
            model: model.to_string(),
            tokens,
            submitted: Instant::now(),
            reply: rtx,
        };
        // channel send can only fail after shutdown; surface as queue-full
        if self.tx.send(req).is_err() {
            self.admission.complete();
            return Err(AdmissionDecision::RejectQueueFull);
        }
        Ok((id, rrx))
    }
}

impl Server {
    /// Start batcher + workers.
    pub fn start(
        cfg: ServerConfig,
        manifest: Manifest,
        router: Router,
        backend: Arc<dyn Backend>,
    ) -> Server {
        let (req_tx, req_rx) = channel::<Request>();
        let (batch_tx, batch_rx) = channel::<Batch>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let metrics = Arc::new(Metrics::new());
        let admission = Arc::new(Admission::depth_only(cfg.max_inflight));

        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut threads = Vec::new();
        // batcher thread
        {
            let bcfg = cfg.batcher;
            let stop = stop.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("s4-batcher".into())
                    .spawn(move || {
                        let mut b = DynamicBatcher::with_stop(bcfg, req_rx, stop);
                        while let Some(batch) = b.next_batch() {
                            if batch_tx.send(batch).is_err() {
                                break;
                            }
                        }
                    })
                    .expect("spawn batcher"),
            );
        }
        // workers
        let manifest = Arc::new(manifest);
        let router = Arc::new(router);
        for w in 0..cfg.workers.max(1) {
            let batch_rx = batch_rx.clone();
            let backend = backend.clone();
            let manifest = manifest.clone();
            let router = router.clone();
            let metrics = metrics.clone();
            let admission = admission.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("s4-worker{w}"))
                    .spawn(move || {
                        loop {
                            let batch = {
                                let rx = batch_rx.lock().unwrap();
                                rx.recv()
                            };
                            let Ok(batch) = batch else { break };
                            serve_batch(&batch, &manifest, &router, &*backend, &metrics);
                            for _ in 0..batch.len() {
                                admission.complete();
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
        }

        Server {
            handle: ServerHandle {
                tx: req_tx,
                admission,
                metrics,
                next_id: Arc::new(std::sync::atomic::AtomicU64::new(1)),
            },
            threads,
            stop,
        }
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// Shut down: signal the batcher (which drains queued work), then join
    /// all threads. Safe even while cloned handles are still alive.
    pub fn shutdown(self) {
        let Server { handle, threads, stop } = self;
        stop.store(true, std::sync::atomic::Ordering::Release);
        drop(handle);
        for t in threads {
            let _ = t.join();
        }
    }
}

/// Execute one formed batch: plan placements, pad, run, demux responses.
fn serve_batch(
    batch: &Batch,
    manifest: &Manifest,
    router: &Router,
    backend: &dyn Backend,
    metrics: &Metrics,
) {
    let placements = match router.plan(manifest, &batch.model, batch.len()) {
        Ok(p) => p,
        Err(e) => {
            for r in &batch.requests {
                metrics.failed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let _ = r.reply.send(Response::error(r.id, format!("routing: {e}")));
            }
            return;
        }
    };
    let mut cursor = 0usize;
    for p in placements {
        let reqs = &batch.requests[cursor..cursor + p.fill];
        cursor += p.fill;
        metrics.record_batch(p.fill, p.batch_capacity);
        let seq = backend.seq_len(&p.artifact);
        let classes = backend.classes(&p.artifact);
        // pack + pad tokens (pad slots repeat the last real sample so the
        // executable always sees valid token ids)
        let mut tokens = Vec::with_capacity(p.batch_capacity * seq);
        for r in reqs {
            let mut t = r.tokens.clone();
            t.resize(seq, 0);
            tokens.extend_from_slice(&t[..seq]);
        }
        for _ in reqs.len()..p.batch_capacity {
            let start = (reqs.len() - 1) * seq;
            let last: Vec<i32> = tokens[start..start + seq].to_vec();
            tokens.extend_from_slice(&last);
        }
        let exec_start = Instant::now();
        match backend.run(&p.artifact, p.batch_capacity, &tokens) {
            Ok(logits) => {
                for (i, r) in reqs.iter().enumerate() {
                    let latency = r.submitted.elapsed();
                    let queue = batch
                        .formed_at
                        .saturating_duration_since(r.submitted)
                        + exec_start.saturating_duration_since(batch.formed_at);
                    metrics.record_completion(
                        latency.as_micros() as u64,
                        queue.as_micros() as u64,
                    );
                    let _ = r.reply.send(Response {
                        id: r.id,
                        logits: logits[i * classes..(i + 1) * classes].to_vec(),
                        served_by: p.artifact.clone(),
                        batch_size: p.batch_capacity,
                        latency_us: latency.as_micros() as u64,
                        queue_us: queue.as_micros() as u64,
                        ok: true,
                        error: None,
                    });
                }
            }
            Err(e) => {
                for r in reqs {
                    metrics.failed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    let _ = r.reply.send(Response::error(r.id, format!("backend: {e}")));
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------

/// Simulator-paced backend: deterministic logits, service time from the
/// analytic cost model (scaled down so tests run fast). Lets the full
/// serving stack be exercised and benchmarked without PJRT artifacts.
pub struct SimBackend {
    /// (artifact name, batch, seq, classes, service time)
    specs: Vec<(String, usize, usize, usize, Duration)>,
}

impl SimBackend {
    pub fn from_manifest(m: &Manifest, time_scale: f64) -> SimBackend {
        use crate::arch::AntoumConfig;
        use crate::graph::models;
        use crate::sim::{simulate, Target};
        let cfg = AntoumConfig::s4();
        let specs = m
            .artifacts
            .iter()
            .map(|a| {
                let g = models::by_name(&a.model, a.batch.max(1))
                    .unwrap_or_else(|_| models::bert(models::BERT_TINY, a.batch.max(1), 128));
                let r = simulate(&g, Target::antoum(&cfg, a.sparsity.max(1)));
                let secs = (r.latency_ms / 1e3 * time_scale).max(1e-6);
                let classes = a.outputs.first().map(|o| o.shape[1]).unwrap_or(2);
                (a.name.clone(), a.batch, a.seq.max(1), classes, Duration::from_secs_f64(secs))
            })
            .collect();
        SimBackend { specs }
    }

    fn spec(&self, artifact: &str) -> &(String, usize, usize, usize, Duration) {
        self.specs
            .iter()
            .find(|s| s.0 == artifact)
            .unwrap_or_else(|| panic!("SimBackend: unknown artifact {artifact}"))
    }
}

impl Backend for SimBackend {
    fn run(&self, artifact: &str, capacity: usize, tokens: &[i32]) -> anyhow::Result<Vec<f32>> {
        let (_, _, seq, classes, dt) = self.spec(artifact).clone();
        anyhow::ensure!(tokens.len() == capacity * seq, "token shape");
        std::thread::sleep(dt);
        // deterministic pseudo-logits: hash of each sample's tokens
        let mut out = Vec::with_capacity(capacity * classes);
        for b in 0..capacity {
            let h = tokens[b * seq..(b + 1) * seq]
                .iter()
                .fold(0u64, |acc, &t| acc.wrapping_mul(31).wrapping_add(t as u64));
            for c in 0..classes {
                out.push(((h >> (c % 16)) & 0xff) as f32 / 255.0);
            }
        }
        Ok(out)
    }

    fn seq_len(&self, artifact: &str) -> usize {
        self.spec(artifact).2
    }

    fn classes(&self, artifact: &str) -> usize {
        self.spec(artifact).3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn manifest() -> Manifest {
        let text = r#"{"artifacts": [
          {"name": "bert_tiny_s8_b1", "file": "x", "family": "bert",
           "model": "bert_tiny", "sparsity": 8, "batch": 1, "seq": 16,
           "inputs": [{"name": "ids", "shape": [1, 16], "dtype": "s32"}],
           "outputs": [{"shape": [1, 2], "dtype": "f32"}]},
          {"name": "bert_tiny_s8_b8", "file": "y", "family": "bert",
           "model": "bert_tiny", "sparsity": 8, "batch": 8, "seq": 16,
           "inputs": [{"name": "ids", "shape": [8, 16], "dtype": "s32"}],
           "outputs": [{"shape": [8, 2], "dtype": "f32"}]}
        ]}"#;
        Manifest::parse(Path::new("/tmp"), text).unwrap()
    }

    /// Echo backend: instant, logits = [first token, batch size].
    struct Echo;
    impl Backend for Echo {
        fn run(&self, _a: &str, capacity: usize, tokens: &[i32]) -> anyhow::Result<Vec<f32>> {
            let seq = tokens.len() / capacity;
            Ok((0..capacity)
                .flat_map(|b| [tokens[b * seq] as f32, capacity as f32])
                .collect())
        }
        fn seq_len(&self, _a: &str) -> usize {
            16
        }
        fn classes(&self, _a: &str) -> usize {
            2
        }
    }

    #[test]
    fn end_to_end_single_request() {
        let srv = Server::start(
            ServerConfig {
                batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
                workers: 1,
                max_inflight: 16,
            },
            manifest(),
            Router::new(crate::coordinator::RoutingPolicy::MaxSparsity),
            Arc::new(Echo),
        );
        let h = srv.handle();
        let (_, rx) = h.submit("bert_tiny", vec![42; 16]).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.logits[0], 42.0);
        srv.shutdown();
    }

    #[test]
    fn batches_fill_under_load() {
        let srv = Server::start(
            ServerConfig {
                batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(20) },
                workers: 1,
                max_inflight: 64,
            },
            manifest(),
            Router::new(crate::coordinator::RoutingPolicy::MaxSparsity),
            Arc::new(Echo),
        );
        let h = srv.handle();
        let rxs: Vec<_> = (0..16)
            .map(|i| h.submit("bert_tiny", vec![i; 16]).unwrap().1)
            .collect();
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert!(r.ok);
        }
        // under instant backend + 20ms window, the 16 requests should ride
        // few batches with strong fill
        assert!(h.metrics.mean_batch_fill() >= 2.0, "{}", h.metrics.report());
        srv.shutdown();
    }

    #[test]
    fn unknown_model_errors_cleanly() {
        let srv = Server::start(
            ServerConfig::default(),
            manifest(),
            Router::new(crate::coordinator::RoutingPolicy::MaxSparsity),
            Arc::new(Echo),
        );
        let h = srv.handle();
        let (_, rx) = h.submit("nonexistent", vec![1; 16]).unwrap();
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(!r.ok);
        assert!(r.error.unwrap().contains("routing"));
        srv.shutdown();
    }

    #[test]
    fn admission_rejects_over_capacity() {
        // max_inflight 1 with a slow-ish path: second submit may reject
        let srv = Server::start(
            ServerConfig {
                batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(50) },
                workers: 1,
                max_inflight: 1,
            },
            manifest(),
            Router::new(crate::coordinator::RoutingPolicy::MaxSparsity),
            Arc::new(Echo),
        );
        let h = srv.handle();
        let (_, _rx1) = h.submit("bert_tiny", vec![1; 16]).unwrap();
        // immediately after, capacity is full until the worker drains it
        let second = h.submit("bert_tiny", vec![2; 16]);
        if let Err(d) = second {
            assert_eq!(d, AdmissionDecision::RejectQueueFull);
        }
        srv.shutdown();
    }
}
