//! The serving loop: batcher thread + worker pool over an
//! [`InferenceBackend`], fronted by the [`ServingService`] submission
//! surface.
//!
//! Wire-up (std threads, no async runtime in this environment):
//! * clients submit through [`ServingService::submit_with`], which runs
//!   the staged [`ingress`](super::ingress) chain (optional response
//!   cache, breaker gate, admission) and holds the returned [`Ticket`];
//! * one batcher thread forms [`Batch`]es — priority-aware, shedding
//!   cancelled/expired requests at formation time;
//! * `workers` threads pull batches from a shared channel, re-check the
//!   shed conditions immediately before execution, ask the [`Router`]
//!   for placements, pack typed spec-driven input batches, run them on
//!   the backend, and demux typed responses.
//!
//! The backend is any [`InferenceBackend`] — PJRT (feature `pjrt`),
//! [`SimBackend`](crate::backend::SimBackend), or
//! [`EchoBackend`](crate::backend::EchoBackend) — and padding/demux is
//! driven entirely by the artifact's `TensorSpec`s, so token models and
//! image models serve through the same path.
//!
//! Worker-count guidance for compute-heavy backends: with
//! [`CpuSparseBackend`](crate::backend::CpuSparseBackend), the worker
//! threads here do batch plumbing (and run small, serial forwards
//! concurrently — each leases its own activation arena), while
//! large-batch matmuls fan out across the backend's persistent
//! [`ExecPool`](crate::sparse::ExecPool), whose dispatch gate admits
//! one multi-stripe job at a time. Raising `workers` overlaps
//! shed/pack/demux and small forwards with pooled compute; it does not
//! multiply core usage for the big batches — the pool already owns the
//! cores — so a handful of workers is enough. How many stripes a given
//! layer call actually fans out across (and at what tile width) is the
//! backend's per-shape dispatch plan: the fixed `m·k` heuristic by
//! default, or a microbenchmarked [`TunePlan`](crate::sparse::TunePlan)
//! when autotuning is on (`--tune startup|lazy`) — either way the pool
//! clamps at its participant count, which honors the `S4_POOL_WORKERS`
//! env override.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::admission::{Admission, AdmissionDecision};
use super::batcher::{Batch, BatcherConfig, DynamicBatcher};
use super::cache::{CacheConfig, ResponseCache};
use super::health::{Breaker, BreakerConfig, BreakerState};
use super::ingress::{
    AdmissionGate, BreakerGate, ChainOutcome, IngressChain, IngressRequest, IngressStage,
    ReplyAttachment,
};
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{
    Priority, ReplySlot, Request, RequestId, Response, SubmitOptions, Ticket,
};
use super::router::{Placement, Router};
use crate::backend::{InferenceBackend, Value};
use crate::runtime::manifest::Manifest;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
    pub workers: usize,
    pub max_inflight: usize,
    /// Backend-health circuit breaker thresholds (always on; the default
    /// only trips on a sustained consecutive-failure streak, so healthy
    /// stacks never notice it).
    pub breaker: BreakerConfig,
    /// Exact response cache + single-flight coalescing
    /// ([`ResponseCache`]), installed as the first ingress stage when
    /// set. `None` (the default) leaves the ingress chain exactly
    /// `[breaker, admission]` — pre-cache behavior, bitwise.
    pub cache: Option<CacheConfig>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batcher: BatcherConfig::default(),
            workers: 2,
            max_inflight: 256,
            breaker: BreakerConfig::default(),
            cache: None,
        }
    }
}

/// The submission surface of a running serving stack — what application
/// code should depend on, rather than the concrete [`ServerHandle`].
///
/// **Shutdown semantics:** handles are cheap clones that may outlive the
/// [`Server`]; dropping one never stops serving. [`Server::shutdown`]
/// signals stop, drains already-queued work (every in-flight ticket
/// still receives exactly one [`Response`]), and joins the threads.
/// Submissions racing a shutdown are rejected with
/// [`AdmissionDecision::RejectQueueFull`].
pub trait ServingService {
    /// Submit a typed request (one sample-shaped [`Value`] per model
    /// input) with explicit QoS options; returns the [`Ticket`] to wait
    /// on, or an immediate rejection.
    fn submit_with(
        &self,
        model: &str,
        inputs: Vec<Value>,
        opts: SubmitOptions,
    ) -> Result<Ticket, AdmissionDecision>;

    /// [`submit_with`](ServingService::submit_with) under
    /// [`SubmitOptions::default`] — the mechanical migration target for
    /// PR 1-era two-arg call sites.
    fn submit(&self, model: &str, inputs: Vec<Value>) -> Result<Ticket, AdmissionDecision> {
        self.submit_with(model, inputs, SubmitOptions::default())
    }

    /// Typed point-in-time metrics for this serving stack.
    fn metrics_snapshot(&self) -> MetricsSnapshot;

    /// The shared [`Metrics`] sink behind this service, when it has one.
    /// Front ends (the socket layer's
    /// [`NetServer`](crate::net::NetServer)) record connection/frame
    /// counters into it so one [`MetricsSnapshot`] covers both the wire
    /// boundary and serving. Adapters without a shared sink keep the
    /// default `None`; the front end then falls back to a private sink.
    fn shared_metrics(&self) -> Option<Arc<Metrics>> {
        None
    }
}

/// Running server; call [`shutdown`](Server::shutdown) to stop cleanly.
pub struct Server {
    handle: ServerHandle,
    /// shared with the worker supervisors: a respawned replacement pushes
    /// its own [`JoinHandle`] here so [`shutdown`](Server::shutdown) joins
    /// every generation of every worker, not just the original spawns
    threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
    stop: Arc<std::sync::atomic::AtomicBool>,
    /// front-end drain hooks, run at the START of [`shutdown`](Server::shutdown)
    /// while the batcher/workers are still serving (see
    /// [`on_shutdown`](Server::on_shutdown))
    drain_hooks: Mutex<Vec<Box<dyn FnOnce() + Send>>>,
}

/// Cheap-to-clone submission handle — the [`ServingService`]
/// implementation backed by a [`Server`]'s queues.
#[derive(Clone)]
pub struct ServerHandle {
    tx: Sender<Request>,
    admission: Arc<Admission>,
    breaker: Arc<Breaker>,
    pub metrics: Arc<Metrics>,
    next_id: Arc<std::sync::atomic::AtomicU64>,
    /// the staged front door: `[cache?, breaker, admission]` — see
    /// [`ingress`](super::ingress)
    chain: Arc<IngressChain>,
}

impl ServingService for ServerHandle {
    fn submit_with(
        &self,
        model: &str,
        inputs: Vec<Value>,
        opts: SubmitOptions,
    ) -> Result<Ticket, AdmissionDecision> {
        let class = opts.priority;
        // Run the ingress chain. `Shed`/`Answer` short-circuit (typed
        // rejection / cache hit or coalesced attach); `Proceed` means the
        // terminal AdmissionGate passed — this submission now holds an
        // admission slot and an `admitted` count, optionally carrying a
        // coalescing-leader attachment installed by the cache stage.
        let attachment = {
            let req = IngressRequest { model, inputs: &inputs, opts: &opts };
            match self.chain.run(&req) {
                ChainOutcome::Shed(d) => return Err(d),
                ChainOutcome::Answer(t) => return Ok(t),
                ChainOutcome::Proceed(a) => a,
            }
        };
        let id = RequestId(
            self.next_id
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed),
        );
        let (rtx, rrx) = channel();
        let cancelled = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let now = Instant::now();
        let (reply, on_abort) = match attachment {
            Some(ReplyAttachment { fanout, on_abort }) => {
                (ReplySlot::with_fanout(rtx, fanout), Some(on_abort))
            }
            None => (ReplySlot::new(rtx), None),
        };
        let req = Request {
            id,
            model: Arc::from(model),
            inputs,
            submitted: now,
            priority: class,
            deadline: opts.deadline.map(|d| now + d),
            cancelled: cancelled.clone(),
            client_tag: opts.client_tag.map(Arc::from),
            reply,
        };
        // channel send can only fail after shutdown; surface as queue-full
        // AND fix the books: the request was never enqueued, so it is a
        // rejection — back out the admitted count (the old code left
        // `admitted` incremented here, skewing admitted vs
        // completed+rejected forever after a shutdown race). A coalescing
        // leader also tears down its cache registration so attached
        // followers get a typed error instead of hanging.
        if self.tx.send(req).is_err() {
            self.admission.complete(class);
            self.metrics.unrecord_admitted(class);
            self.metrics.record_rejected();
            if let Some(abort) = on_abort {
                abort();
            }
            return Err(AdmissionDecision::RejectQueueFull(class));
        }
        // the ticket carries its own absolute deadline (same instant the
        // batcher sheds against), so Ticket::wait can enforce it even
        // when the answer arrives on someone else's schedule
        Ok(Ticket::new(id, class, rrx, cancelled)
            .with_deadline(opts.deadline.map(|d| now + d)))
    }

    fn metrics_snapshot(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    fn shared_metrics(&self) -> Option<Arc<Metrics>> {
        Some(self.metrics.clone())
    }
}

/// Generate inherent mirrors of the [`ServingService`] methods on a
/// concrete handle type, each one a literal delegation to the trait
/// method of the same name — so call sites holding the concrete type
/// don't need the trait in scope, and the two surfaces cannot drift
/// (there is exactly one body per method, in the trait impl).
macro_rules! mirror_serving_service {
    ($ty:ty) => {
        impl $ty {
            /// Inherent mirror of [`ServingService::submit_with`].
            pub fn submit_with(
                &self,
                model: &str,
                inputs: Vec<Value>,
                opts: SubmitOptions,
            ) -> Result<Ticket, AdmissionDecision> {
                ServingService::submit_with(self, model, inputs, opts)
            }

            /// Inherent mirror of [`ServingService::submit`].
            pub fn submit(
                &self,
                model: &str,
                inputs: Vec<Value>,
            ) -> Result<Ticket, AdmissionDecision> {
                ServingService::submit(self, model, inputs)
            }

            /// Inherent mirror of [`ServingService::metrics_snapshot`].
            pub fn metrics_snapshot(&self) -> MetricsSnapshot {
                ServingService::metrics_snapshot(self)
            }
        }
    };
}

// Path-import the macro so other in-crate handle types (the cluster
// router tier) can mirror the same surface without `#[macro_export]`
// making it public API.
pub(crate) use mirror_serving_service;

mirror_serving_service!(ServerHandle);

impl ServerHandle {
    /// Admission slots currently held (0 when the stack is idle) — the
    /// leak detector chaos tests assert on after a fault storm.
    pub fn inflight(&self) -> i64 {
        self.admission.inflight()
    }

    /// Current health-breaker state (observability + tests).
    pub fn breaker_state(&self) -> BreakerState {
        self.breaker.state()
    }
}

impl Server {
    /// Start batcher + workers.
    pub fn start(
        cfg: ServerConfig,
        manifest: Manifest,
        router: Router,
        backend: Arc<dyn InferenceBackend>,
    ) -> Server {
        let (req_tx, req_rx) = channel::<Request>();
        // bounded hand-off (capacity 1): if batches queued eagerly in an
        // unbounded channel, the whole backlog would be frozen into FIFO
        // batches the moment it arrived and priority/deadline decisions
        // could never apply to it. Backpressure keeps the backlog in the
        // batcher's stash, where Interactive still overtakes and dead
        // requests are shed. Formation is µs-cheap vs execution, so one
        // batch of slack never starves the workers.
        let (batch_tx, batch_rx) = std::sync::mpsc::sync_channel::<Batch>(1);
        let metrics = Arc::new(Metrics::new());
        let admission = Arc::new(Admission::depth_only(cfg.max_inflight));
        let breaker = Arc::new(Breaker::new(cfg.breaker));
        // One id mint shared by the ingress chain and submit_with: cache
        // hits and coalesced attaches get real unique RequestIds from the
        // same sequence as admitted requests.
        let next_id = Arc::new(std::sync::atomic::AtomicU64::new(1));
        // Staged front door. Cache runs FIRST so hot keys are answered
        // even while the breaker is degraded (a hit needs no backend);
        // the [breaker, admission] tail is the pre-refactor path, bitwise.
        let mut stages: Vec<Box<dyn IngressStage>> = Vec::new();
        if let Some(ccfg) = cfg.cache.clone() {
            stages.push(Box::new(ResponseCache::new(
                ccfg,
                metrics.clone(),
                next_id.clone(),
            )));
        }
        stages.push(Box::new(BreakerGate::new(breaker.clone(), metrics.clone())));
        stages.push(Box::new(AdmissionGate::new(admission.clone(), metrics.clone())));
        let chain = Arc::new(IngressChain::new(stages));

        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let threads = Arc::new(Mutex::new(Vec::new()));
        // batcher thread
        {
            let bcfg = cfg.batcher;
            let stop = stop.clone();
            let metrics = metrics.clone();
            let admission = admission.clone();
            lock_threads(&threads).push(
                std::thread::Builder::new()
                    .name("s4-batcher".into())
                    .spawn(move || {
                        let mut b = DynamicBatcher::with_stop(bcfg, req_rx, stop)
                            .with_shed_accounting(metrics, admission);
                        while let Some(batch) = b.next_batch() {
                            if batch_tx.send(batch).is_err() {
                                break;
                            }
                        }
                    })
                    .expect("spawn batcher"),
            );
        }
        // supervised workers
        let ctx = Arc::new(WorkerCtx {
            batch_rx: Mutex::new(batch_rx),
            backend,
            manifest: Arc::new(manifest),
            router: Arc::new(router),
            metrics: metrics.clone(),
            admission: admission.clone(),
            breaker: breaker.clone(),
            stop: stop.clone(),
            threads: threads.clone(),
        });
        for w in 0..cfg.workers.max(1) {
            spawn_worker(&ctx, w);
        }

        Server {
            handle: ServerHandle {
                tx: req_tx,
                admission,
                breaker,
                metrics,
                next_id,
                chain,
            },
            threads,
            stop,
            drain_hooks: Mutex::new(Vec::new()),
        }
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// Register a front-end drain hook, run at the start of
    /// [`shutdown`](Server::shutdown) *before* the batcher/workers are
    /// signalled. This is how the socket layer wires drain-on-shutdown:
    /// `srv.on_shutdown(move || net.shutdown())` makes one
    /// `srv.shutdown()` call first stop accepting connections and flush
    /// every in-flight wire request (the coordinator is still answering
    /// tickets at that point), then stop serving. Hooks run in
    /// registration order.
    pub fn on_shutdown(&self, hook: impl FnOnce() + Send + 'static) {
        self.drain_hooks.lock().unwrap().push(Box::new(hook));
    }

    /// Shut down: run the registered front-end drain hooks (while still
    /// serving), then signal the batcher (which drains queued work) and
    /// join all threads. Safe even while cloned handles are still alive.
    ///
    /// Each drain hook runs inside a `catch_unwind` fence: a panicking
    /// front end must not abort shutdown with serving threads unjoined
    /// (they'd hold the process open forever). Remaining hooks still run,
    /// threads still join, and the first panic is re-raised afterwards so
    /// the bug stays loud.
    pub fn shutdown(self) {
        let Server { handle, threads, stop, drain_hooks } = self;
        let hooks = drain_hooks.into_inner().unwrap_or_else(|p| p.into_inner());
        let mut first_panic = None;
        for hook in hooks {
            if let Err(payload) = catch_unwind(AssertUnwindSafe(hook)) {
                first_panic.get_or_insert(payload);
            }
        }
        stop.store(true, std::sync::atomic::Ordering::Release);
        drop(handle);
        // Pop-then-join (without holding the lock): a panicked worker's
        // supervisor may be pushing its replacement's handle concurrently,
        // and joining the dying thread while holding the registry lock
        // would deadlock against that push. Looping until the registry
        // stays empty also catches replacements spawned mid-join.
        loop {
            let Some(t) = lock_threads(&threads).pop() else { break };
            let _ = t.join();
        }
        if let Some(payload) = first_panic {
            resume_unwind(payload);
        }
    }
}

/// Shared registry lock, poison-recovering: a panicking supervisor must
/// not make shutdown unjoinable.
fn lock_threads(
    threads: &Arc<Mutex<Vec<JoinHandle<()>>>>,
) -> std::sync::MutexGuard<'_, Vec<JoinHandle<()>>> {
    threads.lock().unwrap_or_else(|p| p.into_inner())
}

/// Everything one worker generation needs — bundled so a supervisor can
/// hand the identical context to its replacement.
struct WorkerCtx {
    batch_rx: Mutex<Receiver<Batch>>,
    backend: Arc<dyn InferenceBackend>,
    manifest: Arc<Manifest>,
    router: Arc<Router>,
    metrics: Arc<Metrics>,
    admission: Arc<Admission>,
    breaker: Arc<Breaker>,
    stop: Arc<std::sync::atomic::AtomicBool>,
    threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

/// Spawn worker `w` under supervision: if its loop dies by panic while the
/// server is still running, count the restart and spawn an identical
/// replacement, so a panicking backend can never shrink serving capacity.
fn spawn_worker(ctx: &Arc<WorkerCtx>, w: usize) {
    let ctx2 = ctx.clone();
    let handle = std::thread::Builder::new()
        .name(format!("s4-worker{w}"))
        .spawn(move || {
            let died = catch_unwind(AssertUnwindSafe(|| worker_loop(&ctx2))).is_err();
            if died && !ctx2.stop.load(std::sync::atomic::Ordering::Acquire) {
                ctx2.metrics.record_worker_restart();
                spawn_worker(&ctx2, w);
            }
        })
        .expect("spawn worker");
    lock_threads(&ctx.threads).push(handle);
}

/// One worker generation: pull batches and execute each inside a
/// `catch_unwind` fence that upholds the serving invariants even when the
/// backend panics mid-batch:
/// * every request is answered exactly once (typed `Error` for the ones
///   `serve_batch` hadn't answered before the panic — [`ReplySlot`] makes
///   the late defensive answers no-ops for the already-answered ones);
/// * every admission slot is released;
/// * the panic is counted (`worker_panics`) and reported to the breaker.
///
/// The panic is then *re-raised*: this generation dies loudly and the
/// supervisor in [`spawn_worker`] replaces it. Killing the thread (rather
/// than looping here) keeps any state the unwind may have skipped-over
/// confined to the dead generation.
fn worker_loop(ctx: &WorkerCtx) {
    loop {
        let batch = {
            // poison-recovering acquisition: a worker killed between
            // `lock()` and `recv()` must not cascade-kill every other
            // worker that touches this mutex afterwards (same pattern as
            // the ActivationArena locks in backend/cpu.rs)
            let rx = ctx.batch_rx.lock().unwrap_or_else(|p| p.into_inner());
            rx.recv()
        };
        let Ok(batch) = batch else { break };
        // capture per-request stubs before execution: the fence answers
        // and releases from these after a panic consumed the batch
        let stubs: Vec<(RequestId, Priority, ReplySlot)> = batch
            .requests
            .iter()
            .map(|r| (r.id, r.priority, r.reply.clone()))
            .collect();
        let result = catch_unwind(AssertUnwindSafe(|| {
            serve_batch(
                batch,
                &ctx.manifest,
                &ctx.router,
                &*ctx.backend,
                &ctx.metrics,
                &ctx.breaker,
            )
        }));
        // slots release on both paths — serve_batch answered everything on
        // Ok, the fence below answers the remainder on Err
        for (_, class, _) in &stubs {
            ctx.admission.complete(*class);
        }
        if let Err(payload) = result {
            ctx.metrics.record_worker_panic();
            if ctx.breaker.record_failure() {
                ctx.metrics.record_breaker_open();
            }
            let msg = format!("worker panicked: {}", panic_message(&payload));
            for (id, _, slot) in &stubs {
                if slot.send(Response::error(*id, msg.clone())) {
                    ctx.metrics.failed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            }
            resume_unwind(payload);
        }
    }
}

/// Best-effort text of a panic payload (`panic!` with a string literal or
/// a formatted message covers everything the backends throw).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&'static str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("opaque panic payload")
}

/// Execute one formed batch: shed dead requests, plan placements, pack,
/// run, demux responses. Each placement's outcome feeds the health
/// `breaker` (routing errors do not — an unknown model says nothing about
/// backend health).
fn serve_batch(
    batch: Batch,
    manifest: &Manifest,
    router: &Router,
    backend: &dyn InferenceBackend,
    metrics: &Metrics,
    breaker: &Breaker,
) {
    let Batch { model, requests, formed_at } = batch;
    // pre-execution shed: the cancel/deadline re-check closest to the
    // backend — work cancelled or expired while queued behind earlier
    // batches is dropped here, after which execution is committed
    let now = Instant::now();
    let mut live = Vec::with_capacity(requests.len());
    for r in requests {
        match r.shed_response(now) {
            Some(resp) => {
                metrics.record_shed(&resp.status);
                let _ = r.reply.send(resp);
            }
            None => live.push(r),
        }
    }
    if live.is_empty() {
        return;
    }
    let placements = match router.plan(manifest, &model, live.len()) {
        Ok(p) => p,
        Err(e) => {
            for r in &live {
                metrics.failed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let _ = r.reply.send(Response::error(r.id, format!("routing: {e}")));
            }
            return;
        }
    };
    let mut cursor = 0usize;
    for p in placements {
        let reqs = &live[cursor..cursor + p.fill];
        cursor += p.fill;
        metrics.record_batch(p.fill, p.batch_capacity);
        if let Err(e) = run_placement(&p, reqs, backend, formed_at, metrics) {
            if breaker.record_failure() {
                metrics.record_breaker_open();
            }
            for r in reqs {
                metrics.failed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let _ = r.reply.send(Response::error(r.id, format!("backend: {e}")));
            }
        } else {
            breaker.record_success();
        }
    }
}

/// Pack one placement's requests into spec-shaped input batches, execute,
/// demux per-request outputs. A per-request payload problem (wrong dtype,
/// missing input) fails only that request — its slot is zeroed and the
/// rest of the batch still runs. An `Err` return fails the whole
/// placement (the caller answers every request).
fn run_placement(
    p: &Placement,
    reqs: &[Request],
    backend: &dyn InferenceBackend,
    formed_at: Instant,
    metrics: &Metrics,
) -> anyhow::Result<()> {
    let in_specs = backend.input_specs(&p.artifact)?;
    let out_specs = backend.output_specs(&p.artifact)?;

    let mut bad: Vec<Option<String>> = vec![None; reqs.len()];
    // arity first: extra tensors are an error, not silently ignored
    for (ri, r) in reqs.iter().enumerate() {
        if r.inputs.len() > in_specs.len() {
            bad[ri] = Some(format!(
                "expected {} inputs, got {}",
                in_specs.len(),
                r.inputs.len()
            ));
        }
    }
    let mut inputs = Vec::with_capacity(in_specs.len());
    for (i, spec) in in_specs.iter().enumerate() {
        let per = spec.sample_elems();
        // pack to the spec's own leading dim (exactly what the backend's
        // validation will demand); a manifest whose spec cannot hold the
        // fill is a placement-level error here, not a confusing
        // element-count mismatch later
        let slots = spec.batch_dim();
        anyhow::ensure!(
            slots >= reqs.len(),
            "{}: input `{}` batch dim {} < fill {}",
            p.artifact,
            spec.name,
            slots,
            reqs.len()
        );
        let mut v = Value::empty(&spec.dtype)?;
        for (ri, r) in reqs.iter().enumerate() {
            if bad[ri].is_some() {
                v.push_zeros(per);
                continue;
            }
            match r.inputs.get(i) {
                Some(x) if x.matches_dtype(spec) => v.push_padded(x, per)?,
                Some(x) => {
                    bad[ri] = Some(format!(
                        "input `{}` dtype mismatch (spec {}, got {})",
                        spec.name,
                        spec.dtype,
                        x.dtype()
                    ));
                    v.push_zeros(per);
                }
                None => {
                    bad[ri] = Some(format!("missing input {i} (`{}`)", spec.name));
                    v.push_zeros(per);
                }
            }
        }
        // zero-pad unfilled slots (the seed repeated the last real sample
        // here, which underflowed on an empty placement; zeros are always
        // valid padding)
        v.push_zeros(per * (slots - reqs.len()));
        inputs.push(v);
    }

    // nothing real to execute (empty placement, or every slot zeroed by a
    // bad payload): answer the bad requests and skip the inference
    if bad.iter().all(Option::is_some) {
        for (r, msg) in reqs.iter().zip(bad.iter_mut()) {
            if let Some(msg) = msg.take() {
                metrics.failed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let _ = r.reply.send(Response::error(r.id, msg));
            }
        }
        return Ok(());
    }

    let exec_start = Instant::now();
    let outputs = backend.run_batch(&p.artifact, &inputs)?;

    // validate the whole output set before answering anyone, so a
    // malformed backend response cannot double-answer some requests
    anyhow::ensure!(
        outputs.len() == out_specs.len(),
        "{}: backend returned {} outputs, specs say {}",
        p.artifact,
        outputs.len(),
        out_specs.len()
    );
    for (o, spec) in outputs.iter().zip(out_specs) {
        anyhow::ensure!(
            o.len() == spec.elems() && o.dtype() == spec.dtype,
            "{}: output `{}` shape/dtype drifted from spec",
            p.artifact,
            spec.name
        );
        anyhow::ensure!(
            spec.batch_dim() >= reqs.len(),
            "{}: output `{}` batch dim {} < fill {}",
            p.artifact,
            spec.name,
            spec.batch_dim(),
            reqs.len()
        );
    }

    // one shared name for every response demuxed from this placement
    // (refcount clone per request, not a fresh heap String)
    let served_by: Arc<str> = Arc::from(p.artifact.as_str());
    for (ri, r) in reqs.iter().enumerate() {
        if let Some(msg) = bad[ri].take() {
            metrics.failed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let _ = r.reply.send(Response::error(r.id, msg));
            continue;
        }
        let outs: Vec<Value> = outputs
            .iter()
            .zip(out_specs)
            .map(|(o, spec)| {
                let per = spec.sample_elems();
                o.slice(ri * per, per)
            })
            .collect();
        let latency = r.submitted.elapsed();
        let queue = formed_at.saturating_duration_since(r.submitted)
            + exec_start.saturating_duration_since(formed_at);
        metrics.record_completion(
            r.priority,
            latency.as_micros() as u64,
            queue.as_micros() as u64,
        );
        let _ = r.reply.send(Response {
            id: r.id,
            outputs: outs,
            served_by: served_by.clone(),
            batch_size: p.batch_capacity,
            latency_us: latency.as_micros() as u64,
            queue_us: queue.as_micros() as u64,
            status: super::request::ResponseStatus::Ok,
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::EchoBackend;
    use crate::coordinator::request::ResponseStatus;
    use crate::coordinator::RoutingPolicy;
    use std::path::Path;
    use std::time::Duration;

    fn manifest() -> Manifest {
        let text = r#"{"artifacts": [
          {"name": "bert_tiny_s8_b1", "file": "x", "family": "bert",
           "model": "bert_tiny", "sparsity": 8, "batch": 1, "seq": 16,
           "inputs": [{"name": "ids", "shape": [1, 16], "dtype": "s32"}],
           "outputs": [{"shape": [1, 2], "dtype": "f32"}]},
          {"name": "bert_tiny_s8_b8", "file": "y", "family": "bert",
           "model": "bert_tiny", "sparsity": 8, "batch": 8, "seq": 16,
           "inputs": [{"name": "ids", "shape": [8, 16], "dtype": "s32"}],
           "outputs": [{"shape": [8, 2], "dtype": "f32"}]},
          {"name": "resnet50_s8_b4", "file": "z", "family": "resnet",
           "model": "resnet50", "sparsity": 8, "batch": 4, "seq": 0,
           "inputs": [{"name": "images", "shape": [4, 48], "dtype": "f32"}],
           "outputs": [{"shape": [4, 10], "dtype": "f32"}]}
        ]}"#;
        Manifest::parse(Path::new("/tmp"), text).unwrap()
    }

    fn echo_server(cfg: ServerConfig) -> Server {
        let m = manifest();
        let backend = Arc::new(EchoBackend::from_manifest(&m));
        Server::start(cfg, m, Router::new(RoutingPolicy::MaxSparsity), backend)
    }

    #[test]
    fn end_to_end_single_request() {
        let srv = echo_server(ServerConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            workers: 1,
            max_inflight: 16,
            ..Default::default()
        });
        let h = srv.handle();
        let t = h.submit("bert_tiny", vec![Value::tokens(vec![42; 16])]).unwrap();
        let resp = t.wait_timeout(Duration::from_secs(5)).unwrap();
        assert!(resp.is_ok(), "{:?}", resp.status);
        assert_eq!(resp.logits()[0], 42.0);
        srv.shutdown();
    }

    #[test]
    fn image_requests_serve_through_the_same_stack() {
        let srv = echo_server(ServerConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            workers: 1,
            max_inflight: 16,
            ..Default::default()
        });
        let h = srv.handle();
        let mut pixels = vec![0.0f32; 48];
        pixels[0] = 0.625;
        let t = h.submit("resnet50", vec![Value::F32(pixels)]).unwrap();
        let resp = t.wait_timeout(Duration::from_secs(5)).unwrap();
        assert!(resp.is_ok(), "{:?}", resp.status);
        assert_eq!(&*resp.served_by, "resnet50_s8_b4");
        assert_eq!(resp.logits().len(), 10);
        assert_eq!(resp.logits()[0], 0.625);
        srv.shutdown();
    }

    #[test]
    fn batches_fill_under_load() {
        let srv = echo_server(ServerConfig {
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(20) },
            workers: 1,
            max_inflight: 64,
            ..Default::default()
        });
        let h = srv.handle();
        let tickets: Vec<_> = (0..16)
            .map(|i| h.submit("bert_tiny", vec![Value::tokens(vec![i; 16])]).unwrap())
            .collect();
        for t in tickets {
            let r = t.wait_timeout(Duration::from_secs(5)).unwrap();
            assert!(r.is_ok());
        }
        // under instant backend + 20ms window, the 16 requests should ride
        // few batches with strong fill
        assert!(h.metrics.mean_batch_fill() >= 2.0, "{}", h.metrics.report());
        srv.shutdown();
    }

    #[test]
    fn unknown_model_errors_cleanly() {
        let srv = echo_server(ServerConfig::default());
        let h = srv.handle();
        let t = h.submit("nonexistent", vec![Value::tokens(vec![1; 16])]).unwrap();
        let r = t.wait_timeout(Duration::from_secs(5)).unwrap();
        assert!(!r.is_ok());
        assert!(r.error_message().unwrap().contains("routing"));
        srv.shutdown();
    }

    #[test]
    fn wrong_dtype_fails_only_that_request() {
        let srv = echo_server(ServerConfig {
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(20) },
            workers: 1,
            max_inflight: 16,
            ..Default::default()
        });
        let h = srv.handle();
        // an f32 payload for a token model rides the same batch as a good
        // request; only the bad one fails
        let t_bad = h.submit("bert_tiny", vec![Value::F32(vec![1.0; 16])]).unwrap();
        let t_ok = h.submit("bert_tiny", vec![Value::tokens(vec![5; 16])]).unwrap();
        let bad = t_bad.wait_timeout(Duration::from_secs(5)).unwrap();
        let ok = t_ok.wait_timeout(Duration::from_secs(5)).unwrap();
        assert!(!bad.is_ok());
        assert!(bad.error_message().unwrap().contains("dtype"));
        assert!(ok.is_ok(), "{:?}", ok.status);
        assert_eq!(ok.logits()[0], 5.0);
        srv.shutdown();
    }

    #[test]
    fn missing_input_fails_cleanly() {
        let srv = echo_server(ServerConfig::default());
        let h = srv.handle();
        let t = h.submit("bert_tiny", Vec::new()).unwrap();
        let r = t.wait_timeout(Duration::from_secs(5)).unwrap();
        assert!(!r.is_ok());
        assert!(r.error_message().unwrap().contains("missing input"));
        srv.shutdown();
    }

    #[test]
    fn admission_rejects_over_capacity() {
        // max_inflight 1 with a slow-ish path: second submit may reject
        let srv = echo_server(ServerConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(50) },
            workers: 1,
            max_inflight: 1,
            ..Default::default()
        });
        let h = srv.handle();
        let _t1 = h.submit("bert_tiny", vec![Value::tokens(vec![1; 16])]).unwrap();
        // immediately after, capacity is full until the worker drains it
        let second = h.submit("bert_tiny", vec![Value::tokens(vec![2; 16])]);
        if let Err(d) = second {
            assert_eq!(d, AdmissionDecision::RejectQueueFull(Priority::Standard));
        }
        srv.shutdown();
    }

    #[test]
    fn submit_after_shutdown_is_a_rejection_not_an_admission() {
        // satellite regression: the send-failure path used to leave
        // `admitted` incremented while returning a rejection
        let srv = echo_server(ServerConfig::default());
        let h = srv.handle();
        srv.shutdown();
        let r = h.submit("bert_tiny", vec![Value::tokens(vec![1; 16])]);
        assert!(matches!(r, Err(AdmissionDecision::RejectQueueFull(Priority::Standard))));
        let s = h.metrics_snapshot();
        assert_eq!(s.admitted, 0, "failed enqueue must not count as admitted");
        assert_eq!(s.rejected, 1);
        assert_eq!(s.class(Priority::Standard).admitted, 0);
        assert_eq!(h.metrics.admitted.load(std::sync::atomic::Ordering::Relaxed), 0);
    }

    #[test]
    fn drain_hooks_run_while_the_coordinator_is_still_serving() {
        // a front end drains in-flight wire work inside its hook; that
        // only works if tickets still resolve at hook time
        let srv = echo_server(ServerConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            workers: 1,
            max_inflight: 16,
            ..Default::default()
        });
        let h = srv.handle();
        let ran = Arc::new(std::sync::atomic::AtomicBool::new(false));
        {
            let h = h.clone();
            let ran = ran.clone();
            srv.on_shutdown(move || {
                let t = h.submit("bert_tiny", vec![Value::tokens(vec![3; 16])]).unwrap();
                let r = t.wait_timeout(Duration::from_secs(5)).unwrap();
                assert!(r.is_ok(), "hook-time submit must still serve: {:?}", r.status);
                ran.store(true, std::sync::atomic::Ordering::Release);
            });
        }
        srv.shutdown();
        assert!(ran.load(std::sync::atomic::Ordering::Acquire), "hook must run");
        // after shutdown the same handle is rejected
        assert!(h.submit("bert_tiny", vec![Value::tokens(vec![3; 16])]).is_err());
    }

    #[test]
    fn submit_with_carries_priority_and_tag() {
        let srv = echo_server(ServerConfig::default());
        let h = srv.handle();
        let t = h
            .submit_with(
                "bert_tiny",
                vec![Value::tokens(vec![9; 16])],
                SubmitOptions::interactive().with_client_tag("probe"),
            )
            .unwrap();
        assert_eq!(t.priority(), Priority::Interactive);
        let r = t.wait_timeout(Duration::from_secs(5)).unwrap();
        assert!(r.is_ok(), "{:?}", r.status);
        let s = h.metrics_snapshot();
        assert_eq!(s.class(Priority::Interactive).admitted, 1);
        assert_eq!(s.class(Priority::Interactive).completed, 1);
        srv.shutdown();
    }

    #[test]
    fn pre_execution_shed_answers_expired_without_running() {
        // deadline already passed when the worker sees the batch
        let m = manifest();
        let backend = EchoBackend::from_manifest(&m);
        let (tx, rx) = channel();
        let now = Instant::now();
        let req = Request {
            id: RequestId(1),
            model: Arc::from("bert_tiny"),
            inputs: vec![Value::tokens(vec![1; 16])],
            submitted: now,
            priority: Priority::Standard,
            deadline: Some(now), // expired immediately
            cancelled: Arc::new(std::sync::atomic::AtomicBool::new(false)),
            client_tag: None,
            reply: ReplySlot::new(tx),
        };
        let metrics = Metrics::new();
        let batch = Batch {
            model: req.model.clone(),
            requests: vec![req],
            formed_at: Instant::now(),
        };
        std::thread::sleep(Duration::from_millis(1));
        serve_batch(
            batch,
            &m,
            &Router::new(RoutingPolicy::MaxSparsity),
            &backend,
            &metrics,
            &Breaker::new(BreakerConfig::default()),
        );
        let resp = rx.try_recv().unwrap();
        assert_eq!(resp.status, ResponseStatus::Expired);
        assert_eq!(metrics.expired.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(metrics.completed.load(std::sync::atomic::Ordering::Relaxed), 0);
        assert_eq!(metrics.batches.load(std::sync::atomic::Ordering::Relaxed), 0);
    }

    fn faulty_server(cfg: ServerConfig, plan: crate::fault::FaultPlan) -> Server {
        let m = manifest();
        let inner: Arc<dyn InferenceBackend> = Arc::new(EchoBackend::from_manifest(&m));
        let backend = Arc::new(crate::fault::FaultingBackend::new(inner, plan));
        Server::start(cfg, m, Router::new(RoutingPolicy::MaxSparsity), backend)
    }

    #[test]
    fn worker_panic_answers_typed_releases_slots_and_respawns() {
        let srv = faulty_server(
            ServerConfig {
                batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) },
                workers: 1,
                max_inflight: 16,
                ..Default::default()
            },
            crate::fault::FaultPlan::new().with_panic_at(0),
        );
        let h = srv.handle();
        let t = h.submit("bert_tiny", vec![Value::tokens(vec![1; 16])]).unwrap();
        let r = t.wait_timeout(Duration::from_secs(5)).unwrap();
        assert!(!r.is_ok(), "panicked batch must answer typed, not hang");
        assert!(
            r.error_message().unwrap().contains("worker panicked"),
            "{:?}",
            r.status
        );
        // the supervisor respawned the only worker: the stack still serves
        let t = h.submit("bert_tiny", vec![Value::tokens(vec![2; 16])]).unwrap();
        let r = t.wait_timeout(Duration::from_secs(5)).unwrap();
        assert!(r.is_ok(), "respawned worker must serve: {:?}", r.status);
        assert_eq!(r.logits()[0], 2.0);
        let s = h.metrics_snapshot();
        assert_eq!(s.worker_panics, 1, "{}", s.report());
        assert_eq!(s.worker_restarts, 1, "{}", s.report());
        assert_eq!(s.answered(), s.admitted, "{}", s.report());
        assert_eq!(h.inflight(), 0, "panicked batch must release its slots");
        srv.shutdown();
    }

    #[test]
    fn one_panicked_worker_does_not_cascade_kill_the_rest() {
        // satellite regression: with the old `batch_rx.lock().unwrap()`,
        // one worker death could propagate; at workers=4 the other three
        // (plus the respawn) must keep serving everything afterwards
        let srv = faulty_server(
            ServerConfig {
                batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) },
                workers: 4,
                max_inflight: 64,
                ..Default::default()
            },
            crate::fault::FaultPlan::new().with_panic_at(0),
        );
        let h = srv.handle();
        let t = h.submit("bert_tiny", vec![Value::tokens(vec![9; 16])]).unwrap();
        let r = t.wait_timeout(Duration::from_secs(5)).unwrap();
        assert!(r.error_message().unwrap_or("").contains("worker panicked"), "{:?}", r.status);
        for i in 0..12 {
            let t = h.submit("bert_tiny", vec![Value::tokens(vec![i; 16])]).unwrap();
            let r = t.wait_timeout(Duration::from_secs(5)).unwrap();
            assert!(r.is_ok(), "request {i} after the panic: {:?}", r.status);
        }
        let s = h.metrics_snapshot();
        assert_eq!(s.completed, 12, "{}", s.report());
        assert_eq!(s.answered(), s.admitted, "{}", s.report());
        assert_eq!(h.inflight(), 0);
        srv.shutdown();
    }

    #[test]
    fn shutdown_fences_hook_panics_joins_threads_then_reraises() {
        let srv = echo_server(ServerConfig::default());
        let h = srv.handle();
        let later_ran = Arc::new(std::sync::atomic::AtomicBool::new(false));
        srv.on_shutdown(|| panic!("first hook detonates"));
        {
            let later_ran = later_ran.clone();
            srv.on_shutdown(move || later_ran.store(true, std::sync::atomic::Ordering::Release));
        }
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| srv.shutdown()));
        let payload = caught.expect_err("first hook panic must re-raise after joins");
        assert_eq!(
            payload.downcast_ref::<&str>().copied(),
            Some("first hook detonates")
        );
        assert!(
            later_ran.load(std::sync::atomic::Ordering::Acquire),
            "hooks after the panicking one must still run"
        );
        // threads were joined: the serving stack is really gone
        assert!(h.submit("bert_tiny", vec![Value::tokens(vec![1; 16])]).is_err());
    }

    #[test]
    fn breaker_trips_on_error_burst_sheds_then_probes_closed() {
        let srv = faulty_server(
            ServerConfig {
                batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) },
                workers: 1,
                max_inflight: 16,
                breaker: BreakerConfig {
                    failure_threshold: 3,
                    probe_after_sheds: 2,
                    close_after_probes: 1,
                },
                ..Default::default()
            },
            crate::fault::FaultPlan::new().with_error_burst(0, 3),
        );
        let h = srv.handle();
        // the burst: three consecutive backend errors, each answered typed
        for i in 0..3 {
            let t = h.submit("bert_tiny", vec![Value::tokens(vec![i; 16])]).unwrap();
            let r = t.wait_timeout(Duration::from_secs(5)).unwrap();
            assert!(
                r.error_message().unwrap_or("").contains("injected fault"),
                "burst request {i}: {:?}",
                r.status
            );
        }
        assert_eq!(h.breaker_state(), BreakerState::Open);
        // while open: typed retryable shed, no slot, no admitted count
        for _ in 0..2 {
            match h.submit("bert_tiny", vec![Value::tokens(vec![0; 16])]) {
                Err(AdmissionDecision::RejectUnhealthy(Priority::Standard)) => {}
                other => panic!("expected RejectUnhealthy, got {other:?}"),
            }
        }
        // probe passes, succeeds, and closes the breaker
        let t = h.submit("bert_tiny", vec![Value::tokens(vec![7; 16])]).unwrap();
        let r = t.wait_timeout(Duration::from_secs(5)).unwrap();
        assert!(r.is_ok(), "probe must serve: {:?}", r.status);
        assert_eq!(h.breaker_state(), BreakerState::Closed);
        let s = h.metrics_snapshot();
        assert_eq!(s.breaker_opens, 1, "{}", s.report());
        assert_eq!(s.breaker_shed, 2, "{}", s.report());
        assert_eq!(s.answered(), s.admitted, "sheds consume no admission: {}", s.report());
        assert_eq!(h.inflight(), 0);
        srv.shutdown();
    }

    #[test]
    fn zero_fill_placement_pads_with_zeros_instead_of_panicking() {
        // the seed's `(reqs.len() - 1) * seq` underflowed here
        let m = manifest();
        let backend = EchoBackend::from_manifest(&m);
        let p = Placement {
            artifact: "bert_tiny_s8_b8".into(),
            batch_capacity: 8,
            fill: 0,
        };
        let metrics = Metrics::new();
        run_placement(&p, &[], &backend, Instant::now(), &metrics).unwrap();
        assert_eq!(metrics.completed.load(std::sync::atomic::Ordering::Relaxed), 0);
    }
}
