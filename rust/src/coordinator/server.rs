//! The serving loop: batcher thread + worker pool over an
//! [`InferenceBackend`].
//!
//! Wire-up (std threads, no async runtime in this environment):
//! * clients send [`Request`]s through [`ServerHandle::submit`] (admission
//!   happens there);
//! * one batcher thread forms [`Batch`]es;
//! * `workers` threads pull batches from a shared channel, ask the
//!   [`Router`] for placements, pack typed spec-driven input batches, run
//!   them on the backend, and demux typed responses.
//!
//! The backend is any [`InferenceBackend`] — PJRT (feature `pjrt`),
//! [`SimBackend`](crate::backend::SimBackend), or
//! [`EchoBackend`](crate::backend::EchoBackend) — and padding/demux is
//! driven entirely by the artifact's `TensorSpec`s, so token models and
//! image models serve through the same path.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::admission::{Admission, AdmissionDecision};
use super::batcher::{Batch, BatcherConfig, DynamicBatcher};
use super::metrics::Metrics;
use super::request::{Request, RequestId, Response};
use super::router::{Placement, Router};
use crate::backend::{InferenceBackend, Value};
use crate::runtime::manifest::Manifest;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
    pub workers: usize,
    pub max_inflight: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batcher: BatcherConfig::default(),
            workers: 2,
            max_inflight: 256,
        }
    }
}

/// Running server; call [`shutdown`](Server::shutdown) to stop cleanly.
pub struct Server {
    handle: ServerHandle,
    threads: Vec<JoinHandle<()>>,
    stop: Arc<std::sync::atomic::AtomicBool>,
}

/// Cheap-to-clone submission handle.
#[derive(Clone)]
pub struct ServerHandle {
    tx: Sender<Request>,
    admission: Arc<Admission>,
    pub metrics: Arc<Metrics>,
    next_id: Arc<std::sync::atomic::AtomicU64>,
}

impl ServerHandle {
    /// Submit a typed request (one sample-shaped [`Value`] per model
    /// input); returns the receiver for its response, or an immediate
    /// rejection.
    pub fn submit(
        &self,
        model: &str,
        inputs: Vec<Value>,
    ) -> Result<(RequestId, Receiver<Response>), AdmissionDecision> {
        match self.admission.try_admit() {
            AdmissionDecision::Admit => {}
            other => {
                self.metrics
                    .rejected
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                return Err(other);
            }
        }
        self.metrics
            .admitted
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let id = RequestId(
            self.next_id
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed),
        );
        let (rtx, rrx) = channel();
        let req = Request {
            id,
            model: Arc::from(model),
            inputs,
            submitted: Instant::now(),
            reply: rtx,
        };
        // channel send can only fail after shutdown; surface as queue-full
        if self.tx.send(req).is_err() {
            self.admission.complete();
            return Err(AdmissionDecision::RejectQueueFull);
        }
        Ok((id, rrx))
    }

    /// Convenience for single-input token models (BERT-style).
    pub fn submit_tokens(
        &self,
        model: &str,
        tokens: Vec<i32>,
    ) -> Result<(RequestId, Receiver<Response>), AdmissionDecision> {
        self.submit(model, vec![Value::I32(tokens)])
    }
}

impl Server {
    /// Start batcher + workers.
    pub fn start(
        cfg: ServerConfig,
        manifest: Manifest,
        router: Router,
        backend: Arc<dyn InferenceBackend>,
    ) -> Server {
        let (req_tx, req_rx) = channel::<Request>();
        let (batch_tx, batch_rx) = channel::<Batch>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let metrics = Arc::new(Metrics::new());
        let admission = Arc::new(Admission::depth_only(cfg.max_inflight));

        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut threads = Vec::new();
        // batcher thread
        {
            let bcfg = cfg.batcher;
            let stop = stop.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("s4-batcher".into())
                    .spawn(move || {
                        let mut b = DynamicBatcher::with_stop(bcfg, req_rx, stop);
                        while let Some(batch) = b.next_batch() {
                            if batch_tx.send(batch).is_err() {
                                break;
                            }
                        }
                    })
                    .expect("spawn batcher"),
            );
        }
        // workers
        let manifest = Arc::new(manifest);
        let router = Arc::new(router);
        for w in 0..cfg.workers.max(1) {
            let batch_rx = batch_rx.clone();
            let backend = backend.clone();
            let manifest = manifest.clone();
            let router = router.clone();
            let metrics = metrics.clone();
            let admission = admission.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("s4-worker{w}"))
                    .spawn(move || {
                        loop {
                            let batch = {
                                let rx = batch_rx.lock().unwrap();
                                rx.recv()
                            };
                            let Ok(batch) = batch else { break };
                            serve_batch(&batch, &manifest, &router, &*backend, &metrics);
                            for _ in 0..batch.len() {
                                admission.complete();
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
        }

        Server {
            handle: ServerHandle {
                tx: req_tx,
                admission,
                metrics,
                next_id: Arc::new(std::sync::atomic::AtomicU64::new(1)),
            },
            threads,
            stop,
        }
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// Shut down: signal the batcher (which drains queued work), then join
    /// all threads. Safe even while cloned handles are still alive.
    pub fn shutdown(self) {
        let Server { handle, threads, stop } = self;
        stop.store(true, std::sync::atomic::Ordering::Release);
        drop(handle);
        for t in threads {
            let _ = t.join();
        }
    }
}

/// Execute one formed batch: plan placements, pack, run, demux responses.
fn serve_batch(
    batch: &Batch,
    manifest: &Manifest,
    router: &Router,
    backend: &dyn InferenceBackend,
    metrics: &Metrics,
) {
    let placements = match router.plan(manifest, &batch.model, batch.len()) {
        Ok(p) => p,
        Err(e) => {
            for r in &batch.requests {
                metrics.failed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let _ = r.reply.send(Response::error(r.id, format!("routing: {e}")));
            }
            return;
        }
    };
    let mut cursor = 0usize;
    for p in placements {
        let reqs = &batch.requests[cursor..cursor + p.fill];
        cursor += p.fill;
        metrics.record_batch(p.fill, p.batch_capacity);
        if let Err(e) = run_placement(&p, reqs, backend, batch.formed_at, metrics) {
            for r in reqs {
                metrics.failed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let _ = r.reply.send(Response::error(r.id, format!("backend: {e}")));
            }
        }
    }
}

/// Pack one placement's requests into spec-shaped input batches, execute,
/// demux per-request outputs. A per-request payload problem (wrong dtype,
/// missing input) fails only that request — its slot is zeroed and the
/// rest of the batch still runs. An `Err` return fails the whole
/// placement (the caller answers every request).
fn run_placement(
    p: &Placement,
    reqs: &[Request],
    backend: &dyn InferenceBackend,
    formed_at: Instant,
    metrics: &Metrics,
) -> anyhow::Result<()> {
    let in_specs = backend.input_specs(&p.artifact)?;
    let out_specs = backend.output_specs(&p.artifact)?;

    let mut bad: Vec<Option<String>> = vec![None; reqs.len()];
    // arity first: extra tensors are an error, not silently ignored
    for (ri, r) in reqs.iter().enumerate() {
        if r.inputs.len() > in_specs.len() {
            bad[ri] = Some(format!(
                "expected {} inputs, got {}",
                in_specs.len(),
                r.inputs.len()
            ));
        }
    }
    let mut inputs = Vec::with_capacity(in_specs.len());
    for (i, spec) in in_specs.iter().enumerate() {
        let per = spec.sample_elems();
        // pack to the spec's own leading dim (exactly what the backend's
        // validation will demand); a manifest whose spec cannot hold the
        // fill is a placement-level error here, not a confusing
        // element-count mismatch later
        let slots = spec.batch_dim();
        anyhow::ensure!(
            slots >= reqs.len(),
            "{}: input `{}` batch dim {} < fill {}",
            p.artifact,
            spec.name,
            slots,
            reqs.len()
        );
        let mut v = Value::empty(&spec.dtype)?;
        for (ri, r) in reqs.iter().enumerate() {
            if bad[ri].is_some() {
                v.push_zeros(per);
                continue;
            }
            match r.inputs.get(i) {
                Some(x) if x.matches_dtype(spec) => v.push_padded(x, per)?,
                Some(x) => {
                    bad[ri] = Some(format!(
                        "input `{}` dtype mismatch (spec {}, got {})",
                        spec.name,
                        spec.dtype,
                        x.dtype()
                    ));
                    v.push_zeros(per);
                }
                None => {
                    bad[ri] = Some(format!("missing input {i} (`{}`)", spec.name));
                    v.push_zeros(per);
                }
            }
        }
        // zero-pad unfilled slots (the seed repeated the last real sample
        // here, which underflowed on an empty placement; zeros are always
        // valid padding)
        v.push_zeros(per * (slots - reqs.len()));
        inputs.push(v);
    }

    // nothing real to execute (empty placement, or every slot zeroed by a
    // bad payload): answer the bad requests and skip the inference
    if bad.iter().all(Option::is_some) {
        for (r, msg) in reqs.iter().zip(bad.iter_mut()) {
            if let Some(msg) = msg.take() {
                metrics.failed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let _ = r.reply.send(Response::error(r.id, msg));
            }
        }
        return Ok(());
    }

    let exec_start = Instant::now();
    let outputs = backend.run_batch(&p.artifact, &inputs)?;

    // validate the whole output set before answering anyone, so a
    // malformed backend response cannot double-answer some requests
    anyhow::ensure!(
        outputs.len() == out_specs.len(),
        "{}: backend returned {} outputs, specs say {}",
        p.artifact,
        outputs.len(),
        out_specs.len()
    );
    for (o, spec) in outputs.iter().zip(out_specs) {
        anyhow::ensure!(
            o.len() == spec.elems() && o.dtype() == spec.dtype,
            "{}: output `{}` shape/dtype drifted from spec",
            p.artifact,
            spec.name
        );
        anyhow::ensure!(
            spec.batch_dim() >= reqs.len(),
            "{}: output `{}` batch dim {} < fill {}",
            p.artifact,
            spec.name,
            spec.batch_dim(),
            reqs.len()
        );
    }

    for (ri, r) in reqs.iter().enumerate() {
        if let Some(msg) = bad[ri].take() {
            metrics.failed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let _ = r.reply.send(Response::error(r.id, msg));
            continue;
        }
        let outs: Vec<Value> = outputs
            .iter()
            .zip(out_specs)
            .map(|(o, spec)| {
                let per = spec.sample_elems();
                o.slice(ri * per, per)
            })
            .collect();
        let latency = r.submitted.elapsed();
        let queue = formed_at.saturating_duration_since(r.submitted)
            + exec_start.saturating_duration_since(formed_at);
        metrics.record_completion(latency.as_micros() as u64, queue.as_micros() as u64);
        let _ = r.reply.send(Response {
            id: r.id,
            outputs: outs,
            served_by: p.artifact.clone(),
            batch_size: p.batch_capacity,
            latency_us: latency.as_micros() as u64,
            queue_us: queue.as_micros() as u64,
            ok: true,
            error: None,
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::EchoBackend;
    use crate::coordinator::RoutingPolicy;
    use std::path::Path;
    use std::time::Duration;

    fn manifest() -> Manifest {
        let text = r#"{"artifacts": [
          {"name": "bert_tiny_s8_b1", "file": "x", "family": "bert",
           "model": "bert_tiny", "sparsity": 8, "batch": 1, "seq": 16,
           "inputs": [{"name": "ids", "shape": [1, 16], "dtype": "s32"}],
           "outputs": [{"shape": [1, 2], "dtype": "f32"}]},
          {"name": "bert_tiny_s8_b8", "file": "y", "family": "bert",
           "model": "bert_tiny", "sparsity": 8, "batch": 8, "seq": 16,
           "inputs": [{"name": "ids", "shape": [8, 16], "dtype": "s32"}],
           "outputs": [{"shape": [8, 2], "dtype": "f32"}]},
          {"name": "resnet50_s8_b4", "file": "z", "family": "resnet",
           "model": "resnet50", "sparsity": 8, "batch": 4, "seq": 0,
           "inputs": [{"name": "images", "shape": [4, 48], "dtype": "f32"}],
           "outputs": [{"shape": [4, 10], "dtype": "f32"}]}
        ]}"#;
        Manifest::parse(Path::new("/tmp"), text).unwrap()
    }

    fn echo_server(cfg: ServerConfig) -> Server {
        let m = manifest();
        let backend = Arc::new(EchoBackend::from_manifest(&m));
        Server::start(cfg, m, Router::new(RoutingPolicy::MaxSparsity), backend)
    }

    #[test]
    fn end_to_end_single_request() {
        let srv = echo_server(ServerConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            workers: 1,
            max_inflight: 16,
        });
        let h = srv.handle();
        let (_, rx) = h.submit_tokens("bert_tiny", vec![42; 16]).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.logits()[0], 42.0);
        srv.shutdown();
    }

    #[test]
    fn image_requests_serve_through_the_same_stack() {
        let srv = echo_server(ServerConfig {
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            workers: 1,
            max_inflight: 16,
        });
        let h = srv.handle();
        let mut pixels = vec![0.0f32; 48];
        pixels[0] = 0.625;
        let (_, rx) = h.submit("resnet50", vec![Value::F32(pixels)]).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.served_by, "resnet50_s8_b4");
        assert_eq!(resp.logits().len(), 10);
        assert_eq!(resp.logits()[0], 0.625);
        srv.shutdown();
    }

    #[test]
    fn batches_fill_under_load() {
        let srv = echo_server(ServerConfig {
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(20) },
            workers: 1,
            max_inflight: 64,
        });
        let h = srv.handle();
        let rxs: Vec<_> = (0..16)
            .map(|i| h.submit_tokens("bert_tiny", vec![i; 16]).unwrap().1)
            .collect();
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert!(r.ok);
        }
        // under instant backend + 20ms window, the 16 requests should ride
        // few batches with strong fill
        assert!(h.metrics.mean_batch_fill() >= 2.0, "{}", h.metrics.report());
        srv.shutdown();
    }

    #[test]
    fn unknown_model_errors_cleanly() {
        let srv = echo_server(ServerConfig::default());
        let h = srv.handle();
        let (_, rx) = h.submit_tokens("nonexistent", vec![1; 16]).unwrap();
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(!r.ok);
        assert!(r.error.unwrap().contains("routing"));
        srv.shutdown();
    }

    #[test]
    fn wrong_dtype_fails_only_that_request() {
        let srv = echo_server(ServerConfig {
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(20) },
            workers: 1,
            max_inflight: 16,
        });
        let h = srv.handle();
        // an f32 payload for a token model rides the same batch as a good
        // request; only the bad one fails
        let (_, rx_bad) = h.submit("bert_tiny", vec![Value::F32(vec![1.0; 16])]).unwrap();
        let (_, rx_ok) = h.submit_tokens("bert_tiny", vec![5; 16]).unwrap();
        let bad = rx_bad.recv_timeout(Duration::from_secs(5)).unwrap();
        let ok = rx_ok.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(!bad.ok);
        assert!(bad.error.unwrap().contains("dtype"));
        assert!(ok.ok, "{:?}", ok.error);
        assert_eq!(ok.logits()[0], 5.0);
        srv.shutdown();
    }

    #[test]
    fn missing_input_fails_cleanly() {
        let srv = echo_server(ServerConfig::default());
        let h = srv.handle();
        let (_, rx) = h.submit("bert_tiny", Vec::new()).unwrap();
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(!r.ok);
        assert!(r.error.unwrap().contains("missing input"));
        srv.shutdown();
    }

    #[test]
    fn admission_rejects_over_capacity() {
        // max_inflight 1 with a slow-ish path: second submit may reject
        let srv = echo_server(ServerConfig {
            batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(50) },
            workers: 1,
            max_inflight: 1,
        });
        let h = srv.handle();
        let (_, _rx1) = h.submit_tokens("bert_tiny", vec![1; 16]).unwrap();
        // immediately after, capacity is full until the worker drains it
        let second = h.submit_tokens("bert_tiny", vec![2; 16]);
        if let Err(d) = second {
            assert_eq!(d, AdmissionDecision::RejectQueueFull);
        }
        srv.shutdown();
    }

    #[test]
    fn zero_fill_placement_pads_with_zeros_instead_of_panicking() {
        // the seed's `(reqs.len() - 1) * seq` underflowed here
        let m = manifest();
        let backend = EchoBackend::from_manifest(&m);
        let p = Placement {
            artifact: "bert_tiny_s8_b8".into(),
            batch_capacity: 8,
            fill: 0,
        };
        let metrics = Metrics::new();
        run_placement(&p, &[], &backend, Instant::now(), &metrics).unwrap();
        assert_eq!(metrics.completed.load(std::sync::atomic::Ordering::Relaxed), 0);
    }
}
