//! Serving metrics: lock-free counters + histogram latencies.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::LatencyHistogram;

/// Shared serving metrics (cheap to record from any worker).
#[derive(Debug, Default)]
pub struct Metrics {
    pub admitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    pub padding_slots: AtomicU64,
    latency: Mutex<LatencyHistogram>,
    queue: Mutex<LatencyHistogram>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    #[inline]
    pub fn record_completion(&self, latency_us: u64, queue_us: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latency.lock().unwrap().record_us(latency_us as f64);
        self.queue.lock().unwrap().record_us(queue_us as f64);
    }

    #[inline]
    pub fn record_batch(&self, requests: usize, padded_to: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(requests as u64, Ordering::Relaxed);
        self.padding_slots
            .fetch_add((padded_to - requests) as u64, Ordering::Relaxed);
    }

    pub fn latency_quantile_us(&self, q: f64) -> f64 {
        self.latency.lock().unwrap().quantile_us(q)
    }

    pub fn queue_quantile_us(&self, q: f64) -> f64 {
        self.queue.lock().unwrap().quantile_us(q)
    }

    /// Mean requests per executed batch (batching efficiency).
    pub fn mean_batch_fill(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn report(&self) -> String {
        format!(
            "admitted={} rejected={} completed={} failed={} batches={} \
             fill={:.2} pad={} p50={:.0}µs p99={:.0}µs queue_p50={:.0}µs",
            self.admitted.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_fill(),
            self.padding_slots.load(Ordering::Relaxed),
            self.latency_quantile_us(0.5),
            self.latency_quantile_us(0.99),
            self.queue_quantile_us(0.5),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let m = Metrics::new();
        m.admitted.fetch_add(3, Ordering::Relaxed);
        m.record_batch(3, 8);
        m.record_completion(1000, 100);
        m.record_completion(2000, 200);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.padding_slots.load(Ordering::Relaxed), 5);
        assert_eq!(m.mean_batch_fill(), 3.0);
        let r = m.report();
        assert!(r.contains("admitted=3"));
        assert!(m.latency_quantile_us(0.5) > 500.0);
    }

    #[test]
    fn empty_fill_is_zero() {
        assert_eq!(Metrics::new().mean_batch_fill(), 0.0);
    }
}
