//! Serving metrics: lock-free counters + histogram latencies, with a
//! typed point-in-time [`MetricsSnapshot`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::request::{Priority, ResponseStatus};
use crate::util::stats::LatencyHistogram;

/// Both per-request histograms behind ONE mutex: `record_completion` is
/// on the hot path of every served request, and two separate locks cost
/// two acquisitions (and let a reader interleave between them, observing
/// a completion's latency without its queue time).
#[derive(Debug, Default)]
struct Latencies {
    latency: LatencyHistogram,
    queue: LatencyHistogram,
}

/// Shared serving metrics (cheap to record from any worker).
#[derive(Debug, Default)]
pub struct Metrics {
    pub admitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    /// shed before execution: deadline elapsed while queued
    pub expired: AtomicU64,
    /// shed before execution: client cancelled the ticket
    pub cancelled: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    pub padding_slots: AtomicU64,
    admitted_by_class: [AtomicU64; 3],
    completed_by_class: [AtomicU64; 3],
    lat: Mutex<Latencies>,
}

/// Admitted/completed counts for one [`Priority`] class.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ClassStats {
    pub admitted: u64,
    pub completed: u64,
}

/// Typed point-in-time view of [`Metrics`] — what
/// [`ServingService::metrics_snapshot`](crate::coordinator::ServingService::metrics_snapshot)
/// returns, so dashboards and benches consume fields, not a formatted
/// string.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub admitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub expired: u64,
    pub cancelled: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub padding_slots: u64,
    /// indexed by [`Priority::idx`]
    pub by_class: [ClassStats; 3],
    pub mean_batch_fill: f64,
    pub latency_p50_us: f64,
    pub latency_p99_us: f64,
    pub queue_p50_us: f64,
    pub queue_p99_us: f64,
}

impl MetricsSnapshot {
    pub fn class(&self, p: Priority) -> ClassStats {
        self.by_class[p.idx()]
    }

    /// Every admitted request is eventually answered exactly once.
    pub fn answered(&self) -> u64 {
        self.completed + self.failed + self.expired + self.cancelled
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "admitted={} rejected={} completed={} failed={} expired={} \
             cancelled={} batches={} fill={:.2} pad={} p50={:.0}µs p99={:.0}µs \
             queue_p50={:.0}µs",
            self.admitted,
            self.rejected,
            self.completed,
            self.failed,
            self.expired,
            self.cancelled,
            self.batches,
            self.mean_batch_fill,
            self.padding_slots,
            self.latency_p50_us,
            self.latency_p99_us,
            self.queue_p50_us,
        );
        for p in Priority::ALL {
            let c = self.class(p);
            s.push_str(&format!(
                " {}={}/{}",
                p.as_str(),
                c.completed,
                c.admitted
            ));
        }
        s
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    #[inline]
    pub fn record_admitted(&self, class: Priority) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
        self.admitted_by_class[class.idx()].fetch_add(1, Ordering::Relaxed);
    }

    /// Back out a [`record_admitted`](Self::record_admitted) for a
    /// request that turned out to be rejected (queue send failed after
    /// admission) — counted as a rejection instead.
    #[inline]
    pub fn unrecord_admitted(&self, class: Priority) {
        self.admitted.fetch_sub(1, Ordering::Relaxed);
        self.admitted_by_class[class.idx()].fetch_sub(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn record_completion(&self, class: Priority, latency_us: u64, queue_us: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.completed_by_class[class.idx()].fetch_add(1, Ordering::Relaxed);
        let mut l = self.lat.lock().unwrap();
        l.latency.record_us(latency_us as f64);
        l.queue.record_us(queue_us as f64);
    }

    /// Count one request shed before execution ([`ResponseStatus::Expired`]
    /// or [`ResponseStatus::Cancelled`]; other statuses are not sheds).
    #[inline]
    pub fn record_shed(&self, status: &ResponseStatus) {
        match status {
            ResponseStatus::Expired => self.expired.fetch_add(1, Ordering::Relaxed),
            ResponseStatus::Cancelled => self.cancelled.fetch_add(1, Ordering::Relaxed),
            ResponseStatus::Ok | ResponseStatus::Error(_) => return,
        };
    }

    #[inline]
    pub fn record_batch(&self, requests: usize, padded_to: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(requests as u64, Ordering::Relaxed);
        self.padding_slots
            .fetch_add((padded_to - requests) as u64, Ordering::Relaxed);
    }

    pub fn latency_quantile_us(&self, q: f64) -> f64 {
        self.lat.lock().unwrap().latency.quantile_us(q)
    }

    pub fn queue_quantile_us(&self, q: f64) -> f64 {
        self.lat.lock().unwrap().queue.quantile_us(q)
    }

    /// Mean requests per executed batch (batching efficiency).
    pub fn mean_batch_fill(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn admitted_class(&self, p: Priority) -> u64 {
        self.admitted_by_class[p.idx()].load(Ordering::Relaxed)
    }

    pub fn completed_class(&self, p: Priority) -> u64 {
        self.completed_by_class[p.idx()].load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut by_class = [ClassStats::default(); 3];
        for p in Priority::ALL {
            by_class[p.idx()] = ClassStats {
                admitted: self.admitted_class(p),
                completed: self.completed_class(p),
            };
        }
        let (lp50, lp99, qp50, qp99) = {
            let l = self.lat.lock().unwrap();
            (
                l.latency.quantile_us(0.5),
                l.latency.quantile_us(0.99),
                l.queue.quantile_us(0.5),
                l.queue.quantile_us(0.99),
            )
        };
        MetricsSnapshot {
            admitted: self.admitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_requests: self.batched_requests.load(Ordering::Relaxed),
            padding_slots: self.padding_slots.load(Ordering::Relaxed),
            by_class,
            mean_batch_fill: self.mean_batch_fill(),
            latency_p50_us: lp50,
            latency_p99_us: lp99,
            queue_p50_us: qp50,
            queue_p99_us: qp99,
        }
    }

    pub fn report(&self) -> String {
        self.snapshot().report()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let m = Metrics::new();
        m.record_admitted(Priority::Standard);
        m.record_admitted(Priority::Standard);
        m.record_admitted(Priority::Interactive);
        m.record_batch(3, 8);
        m.record_completion(Priority::Standard, 1000, 100);
        m.record_completion(Priority::Interactive, 2000, 200);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.padding_slots.load(Ordering::Relaxed), 5);
        assert_eq!(m.mean_batch_fill(), 3.0);
        let r = m.report();
        assert!(r.contains("admitted=3"), "{r}");
        assert!(r.contains("interactive=1/1"), "{r}");
        assert!(m.latency_quantile_us(0.5) > 500.0);
    }

    #[test]
    fn snapshot_is_typed_and_consistent() {
        let m = Metrics::new();
        m.record_admitted(Priority::Bulk);
        m.record_admitted(Priority::Bulk);
        m.record_completion(Priority::Bulk, 500, 50);
        m.record_shed(&ResponseStatus::Expired);
        let s = m.snapshot();
        assert_eq!(s.admitted, 2);
        assert_eq!(s.expired, 1);
        assert_eq!(s.cancelled, 0);
        assert_eq!(s.class(Priority::Bulk), ClassStats { admitted: 2, completed: 1 });
        assert_eq!(s.class(Priority::Interactive), ClassStats::default());
        assert_eq!(s.answered(), 2); // 1 completed + 1 expired
        assert!(s.latency_p50_us > 0.0 && s.latency_p99_us >= s.latency_p50_us);
    }

    #[test]
    fn shed_counters_by_status() {
        let m = Metrics::new();
        m.record_shed(&ResponseStatus::Expired);
        m.record_shed(&ResponseStatus::Cancelled);
        m.record_shed(&ResponseStatus::Cancelled);
        m.record_shed(&ResponseStatus::Ok); // not a shed
        m.record_shed(&ResponseStatus::Error("x".into())); // not a shed
        assert_eq!(m.expired.load(Ordering::Relaxed), 1);
        assert_eq!(m.cancelled.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn unrecord_admitted_backs_out_both_counters() {
        let m = Metrics::new();
        m.record_admitted(Priority::Interactive);
        m.unrecord_admitted(Priority::Interactive);
        m.record_rejected();
        let s = m.snapshot();
        assert_eq!(s.admitted, 0);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.class(Priority::Interactive).admitted, 0);
    }

    #[test]
    fn empty_fill_is_zero() {
        assert_eq!(Metrics::new().mean_batch_fill(), 0.0);
    }
}
