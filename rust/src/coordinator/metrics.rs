//! Serving metrics: lock-free counters + histogram latencies, with a
//! typed point-in-time [`MetricsSnapshot`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::request::{Priority, ResponseStatus};
use crate::util::stats::LatencyHistogram;

/// Both per-request histograms behind ONE mutex: `record_completion` is
/// on the hot path of every served request, and two separate locks cost
/// two acquisitions (and let a reader interleave between them, observing
/// a completion's latency without its queue time).
#[derive(Debug, Default)]
struct Latencies {
    latency: LatencyHistogram,
    queue: LatencyHistogram,
}

/// Shared serving metrics (cheap to record from any worker).
#[derive(Debug, Default)]
pub struct Metrics {
    pub admitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    /// shed before execution: deadline elapsed while queued
    pub expired: AtomicU64,
    /// shed before execution: client cancelled the ticket
    pub cancelled: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    pub padding_slots: AtomicU64,
    /// Connection-level counters recorded by the net front end
    /// ([`NetServer`](crate::net::NetServer)) when it shares this sink
    /// (via [`ServingService::shared_metrics`](crate::coordinator::ServingService::shared_metrics)),
    /// so the socket boundary is observable through the same snapshot as
    /// serving. All zero when no front end is attached.
    pub conns_accepted: AtomicU64,
    /// gauge: connections currently being served
    pub conns_active: AtomicU64,
    /// closed by the server on a protocol/IO error or handler panic
    /// (a clean client close does not count)
    pub conns_closed_on_error: AtomicU64,
    pub frames_in: AtomicU64,
    pub frames_out: AtomicU64,
    /// frames rejected by the codec (bad magic, oversized, truncated,
    /// undecodable payload); each also closes its connection
    pub frames_malformed: AtomicU64,
    /// batches whose execution panicked inside a worker's `catch_unwind`
    /// fence (every request in the batch is answered with a typed error)
    pub worker_panics: AtomicU64,
    /// replacement worker threads spawned by the supervisor after a panic
    /// — capacity never shrinks, so this tracks `worker_panics` unless a
    /// panic races shutdown
    pub worker_restarts: AtomicU64,
    /// health breaker transitions into `Open` (consecutive-failure trips
    /// and probe-failure re-opens both count)
    pub breaker_opens: AtomicU64,
    /// submissions shed at the front door with `RejectUnhealthy` while the
    /// breaker was degraded (also counted in `rejected`)
    pub breaker_shed: AtomicU64,
    /// submissions answered from a fresh resolved cache entry — never
    /// admitted, so `answered() == admitted` is untouched; the extended
    /// identity is [`MetricsSnapshot::served`]
    pub cache_hits: AtomicU64,
    /// submissions that probed the cache and found no usable entry (the
    /// request then proceeds through breaker/admission as usual)
    pub cache_misses: AtomicU64,
    /// submissions attached to an identical in-flight leader
    /// (single-flight coalescing) — like hits, answered without admission
    pub coalesced: AtomicU64,
    /// gauge: response-cache entries currently held (resolved + in-flight)
    pub cache_size: AtomicU64,
    /// requests forwarded to a cluster node by the router tier
    /// ([`RouterServer`](crate::cluster::RouterServer)); zero without one
    pub router_forwards: AtomicU64,
    /// forwards served by a non-primary replica — the primary was shed by
    /// its breaker or failed mid-forward and a replica absorbed the work
    pub router_failovers: AtomicU64,
    /// submissions that found no live replica at all: shed at the door
    /// with a typed retryable reject, or answered with a typed error after
    /// every replica failed mid-flight
    pub router_no_healthy: AtomicU64,
    admitted_by_class: [AtomicU64; 3],
    completed_by_class: [AtomicU64; 3],
    lat: Mutex<Latencies>,
}

/// Admitted/completed counts for one [`Priority`] class.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ClassStats {
    pub admitted: u64,
    pub completed: u64,
}

/// Point-in-time view of the connection-level counters the net front end
/// records — part of [`MetricsSnapshot`] so socket observability rides
/// the same path as serving observability.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NetStats {
    pub conns_accepted: u64,
    pub conns_active: u64,
    pub conns_closed_on_error: u64,
    pub frames_in: u64,
    pub frames_out: u64,
    pub frames_malformed: u64,
}

/// Router-tier counters for one cluster node, keyed by its membership
/// id. Filled by [`RouterServer`](crate::cluster::RouterServer)'s
/// `metrics_snapshot` — the shared [`Metrics`] sink holds only the
/// fleet-wide aggregates (it has no notion of node identity).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct NodeRouterStats {
    /// Node id from the [`ClusterSpec`](crate::cluster::ClusterSpec).
    pub node: String,
    /// Requests this node served for the router.
    pub forwards: u64,
    /// Forwards this node absorbed as a failover target (it was not the
    /// request's first-choice replica).
    pub failovers: u64,
    /// Requests whose *primary* was this node but which found no live
    /// replica anywhere (shed or errored) — attributes lost work to the
    /// node that should have taken it.
    pub no_healthy_replica: u64,
}

/// Point-in-time router-tier counters: fleet-wide aggregates plus the
/// per-node breakdown. All zero/empty without a router tier.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RouterStats {
    pub forwards: u64,
    pub failovers: u64,
    pub no_healthy_replica: u64,
    /// Per-node rows in membership order (empty when the snapshot was
    /// taken from the bare [`Metrics`] sink rather than a router).
    pub by_node: Vec<NodeRouterStats>,
}

/// Typed point-in-time view of [`Metrics`] — what
/// [`ServingService::metrics_snapshot`](crate::coordinator::ServingService::metrics_snapshot)
/// returns, so dashboards and benches consume fields, not a formatted
/// string.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub admitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub expired: u64,
    pub cancelled: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub padding_slots: u64,
    /// batches that panicked inside the worker fence (answered typed)
    pub worker_panics: u64,
    /// replacement workers respawned by the supervisor
    pub worker_restarts: u64,
    /// breaker transitions into `Open`
    pub breaker_opens: u64,
    /// submissions shed with `RejectUnhealthy` (subset of `rejected`)
    pub breaker_shed: u64,
    /// submissions answered from the response cache (never admitted)
    pub cache_hits: u64,
    /// cache probes that found no usable entry
    pub cache_misses: u64,
    /// submissions coalesced onto an identical in-flight leader
    pub coalesced: u64,
    /// gauge: cache entries currently held
    pub cache_size: u64,
    /// indexed by [`Priority::idx`]
    pub by_class: [ClassStats; 3],
    /// socket-boundary counters (all zero without a net front end)
    pub net: NetStats,
    /// router-tier counters (all zero/empty without a cluster router)
    pub cluster: RouterStats,
    pub mean_batch_fill: f64,
    pub latency_p50_us: f64,
    pub latency_p99_us: f64,
    pub latency_p999_us: f64,
    pub queue_p50_us: f64,
    pub queue_p99_us: f64,
    pub queue_p999_us: f64,
}

impl MetricsSnapshot {
    pub fn class(&self, p: Priority) -> ClassStats {
        self.by_class[p.idx()]
    }

    /// Every admitted request is eventually answered exactly once.
    pub fn answered(&self) -> u64 {
        self.completed + self.failed + self.expired + self.cancelled
    }

    /// Everything that received a response: the admitted pipeline
    /// ([`answered`](MetricsSnapshot::answered), which must equal
    /// `admitted`) plus cache hits and coalesced attaches, which are
    /// answered without ever being admitted.
    pub fn served(&self) -> u64 {
        self.answered() + self.cache_hits + self.coalesced
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "admitted={} rejected={} completed={} failed={} expired={} \
             cancelled={} batches={} fill={:.2} pad={} p50={:.0}µs p99={:.0}µs \
             queue_p50={:.0}µs",
            self.admitted,
            self.rejected,
            self.completed,
            self.failed,
            self.expired,
            self.cancelled,
            self.batches,
            self.mean_batch_fill,
            self.padding_slots,
            self.latency_p50_us,
            self.latency_p99_us,
            self.queue_p50_us,
        );
        for p in Priority::ALL {
            let c = self.class(p);
            s.push_str(&format!(
                " {}={}/{}",
                p.as_str(),
                c.completed,
                c.admitted
            ));
        }
        if self.worker_panics > 0 || self.worker_restarts > 0 || self.breaker_opens > 0 {
            s.push_str(&format!(
                " fault[panics={} restarts={} breaker_opens={} breaker_shed={}]",
                self.worker_panics, self.worker_restarts, self.breaker_opens, self.breaker_shed,
            ));
        }
        if self.cache_hits > 0 || self.cache_misses > 0 || self.coalesced > 0 {
            s.push_str(&format!(
                " cache[hits={} misses={} coalesced={} size={}]",
                self.cache_hits, self.cache_misses, self.coalesced, self.cache_size,
            ));
        }
        if self.net.conns_accepted > 0 {
            s.push_str(&format!(
                " net[conns={}/{} err_closed={} frames={}/{} malformed={}]",
                self.net.conns_active,
                self.net.conns_accepted,
                self.net.conns_closed_on_error,
                self.net.frames_in,
                self.net.frames_out,
                self.net.frames_malformed,
            ));
        }
        if self.cluster.forwards > 0 || self.cluster.no_healthy_replica > 0 {
            s.push_str(&format!(
                " cluster[forwards={} failovers={} no_healthy={}",
                self.cluster.forwards, self.cluster.failovers, self.cluster.no_healthy_replica,
            ));
            for n in &self.cluster.by_node {
                s.push_str(&format!(
                    " {}={}/{}/{}",
                    n.node, n.forwards, n.failovers, n.no_healthy_replica
                ));
            }
            s.push(']');
        }
        s
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    #[inline]
    pub fn record_admitted(&self, class: Priority) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
        self.admitted_by_class[class.idx()].fetch_add(1, Ordering::Relaxed);
    }

    /// Back out a [`record_admitted`](Self::record_admitted) for a
    /// request that turned out to be rejected (queue send failed after
    /// admission) — counted as a rejection instead.
    #[inline]
    pub fn unrecord_admitted(&self, class: Priority) {
        self.admitted.fetch_sub(1, Ordering::Relaxed);
        self.admitted_by_class[class.idx()].fetch_sub(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn record_completion(&self, class: Priority, latency_us: u64, queue_us: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.completed_by_class[class.idx()].fetch_add(1, Ordering::Relaxed);
        let mut l = self.lat.lock().unwrap();
        l.latency.record_us(latency_us as f64);
        l.queue.record_us(queue_us as f64);
    }

    /// Count one request shed before execution ([`ResponseStatus::Expired`]
    /// or [`ResponseStatus::Cancelled`]; other statuses are not sheds).
    #[inline]
    pub fn record_shed(&self, status: &ResponseStatus) {
        match status {
            ResponseStatus::Expired => self.expired.fetch_add(1, Ordering::Relaxed),
            ResponseStatus::Cancelled => self.cancelled.fetch_add(1, Ordering::Relaxed),
            ResponseStatus::Ok | ResponseStatus::Error(_) => return,
        };
    }

    #[inline]
    pub fn record_batch(&self, requests: usize, padded_to: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(requests as u64, Ordering::Relaxed);
        self.padding_slots
            .fetch_add((padded_to - requests) as u64, Ordering::Relaxed);
    }

    /// One accepted connection starts being served (bumps the gauge too).
    #[inline]
    pub fn record_conn_accepted(&self) {
        self.conns_accepted.fetch_add(1, Ordering::Relaxed);
        self.conns_active.fetch_add(1, Ordering::Relaxed);
    }

    /// One connection finished; `on_error` marks protocol/IO failures and
    /// handler panics (clean client closes pass `false`).
    #[inline]
    pub fn record_conn_closed(&self, on_error: bool) {
        // fetch_update so a stray double-close cannot wrap the gauge
        let _ = self
            .conns_active
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
        if on_error {
            self.conns_closed_on_error.fetch_add(1, Ordering::Relaxed);
        }
    }

    #[inline]
    pub fn record_frame_in(&self) {
        self.frames_in.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn record_frame_out(&self) {
        self.frames_out.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn record_malformed_frame(&self) {
        self.frames_malformed.fetch_add(1, Ordering::Relaxed);
    }

    /// One batch panicked inside a worker's `catch_unwind` fence.
    #[inline]
    pub fn record_worker_panic(&self) {
        self.worker_panics.fetch_add(1, Ordering::Relaxed);
    }

    /// The supervisor respawned a replacement worker thread.
    #[inline]
    pub fn record_worker_restart(&self) {
        self.worker_restarts.fetch_add(1, Ordering::Relaxed);
    }

    /// The health breaker transitioned into `Open`.
    #[inline]
    pub fn record_breaker_open(&self) {
        self.breaker_opens.fetch_add(1, Ordering::Relaxed);
    }

    /// One submission shed with `RejectUnhealthy`. Counted in `rejected`
    /// too, so `admitted + rejected` still covers every submission.
    #[inline]
    pub fn record_breaker_shed(&self) {
        self.breaker_shed.fetch_add(1, Ordering::Relaxed);
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// One submission answered from a fresh resolved cache entry.
    #[inline]
    pub fn record_cache_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// One cache probe found no usable entry.
    #[inline]
    pub fn record_cache_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// One submission attached to an identical in-flight leader.
    #[inline]
    pub fn record_coalesced(&self) {
        self.coalesced.fetch_add(1, Ordering::Relaxed);
    }

    /// Publish the cache's current entry count (gauge, not a counter).
    #[inline]
    pub fn set_cache_size(&self, n: u64) {
        self.cache_size.store(n, Ordering::Relaxed);
    }

    /// One admitted request answered with a typed `Error` response (the
    /// router tier's transport failures land here; in-process serving
    /// records failures from the worker fence directly).
    #[inline]
    pub fn record_failed(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    /// The router tier forwarded one request to a cluster node.
    #[inline]
    pub fn record_forward(&self) {
        self.router_forwards.fetch_add(1, Ordering::Relaxed);
    }

    /// A forward was served by a non-primary replica.
    #[inline]
    pub fn record_failover(&self) {
        self.router_failovers.fetch_add(1, Ordering::Relaxed);
    }

    /// A submission found no live replica (shed at the door or failed on
    /// every replica). The door-shed path also records a rejection via
    /// [`record_rejected`](Self::record_rejected) so `admitted + rejected`
    /// still covers every submission.
    #[inline]
    pub fn record_no_healthy_replica(&self) {
        self.router_no_healthy.fetch_add(1, Ordering::Relaxed);
    }

    pub fn latency_quantile_us(&self, q: f64) -> f64 {
        self.lat.lock().unwrap().latency.quantile_us(q)
    }

    pub fn queue_quantile_us(&self, q: f64) -> f64 {
        self.lat.lock().unwrap().queue.quantile_us(q)
    }

    /// Batch quantile read: one lock acquisition for any number of
    /// quantiles (benches and snapshots read p50/p99/p999 together).
    pub fn latency_quantiles_us(&self, qs: &[f64]) -> Vec<f64> {
        self.lat.lock().unwrap().latency.quantiles(qs)
    }

    pub fn queue_quantiles_us(&self, qs: &[f64]) -> Vec<f64> {
        self.lat.lock().unwrap().queue.quantiles(qs)
    }

    /// Mean requests per executed batch (batching efficiency).
    pub fn mean_batch_fill(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn admitted_class(&self, p: Priority) -> u64 {
        self.admitted_by_class[p.idx()].load(Ordering::Relaxed)
    }

    pub fn completed_class(&self, p: Priority) -> u64 {
        self.completed_by_class[p.idx()].load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut by_class = [ClassStats::default(); 3];
        for p in Priority::ALL {
            by_class[p.idx()] = ClassStats {
                admitted: self.admitted_class(p),
                completed: self.completed_class(p),
            };
        }
        // one lock for all six quantiles (see LatencyHistogram::quantiles)
        let (lat_q, queue_q) = {
            let l = self.lat.lock().unwrap();
            (l.latency.quantiles(&[0.5, 0.99, 0.999]), l.queue.quantiles(&[0.5, 0.99, 0.999]))
        };
        MetricsSnapshot {
            admitted: self.admitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_requests: self.batched_requests.load(Ordering::Relaxed),
            padding_slots: self.padding_slots.load(Ordering::Relaxed),
            worker_panics: self.worker_panics.load(Ordering::Relaxed),
            worker_restarts: self.worker_restarts.load(Ordering::Relaxed),
            breaker_opens: self.breaker_opens.load(Ordering::Relaxed),
            breaker_shed: self.breaker_shed.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            cache_size: self.cache_size.load(Ordering::Relaxed),
            by_class,
            net: NetStats {
                conns_accepted: self.conns_accepted.load(Ordering::Relaxed),
                conns_active: self.conns_active.load(Ordering::Relaxed),
                conns_closed_on_error: self.conns_closed_on_error.load(Ordering::Relaxed),
                frames_in: self.frames_in.load(Ordering::Relaxed),
                frames_out: self.frames_out.load(Ordering::Relaxed),
                frames_malformed: self.frames_malformed.load(Ordering::Relaxed),
            },
            cluster: RouterStats {
                forwards: self.router_forwards.load(Ordering::Relaxed),
                failovers: self.router_failovers.load(Ordering::Relaxed),
                no_healthy_replica: self.router_no_healthy.load(Ordering::Relaxed),
                by_node: Vec::new(),
            },
            mean_batch_fill: self.mean_batch_fill(),
            latency_p50_us: lat_q[0],
            latency_p99_us: lat_q[1],
            latency_p999_us: lat_q[2],
            queue_p50_us: queue_q[0],
            queue_p99_us: queue_q[1],
            queue_p999_us: queue_q[2],
        }
    }

    pub fn report(&self) -> String {
        self.snapshot().report()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let m = Metrics::new();
        m.record_admitted(Priority::Standard);
        m.record_admitted(Priority::Standard);
        m.record_admitted(Priority::Interactive);
        m.record_batch(3, 8);
        m.record_completion(Priority::Standard, 1000, 100);
        m.record_completion(Priority::Interactive, 2000, 200);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.padding_slots.load(Ordering::Relaxed), 5);
        assert_eq!(m.mean_batch_fill(), 3.0);
        let r = m.report();
        assert!(r.contains("admitted=3"), "{r}");
        assert!(r.contains("interactive=1/1"), "{r}");
        assert!(m.latency_quantile_us(0.5) > 500.0);
    }

    #[test]
    fn snapshot_is_typed_and_consistent() {
        let m = Metrics::new();
        m.record_admitted(Priority::Bulk);
        m.record_admitted(Priority::Bulk);
        m.record_completion(Priority::Bulk, 500, 50);
        m.record_shed(&ResponseStatus::Expired);
        let s = m.snapshot();
        assert_eq!(s.admitted, 2);
        assert_eq!(s.expired, 1);
        assert_eq!(s.cancelled, 0);
        assert_eq!(s.class(Priority::Bulk), ClassStats { admitted: 2, completed: 1 });
        assert_eq!(s.class(Priority::Interactive), ClassStats::default());
        assert_eq!(s.answered(), 2); // 1 completed + 1 expired
        assert!(s.latency_p50_us > 0.0 && s.latency_p99_us >= s.latency_p50_us);
    }

    #[test]
    fn shed_counters_by_status() {
        let m = Metrics::new();
        m.record_shed(&ResponseStatus::Expired);
        m.record_shed(&ResponseStatus::Cancelled);
        m.record_shed(&ResponseStatus::Cancelled);
        m.record_shed(&ResponseStatus::Ok); // not a shed
        m.record_shed(&ResponseStatus::Error("x".into())); // not a shed
        assert_eq!(m.expired.load(Ordering::Relaxed), 1);
        assert_eq!(m.cancelled.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn unrecord_admitted_backs_out_both_counters() {
        let m = Metrics::new();
        m.record_admitted(Priority::Interactive);
        m.unrecord_admitted(Priority::Interactive);
        m.record_rejected();
        let s = m.snapshot();
        assert_eq!(s.admitted, 0);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.class(Priority::Interactive).admitted, 0);
    }

    #[test]
    fn fault_counters_flow_into_snapshot_and_report() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(
            (s.worker_panics, s.worker_restarts, s.breaker_opens, s.breaker_shed),
            (0, 0, 0, 0)
        );
        assert!(!s.report().contains("fault["), "no fault line when healthy");
        m.record_worker_panic();
        m.record_worker_restart();
        m.record_breaker_open();
        m.record_breaker_shed();
        m.record_breaker_shed();
        let s = m.snapshot();
        assert_eq!(s.worker_panics, 1);
        assert_eq!(s.worker_restarts, 1);
        assert_eq!(s.breaker_opens, 1);
        assert_eq!(s.breaker_shed, 2);
        assert_eq!(s.rejected, 2, "breaker sheds count as rejections");
        assert!(
            s.report().contains("fault[panics=1 restarts=1 breaker_opens=1 breaker_shed=2]"),
            "{}",
            s.report()
        );
    }

    #[test]
    fn empty_fill_is_zero() {
        assert_eq!(Metrics::new().mean_batch_fill(), 0.0);
    }

    #[test]
    fn router_counters_flow_into_snapshot_and_report() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().cluster, RouterStats::default());
        assert!(!m.report().contains("cluster["), "no cluster line without a router tier");
        m.record_forward();
        m.record_forward();
        m.record_forward();
        m.record_failover();
        m.record_no_healthy_replica();
        m.record_rejected(); // the door-shed path pairs these two
        let mut s = m.snapshot();
        assert_eq!(s.cluster.forwards, 3);
        assert_eq!(s.cluster.failovers, 1);
        assert_eq!(s.cluster.no_healthy_replica, 1);
        assert_eq!(s.rejected, 1);
        assert!(s.cluster.by_node.is_empty(), "bare sink has no node identity");
        assert!(
            s.report().contains("cluster[forwards=3 failovers=1 no_healthy=1]"),
            "{}",
            s.report()
        );
        // the router tier appends its per-node rows to the snapshot
        s.cluster.by_node = vec![
            NodeRouterStats { node: "n0".into(), forwards: 2, failovers: 0, no_healthy_replica: 1 },
            NodeRouterStats { node: "n1".into(), forwards: 1, failovers: 1, no_healthy_replica: 0 },
        ];
        assert!(
            s.report().contains("cluster[forwards=3 failovers=1 no_healthy=1 n0=2/0/1 n1=1/1/0]"),
            "{}",
            s.report()
        );
    }

    #[test]
    fn net_counters_flow_into_snapshot_and_report() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().net, NetStats::default());
        assert!(!m.report().contains("net["), "no net line without a front end");
        m.record_conn_accepted();
        m.record_conn_accepted();
        m.record_frame_in();
        m.record_frame_in();
        m.record_frame_out();
        m.record_malformed_frame();
        m.record_conn_closed(true);
        let s = m.snapshot();
        assert_eq!(
            s.net,
            NetStats {
                conns_accepted: 2,
                conns_active: 1,
                conns_closed_on_error: 1,
                frames_in: 2,
                frames_out: 1,
                frames_malformed: 1,
            }
        );
        assert!(s.report().contains("net[conns=1/2"), "{}", s.report());
        // clean close: gauge drops, error counter untouched
        m.record_conn_closed(false);
        let s = m.snapshot();
        assert_eq!(s.net.conns_active, 0);
        assert_eq!(s.net.conns_closed_on_error, 1);
        // stray extra close must not wrap the gauge
        m.record_conn_closed(false);
        assert_eq!(m.snapshot().net.conns_active, 0);
    }

    #[test]
    fn cache_counters_flow_into_snapshot_report_and_served() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!((s.cache_hits, s.cache_misses, s.coalesced, s.cache_size), (0, 0, 0, 0));
        assert!(!s.report().contains("cache["), "no cache line when the cache is off");
        // one admitted+completed execution, then 2 hits + 1 coalesced on it
        m.record_admitted(Priority::Standard);
        m.record_completion(Priority::Standard, 100, 10);
        m.record_cache_miss();
        m.record_cache_hit();
        m.record_cache_hit();
        m.record_coalesced();
        m.set_cache_size(1);
        let s = m.snapshot();
        assert_eq!((s.cache_hits, s.cache_misses, s.coalesced, s.cache_size), (2, 1, 1, 1));
        assert_eq!(s.answered(), s.admitted, "hits/coalesced never touch the core invariant");
        assert_eq!(s.served(), 4, "1 answered + 2 hits + 1 coalesced");
        assert!(
            s.report().contains("cache[hits=2 misses=1 coalesced=1 size=1]"),
            "{}",
            s.report()
        );
        m.set_cache_size(0);
        assert_eq!(m.snapshot().cache_size, 0, "size is a gauge, not a counter");
    }

    #[test]
    fn batch_quantiles_match_scalar_reads_including_p999() {
        let m = Metrics::new();
        for us in [100, 1_000, 10_000, 100_000] {
            m.record_completion(Priority::Standard, us, us / 10);
        }
        let qs = [0.5, 0.99, 0.999];
        let lat = m.latency_quantiles_us(&qs);
        let queue = m.queue_quantiles_us(&qs);
        for (i, &q) in qs.iter().enumerate() {
            assert_eq!(lat[i], m.latency_quantile_us(q));
            assert_eq!(queue[i], m.queue_quantile_us(q));
        }
        let s = m.snapshot();
        assert_eq!(s.latency_p999_us, lat[2]);
        assert_eq!(s.queue_p999_us, queue[2]);
        assert!(s.latency_p99_us <= s.latency_p999_us);
    }
}
