//! Dynamic batcher: trade a bounded wait for batch fill.
//!
//! The classic serving batcher (vLLM/Triton style, simplified to
//! fixed-shape classification): block for the first request, then keep
//! draining the queue until either `max_batch` requests are collected or
//! `max_wait` has elapsed since the first one. Requests for different
//! models are never mixed in one batch.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::request::Request;

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// A formed batch: same-model requests, ready for routing.
#[derive(Debug)]
pub struct Batch {
    /// shared with every request in the batch (refcount clone, no alloc)
    pub model: Arc<str>,
    pub requests: Vec<Request>,
    pub formed_at: Instant,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// Pulls requests off a channel, forms batches.
pub struct DynamicBatcher {
    cfg: BatcherConfig,
    rx: Receiver<Request>,
    /// same-model constraint: requests for *other* models wait here
    stash: VecDeque<Request>,
    /// cooperative shutdown: senders may outlive the server (cloned
    /// handles), so channel-closure alone cannot signal exit
    stop: Arc<AtomicBool>,
}

impl DynamicBatcher {
    pub fn new(cfg: BatcherConfig, rx: Receiver<Request>) -> DynamicBatcher {
        Self::with_stop(cfg, rx, Arc::new(AtomicBool::new(false)))
    }

    pub fn with_stop(
        cfg: BatcherConfig,
        rx: Receiver<Request>,
        stop: Arc<AtomicBool>,
    ) -> DynamicBatcher {
        DynamicBatcher { cfg, rx, stash: VecDeque::new(), stop }
    }

    /// Form the next batch. `None` when shutdown is signalled (or the
    /// channel closed) and no requests remain.
    pub fn next_batch(&mut self) -> Option<Batch> {
        // seed: stashed request first, else poll the channel (bounded
        // waits so the stop flag is observed)
        let first = match self.stash.pop_front() {
            Some(r) => r,
            None => loop {
                if self.stop.load(Ordering::Acquire) {
                    // drain anything already queued before exiting
                    match self.rx.try_recv() {
                        Ok(r) => break r,
                        Err(_) => return None,
                    }
                }
                match self.rx.recv_timeout(Duration::from_millis(20)) {
                    Ok(r) => break r,
                    Err(RecvTimeoutError::Timeout) => continue,
                    Err(RecvTimeoutError::Disconnected) => return None,
                }
            },
        };
        let model = first.model.clone();
        let deadline = Instant::now() + self.cfg.max_wait;
        let mut requests = vec![first];

        // take same-model requests; keep the rest stashed in arrival
        // order. Single in-place rotation pass — each element is popped
        // once and either joins the batch or returns to the back, so the
        // stash buffer is reused with zero allocation. (The seed used
        // `VecDeque::remove` under a scan, which shifts the tail once per
        // hit — O(n²) when many models interleave under fan-in.)
        for _ in 0..self.stash.len() {
            let r = self.stash.pop_front().expect("bounded by len");
            if requests.len() < self.cfg.max_batch && r.model == model {
                requests.push(r);
            } else {
                self.stash.push_back(r);
            }
        }
        while requests.len() < self.cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(r) if r.model == model => requests.push(r),
                Ok(r) => self.stash.push_back(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(Batch { model, requests, formed_at: Instant::now() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{RequestId, Response};
    use std::sync::mpsc;

    fn req(id: u64, model: &str) -> (Request, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                id: RequestId(id),
                model: Arc::from(model),
                inputs: vec![crate::backend::Value::I32(vec![0; 4])],
                submitted: Instant::now(),
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn fills_to_max_batch_without_waiting() {
        let (tx, rx) = mpsc::channel();
        let mut b = DynamicBatcher::new(
            BatcherConfig { max_batch: 3, max_wait: Duration::from_secs(10) },
            rx,
        );
        let mut keep = Vec::new();
        for i in 0..5 {
            let (r, resp) = req(i, "m");
            tx.send(r).unwrap();
            keep.push(resp);
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(&*batch.model, "m");
        let batch2 = b.next_batch().unwrap();
        assert_eq!(batch2.len(), 2);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (tx, rx) = mpsc::channel();
        let mut b = DynamicBatcher::new(
            BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) },
            rx,
        );
        let (r, _resp) = req(1, "m");
        tx.send(r).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn models_never_mixed() {
        let (tx, rx) = mpsc::channel();
        let mut b = DynamicBatcher::new(
            BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) },
            rx,
        );
        let mut keep = Vec::new();
        for (i, m) in [(1, "a"), (2, "b"), (3, "a"), (4, "b")] {
            let (r, resp) = req(i, m);
            tx.send(r).unwrap();
            keep.push(resp);
        }
        let b1 = b.next_batch().unwrap();
        assert_eq!(&*b1.model, "a");
        assert_eq!(b1.len(), 2);
        let b2 = b.next_batch().unwrap();
        assert_eq!(&*b2.model, "b");
        assert_eq!(b2.len(), 2);
    }

    #[test]
    fn stash_drain_preserves_per_model_arrival_order() {
        let (tx, rx) = mpsc::channel();
        let mut b = DynamicBatcher::new(
            BatcherConfig { max_batch: 2, max_wait: Duration::from_millis(2) },
            rx,
        );
        let mut keep = Vec::new();
        for (i, m) in [(1, "a"), (2, "b"), (3, "a"), (4, "b"), (5, "a"), (6, "b")] {
            let (r, resp) = req(i, m);
            tx.send(r).unwrap();
            keep.push(resp);
        }
        drop(tx);
        let mut total = 0;
        let (mut last_a, mut last_b) = (0u64, 0u64);
        while let Some(batch) = b.next_batch() {
            for r in &batch.requests {
                total += 1;
                let last = if &*batch.model == "a" { &mut last_a } else { &mut last_b };
                assert!(r.id.0 > *last, "arrival order violated: {:?}", r.id);
                *last = r.id.0;
            }
        }
        assert_eq!(total, 6, "no request lost");
    }

    #[test]
    fn shutdown_returns_none() {
        let (tx, rx) = mpsc::channel::<Request>();
        drop(tx);
        let mut b = DynamicBatcher::new(BatcherConfig::default(), rx);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn stashed_requests_preserved_across_batches() {
        let (tx, rx) = mpsc::channel();
        let mut b = DynamicBatcher::new(
            BatcherConfig { max_batch: 2, max_wait: Duration::from_millis(2) },
            rx,
        );
        let mut keep = Vec::new();
        for (i, m) in [(1, "a"), (2, "b"), (3, "b"), (4, "b")] {
            let (r, resp) = req(i, m);
            tx.send(r).unwrap();
            keep.push(resp);
        }
        drop(tx);
        let sizes: Vec<(String, usize)> = std::iter::from_fn(|| b.next_batch())
            .map(|batch| (batch.model.to_string(), batch.len()))
            .collect();
        let total: usize = sizes.iter().map(|(_, n)| n).sum();
        assert_eq!(total, 4, "no request lost: {sizes:?}");
    }
}
