//! Dynamic batcher: trade a bounded wait for batch fill — QoS-aware.
//!
//! The classic serving batcher (vLLM/Triton style, simplified to
//! fixed-shape classification), extended with the v2 lifecycle rules:
//!
//! * everything already queued is pulled into the stash before a batch is
//!   seeded, so scheduling decisions see the whole backlog;
//! * the seed is the **highest-priority** stashed request (FIFO within a
//!   class): an `Interactive` request is never left waiting while a
//!   `Bulk` request seeds a batch;
//! * batch fill drains same-model stash entries in (priority, arrival)
//!   order, then waits up to `max_wait` for stragglers;
//! * cancelled or deadline-expired requests are shed at formation time —
//!   answered with [`Response::cancelled`]/[`Response::expired`] and
//!   never handed to a worker.
//!
//! Requests for different models are never mixed in one batch.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::admission::Admission;
use super::metrics::Metrics;
use super::request::{Priority, Request};

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Idle-poll interval for the seed-wait loop: bounded by `max_wait` so a
/// sub-20ms batching config is not quantized by a hardcoded poll (the
/// stop flag — and with it shutdown — is observed once per poll), with a
/// 1ms floor so an aggressive `max_wait` cannot turn the idle loop into
/// a busy spin.
fn idle_poll(max_wait: Duration) -> Duration {
    max_wait
        .min(Duration::from_millis(20))
        .max(Duration::from_millis(1))
}

/// A formed batch: same-model requests, ready for routing.
#[derive(Debug)]
pub struct Batch {
    /// shared with every request in the batch (refcount clone, no alloc)
    pub model: Arc<str>,
    pub requests: Vec<Request>,
    pub formed_at: Instant,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// Server-side bookkeeping for requests the batcher sheds: shed counters
/// plus the admission slot the request still holds. A standalone batcher
/// (unit tests, offline replay) runs without one — shed requests are
/// still answered, just not accounted.
struct ShedSink {
    metrics: Arc<Metrics>,
    admission: Arc<Admission>,
}

/// Pulls requests off a channel, forms batches.
pub struct DynamicBatcher {
    cfg: BatcherConfig,
    rx: Receiver<Request>,
    /// the visible backlog: same-model constraint and priority seeding
    /// both operate on this queue (arrival order preserved within it)
    stash: VecDeque<Request>,
    /// cooperative shutdown: senders may outlive the server (cloned
    /// handles), so channel-closure alone cannot signal exit
    stop: Arc<AtomicBool>,
    shed: Option<ShedSink>,
}

impl DynamicBatcher {
    pub fn new(cfg: BatcherConfig, rx: Receiver<Request>) -> DynamicBatcher {
        Self::with_stop(cfg, rx, Arc::new(AtomicBool::new(false)))
    }

    pub fn with_stop(
        cfg: BatcherConfig,
        rx: Receiver<Request>,
        stop: Arc<AtomicBool>,
    ) -> DynamicBatcher {
        DynamicBatcher { cfg, rx, stash: VecDeque::new(), stop, shed: None }
    }

    /// Attach the server's metrics + admission so shed requests release
    /// their in-flight slot and are counted (`Server::start` wires this).
    pub fn with_shed_accounting(
        mut self,
        metrics: Arc<Metrics>,
        admission: Arc<Admission>,
    ) -> DynamicBatcher {
        self.shed = Some(ShedSink { metrics, admission });
        self
    }

    /// Stash depth per priority class (observability; lets tests assert
    /// the "never seed Bulk while Interactive is stashed" invariant).
    pub fn stash_depth_by_class(&self) -> [usize; 3] {
        let mut depth = [0usize; 3];
        for r in &self.stash {
            depth[r.priority.idx()] += 1;
        }
        depth
    }

    /// Answer a shed request and release its accounting (metrics +
    /// admission slot) when a sink is attached.
    fn answer_shed(&self, r: Request, resp: super::request::Response) {
        if let Some(sink) = &self.shed {
            sink.metrics.record_shed(&resp.status);
            sink.admission.complete(r.priority);
        }
        let _ = r.reply.send(resp);
    }

    /// One rotation pass over the stash: shed every cancelled/expired
    /// entry (they must not squat on admission slots or per-class
    /// budgets while a backlog drains) and count the survivors per
    /// class, so `fill` can skip classes with nothing stashed.
    fn reap_and_count(&mut self, now: Instant) -> [usize; 3] {
        let mut count = [0usize; 3];
        for _ in 0..self.stash.len() {
            let r = self.stash.pop_front().expect("bounded by len");
            match r.shed_response(now) {
                Some(resp) => self.answer_shed(r, resp),
                None => {
                    count[r.priority.idx()] += 1;
                    self.stash.push_back(r);
                }
            }
        }
        count
    }

    /// Form the next batch. `None` when shutdown is signalled (or the
    /// channel closed) and no requests remain.
    pub fn next_batch(&mut self) -> Option<Batch> {
        loop {
            // pull the whole queued backlog into the stash: priority
            // seeding needs a global view, not channel arrival order
            while let Ok(r) = self.rx.try_recv() {
                self.stash.push_back(r);
            }
            let now = Instant::now();
            // shed every dead entry (releasing its admission slot), then
            // seed with the best (priority class, arrival order) survivor
            let class_counts = self.reap_and_count(now);
            if let Some(first) = self.take_seed(now) {
                return Some(self.fill(first, class_counts));
            }
            // stash is empty here: idle-wait for the next arrival with a
            // bounded poll so the stop flag is observed promptly
            if self.stop.load(Ordering::Acquire) {
                // drain anything that raced the flag before exiting
                match self.rx.try_recv() {
                    Ok(r) => self.stash.push_back(r),
                    Err(_) => return None,
                }
                continue;
            }
            match self.rx.recv_timeout(idle_poll(self.cfg.max_wait)) {
                Ok(r) => self.stash.push_back(r),
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => return None,
            }
        }
    }

    /// Remove and return the seed: the earliest-arrived request of the
    /// most urgent class present, shedding dead entries encountered on
    /// the way. `None` if the stash empties out.
    fn take_seed(&mut self, now: Instant) -> Option<Request> {
        loop {
            let mut best: Option<(Priority, usize)> = None;
            for (i, r) in self.stash.iter().enumerate() {
                if best.map_or(true, |(bp, _)| r.priority < bp) {
                    best = Some((r.priority, i));
                    if r.priority == Priority::Interactive {
                        break; // nothing outranks the first Interactive
                    }
                }
            }
            let (_, i) = best?;
            let r = self.stash.remove(i).expect("index from scan");
            match r.shed_response(now) {
                Some(resp) => self.answer_shed(r, resp),
                None => return Some(r),
            }
        }
    }

    /// Fill a batch around `first`: same-model stash entries in
    /// (priority, arrival) order, then a bounded wait for stragglers.
    /// `class_counts` is the per-class stash census from
    /// [`reap_and_count`](Self::reap_and_count) (the seed already
    /// removed); passes over classes with nothing stashed are skipped.
    fn fill(&mut self, first: Request, mut class_counts: [usize; 3]) -> Batch {
        class_counts[first.priority.idx()] =
            class_counts[first.priority.idx()].saturating_sub(1);
        let model = first.model.clone();
        let deadline = Instant::now() + self.cfg.max_wait;
        let mut requests = vec![first];

        // Priority passes over the stash. Each pass is the PR 2 in-place
        // rotation (pop each element once; it either joins the batch or
        // returns to the back — zero allocation, order of the remainder
        // preserved), run once per class so Interactive stragglers board
        // before Bulk even when they arrived later.
        for class in Priority::ALL {
            if requests.len() >= self.cfg.max_batch {
                break;
            }
            if class_counts[class.idx()] == 0 {
                continue; // nothing of this class stashed — skip the pass
            }
            for _ in 0..self.stash.len() {
                let r = self.stash.pop_front().expect("bounded by len");
                if requests.len() < self.cfg.max_batch
                    && r.priority == class
                    && r.model == model
                {
                    match r.shed_response(Instant::now()) {
                        Some(resp) => self.answer_shed(r, resp),
                        None => requests.push(r),
                    }
                } else {
                    self.stash.push_back(r);
                }
            }
        }
        // bounded wait for same-model stragglers
        while requests.len() < self.cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(r) if r.model == model => match r.shed_response(Instant::now()) {
                    Some(resp) => self.answer_shed(r, resp),
                    None => requests.push(r),
                },
                Ok(r) => self.stash.push_back(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Batch { model, requests, formed_at: Instant::now() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{ReplySlot, RequestId, Response, ResponseStatus};
    use std::sync::mpsc;

    fn req(id: u64, model: &str) -> (Request, mpsc::Receiver<Response>) {
        req_qos(id, model, Priority::Standard, None)
    }

    fn req_qos(
        id: u64,
        model: &str,
        priority: Priority,
        deadline: Option<Duration>,
    ) -> (Request, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        let now = Instant::now();
        (
            Request {
                id: RequestId(id),
                model: Arc::from(model),
                inputs: vec![crate::backend::Value::I32(vec![0; 4])],
                submitted: now,
                priority,
                deadline: deadline.map(|d| now + d),
                cancelled: Arc::new(AtomicBool::new(false)),
                client_tag: None,
                reply: ReplySlot::new(tx),
            },
            rx,
        )
    }

    #[test]
    fn idle_poll_tracks_max_wait_with_floor_and_cap() {
        assert_eq!(idle_poll(Duration::from_millis(2)), Duration::from_millis(2));
        assert_eq!(idle_poll(Duration::from_millis(100)), Duration::from_millis(20));
        assert_eq!(idle_poll(Duration::from_micros(10)), Duration::from_millis(1));
        assert_eq!(idle_poll(Duration::from_millis(20)), Duration::from_millis(20));
    }

    #[test]
    fn fills_to_max_batch_without_waiting() {
        let (tx, rx) = mpsc::channel();
        let mut b = DynamicBatcher::new(
            BatcherConfig { max_batch: 3, max_wait: Duration::from_millis(200) },
            rx,
        );
        let mut keep = Vec::new();
        for i in 0..5 {
            let (r, resp) = req(i, "m");
            tx.send(r).unwrap();
            keep.push(resp);
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(&*batch.model, "m");
        let batch2 = b.next_batch().unwrap();
        assert_eq!(batch2.len(), 2);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (tx, rx) = mpsc::channel();
        let mut b = DynamicBatcher::new(
            BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) },
            rx,
        );
        let (r, _resp) = req(1, "m");
        tx.send(r).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn sub_poll_max_wait_forms_batches_at_its_own_cadence() {
        // satellite regression: with the idle poll hardcoded at 20ms, a
        // 2ms max_wait config had its shutdown/flush responsiveness
        // quantized to the poll. The deadline flush above plus this
        // stop-latency bound pin the ~2ms cadence. Best-of-3 so a single
        // descheduling hiccup on a loaded CI runner cannot flake the
        // assert — under the old 20ms quantum every attempt is slow.
        let mut best = Duration::MAX;
        for _ in 0..3 {
            let (_tx, rx) = mpsc::channel::<Request>();
            let stop = Arc::new(AtomicBool::new(false));
            let mut b = DynamicBatcher::with_stop(
                BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) },
                rx,
                stop.clone(),
            );
            let h = std::thread::spawn(move || b.next_batch());
            // let the batcher settle into its idle poll, then signal stop
            std::thread::sleep(Duration::from_millis(10));
            let t0 = Instant::now();
            stop.store(true, Ordering::Release);
            assert!(h.join().unwrap().is_none());
            best = best.min(t0.elapsed());
        }
        // observed within ~one 2ms poll; far below the old 20ms quantum
        assert!(
            best < Duration::from_millis(15),
            "stop took {best:?} at best, idle poll not derived from max_wait"
        );
    }

    #[test]
    fn models_never_mixed() {
        let (tx, rx) = mpsc::channel();
        let mut b = DynamicBatcher::new(
            BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) },
            rx,
        );
        let mut keep = Vec::new();
        for (i, m) in [(1, "a"), (2, "b"), (3, "a"), (4, "b")] {
            let (r, resp) = req(i, m);
            tx.send(r).unwrap();
            keep.push(resp);
        }
        let b1 = b.next_batch().unwrap();
        assert_eq!(&*b1.model, "a");
        assert_eq!(b1.len(), 2);
        let b2 = b.next_batch().unwrap();
        assert_eq!(&*b2.model, "b");
        assert_eq!(b2.len(), 2);
    }

    #[test]
    fn interactive_seeds_before_earlier_bulk() {
        // bulk request arrives FIRST; the later interactive one must
        // still seed the first batch
        let (tx, rx) = mpsc::channel();
        let mut b = DynamicBatcher::new(
            BatcherConfig { max_batch: 1, max_wait: Duration::ZERO },
            rx,
        );
        let mut keep = Vec::new();
        for (i, p) in [
            (1, Priority::Bulk),
            (2, Priority::Standard),
            (3, Priority::Interactive),
        ] {
            let (r, resp) = req_qos(i, "m", p, None);
            tx.send(r).unwrap();
            keep.push(resp);
        }
        drop(tx);
        let order: Vec<u64> = std::iter::from_fn(|| b.next_batch())
            .map(|batch| batch.requests[0].id.0)
            .collect();
        assert_eq!(order, vec![3, 2, 1], "seed order must follow class urgency");
    }

    #[test]
    fn batch_fill_prefers_higher_class_stragglers() {
        // seed is interactive; the batch's remaining slot must go to the
        // other interactive request even though bulk arrived earlier
        let (tx, rx) = mpsc::channel();
        let mut b = DynamicBatcher::new(
            BatcherConfig { max_batch: 2, max_wait: Duration::ZERO },
            rx,
        );
        let mut keep = Vec::new();
        for (i, p) in [
            (1, Priority::Interactive),
            (2, Priority::Bulk),
            (3, Priority::Interactive),
        ] {
            let (r, resp) = req_qos(i, "m", p, None);
            tx.send(r).unwrap();
            keep.push(resp);
        }
        let b1 = b.next_batch().unwrap();
        let ids: Vec<u64> = b1.requests.iter().map(|r| r.id.0).collect();
        assert_eq!(ids, vec![1, 3]);
        assert_eq!(b.stash_depth_by_class(), [0, 0, 1]);
    }

    #[test]
    fn expired_requests_are_shed_at_formation() {
        let (tx, rx) = mpsc::channel();
        let mut b = DynamicBatcher::new(
            BatcherConfig { max_batch: 8, max_wait: Duration::ZERO },
            rx,
        );
        let (dead, dead_rx) = req_qos(1, "m", Priority::Standard, Some(Duration::ZERO));
        let (live, _live_rx) = req_qos(2, "m", Priority::Standard, None);
        tx.send(dead).unwrap();
        tx.send(live).unwrap();
        std::thread::sleep(Duration::from_millis(2)); // let the deadline pass
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch.requests[0].id.0, 2);
        let shed = dead_rx.try_recv().unwrap();
        assert_eq!(shed.status, ResponseStatus::Expired);
    }

    #[test]
    fn cancelled_requests_are_shed_at_formation() {
        let (tx, rx) = mpsc::channel();
        let mut b = DynamicBatcher::new(
            BatcherConfig { max_batch: 8, max_wait: Duration::ZERO },
            rx,
        );
        let (gone, gone_rx) = req_qos(1, "m", Priority::Interactive, None);
        let flag = gone.cancelled.clone();
        let (live, _live_rx) = req_qos(2, "m", Priority::Bulk, None);
        tx.send(gone).unwrap();
        tx.send(live).unwrap();
        flag.store(true, Ordering::Release);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch.requests[0].id.0, 2);
        assert_eq!(gone_rx.try_recv().unwrap().status, ResponseStatus::Cancelled);
    }

    #[test]
    fn dead_low_class_entries_shed_while_backlog_drains() {
        // review regression: an expired Bulk request queued behind a
        // Standard backlog must be shed at the NEXT formation pass (so it
        // releases its admission slot), not when its class is finally
        // seeded after the drain
        let (tx, rx) = mpsc::channel();
        let mut b = DynamicBatcher::new(
            BatcherConfig { max_batch: 1, max_wait: Duration::ZERO },
            rx,
        );
        let mut keep = Vec::new();
        for i in 0..3 {
            let (r, resp) = req_qos(i, "m", Priority::Standard, None);
            tx.send(r).unwrap();
            keep.push(resp);
        }
        let (dead_bulk, dead_rx) = req_qos(9, "m", Priority::Bulk, Some(Duration::ZERO));
        tx.send(dead_bulk).unwrap();
        std::thread::sleep(Duration::from_millis(2));
        // first formation: seeds Standard 0, but the dead Bulk is already
        // reaped out of the stash
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests[0].id.0, 0);
        assert_eq!(dead_rx.try_recv().unwrap().status, ResponseStatus::Expired);
        assert_eq!(b.stash_depth_by_class(), [0, 2, 0]);
    }

    #[test]
    fn shed_accounting_releases_admission_and_counts() {
        let metrics = Arc::new(Metrics::new());
        let admission = Arc::new(Admission::depth_only(4));
        let (tx, rx) = mpsc::channel();
        let mut b = DynamicBatcher::new(
            BatcherConfig { max_batch: 8, max_wait: Duration::ZERO },
            rx,
        )
        .with_shed_accounting(metrics.clone(), admission.clone());
        assert_eq!(
            admission.try_admit(Priority::Standard),
            crate::coordinator::AdmissionDecision::Admit
        );
        let (dead, dead_rx) = req_qos(1, "m", Priority::Standard, Some(Duration::ZERO));
        tx.send(dead).unwrap();
        drop(tx);
        std::thread::sleep(Duration::from_millis(2));
        assert!(b.next_batch().is_none(), "only request was shed");
        assert_eq!(dead_rx.try_recv().unwrap().status, ResponseStatus::Expired);
        assert_eq!(metrics.expired.load(Ordering::Relaxed), 1);
        assert_eq!(admission.inflight(), 0, "shed must release the slot");
    }

    #[test]
    fn stash_drain_preserves_per_model_arrival_order() {
        let (tx, rx) = mpsc::channel();
        let mut b = DynamicBatcher::new(
            BatcherConfig { max_batch: 2, max_wait: Duration::from_millis(2) },
            rx,
        );
        let mut keep = Vec::new();
        for (i, m) in [(1, "a"), (2, "b"), (3, "a"), (4, "b"), (5, "a"), (6, "b")] {
            let (r, resp) = req(i, m);
            tx.send(r).unwrap();
            keep.push(resp);
        }
        drop(tx);
        let mut total = 0;
        let (mut last_a, mut last_b) = (0u64, 0u64);
        while let Some(batch) = b.next_batch() {
            for r in &batch.requests {
                total += 1;
                let last = if &*batch.model == "a" { &mut last_a } else { &mut last_b };
                assert!(r.id.0 > *last, "arrival order violated: {:?}", r.id);
                *last = r.id.0;
            }
        }
        assert_eq!(total, 6, "no request lost");
    }

    #[test]
    fn shutdown_returns_none() {
        let (tx, rx) = mpsc::channel::<Request>();
        drop(tx);
        let mut b = DynamicBatcher::new(BatcherConfig::default(), rx);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn stashed_requests_preserved_across_batches() {
        let (tx, rx) = mpsc::channel();
        let mut b = DynamicBatcher::new(
            BatcherConfig { max_batch: 2, max_wait: Duration::from_millis(2) },
            rx,
        );
        let mut keep = Vec::new();
        for (i, m) in [(1, "a"), (2, "b"), (3, "b"), (4, "b")] {
            let (r, resp) = req(i, m);
            tx.send(r).unwrap();
            keep.push(resp);
        }
        drop(tx);
        let sizes: Vec<(String, usize)> = std::iter::from_fn(|| b.next_batch())
            .map(|batch| (batch.model.to_string(), batch.len()))
            .collect();
        let total: usize = sizes.iter().map(|(_, n)| n).sum();
        assert_eq!(total, 4, "no request lost: {sizes:?}");
    }
}
