//! Admission control: bound the queue, shed load early.
//!
//! Two mechanisms compose (either can reject):
//! * **queue depth bound** — reject when in-flight requests exceed a cap
//!   (keeps tail latency bounded under overload);
//! * **token bucket** — smooth sustained rate to what the backend can
//!   actually serve (capacity = burst tolerance).

use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionDecision {
    Admit,
    RejectQueueFull,
    RejectRateLimited,
}

#[derive(Debug)]
struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Thread-safe admission controller.
#[derive(Debug)]
pub struct Admission {
    max_inflight: i64,
    inflight: AtomicI64,
    /// requests/second sustained; f64::INFINITY disables rate limiting
    rate: f64,
    burst: f64,
    bucket: Mutex<Bucket>,
}

impl Admission {
    pub fn new(max_inflight: usize, rate_per_sec: f64, burst: usize) -> Admission {
        Admission {
            max_inflight: max_inflight as i64,
            inflight: AtomicI64::new(0),
            rate: rate_per_sec,
            burst: burst as f64,
            bucket: Mutex::new(Bucket { tokens: burst as f64, last: Instant::now() }),
        }
    }

    /// Unlimited-rate controller with only a queue bound.
    pub fn depth_only(max_inflight: usize) -> Admission {
        Admission::new(max_inflight, f64::INFINITY, 1)
    }

    /// Try to admit one request. On `Admit`, the caller MUST later call
    /// [`complete`](Self::complete) exactly once.
    pub fn try_admit(&self) -> AdmissionDecision {
        // optimistic in-flight increment; back out on reject
        let inflight = self.inflight.fetch_add(1, Ordering::AcqRel) + 1;
        if inflight > self.max_inflight {
            self.inflight.fetch_sub(1, Ordering::AcqRel);
            return AdmissionDecision::RejectQueueFull;
        }
        if self.rate.is_finite() {
            let mut b = self.bucket.lock().unwrap();
            let now = Instant::now();
            let dt = now.duration_since(b.last).as_secs_f64();
            b.tokens = (b.tokens + dt * self.rate).min(self.burst);
            b.last = now;
            if b.tokens < 1.0 {
                drop(b);
                self.inflight.fetch_sub(1, Ordering::AcqRel);
                return AdmissionDecision::RejectRateLimited;
            }
            b.tokens -= 1.0;
        }
        AdmissionDecision::Admit
    }

    /// Mark one admitted request finished.
    pub fn complete(&self) {
        let prev = self.inflight.fetch_sub(1, Ordering::AcqRel);
        debug_assert!(prev > 0, "complete() without admit()");
    }

    pub fn inflight(&self) -> i64 {
        self.inflight.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_bound_rejects_then_recovers() {
        let a = Admission::depth_only(2);
        assert_eq!(a.try_admit(), AdmissionDecision::Admit);
        assert_eq!(a.try_admit(), AdmissionDecision::Admit);
        assert_eq!(a.try_admit(), AdmissionDecision::RejectQueueFull);
        a.complete();
        assert_eq!(a.try_admit(), AdmissionDecision::Admit);
        assert_eq!(a.inflight(), 2);
    }

    #[test]
    fn rate_limit_caps_burst() {
        // 1 req/s, burst 3: first 3 admit, 4th rejects immediately
        let a = Admission::new(100, 1.0, 3);
        let mut admitted = 0;
        for _ in 0..5 {
            if a.try_admit() == AdmissionDecision::Admit {
                admitted += 1;
            }
        }
        assert_eq!(admitted, 3);
    }

    #[test]
    fn rate_limit_refills_over_time() {
        let a = Admission::new(100, 1000.0, 1);
        assert_eq!(a.try_admit(), AdmissionDecision::Admit);
        assert_eq!(a.try_admit(), AdmissionDecision::RejectRateLimited);
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert_eq!(a.try_admit(), AdmissionDecision::Admit);
    }

    #[test]
    fn inflight_never_negative_under_contention() {
        let a = std::sync::Arc::new(Admission::depth_only(8));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let a = a.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    if a.try_admit() == AdmissionDecision::Admit {
                        a.complete();
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.inflight(), 0);
    }
}
