//! Admission control: bound the queue, shed load early — per class.
//!
//! Three mechanisms compose (any can reject):
//! * **queue depth bound** — reject when in-flight requests exceed a cap
//!   (keeps tail latency bounded under overload);
//! * **per-class budget** — each [`Priority`] class has its own in-flight
//!   cap; by default `Bulk` is capped at a quarter of `max_inflight`, so
//!   a bulk backlog can never fill the queue and starve `Interactive`;
//! * **token bucket** — smooth sustained rate to what the backend can
//!   actually serve (capacity = burst tolerance).

use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::request::Priority;

/// Outcome of [`Admission::try_admit`]. Rejections carry the class that
/// was turned away, so callers (and metrics) can tell a shed bulk
/// backfill from a refused interactive request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionDecision {
    Admit,
    RejectQueueFull(Priority),
    RejectRateLimited(Priority),
    /// Shed by the backend-health circuit breaker
    /// ([`Breaker`](super::health::Breaker)): the backend is failing and
    /// queueing more work behind it would only strand tickets. Explicitly
    /// retryable — the breaker probes its way back to `Closed` and healthy
    /// traffic resumes without operator action.
    RejectUnhealthy(Priority),
}

#[derive(Debug)]
struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Thread-safe admission controller.
#[derive(Debug)]
pub struct Admission {
    max_inflight: i64,
    /// per-class in-flight caps, indexed by [`Priority::idx`]
    class_caps: [i64; 3],
    inflight: AtomicI64,
    inflight_class: [AtomicI64; 3],
    /// requests/second sustained; f64::INFINITY disables rate limiting
    rate: f64,
    burst: f64,
    bucket: Mutex<Bucket>,
}

impl Admission {
    /// Default class budgets for a total cap: `Interactive`/`Standard`
    /// may use the whole queue, `Bulk` at most a quarter of it (≥ 1).
    fn default_class_caps(max_inflight: usize) -> [i64; 3] {
        let bulk = (max_inflight / 4).max(1) as i64;
        [max_inflight as i64, max_inflight as i64, bulk]
    }

    pub fn new(max_inflight: usize, rate_per_sec: f64, burst: usize) -> Admission {
        Admission {
            max_inflight: max_inflight as i64,
            class_caps: Self::default_class_caps(max_inflight),
            inflight: AtomicI64::new(0),
            inflight_class: [AtomicI64::new(0), AtomicI64::new(0), AtomicI64::new(0)],
            rate: rate_per_sec,
            burst: burst as f64,
            bucket: Mutex::new(Bucket { tokens: burst as f64, last: Instant::now() }),
        }
    }

    /// Unlimited-rate controller with only depth + class bounds.
    pub fn depth_only(max_inflight: usize) -> Admission {
        Admission::new(max_inflight, f64::INFINITY, 1)
    }

    /// Override the per-class in-flight caps (indexed by
    /// [`Priority::idx`]); caps above `max_inflight` are harmless — the
    /// total bound still applies.
    pub fn with_class_caps(mut self, caps: [usize; 3]) -> Admission {
        self.class_caps = [caps[0] as i64, caps[1] as i64, caps[2] as i64];
        self
    }

    /// Try to admit one `class` request. On `Admit`, the caller MUST
    /// later call [`complete`](Self::complete) exactly once with the same
    /// class.
    pub fn try_admit(&self, class: Priority) -> AdmissionDecision {
        // optimistic increments; back out on reject
        let inflight = self.inflight.fetch_add(1, Ordering::AcqRel) + 1;
        if inflight > self.max_inflight {
            self.inflight.fetch_sub(1, Ordering::AcqRel);
            return AdmissionDecision::RejectQueueFull(class);
        }
        let per = &self.inflight_class[class.idx()];
        if per.fetch_add(1, Ordering::AcqRel) + 1 > self.class_caps[class.idx()] {
            per.fetch_sub(1, Ordering::AcqRel);
            self.inflight.fetch_sub(1, Ordering::AcqRel);
            return AdmissionDecision::RejectQueueFull(class);
        }
        if self.rate.is_finite() {
            let mut b = self.bucket.lock().unwrap();
            let now = Instant::now();
            let dt = now.duration_since(b.last).as_secs_f64();
            b.tokens = (b.tokens + dt * self.rate).min(self.burst);
            b.last = now;
            if b.tokens < 1.0 {
                drop(b);
                per.fetch_sub(1, Ordering::AcqRel);
                self.inflight.fetch_sub(1, Ordering::AcqRel);
                return AdmissionDecision::RejectRateLimited(class);
            }
            b.tokens -= 1.0;
        }
        AdmissionDecision::Admit
    }

    /// Mark one admitted `class` request finished (served, failed,
    /// expired, or cancelled — anything that releases its slot).
    pub fn complete(&self, class: Priority) {
        let prev_class = self.inflight_class[class.idx()].fetch_sub(1, Ordering::AcqRel);
        let prev = self.inflight.fetch_sub(1, Ordering::AcqRel);
        debug_assert!(prev > 0 && prev_class > 0, "complete() without admit()");
    }

    pub fn inflight(&self) -> i64 {
        self.inflight.load(Ordering::Acquire)
    }

    pub fn inflight_class(&self, class: Priority) -> i64 {
        self.inflight_class[class.idx()].load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_bound_rejects_then_recovers() {
        let a = Admission::depth_only(2);
        assert_eq!(a.try_admit(Priority::Standard), AdmissionDecision::Admit);
        assert_eq!(a.try_admit(Priority::Standard), AdmissionDecision::Admit);
        assert_eq!(
            a.try_admit(Priority::Standard),
            AdmissionDecision::RejectQueueFull(Priority::Standard)
        );
        a.complete(Priority::Standard);
        assert_eq!(a.try_admit(Priority::Standard), AdmissionDecision::Admit);
        assert_eq!(a.inflight(), 2);
    }

    #[test]
    fn bulk_budget_cannot_starve_interactive() {
        // max_inflight 8 → default bulk cap 2: the bulk flood stops at 2
        // while interactive still has 6 slots
        let a = Admission::depth_only(8);
        assert_eq!(a.try_admit(Priority::Bulk), AdmissionDecision::Admit);
        assert_eq!(a.try_admit(Priority::Bulk), AdmissionDecision::Admit);
        assert_eq!(
            a.try_admit(Priority::Bulk),
            AdmissionDecision::RejectQueueFull(Priority::Bulk)
        );
        assert_eq!(a.inflight_class(Priority::Bulk), 2);
        for _ in 0..6 {
            assert_eq!(a.try_admit(Priority::Interactive), AdmissionDecision::Admit);
        }
        // total bound now binds — and names the rejected class
        assert_eq!(
            a.try_admit(Priority::Interactive),
            AdmissionDecision::RejectQueueFull(Priority::Interactive)
        );
        a.complete(Priority::Bulk);
        assert_eq!(a.inflight_class(Priority::Bulk), 1);
        assert_eq!(a.try_admit(Priority::Bulk), AdmissionDecision::Admit);
    }

    #[test]
    fn class_caps_are_overridable() {
        let a = Admission::depth_only(8).with_class_caps([1, 8, 8]);
        assert_eq!(a.try_admit(Priority::Interactive), AdmissionDecision::Admit);
        assert_eq!(
            a.try_admit(Priority::Interactive),
            AdmissionDecision::RejectQueueFull(Priority::Interactive)
        );
        for _ in 0..7 {
            assert_eq!(a.try_admit(Priority::Bulk), AdmissionDecision::Admit);
        }
    }

    #[test]
    fn rate_limit_caps_burst() {
        // 1 req/s, burst 3: first 3 admit, 4th rejects immediately
        let a = Admission::new(100, 1.0, 3);
        let mut admitted = 0;
        for _ in 0..5 {
            if a.try_admit(Priority::Standard) == AdmissionDecision::Admit {
                admitted += 1;
            }
        }
        assert_eq!(admitted, 3);
        assert_eq!(a.inflight(), 3, "rate rejects must back out both counters");
        assert_eq!(a.inflight_class(Priority::Standard), 3);
    }

    #[test]
    fn rate_limit_refills_over_time() {
        let a = Admission::new(100, 1000.0, 1);
        assert_eq!(a.try_admit(Priority::Standard), AdmissionDecision::Admit);
        assert_eq!(
            a.try_admit(Priority::Standard),
            AdmissionDecision::RejectRateLimited(Priority::Standard)
        );
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert_eq!(a.try_admit(Priority::Standard), AdmissionDecision::Admit);
    }

    #[test]
    fn inflight_never_negative_under_contention() {
        let a = std::sync::Arc::new(Admission::depth_only(8));
        let mut handles = Vec::new();
        for t in 0..4 {
            let a = a.clone();
            handles.push(std::thread::spawn(move || {
                let class = Priority::ALL[t % 3];
                for _ in 0..1000 {
                    if a.try_admit(class) == AdmissionDecision::Admit {
                        a.complete(class);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.inflight(), 0);
        for p in Priority::ALL {
            assert_eq!(a.inflight_class(p), 0);
        }
    }
}
