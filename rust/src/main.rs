//! `s4` CLI — the SparseRT command-line front end.
//!
//! Subcommands:
//! * `chip-info`                         — print the Antoum configuration and derived numbers
//! * `simulate --model M [--sparsity S]` — one simulation, with engine breakdown
//! * `sweep`                             — Fig. 2 (speedup vs sparsity + T4 reference)
//! * `serve`                             — run the serving stack on the AOT artifacts
//! * `net-serve --addr A`                — expose the serving stack over TCP (wire protocol)
//! * `net-load --addr A --rate R`        — open-loop load against a running net-serve
//! * `cluster-route --nodes id=addr,...` — router tier fronting a static fleet of net-serves
//! * `residency --model M`               — memory-capacity report
//!
//! The richer experiment drivers live in `examples/` (quickstart,
//! serve_bert, sparsity_sweep, accuracy_frontier, video_pipeline).

use s4::arch::AntoumConfig;
use s4::graph::models;
use s4::sim::{report, simulate, Target};
use s4::sparse::tensor::DType;
use s4::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional().first().map(String::as_str).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> anyhow::Result<()> {
    match cmd {
        "chip-info" => chip_info(),
        "simulate" => cmd_simulate(args),
        "sweep" => cmd_sweep(args),
        "residency" => cmd_residency(args),
        "serve" => cmd_serve(args),
        "net-serve" => cmd_net_serve(args),
        "net-load" => cmd_net_load(args),
        "cluster-route" => cmd_cluster_route(args),
        "help" | _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "s4 — SparseRT: high-sparsity accelerator stack (S4/Antoum reproduction)\n\
         \n\
         USAGE: s4 <command> [flags]\n\
         \n\
         COMMANDS:\n\
           chip-info                          chip parameters + derived TOPS\n\
           simulate  --model M [--sparsity S] [--batch B] [--event]\n\
           sweep     [--batch B] [--models resnet50,bert_base]\n\
           residency --model M [--sparsity S]\n\
           serve     [--requests N] [--rate R] [--policy max|dense|fixed:S]\n\
                     [--backend cpu|sim|echo] [--precision f32|int8]\n\
                     [--default-priority interactive|standard|bulk]\n\
                     [--deadline-ms D]\n\
                     [--cache-entries N] [--cache-ttl-ms T]   (response cache)\n\
                     [--tune off|startup|lazy] [--tune-plan FILE]  (kernel autotuning)\n\
           net-serve [--addr 127.0.0.1:7450] [--backend cpu|sim|echo]\n\
                     [--precision f32|int8] [--policy max|dense|fixed:S]\n\
                     [--max-conns N] [--duration-s T]    (0 = run until killed)\n\
                     [--cache-entries N] [--cache-ttl-ms T]   (response cache)\n\
                     [--tune off|startup|lazy] [--tune-plan FILE]  (kernel autotuning)\n\
           net-load  --addr HOST:PORT [--rate RPS] [--duration-s T]\n\
                     [--connections N] [--model M] [--seq LEN] [--seed S]\n\
                     [--mix interactive=0.2,standard=0.5,bulk=0.3]\n\
                     [--deadlines-ms interactive=5,bulk=50]\n\
                     [--nodes id=addr:models,...] [--replication R]\n\
                     (with --nodes: drive an in-process cluster router\n\
                      over the listed net-serve nodes instead of --addr)\n\
           cluster-route --nodes id=addr[:m1+m2],... | --cluster-file F\n\
                     [--addr 127.0.0.1:7460] [--replication R]\n\
                     [--max-conns N] [--probe-ms T] [--duration-s T]\n\
           help\n\
         \n\
         MODELS: resnet50 resnet152 bert_tiny bert_mini bert_base bert_large"
    );
}

fn chip_info() -> anyhow::Result<()> {
    let c = AntoumConfig::s4();
    c.validate()?;
    println!("chip: {}", c.name);
    println!("  subsystems:        {}", c.subsystems);
    println!("  clock:             {:.2} GHz", c.clock_ghz);
    println!(
        "  INT8 dense:        {:.1} TOPS  (sparse-equivalent @32x: {:.0} TOPS)",
        c.equivalent_tops(DType::Int8, 1),
        c.equivalent_tops(DType::Int8, 32)
    );
    println!(
        "  BF16 dense:        {:.1} TFLOPS (sparse-equivalent @32x: {:.0} TFLOPS)",
        c.equivalent_tops(DType::Bf16, 1),
        c.equivalent_tops(DType::Bf16, 32)
    );
    println!("  LPDDR4:            {} GB @ {} GB/s", c.dram_bytes >> 30, c.dram_gbps);
    println!("  ring NoC:          {} nodes, {} GB/s/link", c.subsystems, c.noc_link_gbps);
    println!("  video decode:      {}x 1080p30", c.video_streams_1080p30);
    println!("  JPEG decode:       {} FPS @1080p", c.jpeg_fps_1080p);
    println!("  TDP:               {} W", c.tdp_w);
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let model = args.get_or("model", "bert_base").to_string();
    let sparsity = args.get_usize("sparsity", 8)?;
    let batch = args.get_usize("batch", 8)?;
    let g = models::by_name(&model, batch)?;
    let cfg = AntoumConfig::s4();
    let r = if args.has("event") {
        s4::sim::simulate_event(
            &g,
            &cfg,
            sparsity,
            DType::Int8,
            s4::sim::Parallelism::DataParallel,
        )
    } else {
        simulate(&g, Target::antoum(&cfg, sparsity))
    };
    print!("{}", report::breakdown_table(&r));
    let t4 = simulate(&g, Target::t4());
    println!(
        "T4 dense reference: {:.3} ms/batch, {:.0} samples/s  (S4 is {:.2}x)",
        t4.latency_ms,
        t4.throughput,
        r.throughput / t4.throughput
    );
    Ok(())
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    let batch = args.get_usize("batch", 16)?;
    let cfg = AntoumConfig::s4();
    let resnet = models::resnet50(batch, 224);
    let bert = models::bert(models::BERT_BASE, batch, 128);
    let mut rows = Vec::new();
    let base_r = simulate(&resnet, Target::antoum(&cfg, 1)).throughput;
    let base_b = simulate(&bert, Target::antoum(&cfg, 1)).throughput;
    for &s in &s4::sparse::SUPPORTED_SPARSITIES {
        let tr = simulate(&resnet, Target::antoum(&cfg, s)).throughput;
        let tb = simulate(&bert, Target::antoum(&cfg, s)).throughput;
        rows.push(report::Fig2Row {
            sparsity: s,
            resnet50_tput: tr,
            resnet50_speedup: tr / base_r,
            bert_tput: tb,
            bert_speedup: tb / base_b,
        });
    }
    let t4r = simulate(&resnet, Target::t4()).throughput;
    let t4b = simulate(&bert, Target::t4()).throughput;
    print!("{}", report::fig2_table(&rows, t4r, t4b));
    if args.has("json") {
        println!("{}", report::fig2_json(&rows, t4r, t4b));
    }
    Ok(())
}

fn cmd_residency(args: &Args) -> anyhow::Result<()> {
    let model = args.get_or("model", "bert_large").to_string();
    let sparsity = args.get_usize("sparsity", 8)?;
    let g = models::by_name(&model, args.get_usize("batch", 8)?)?;
    let cfg = AntoumConfig::s4();
    let dram = s4::arch::memory::DramModel::from_config(&cfg);
    let r = dram.residency(&g, sparsity, DType::Int8);
    println!(
        "{model} @ s={sparsity}: weights {:.1} MB, activations {:.1} MB, \
         capacity {:.1} GB ({:.2}% used)",
        r.weight_bytes as f64 / 1e6,
        r.activation_bytes as f64 / 1e6,
        r.capacity_bytes as f64 / 1e9,
        100.0 * r.utilization
    );
    Ok(())
}

/// Routing policy from `--policy max|dense|fixed:S` (shared by `serve`
/// and `net-serve`).
fn policy_from_args(args: &Args) -> anyhow::Result<s4::coordinator::RoutingPolicy> {
    use s4::coordinator::RoutingPolicy;
    Ok(match args.get_or("policy", "max") {
        "max" => RoutingPolicy::MaxSparsity,
        "dense" => RoutingPolicy::Dense,
        p if p.starts_with("fixed:") => RoutingPolicy::Fixed(p[6..].parse()?),
        p => anyhow::bail!("unknown policy {p:?}"),
    })
}

/// Response-cache config from `--cache-entries N` / `--cache-ttl-ms T`
/// (shared by `serve` and `net-serve`). Either flag alone enables the
/// cache with the other bound at its default; neither flag — or an
/// explicit `--cache-entries 0` — leaves it off (the ingress chain is
/// then exactly the pre-cache `[breaker, admission]` path). An explicit
/// `--cache-ttl-ms 0` is the coalescing-only mode: concurrent identical
/// requests still share one execution, but settled responses are never
/// reused — distinguished from the flag being absent, which keeps the
/// default TTL.
fn cache_from_args(args: &Args) -> anyhow::Result<Option<s4::coordinator::CacheConfig>> {
    let entries =
        args.has("cache-entries").then(|| args.get_usize("cache-entries", 0)).transpose()?;
    let ttl_ms =
        args.has("cache-ttl-ms").then(|| args.get_u64("cache-ttl-ms", 0)).transpose()?;
    if (entries.is_none() && ttl_ms.is_none()) || entries == Some(0) {
        return Ok(None);
    }
    let mut cfg = s4::coordinator::CacheConfig::default();
    if let Some(n) = entries {
        cfg.max_entries = n;
    }
    if let Some(t) = ttl_ms {
        cfg.ttl = std::time::Duration::from_millis(t);
    }
    Ok(Some(cfg))
}

/// Kernel-autotuning options from `--tune off|startup|lazy` +
/// `--tune-plan FILE` (cpu backend only; see [`s4::sparse::tune`]).
fn tuning_from_args(args: &Args) -> anyhow::Result<s4::backend::TuneOptions> {
    use s4::backend::{TuneMode, TuneOptions};
    let mode = match args.get("tune") {
        Some(m) => TuneMode::parse(m)
            .ok_or_else(|| anyhow::anyhow!("unknown --tune mode {m:?} (off | startup | lazy)"))?,
        None => TuneMode::Off,
    };
    let plan_path = args.get("tune-plan").map(std::path::PathBuf::from);
    anyhow::ensure!(
        plan_path.is_none() || mode != TuneMode::Off,
        "--tune-plan needs --tune startup|lazy (a plan is never consulted with tuning off)"
    );
    Ok(TuneOptions { mode, config: Default::default(), plan_path })
}

/// Backend from `--backend cpu|sim|echo` + `--precision` + `--tune`
/// flags (shared by `serve` and `net-serve`).
fn backend_from_args(
    args: &Args,
    manifest: &s4::runtime::Manifest,
) -> anyhow::Result<std::sync::Arc<dyn s4::coordinator::InferenceBackend>> {
    use s4::backend::TuneMode;
    use s4::coordinator::{CpuSparseBackend, EchoBackend, InferenceBackend, Precision, SimBackend};
    use std::sync::Arc;
    // precision override for the cpu backend: f32 | int8 (default:
    // per-artifact from the manifest)
    let precision = args.get("precision").map(Precision::parse).transpose()?;
    let tune = tuning_from_args(args)?;
    let cpu_only_flags = precision.is_some() || tune.mode != TuneMode::Off;
    let backend: Arc<dyn InferenceBackend> = match args.get_or("backend", "cpu") {
        // real sparse compute through the tiled SpMM engine (f32 or the
        // quantized int8 packed kernel), with optional per-shape kernel
        // autotuning (startup: calibrate every net now; lazy: on first
        // batch per shape class)
        "cpu" => Arc::new(CpuSparseBackend::with_tuning_precision(manifest, precision, tune)),
        // simulator-paced pseudo-outputs (latency realism, no compute)
        "sim" if !cpu_only_flags => Arc::new(SimBackend::from_manifest(manifest, 1.0)),
        // instant reflection (coordinator overhead probing)
        "echo" if !cpu_only_flags => Arc::new(EchoBackend::from_manifest(manifest)),
        b @ ("sim" | "echo") => {
            anyhow::bail!("--precision/--tune only apply to --backend cpu (got {b})")
        }
        b => anyhow::bail!("unknown backend {b:?} (cpu | sim | echo)"),
    };
    Ok(backend)
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    use s4::backend::Value;
    use s4::coordinator::{Priority, Router, Server, ServerConfig, SubmitOptions};
    use s4::runtime::{default_artifact_dir, Manifest};

    let n = args.get_usize("requests", 64)?;
    let rate = args.get_f64("rate", 200.0)?;
    let policy = policy_from_args(args)?;
    // QoS defaults for every request this driver submits
    let priority = Priority::parse(args.get_or("default-priority", "standard"))?;
    let deadline_ms = args.get_u64("deadline-ms", 0)?;
    let mut opts = SubmitOptions::default().with_priority(priority);
    if deadline_ms > 0 {
        opts = opts.with_deadline(std::time::Duration::from_millis(deadline_ms));
    }
    let manifest = Manifest::load(&default_artifact_dir())?;
    let backend = backend_from_args(args, &manifest)?;
    let cfg = ServerConfig { cache: cache_from_args(args)?, ..Default::default() };
    let srv = Server::start(cfg, manifest, Router::new(policy), backend);
    let h = srv.handle();
    let mut rng = s4::util::rng::Xoshiro256::seed_from_u64(7);
    let mut tickets = Vec::new();
    for _ in 0..n {
        std::thread::sleep(std::time::Duration::from_secs_f64(rng.next_exp(rate)));
        let tokens: Vec<i32> = (0..128).map(|_| rng.next_below(1000) as i32).collect();
        match h.submit_with("bert_tiny", vec![Value::tokens(tokens)], opts.clone()) {
            Ok(t) => tickets.push(t),
            Err(d) => println!("rejected: {d:?}"),
        }
    }
    let (mut ok, mut shed) = (0, 0);
    for t in tickets {
        match t.wait_timeout(std::time::Duration::from_secs(30)) {
            Ok(r) if r.is_ok() => ok += 1,
            Ok(r) if matches!(
                r.status,
                s4::coordinator::ResponseStatus::Expired
                    | s4::coordinator::ResponseStatus::Cancelled
            ) =>
            {
                shed += 1
            }
            _ => {}
        }
    }
    println!("served {ok}/{n} requests ({shed} shed by deadline/cancel)");
    println!("{}", h.metrics_snapshot().report());
    srv.shutdown();
    Ok(())
}

/// `s4 net-serve`: the serving stack behind a TCP socket. Runs for
/// `--duration-s` seconds (0 = until the process is killed); one
/// shutdown call drains the socket layer first, then the coordinator.
fn cmd_net_serve(args: &Args) -> anyhow::Result<()> {
    use s4::coordinator::{Router, Server, ServerConfig};
    use s4::net::{NetServer, NetServerConfig};
    use s4::runtime::{default_artifact_dir, Manifest};
    use std::sync::Arc;

    let addr = args.get_or("addr", "127.0.0.1:7450").to_string();
    let duration_s = args.get_u64("duration-s", 0)?;
    let policy = policy_from_args(args)?;
    let manifest = Manifest::load(&default_artifact_dir())?;
    let backend = backend_from_args(args, &manifest)?;
    let cfg = ServerConfig { cache: cache_from_args(args)?, ..Default::default() };
    let srv = Server::start(cfg, manifest, Router::new(policy), backend);
    let handle = Arc::new(srv.handle());

    let net_cfg = NetServerConfig {
        max_connections: args.get_usize("max-conns", 64)?,
        ..NetServerConfig::default()
    };
    let net = Arc::new(NetServer::bind(addr.as_str(), handle.clone(), net_cfg)?);
    println!("net-serve: listening on {}", net.local_addr());
    {
        // drain order: stop the socket layer while the coordinator is
        // still answering tickets, then stop serving
        let net = net.clone();
        srv.on_shutdown(move || net.shutdown());
    }

    if duration_s == 0 {
        // run until killed; the coordinator drains queued work on signal
        // death the same way any process exit does
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    std::thread::sleep(std::time::Duration::from_secs(duration_s));
    srv.shutdown();
    println!("{}", handle.metrics_snapshot().report());
    Ok(())
}

/// `s4 net-load`: open-loop load against a running `net-serve` — or,
/// with `--nodes`, against an in-process cluster router fronting a
/// static fleet of them (the router's per-node forward/failover counters
/// are printed with the end-of-run report).
fn cmd_net_load(args: &Args) -> anyhow::Result<()> {
    use s4::coordinator::Priority;
    use s4::net::LoadSpec;

    let mut spec = LoadSpec {
        model: args.get_or("model", "bert_tiny").to_string(),
        rate_rps: args.get_f64("rate", 200.0)?,
        duration: std::time::Duration::from_secs(args.get_u64("duration-s", 5)?.max(1)),
        connections: args.get_usize("connections", 2)?,
        seed: args.get_u64("seed", 0x54_4E45_54)?,
        ..LoadSpec::default()
    };
    let seq = args.get_usize("seq", 32)?;
    spec.tokens = (0..seq as i32).map(|i| (i * 37 + 11) % 1000).collect();
    if let Some(kv) = args.get_kv_f64("mix")? {
        spec.mix = [0.0; 3];
        for (name, w) in kv {
            anyhow::ensure!(w >= 0.0, "--mix: negative weight for {name}");
            spec.mix[Priority::parse(&name)?.idx()] = w;
        }
    }
    if let Some(kv) = args.get_kv_f64("deadlines-ms")? {
        for (name, ms) in kv {
            anyhow::ensure!(ms > 0.0, "--deadlines-ms: non-positive deadline for {name}");
            spec.deadlines[Priority::parse(&name)?.idx()] =
                Some(std::time::Duration::from_secs_f64(ms / 1000.0));
        }
    }
    if let Some(flag) = args.get("nodes") {
        // in-process router tier over the declared fleet: same open-loop
        // schedule, submissions fan out/fail over across the nodes
        use std::sync::Arc;
        let cluster = s4::cluster::ClusterSpec::parse_flag(flag)?;
        let cfg = s4::cluster::RouterConfig {
            replication: args.get_usize("replication", 2)?,
            ..Default::default()
        };
        let router = s4::cluster::RouterServer::new(cluster, cfg)?;
        println!(
            "net-load: {} rps for {:?} via router over {} node(s), R={} ({} connection(s), mix {:?})",
            spec.rate_rps,
            spec.duration,
            router.membership().spec().len(),
            router.placement().replication(),
            spec.connections,
            spec.mix
        );
        let report = s4::net::run_open_loop_local(&Arc::new(router.clone()), &spec)?;
        report.print();
        println!("{}", router.metrics_snapshot().report());
        return Ok(());
    }
    let addr = args
        .get("addr")
        .ok_or_else(|| anyhow::anyhow!("net-load needs --addr HOST:PORT (or --nodes ...)"))?
        .to_string();
    println!(
        "net-load: {} rps for {:?} against {} ({} connection(s), mix {:?})",
        spec.rate_rps, spec.duration, addr, spec.connections, spec.mix
    );
    let report = s4::net::run_open_loop(addr.as_str(), &spec)?;
    report.print();
    Ok(())
}

/// `s4 cluster-route`: bind a [`s4::cluster::RouterServer`] behind a TCP
/// socket fronting a static fleet of running `net-serve` nodes. The
/// router is wire-transparent, so any client that speaks to `net-serve`
/// (`s4 net-load`, [`s4::net::NetClient`]) drives the whole fleet
/// unchanged. An active TCP probe loop feeds the per-node breakers so
/// dead nodes are shed before the first real submission discovers them.
fn cmd_cluster_route(args: &Args) -> anyhow::Result<()> {
    use s4::cluster::{ClusterSpec, RouterConfig, RouterServer};
    use s4::net::{NetServer, NetServerConfig};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    let spec = match (args.get("nodes"), args.get("cluster-file")) {
        (Some(flag), _) => ClusterSpec::parse_flag(flag)?,
        (None, Some(path)) => ClusterSpec::load(std::path::Path::new(path))?,
        (None, None) => anyhow::bail!(
            "cluster-route needs --nodes id=host:port[:m1+m2],... or --cluster-file FILE"
        ),
    };
    let addr = args.get_or("addr", "127.0.0.1:7460").to_string();
    let duration_s = args.get_u64("duration-s", 0)?;
    let probe_ms = args.get_u64("probe-ms", 500)?.max(1);
    let cfg = RouterConfig {
        replication: args.get_usize("replication", 2)?,
        ..RouterConfig::default()
    };
    let router = RouterServer::new(spec, cfg)?;
    let net_cfg = NetServerConfig {
        max_connections: args.get_usize("max-conns", 256)?,
        ..NetServerConfig::default()
    };
    let net = Arc::new(NetServer::bind(addr.as_str(), Arc::new(router.clone()), net_cfg)?);
    println!(
        "cluster-route: listening on {} fronting {} node(s), R={}",
        net.local_addr(),
        router.membership().spec().len(),
        router.placement().replication()
    );
    let stop_at = (duration_s > 0).then(|| Instant::now() + Duration::from_secs(duration_s));
    let mut last: Vec<bool> = Vec::new();
    loop {
        let probe = router.probe(Duration::from_millis(probe_ms));
        for (i, (id, ok)) in probe.iter().enumerate() {
            if last.get(i) != Some(ok) {
                println!(
                    "cluster-route: node {id} {}",
                    if *ok { "reachable" } else { "unreachable" }
                );
            }
        }
        last = probe.into_iter().map(|(_, ok)| ok).collect();
        if let Some(d) = stop_at {
            if Instant::now() >= d {
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(probe_ms));
    }
    net.shutdown();
    println!("{}", router.metrics_snapshot().report());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn cache_flags_distinguish_absent_from_explicit_zero() {
        let default = s4::coordinator::CacheConfig::default();
        assert!(cache_from_args(&args("")).unwrap().is_none());
        let c = cache_from_args(&args("--cache-entries 64")).unwrap().unwrap();
        assert_eq!((c.max_entries, c.ttl), (64, default.ttl));
        // explicit ttl 0 is the coalescing-only mode, not the 60s default
        let c = cache_from_args(&args("--cache-entries 64 --cache-ttl-ms 0")).unwrap().unwrap();
        assert_eq!(c.ttl, std::time::Duration::ZERO);
        // ttl alone enables the cache with default entries
        let c = cache_from_args(&args("--cache-ttl-ms 250")).unwrap().unwrap();
        assert_eq!(c.max_entries, default.max_entries);
        assert_eq!(c.ttl, std::time::Duration::from_millis(250));
        // explicit --cache-entries 0 is off, whatever else is set
        assert!(cache_from_args(&args("--cache-entries 0 --cache-ttl-ms 250"))
            .unwrap()
            .is_none());
    }

    #[test]
    fn tune_flags_parse_modes_and_reject_bad_input() {
        use s4::backend::TuneMode;
        // default: tuning off, no plan file
        let t = tuning_from_args(&args("")).unwrap();
        assert_eq!(t.mode, TuneMode::Off);
        assert!(t.plan_path.is_none());
        // explicit modes
        assert_eq!(tuning_from_args(&args("--tune off")).unwrap().mode, TuneMode::Off);
        assert_eq!(tuning_from_args(&args("--tune startup")).unwrap().mode, TuneMode::Startup);
        let t = tuning_from_args(&args("--tune lazy --tune-plan /tmp/plan.json")).unwrap();
        assert_eq!(t.mode, TuneMode::Lazy);
        assert_eq!(t.plan_path.as_deref(), Some(std::path::Path::new("/tmp/plan.json")));
        // unknown mode is an error, not a silent default
        assert!(tuning_from_args(&args("--tune eager")).is_err());
        // a plan file without a tuning mode would never be read — reject
        assert!(tuning_from_args(&args("--tune-plan /tmp/plan.json")).is_err());
        assert!(tuning_from_args(&args("--tune off --tune-plan /tmp/plan.json")).is_err());
    }
}
