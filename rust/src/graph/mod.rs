//! Op-graph IR with per-op work accounting.
//!
//! The simulator does not execute tensors; it executes *workloads*. This IR
//! describes a model as a DAG of ops, each knowing its FLOPs, parameter
//! count, weight bytes at a given (sparsity, dtype), and activation bytes —
//! everything the Antoum engine models and the T4 roofline need.
//!
//! `models` builds the paper's four benchmark networks (ResNet-50/152,
//! BERT-base/large) at full fidelity (layer counts, channel widths,
//! attention shapes), cross-checked against published FLOP/param counts in
//! unit tests.

pub mod fusion;
pub mod ir;
pub mod models;
pub mod op;

pub use ir::{Graph, OpId};
pub use op::{ActFunc, Op, OpKind};
