//! Op kinds and their work accounting.

use crate::sparse::format::BLOCK;
use crate::sparse::tensor::DType;

/// Activation functions (the activation engine's op set + None).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ActFunc {
    Relu,
    Gelu,
    Exp,
    Log,
    Reciprocal,
    Sigmoid,
    Tanh,
}

/// The op vocabulary. Every shape is *per forward pass* at the graph's
/// batch size (builders bake the batch in).
#[derive(Clone, Debug, PartialEq)]
pub enum OpKind {
    /// Weighted conv (SPU, sparsifiable). Input spatial h×w, NHWC.
    Conv2d {
        h: usize,
        w: usize,
        cin: usize,
        cout: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        batch: usize,
    },
    /// Weighted matmul `[m,k]@[k,n]` (SPU, sparsifiable). `m` includes batch.
    MatMul { m: usize, k: usize, n: usize },
    /// Activation×activation batched matmul (SPU, dense — no weights).
    BatchMatMul { b: usize, m: usize, k: usize, n: usize },
    /// Softmax over `rows` rows of `cols` (activation engine + VPU).
    Softmax { rows: usize, cols: usize },
    /// LayerNorm over `rows` rows of `cols` (VPU + activation engine rsqrt).
    LayerNorm { rows: usize, cols: usize },
    /// Standalone elementwise activation (activation engine).
    Activation { elems: usize, func: ActFunc },
    /// Elementwise arithmetic of `arity` inputs (VPU): residual adds etc.
    Elementwise { elems: usize, arity: usize },
    /// Pooling window reduce (VPU).
    Pool { elems_in: usize, window: usize },
    /// Embedding gather (embedding-lookup engine).
    Embed { tokens: usize, dim: usize, vocab: usize },
    /// Layout change (memory-reshape engine): pure data movement.
    Reshape { bytes: usize },
}

/// A node in the graph.
#[derive(Clone, Debug)]
pub struct Op {
    pub name: String,
    pub kind: OpKind,
    pub inputs: Vec<super::ir::OpId>,
    /// Epilogue fused into a Conv2d/MatMul by the fusion pass (paper §2
    /// item iii: "fused ... bias addition, elementwise, activation").
    pub fused_act: Option<ActFunc>,
    pub fused_bias: bool,
    pub fused_residual: bool,
}

impl OpKind {
    /// Is this a weighted op the SPU can exploit sparsity on?
    pub fn sparsifiable(&self) -> bool {
        matches!(self, OpKind::Conv2d { .. } | OpKind::MatMul { .. })
    }

    /// Output spatial dims of a conv.
    pub fn conv_out_hw(&self) -> Option<(usize, usize)> {
        match *self {
            OpKind::Conv2d { h, w, kh, kw, stride, .. } => {
                let pad = kh / 2; // builders use same-ish padding
                Some((
                    (h + 2 * pad - kh) / stride + 1,
                    (w + 2 * pad - kw) / stride + 1,
                ))
            }
            _ => None,
        }
    }

    /// Dense FLOPs (mul+add = 2 FLOPs per MAC).
    pub fn flops_dense(&self) -> f64 {
        match *self {
            OpKind::Conv2d { cin, cout, kh, kw, batch, .. } => {
                let (ho, wo) = self.conv_out_hw().unwrap();
                2.0 * (batch * ho * wo * cout) as f64 * (kh * kw * cin) as f64
            }
            OpKind::MatMul { m, k, n } => 2.0 * m as f64 * k as f64 * n as f64,
            OpKind::BatchMatMul { b, m, k, n } => {
                2.0 * b as f64 * m as f64 * k as f64 * n as f64
            }
            // softmax: max, sub, exp, sum, div ≈ 5 passes
            OpKind::Softmax { rows, cols } => 5.0 * (rows * cols) as f64,
            // mean, var, normalize, scale+shift ≈ 6 passes
            OpKind::LayerNorm { rows, cols } => 6.0 * (rows * cols) as f64,
            OpKind::Activation { elems, .. } => elems as f64,
            OpKind::Elementwise { elems, arity } => (elems * arity) as f64,
            OpKind::Pool { elems_in, .. } => elems_in as f64,
            OpKind::Embed { tokens, dim, .. } => (tokens * dim) as f64,
            OpKind::Reshape { .. } => 0.0,
        }
    }

    /// FLOPs actually executed at SPU sparsity factor `s` (weighted ops
    /// scale 1/s; everything else is unchanged — the Amdahl term behind
    /// BERT's sublinear Fig. 2 curve).
    pub fn flops_at(&self, s: usize) -> f64 {
        if self.sparsifiable() {
            self.flops_dense() / s as f64
        } else {
            self.flops_dense()
        }
    }

    /// Dense parameter count (weights only; biases folded in as +n).
    pub fn params(&self) -> usize {
        match *self {
            OpKind::Conv2d { cin, cout, kh, kw, .. } => kh * kw * cin * cout + cout,
            OpKind::MatMul { k, n, .. } => k * n + n,
            OpKind::Embed { dim, vocab, .. } => vocab * dim,
            OpKind::LayerNorm { cols, .. } => 2 * cols,
            _ => 0,
        }
    }

    /// Weight bytes *streamed per pass* at sparsity `s` and dtype `dt`
    /// (block-balanced encoding: values + u8 offsets for sparsifiable ops;
    /// dense layout otherwise). Embedding tables are NOT streamed — the
    /// lookup engine reads only the requested rows (counted as DRAM
    /// traffic in `arch::engines::lookup_dram_bytes`); their residency is
    /// in `storage_bytes`.
    pub fn weight_bytes(&self, s: usize, dt: DType) -> usize {
        if matches!(self, OpKind::Embed { .. }) {
            return 0;
        }
        let p = self.params();
        if p == 0 {
            return 0;
        }
        if self.sparsifiable() && s > 1 {
            // block-balanced encoding: kept values + u8 in-block offsets;
            // per-block headers are amortized below 1% and ignored.
            let kept = p / s;
            let _ = BLOCK; // format constant documented via sparse::format
            kept * dt.bytes() + kept
        } else {
            p * dt.bytes()
        }
    }

    /// DRAM-resident weight storage at (s, dt) — includes embedding tables
    /// (capacity planning, `arch::memory::DramModel::fits`).
    pub fn storage_bytes(&self, s: usize, dt: DType) -> usize {
        if let OpKind::Embed { dim, vocab, .. } = *self {
            return vocab * dim * dt.bytes();
        }
        self.weight_bytes(s, dt)
    }

    /// Activation bytes read per pass at dtype `dt`.
    pub fn input_bytes(&self, dt: DType) -> usize {
        let elems = match *self {
            OpKind::Conv2d { h, w, cin, batch, .. } => batch * h * w * cin,
            OpKind::MatMul { m, k, .. } => m * k,
            OpKind::BatchMatMul { b, m, k, n } => b * (m * k + k * n),
            OpKind::Softmax { rows, cols } | OpKind::LayerNorm { rows, cols } => {
                rows * cols
            }
            OpKind::Activation { elems, .. } => elems,
            OpKind::Elementwise { elems, arity } => elems * arity,
            OpKind::Pool { elems_in, .. } => elems_in,
            OpKind::Embed { tokens, .. } => tokens, // indices (4B each, but dt ok)
            OpKind::Reshape { bytes } => return bytes,
        };
        elems * dt.bytes()
    }

    /// Activation bytes written per pass at dtype `dt`.
    pub fn output_bytes(&self, dt: DType) -> usize {
        let elems = match *self {
            OpKind::Conv2d { cout, batch, .. } => {
                let (ho, wo) = self.conv_out_hw().unwrap();
                batch * ho * wo * cout
            }
            OpKind::MatMul { m, n, .. } => m * n,
            OpKind::BatchMatMul { b, m, n, .. } => b * m * n,
            OpKind::Softmax { rows, cols } | OpKind::LayerNorm { rows, cols } => {
                rows * cols
            }
            OpKind::Activation { elems, .. } => elems,
            OpKind::Elementwise { elems, .. } => elems,
            OpKind::Pool { elems_in, window } => elems_in / window.max(1),
            OpKind::Embed { tokens, dim, .. } => tokens * dim,
            OpKind::Reshape { bytes } => return bytes,
        };
        elems * dt.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_flops_resnet_stem() {
        // ResNet stem: 7x7/2, 3→64 on 224² ≈ 236 MFLOPs·... known value:
        // 2 * 112*112*64 * 7*7*3 = 236 MFLOPs (per image)
        let k = OpKind::Conv2d {
            h: 224, w: 224, cin: 3, cout: 64, kh: 7, kw: 7, stride: 2, batch: 1,
        };
        let f = k.flops_dense();
        assert!((f - 2.0 * 112.0 * 112.0 * 64.0 * 147.0).abs() / f < 1e-9);
    }

    #[test]
    fn sparsity_scales_weighted_ops_only() {
        let mm = OpKind::MatMul { m: 128, k: 768, n: 768 };
        assert_eq!(mm.flops_at(8), mm.flops_dense() / 8.0);
        let sm = OpKind::Softmax { rows: 128, cols: 128 };
        assert_eq!(sm.flops_at(8), sm.flops_dense());
    }

    #[test]
    fn weight_bytes_shrink_with_sparsity() {
        let mm = OpKind::MatMul { m: 128, k: 1024, n: 1024 };
        let d = mm.weight_bytes(1, DType::Bf16);
        let s8 = mm.weight_bytes(8, DType::Bf16);
        let s32 = mm.weight_bytes(32, DType::Bf16);
        assert!(s8 < d / 5, "s8={s8} d={d}");
        assert!(s32 < s8, "s32={s32}");
    }

    #[test]
    fn embed_not_sparsified() {
        let e = OpKind::Embed { tokens: 128, dim: 768, vocab: 30522 };
        assert!(!e.sparsifiable());
        assert_eq!(e.weight_bytes(8, DType::Bf16), e.weight_bytes(1, DType::Bf16));
    }

    #[test]
    fn matmul_params_includes_bias() {
        let mm = OpKind::MatMul { m: 1, k: 10, n: 20 };
        assert_eq!(mm.params(), 10 * 20 + 20);
    }

    #[test]
    fn reshape_moves_bytes_computes_nothing() {
        let r = OpKind::Reshape { bytes: 4096 };
        assert_eq!(r.flops_dense(), 0.0);
        assert_eq!(r.input_bytes(DType::Bf16), 4096);
        assert_eq!(r.output_bytes(DType::Bf16), 4096);
    }
}
