//! Builders for the paper's benchmark networks.
//!
//! Full-fidelity workload graphs (layer counts, widths, attention shapes)
//! for ResNet-50/152 and BERT-base/large — the four models of Fig. 2/3 —
//! plus the tiny variants matching the executable AOT artifacts.
//! FLOP/param totals are asserted against published numbers in tests.

use super::ir::{Graph, OpId};
use super::op::{ActFunc, OpKind};

// ------------------------------ ResNet ------------------------------------

/// Bottleneck stage spec: (blocks, mid channels, out channels, first stride).
const RESNET_STAGES: [(usize, usize, usize, usize); 4] = [
    (3, 64, 256, 1),
    (4, 128, 512, 2),
    (6, 256, 1024, 2),
    (36, 512, 2048, 2), // blocks field overridden per variant
];

fn conv(
    g: &mut Graph,
    name: String,
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    k: usize,
    stride: usize,
    input: &[OpId],
    act: Option<ActFunc>,
) -> (OpId, usize, usize) {
    let kind = OpKind::Conv2d { h, w, cin, cout, kh: k, kw: k, stride, batch: g.batch };
    let (ho, wo) = kind.conv_out_hw().unwrap();
    let id = g.add_fused(name, kind, input, act);
    (id, ho, wo)
}

fn resnet(name: &str, blocks_per_stage: [usize; 4], batch: usize, image: usize) -> Graph {
    let mut g = Graph::new(name, batch);
    // stem: 7x7/2 conv + 3x3/2 maxpool
    let (stem, mut h, mut w) =
        conv(&mut g, "stem".into(), image, image, 3, 64, 7, 2, &[], Some(ActFunc::Relu));
    let pool = g.add(
        "maxpool",
        OpKind::Pool { elems_in: batch * h * w * 64, window: 4 },
        &[stem],
    );
    h /= 2;
    w /= 2;
    let mut prev = pool;
    let mut cin = 64usize;
    for (si, &(_, mid, cout, stride0)) in RESNET_STAGES.iter().enumerate() {
        let blocks = blocks_per_stage[si];
        for b in 0..blocks {
            let stride = if b == 0 { stride0 } else { 1 };
            let tag = format!("s{}b{}", si + 1, b);
            // projection shortcut on the first block of each stage
            let shortcut = if b == 0 {
                let (sc, _, _) = conv(
                    &mut g,
                    format!("{tag}.down"),
                    h, w, cin, cout, 1, stride,
                    &[prev],
                    None,
                );
                sc
            } else {
                prev
            };
            let (c1, h1, w1) = conv(
                &mut g, format!("{tag}.c1"), h, w, cin, mid, 1, 1, &[prev],
                Some(ActFunc::Relu),
            );
            let (c2, h2, w2) = conv(
                &mut g, format!("{tag}.c2"), h1, w1, mid, mid, 3, stride, &[c1],
                Some(ActFunc::Relu),
            );
            let (c3, h3, w3) = conv(
                &mut g, format!("{tag}.c3"), h2, w2, mid, cout, 1, 1, &[c2], None,
            );
            // residual add + relu (VPU elementwise)
            let add = g.add(
                format!("{tag}.add"),
                OpKind::Elementwise { elems: batch * h3 * w3 * cout, arity: 2 },
                &[c3, shortcut],
            );
            prev = g.add(
                format!("{tag}.relu"),
                OpKind::Activation { elems: batch * h3 * w3 * cout, func: ActFunc::Relu },
                &[add],
            );
            h = h3;
            w = w3;
            cin = cout;
        }
    }
    let gap = g.add(
        "avgpool",
        OpKind::Pool { elems_in: batch * h * w * cin, window: h * w },
        &[prev],
    );
    g.add("fc", OpKind::MatMul { m: batch, k: cin, n: 1000 }, &[gap]);
    g
}

/// ResNet-50 at `image`² input (paper Fig. 2/3 uses 224).
pub fn resnet50(batch: usize, image: usize) -> Graph {
    resnet("resnet50", [3, 4, 6, 3], batch, image)
}

/// ResNet-152.
pub fn resnet152(batch: usize, image: usize) -> Graph {
    resnet("resnet152", [3, 8, 36, 3], batch, image)
}

// ------------------------------- BERT -------------------------------------

/// Transformer encoder spec (mirrors python `compile/model.py` configs).
#[derive(Clone, Copy, Debug)]
pub struct BertSpec {
    pub name: &'static str,
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
}

pub const BERT_TINY: BertSpec =
    BertSpec { name: "bert_tiny", vocab: 1024, hidden: 128, layers: 2, heads: 2, ffn: 512 };
pub const BERT_MINI: BertSpec =
    BertSpec { name: "bert_mini", vocab: 2048, hidden: 256, layers: 4, heads: 4, ffn: 1024 };
pub const BERT_BASE: BertSpec = BertSpec {
    name: "bert_base", vocab: 30522, hidden: 768, layers: 12, heads: 12, ffn: 3072,
};
pub const BERT_LARGE: BertSpec = BertSpec {
    name: "bert_large", vocab: 30522, hidden: 1024, layers: 24, heads: 16, ffn: 4096,
};

/// Build a BERT encoder graph at (batch, seq).
pub fn bert(spec: BertSpec, batch: usize, seq: usize) -> Graph {
    let mut g = Graph::new(spec.name, batch);
    let (h, f) = (spec.hidden, spec.ffn);
    let m = batch * seq;
    let hd = h / spec.heads;
    let emb = g.add(
        "embed",
        OpKind::Embed { tokens: m, dim: h, vocab: spec.vocab },
        &[],
    );
    let mut x = emb;
    for l in 0..spec.layers {
        let t = format!("l{l}");
        let q = g.add_fused(format!("{t}.q"), OpKind::MatMul { m, k: h, n: h }, &[x], None);
        let k = g.add_fused(format!("{t}.k"), OpKind::MatMul { m, k: h, n: h }, &[x], None);
        let v = g.add_fused(format!("{t}.v"), OpKind::MatMul { m, k: h, n: h }, &[x], None);
        // heads live in the batch dim of the activation matmuls
        let qk = g.add(
            format!("{t}.qk"),
            OpKind::BatchMatMul { b: batch * spec.heads, m: seq, k: hd, n: seq },
            &[q, k],
        );
        let sm = g.add(
            format!("{t}.softmax"),
            OpKind::Softmax { rows: batch * spec.heads * seq, cols: seq },
            &[qk],
        );
        let pv = g.add(
            format!("{t}.pv"),
            OpKind::BatchMatMul { b: batch * spec.heads, m: seq, k: seq, n: hd },
            &[sm, v],
        );
        let o = g.add_fused(format!("{t}.o"), OpKind::MatMul { m, k: h, n: h }, &[pv], None);
        let r1 = g.add(
            format!("{t}.res1"),
            OpKind::Elementwise { elems: m * h, arity: 2 },
            &[x, o],
        );
        let ln1 = g.add(
            format!("{t}.ln1"),
            OpKind::LayerNorm { rows: m, cols: h },
            &[r1],
        );
        let up = g.add_fused(
            format!("{t}.ffn_up"),
            OpKind::MatMul { m, k: h, n: f },
            &[ln1],
            Some(ActFunc::Gelu),
        );
        let down = g.add_fused(
            format!("{t}.ffn_down"),
            OpKind::MatMul { m, k: f, n: h },
            &[up],
            None,
        );
        let r2 = g.add(
            format!("{t}.res2"),
            OpKind::Elementwise { elems: m * h, arity: 2 },
            &[ln1, down],
        );
        x = g.add(format!("{t}.ln2"), OpKind::LayerNorm { rows: m, cols: h }, &[r2]);
    }
    g.add("cls", OpKind::MatMul { m: batch, k: h, n: 2 }, &[x]);
    g
}

/// Graph lookup by name — CLI / bench entry point.
pub fn by_name(name: &str, batch: usize) -> anyhow::Result<Graph> {
    Ok(match name {
        "resnet50" => resnet50(batch, 224),
        "resnet152" => resnet152(batch, 224),
        "bert_tiny" => bert(BERT_TINY, batch, 128),
        "bert_mini" => bert(BERT_MINI, batch, 128),
        "bert_base" => bert(BERT_BASE, batch, 128),
        "bert_large" => bert(BERT_LARGE, batch, 128),
        other => anyhow::bail!(
            "unknown model {other:?} (have: resnet50, resnet152, bert_tiny, \
             bert_mini, bert_base, bert_large)"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet50_flops_and_params_match_published() {
        let g = resnet50(1, 224);
        let gf = g.flops_dense() / 1e9;
        // published: ~4.1 GMACs ⇒ ~8.2 GFLOPs (2/MAC); tolerate ±15%
        assert!((7.0..9.5).contains(&gf), "resnet50 GFLOPs={gf}");
        let p = g.params() as f64 / 1e6;
        assert!((23.0..28.0).contains(&p), "resnet50 Mparams={p}");
    }

    #[test]
    fn resnet152_roughly_3x_resnet50() {
        let g50 = resnet50(1, 224);
        let g152 = resnet152(1, 224);
        let ratio = g152.flops_dense() / g50.flops_dense();
        assert!((2.5..3.2).contains(&ratio), "ratio={ratio}");
        let p = g152.params() as f64 / 1e6;
        assert!((55.0..65.0).contains(&p), "resnet152 Mparams={p}");
    }

    #[test]
    fn bert_base_params_match_published() {
        let g = bert(BERT_BASE, 1, 128);
        let p = g.params() as f64 / 1e6;
        // 110M total (85.6M encoder + 23.4M embed + heads)
        assert!((105.0..115.0).contains(&p), "bert_base Mparams={p}");
    }

    #[test]
    fn bert_base_flops_seq128() {
        let g = bert(BERT_BASE, 1, 128);
        let gf = g.flops_dense() / 1e9;
        // ≈ 2·85.6M·128 + attention ≈ 22.6 GFLOPs
        assert!((19.0..26.0).contains(&gf), "bert_base GFLOPs={gf}");
    }

    #[test]
    fn bert_large_vs_base() {
        let b = bert(BERT_BASE, 1, 128);
        let l = bert(BERT_LARGE, 1, 128);
        let r = l.flops_dense() / b.flops_dense();
        assert!((3.0..4.0).contains(&r), "large/base flops ratio={r}");
        assert!((l.params() as f64 / 1e6) > 320.0);
    }

    #[test]
    fn resnet_more_sparsifiable_than_bert() {
        // the paper's Fig. 2 asymmetry: ResNet ≈ all conv; BERT has big
        // attention+LN+softmax tails.
        let r = resnet50(1, 224).sparsifiable_fraction();
        let b = bert(BERT_BASE, 1, 128).sparsifiable_fraction();
        assert!(r > 0.99, "resnet sparsifiable={r}");
        assert!(b < 0.98, "bert sparsifiable={b}");
        assert!(r > b);
    }

    #[test]
    fn batch_scales_flops_linearly() {
        let a = resnet50(1, 224).flops_dense();
        let b = resnet50(8, 224).flops_dense();
        assert!((b / a - 8.0).abs() < 1e-6);
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["resnet50", "resnet152", "bert_base", "bert_large", "bert_tiny"] {
            assert_eq!(by_name(n, 2).unwrap().batch, 2);
        }
        assert!(by_name("vgg", 1).is_err());
    }

    #[test]
    fn graphs_are_connected_chains() {
        // every op except sources must have at least one input
        for g in [resnet50(1, 224), bert(BERT_TINY, 1, 128)] {
            let sources = g.ops.iter().filter(|o| o.inputs.is_empty()).count();
            assert!(sources <= 2, "{}: {} sources", g.name, sources);
        }
    }
}
