//! Graph container: append-only DAG with topological order by construction,
//! plus whole-graph work accounting (the numbers Fig. 2/3 are computed
//! from).

use super::op::{ActFunc, Op, OpKind};
use crate::sparse::tensor::DType;

/// Index of an op within its graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OpId(pub usize);

/// A model as a DAG of ops. Ops are stored in topological order (builders
/// may only reference already-added ops — enforced at `add`).
#[derive(Clone, Debug)]
pub struct Graph {
    pub name: String,
    pub batch: usize,
    pub ops: Vec<Op>,
}

impl Graph {
    pub fn new(name: impl Into<String>, batch: usize) -> Graph {
        Graph { name: name.into(), batch, ops: Vec::new() }
    }

    /// Append an op; inputs must already exist (keeps ops topo-sorted).
    pub fn add(&mut self, name: impl Into<String>, kind: OpKind, inputs: &[OpId]) -> OpId {
        for &OpId(i) in inputs {
            assert!(i < self.ops.len(), "input {i} not yet defined (topo order)");
        }
        self.ops.push(Op {
            name: name.into(),
            kind,
            inputs: inputs.to_vec(),
            fused_act: None,
            fused_bias: false,
            fused_residual: false,
        });
        OpId(self.ops.len() - 1)
    }

    /// Append a weighted op with a fused activation epilogue.
    pub fn add_fused(
        &mut self,
        name: impl Into<String>,
        kind: OpKind,
        inputs: &[OpId],
        act: Option<ActFunc>,
    ) -> OpId {
        let id = self.add(name, kind, inputs);
        let op = &mut self.ops[id.0];
        op.fused_act = act;
        op.fused_bias = true;
        id
    }

    pub fn op(&self, id: OpId) -> &Op {
        &self.ops[id.0]
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Consumers of each op (adjacency reversed), for scheduling.
    pub fn consumers(&self) -> Vec<Vec<OpId>> {
        let mut out = vec![Vec::new(); self.ops.len()];
        for (i, op) in self.ops.iter().enumerate() {
            for &inp in &op.inputs {
                out[inp.0].push(OpId(i));
            }
        }
        out
    }

    // ------------------------- accounting ------------------------------

    /// Total dense FLOPs of one forward pass.
    pub fn flops_dense(&self) -> f64 {
        self.ops.iter().map(|o| o.kind.flops_dense()).sum()
    }

    /// Total FLOPs executed at SPU sparsity `s`.
    pub fn flops_at(&self, s: usize) -> f64 {
        self.ops.iter().map(|o| o.kind.flops_at(s)).sum()
    }

    /// Fraction of dense FLOPs in sparsifiable (weighted) ops — the
    /// Amdahl knob that separates ResNet's near-linear Fig. 2 curve from
    /// BERT's sublinear one.
    pub fn sparsifiable_fraction(&self) -> f64 {
        let sp: f64 = self
            .ops
            .iter()
            .filter(|o| o.kind.sparsifiable())
            .map(|o| o.kind.flops_dense())
            .sum();
        sp / self.flops_dense()
    }

    /// Dense parameter count.
    pub fn params(&self) -> usize {
        self.ops.iter().map(|o| o.kind.params()).sum()
    }

    /// Total weight bytes streamed per pass at (sparsity, dtype).
    pub fn weight_bytes(&self, s: usize, dt: DType) -> usize {
        self.ops.iter().map(|o| o.kind.weight_bytes(s, dt)).sum()
    }

    /// Total activation traffic (in+out) per pass at dtype.
    pub fn activation_bytes(&self, dt: DType) -> usize {
        self.ops
            .iter()
            .map(|o| o.kind.input_bytes(dt) + o.kind.output_bytes(dt))
            .sum()
    }

    /// Ideal speedup at sparsity `s` if compute were the only limit
    /// (upper bound the simulator's Fig. 2 curve must stay under).
    pub fn amdahl_speedup(&self, s: usize) -> f64 {
        self.flops_dense() / self.flops_at(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Graph {
        let mut g = Graph::new("tiny", 1);
        let a = g.add("mm1", OpKind::MatMul { m: 128, k: 256, n: 256 }, &[]);
        let b = g.add("act", OpKind::Activation { elems: 128 * 256, func: ActFunc::Gelu }, &[a]);
        g.add("mm2", OpKind::MatMul { m: 128, k: 256, n: 128 }, &[b]);
        g
    }

    #[test]
    fn topo_order_enforced() {
        let g = tiny();
        assert_eq!(g.len(), 3);
        for (i, op) in g.ops.iter().enumerate() {
            for inp in &op.inputs {
                assert!(inp.0 < i);
            }
        }
    }

    #[test]
    #[should_panic(expected = "not yet defined")]
    fn forward_reference_panics() {
        let mut g = Graph::new("bad", 1);
        g.add("x", OpKind::MatMul { m: 1, k: 32, n: 32 }, &[OpId(5)]);
    }

    #[test]
    fn consumers_reverse_edges() {
        let g = tiny();
        let cons = g.consumers();
        assert_eq!(cons[0], vec![OpId(1)]);
        assert_eq!(cons[1], vec![OpId(2)]);
        assert!(cons[2].is_empty());
    }

    #[test]
    fn amdahl_bounds() {
        let g = tiny();
        let sp = g.sparsifiable_fraction();
        assert!(sp > 0.99, "matmul-dominated: {sp}"); // activation is tiny
        let a32 = g.amdahl_speedup(32);
        assert!(a32 > 20.0 && a32 <= 32.0, "a32={a32}");
        assert!(g.amdahl_speedup(1) == 1.0);
    }

    #[test]
    fn accounting_sums() {
        let g = tiny();
        assert_eq!(g.params(), 256 * 256 + 256 + 256 * 128 + 128);
        assert!(g.flops_dense() > 0.0);
        assert!(g.weight_bytes(8, DType::Bf16) < g.weight_bytes(1, DType::Bf16));
        assert!(g.activation_bytes(DType::Bf16) > 0);
    }
}
