//! Operator fusion pass — paper §2 item (iii): the SPU natively fuses
//! "bias addition, elementwise operations, quantization, and certain
//! activation functions" into conv/matmul.
//!
//! The pass rewrites   weighted-op → activation   and
//! weighted-op → elementwise-add(residual)   chains into the weighted op's
//! epilogue when the intermediate has exactly one consumer. The simulator
//! costs fused epilogues at zero extra memory traffic (they happen in the
//! SPU's output pipeline), which is precisely why fusion matters for the
//! bandwidth-bound layers.

use super::ir::{Graph, OpId};
use super::op::{ActFunc, OpKind};

/// Statistics of one fusion run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FusionStats {
    pub fused_activations: usize,
    pub fused_residuals: usize,
    pub ops_before: usize,
    pub ops_after: usize,
}

/// Apply fusion, returning the rewritten graph and statistics.
///
/// Correctness invariant (checked by property tests): total dense FLOPs of
/// *weighted* ops are unchanged, and every removed op's work is
/// representable in an epilogue (activation or 2-ary elementwise).
pub fn fuse(g: &Graph) -> (Graph, FusionStats) {
    let consumers = g.consumers();
    let n = g.ops.len();
    // ops to delete, and per-surviving-op epilogue edits
    let mut dead = vec![false; n];
    let mut fuse_act: Vec<Option<ActFunc>> = vec![None; n];
    let mut fuse_res = vec![false; n];
    // where a deleted op's output should be re-read from
    let mut redirect: Vec<OpId> = (0..n).map(OpId).collect();

    let mut stats = FusionStats { ops_before: n, ..Default::default() };

    for i in 0..n {
        let op = &g.ops[i];
        if !op.kind.sparsifiable() || op.fused_act.is_some() {
            // only fuse into weighted ops without an existing epilogue
            continue;
        }
        // single consumer?
        if consumers[i].len() != 1 {
            continue;
        }
        let c = consumers[i][0].0;
        if dead[c] {
            continue;
        }
        match &g.ops[c].kind {
            OpKind::Activation { func, .. } => {
                dead[c] = true;
                fuse_act[i] = Some(*func);
                redirect[c] = OpId(i);
                stats.fused_activations += 1;
            }
            OpKind::Elementwise { arity: 2, .. } => {
                // residual add: fuse if the weighted op is one of the two
                // operands and the add itself feeds ≤1 activation next
                dead[c] = true;
                fuse_res[i] = true;
                redirect[c] = OpId(i);
                stats.fused_residuals += 1;
                // chain: add → relu with single consumer also folds in
                if consumers[c].len() == 1 {
                    let r = consumers[c][0].0;
                    if let OpKind::Activation { func, .. } = &g.ops[r].kind {
                        if !dead[r] && fuse_act[i].is_none() {
                            dead[r] = true;
                            fuse_act[i] = Some(*func);
                            redirect[r] = OpId(i);
                            stats.fused_activations += 1;
                        }
                    }
                }
            }
            _ => {}
        }
    }

    // resolve redirect chains (act fused after residual, etc.)
    fn resolve(redirect: &[OpId], mut id: OpId) -> OpId {
        while redirect[id.0] != id {
            id = redirect[id.0];
        }
        id
    }

    // rebuild compacted graph
    let mut out = Graph::new(g.name.clone(), g.batch);
    let mut new_id = vec![OpId(usize::MAX); n];
    for i in 0..n {
        if dead[i] {
            continue;
        }
        let op = &g.ops[i];
        let inputs: Vec<OpId> = op
            .inputs
            .iter()
            .map(|&inp| {
                let r = resolve(&redirect, inp);
                new_id[r.0]
            })
            .collect();
        let id = out.add(op.name.clone(), op.kind.clone(), &inputs);
        let new_op = &mut out.ops[id.0];
        new_op.fused_act = op.fused_act.or(fuse_act[i]);
        new_op.fused_bias = op.fused_bias || op.kind.sparsifiable();
        new_op.fused_residual = op.fused_residual || fuse_res[i];
        new_id[i] = id;
    }
    stats.ops_after = out.ops.len();
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn fuses_resnet_relu_chains() {
        let g = models::resnet50(1, 224);
        let (f, stats) = fuse(&g);
        assert!(stats.fused_activations + stats.fused_residuals > 10);
        assert!(f.len() < g.len());
        // weighted work is preserved exactly
        let wf = |gr: &Graph| -> f64 {
            gr.ops
                .iter()
                .filter(|o| o.kind.sparsifiable())
                .map(|o| o.kind.flops_dense())
                .sum()
        };
        assert_eq!(wf(&g), wf(&f));
    }

    #[test]
    fn fused_graph_still_topo_ordered() {
        let (f, _) = fuse(&models::resnet50(1, 224));
        for (i, op) in f.ops.iter().enumerate() {
            for inp in &op.inputs {
                assert!(inp.0 < i, "op {i} reads future op {}", inp.0);
            }
        }
    }

    #[test]
    fn bert_gelu_prefused_not_double_counted() {
        // bert builder already fuses GELU into ffn_up; pass must not
        // change weighted-op count
        let g = models::bert(models::BERT_TINY, 1, 128);
        let (f, _) = fuse(&g);
        let count = |gr: &Graph| gr.ops.iter().filter(|o| o.kind.sparsifiable()).count();
        assert_eq!(count(&g), count(&f));
    }

    #[test]
    fn fusion_idempotent() {
        let g = models::resnet50(1, 224);
        let (f1, _) = fuse(&g);
        let (f2, s2) = fuse(&f1);
        assert_eq!(f1.len(), f2.len());
        assert_eq!(s2.fused_activations + s2.fused_residuals, 0);
    }

    #[test]
    fn multi_consumer_not_fused() {
        use crate::graph::op::OpKind;
        let mut g = Graph::new("t", 1);
        let a = g.add("mm", OpKind::MatMul { m: 32, k: 32, n: 32 }, &[]);
        let r = g.add("relu", OpKind::Activation { elems: 1024, func: ActFunc::Relu }, &[a]);
        // relu consumed twice → the MATMUL's consumer (relu) is single, so
        // relu fuses; but `a` consumed twice must never fuse
        g.add("u1", OpKind::MatMul { m: 32, k: 32, n: 32 }, &[r]);
        g.add("u2", OpKind::MatMul { m: 32, k: 32, n: 32 }, &[r]);
        let (f, stats) = fuse(&g);
        assert_eq!(stats.fused_activations, 1);
        assert_eq!(f.len(), 3);
        // both consumers now read the fused matmul
        assert_eq!(f.ops[1].inputs, vec![OpId(0)]);
        assert_eq!(f.ops[2].inputs, vec![OpId(0)]);
    }
}
