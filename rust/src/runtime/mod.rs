//! The runtime layer: artifact manifests plus the PJRT bridge.
//!
//! [`manifest`] (always available) parses `artifacts/manifest.json` — the
//! contract between the build-time Python world (`python/compile/aot.py`)
//! and the serve-time rust world, including the [`TensorSpec`]s that drive
//! the unified [`backend`](crate::backend) API.
//!
//! [`executor`] (feature `pjrt`) loads the HLO-text artifacts and executes
//! them on the PJRT CPU client; [`executor::PjrtServingBackend`] plugs it
//! into the serving coordinator through the same `InferenceBackend` trait
//! the simulator backend implements. No Python on the request path.

#[cfg(feature = "pjrt")]
pub mod executor;
pub mod manifest;

#[cfg(feature = "pjrt")]
pub use executor::{Executor, LoadedModel, PjrtServingBackend};
pub use manifest::{ArtifactIndex, ArtifactMeta, Manifest, Precision, TensorSpec};

// `Value` started life here; it now lives in the unified backend API and
// is re-exported for the runtime-centric import path.
pub use crate::backend::Value;

use std::path::PathBuf;

/// Default artifact directory: `$S4_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("S4_ARTIFACTS") {
        return PathBuf::from(d);
    }
    // relative to the crate root when run via cargo, else cwd
    let candidates = [
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        PathBuf::from("artifacts"),
    ];
    for c in &candidates {
        if c.join("manifest.json").exists() {
            return c.clone();
        }
    }
    candidates[0].clone()
}
