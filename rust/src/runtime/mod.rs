//! The PJRT runtime bridge: Python lowers models once (`make artifacts`);
//! this module loads the HLO-text artifacts and executes them. No Python
//! on the request path.

pub mod executor;
pub mod manifest;

pub use executor::{Executor, LoadedModel, Value};
pub use manifest::{ArtifactMeta, Manifest, TensorSpec};

use std::path::PathBuf;

/// Default artifact directory: `$S4_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("S4_ARTIFACTS") {
        return PathBuf::from(d);
    }
    // relative to the crate root when run via cargo, else cwd
    let candidates = [
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        PathBuf::from("artifacts"),
    ];
    for c in &candidates {
        if c.join("manifest.json").exists() {
            return c.clone();
        }
    }
    candidates[0].clone()
}
