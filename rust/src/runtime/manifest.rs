//! Artifact manifest: what `python/compile/aot.py` built.
//!
//! `artifacts/manifest.json` is the contract between the build-time Python
//! world and the serve-time rust world; this module parses and validates
//! it (and the per-artifact golden files used by the integration tests).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Numeric precision an artifact is served at. `Int8` runs the quantized
/// packed kernel ([`crate::sparse::pack::qspmm_tiled`]); `F32` the float
/// one. Selected per artifact via the manifest's optional `"precision"`
/// field (default `f32`), overridable process-wide with
/// `s4 serve --precision`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Precision {
    #[default]
    F32,
    Int8,
}

impl Precision {
    pub fn parse(s: &str) -> anyhow::Result<Precision> {
        match s {
            "f32" => Ok(Precision::F32),
            "int8" => Ok(Precision::Int8),
            other => anyhow::bail!("unknown precision {other:?} (f32 | int8)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }
}

/// Tensor spec of a runtime input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "s32" | "f32"
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    /// Leading (batch) dimension — 1 for scalar/unbatched shapes.
    pub fn batch_dim(&self) -> usize {
        self.shape.first().copied().unwrap_or(1).max(1)
    }

    /// Elements in one sample: the shape without its leading batch dim.
    /// The coordinator packs/demuxes batches in units of this.
    pub fn sample_elems(&self) -> usize {
        self.elems() / self.batch_dim()
    }

    fn from_json(j: &Json) -> anyhow::Result<TensorSpec> {
        let shape = j
            .get("shape")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("tensor spec missing shape"))?
            .iter()
            .map(|v| v.as_u64().map(|x| x as usize))
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| anyhow::anyhow!("bad shape"))?;
        Ok(TensorSpec {
            name: j.get("name").as_str().unwrap_or("").to_string(),
            shape,
            dtype: j
                .get("dtype")
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("tensor spec missing dtype"))?
                .to_string(),
        })
    }
}

/// One compiled model variant.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub family: String,
    pub model: String,
    pub sparsity: usize,
    pub batch: usize,
    pub seq: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub hlo_bytes: usize,
    pub golden: Option<String>,
    /// Serving precision (manifest `"precision"` field, default f32).
    pub precision: Precision,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
    /// artifact name → index into `artifacts`, built once at parse time
    by_name: HashMap<String, usize>,
}

/// Name-keyed artifact map carrying one payload per artifact — the shared
/// lookup every backend keeps on its spec-introspection hot path (the
/// `executor.rs` HashMap pattern, extracted). Build it once from a
/// manifest with a payload constructor; `get` is O(1) thereafter.
pub struct ArtifactIndex<T> {
    entries: Vec<(ArtifactMeta, T)>,
    by_name: HashMap<String, usize>,
}

impl<T> ArtifactIndex<T> {
    /// One entry per manifest artifact, payload built by `f` (called in
    /// manifest order, so deterministic construction stays deterministic).
    pub fn build<F: FnMut(&ArtifactMeta) -> T>(m: &Manifest, mut f: F) -> ArtifactIndex<T> {
        let entries: Vec<(ArtifactMeta, T)> =
            m.artifacts.iter().map(|a| (a.clone(), f(a))).collect();
        let by_name = entries
            .iter()
            .enumerate()
            .map(|(i, (a, _))| (a.name.clone(), i))
            .collect();
        ArtifactIndex { entries, by_name }
    }

    pub fn get(&self, name: &str) -> Option<&(ArtifactMeta, T)> {
        self.by_name.get(name).map(|&i| &self.entries[i])
    }

    pub fn iter(&self) -> impl Iterator<Item = &(ArtifactMeta, T)> {
        self.entries.iter()
    }
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read {}: {e} (run `make artifacts`)", path.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> anyhow::Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let arts = j
            .get("artifacts")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("manifest missing artifacts[]"))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let inputs = a
                .get("inputs")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<anyhow::Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<anyhow::Result<Vec<_>>>()?;
            artifacts.push(ArtifactMeta {
                name: req_str(a, "name")?,
                file: req_str(a, "file")?,
                family: req_str(a, "family")?,
                model: req_str(a, "model")?,
                sparsity: a.get("sparsity").as_u64().unwrap_or(1) as usize,
                batch: a.get("batch").as_u64().unwrap_or(1) as usize,
                seq: a.get("seq").as_u64().unwrap_or(0) as usize,
                inputs,
                outputs,
                hlo_bytes: a.get("hlo_bytes").as_u64().unwrap_or(0) as usize,
                golden: a.get("golden").as_str().map(String::from),
                precision: match a.get("precision") {
                    Json::Null => Precision::F32,
                    p => Precision::parse(p.as_str().ok_or_else(|| {
                        // a present-but-non-string field must fail loudly,
                        // not silently serve the f32 path
                        anyhow::anyhow!("artifact `precision` must be a string")
                    })?)?,
                },
            });
        }
        anyhow::ensure!(!artifacts.is_empty(), "manifest has no artifacts");
        // names must be unique: the keyed lookups below (and every
        // ArtifactIndex) resolve by name, while other consumers scan the
        // vec — duplicates would make the two disagree
        let mut by_name = HashMap::with_capacity(artifacts.len());
        for (i, a) in artifacts.iter().enumerate() {
            anyhow::ensure!(
                by_name.insert(a.name.clone(), i).is_none(),
                "duplicate artifact name `{}`",
                a.name
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts, by_name })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.by_name.get(name).map(|&i| &self.artifacts[i])
    }

    /// Variants of a model sorted by sparsity ascending (router policy
    /// input).
    pub fn variants_of(&self, model: &str, batch: usize) -> Vec<&ArtifactMeta> {
        let mut v: Vec<&ArtifactMeta> = self
            .artifacts
            .iter()
            .filter(|a| a.model == model && a.batch == batch)
            .collect();
        v.sort_by_key(|a| a.sparsity);
        v
    }

    pub fn hlo_path(&self, a: &ArtifactMeta) -> PathBuf {
        self.dir.join(&a.file)
    }

    /// Golden (input, output) for an artifact, if recorded.
    pub fn golden(&self, a: &ArtifactMeta) -> anyhow::Result<(Vec<f64>, Vec<f64>)> {
        let g = a
            .golden
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("{} has no golden", a.name))?;
        let j = Json::parse(&std::fs::read_to_string(self.dir.join(g))?)
            .map_err(|e| anyhow::anyhow!("golden: {e}"))?;
        let vec = |key: &str| -> anyhow::Result<Vec<f64>> {
            j.get(key)
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("golden missing {key}"))?
                .iter()
                .map(|v| v.as_f64().ok_or_else(|| anyhow::anyhow!("non-numeric")))
                .collect()
        };
        Ok((vec("input")?, vec("output")?))
    }
}

fn req_str(j: &Json, key: &str) -> anyhow::Result<String> {
    j.get(key)
        .as_str()
        .map(String::from)
        .ok_or_else(|| anyhow::anyhow!("manifest artifact missing `{key}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "m_s8_b1", "file": "m.hlo.txt", "family": "bert",
         "model": "m", "sparsity": 8, "batch": 1, "seq": 128,
         "inputs": [{"name": "ids", "shape": [1, 128], "dtype": "s32"}],
         "outputs": [{"shape": [1, 2], "dtype": "f32"}],
         "hlo_bytes": 123},
        {"name": "m_s1_b1", "file": "m1.hlo.txt", "family": "bert",
         "model": "m", "sparsity": 1, "batch": 1, "seq": 128,
         "inputs": [], "outputs": [], "hlo_bytes": 456}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.get("m_s8_b1").unwrap();
        assert_eq!(a.sparsity, 8);
        assert_eq!(a.inputs[0].elems(), 128);
        assert_eq!(a.inputs[0].dtype, "s32");
        assert_eq!(a.inputs[0].batch_dim(), 1);
        assert_eq!(a.inputs[0].sample_elems(), 128);
        assert_eq!(a.outputs[0].sample_elems(), 2);
    }

    #[test]
    fn spec_batch_dim_degenerate_shapes() {
        let s = |shape: Vec<usize>| TensorSpec {
            name: "t".into(),
            shape,
            dtype: "f32".into(),
        };
        assert_eq!(s(vec![]).batch_dim(), 1);
        assert_eq!(s(vec![]).sample_elems(), 1);
        assert_eq!(s(vec![8, 16]).batch_dim(), 8);
        assert_eq!(s(vec![8, 16]).sample_elems(), 16);
        assert_eq!(s(vec![0, 16]).sample_elems(), 0);
    }

    #[test]
    fn variants_sorted_by_sparsity() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let v = m.variants_of("m", 1);
        assert_eq!(v.len(), 2);
        assert_eq!(v[0].sparsity, 1);
        assert_eq!(v[1].sparsity, 8);
        assert!(m.variants_of("nope", 1).is_empty());
    }

    #[test]
    fn precision_parses_and_defaults() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        // SAMPLE carries no precision field → f32 default
        assert_eq!(m.get("m_s8_b1").unwrap().precision, Precision::F32);
        let text = r#"{"artifacts": [
          {"name": "q", "file": "f", "family": "bert", "model": "m",
           "precision": "int8", "inputs": [], "outputs": []}
        ]}"#;
        let m = Manifest::parse(Path::new("/tmp"), text).unwrap();
        assert_eq!(m.get("q").unwrap().precision, Precision::Int8);
        let bad = text.replace("int8", "fp4");
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
        // present-but-non-string must fail loudly, not default to f32
        let non_str = text.replace(r#""int8""#, "8");
        assert!(Manifest::parse(Path::new("/tmp"), &non_str).is_err());
        assert_eq!(Precision::parse("f32").unwrap().name(), "f32");
        assert!(Precision::parse("bf16").is_err());
    }

    #[test]
    fn artifact_index_keyed_lookup() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let idx = ArtifactIndex::build(&m, |a| a.sparsity * 10);
        let (a, payload) = idx.get("m_s8_b1").unwrap();
        assert_eq!(a.name, "m_s8_b1");
        assert_eq!(*payload, 80);
        assert!(idx.get("nope").is_none());
        assert_eq!(idx.iter().count(), m.artifacts.len());
        // iteration preserves manifest order
        let names: Vec<&str> = idx.iter().map(|(a, _)| a.name.as_str()).collect();
        assert_eq!(names, vec!["m_s8_b1", "m_s1_b1"]);
    }

    #[test]
    fn rejects_bad_manifests() {
        assert!(Manifest::parse(Path::new("/tmp"), "{}").is_err());
        assert!(Manifest::parse(Path::new("/tmp"), r#"{"artifacts": []}"#).is_err());
        assert!(Manifest::parse(Path::new("/tmp"), "not json").is_err());
        // missing required name
        let bad = r#"{"artifacts": [{"file": "x", "family": "f", "model": "m"}]}"#;
        assert!(Manifest::parse(Path::new("/tmp"), bad).is_err());
        // duplicate names (keyed lookup would disagree with vec scans)
        let dup = r#"{"artifacts": [
          {"name": "a", "file": "x", "family": "f", "model": "m",
           "inputs": [], "outputs": []},
          {"name": "a", "file": "y", "family": "f", "model": "m",
           "inputs": [], "outputs": []}
        ]}"#;
        assert!(Manifest::parse(Path::new("/tmp"), dup).is_err());
    }
}
