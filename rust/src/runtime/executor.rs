//! PJRT executor: load AOT-lowered HLO text, compile once, execute many.
//!
//! Wraps the `xla` crate (PJRT C API). One [`Executor`] owns the CPU
//! client and a cache of compiled executables keyed by artifact name —
//! compilation happens once per variant at load (or first use), never on
//! the request path.

use std::collections::HashMap;
use std::path::Path;

use crate::runtime::manifest::{ArtifactMeta, Manifest};

/// Runtime input values (matching the artifact's `TensorSpec` order).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    I32(Vec<i32>),
    F32(Vec<f32>),
}

impl Value {
    pub fn len(&self) -> usize {
        match self {
            Value::I32(v) => v.len(),
            Value::F32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A compiled model variant ready to execute.
pub struct LoadedModel {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedModel {
    /// Execute with positional inputs; returns the flattened f32 outputs
    /// (one vec per output tensor; our artifacts have exactly one).
    pub fn run(&self, inputs: &[Value]) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(
            inputs.len() == self.meta.inputs.len(),
            "{}: expected {} inputs, got {}",
            self.meta.name,
            self.meta.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (v, spec) in inputs.iter().zip(&self.meta.inputs) {
            anyhow::ensure!(
                v.len() == spec.elems(),
                "{}: input `{}` needs {} elems, got {}",
                self.meta.name,
                spec.name,
                spec.elems(),
                v.len()
            );
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = match (v, spec.dtype.as_str()) {
                (Value::I32(x), "s32") => xla::Literal::vec1(x).reshape(&dims)?,
                (Value::F32(x), "f32") => xla::Literal::vec1(x).reshape(&dims)?,
                (v, dt) => anyhow::bail!(
                    "{}: input `{}` dtype mismatch (artifact {dt}, value {:?})",
                    self.meta.name,
                    spec.name,
                    std::mem::discriminant(v)
                ),
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(vec![out.to_vec::<f32>()?])
    }
}

/// The PJRT client + compiled-executable cache.
pub struct Executor {
    client: xla::PjRtClient,
    cache: HashMap<String, LoadedModel>,
}

impl Executor {
    /// Create a CPU-PJRT executor.
    pub fn cpu() -> anyhow::Result<Executor> {
        Ok(Executor { client: xla::PjRtClient::cpu()?, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one HLO-text file (no manifest needed — tests/tools).
    pub fn compile_file(
        &self,
        meta: &ArtifactMeta,
        path: &Path,
    ) -> anyhow::Result<LoadedModel> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(LoadedModel { meta: meta.clone(), exe })
    }

    /// Load (compile + cache) an artifact from a manifest.
    pub fn load(&mut self, m: &Manifest, name: &str) -> anyhow::Result<&LoadedModel> {
        if !self.cache.contains_key(name) {
            let meta = m
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("artifact `{name}` not in manifest"))?;
            let lm = self.compile_file(meta, &m.hlo_path(meta))?;
            self.cache.insert(name.to_string(), lm);
        }
        Ok(&self.cache[name])
    }

    /// Load every artifact in the manifest (serve-time warmup).
    pub fn load_all(&mut self, m: &Manifest) -> anyhow::Result<usize> {
        for a in &m.artifacts {
            let name = a.name.clone();
            self.load(m, &name)?;
        }
        Ok(self.cache.len())
    }

    pub fn loaded(&self, name: &str) -> Option<&LoadedModel> {
        self.cache.get(name)
    }

    pub fn loaded_count(&self) -> usize {
        self.cache.len()
    }
}
