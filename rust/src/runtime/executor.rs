//! PJRT executor (feature `pjrt`): load AOT-lowered HLO text, compile
//! once, execute many.
//!
//! Wraps the external `xla` crate (PJRT C API). One [`Executor`] owns the
//! CPU client and a cache of compiled executables keyed by artifact name —
//! compilation happens once per variant at load (or first use), never on
//! the request path. [`PjrtServingBackend`] adapts the executor to the
//! unified [`InferenceBackend`] trait for the serving coordinator.

use std::collections::HashMap;
use std::path::Path;
use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;

use crate::backend::{validate_inputs, InferenceBackend, Value};
use crate::runtime::manifest::{ArtifactMeta, Manifest, TensorSpec};

/// A compiled model variant ready to execute.
pub struct LoadedModel {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedModel {
    /// Execute with positional inputs (validated against the artifact's
    /// input specs); returns one [`Value`] per output tensor (our
    /// artifacts emit exactly one f32 tensor).
    pub fn run(&self, inputs: &[Value]) -> anyhow::Result<Vec<Value>> {
        validate_inputs(&self.meta.name, &self.meta.inputs, inputs)?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (v, spec) in inputs.iter().zip(&self.meta.inputs) {
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = match v {
                Value::I32(x) => xla::Literal::vec1(x).reshape(&dims)?,
                Value::F32(x) => xla::Literal::vec1(x).reshape(&dims)?,
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(vec![Value::F32(out.to_vec::<f32>()?)])
    }
}

/// The PJRT client + compiled-executable cache.
pub struct Executor {
    client: xla::PjRtClient,
    cache: HashMap<String, LoadedModel>,
}

impl Executor {
    /// Create a CPU-PJRT executor.
    pub fn cpu() -> anyhow::Result<Executor> {
        Ok(Executor { client: xla::PjRtClient::cpu()?, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one HLO-text file (no manifest needed — tests/tools).
    pub fn compile_file(
        &self,
        meta: &ArtifactMeta,
        path: &Path,
    ) -> anyhow::Result<LoadedModel> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(LoadedModel { meta: meta.clone(), exe })
    }

    /// Load (compile + cache) an artifact from a manifest.
    pub fn load(&mut self, m: &Manifest, name: &str) -> anyhow::Result<&LoadedModel> {
        if !self.cache.contains_key(name) {
            let meta = m
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("artifact `{name}` not in manifest"))?;
            let lm = self.compile_file(meta, &m.hlo_path(meta))?;
            self.cache.insert(name.to_string(), lm);
        }
        Ok(&self.cache[name])
    }

    /// Load every artifact in the manifest (serve-time warmup).
    pub fn load_all(&mut self, m: &Manifest) -> anyhow::Result<usize> {
        for a in &m.artifacts {
            let name = a.name.clone();
            self.load(m, &name)?;
        }
        Ok(self.cache.len())
    }

    pub fn loaded(&self, name: &str) -> Option<&LoadedModel> {
        self.cache.get(name)
    }

    pub fn loaded_count(&self) -> usize {
        self.cache.len()
    }
}

// ---------------------------------------------------------------------------

type Job = (String, Vec<Value>, Sender<anyhow::Result<Vec<Value>>>);

/// Serving backend over the PJRT executor, implementing the unified
/// [`InferenceBackend`] trait.
///
/// The PJRT client is not `Send`/`Sync` (Rc-based internals), so a
/// dedicated thread owns it; coordinator workers submit execution jobs
/// over a channel. All artifacts are compiled at construction — the
/// request path is pure execution.
pub struct PjrtServingBackend {
    tx: Mutex<Sender<Job>>,
    /// artifact → (input specs, output specs), snapshotted from the manifest
    specs: HashMap<String, (Vec<TensorSpec>, Vec<TensorSpec>)>,
}

impl PjrtServingBackend {
    pub fn new(m: &Manifest) -> anyhow::Result<PjrtServingBackend> {
        let specs = m
            .artifacts
            .iter()
            .map(|a| (a.name.clone(), (a.inputs.clone(), a.outputs.clone())))
            .collect();
        let (tx, rx) = channel::<Job>();
        let m2 = m.clone();
        // readiness signal: compilation happens before serving starts
        let (ready_tx, ready_rx) = channel::<anyhow::Result<usize>>();
        std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || {
                let mut ex = match Executor::cpu() {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                match ex.load_all(&m2) {
                    Ok(n) => {
                        let _ = ready_tx.send(Ok(n));
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                }
                while let Ok((artifact, inputs, resp)) = rx.recv() {
                    let result = ex
                        .loaded(&artifact)
                        .ok_or_else(|| anyhow::anyhow!("artifact {artifact} not loaded"))
                        .and_then(|model| model.run(&inputs));
                    let _ = resp.send(result);
                }
            })?;
        let n = ready_rx.recv()??;
        eprintln!("compiled {n} artifacts on the PJRT executor thread");
        Ok(PjrtServingBackend { tx: Mutex::new(tx), specs })
    }

    fn spec_pair(&self, artifact: &str) -> anyhow::Result<&(Vec<TensorSpec>, Vec<TensorSpec>)> {
        self.specs
            .get(artifact)
            .ok_or_else(|| anyhow::anyhow!("PjrtServingBackend: unknown artifact `{artifact}`"))
    }
}

impl InferenceBackend for PjrtServingBackend {
    fn input_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        Ok(&self.spec_pair(artifact)?.0)
    }

    fn output_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        Ok(&self.spec_pair(artifact)?.1)
    }

    fn run_batch(&self, artifact: &str, inputs: &[Value]) -> anyhow::Result<Vec<Value>> {
        self.spec_pair(artifact)?; // fail fast on unknown artifacts
        let (rtx, rrx) = channel();
        self.tx
            .lock()
            .unwrap()
            .send((artifact.to_string(), inputs.to_vec(), rtx))
            .map_err(|_| anyhow::anyhow!("pjrt executor thread gone"))?;
        rrx.recv()
            .map_err(|_| anyhow::anyhow!("pjrt executor thread gone"))?
    }
}
