//! # s4 — High-sparsity AI accelerator stack (S4/Antoum reproduction)
//!
//! Reproduction of *"S4: a High-sparsity, High-performance AI Accelerator"*
//! (Yen, Xiao, Xu — Moffett AI, 2022): the Antoum chip model, the SparseRT
//! serving runtime, the sparse-tensor substrate, and the evaluation harness
//! that regenerates every table and figure in the paper on a simulated
//! testbed (the silicon itself is the one thing we cannot ship).
//!
//! ## Layer map
//!
//! * [`sparse`] — block-balanced sparse tensor formats, pruning, reference
//!   sparse ops (the numerics the simulator is validated against), INT8
//!   quantization composed with sparsity ([`sparse::quant`]:
//!   `prune → per-channel calibrate → quantize`, serial `qspmm`
//!   reference), the parallel tiled SpMM engine ([`sparse::pack`]:
//!   packed execution layouts + `spmm_tiled`/`qspmm_tiled`, the
//!   multithreaded cache-tiled f32/int8 kernels the CPU serving backend
//!   runs on), and the persistent stripe-execution pool
//!   ([`sparse::pool`]: [`sparse::ExecPool`] — long-lived parked
//!   workers, generic `(stripe_fn, out chunks)` dispatch, per-worker
//!   reusable scratch — the layer every tiled kernel dispatches through
//!   instead of spawning threads per call), and roofline-guided kernel
//!   autotuning ([`sparse::tune`]: per-shape-class microbenchmarked
//!   `(tile_n, max_stripes)` dispatch plans, deterministic
//!   [`sparse::TunePlan`] lookup with JSON save/load — both axes are
//!   bitwise-invariant, so a plan changes speed, never logits).
//! * [`graph`] — an op-graph IR with per-op FLOPs/bytes accounting plus
//!   builders for the paper's benchmark models (ResNet-50/152,
//!   BERT-base/large).
//! * [`arch`] — the Antoum SoC model: SPUs (up to 32× sparse speedup), VPU,
//!   activation engine, embedding-lookup / memory-reshape units, video &
//!   JPEG codecs, LPDDR4 memory system, and the 4-subsystem ring NoC, glued
//!   together by a discrete-event simulation core.
//! * [`sim`] — maps graphs onto the chip, schedules them, and produces
//!   latency/throughput/energy reports; includes the Nvidia T4 dense
//!   baseline the paper compares against.
//! * [`backend`] — the unified typed inference API: [`backend::Value`]
//!   payloads, manifest-driven `TensorSpec` introspection, and the
//!   [`backend::InferenceBackend`] trait every execution engine implements
//!   ([`backend::CpuSparseBackend`] — real block-balanced sparse compute
//!   through the tiled SpMM engine, at f32 or int8 precision per artifact
//!   ([`backend::Precision`], `s4 serve --precision`), [`backend::SimBackend`],
//!   [`backend::EchoBackend`], and the PJRT executor under the `pjrt`
//!   feature) — plus the [`backend::conformance`] suite that pins the
//!   contract.
//! * [`runtime`] — artifact manifests (`artifacts/manifest.json`, the
//!   contract with `python/compile/aot.py`) and, behind the `pjrt`
//!   feature, the PJRT bridge that compiles and executes the AOT-lowered
//!   HLO. Python never runs at serve time.
//! * [`coordinator`] — the SparseRT serving layer: the QoS-aware
//!   [`coordinator::ServingService`] submission surface
//!   ([`coordinator::SubmitOptions`] priority/deadline/tag,
//!   [`coordinator::Ticket`] wait/poll/cancel handles, typed
//!   [`coordinator::ResponseStatus`] outcomes), a staged ingress
//!   pipeline ([`coordinator::IngressStage`] chain: optional exact
//!   response cache with single-flight coalescing
//!   ([`coordinator::ResponseCache`], `--cache-entries`/`--cache-ttl-ms`),
//!   breaker gate, per-class admission control), request router,
//!   priority-aware dynamic batcher with deadline/cancel shedding,
//!   supervised worker pool (per-batch panic fence + automatic respawn,
//!   so a panicking backend never strands a ticket or shrinks capacity),
//!   a consecutive-failure backend-health circuit breaker with typed
//!   retryable shedding ([`coordinator::Breaker`]), metrics
//!   ([`coordinator::MetricsSnapshot`]) — generic over any
//!   [`backend::InferenceBackend`].
//! * [`fault`] — deterministic seeded fault injection for all of the
//!   above: call-indexed [`fault::FaultPlan`] schedules,
//!   [`fault::FaultingBackend`] wrapping any backend with
//!   panic/error/slow injections, and client-side connection chaos
//!   helpers ([`fault::net`]: dropped, garbled, truncated peers). Drives
//!   `tests/chaos.rs` and `benches/fault_recovery.rs`
//!   (`BENCH_fault.json`); reusable for staging burn-in.
//! * [`net`] — the network serving front end over the coordinator: a
//!   length-prefixed binary frame codec whose request frames carry the
//!   full QoS surface and whose f32 payloads round-trip bitwise
//!   ([`net::wire`]), a TCP server binding any
//!   [`coordinator::ServingService`] behind a socket with bounded
//!   per-connection threads and drain-on-shutdown ([`net::NetServer`],
//!   `s4 net-serve`), a blocking pipelined client ([`net::NetClient`]),
//!   and an open-loop load generator with per-class p50/p99/p999 and
//!   achieved-vs-offered reporting ([`net::loadgen`], `s4 net-load`,
//!   `BENCH_net.json`).
//! * [`cluster`] — multi-node sharded serving over the above: static
//!   membership ([`cluster::ClusterSpec`], `--nodes` flag or TOML
//!   subset) with per-node breaker-tracked liveness
//!   ([`cluster::Membership`]), deterministic hash-by-model placement
//!   with replication factor R ([`cluster::ClusterPlacement`]), and a
//!   wire-transparent router tier ([`cluster::RouterServer`],
//!   `s4 cluster-route`) that forwards each submission to a replica
//!   over pooled [`net::NetClient`]s, rotates replicas for load spread,
//!   fails over when a node's breaker opens, and sheds typed-retryable
//!   when no replica is healthy (`tests/cluster_e2e.rs`,
//!   `BENCH_cluster.json`).
//! * [`util`] — in-repo substrates this environment lacks crates for:
//!   JSON, deterministic RNG, stats, CLI parsing, a bench harness (with
//!   the `BENCH_<topic>.json` machine-readable perf-trajectory writer —
//!   see EXPERIMENTS.md §Perf), and a mini property-testing runner.
//!
//! ## Feature flags
//!
//! * `pjrt` *(off by default)* — compiles [`runtime::executor`] (the
//!   `Executor`/`LoadedModel` PJRT bridge and `PjrtServingBackend`), the
//!   `serve_bert` example, and the `runtime_e2e` tests. It needs the
//!   external `xla` crate (see `rust/Cargo.toml`). Everything else —
//!   simulator, coordinator, Sim/Echo backends, benches — builds without
//!   it, so `cargo build --release && cargo test -q` is hermetic.
//!
//! ## Quickstart
//!
//! Simulate (no artifacts or PJRT needed):
//!
//! ```no_run
//! use s4::arch::AntoumConfig;
//! use s4::graph::models;
//! use s4::sim::{simulate, Target};
//!
//! let chip = AntoumConfig::s4();
//! let g = models::resnet50(1, 224);
//! let r = simulate(&g, Target::antoum(&chip, 8)); // sparsity 8x
//! println!("latency: {:.3} ms, throughput: {:.0} img/s",
//!          r.latency_ms, r.throughput);
//! ```
//!
//! Serve — any model, text or vision, goes through one trait; every
//! submission returns a [`coordinator::Ticket`] and takes optional QoS
//! ([`coordinator::SubmitOptions`]: priority class, deadline, tag):
//!
//! ```no_run
//! use std::sync::Arc;
//! use std::time::Duration;
//! use s4::backend::{SimBackend, Value};
//! use s4::coordinator::{Router, RoutingPolicy, Server, ServerConfig, SubmitOptions};
//! use s4::runtime::{default_artifact_dir, Manifest};
//!
//! let manifest = Manifest::load(&default_artifact_dir()).unwrap();
//! let backend = Arc::new(SimBackend::from_manifest(&manifest, 1.0));
//! let srv = Server::start(ServerConfig::default(), manifest,
//!                         Router::new(RoutingPolicy::MaxSparsity), backend);
//! let h = srv.handle();
//! // default options (Standard priority, no deadline)
//! let t = h.submit("bert_tiny", vec![Value::tokens(vec![42; 128])]).unwrap();
//! println!("logits: {:?}", t.wait().unwrap().logits());
//! // latency-critical, shed if not executed within 20ms, cancellable
//! let t = h.submit_with("bert_tiny", vec![Value::tokens(vec![7; 128])],
//!                       SubmitOptions::interactive()
//!                           .with_deadline(Duration::from_millis(20))).unwrap();
//! if t.try_poll().is_none() { t.cancel(); }
//! println!("outcome: {:?}", t.wait().unwrap().status);
//! println!("{}", h.metrics_snapshot().report());
//! srv.shutdown();
//! ```

pub mod arch;
pub mod backend;
pub mod cluster;
pub mod coordinator;
pub mod fault;
pub mod graph;
pub mod net;
pub mod runtime;
pub mod sim;
pub mod sparse;
pub mod util;

/// Crate-wide result type (anyhow-backed).
pub type Result<T> = anyhow::Result<T>;
