//! # s4 — High-sparsity AI accelerator stack (S4/Antoum reproduction)
//!
//! Reproduction of *"S4: a High-sparsity, High-performance AI Accelerator"*
//! (Yen, Xiao, Xu — Moffett AI, 2022): the Antoum chip model, the SparseRT
//! serving runtime, the sparse-tensor substrate, and the evaluation harness
//! that regenerates every table and figure in the paper on a simulated
//! testbed (the silicon itself is the one thing we cannot ship).
//!
//! ## Layer map
//!
//! * [`sparse`] — block-balanced sparse tensor formats, pruning, and
//!   reference sparse ops (the numerics the simulator is validated against).
//! * [`graph`] — an op-graph IR with per-op FLOPs/bytes accounting plus
//!   builders for the paper's benchmark models (ResNet-50/152,
//!   BERT-base/large).
//! * [`arch`] — the Antoum SoC model: SPUs (up to 32× sparse speedup), VPU,
//!   activation engine, embedding-lookup / memory-reshape units, video &
//!   JPEG codecs, LPDDR4 memory system, and the 4-subsystem ring NoC, glued
//!   together by a discrete-event simulation core.
//! * [`sim`] — maps graphs onto the chip, schedules them, and produces
//!   latency/throughput/energy reports; includes the Nvidia T4 dense
//!   baseline the paper compares against.
//! * [`runtime`] — the PJRT bridge: loads `artifacts/*.hlo.txt` (AOT-lowered
//!   JAX models whose matmuls/convs run the Pallas sparse kernel) and
//!   executes them on the CPU client. Python never runs at serve time.
//! * [`coordinator`] — the SparseRT serving layer: request router, dynamic
//!   batcher, admission control, worker pool, metrics.
//! * [`util`] — in-repo substrates this environment lacks crates for:
//!   JSON, deterministic RNG, stats, CLI parsing, a bench harness, and a
//!   mini property-testing runner.
//!
//! ## Quickstart
//!
//! ```no_run
//! use s4::arch::AntoumConfig;
//! use s4::graph::models;
//! use s4::sim::{simulate, Target};
//!
//! let chip = AntoumConfig::s4();
//! let g = models::resnet50(1, 224);
//! let r = simulate(&g, Target::antoum(&chip, 8)); // sparsity 8x
//! println!("latency: {:.3} ms, throughput: {:.0} img/s",
//!          r.latency_ms, r.throughput);
//! ```

pub mod arch;
pub mod coordinator;
pub mod graph;
pub mod runtime;
pub mod sim;
pub mod sparse;
pub mod util;

/// Crate-wide result type (anyhow-backed).
pub type Result<T> = anyhow::Result<T>;
