//! Cluster-wide placement: which node(s) serve a model, and how a batch
//! of work splits across them.
//!
//! This is the fleet-level analogue of the single-node
//! [`coordinator::router`](crate::coordinator::router): that layer picks
//! compiled *artifacts* inside one process; this one picks *nodes*
//! across the fleet. The policy is deterministic sharding with
//! replication — **hash-by-model with replication factor R**:
//!
//! 1. collect the nodes whose [`NodeSpec`] hosts the model (an empty
//!    per-node model list hosts everything), in spec order;
//! 2. hash the model name (FNV-1a, stable across runs and platforms) to
//!    pick a start offset into that host list;
//! 3. the replica set is the next `R` hosts ring-wise from the offset.
//!
//! Every router handed the same [`ClusterSpec`] and the same R computes
//! the same replica set for every model — no coordination channel, no
//! shared state, which is what makes a *static* membership tier viable.
//! [`ClusterPlacement::plan`] additionally answers the capacity
//! question ("this many samples → which node gets how many") by
//! round-robin splitting fill across the replica set, mirroring the
//! shape of the single-node planner's `Vec<Placement>` answer.

use super::membership::ClusterSpec;

/// One node's share of a cluster-level plan: node index (spec order),
/// the model routed, and how many of the `n` requested samples land
/// there.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NodeShare {
    /// Index into [`ClusterSpec::nodes`].
    pub node: usize,
    /// The model being routed (nodes resolve it to an artifact locally).
    pub model: String,
    /// Samples assigned to this node.
    pub fill: usize,
}

/// Deterministic shard/replicate view over a [`ClusterSpec`].
#[derive(Clone, Debug)]
pub struct ClusterPlacement {
    /// Hosted-model sets, one per node, spec order. `None` = hosts all.
    hosted: Vec<Option<Vec<String>>>,
    /// Replication factor R (clamped to ≥ 1, and per-model to the number
    /// of hosts).
    replication: usize,
}

impl ClusterPlacement {
    pub fn new(spec: &ClusterSpec, replication: usize) -> ClusterPlacement {
        let hosted = spec
            .nodes
            .iter()
            .map(|n| if n.models.is_empty() { None } else { Some(n.models.clone()) })
            .collect();
        ClusterPlacement { hosted, replication: replication.max(1) }
    }

    pub fn replication(&self) -> usize {
        self.replication
    }

    /// The replica set for `model`: node indices in preference order
    /// (primary first), empty when no node hosts the model. The order is
    /// a pure function of (spec, R, model) — see the module docs.
    pub fn replicas(&self, model: &str) -> Vec<usize> {
        let hosts: Vec<usize> = self
            .hosted
            .iter()
            .enumerate()
            .filter(|(_, m)| match m {
                None => true,
                Some(list) => list.iter().any(|h| h == model),
            })
            .map(|(i, _)| i)
            .collect();
        if hosts.is_empty() {
            return Vec::new();
        }
        let start = (fnv1a(model.as_bytes()) as usize) % hosts.len();
        let r = self.replication.min(hosts.len());
        (0..r).map(|k| hosts[(start + k) % hosts.len()]).collect()
    }

    /// Cluster-wide plan for `n` samples of `model`: which node(s),
    /// which model, what fill. Fill is split round-robin across the
    /// replica set starting at the primary, so `Σ fill == n` and no
    /// replica gets more than `ceil(n / R)` — the fleet-level mirror of
    /// the single-node planner's exact-cover invariant.
    pub fn plan(&self, model: &str, n: usize) -> anyhow::Result<Vec<NodeShare>> {
        let reps = self.replicas(model);
        anyhow::ensure!(!reps.is_empty(), "no cluster node hosts model `{model}`");
        let mut fills = vec![0usize; reps.len()];
        for i in 0..n {
            fills[i % reps.len()] += 1;
        }
        Ok(reps
            .into_iter()
            .zip(fills)
            .filter(|(_, f)| *f > 0)
            .map(|(node, fill)| NodeShare { node, model: model.to_string(), fill })
            .collect())
    }
}

/// FNV-1a 64-bit — tiny, stable, and plenty for spreading model names
/// over a handful of nodes. Not a DoS-resistant hash; membership is a
/// trusted config, not attacker input.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(flag: &str) -> ClusterSpec {
        ClusterSpec::parse_flag(flag).unwrap()
    }

    #[test]
    fn replicas_are_deterministic_and_bounded_by_r() {
        let s = spec("a=h:1,b=h:2,c=h:3");
        let p = ClusterPlacement::new(&s, 2);
        let r1 = p.replicas("bert_tiny");
        let r2 = p.replicas("bert_tiny");
        assert_eq!(r1, r2, "same spec + model → same replica set");
        assert_eq!(r1.len(), 2, "replication factor honoured");
        assert_ne!(r1[0], r1[1], "replicas are distinct nodes");
        // R larger than the fleet clamps instead of repeating nodes
        let p = ClusterPlacement::new(&s, 9);
        let r = p.replicas("bert_tiny");
        assert_eq!(r.len(), 3);
        let mut sorted = r.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 3, "no node appears twice");
    }

    #[test]
    fn hosted_model_lists_constrain_the_replica_set() {
        let s = spec("a=h:1:bert,b=h:2:gpt,c=h:3");
        let p = ClusterPlacement::new(&s, 3);
        let bert = p.replicas("bert");
        assert!(bert.contains(&0), "a hosts bert");
        assert!(bert.contains(&2), "c hosts everything");
        assert!(!bert.contains(&1), "b hosts only gpt");
        assert!(p.replicas("llama").contains(&2), "only the host-all node");
        assert_eq!(p.replicas("llama").len(), 1);
    }

    #[test]
    fn different_models_spread_across_the_fleet() {
        // with enough models, hashing must not pin every primary to one
        // node — that would be a broken shard function
        let s = spec("a=h:1,b=h:2,c=h:3,d=h:4");
        let p = ClusterPlacement::new(&s, 1);
        let mut primaries = std::collections::HashSet::new();
        for m in ["bert_tiny", "bert_base", "resnet50", "gpt2", "t5", "vit", "llama", "mixtral"] {
            primaries.insert(p.replicas(m)[0]);
        }
        assert!(primaries.len() >= 2, "8 models all hashed to one primary: {primaries:?}");
    }

    #[test]
    fn plan_covers_n_exactly_and_caps_per_replica_skew() {
        let s = spec("a=h:1,b=h:2,c=h:3");
        let p = ClusterPlacement::new(&s, 3);
        for n in [1usize, 2, 3, 7, 24] {
            let shares = p.plan("bert_tiny", n).unwrap();
            let total: usize = shares.iter().map(|s| s.fill).sum();
            assert_eq!(total, n, "Σ fill == n for n={n}");
            let max = shares.iter().map(|s| s.fill).max().unwrap();
            assert!(max <= (n + 2) / 3, "n={n}: share {max} exceeds ceil(n/R)");
        }
        assert!(p.plan("unhosted", 1).is_ok(), "host-all nodes pick it up");
        let constrained = ClusterPlacement::new(&spec("a=h:1:x"), 1);
        assert!(constrained.plan("y", 1).is_err(), "no host → typed error");
    }

    #[test]
    fn fnv1a_is_the_reference_function() {
        // reference vectors for 64-bit FNV-1a
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
