//! Static cluster membership: who the nodes are, what they host, and
//! whether each one is currently believed healthy.
//!
//! Membership is **static by design** for this PR: the fleet is declared
//! up front (a `--nodes` flag list or a minimal TOML file) and never
//! changes while the router runs. Liveness, by contrast, is dynamic —
//! per-node health is tracked with the same consecutive-failure
//! [`Breaker`] the single-node coordinator uses for its backend, fed by
//! real forward outcomes (and optionally by an active TCP probe, see
//! [`RouterServer::probe`](crate::cluster::RouterServer::probe)): a node
//! that keeps failing transport is opened and shed, a node that answers
//! again is closed. Dynamic membership (join/leave, artifact hand-off)
//! is deliberately out of scope and tracked in ROADMAP.md.
//!
//! Two declaration formats, both parsed here with zero dependencies:
//!
//! ```text
//! --nodes n0=127.0.0.1:7450:bert_tiny+resnet50,n1=127.0.0.1:7451
//! ```
//!
//! (`id=host:port[:model+model+...]`; an entry with no model list hosts
//! *every* model), or a TOML subset:
//!
//! ```toml
//! [[node]]
//! id = "n0"
//! addr = "127.0.0.1:7450"
//! models = ["bert_tiny", "resnet50"]
//! ```

use std::path::Path;

use crate::coordinator::health::{Breaker, BreakerConfig, BreakerState};

/// One declared node: identity, dial address, hosted model set.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NodeSpec {
    /// Stable node id (`n0`, `blue`, ...) — used in metrics and logs.
    pub id: String,
    /// Dial address, `host:port`.
    pub addr: String,
    /// Models this node serves. **Empty means "hosts every model"** —
    /// the common homogeneous-replica fleet needs no per-node list.
    pub models: Vec<String>,
}

impl NodeSpec {
    /// Does this node host `model`? (Empty model list = hosts all.)
    pub fn hosts(&self, model: &str) -> bool {
        self.models.is_empty() || self.models.iter().any(|m| m == model)
    }
}

/// The static fleet declaration: an ordered list of [`NodeSpec`]s.
/// Order matters — placement hashes index into this order, so two
/// routers handed the same spec agree on every routing decision.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ClusterSpec {
    pub nodes: Vec<NodeSpec>,
}

impl ClusterSpec {
    /// Parse the `--nodes` flag format:
    /// `id=host:port[:model+model+...]` entries separated by commas.
    ///
    /// The third `:`-field is a model list only when it is not all
    /// digits — `n0=localhost:7450` is an addr with a port, not a model
    /// named `7450`.
    pub fn parse_flag(s: &str) -> anyhow::Result<ClusterSpec> {
        let mut nodes = Vec::new();
        for entry in s.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let (id, rest) = entry
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("node entry `{entry}`: expected id=addr[:models]"))?;
            anyhow::ensure!(!id.trim().is_empty(), "node entry `{entry}`: empty id");
            let (addr, models) = match rest.rsplit_once(':') {
                // `host:port` — the suffix is the port, not a model list
                Some((_, tail)) if !tail.is_empty() && tail.bytes().all(|b| b.is_ascii_digit()) => {
                    (rest.to_string(), Vec::new())
                }
                Some((addr, tail)) => {
                    let models: Vec<String> = tail
                        .split('+')
                        .map(str::trim)
                        .filter(|m| !m.is_empty())
                        .map(str::to_string)
                        .collect();
                    (addr.to_string(), models)
                }
                None => anyhow::bail!("node entry `{entry}`: addr must be host:port"),
            };
            anyhow::ensure!(
                addr.contains(':'),
                "node entry `{entry}`: addr `{addr}` must be host:port"
            );
            nodes.push(NodeSpec { id: id.trim().to_string(), addr, models });
        }
        let spec = ClusterSpec { nodes };
        spec.validate()?;
        Ok(spec)
    }

    /// Parse the TOML subset shown in the module docs: `[[node]]` tables
    /// with `id`, `addr`, and an optional `models` string array. No
    /// general TOML — no dependencies — just what a fleet file needs.
    pub fn parse_toml(text: &str) -> anyhow::Result<ClusterSpec> {
        let mut nodes: Vec<NodeSpec> = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line == "[[node]]" {
                nodes.push(NodeSpec { id: String::new(), addr: String::new(), models: Vec::new() });
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("cluster file line {}: expected key = value", lineno + 1)
            })?;
            let node = nodes.last_mut().ok_or_else(|| {
                anyhow::anyhow!("cluster file line {}: key before any [[node]]", lineno + 1)
            })?;
            let (key, value) = (key.trim(), value.trim());
            match key {
                "id" => node.id = unquote(value, lineno)?,
                "addr" => node.addr = unquote(value, lineno)?,
                "models" => {
                    let inner = value
                        .strip_prefix('[')
                        .and_then(|v| v.strip_suffix(']'))
                        .ok_or_else(|| {
                            anyhow::anyhow!(
                                "cluster file line {}: models must be [\"a\", ...]",
                                lineno + 1
                            )
                        })?;
                    node.models = inner
                        .split(',')
                        .map(str::trim)
                        .filter(|m| !m.is_empty())
                        .map(|m| unquote(m, lineno))
                        .collect::<anyhow::Result<Vec<_>>>()?;
                }
                other => anyhow::bail!(
                    "cluster file line {}: unknown key `{other}` (id/addr/models)",
                    lineno + 1
                ),
            }
        }
        let spec = ClusterSpec { nodes };
        spec.validate()?;
        Ok(spec)
    }

    /// Load a TOML fleet file from disk.
    pub fn load(path: &Path) -> anyhow::Result<ClusterSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read cluster file {}: {e}", path.display()))?;
        ClusterSpec::parse_toml(&text)
    }

    /// Non-empty, unique ids, well-formed addrs.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.nodes.is_empty(), "cluster spec declares no nodes");
        for (i, n) in self.nodes.iter().enumerate() {
            anyhow::ensure!(!n.id.is_empty(), "node #{i}: empty id");
            anyhow::ensure!(n.addr.contains(':'), "node `{}`: addr must be host:port", n.id);
            anyhow::ensure!(
                !self.nodes[..i].iter().any(|m| m.id == n.id),
                "duplicate node id `{}`",
                n.id
            );
        }
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, id: &str) -> Option<&NodeSpec> {
        self.nodes.iter().find(|n| n.id == id)
    }
}

/// Membership + liveness: the static [`ClusterSpec`] paired with one
/// health [`Breaker`] per node, indexed in spec order. The breakers are
/// fed by whoever talks to the nodes (the router's forward path, an
/// active prober); this type just owns them so every consumer sees one
/// consistent health view.
pub struct Membership {
    spec: ClusterSpec,
    health: Vec<Breaker>,
}

impl Membership {
    pub fn new(spec: ClusterSpec, breaker: BreakerConfig) -> Membership {
        let health = spec.nodes.iter().map(|_| Breaker::new(breaker)).collect();
        Membership { spec, health }
    }

    pub fn spec(&self) -> &ClusterSpec {
        &self.spec
    }

    pub fn node(&self, idx: usize) -> &NodeSpec {
        &self.spec.nodes[idx]
    }

    /// The health breaker for node `idx` (spec order).
    pub fn breaker(&self, idx: usize) -> &Breaker {
        &self.health[idx]
    }

    /// Is node `idx` currently believed live? `Open` means "shedding";
    /// `Closed`/`HalfOpen` both still admit traffic (HalfOpen is how an
    /// opened node earns its way back).
    pub fn live(&self, idx: usize) -> bool {
        self.health[idx].state() != BreakerState::Open
    }

    /// Number of nodes currently believed live.
    pub fn live_count(&self) -> usize {
        (0..self.spec.nodes.len()).filter(|&i| self.live(i)).count()
    }
}

fn unquote(v: &str, lineno: usize) -> anyhow::Result<String> {
    v.strip_prefix('"')
        .and_then(|v| v.strip_suffix('"'))
        .map(str::to_string)
        .ok_or_else(|| anyhow::anyhow!("cluster file line {}: expected \"quoted\" string", lineno + 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_format_parses_ids_addrs_and_model_lists() {
        let spec = ClusterSpec::parse_flag(
            "n0=127.0.0.1:7450:bert_tiny+resnet50, n1=127.0.0.1:7451, n2=host:9:m",
        )
        .unwrap();
        assert_eq!(spec.len(), 3);
        assert_eq!(spec.nodes[0].id, "n0");
        assert_eq!(spec.nodes[0].addr, "127.0.0.1:7450");
        assert_eq!(spec.nodes[0].models, vec!["bert_tiny", "resnet50"]);
        // no model list → hosts everything
        assert_eq!(spec.nodes[1].addr, "127.0.0.1:7451");
        assert!(spec.nodes[1].models.is_empty());
        assert!(spec.nodes[1].hosts("anything"));
        assert_eq!(spec.nodes[2].models, vec!["m"]);
        assert!(spec.nodes[0].hosts("bert_tiny"));
        assert!(!spec.nodes[0].hosts("gpt"));
    }

    #[test]
    fn flag_format_rejects_malformed_entries() {
        assert!(ClusterSpec::parse_flag("").is_err(), "no nodes");
        assert!(ClusterSpec::parse_flag("n0=noport").is_err(), "addr without port");
        assert!(ClusterSpec::parse_flag("justaddr:80").is_err(), "missing id=");
        assert!(
            ClusterSpec::parse_flag("n0=h:1,n0=h:2").is_err(),
            "duplicate ids must be rejected"
        );
    }

    #[test]
    fn toml_subset_round_trips_the_module_doc_example() {
        let spec = ClusterSpec::parse_toml(
            r#"
            # fleet file
            [[node]]
            id = "n0"
            addr = "127.0.0.1:7450"
            models = ["bert_tiny", "resnet50"]

            [[node]]
            id = "n1"
            addr = "127.0.0.1:7451"
            "#,
        )
        .unwrap();
        assert_eq!(spec.len(), 2);
        assert_eq!(spec.node("n0").unwrap().models, vec!["bert_tiny", "resnet50"]);
        assert!(spec.node("n1").unwrap().models.is_empty());
        assert!(ClusterSpec::parse_toml("id = \"x\"").is_err(), "key before [[node]]");
        assert!(ClusterSpec::parse_toml("[[node]]\nid = unquoted").is_err());
    }

    #[test]
    fn membership_tracks_per_node_liveness_with_breakers() {
        let spec = ClusterSpec::parse_flag("a=h:1,b=h:2").unwrap();
        let cfg = BreakerConfig { failure_threshold: 2, ..BreakerConfig::default() };
        let m = Membership::new(spec, cfg);
        assert_eq!(m.live_count(), 2);
        // consecutive failures on one node open only that node
        m.breaker(0).record_failure();
        assert!(m.live(0), "below threshold stays live");
        m.breaker(0).record_failure();
        assert!(!m.live(0), "threshold reached → open → shed");
        assert!(m.live(1), "other node untouched");
        assert_eq!(m.live_count(), 1);
    }
}
