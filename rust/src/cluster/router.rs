//! The router tier: a [`ServingService`] that fronts a static fleet of
//! serving nodes over TCP.
//!
//! [`RouterServer`] is **wire-transparent**: it implements the same
//! [`ServingService`] trait as a single-node [`ServerHandle`], so it can
//! sit behind a [`NetServer`] and every existing client — `s4 net-load`,
//! [`NetClient`], the load harness — drives it unchanged. Internally a
//! submission is:
//!
//! 1. **placed** — [`ClusterPlacement::replicas`] answers which nodes
//!    host the model (deterministic hash-by-model, replication R);
//! 2. **rotated** — the replica set is rotated round-robin per request,
//!    so replicas share load instead of the primary serving alone; the
//!    rest of the rotated order is the failover sequence;
//! 3. **health-gated** — each candidate's [`Breaker`] is consulted
//!    ([`Membership`]); an open node is shed from the candidate list.
//!    All candidates open → a typed, retryable
//!    [`AdmissionDecision::RejectUnhealthy`] at the door;
//! 4. **forwarded** — a forwarder thread replays the submission over a
//!    pooled [`NetClient`] to the first candidate, failing over down the
//!    rotated order on transport errors (each failure feeds that node's
//!    breaker). The node's answer flows back bitwise: outputs,
//!    `served_by`, timing, and typed status are preserved verbatim, so
//!    routed logits are byte-identical to direct submission.
//!
//! The ledger invariant holds at the router exactly as it does on a
//! node: every admitted submission is answered exactly once
//! (`answered() == admitted`), with forwards/failovers/no-healthy
//! counted per node in [`MetricsSnapshot::cluster`].
//!
//! Client-side cancellation and deadlines are honoured at the router:
//! the minted [`Ticket`] carries the submission's own deadline
//! (synthesizing a typed `Expired` if the fleet is slower), and a
//! cancel observed before the forward starts short-circuits to
//! `Cancelled` without touching the network.
//!
//! [`Breaker`]: crate::coordinator::health::Breaker
//! [`ServerHandle`]: crate::coordinator::ServerHandle

use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::backend::{InferenceBackend, Value};
use crate::coordinator::admission::AdmissionDecision;
use crate::coordinator::health::{BreakerConfig, BreakerVerdict};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot, NodeRouterStats};
use crate::coordinator::request::{
    Priority, RequestId, Response, ResponseStatus, SubmitOptions, Ticket,
};
use crate::coordinator::router::Router as NodeRouter;
use crate::coordinator::server::{mirror_serving_service, Server, ServerConfig, ServerHandle};
use crate::coordinator::ServingService;
use crate::net::client::{NetClient, RetryPolicy};
use crate::net::server::{NetServer, NetServerConfig};
use crate::net::wire::{ResponseFrame, WireStatus};
use crate::runtime::Manifest;

use super::membership::{ClusterSpec, Membership, NodeSpec};
use super::placement::ClusterPlacement;

/// Router-tier tunables.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Replication factor R handed to [`ClusterPlacement`]: how many
    /// nodes back each model (clamped per model to its host count).
    pub replication: usize,
    /// Per-node health breaker config (same state machine as the
    /// single-node backend breaker).
    pub breaker: BreakerConfig,
    /// Connect retry policy for dialing nodes.
    pub retry: RetryPolicy,
    /// Per-forward response wait bound.
    pub recv_timeout: Duration,
    /// Idle pooled connections retained per node.
    pub pool_per_node: usize,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            replication: 2,
            breaker: BreakerConfig::default(),
            retry: RetryPolicy::default(),
            recv_timeout: Duration::from_secs(10),
            pool_per_node: 32,
        }
    }
}

/// Per-node runtime state: the connection pool and the per-node router
/// counters surfaced in [`MetricsSnapshot::cluster`].
#[derive(Default)]
struct NodeRuntime {
    pool: Mutex<Vec<NetClient>>,
    forwards: AtomicU64,
    failovers: AtomicU64,
    no_healthy: AtomicU64,
}

struct RouterInner {
    membership: Membership,
    placement: ClusterPlacement,
    nodes: Vec<NodeRuntime>,
    cfg: RouterConfig,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    /// Round-robin cursor rotating the replica set per request.
    rr: AtomicU64,
}

/// The routing front end. Cheap to clone (shared inner); see the module
/// docs for the submission path.
#[derive(Clone)]
pub struct RouterServer {
    inner: Arc<RouterInner>,
}

impl RouterServer {
    pub fn new(spec: ClusterSpec, cfg: RouterConfig) -> anyhow::Result<RouterServer> {
        spec.validate()?;
        let placement = ClusterPlacement::new(&spec, cfg.replication);
        let nodes = spec.nodes.iter().map(|_| NodeRuntime::default()).collect();
        let membership = Membership::new(spec, cfg.breaker);
        Ok(RouterServer {
            inner: Arc::new(RouterInner {
                membership,
                placement,
                nodes,
                cfg,
                metrics: Arc::new(Metrics::new()),
                next_id: AtomicU64::new(1),
                rr: AtomicU64::new(0),
            }),
        })
    }

    pub fn membership(&self) -> &Membership {
        &self.inner.membership
    }

    pub fn placement(&self) -> &ClusterPlacement {
        &self.inner.placement
    }

    /// Actively probe every node with a bounded TCP connect, feeding the
    /// health breakers, and report `(node id, reachable)` per node. The
    /// forward path is the authoritative health signal; this lets an
    /// idle router notice a dead node before the first real submission
    /// pays for the discovery.
    pub fn probe(&self, timeout: Duration) -> Vec<(String, bool)> {
        let inner = &self.inner;
        (0..inner.membership.spec().len())
            .map(|i| {
                let n = inner.membership.node(i);
                let ok = n
                    .addr
                    .to_socket_addrs()
                    .ok()
                    .and_then(|mut it| it.next())
                    .map(|sa| TcpStream::connect_timeout(&sa, timeout).is_ok())
                    .unwrap_or(false);
                let b = inner.membership.breaker(i);
                if ok {
                    b.record_success();
                } else if b.record_failure() {
                    inner.metrics.record_breaker_open();
                }
                (n.id.clone(), ok)
            })
            .collect()
    }
}

impl RouterInner {
    /// The candidate order for one request: the deterministic replica
    /// set, rotated by a per-router round-robin cursor so replicas share
    /// steady-state load. Element 0 is this request's primary; the rest
    /// is its failover order.
    fn candidates(&self, model: &str) -> Vec<usize> {
        let reps = self.placement.replicas(model);
        if reps.len() <= 1 {
            return reps;
        }
        let k = (self.rr.fetch_add(1, Ordering::Relaxed) as usize) % reps.len();
        (0..reps.len()).map(|i| reps[(k + i) % reps.len()]).collect()
    }
}

/// Everything one forwarder thread needs, moved in whole.
struct ForwardJob {
    inner: Arc<RouterInner>,
    id: RequestId,
    class: Priority,
    model: String,
    inputs: Vec<Value>,
    opts: SubmitOptions,
    tx: std::sync::mpsc::Sender<Response>,
    cancelled: Arc<AtomicBool>,
    submitted: Instant,
    order: Vec<usize>,
}

impl ForwardJob {
    fn run(self) {
        let resp = if self.cancelled.load(Ordering::Acquire) {
            // cancelled before the forward started: never touch the wire
            Response::cancelled(self.id)
        } else {
            forward(&self.inner, self.id, &self.model, &self.inputs, &self.opts, &self.order)
        };
        // ledger: exactly one terminal record per admitted submission,
        // recorded BEFORE the reply is delivered so a waiter observing
        // the response also observes a settled snapshot
        match &resp.status {
            ResponseStatus::Ok => {
                let lat = self.submitted.elapsed().as_micros() as u64;
                self.inner.metrics.record_completion(self.class, lat, resp.queue_us);
            }
            ResponseStatus::Error(_) => self.inner.metrics.record_failed(),
            s @ (ResponseStatus::Expired | ResponseStatus::Cancelled) => {
                self.inner.metrics.record_shed(s)
            }
        }
        let _ = self.tx.send(resp);
    }
}

/// Walk the candidate order: dial (pooled), replay the submission, and
/// return the first served answer. Transport failures feed the node's
/// breaker and fall through to the next replica; a typed `Rejected`
/// frame from a node is an admission verdict, not a health signal — it
/// also falls through, without dinging the breaker.
fn forward(
    inner: &Arc<RouterInner>,
    id: RequestId,
    model: &str,
    inputs: &[Value],
    opts: &SubmitOptions,
    order: &[usize],
) -> Response {
    let mut last_reject: Option<String> = None;
    for (pos, &ni) in order.iter().enumerate() {
        let breaker = inner.membership.breaker(ni);
        let node = &inner.nodes[ni];
        let pooled = node.pool.lock().unwrap().pop();
        let mut client = match pooled {
            Some(c) => c,
            None => {
                let addr = inner.membership.node(ni).addr.as_str();
                match NetClient::connect_retrying(addr, &inner.cfg.retry, inner.cfg.recv_timeout) {
                    Ok(c) => c,
                    Err(_) => {
                        if breaker.record_failure() {
                            inner.metrics.record_breaker_open();
                        }
                        continue;
                    }
                }
            }
        };
        match client.call_with(model, inputs.to_vec(), opts) {
            Ok(frame) => {
                breaker.record_success();
                let mut pool = node.pool.lock().unwrap();
                if pool.len() < inner.cfg.pool_per_node {
                    pool.push(client);
                }
                drop(pool);
                if let WireStatus::Rejected(msg) = &frame.status {
                    last_reject = Some(msg.clone());
                    continue;
                }
                node.forwards.fetch_add(1, Ordering::Relaxed);
                inner.metrics.record_forward();
                if pos > 0 {
                    node.failovers.fetch_add(1, Ordering::Relaxed);
                    inner.metrics.record_failover();
                }
                return response_from_frame(id, frame);
            }
            Err(_) => {
                // suspect connection: drop it rather than pooling it
                if breaker.record_failure() {
                    inner.metrics.record_breaker_open();
                }
                continue;
            }
        }
    }
    inner.metrics.record_no_healthy_replica();
    if let Some(&primary) = order.first() {
        inner.nodes[primary].no_healthy.fetch_add(1, Ordering::Relaxed);
    }
    match last_reject {
        Some(msg) => Response::error(id, format!("cluster: every replica rejected (retryable): {msg}")),
        None => Response::error(id, "cluster: no healthy replica answered (retryable)"),
    }
}

/// Re-stamp a node's wire answer with the router-minted id; everything
/// else — outputs, `served_by`, timing, typed status — passes through
/// verbatim (the transparency the parity test pins bitwise).
fn response_from_frame(id: RequestId, f: ResponseFrame) -> Response {
    let status = match f.status {
        WireStatus::Ok => ResponseStatus::Ok,
        WireStatus::Error(m) => ResponseStatus::Error(m),
        WireStatus::Expired => ResponseStatus::Expired,
        WireStatus::Cancelled => ResponseStatus::Cancelled,
        // unreachable via forward() (rejects fall through), kept total
        // for direct callers
        WireStatus::Rejected(m) => ResponseStatus::Error(format!("rejected by node: {m}")),
    };
    Response {
        id,
        outputs: f.outputs,
        served_by: f.served_by.into(),
        batch_size: f.batch_size as usize,
        latency_us: f.latency_us,
        queue_us: f.queue_us,
        status,
    }
}

impl ServingService for RouterServer {
    fn submit_with(
        &self,
        model: &str,
        inputs: Vec<Value>,
        opts: SubmitOptions,
    ) -> Result<Ticket, AdmissionDecision> {
        let inner = &self.inner;
        let class = opts.priority;
        let now = Instant::now();

        let order = inner.candidates(model);
        if order.is_empty() {
            // no node hosts the model: admitted-and-answered with a
            // typed error so `answered() == admitted` holds (mirrors the
            // single-node unroutable-model path rather than inventing a
            // new rejection kind)
            let id = RequestId(inner.next_id.fetch_add(1, Ordering::Relaxed));
            let (tx, rx) = channel();
            inner.metrics.record_admitted(class);
            inner.metrics.record_failed();
            let _ = tx.send(Response::error(id, format!("cluster: no node hosts model `{model}`")));
            return Ok(Ticket::new(id, class, rx, Arc::new(AtomicBool::new(false)))
                .with_deadline(opts.deadline.map(|d| now + d)));
        }

        // health gate: drop candidates whose breaker sheds this class
        let live: Vec<usize> = order
            .iter()
            .copied()
            .filter(|&i| {
                !matches!(inner.membership.breaker(i).admit(class), BreakerVerdict::Shed)
            })
            .collect();
        if live.is_empty() {
            // every replica believed down: typed, retryable shed at the
            // door — nothing queued, nothing forwarded
            inner.metrics.record_no_healthy_replica();
            inner.metrics.record_breaker_shed();
            inner.nodes[order[0]].no_healthy.fetch_add(1, Ordering::Relaxed);
            return Err(AdmissionDecision::RejectUnhealthy(class));
        }

        let id = RequestId(inner.next_id.fetch_add(1, Ordering::Relaxed));
        let (tx, rx) = channel();
        let cancelled = Arc::new(AtomicBool::new(false));
        inner.metrics.record_admitted(class);
        let job = ForwardJob {
            inner: inner.clone(),
            id,
            class,
            model: model.to_string(),
            inputs,
            opts: opts.clone(),
            tx: tx.clone(),
            cancelled: cancelled.clone(),
            submitted: now,
            order: live,
        };
        if let Err(e) =
            std::thread::Builder::new().name("s4-router-fwd".into()).spawn(move || job.run())
        {
            inner.metrics.record_failed();
            let _ = tx.send(Response::error(id, format!("router: spawn forwarder: {e}")));
        }
        Ok(Ticket::new(id, class, rx, cancelled).with_deadline(opts.deadline.map(|d| now + d)))
    }

    fn metrics_snapshot(&self) -> MetricsSnapshot {
        let inner = &self.inner;
        let mut snap = inner.metrics.snapshot();
        snap.cluster.by_node = inner
            .nodes
            .iter()
            .enumerate()
            .map(|(i, n)| NodeRouterStats {
                node: inner.membership.node(i).id.clone(),
                forwards: n.forwards.load(Ordering::Relaxed),
                failovers: n.failovers.load(Ordering::Relaxed),
                no_healthy_replica: n.no_healthy.load(Ordering::Relaxed),
            })
            .collect();
        snap
    }

    fn shared_metrics(&self) -> Option<Arc<Metrics>> {
        Some(self.inner.metrics.clone())
    }
}

mirror_serving_service!(RouterServer);

/// One in-process cluster node booted by [`spawn_local_cluster`]: a full
/// coordinator [`Server`] behind its own [`NetServer`] on a loopback
/// port.
pub struct LocalNode {
    pub id: String,
    pub addr: SocketAddr,
    server: Option<Server>,
    net: Arc<NetServer>,
    /// Direct (router-bypassing) handle into this node's coordinator —
    /// parity tests and per-node ledger checks use it.
    pub handle: ServerHandle,
}

impl LocalNode {
    /// Kill this node: stop the socket front end, then drain and join
    /// the coordinator. Idempotent; after this the port refuses
    /// connections, which is exactly the failure the router's breaker
    /// tier exists to absorb.
    pub fn kill(&mut self) {
        self.net.shutdown();
        if let Some(s) = self.server.take() {
            s.shutdown();
        }
    }

    pub fn is_killed(&self) -> bool {
        self.server.is_none()
    }
}

/// An in-process fleet for tests and benches: N [`LocalNode`]s plus the
/// [`ClusterSpec`] describing them.
pub struct LocalCluster {
    pub nodes: Vec<LocalNode>,
}

impl LocalCluster {
    /// The spec a [`RouterServer`] fronting this fleet should be built
    /// from. Every local node hosts every model (empty model list).
    pub fn spec(&self) -> ClusterSpec {
        ClusterSpec {
            nodes: self
                .nodes
                .iter()
                .map(|n| NodeSpec { id: n.id.clone(), addr: n.addr.to_string(), models: Vec::new() })
                .collect(),
        }
    }

    pub fn shutdown(mut self) {
        for n in &mut self.nodes {
            n.kill();
        }
    }
}

/// Boot `n` in-process serving nodes, each a full coordinator stack
/// behind its own loopback [`NetServer`] (OS-assigned ports — tests
/// never race on fixed ones). `mk(i)` supplies node `i`'s stack.
pub fn spawn_local_cluster(
    n: usize,
    mk: impl Fn(usize) -> (ServerConfig, Manifest, NodeRouter, Arc<dyn InferenceBackend>),
) -> anyhow::Result<LocalCluster> {
    spawn_local_cluster_cfg(n, NetServerConfig::default(), mk)
}

/// [`spawn_local_cluster`] with an explicit per-node [`NetServerConfig`]
/// (benches raise `max_connections` for high-concurrency forwarding).
pub fn spawn_local_cluster_cfg(
    n: usize,
    net_cfg: NetServerConfig,
    mk: impl Fn(usize) -> (ServerConfig, Manifest, NodeRouter, Arc<dyn InferenceBackend>),
) -> anyhow::Result<LocalCluster> {
    anyhow::ensure!(n > 0, "cluster needs at least one node");
    let mut nodes = Vec::with_capacity(n);
    for i in 0..n {
        let (cfg, manifest, router, backend) = mk(i);
        let server = Server::start(cfg, manifest, router, backend);
        let handle = server.handle();
        let net =
            Arc::new(NetServer::bind("127.0.0.1:0", Arc::new(handle.clone()), net_cfg.clone())?);
        let addr = net.local_addr();
        nodes.push(LocalNode { id: format!("n{i}"), addr, server: Some(server), net, handle });
    }
    Ok(LocalCluster { nodes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::EchoBackend;
    use crate::coordinator::BreakerState;
    use std::net::TcpListener;

    const MANIFEST: &str = r#"{"artifacts": [
      {"name": "bert_tiny_s8_b1", "file": "x", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 1, "seq": 32,
       "inputs": [{"name": "ids", "shape": [1, 32], "dtype": "s32"}],
       "outputs": [{"shape": [1, 2], "dtype": "f32"}]},
      {"name": "bert_tiny_s8_b8", "file": "y", "family": "bert",
       "model": "bert_tiny", "sparsity": 8, "batch": 8, "seq": 32,
       "inputs": [{"name": "ids", "shape": [8, 32], "dtype": "s32"}],
       "outputs": [{"shape": [8, 2], "dtype": "f32"}]}
    ]}"#;

    fn manifest() -> Manifest {
        Manifest::parse(std::path::Path::new("/tmp"), MANIFEST).unwrap()
    }

    fn echo_node(_i: usize) -> (ServerConfig, Manifest, NodeRouter, Arc<dyn InferenceBackend>) {
        let m = manifest();
        let backend: Arc<dyn InferenceBackend> = Arc::new(EchoBackend::from_manifest(&m));
        let router = NodeRouter::new(crate::coordinator::RoutingPolicy::MaxSparsity);
        (ServerConfig::default(), m, router, backend)
    }

    /// A loopback port with nothing listening — connects get RST fast.
    fn dead_addr() -> String {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        drop(l);
        addr.to_string()
    }

    fn fast_cfg(replication: usize) -> RouterConfig {
        RouterConfig {
            replication,
            retry: RetryPolicy {
                attempts: 1,
                connect_timeout: Duration::from_millis(250),
                ..RetryPolicy::default()
            },
            recv_timeout: Duration::from_secs(5),
            ..RouterConfig::default()
        }
    }

    #[test]
    fn routes_and_round_robins_across_replicas() {
        let cluster = spawn_local_cluster(2, echo_node).unwrap();
        let router = RouterServer::new(cluster.spec(), fast_cfg(2)).unwrap();
        for i in 0..4u64 {
            let t = router
                .submit("bert_tiny", vec![Value::tokens(vec![i as i32; 4])])
                .expect("routable");
            let r = t.wait().unwrap();
            assert!(r.is_ok(), "forwarded submission must serve: {:?}", r.status);
        }
        let snap = router.metrics_snapshot();
        assert_eq!(snap.cluster.forwards, 4);
        assert_eq!(snap.cluster.failovers, 0, "both nodes healthy");
        assert_eq!(snap.answered(), snap.admitted, "router ledger reconciles");
        // rotation spreads a single hot model over both replicas
        for n in &snap.cluster.by_node {
            assert_eq!(n.forwards, 2, "round-robin must split 4 forwards 2/2: {snap:?}");
        }
        cluster.shutdown();
    }

    #[test]
    fn fails_over_to_a_live_replica_when_one_node_is_dead() {
        let cluster = spawn_local_cluster(1, echo_node).unwrap();
        let mut spec = cluster.spec();
        spec.nodes.push(NodeSpec { id: "dead".into(), addr: dead_addr(), models: Vec::new() });
        let router = RouterServer::new(spec, fast_cfg(2)).unwrap();
        for i in 0..4u64 {
            let t = router
                .submit("bert_tiny", vec![Value::tokens(vec![i as i32; 4])])
                .expect("routable");
            let r = t.wait().unwrap();
            assert!(r.is_ok(), "must fail over to the live node: {:?}", r.status);
        }
        let snap = router.metrics_snapshot();
        assert_eq!(snap.cluster.forwards, 4, "every submission served");
        assert!(
            snap.cluster.failovers >= 1,
            "requests whose rotated primary was the dead node must fail over: {snap:?}"
        );
        assert_eq!(snap.answered(), snap.admitted);
        cluster.shutdown();
    }

    #[test]
    fn all_replicas_open_is_a_typed_retryable_door_shed() {
        let spec = ClusterSpec::parse_flag(&format!("d0={},d1={}", dead_addr(), dead_addr()))
            .unwrap();
        let cfg = RouterConfig {
            breaker: BreakerConfig { failure_threshold: 1, ..BreakerConfig::default() },
            ..fast_cfg(2)
        };
        let router = RouterServer::new(spec, cfg).unwrap();
        // first submission is admitted, burns through both dead replicas,
        // and is answered with a typed retryable error
        let t = router.submit("bert_tiny", vec![Value::tokens(vec![1; 4])]).expect("admitted");
        let r = t.wait().unwrap();
        assert!(
            r.error_message().map(|m| m.contains("no healthy replica")).unwrap_or(false),
            "expected the no-healthy-replica error, got {:?}",
            r.status
        );
        assert_eq!(router.membership().breaker(0).state(), BreakerState::Open);
        assert_eq!(router.membership().breaker(1).state(), BreakerState::Open);
        // Bulk never probes an open breaker → clean door shed
        let res = router.submit_with(
            "bert_tiny",
            vec![Value::tokens(vec![1; 4])],
            SubmitOptions::bulk(),
        );
        match res {
            Err(AdmissionDecision::RejectUnhealthy(p)) => assert_eq!(p, Priority::Bulk),
            other => panic!("expected RejectUnhealthy door shed, got {other:?}"),
        }
        let snap = router.metrics_snapshot();
        assert!(snap.cluster.no_healthy_replica >= 2, "mid-flight + door: {snap:?}");
        assert_eq!(snap.answered(), snap.admitted, "door shed is not admitted");
    }

    #[test]
    fn unhosted_model_is_answered_with_a_typed_error() {
        let spec = ClusterSpec::parse_flag("a=127.0.0.1:1:only_this").unwrap();
        let router = RouterServer::new(spec, fast_cfg(1)).unwrap();
        let t = router.submit("ghost", vec![Value::tokens(vec![1; 4])]).expect("admitted");
        let r = t.wait().unwrap();
        assert!(
            r.error_message().map(|m| m.contains("no node hosts")).unwrap_or(false),
            "expected unhosted-model error, got {:?}",
            r.status
        );
        let snap = router.metrics_snapshot();
        assert_eq!(snap.answered(), snap.admitted);
        assert_eq!(snap.cluster.forwards, 0, "nothing touched the wire");
    }

    fn bits_of(vals: &[Value]) -> Vec<Vec<u32>> {
        vals.iter()
            .map(|v| match v {
                Value::F32(x) => x.iter().map(|f| f.to_bits()).collect(),
                Value::I32(x) => x.iter().map(|i| *i as u32).collect(),
            })
            .collect()
    }

    /// Property: forwarding is transparent in both directions — every
    /// [`SubmitOptions`] field survives the router → node hop bitwise,
    /// and every response field (outputs, served_by, timing, batch size)
    /// survives the node → router hop bitwise.
    #[test]
    fn prop_forwarding_preserves_options_and_response_bits() {
        use crate::util::prop::{check, Gen};

        struct Canned {
            metrics: Arc<Metrics>,
            next: AtomicU64,
            seen: Mutex<Vec<(String, Vec<Value>, SubmitOptions)>>,
            reply: Mutex<Response>,
        }
        impl ServingService for Canned {
            fn submit_with(
                &self,
                model: &str,
                inputs: Vec<Value>,
                opts: SubmitOptions,
            ) -> Result<Ticket, AdmissionDecision> {
                let id = RequestId(self.next.fetch_add(1, Ordering::Relaxed));
                self.seen.lock().unwrap().push((model.to_string(), inputs, opts.clone()));
                let (tx, rx) = channel();
                let mut resp = self.reply.lock().unwrap().clone();
                resp.id = id;
                tx.send(resp).unwrap();
                Ok(Ticket::new(id, opts.priority, rx, Arc::new(AtomicBool::new(false))))
            }
            fn metrics_snapshot(&self) -> MetricsSnapshot {
                self.metrics.snapshot()
            }
        }

        let canned = Arc::new(Canned {
            metrics: Arc::new(Metrics::new()),
            next: AtomicU64::new(1),
            seen: Mutex::new(Vec::new()),
            reply: Mutex::new(Response::error(RequestId(0), "unset")),
        });
        let net =
            NetServer::bind("127.0.0.1:0", canned.clone(), NetServerConfig::default()).unwrap();
        let spec = ClusterSpec::parse_flag(&format!("n0={}", net.local_addr())).unwrap();
        let router = RouterServer::new(spec, fast_cfg(1)).unwrap();

        check("router_forwarding_transparency", 40, |g: &mut Gen| {
            // random QoS surface; deadlines are µs-granular because that
            // is the wire encoding (and generous, so nothing expires)
            let mut opts = SubmitOptions::default().with_priority(*g.pick(&Priority::ALL));
            if g.bool() {
                opts = opts
                    .with_deadline(Duration::from_micros(g.usize_in(500_000, 3_000_000) as u64));
            }
            if g.bool() {
                opts = opts.with_client_tag(format!("tag-{}", g.usize_in(0, 9999)));
            }
            let inputs = vec![
                Value::tokens((0..g.usize_in(1, 16)).map(|i| i as i32 * 3 + 1).collect()),
                Value::F32(g.vec_f32(12)),
            ];
            let reply = Response {
                id: RequestId(0),
                outputs: vec![Value::F32(g.vec_f32(12))],
                served_by: Arc::from(format!("artifact_{}", g.usize_in(0, 99)).as_str()),
                batch_size: g.usize_in(1, 64),
                latency_us: g.usize_in(0, 1_000_000) as u64,
                queue_us: g.usize_in(0, 1_000_000) as u64,
                status: ResponseStatus::Ok,
            };
            *canned.reply.lock().unwrap() = reply.clone();

            let t = router
                .submit_with("any_model", inputs.clone(), opts.clone())
                .map_err(|d| format!("rejected: {d:?}"))?;
            let r = t.wait().map_err(|e| format!("wait: {e}"))?;

            // node → router: the answer passes through verbatim
            crate::prop_assert!(r.status == ResponseStatus::Ok, "status: {:?}", r.status);
            crate::prop_assert!(
                *r.served_by == *reply.served_by,
                "served_by drifted: {} != {}",
                r.served_by,
                reply.served_by
            );
            crate::prop_assert!(r.batch_size == reply.batch_size, "batch_size drifted");
            crate::prop_assert!(
                r.latency_us == reply.latency_us && r.queue_us == reply.queue_us,
                "timing drifted: {}/{} != {}/{}",
                r.latency_us,
                r.queue_us,
                reply.latency_us,
                reply.queue_us
            );
            crate::prop_assert!(
                bits_of(&r.outputs) == bits_of(&reply.outputs),
                "output bits drifted"
            );

            // router → node: the node saw exactly what the client sent
            let (model, seen_inputs, seen_opts) = canned
                .seen
                .lock()
                .unwrap()
                .pop()
                .ok_or_else(|| "node saw no submission".to_string())?;
            crate::prop_assert!(model == "any_model", "model drifted: {model}");
            crate::prop_assert!(
                bits_of(&seen_inputs) == bits_of(&inputs),
                "input bits drifted"
            );
            crate::prop_assert!(
                seen_opts.priority == opts.priority,
                "priority drifted: {:?} != {:?}",
                seen_opts.priority,
                opts.priority
            );
            crate::prop_assert!(
                seen_opts.deadline == opts.deadline,
                "deadline drifted: {:?} != {:?}",
                seen_opts.deadline,
                opts.deadline
            );
            crate::prop_assert!(
                seen_opts.client_tag == opts.client_tag,
                "client_tag drifted: {:?} != {:?}",
                seen_opts.client_tag,
                opts.client_tag
            );
            Ok(())
        });
        net.shutdown();
    }

    #[test]
    fn router_ticket_honours_its_own_deadline() {
        // unreachable-but-not-refusing address keeps the forward pending
        // long enough for the ticket's own deadline to fire first
        let spec = ClusterSpec::parse_flag(&format!("d={}", dead_addr())).unwrap();
        let cfg = RouterConfig {
            retry: RetryPolicy {
                attempts: 3,
                base: Duration::from_millis(200),
                connect_timeout: Duration::from_millis(500),
                ..RetryPolicy::default()
            },
            ..fast_cfg(1)
        };
        let router = RouterServer::new(spec, cfg).unwrap();
        let t = router
            .submit_with(
                "bert_tiny",
                vec![Value::tokens(vec![1; 4])],
                SubmitOptions::default().with_deadline(Duration::from_millis(30)),
            )
            .expect("admitted");
        let start = Instant::now();
        let r = t.wait().unwrap();
        assert_eq!(r.status, ResponseStatus::Expired, "own deadline, typed");
        assert!(start.elapsed() < Duration::from_secs(2), "did not wait out the retries");
    }
}
