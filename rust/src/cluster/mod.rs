//! Multi-node sharded serving (Layer 5): a router tier over a static
//! fleet of single-node serving stacks.
//!
//! The single-node coordinator ([`crate::coordinator`]) plus its socket
//! front end ([`crate::net`]) make one machine a serving node. This
//! layer composes N of them into a cluster without touching either:
//!
//! * [`membership`] — the static fleet declaration ([`ClusterSpec`]:
//!   node id, dial address, hosted model set; `--nodes` flag or TOML
//!   subset) paired with per-node liveness ([`Membership`]), tracked
//!   with the same consecutive-failure [`Breaker`] the coordinator uses
//!   for its backend.
//! * [`placement`] — deterministic cluster-wide placement
//!   ([`ClusterPlacement`]): hash-by-model with replication factor R,
//!   answering "which node(s), what fill" with zero coordination.
//! * [`router`] — [`RouterServer`], a [`ServingService`] that forwards
//!   each submission to a replica over pooled [`NetClient`]s, rotating
//!   the replica set for load spread, failing over on transport errors,
//!   and shedding typed-retryable when no replica is healthy. It is
//!   wire-transparent: put a [`NetServer`] in front and every existing
//!   client drives the whole fleet unchanged.
//!
//! ```text
//!                          s4 cluster-route / tests / benches
//!                                      │
//!   clients (NetClient,     ┌──────────▼──────────┐
//!   s4 net-load, loadgen) ─▶│ NetServer           │   the same socket
//!                           │  └─ RouterServer    │   boundary a single
//!                           │      placement ── membership (breaker/node)
//!                           └──────┬───────┬──────┘
//!                    pooled NetClient│       │failover on open breaker
//!                           ┌──────▼─┐   ┌─▼──────┐
//!                           │ node 0 │   │ node 1 │  ... (NetServer +
//!                           │ Server │   │ Server │       coordinator each)
//!                           └────────┘   └────────┘
//! ```
//!
//! Membership is static for this layer (dynamic join/leave is future
//! work, see ROADMAP.md); health is dynamic — breakers open on real
//! forward failures and earn their way closed again.
//!
//! [`Breaker`]: crate::coordinator::health::Breaker
//! [`ServingService`]: crate::coordinator::ServingService
//! [`NetClient`]: crate::net::NetClient
//! [`NetServer`]: crate::net::NetServer

pub mod membership;
pub mod placement;
pub mod router;

pub use membership::{ClusterSpec, Membership, NodeSpec};
pub use placement::{ClusterPlacement, NodeShare};
pub use router::{
    spawn_local_cluster, spawn_local_cluster_cfg, LocalCluster, LocalNode, RouterConfig,
    RouterServer,
};
