//! Pruning on the rust side: magnitude projection into the hardware
//! pattern, and the gradual (Zhu–Gupta) sparsity schedule used by the
//! workload generators. The *training-time* pruning experiments (Table 1)
//! live in `python/compile/prune.py`; this module covers what the serving
//! stack needs — projecting externally-supplied dense weights onto the SPU
//! format and reasoning about schedules.

use super::format::BlockBalanced;
use super::tensor::Dense2;

/// Project a dense matrix to block-balanced sparsity `s` (magnitude).
/// Thin named wrapper so call sites read as intent.
pub fn magnitude_prune(w: &Dense2, sparsity: usize) -> anyhow::Result<BlockBalanced> {
    BlockBalanced::from_dense(w, sparsity)
}

/// Gradual pruning schedule from Zhu & Gupta (2017), eq. (1):
/// `s_t = s_f + (s_i - s_f) * (1 - (t - t0)/(n*Δt))^3` — the paper's §4
/// "training from scratch" option uses this family.
#[derive(Clone, Copy, Debug)]
pub struct PruneSchedule {
    /// initial sparsity FRACTION (0.0 = dense)
    pub initial: f64,
    /// final sparsity fraction, e.g. 0.96875 for 32×
    pub target: f64,
    /// step pruning starts
    pub begin_step: usize,
    /// step target is reached
    pub end_step: usize,
}

impl PruneSchedule {
    /// Schedule reaching hardware factor `s` (fraction `1 - 1/s`).
    pub fn to_factor(s: usize, begin_step: usize, end_step: usize) -> PruneSchedule {
        assert!(s >= 1);
        PruneSchedule {
            initial: 0.0,
            target: 1.0 - 1.0 / s as f64,
            begin_step,
            end_step,
        }
    }

    /// Sparsity fraction at step `t` (clamped outside the ramp).
    pub fn fraction_at(&self, t: usize) -> f64 {
        if t <= self.begin_step {
            return self.initial;
        }
        if t >= self.end_step {
            return self.target;
        }
        let p = (t - self.begin_step) as f64 / (self.end_step - self.begin_step) as f64;
        self.target + (self.initial - self.target) * (1.0 - p).powi(3)
    }

    /// Largest supported hardware factor whose fraction ≤ `fraction_at(t)`,
    /// i.e. the factor the projection uses at step `t`.
    pub fn factor_at(&self, t: usize) -> usize {
        let f = self.fraction_at(t);
        let mut best = 1;
        for &s in &super::SUPPORTED_SPARSITIES {
            if 1.0 - 1.0 / s as f64 <= f + 1e-12 {
                best = s;
            }
        }
        best
    }
}

/// Fraction of exactly-zero weights after projecting `w` at factor `s`.
pub fn measured_sparsity(w: &Dense2, s: usize) -> anyhow::Result<f64> {
    let pruned = magnitude_prune(w, s)?.to_dense();
    Ok(pruned.zeros_count() as f64 / (pruned.rows * pruned.cols) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_endpoints() {
        let sch = PruneSchedule::to_factor(32, 100, 1000);
        assert_eq!(sch.fraction_at(0), 0.0);
        assert_eq!(sch.fraction_at(100), 0.0);
        assert!((sch.fraction_at(1000) - 0.96875).abs() < 1e-12);
        assert!((sch.fraction_at(5000) - 0.96875).abs() < 1e-12);
    }

    #[test]
    fn schedule_monotone() {
        let sch = PruneSchedule::to_factor(16, 0, 1000);
        let mut prev = -1.0;
        for t in (0..=1000).step_by(50) {
            let f = sch.fraction_at(t);
            assert!(f >= prev, "t={t}");
            prev = f;
        }
    }

    #[test]
    fn schedule_cubic_shape() {
        // cubic ramp: most pruning happens early
        let sch = PruneSchedule::to_factor(2, 0, 1000);
        assert!(sch.fraction_at(500) > 0.5 * sch.target);
    }

    #[test]
    fn factor_at_steps_through_supported_set() {
        let sch = PruneSchedule::to_factor(32, 0, 1000);
        assert_eq!(sch.factor_at(0), 1);
        assert_eq!(sch.factor_at(1000), 32);
        let mid = sch.factor_at(500);
        assert!(super::super::is_supported_sparsity(mid));
        assert!((1..=32).contains(&mid));
    }

    #[test]
    fn measured_sparsity_matches_factor() {
        let w = Dense2::randn(256, 64, 60);
        for &s in &[2usize, 8, 32] {
            let f = measured_sparsity(&w, s).unwrap();
            // gaussian weights ⇒ no exact-zero ties; fraction is exact
            assert!((f - (1.0 - 1.0 / s as f64)).abs() < 1e-9, "s={s} f={f}");
        }
    }
}
