//! Persistent stripe-execution pool — the dispatch layer every tiled
//! kernel runs on.
//!
//! Before this module, `spmm_tiled` and `qspmm_tiled` paid OS-level
//! overhead on **every call**: `std::thread::scope` spawns (and joins,
//! and frees) a fresh thread per output stripe, which costs tens of
//! microseconds per thread — more than the compute itself for the
//! small-`m` batches the Interactive serving class produces. [`ExecPool`]
//! amortizes that: a fixed set of workers is spawned once, parks on a
//! condvar between dispatches, and is woken with two lock round-trips
//! per layer call. `BENCH_pool.json` (schema `s4-bench-v1`, written by
//! `rust/benches/pool_latency.rs`) pins `pooled_small_m_speedup_vs_spawn
//! > 1`; targets live in EXPERIMENTS.md §Perf ("Dispatch overhead").
//!
//! Design:
//! * **stripe tasks** — a dispatch partitions an `m × cols` row-major
//!   output into at most `workers + 1` contiguous row stripes
//!   ([`partition_rows`], shared with the spawn-per-call baseline so the
//!   two paths can never disagree about geometry) and runs
//!   `stripe_fn(row0, chunk)` on each. Stripe 0 always runs **on the
//!   calling thread** — a 1-stripe job (the `m == 1` Interactive case)
//!   never takes a lock or wakes anyone.
//! * **static assignment, no work stealing** — worker `i` owns stripe
//!   `i + 1` for the whole dispatch. Stripes are equal-sized to within
//!   one row, so there is nothing to steal, and static assignment is
//!   what makes the lifetime-erasure below provable: a worker can only
//!   ever touch the job its epoch handed it.
//! * **parking/wakeup** — workers sleep on a condvar keyed by a dispatch
//!   epoch; the dispatcher publishes the job under the mutex, bumps the
//!   epoch, and `notify_all`s. Completion is a counter under the same
//!   mutex plus a second condvar the dispatcher waits on.
//! * **per-worker reusable scratch** — [`with_scratch_f32`] /
//!   [`with_scratch_i32`] hand kernels a thread-local, monotonically
//!   grown accumulator buffer, so steady-state stripe execution does no
//!   heap allocation (on pool workers *and* on the calling thread).
//! * **generic over the kernel** — dispatch takes `(out, cols,
//!   stripe_fn)`; nothing in this module knows about f32 vs int8 (or the
//!   future bf16 / NUMA-striped kernels — those add a placement policy
//!   here, not a new spawn path).
//!
//! Determinism: the pool decides only *which thread* computes a stripe,
//! never the reduction order within an output element, so kernels that
//! are bitwise-deterministic under `std::thread::scope` stay
//! bitwise-deterministic here at any worker count (pinned by
//! `prop_pooled_matches_scoped_and_serial` in `rust/tests/properties.rs`).
//!
//! Concurrency contract: one dispatch runs at a time per pool (an
//! internal gate serializes concurrent callers — deliberate: two
//! parallel SpMMs would oversubscribe the same cores, not finish
//! sooner). `stripe_fn` must not dispatch on the same pool (the gate is
//! not reentrant); it may use a *different* pool.

use std::cell::RefCell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Split `m` rows into at most `parts` contiguous stripes, as equal as
/// possible: the first `m % parts` stripes get one extra row. Yields
/// `(row0, rows)` pairs with `rows > 0` — when `m < parts` only `m`
/// single-row stripes are produced, so callers never see empty work.
///
/// This is the ONE partitioning used by the pool, the spawn-per-call
/// baseline ([`scoped_stripes`]), and therefore both tiled kernels —
/// `spmm_tiled`/`qspmm_tiled` previously each hand-rolled a ceil-divide
/// copy of this logic.
pub fn partition_rows(m: usize, parts: usize) -> impl Iterator<Item = (usize, usize)> {
    let parts = parts.max(1).min(m.max(1));
    (0..parts.min(m)).map(move |i| stripe_at(m, parts, i))
}

/// Closed form of [`partition_rows`]'s `i`-th stripe: `(row0, rows)`.
/// Workers use this directly so a dispatch carries no per-stripe table.
#[inline]
fn stripe_at(m: usize, parts: usize, i: usize) -> (usize, usize) {
    let q = m / parts;
    let r = m % parts;
    let rows = q + usize::from(i < r);
    let row0 = i * q + i.min(r);
    (row0, rows)
}

/// Spawn-per-call stripe execution — the exact dispatch discipline the
/// tiled kernels used before [`ExecPool`] existed, kept (a) as the
/// measured baseline for `benches/pool_latency.rs` and (b) as the shared
/// deduplication of the two kernels' old `std::thread::scope`
/// scaffolding. Runs `stripe_fn(row0, chunk)` over the stripes of
/// [`partition_rows`]`(m, max_stripes)` where `m = out.len() / cols`.
pub fn scoped_stripes<T, F>(out: &mut [T], cols: usize, max_stripes: usize, stripe_fn: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let m = if cols == 0 { 0 } else { out.len() / cols };
    assert_eq!(out.len(), m * cols, "out is not m x cols");
    if m == 0 {
        return;
    }
    let stripes = max_stripes.max(1).min(m);
    if stripes == 1 {
        stripe_fn(0, out);
        return;
    }
    std::thread::scope(|s| {
        let f = &stripe_fn;
        let mut rest = &mut *out;
        for (row0, rows) in partition_rows(m, stripes) {
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(rows * cols);
            rest = tail;
            s.spawn(move || f(row0, chunk));
        }
    });
}

/// The type-erased job a dispatch publishes: `f(stripe_index)` runs one
/// stripe. The borrow behind the pointer outlives every use because
/// [`ExecPool::run_stripes`] does not return until all stripes complete.
type JobFn = dyn Fn(usize) + Sync;

#[derive(Clone, Copy)]
struct JobSlot(*const JobFn);

// SAFETY: the pointer is only dereferenced by pool workers between job
// publication and completion, a window during which the dispatcher keeps
// the referent alive and `F: Sync` makes shared calls sound.
unsafe impl Send for JobSlot {}

/// Raw output-base pointer a dispatch shares with its stripes —
/// provenance-preserving (no `usize` laundering, so the pool stays
/// Miri/strict-provenance clean).
struct OutPtr<T>(*mut T);

impl<T> Clone for OutPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for OutPtr<T> {}

// SAFETY: stripes derived from this pointer index disjoint ranges of a
// live `&mut [T]` the dispatcher holds for the whole dispatch.
unsafe impl<T: Send> Send for OutPtr<T> {}
unsafe impl<T: Send> Sync for OutPtr<T> {}

struct Ctrl {
    /// bumped once per dispatch; workers detect new work by `epoch !=
    /// last seen`
    epoch: u64,
    /// workers participating in the current dispatch (worker ids `0 ..
    /// need`); non-participants skip the epoch without touching the job
    need: usize,
    job: Option<JobSlot>,
    /// participants finished so far (compared against `need`)
    done: usize,
    /// a worker's stripe panicked; surfaced by the dispatcher after join
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    ctrl: Mutex<Ctrl>,
    /// workers park here between dispatches
    work_cv: Condvar,
    /// the dispatcher parks here until `done == need`
    done_cv: Condvar,
}

/// Long-lived stripe-execution pool: `workers` pinned-count background
/// threads plus the calling thread, woken per dispatch, parked between.
///
/// Construction is the expensive part (thread spawns) and happens once —
/// per backend via
/// [`CpuSparseBackend::with_pool`](crate::backend::cpu::CpuSparseBackend::with_pool),
/// or process-wide via [`ExecPool::global`]. Dropping a pool joins its
/// workers.
pub struct ExecPool {
    shared: Arc<Shared>,
    /// serializes dispatches; see the module-level concurrency contract
    gate: Mutex<()>,
    workers: usize,
    handles: Vec<JoinHandle<()>>,
}

impl ExecPool {
    /// Spawn `workers` background threads (total parallelism is
    /// `workers + 1`: the dispatching thread always executes stripe 0).
    /// `ExecPool::new(0)` is valid and runs everything inline.
    pub fn new(workers: usize) -> ExecPool {
        let shared = Arc::new(Shared {
            ctrl: Mutex::new(Ctrl {
                epoch: 0,
                need: 0,
                job: None,
                done: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|id| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("s4-pool{id}"))
                    .spawn(move || worker_loop(&shared, id))
                    .expect("spawn pool worker")
            })
            .collect();
        ExecPool { shared, gate: Mutex::new(()), workers, handles }
    }

    /// The process-wide pool the bare `spmm_tiled`/`qspmm_tiled` wrappers
    /// dispatch through: [`configured_participants`]` - 1` workers, i.e.
    /// total parallelism equal to the machine width (or the
    /// `S4_POOL_WORKERS` override). Explicit `threads` arguments are
    /// honored up to that width; beyond it a dispatch is capped at
    /// [`participants`](ExecPool::participants) (the old spawn-per-call
    /// path would oversubscribe instead, which never helped — callers who
    /// really want more stripes than cores can build their own
    /// [`ExecPool::new`]). Never dropped.
    pub fn global() -> &'static Arc<ExecPool> {
        static POOL: OnceLock<Arc<ExecPool>> = OnceLock::new();
        POOL.get_or_init(|| Arc::new(ExecPool::new(configured_participants().saturating_sub(1))))
    }

    /// Background worker count (excludes the dispatching thread).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Maximum concurrent stripes per dispatch: workers + the caller.
    pub fn participants(&self) -> usize {
        self.workers + 1
    }

    /// Clamp a thread-sweep list to what this pool can actually
    /// dispatch: entries above [`participants`](ExecPool::participants)
    /// are dropped (falling back to a single `participants()` entry if
    /// that empties the list), so recorded measurements never claim
    /// parallelism the pool silently downgraded. Shared by the scaling
    /// benches — keep their sweeps honest in `BENCH_*.json`.
    pub fn clamp_thread_sweep(&self, sweep: &mut Vec<usize>) {
        let cap = self.participants();
        sweep.retain(|&t| t <= cap);
        if sweep.is_empty() {
            sweep.push(cap);
        }
    }

    /// Run `stripe_fn(row0, chunk)` over disjoint row stripes of `out`
    /// (an `m × cols` row-major buffer, `m = out.len() / cols`),
    /// partitioned by [`partition_rows`]`(m, max_stripes)` and capped at
    /// [`participants`](ExecPool::participants). Stripe 0 runs on the
    /// calling thread; stripes `1..` on pool workers. Returns after every
    /// stripe completes — a panic inside any stripe is re-raised here,
    /// never left in a worker.
    pub fn run_stripes<T, F>(&self, out: &mut [T], cols: usize, max_stripes: usize, stripe_fn: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let m = if cols == 0 { 0 } else { out.len() / cols };
        // hard assert: a ragged buffer would silently leave a tail of
        // stale elements unwritten (cost is nil next to a dispatch)
        assert_eq!(out.len(), m * cols, "out is not m x cols");
        if m == 0 {
            return;
        }
        let stripes = max_stripes.max(1).min(m).min(self.participants());
        if stripes == 1 {
            // the small-batch fast path: no lock, no wakeup, no worker
            stripe_fn(0, out);
            return;
        }

        let base = OutPtr(out.as_mut_ptr());
        let run_stripe = move |i: usize| {
            let (row0, rows) = stripe_at(m, stripes, i);
            // SAFETY: stripes index disjoint `rows * cols` ranges of a
            // live `&mut [T]` the dispatcher holds for the whole call.
            let chunk = unsafe {
                std::slice::from_raw_parts_mut(base.0.add(row0 * cols), rows * cols)
            };
            stripe_fn(row0, chunk);
        };
        let job: &JobFn = &run_stripe;
        // Lifetime erasure: sound because this function blocks until
        // `done == need`, i.e. until no worker can touch the job again.
        let slot = JobSlot(unsafe {
            std::mem::transmute::<&JobFn, &'static JobFn>(job) as *const JobFn
        });

        let gate = self.gate.lock().unwrap();
        let need = stripes - 1;
        {
            let mut g = self.shared.ctrl.lock().unwrap();
            g.epoch += 1;
            g.need = need;
            g.done = 0;
            g.panicked = false;
            g.job = Some(slot);
            self.shared.work_cv.notify_all();
        }
        // the dispatcher is participant 0 — it computes, it doesn't sleep
        let caller = catch_unwind(AssertUnwindSafe(|| run_stripe(0)));
        let panicked = {
            let mut g = self.shared.ctrl.lock().unwrap();
            while g.done < g.need {
                g = self.shared.done_cv.wait(g).unwrap();
            }
            g.job = None;
            g.panicked
        };
        // release the gate BEFORE re-raising, so a panicking stripe
        // doesn't poison the dispatch mutex and brick the pool
        drop(gate);
        if let Err(e) = caller {
            resume_unwind(e);
        }
        assert!(!panicked, "ExecPool: a worker stripe panicked");
    }
}

/// Parse an `S4_POOL_WORKERS` value: a positive integer participant
/// count (whitespace-tolerant), or `None` for anything unusable — an
/// unset/garbled override silently falls back to machine width rather
/// than wedging serving at startup.
pub fn parse_pool_workers(s: &str) -> Option<usize> {
    s.trim().parse::<usize>().ok().filter(|&n| n >= 1)
}

/// Total participant count the process-wide pool is sized to: the
/// `S4_POOL_WORKERS` env override when set and valid, else
/// `available_parallelism`. Read once per call (the [`ExecPool::global`]
/// sizing and the `host.effective_workers` stamp in every
/// `BENCH_*.json` both consult this, so recorded numbers always name the
/// parallelism that actually ran).
pub fn configured_participants() -> usize {
    std::env::var("S4_POOL_WORKERS")
        .ok()
        .and_then(|v| parse_pool_workers(&v))
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
}

impl Drop for ExecPool {
    fn drop(&mut self) {
        {
            let mut g = self.shared.ctrl.lock().unwrap();
            g.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, id: usize) {
    let mut seen = 0u64;
    loop {
        let slot = {
            let mut g = shared.ctrl.lock().unwrap();
            loop {
                if g.shutdown {
                    return;
                }
                if g.epoch != seen {
                    seen = g.epoch;
                    if id < g.need {
                        break g.job.expect("job published with epoch");
                    }
                    // not a participant this dispatch — skip the epoch
                    // (dispatch completion never waits on this worker)
                }
                g = shared.work_cv.wait(g).unwrap();
            }
        };
        // SAFETY: `slot` belongs to the epoch just observed; the
        // dispatcher keeps its referent alive until `done == need`,
        // which this worker contributes to only after the call returns.
        let result = catch_unwind(AssertUnwindSafe(|| unsafe { (&*slot.0)(id + 1) }));
        let mut g = shared.ctrl.lock().unwrap();
        if result.is_err() {
            g.panicked = true;
        }
        g.done += 1;
        if g.done >= g.need {
            shared.done_cv.notify_all();
        }
    }
}

// --------------------------- per-worker scratch ----------------------------

thread_local! {
    static SCRATCH_F32: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
    static SCRATCH_I32: RefCell<Vec<i32>> = const { RefCell::new(Vec::new()) };
}

/// Hand `f` a thread-local f32 scratch slice of length `len`, grown
/// monotonically and reused across calls — on a pool worker this is the
/// "per-worker reusable scratch" that makes steady-state stripe
/// execution allocation-free. Contents are dirty; callers zero what they
/// need (the kernels `fill(0.0)` per tile anyway). Not reentrant.
pub fn with_scratch_f32<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    SCRATCH_F32.with(|cell| {
        let mut v = cell.borrow_mut();
        if v.len() < len {
            v.resize(len, 0.0);
        }
        f(&mut v[..len])
    })
}

/// The i32 twin of [`with_scratch_f32`] (the INT8 kernel's accumulator).
pub fn with_scratch_i32<R>(len: usize, f: impl FnOnce(&mut [i32]) -> R) -> R {
    SCRATCH_I32.with(|cell| {
        let mut v = cell.borrow_mut();
        if v.len() < len {
            v.resize(len, 0);
        }
        f(&mut v[..len])
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    // -------------------------- partition_rows ----------------------------

    fn collect(m: usize, parts: usize) -> Vec<(usize, usize)> {
        partition_rows(m, parts).collect()
    }

    #[test]
    fn partition_rows_exact_division() {
        assert_eq!(collect(8, 4), vec![(0, 2), (2, 2), (4, 2), (6, 2)]);
    }

    #[test]
    fn partition_rows_remainder_spreads_early() {
        // m % parts != 0: first `m % parts` stripes get the extra row
        assert_eq!(collect(10, 4), vec![(0, 3), (3, 3), (6, 2), (8, 2)]);
        assert_eq!(collect(7, 3), vec![(0, 3), (3, 2), (5, 2)]);
    }

    #[test]
    fn partition_rows_fewer_rows_than_parts() {
        // m < threads: exactly m single-row stripes, never an empty one
        assert_eq!(collect(3, 8), vec![(0, 1), (1, 1), (2, 1)]);
        assert_eq!(collect(1, 4), vec![(0, 1)]);
    }

    #[test]
    fn partition_rows_degenerate_inputs() {
        assert_eq!(collect(0, 4), vec![]);
        assert_eq!(collect(5, 0), vec![(0, 5)], "parts clamps to 1");
    }

    #[test]
    fn partition_rows_covers_all_rows_contiguously() {
        for m in 0..40 {
            for parts in 1..9 {
                let stripes = collect(m, parts);
                let mut next = 0;
                for (row0, rows) in &stripes {
                    assert_eq!(*row0, next, "gap at m={m} parts={parts}");
                    assert!(*rows > 0, "empty stripe at m={m} parts={parts}");
                    next = row0 + rows;
                }
                assert_eq!(next, m, "rows lost at m={m} parts={parts}");
                assert!(stripes.len() <= parts.max(1));
            }
        }
    }

    // ------------------------------ dispatch -------------------------------

    /// Every stripe writes `row index + 1` into its rows; the full output
    /// must come back exactly covered, whatever the pool/stripe count.
    fn check_covering(pool: &ExecPool, m: usize, cols: usize, max_stripes: usize) {
        let mut out = vec![0u32; m * cols];
        pool.run_stripes(&mut out, cols, max_stripes, |row0, chunk| {
            for (li, row) in chunk.chunks_mut(cols).enumerate() {
                row.fill((row0 + li + 1) as u32);
            }
        });
        for r in 0..m {
            for c in 0..cols {
                assert_eq!(out[r * cols + c], (r + 1) as u32, "({r},{c})");
            }
        }
    }

    #[test]
    fn pool_dispatch_covers_output_at_any_worker_count() {
        for workers in [0usize, 1, 2, 3, 7] {
            let pool = ExecPool::new(workers);
            for m in [1usize, 2, 5, 16, 33] {
                for max_stripes in [1usize, 2, 4, 16] {
                    check_covering(&pool, m, 3, max_stripes);
                }
            }
        }
    }

    #[test]
    fn pool_reuse_across_many_dispatches() {
        // the steady-state serving pattern: one pool, many layer calls
        let pool = ExecPool::new(3);
        for i in 0..200 {
            check_covering(&pool, 1 + i % 17, 4, 4);
        }
    }

    #[test]
    fn pool_zero_workers_runs_inline() {
        let pool = ExecPool::new(0);
        assert_eq!(pool.participants(), 1);
        check_covering(&pool, 9, 2, 8);
    }

    #[test]
    fn pool_empty_output_is_a_noop() {
        let pool = ExecPool::new(2);
        let mut out: Vec<f32> = Vec::new();
        pool.run_stripes(&mut out, 4, 4, |_, _| panic!("no stripes expected"));
        pool.run_stripes(&mut out, 0, 4, |_, _| panic!("no stripes expected"));
    }

    #[test]
    fn pool_worker_panic_is_propagated_not_hung() {
        let pool = ExecPool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let mut out = vec![0u8; 8];
            pool.run_stripes(&mut out, 1, 4, |row0, _| {
                if row0 >= 4 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "stripe panic must surface to the dispatcher");
        // ...and the pool must still be usable afterwards
        check_covering(&pool, 6, 2, 3);
    }

    #[test]
    fn pool_concurrent_dispatchers_serialize_safely() {
        // two threads hammer one shared pool; the gate serializes them
        // and every dispatch still completes correctly
        let pool = Arc::new(ExecPool::new(2));
        std::thread::scope(|s| {
            for _ in 0..2 {
                let pool = pool.clone();
                s.spawn(move || {
                    for i in 0..50 {
                        check_covering(&pool, 2 + i % 7, 3, 3);
                    }
                });
            }
        });
    }

    #[test]
    fn pool_drop_joins_workers() {
        let pool = ExecPool::new(4);
        check_covering(&pool, 8, 2, 4);
        drop(pool); // must not hang or leak parked threads
    }

    #[test]
    fn pool_global_is_shared_and_machine_wide() {
        let a = ExecPool::global();
        let b = ExecPool::global();
        assert!(Arc::ptr_eq(a, b));
        // sizing must agree with whatever configured_participants() said
        // at first touch (machine width, or the S4_POOL_WORKERS override)
        assert_eq!(
            a.participants(),
            configured_participants(),
            "global pool spans the configured width"
        );
    }

    #[test]
    fn pool_workers_override_parse() {
        // the S4_POOL_WORKERS grammar: positive integers, whitespace ok
        assert_eq!(parse_pool_workers("4"), Some(4));
        assert_eq!(parse_pool_workers(" 12\n"), Some(12));
        assert_eq!(parse_pool_workers("1"), Some(1));
        // everything unusable falls back (None), never panics
        assert_eq!(parse_pool_workers("0"), None, "zero participants is meaningless");
        assert_eq!(parse_pool_workers(""), None);
        assert_eq!(parse_pool_workers("-2"), None);
        assert_eq!(parse_pool_workers("4.5"), None);
        assert_eq!(parse_pool_workers("all"), None);
        // env readers can't be unit-tested without racing other tests on
        // process-global state; configured_participants() is covered by
        // its invariant instead
        assert!(configured_participants() >= 1);
    }

    #[test]
    fn pool_clamp_thread_sweep_drops_unreachable_points() {
        let pool = ExecPool::new(3); // 4 participants
        let mut sweep = vec![1, 2, 4, 8];
        pool.clamp_thread_sweep(&mut sweep);
        assert_eq!(sweep, vec![1, 2, 4]);
        let mut all_over = vec![16, 32];
        pool.clamp_thread_sweep(&mut all_over);
        assert_eq!(all_over, vec![4], "empty sweep falls back to the cap");
    }

    // ------------------------------ scratch --------------------------------

    #[test]
    fn pool_scratch_grows_monotonically_and_is_reused() {
        let p0 = with_scratch_f32(64, |s| {
            s.fill(1.0);
            s.as_ptr() as usize
        });
        // same or smaller request: same allocation, dirty contents
        let (p1, first) = with_scratch_f32(32, |s| (s.as_ptr() as usize, s[0]));
        assert_eq!(p0, p1, "scratch must be reused, not reallocated");
        assert_eq!(first, 1.0, "scratch is handed back dirty by design");
        // growth keeps the slice length honest
        with_scratch_f32(128, |s| assert_eq!(s.len(), 128));
        with_scratch_i32(16, |s| {
            s.fill(7);
            assert_eq!(s.len(), 16);
        });
    }

    // -------------------------- scoped baseline ----------------------------

    #[test]
    fn pool_scoped_baseline_matches_pooled_dispatch() {
        let pool = ExecPool::new(3);
        for m in [1usize, 2, 7, 20] {
            let mut a = vec![0u32; m * 3];
            let mut b = vec![0u32; m * 3];
            let f = |row0: usize, chunk: &mut [u32]| {
                for (li, row) in chunk.chunks_mut(3).enumerate() {
                    row.fill((row0 + li) as u32 * 10);
                }
            };
            pool.run_stripes(&mut a, 3, 4, f);
            scoped_stripes(&mut b, 3, 4, f);
            assert_eq!(a, b, "m={m}");
        }
    }
}
