//! Roofline-guided kernel autotuning: per-shape dispatch plans for the
//! tiled SpMM engine.
//!
//! The fixed defaults the serving backend shipped with — one column tile
//! width ([`N_TILE`] = 128) and one worker-count heuristic (`m·k ≥ 2048 →
//! parallel, else serial`) — are a single point on what "The Sparsity
//! Roofline" (PAPERS.md) shows is a *measured curve per layer shape*:
//! the profitable tile width and stripe count depend on `(m, k, n, keep,
//! precision)`, and the fixed point is provably wrong on whole regions of
//! it (the size heuristic ignores `n`, so a small-m × wide-n layer runs
//! serial while holding multiple stripes' worth of compute). This module
//! closes ROADMAP "Kernel frontier (d)": measure a small candidate grid
//! per shape class once, remember the winner, dispatch on it forever.
//!
//! Pieces:
//! * [`DispatchPlan`] — the tunable dispatch parameters of one kernel
//!   call: column tile width + stripe cap. Both are **bitwise-invariant**
//!   by the engine's determinism contract (any tile width / stripe count
//!   reproduces the serial reference bit-for-bit —
//!   `prop_pooled_matches_scoped_and_serial`), which is exactly what
//!   makes autotuning safe: a plan can only change *speed*, never
//!   logits. Precision is deliberately NOT a plan axis — it changes
//!   numerics and stays manifest-driven.
//! * [`ShapeClass`] — the lookup key `(m-bucket, k, n, keep, dtype)`.
//!   Batch rows bucket to the next power of two ([`bucket_m`]) so a
//!   handful of tuned points covers every batch size an artifact can
//!   produce.
//! * [`TuneConfig`] — the candidate grid + measurement effort. The
//!   defaults keep a tune of one shape class in the low milliseconds.
//! * [`TunePlan`] — the deterministic lookup table (a `BTreeMap`, so
//!   iteration and serialization order are stable) with JSON save/load
//!   (schema `s4-tune-v1`, `--tune-plan <path>`): serving restarts skip
//!   recalibration by loading the previous run's plan.
//! * [`Tuner`] — the microbenchmark grid search itself: per candidate,
//!   repack the weights once at the candidate tile width
//!   ([`PackedBlockBalanced::repacked`] — a pure storage-order permute),
//!   time the kernel min-of-reps, and keep the argmin (first in grid
//!   order on ties, so the pick is stable under timing jitter on flat
//!   regions).
//!
//! Consumed by [`crate::backend::cpu::CpuSparseBackend`] (`with_tuning`,
//! `s4 serve --tune {off,startup,lazy}`); measured by
//! `rust/benches/autotune.rs` → `BENCH_autotune.json` (EXPERIMENTS.md
//! §Perf "Autotuning").

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;
use std::time::Instant;

use super::matmul::Act;
use super::pack::{
    qspmm_tiled_into, spmm_tiled_into, PackedBlockBalanced, QPackedBlockBalanced, N_TILE,
};
use super::pool::ExecPool;
use super::tensor::{DType, Dense2};
use crate::util::json::Json;

/// Largest m-bucket: batches wider than this share one plan (they are
/// deep in the saturated regime where the optimum stops moving).
pub const M_BUCKET_CAP: usize = 1024;

/// Bucket a batch row count for plan lookup: the next power of two
/// (capped at [`M_BUCKET_CAP`]), so `m ∈ {5,6,7,8} → 8`. Powers of two
/// match how dispatch profitability actually moves — stripe counts are
/// small integers, so doubling m is what changes the answer, not m±1.
pub fn bucket_m(m: usize) -> usize {
    m.max(1).next_power_of_two().min(M_BUCKET_CAP)
}

/// The tunable dispatch parameters of one tiled-kernel call. Everything
/// here is bitwise-invariant: two plans differ in wall clock, never in
/// output bits (pinned by `prop_tuned_matches_serial_any_plan`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct DispatchPlan {
    /// column tile width the weights are packed with
    pub tile_n: usize,
    /// stripe cap handed to [`ExecPool::run_stripes`] (further clamped
    /// there by `m` and the pool's participant count)
    pub max_stripes: usize,
}

impl DispatchPlan {
    /// The pre-tuning fixed dispatch: default tile width and the
    /// backend's historical size heuristic — parallel only when
    /// `m·k ≥ 2048`, which ignores `n` entirely (the blind spot the
    /// autotuner exploits). Kept as the baseline every tuned plan is
    /// measured against; including it in the grid means a tuned plan can
    /// never lose to it by more than timing noise.
    pub fn fixed_default(m: usize, k: usize, threads: usize) -> DispatchPlan {
        DispatchPlan {
            tile_n: N_TILE,
            max_stripes: if m * k >= 2048 { threads.max(1) } else { 1 },
        }
    }
}

/// Plan lookup key: the shape class of one layer call.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct ShapeClass {
    /// [`bucket_m`] of the batch row count
    pub m_bucket: usize,
    /// reduction width
    pub k: usize,
    /// output width
    pub n: usize,
    /// rows kept per block per column (encodes the sparsity tier)
    pub keep: usize,
    /// kernel element type ([`DType::F32`] | [`DType::Int8`]); precision
    /// is part of the *key*, never a tuned *value*
    pub dtype: DType,
}

impl ShapeClass {
    pub fn of(m: usize, k: usize, n: usize, keep: usize, dtype: DType) -> ShapeClass {
        ShapeClass { m_bucket: bucket_m(m), k, n, keep, dtype }
    }
}

/// Candidate grid + measurement effort for one tune run.
#[derive(Clone, Debug)]
pub struct TuneConfig {
    /// column tile widths to try (sorted, deduped by [`candidates`](TuneConfig::candidates))
    pub tile_candidates: Vec<usize>,
    /// stripe caps to try
    pub stripe_candidates: Vec<usize>,
    /// timed repetitions per candidate; the minimum is kept (min-of-reps
    /// is the standard microbenchmark noise filter)
    pub reps: usize,
    /// untimed warmup calls per candidate (cache/branch-predictor fill)
    pub warmup: usize,
    /// minimum wall time per timed sample — tiny layers are batched into
    /// enough kernel calls that the clock can resolve them
    pub min_sample_secs: f64,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig {
            // around the default 128: half/quarter tiles for narrow
            // outputs (less per-tile epilogue waste, better L1 residency
            // at high keep), double for wide-n streaming layers
            tile_candidates: vec![32, 64, 128, 256],
            // 1 = the serial fast path; 8 = the backend's default thread
            // cap; the backend additionally injects its own thread count
            stripe_candidates: vec![1, 2, 4, 8],
            reps: 5,
            warmup: 2,
            min_sample_secs: 2e-5,
        }
    }
}

impl TuneConfig {
    /// Cheaper effort for lazy (first-request) tuning and CI smoke runs.
    pub fn quick() -> Self {
        TuneConfig { reps: 3, warmup: 1, min_sample_secs: 1e-5, ..TuneConfig::default() }
    }

    /// Make sure `t` is among the tile candidates (used to guarantee the
    /// incumbent default configuration is always in the grid).
    pub fn ensure_tile(&mut self, t: usize) {
        if t > 0 && !self.tile_candidates.contains(&t) {
            self.tile_candidates.push(t);
        }
    }

    /// Make sure `s` is among the stripe candidates.
    pub fn ensure_stripe(&mut self, s: usize) {
        if s > 0 && !self.stripe_candidates.contains(&s) {
            self.stripe_candidates.push(s);
        }
    }

    /// The full candidate grid in deterministic order (tiles × stripes,
    /// both ascending, deduped).
    pub fn candidates(&self) -> Vec<DispatchPlan> {
        let tiles: BTreeSet<usize> = self.tile_candidates.iter().copied().filter(|&t| t > 0).collect();
        let stripes: BTreeSet<usize> =
            self.stripe_candidates.iter().copied().filter(|&s| s > 0).collect();
        let mut out = Vec::with_capacity(tiles.len() * stripes.len());
        for &t in &tiles {
            for &s in &stripes {
                out.push(DispatchPlan { tile_n: t, max_stripes: s });
            }
        }
        out
    }
}

/// The tuned lookup table: shape class → winning dispatch plan.
/// `BTreeMap` keeps iteration and JSON serialization deterministic, so
/// two identical tune runs (or a save/load round trip) produce
/// byte-identical plan files.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TunePlan {
    entries: BTreeMap<ShapeClass, DispatchPlan>,
}

impl TunePlan {
    pub fn new() -> TunePlan {
        TunePlan::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn insert(&mut self, class: ShapeClass, plan: DispatchPlan) {
        self.entries.insert(class, plan);
    }

    pub fn get(&self, class: &ShapeClass) -> Option<DispatchPlan> {
        self.entries.get(class).copied()
    }

    /// Hot-path lookup: bucket `m` and fetch the plan for the class, if
    /// one was tuned. `None` means "dispatch on the fixed default".
    pub fn lookup(&self, m: usize, k: usize, n: usize, keep: usize, dtype: DType) -> Option<DispatchPlan> {
        self.get(&ShapeClass::of(m, k, n, keep, dtype))
    }

    /// Absorb every entry of `other` (later inserts win on key clashes).
    pub fn merge(&mut self, other: &TunePlan) {
        for (c, p) in &other.entries {
            self.entries.insert(*c, *p);
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = (&ShapeClass, &DispatchPlan)> {
        self.entries.iter()
    }

    /// Serialize (schema `s4-tune-v1`): one flat object per entry, keys
    /// in `BTreeMap` order, so the file is deterministic and diffable.
    pub fn to_json(&self) -> Json {
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|(c, p)| {
                Json::obj(vec![
                    ("m_bucket", Json::Num(c.m_bucket as f64)),
                    ("k", Json::Num(c.k as f64)),
                    ("n", Json::Num(c.n as f64)),
                    ("keep", Json::Num(c.keep as f64)),
                    ("precision", Json::Str(c.dtype.name().to_string())),
                    ("tile_n", Json::Num(p.tile_n as f64)),
                    ("max_stripes", Json::Num(p.max_stripes as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::Str("s4-tune-v1".into())),
            ("entries", Json::Arr(entries)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<TunePlan> {
        anyhow::ensure!(
            j.get("schema").as_str() == Some("s4-tune-v1"),
            "tune plan: unknown schema {:?} (want s4-tune-v1)",
            j.get("schema")
        );
        let mut plan = TunePlan::new();
        let entries = j
            .get("entries")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("tune plan: missing entries[]"))?;
        for e in entries {
            let num = |key: &str| -> anyhow::Result<usize> {
                e.get(key)
                    .as_u64()
                    .map(|v| v as usize)
                    .ok_or_else(|| anyhow::anyhow!("tune plan entry: bad `{key}` in {e}"))
            };
            let prec = e
                .get("precision")
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("tune plan entry: missing precision"))?;
            let dtype = DType::parse(prec)
                .ok_or_else(|| anyhow::anyhow!("tune plan entry: unknown precision {prec:?}"))?;
            let class = ShapeClass {
                m_bucket: num("m_bucket")?,
                k: num("k")?,
                n: num("n")?,
                keep: num("keep")?,
                dtype,
            };
            let plan_entry =
                DispatchPlan { tile_n: num("tile_n")?, max_stripes: num("max_stripes")? };
            anyhow::ensure!(plan_entry.tile_n > 0, "tune plan entry: tile_n must be > 0");
            anyhow::ensure!(plan_entry.max_stripes > 0, "tune plan entry: max_stripes must be > 0");
            plan.insert(class, plan_entry);
        }
        Ok(plan)
    }

    /// Write the plan file (`--tune-plan <path>`).
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .map_err(|e| anyhow::anyhow!("write tune plan {}: {e}", path.display()))
    }

    /// Read a plan file written by [`save`](TunePlan::save).
    pub fn load(path: &Path) -> anyhow::Result<TunePlan> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read tune plan {}: {e}", path.display()))?;
        let j = Json::parse(text.trim())
            .map_err(|e| anyhow::anyhow!("tune plan {}: {e}", path.display()))?;
        Self::from_json(&j)
    }
}

/// The microbenchmark grid search. Borrows the dispatch pool the plans
/// will later run on — tuning against a different pool than serving
/// would measure the wrong dispatch costs.
pub struct Tuner<'a> {
    pool: &'a ExecPool,
    cfg: TuneConfig,
}

impl<'a> Tuner<'a> {
    pub fn new(pool: &'a ExecPool, cfg: TuneConfig) -> Tuner<'a> {
        Tuner { pool, cfg }
    }

    pub fn config(&self) -> &TuneConfig {
        &self.cfg
    }

    /// Deduped candidate grid with stripe caps clamped to what the pool
    /// can actually dispatch — a recorded plan never claims parallelism
    /// the pool would silently downgrade (same honesty rule as
    /// [`ExecPool::clamp_thread_sweep`]).
    fn effective_candidates(&self) -> Vec<DispatchPlan> {
        let cap = self.pool.participants();
        let mut seen = BTreeSet::new();
        let mut out = Vec::new();
        for c in self.cfg.candidates() {
            let eff = DispatchPlan { tile_n: c.tile_n, max_stripes: c.max_stripes.min(cap) };
            if seen.insert(eff) {
                out.push(eff);
            }
        }
        out
    }

    /// Minimum per-call wall time of `call`, with warmup and clock-
    /// resolution batching (tiny layers run many calls per sample).
    fn min_time(&self, mut call: impl FnMut()) -> f64 {
        for _ in 0..self.cfg.warmup.max(1) {
            call();
        }
        let mut iters: u32 = 1;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                call();
            }
            if t0.elapsed().as_secs_f64() >= self.cfg.min_sample_secs || iters >= 1 << 12 {
                break;
            }
            iters = iters.saturating_mul(4).min(1 << 12);
        }
        let mut best = f64::INFINITY;
        for _ in 0..self.cfg.reps.max(1) {
            let t0 = Instant::now();
            for _ in 0..iters {
                call();
            }
            best = best.min(t0.elapsed().as_secs_f64() / iters as f64);
        }
        best
    }

    /// Grid-search the f32 kernel for batch rows `m` over `w`'s shape.
    /// Per candidate the weights are repacked ONCE at the candidate tile
    /// (the tune-time cost the hot path never pays), then the kernel is
    /// timed min-of-reps; the argmin wins, first-in-grid-order on ties.
    pub fn tune_f32(
        &self,
        w: &PackedBlockBalanced,
        bias: Option<&[f32]>,
        act: Act,
        m: usize,
    ) -> DispatchPlan {
        let m = m.max(1);
        let x = Dense2::randn(m, w.k, tune_seed(m, w.k, w.n));
        let mut out = Dense2::zeros(0, 0);
        let mut best: Option<(f64, DispatchPlan)> = None;
        for cand in self.effective_candidates() {
            let repacked;
            let wt: &PackedBlockBalanced = if cand.tile_n == w.n_tile {
                w
            } else {
                repacked = w.repacked(cand.tile_n);
                &repacked
            };
            let t = self.min_time(|| {
                spmm_tiled_into(self.pool, &x, wt, bias, act, cand.max_stripes, &mut out);
                std::hint::black_box(&out);
            });
            if best.map_or(true, |(bt, _)| t < bt) {
                best = Some((t, cand));
            }
        }
        best.map(|(_, p)| p)
            .unwrap_or_else(|| DispatchPlan { tile_n: w.n_tile, max_stripes: 1 })
    }

    /// The INT8 twin of [`tune_f32`](Tuner::tune_f32).
    pub fn tune_int8(
        &self,
        w: &QPackedBlockBalanced,
        bias: Option<&[f32]>,
        act: Act,
        m: usize,
    ) -> DispatchPlan {
        let m = m.max(1);
        let x = Dense2::randn(m, w.k, tune_seed(m, w.k, w.n));
        let mut out = Dense2::zeros(0, 0);
        let mut qbuf = Vec::new();
        let mut best: Option<(f64, DispatchPlan)> = None;
        for cand in self.effective_candidates() {
            let repacked;
            let wt: &QPackedBlockBalanced = if cand.tile_n == w.n_tile {
                w
            } else {
                repacked = w.repacked(cand.tile_n);
                &repacked
            };
            let t = self.min_time(|| {
                qspmm_tiled_into(
                    self.pool,
                    &x,
                    wt,
                    bias,
                    act,
                    cand.max_stripes,
                    &mut qbuf,
                    &mut out,
                );
                std::hint::black_box(&out);
            });
            if best.map_or(true, |(bt, _)| t < bt) {
                best = Some((t, cand));
            }
        }
        best.map(|(_, p)| p)
            .unwrap_or_else(|| DispatchPlan { tile_n: w.n_tile, max_stripes: 1 })
    }
}

/// Deterministic seed for the representative tune input of a shape.
fn tune_seed(m: usize, k: usize, n: usize) -> u64 {
    0x7E57_5EED ^ ((m as u64) << 40) ^ ((k as u64) << 20) ^ n as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::format::BlockBalanced;
    use crate::sparse::matmul::spmm;
    use crate::sparse::quant::qspmm;

    fn plan_with_entries() -> TunePlan {
        let mut p = TunePlan::new();
        p.insert(
            ShapeClass::of(2, 512, 512, 4, DType::F32),
            DispatchPlan { tile_n: 64, max_stripes: 2 },
        );
        p.insert(
            ShapeClass::of(7, 256, 2048, 8, DType::Int8),
            DispatchPlan { tile_n: 256, max_stripes: 8 },
        );
        p
    }

    #[test]
    fn tune_bucket_m_is_next_power_of_two_capped() {
        assert_eq!(bucket_m(0), 1);
        assert_eq!(bucket_m(1), 1);
        assert_eq!(bucket_m(2), 2);
        assert_eq!(bucket_m(3), 4);
        assert_eq!(bucket_m(8), 8);
        assert_eq!(bucket_m(9), 16);
        assert_eq!(bucket_m(100_000), M_BUCKET_CAP);
    }

    #[test]
    fn tune_lookup_buckets_m_and_keys_on_dtype() {
        let p = plan_with_entries();
        // m=2 and m=1.. wait, bucket(2)=2: both 2 and nothing else
        let hit = p.lookup(2, 512, 512, 4, DType::F32);
        assert_eq!(hit, Some(DispatchPlan { tile_n: 64, max_stripes: 2 }));
        // 7 buckets to 8, as does 5
        assert_eq!(
            p.lookup(5, 256, 2048, 8, DType::Int8),
            Some(DispatchPlan { tile_n: 256, max_stripes: 8 })
        );
        // same shape, other precision: distinct class, no plan
        assert_eq!(p.lookup(2, 512, 512, 4, DType::Int8), None);
        assert_eq!(p.lookup(2, 512, 513, 4, DType::F32), None);
    }

    #[test]
    fn tune_plan_json_round_trip_is_identical() {
        let p = plan_with_entries();
        let j = p.to_json();
        assert_eq!(j.get("schema").as_str(), Some("s4-tune-v1"));
        let back = TunePlan::from_json(&j).unwrap();
        assert_eq!(back, p);
        // and through the serialized text too
        let reparsed = TunePlan::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(reparsed, p);
    }

    #[test]
    fn tune_plan_save_load_round_trip_on_disk() {
        let p = plan_with_entries();
        let path = std::env::temp_dir().join(format!("s4_tune_plan_{}.json", std::process::id()));
        p.save(&path).unwrap();
        let back = TunePlan::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(back, p, "bucket boundaries and plans must survive the file");
    }

    #[test]
    fn tune_plan_rejects_bad_schema_and_entries() {
        assert!(TunePlan::from_json(&Json::parse(r#"{"schema":"v0","entries":[]}"#).unwrap())
            .is_err());
        let bad = r#"{"schema":"s4-tune-v1","entries":[{"m_bucket":1,"k":64,"n":8,"keep":4,
            "precision":"f64","tile_n":32,"max_stripes":2}]}"#;
        assert!(TunePlan::from_json(&Json::parse(bad).unwrap()).is_err(), "unknown precision");
        let zero = r#"{"schema":"s4-tune-v1","entries":[{"m_bucket":1,"k":64,"n":8,"keep":4,
            "precision":"f32","tile_n":0,"max_stripes":2}]}"#;
        assert!(TunePlan::from_json(&Json::parse(zero).unwrap()).is_err(), "zero tile");
    }

    #[test]
    fn tune_config_grid_is_deterministic_and_extendable() {
        let mut cfg = TuneConfig::default();
        let grid = cfg.candidates();
        assert_eq!(grid.len(), 16, "4 tiles x 4 stripes");
        assert_eq!(grid, cfg.candidates(), "grid order is stable");
        // the incumbent default config is representable in the grid
        assert!(grid.contains(&DispatchPlan { tile_n: N_TILE, max_stripes: 1 }));
        cfg.ensure_tile(N_TILE); // already present: no growth
        cfg.ensure_stripe(8);
        assert_eq!(cfg.candidates().len(), 16);
        cfg.ensure_tile(48);
        cfg.ensure_stripe(5);
        assert_eq!(cfg.candidates().len(), 25);
        assert!(cfg.candidates().contains(&DispatchPlan { tile_n: 48, max_stripes: 5 }));
    }

    #[test]
    fn tune_fixed_default_mirrors_backend_heuristic() {
        // parallel iff m*k >= 2048, n-blind — the documented weakness
        assert_eq!(
            DispatchPlan::fixed_default(2, 512, 8),
            DispatchPlan { tile_n: N_TILE, max_stripes: 1 }
        );
        assert_eq!(
            DispatchPlan::fixed_default(16, 128, 8),
            DispatchPlan { tile_n: N_TILE, max_stripes: 8 }
        );
        assert_eq!(DispatchPlan::fixed_default(0, 0, 0).max_stripes, 1);
    }

    #[test]
    fn tune_picks_a_grid_member_and_stays_bitwise() {
        // whatever the tuner picks, dispatching on the pick must be
        // bitwise-identical to the serial references — the invariance
        // that makes tuning safe at all
        let pool = ExecPool::new(2);
        let tuner = Tuner::new(&pool, TuneConfig::quick());
        let m = 4;
        let x = Dense2::randn(m, 64, 11);
        let w = BlockBalanced::from_dense(&Dense2::randn(64, 96, 12), 8).unwrap();
        let packed = w.pack();
        let plan = tuner.tune_f32(&packed, None, Act::None, m);
        assert!(tuner
            .effective_candidates()
            .contains(&plan), "picked plan {plan:?} must come from the grid");
        let serial = spmm(&x, &w, None, Act::None);
        let wt = packed.repacked(plan.tile_n);
        let mut out = Dense2::zeros(0, 0);
        spmm_tiled_into(&pool, &x, &wt, None, Act::None, plan.max_stripes, &mut out);
        assert_eq!(serial.data, out.data, "tuned f32 dispatch diverged");

        let qb = w.quantize();
        let qpacked = qb.pack();
        let qplan = tuner.tune_int8(&qpacked, None, Act::None, m);
        let qserial = qspmm(&x, &qb, None, Act::None);
        let qwt = qpacked.repacked(qplan.tile_n);
        let mut qout = Dense2::zeros(0, 0);
        let mut qbuf = Vec::new();
        qspmm_tiled_into(&pool, &x, &qwt, None, Act::None, qplan.max_stripes, &mut qbuf, &mut qout);
        assert_eq!(qserial.data, qout.data, "tuned int8 dispatch diverged");
    }

    #[test]
    fn tune_candidates_clamp_stripes_to_pool() {
        let pool = ExecPool::new(1); // 2 participants
        let tuner = Tuner::new(&pool, TuneConfig::default());
        for c in tuner.effective_candidates() {
            assert!(c.max_stripes <= 2, "stripe cap {c:?} exceeds pool participants");
        }
        // 4 tiles x {1,2} stripes after clamping+dedup
        assert_eq!(tuner.effective_candidates().len(), 8);
    }
}
