//! Compressed sparse formats.
//!
//! [`BlockBalanced`] is the hardware format (mirrors
//! `python/compile/kernels/pack.py` — keep in sync): along the reduction
//! dim every block of `BLOCK` weights keeps exactly `BLOCK/s` non-zeros
//! per output column, stored as values + *block-relative u8 offsets*
//! (the on-chip encoding; Python uses absolute i32 for kernel addressing).
//! [`Csr`] is the general-purpose comparison format used by the ablation
//! benches to show why the balanced constraint is what buys linear
//! speedup.

use super::tensor::{DType, Dense2};

/// Hardware block size along the reduction dimension (one SPU weight-buffer
/// row). 32 admits every supported sparsity factor up to 32×.
pub const BLOCK: usize = 32;

/// Block-balanced compressed matrix. Logical shape `[k, n]`, reduction dim
/// `k`; physically `[k/s, n]` values + offsets, column-major-by-block like
/// the SPU weight buffer streams them.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockBalanced {
    pub k: usize,
    pub n: usize,
    pub sparsity: usize,
    /// `[k/s * n]`, laid out row-major over `[k/s, n]` (same as Python).
    pub values: Vec<f32>,
    /// block-relative offsets in `[0, BLOCK)`, same layout as `values`.
    pub offsets: Vec<u8>,
}

impl BlockBalanced {
    /// Rows kept per block per column.
    pub fn keep(&self) -> usize {
        BLOCK / self.sparsity
    }

    /// Compressed row count `k/s`.
    pub fn kc(&self) -> usize {
        self.k / self.sparsity
    }

    /// Prune `w` ([k, n] dense) to block-balanced sparsity `s` by magnitude
    /// — keeps the `BLOCK/s` largest-|w| rows of every (block, column).
    /// Ties break toward the lower row index (matches numpy argsort
    /// stability in `pack.py`).
    pub fn from_dense(w: &Dense2, sparsity: usize) -> anyhow::Result<BlockBalanced> {
        anyhow::ensure!(
            super::is_supported_sparsity(sparsity),
            "sparsity {sparsity} unsupported (SPU supports {:?})",
            super::SUPPORTED_SPARSITIES
        );
        anyhow::ensure!(
            w.rows % BLOCK == 0,
            "reduction dim {} not divisible by block {BLOCK}",
            w.rows
        );
        let (k, n) = (w.rows, w.cols);
        let keep = BLOCK / sparsity;
        let nblocks = k / BLOCK;
        let kc = k / sparsity;
        let mut values = vec![0.0f32; kc * n];
        let mut offsets = vec![0u8; kc * n];
        // scratch: (|w|, row-in-block) pairs for one (block, col)
        let mut cand: Vec<(f32, usize)> = Vec::with_capacity(BLOCK);
        for b in 0..nblocks {
            for c in 0..n {
                cand.clear();
                for r in 0..BLOCK {
                    cand.push((w.at(b * BLOCK + r, c).abs(), r));
                }
                // top-`keep` by magnitude; stable tie-break on row index.
                cand.sort_by(|x, y| {
                    y.0.partial_cmp(&x.0)
                        .unwrap()
                        .then(x.1.cmp(&y.1))
                });
                let mut kept: Vec<usize> =
                    cand[..keep].iter().map(|&(_, r)| r).collect();
                kept.sort_unstable();
                for (slot, &r) in kept.iter().enumerate() {
                    let out_row = b * keep + slot;
                    values[out_row * n + c] = w.at(b * BLOCK + r, c);
                    offsets[out_row * n + c] = r as u8;
                }
            }
        }
        Ok(BlockBalanced { k, n, sparsity, values, offsets })
    }

    /// Decompress to dense `[k, n]`.
    pub fn to_dense(&self) -> Dense2 {
        let keep = self.keep();
        let mut out = Dense2::zeros(self.k, self.n);
        for cr in 0..self.kc() {
            let block = cr / keep;
            for c in 0..self.n {
                let off = self.offsets[cr * self.n + c] as usize;
                let v = self.values[cr * self.n + c];
                if v != 0.0 {
                    *out.at_mut(block * BLOCK + off, c) = v;
                }
            }
        }
        out
    }

    /// Absolute reduction row of compressed slot `(cr, c)`.
    #[inline]
    pub fn abs_row(&self, cr: usize, c: usize) -> usize {
        let block = cr / self.keep();
        block * BLOCK + self.offsets[cr * self.n + c] as usize
    }

    /// Storage footprint in bytes at the given weight dtype
    /// (values at `dtype` + 1 byte/offset + per-block bookkeeping).
    /// This is what the paper's "sparsity directly reduces memory
    /// footprint and I/O" claim quantifies.
    pub fn bytes(&self, dtype: DType) -> usize {
        let slots = self.kc() * self.n;
        slots * dtype.bytes() + slots + (self.k / BLOCK) * 8
    }

    /// Dense footprint of the same logical matrix.
    pub fn dense_bytes(&self, dtype: DType) -> usize {
        self.k * self.n * dtype.bytes()
    }

    /// Validate structural invariants (offset ranges, ascending in block).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.values.len() == self.kc() * self.n, "values len");
        anyhow::ensure!(self.offsets.len() == self.kc() * self.n, "offsets len");
        let keep = self.keep();
        for cr in 0..self.kc() {
            for c in 0..self.n {
                let off = self.offsets[cr * self.n + c] as usize;
                anyhow::ensure!(off < BLOCK, "offset {off} out of block");
                if cr % keep > 0 {
                    let prev = self.offsets[(cr - 1) * self.n + c] as usize;
                    anyhow::ensure!(
                        prev < off || self.values[cr * self.n + c] == 0.0,
                        "offsets not ascending within block (col {c}, row {cr})"
                    );
                }
            }
        }
        Ok(())
    }
}

/// Compressed sparse row — the unstructured-comparison format.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    pub fn from_dense(w: &Dense2) -> Csr {
        let mut row_ptr = Vec::with_capacity(w.rows + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0);
        for r in 0..w.rows {
            for c in 0..w.cols {
                let v = w.at(r, c);
                if v != 0.0 {
                    col_idx.push(c as u32);
                    values.push(v);
                }
            }
            row_ptr.push(values.len());
        }
        Csr { rows: w.rows, cols: w.cols, row_ptr, col_idx, values }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn to_dense(&self) -> Dense2 {
        let mut out = Dense2::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for i in self.row_ptr[r]..self.row_ptr[r + 1] {
                *out.at_mut(r, self.col_idx[i] as usize) = self.values[i];
            }
        }
        out
    }

    /// Storage bytes: values + 4-byte col ids + row pointers.
    pub fn bytes(&self, dtype: DType) -> usize {
        self.nnz() * (dtype.bytes() + 4) + (self.rows + 1) * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randw(k: usize, n: usize, seed: u64) -> Dense2 {
        Dense2::randn(k, n, seed)
    }

    #[test]
    fn roundtrip_preserves_kept_weights() {
        let w = randw(64, 16, 1);
        for &s in &super::super::SUPPORTED_SPARSITIES {
            let bb = BlockBalanced::from_dense(&w, s).unwrap();
            bb.validate().unwrap();
            let d = bb.to_dense();
            // every kept entry equals the original; kept count per block/col
            let keep = BLOCK / s;
            for blk in 0..64 / BLOCK {
                for c in 0..16 {
                    let nz = (0..BLOCK)
                        .filter(|&r| d.at(blk * BLOCK + r, c) != 0.0)
                        .count();
                    assert!(nz <= keep, "s={s} blk={blk} col={c}: {nz} > {keep}");
                }
            }
            for r in 0..64 {
                for c in 0..16 {
                    let v = d.at(r, c);
                    if v != 0.0 {
                        assert_eq!(v, w.at(r, c));
                    }
                }
            }
        }
    }

    #[test]
    fn s1_is_lossless() {
        let w = randw(96, 8, 2);
        let bb = BlockBalanced::from_dense(&w, 1).unwrap();
        assert_eq!(bb.to_dense(), w);
    }

    #[test]
    fn keeps_largest_magnitudes() {
        // strictly increasing magnitude → top rows of each block survive
        let mut w = Dense2::zeros(64, 1);
        for r in 0..64 {
            *w.at_mut(r, 0) = (r + 1) as f32;
        }
        let bb = BlockBalanced::from_dense(&w, 4).unwrap(); // keep 8/32
        let d = bb.to_dense();
        for r in 0..64 {
            let kept = d.at(r, 0) != 0.0;
            let expect = (24..32).contains(&(r % 32));
            assert_eq!(kept, expect, "row {r}");
        }
    }

    #[test]
    fn bytes_scale_with_sparsity() {
        let w = randw(1024, 256, 3);
        let b1 = BlockBalanced::from_dense(&w, 1).unwrap().bytes(DType::Bf16);
        let b8 = BlockBalanced::from_dense(&w, 8).unwrap().bytes(DType::Bf16);
        let b32 = BlockBalanced::from_dense(&w, 32).unwrap().bytes(DType::Bf16);
        assert!(b8 < b1 / 6, "b8={b8} b1={b1}");
        assert!(b32 < b8 / 3, "b32={b32} b8={b8}");
    }

    #[test]
    fn rejects_bad_args() {
        let w = randw(60, 4, 4); // 60 % 32 != 0
        assert!(BlockBalanced::from_dense(&w, 2).is_err());
        let w2 = randw(64, 4, 5);
        assert!(BlockBalanced::from_dense(&w2, 3).is_err());
    }

    #[test]
    fn abs_row_matches_dense_position() {
        let w = randw(64, 8, 6);
        let bb = BlockBalanced::from_dense(&w, 8).unwrap();
        let d = bb.to_dense();
        for cr in 0..bb.kc() {
            for c in 0..bb.n {
                let v = bb.values[cr * bb.n + c];
                if v != 0.0 {
                    assert_eq!(d.at(bb.abs_row(cr, c), c), v);
                }
            }
        }
    }

    #[test]
    fn csr_roundtrip_and_nnz() {
        let w = randw(32, 32, 7);
        let bb = BlockBalanced::from_dense(&w, 4).unwrap();
        let pruned = bb.to_dense();
        let csr = Csr::from_dense(&pruned);
        assert_eq!(csr.to_dense(), pruned);
        assert_eq!(csr.nnz(), 32 * 32 / 4);
    }

    #[test]
    fn balanced_beats_csr_storage() {
        // the structured format stores u8 offsets vs CSR's u32 col ids
        let w = randw(1024, 512, 8);
        let bb = BlockBalanced::from_dense(&w, 8).unwrap();
        let csr = Csr::from_dense(&bb.to_dense());
        assert!(bb.bytes(DType::Bf16) < csr.bytes(DType::Bf16));
    }
}
