//! Packed execution layout + the parallel tiled SpMM engine.
//!
//! [`BlockBalanced`] stores values/offsets row-major over `[k/s, n]` —
//! the natural *construction* layout (it mirrors `pack.py`). The hot
//! kernel wants something else: weight data grouped by output-column
//! tile so one tile streams contiguously while an input block stays in
//! registers. [`BlockBalanced::pack`] reorders into that layout once at
//! load time; [`spmm_tiled`] is the kernel the serving backend
//! ([`crate::backend::cpu`]) runs batches through.
//!
//! Kernel structure (targets in EXPERIMENTS.md §Perf):
//! * **parallel over output-row stripes** — each participant owns a
//!   disjoint `&mut` stripe of the output, dispatched through the
//!   persistent [`ExecPool`](crate::sparse::pool::ExecPool) (parked
//!   workers woken per call — no per-call thread spawns; the old
//!   spawn-per-call discipline survives only as the measured baseline
//!   [`scoped_stripes`](crate::sparse::pool::scoped_stripes));
//! * **cache-blocked over `n`** — weights are walked one column tile at
//!   a time; a tile's `keep × tile` slab sits in L1 while it is reused
//!   across a chunk of input rows, cutting DRAM traffic by the chunk
//!   length;
//! * **reusable per-worker scratch** — accumulation runs in the pool's
//!   thread-local scratch tile
//!   ([`with_scratch_f32`](crate::sparse::pool::with_scratch_f32)),
//!   grown once and reused across layer calls; the fused
//!   bias+activation epilogue writes the output exactly once;
//! * **specialized inner loops** — the per-block gather loop is
//!   monomorphized over `keep ∈ {32,16,8,4,2,1}` (sparsity 1..32×) so
//!   the compiler fully unrolls the `keep` dimension.
//!
//! Determinism: every output element is reduced in ascending
//! compressed-row order — the same order as the serial [`spmm`]
//! reference — for *any* thread count or tile width, so results are
//! bit-identical across machines and `threads` settings (the property
//! tests in `rust/tests/properties.rs` pin this).
//!
//! [`spmm`]: crate::sparse::matmul::spmm

use super::format::{BlockBalanced, BLOCK};
use super::matmul::Act;
use super::pool::{scoped_stripes, with_scratch_f32, with_scratch_i32, ExecPool};
use super::quant::{QBlockBalanced, QParams};
use super::tensor::Dense2;
use super::tune::DispatchPlan;

/// Default output-column tile width: 128 columns × one weight-buffer row
/// of values+offsets per block keeps a whole per-block slab (`keep × 128`
/// slots at 5 bytes/slot ≤ 20 KiB even at keep=32) inside L1d.
pub const N_TILE: usize = 128;

/// Input rows processed per weight-tile pass: each column tile is
/// streamed from memory once per `ROW_CHUNK` rows instead of once per
/// row.
const ROW_CHUNK: usize = 16;

/// [`BlockBalanced`] reordered for execution: values and offsets advance
/// in lockstep through column tiles (an interleave at tile granularity —
/// per-slot interleaving would break f32 alignment for no cache benefit).
///
/// Layout: tiles are laid out left to right; within tile `t` (columns
/// `[t*n_tile, t*n_tile + tw)`), compressed rows are contiguous:
/// slot `(cr, c)` lives at `kc*t*n_tile + cr*tw + (c - t*n_tile)`.
/// The `keep` rows of one reduction block therefore form one contiguous
/// `keep × tw` slab — the unit the inner kernel streams.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedBlockBalanced {
    pub k: usize,
    pub n: usize,
    pub sparsity: usize,
    /// column tile width the data was packed with
    pub n_tile: usize,
    /// `[k/s * n]` values in tile order (see struct docs)
    pub values: Vec<f32>,
    /// block-relative offsets in `[0, BLOCK)`, same order as `values`
    pub offsets: Vec<u8>,
}

impl PackedBlockBalanced {
    /// Rows kept per block per column.
    pub fn keep(&self) -> usize {
        BLOCK / self.sparsity
    }

    /// Compressed row count `k/s`.
    pub fn kc(&self) -> usize {
        self.k / self.sparsity
    }

    /// The same weights repacked at a different column tile width — a
    /// pure storage-order permute (unpack to row-major, re-tile), so the
    /// result is exactly what `pack_tiled(n_tile)` on the original
    /// [`BlockBalanced`] would produce. This is the autotuner's tune-time
    /// operation: the hot path never repacks, it dispatches on a variant
    /// materialized once here.
    pub fn repacked(&self, n_tile: usize) -> PackedBlockBalanced {
        if n_tile == self.n_tile {
            return self.clone();
        }
        let (values, offsets) =
            unpack_slots(&self.values, &self.offsets, self.kc(), self.n, self.n_tile);
        let (values, offsets) = pack_slots(&values, &offsets, self.kc(), self.n, n_tile);
        PackedBlockBalanced {
            k: self.k,
            n: self.n,
            sparsity: self.sparsity,
            n_tile,
            values,
            offsets,
        }
    }
}

/// The tile reorder itself, generic over the value element so the f32
/// and i8 packed layouts come from ONE loop and can never diverge (the
/// int8 kernel's bitwise contract assumes the identical tile order).
fn pack_slots<V: Copy>(
    values: &[V],
    offsets: &[u8],
    kc: usize,
    n: usize,
    n_tile: usize,
) -> (Vec<V>, Vec<u8>) {
    assert!(n_tile > 0, "tile width must be positive");
    let mut pv = Vec::with_capacity(kc * n);
    let mut po = Vec::with_capacity(kc * n);
    let mut col = 0;
    while col < n {
        let tw = n_tile.min(n - col);
        for cr in 0..kc {
            let at = cr * n + col;
            pv.extend_from_slice(&values[at..at + tw]);
            po.extend_from_slice(&offsets[at..at + tw]);
        }
        col += tw;
    }
    (pv, po)
}

/// Inverse of [`pack_slots`]: tile order back to row-major `[kc, n]`.
/// `pack_slots(unpack_slots(p)) == p` at any pair of tile widths, which
/// is what makes [`PackedBlockBalanced::repacked`] a pure permute.
fn unpack_slots<V: Copy + Default>(
    values: &[V],
    offsets: &[u8],
    kc: usize,
    n: usize,
    n_tile: usize,
) -> (Vec<V>, Vec<u8>) {
    assert!(n_tile > 0, "tile width must be positive");
    let mut rv = vec![V::default(); kc * n];
    let mut ro = vec![0u8; kc * n];
    let mut col = 0;
    let mut base = 0;
    while col < n {
        let tw = n_tile.min(n - col);
        for cr in 0..kc {
            let at = base + cr * tw;
            rv[cr * n + col..cr * n + col + tw].copy_from_slice(&values[at..at + tw]);
            ro[cr * n + col..cr * n + col + tw].copy_from_slice(&offsets[at..at + tw]);
        }
        base += kc * tw;
        col += tw;
    }
    (rv, ro)
}

impl BlockBalanced {
    /// Reorder into the execution layout at the default tile width.
    pub fn pack(&self) -> PackedBlockBalanced {
        self.pack_tiled(N_TILE)
    }

    /// Reorder into the execution layout with an explicit column tile
    /// width (property tests use small widths to exercise tile seams).
    pub fn pack_tiled(&self, n_tile: usize) -> PackedBlockBalanced {
        let (values, offsets) =
            pack_slots(&self.values, &self.offsets, self.kc(), self.n, n_tile);
        PackedBlockBalanced {
            k: self.k,
            n: self.n,
            sparsity: self.sparsity,
            n_tile,
            values,
            offsets,
        }
    }
}

/// [`QBlockBalanced`] reordered for execution: the INT8 twin of
/// [`PackedBlockBalanced`], same tile order, values as i8 plus the
/// per-output-channel dequantization scales. Produced by the
/// `prune → per-channel calibrate → pack` pipeline
/// (`BlockBalanced::from_dense` → [`BlockBalanced::quantize`] →
/// [`QBlockBalanced::pack`]); executed by [`qspmm_tiled`].
#[derive(Clone, Debug, PartialEq)]
pub struct QPackedBlockBalanced {
    pub k: usize,
    pub n: usize,
    pub sparsity: usize,
    /// column tile width the data was packed with
    pub n_tile: usize,
    /// `[k/s * n]` i8 values in tile order (see [`PackedBlockBalanced`])
    pub values: Vec<i8>,
    /// block-relative offsets in `[0, BLOCK)`, same order as `values`
    pub offsets: Vec<u8>,
    /// per-output-column dequantization scales (column order, NOT tiled —
    /// the epilogue indexes them by absolute column)
    pub scales: Vec<f32>,
}

impl QPackedBlockBalanced {
    /// Rows kept per block per column.
    pub fn keep(&self) -> usize {
        BLOCK / self.sparsity
    }

    /// Compressed row count `k/s`.
    pub fn kc(&self) -> usize {
        self.k / self.sparsity
    }

    /// Worst-case absolute weight error (½ LSB of the coarsest channel).
    pub fn max_error_bound(&self) -> f32 {
        self.scales.iter().fold(0.0f32, |m, &s| m.max(0.5 * s))
    }

    /// Worst-case *relative* weight error: ½ LSB over the largest
    /// representable weight of the coarsest channel — `0.5/127` by
    /// construction of symmetric INT8, exposed as a derived quantity so
    /// accuracy tolerances cite the bound, not a magic constant.
    pub fn rel_error_bound(&self) -> f32 {
        let smax = self.scales.iter().fold(0.0f32, |m, &s| m.max(s));
        if smax == 0.0 {
            0.0
        } else {
            self.max_error_bound() / (127.0 * smax)
        }
    }

    /// The INT8 twin of [`PackedBlockBalanced::repacked`]: same weights,
    /// different tile order. Scales stay untouched (they are column-
    /// indexed, not tiled), so dequantization — and therefore the bitwise
    /// output contract — is unaffected by the permute.
    pub fn repacked(&self, n_tile: usize) -> QPackedBlockBalanced {
        if n_tile == self.n_tile {
            return self.clone();
        }
        let (values, offsets) =
            unpack_slots(&self.values, &self.offsets, self.kc(), self.n, self.n_tile);
        let (values, offsets) = pack_slots(&values, &offsets, self.kc(), self.n, n_tile);
        QPackedBlockBalanced {
            k: self.k,
            n: self.n,
            sparsity: self.sparsity,
            n_tile,
            values,
            offsets,
            scales: self.scales.clone(),
        }
    }
}

impl QBlockBalanced {
    /// Reorder into the execution layout at the default tile width.
    pub fn pack(&self) -> QPackedBlockBalanced {
        self.pack_tiled(N_TILE)
    }

    /// Reorder into the execution layout with an explicit column tile
    /// width — the identical reorder as [`BlockBalanced::pack_tiled`]
    /// (both go through [`pack_slots`]).
    pub fn pack_tiled(&self, n_tile: usize) -> QPackedBlockBalanced {
        let (values, offsets) =
            pack_slots(&self.values, &self.offsets, self.kc(), self.n, n_tile);
        QPackedBlockBalanced {
            k: self.k,
            n: self.n,
            sparsity: self.sparsity,
            n_tile,
            values,
            offsets,
            scales: self.scales.clone(),
        }
    }
}

/// `y = act(x @ W + b)` over the packed layout, parallel + tiled.
/// `x`: [m, k]; returns [m, n]. Accumulates in f32, matching the serial
/// [`spmm`](crate::sparse::matmul::spmm) reduction order element-for-
/// element, so the two agree bitwise for any `threads`.
///
/// Dispatches through the process-wide [`ExecPool::global`]; the
/// serving hot path uses [`spmm_tiled_into`] with a per-backend pool
/// and a reused output buffer instead.
pub fn spmm_tiled(
    x: &Dense2,
    w: &PackedBlockBalanced,
    bias: Option<&[f32]>,
    act: Act,
    threads: usize,
) -> Dense2 {
    let mut out = Dense2::zeros(0, 0);
    spmm_tiled_into(ExecPool::global(), x, w, bias, act, threads, &mut out);
    out
}

/// [`spmm_tiled`] with explicit dispatch pool and caller-owned output:
/// `out` is reshaped to `[m, n]` in place (its allocation is reused when
/// capacity suffices — the zero-alloc serving path), then every element
/// is written exactly once by the fused epilogue. At most `threads`
/// stripes run concurrently, capped by the pool's participant count;
/// results are bitwise identical to the serial reference at any setting.
pub fn spmm_tiled_into(
    pool: &ExecPool,
    x: &Dense2,
    w: &PackedBlockBalanced,
    bias: Option<&[f32]>,
    act: Act,
    threads: usize,
    out: &mut Dense2,
) {
    assert_eq!(x.cols, w.k, "reduction dim mismatch");
    if let Some(b) = bias {
        assert_eq!(b.len(), w.n, "bias length");
    }
    let (m, n) = (x.rows, w.n);
    // no zero-fill: the fused epilogue writes every element exactly once
    out.reshape_for_overwrite(m, n);
    if m == 0 || n == 0 {
        return;
    }
    pool.run_stripes(&mut out.data, n, threads, |row0, chunk| {
        stripe(x, w, bias, act, row0, chunk)
    });
}

/// [`spmm_tiled_into`] dispatched on a tuned
/// [`DispatchPlan`](crate::sparse::tune::DispatchPlan): `w` must already
/// be packed at the plan's tile width (repacking happened once at tune
/// time — the hot path only asserts the invariant), and the plan's
/// stripe cap replaces the caller-chosen `threads`. Plans vary only
/// bitwise-invariant parameters, so output is identical to the serial
/// reference at any plan.
pub fn spmm_tiled_into_plan(
    pool: &ExecPool,
    x: &Dense2,
    w: &PackedBlockBalanced,
    bias: Option<&[f32]>,
    act: Act,
    plan: DispatchPlan,
    out: &mut Dense2,
) {
    assert_eq!(
        w.n_tile, plan.tile_n,
        "weights packed at tile {} but plan wants {} — repack at tune time",
        w.n_tile, plan.tile_n
    );
    spmm_tiled_into(pool, x, w, bias, act, plan.max_stripes, out);
}

/// Spawn-per-call variant of [`spmm_tiled`] — the pre-pool dispatch
/// discipline (one fresh scoped thread per stripe, every call), retained
/// as the measured baseline `benches/pool_latency.rs` compares the pool
/// against. Same kernel, same stripes, bitwise-identical results.
pub fn spmm_tiled_scoped(
    x: &Dense2,
    w: &PackedBlockBalanced,
    bias: Option<&[f32]>,
    act: Act,
    threads: usize,
) -> Dense2 {
    assert_eq!(x.cols, w.k, "reduction dim mismatch");
    if let Some(b) = bias {
        assert_eq!(b.len(), w.n, "bias length");
    }
    let (m, n) = (x.rows, w.n);
    let mut out = Dense2::zeros(m, n);
    if m == 0 || n == 0 {
        return out;
    }
    scoped_stripes(&mut out.data, n, threads, |row0, chunk| {
        stripe(x, w, bias, act, row0, chunk)
    });
    out
}

/// One thread's stripe: rows `row0 ..` of `x` into `out` (a disjoint
/// `rows × n` slice of the output). Dispatches to the `keep`-
/// monomorphized kernel.
fn stripe(
    x: &Dense2,
    w: &PackedBlockBalanced,
    bias: Option<&[f32]>,
    act: Act,
    row0: usize,
    out: &mut [f32],
) {
    match w.keep() {
        1 => stripe_keep::<1>(x, w, bias, act, row0, out),
        2 => stripe_keep::<2>(x, w, bias, act, row0, out),
        4 => stripe_keep::<4>(x, w, bias, act, row0, out),
        8 => stripe_keep::<8>(x, w, bias, act, row0, out),
        16 => stripe_keep::<16>(x, w, bias, act, row0, out),
        32 => stripe_keep::<32>(x, w, bias, act, row0, out),
        other => unreachable!("pack() only produces supported keeps, got {other}"),
    }
}

fn stripe_keep<const KEEP: usize>(
    x: &Dense2,
    w: &PackedBlockBalanced,
    bias: Option<&[f32]>,
    act: Act,
    row0: usize,
    out: &mut [f32],
) {
    // per-worker reusable accumulator tile (zeroed per column tile in the
    // inner kernel) — no allocation in steady state
    with_scratch_f32(ROW_CHUNK * w.n_tile.min(w.n), |scratch| {
        stripe_keep_in::<KEEP>(x, w, bias, act, row0, out, scratch)
    })
}

fn stripe_keep_in<const KEEP: usize>(
    x: &Dense2,
    w: &PackedBlockBalanced,
    bias: Option<&[f32]>,
    act: Act,
    row0: usize,
    out: &mut [f32],
    scratch: &mut [f32],
) {
    let n = w.n;
    let kc = w.kc();
    let nblocks = w.k / BLOCK;
    let rows = out.len() / n;
    let mut r = 0;
    while r < rows {
        let rc = ROW_CHUNK.min(rows - r);
        let mut col = 0;
        while col < n {
            let tw = w.n_tile.min(n - col);
            // slots before this tile: every earlier tile is full width
            let tile_base = kc * col;
            let acc_all = &mut scratch[..rc * tw];
            acc_all.fill(0.0);
            for blk in 0..nblocks {
                let at = tile_base + blk * KEEP * tw;
                let vals = &w.values[at..at + KEEP * tw];
                let offs = &w.offsets[at..at + KEEP * tw];
                for li in 0..rc {
                    let xrow = x.row(row0 + r + li);
                    let xblock: &[f32; BLOCK] =
                        xrow[blk * BLOCK..][..BLOCK].try_into().unwrap();
                    let acc = &mut acc_all[li * tw..][..tw];
                    for j in 0..KEEP {
                        let vrow = &vals[j * tw..][..tw];
                        let orow = &offs[j * tw..][..tw];
                        for ((a, &v), &o) in acc.iter_mut().zip(vrow).zip(orow) {
                            // `off & 31` keeps the gather provably in
                            // bounds of the fixed-size block (offsets are
                            // validated < BLOCK at construction), so the
                            // loop vectorizes without panicking paths —
                            // same trick as the serial reference.
                            *a += xblock[(o & 31) as usize] * v;
                        }
                    }
                }
            }
            // fused epilogue: bias + activation, single write to out
            for li in 0..rc {
                let acc = &scratch[li * tw..][..tw];
                let orow = &mut out[(r + li) * n + col..][..tw];
                match bias {
                    Some(b) => {
                        let bt = &b[col..col + tw];
                        for ((o, &a), &bv) in orow.iter_mut().zip(acc).zip(bt) {
                            *o = act.apply(a + bv);
                        }
                    }
                    None => {
                        for (o, &a) in orow.iter_mut().zip(acc) {
                            *o = act.apply(a);
                        }
                    }
                }
            }
            col += tw;
        }
        r += rc;
    }
}

/// `y = act(dequant(x_q @ W_q) + b)` over the INT8 packed layout,
/// parallel + tiled — the quantized twin of [`spmm_tiled`], same
/// stripe-parallel / cache-blocked / `keep`-monomorphized structure.
///
/// Activations are quantized once per call (per-tensor max-abs, the same
/// dynamic scheme as the serial [`qspmm`](crate::sparse::quant::qspmm)
/// reference), every tile accumulates in i32 (exact integer arithmetic —
/// order-independent, so determinism is free), and the fused epilogue
/// applies `dequant → bias → activation` in the identical f32 expression
/// tree as the serial reference: the two agree **bitwise** for any
/// thread count or tile width.
///
/// Dispatches through the process-wide [`ExecPool::global`]; the
/// serving hot path uses [`qspmm_tiled_into`] with a per-backend pool
/// and reused buffers instead.
pub fn qspmm_tiled(
    x: &Dense2,
    w: &QPackedBlockBalanced,
    bias: Option<&[f32]>,
    act: Act,
    threads: usize,
) -> Dense2 {
    let mut qbuf = Vec::new();
    let mut out = Dense2::zeros(0, 0);
    qspmm_tiled_into(ExecPool::global(), x, w, bias, act, threads, &mut qbuf, &mut out);
    out
}

/// [`qspmm_tiled`] with explicit dispatch pool and caller-owned buffers:
/// `qbuf` stages the per-tensor-quantized activations and `out` is
/// reshaped to `[m, n]` in place — both reuse their allocations across
/// calls (the zero-alloc serving path). Bitwise identical to the serial
/// [`qspmm`](crate::sparse::quant::qspmm) reference at any `threads`,
/// tile width, or pool size.
#[allow(clippy::too_many_arguments)]
pub fn qspmm_tiled_into(
    pool: &ExecPool,
    x: &Dense2,
    w: &QPackedBlockBalanced,
    bias: Option<&[f32]>,
    act: Act,
    threads: usize,
    qbuf: &mut Vec<i8>,
    out: &mut Dense2,
) {
    assert_eq!(x.cols, w.k, "reduction dim mismatch");
    if let Some(b) = bias {
        assert_eq!(b.len(), w.n, "bias length");
    }
    let (m, n) = (x.rows, w.n);
    // no zero-fill: the fused epilogue writes every element exactly once
    out.reshape_for_overwrite(m, n);
    if m == 0 || n == 0 {
        return;
    }
    // per-tensor activation quantization, shared by every stripe; the
    // staging buffer's capacity is reused call over call
    let xq = QParams::calibrate(&x.data);
    qbuf.clear();
    qbuf.extend(x.data.iter().map(|&v| xq.quantize(v)));
    let xdata: &[i8] = &qbuf[..];
    pool.run_stripes(&mut out.data, n, threads, |row0, chunk| {
        qstripe(xdata, x.cols, xq.scale, w, bias, act, row0, chunk)
    });
}

/// [`qspmm_tiled_into`] dispatched on a tuned plan — the INT8 twin of
/// [`spmm_tiled_into_plan`]; same invariant (weights pre-packed at the
/// plan's tile), same bitwise contract.
#[allow(clippy::too_many_arguments)]
pub fn qspmm_tiled_into_plan(
    pool: &ExecPool,
    x: &Dense2,
    w: &QPackedBlockBalanced,
    bias: Option<&[f32]>,
    act: Act,
    plan: DispatchPlan,
    qbuf: &mut Vec<i8>,
    out: &mut Dense2,
) {
    assert_eq!(
        w.n_tile, plan.tile_n,
        "weights packed at tile {} but plan wants {} — repack at tune time",
        w.n_tile, plan.tile_n
    );
    qspmm_tiled_into(pool, x, w, bias, act, plan.max_stripes, qbuf, out);
}

/// Spawn-per-call variant of [`qspmm_tiled`] — the pre-pool dispatch
/// discipline, retained as the bench baseline (see [`spmm_tiled_scoped`]).
pub fn qspmm_tiled_scoped(
    x: &Dense2,
    w: &QPackedBlockBalanced,
    bias: Option<&[f32]>,
    act: Act,
    threads: usize,
) -> Dense2 {
    assert_eq!(x.cols, w.k, "reduction dim mismatch");
    if let Some(b) = bias {
        assert_eq!(b.len(), w.n, "bias length");
    }
    let (m, n) = (x.rows, w.n);
    let mut out = Dense2::zeros(m, n);
    if m == 0 || n == 0 {
        return out;
    }
    let xq = QParams::calibrate(&x.data);
    let xdata: Vec<i8> = x.data.iter().map(|&v| xq.quantize(v)).collect();
    scoped_stripes(&mut out.data, n, threads, |row0, chunk| {
        qstripe(&xdata, x.cols, xq.scale, w, bias, act, row0, chunk)
    });
    out
}

/// One thread's INT8 stripe: rows `row0 ..` of the quantized input into
/// `out`. Dispatches to the `keep`-monomorphized kernel.
#[allow(clippy::too_many_arguments)]
fn qstripe(
    xdata: &[i8],
    k: usize,
    sx: f32,
    w: &QPackedBlockBalanced,
    bias: Option<&[f32]>,
    act: Act,
    row0: usize,
    out: &mut [f32],
) {
    match w.keep() {
        1 => qstripe_keep::<1>(xdata, k, sx, w, bias, act, row0, out),
        2 => qstripe_keep::<2>(xdata, k, sx, w, bias, act, row0, out),
        4 => qstripe_keep::<4>(xdata, k, sx, w, bias, act, row0, out),
        8 => qstripe_keep::<8>(xdata, k, sx, w, bias, act, row0, out),
        16 => qstripe_keep::<16>(xdata, k, sx, w, bias, act, row0, out),
        32 => qstripe_keep::<32>(xdata, k, sx, w, bias, act, row0, out),
        other => unreachable!("pack() only produces supported keeps, got {other}"),
    }
}

#[allow(clippy::too_many_arguments)]
fn qstripe_keep<const KEEP: usize>(
    xdata: &[i8],
    k: usize,
    sx: f32,
    w: &QPackedBlockBalanced,
    bias: Option<&[f32]>,
    act: Act,
    row0: usize,
    out: &mut [f32],
) {
    // per-worker reusable i32 accumulator tile (see stripe_keep)
    with_scratch_i32(ROW_CHUNK * w.n_tile.min(w.n), |scratch| {
        qstripe_keep_in::<KEEP>(xdata, k, sx, w, bias, act, row0, out, scratch)
    })
}

#[allow(clippy::too_many_arguments)]
fn qstripe_keep_in<const KEEP: usize>(
    xdata: &[i8],
    k: usize,
    sx: f32,
    w: &QPackedBlockBalanced,
    bias: Option<&[f32]>,
    act: Act,
    row0: usize,
    out: &mut [f32],
    scratch: &mut [i32],
) {
    let n = w.n;
    let kc = w.kc();
    let nblocks = w.k / BLOCK;
    let rows = out.len() / n;
    let mut r = 0;
    while r < rows {
        let rc = ROW_CHUNK.min(rows - r);
        let mut col = 0;
        while col < n {
            let tw = w.n_tile.min(n - col);
            let tile_base = kc * col;
            let acc_all = &mut scratch[..rc * tw];
            acc_all.fill(0);
            for blk in 0..nblocks {
                let at = tile_base + blk * KEEP * tw;
                let vals = &w.values[at..at + KEEP * tw];
                let offs = &w.offsets[at..at + KEEP * tw];
                for li in 0..rc {
                    let xrow = &xdata[(row0 + r + li) * k..(row0 + r + li + 1) * k];
                    let xblock: &[i8; BLOCK] =
                        xrow[blk * BLOCK..][..BLOCK].try_into().unwrap();
                    let acc = &mut acc_all[li * tw..][..tw];
                    for j in 0..KEEP {
                        let vrow = &vals[j * tw..][..tw];
                        let orow = &offs[j * tw..][..tw];
                        for ((a, &v), &o) in acc.iter_mut().zip(vrow).zip(orow) {
                            // same provably-in-bounds gather trick as the
                            // f32 kernel; widening i8×i8→i32 MACs are the
                            // SPU INT8 datapath
                            *a += xblock[(o & 31) as usize] as i32 * v as i32;
                        }
                    }
                }
            }
            // fused epilogue: dequant → bias → activation, single write.
            // Expression tree `acc·(sx·sw) [+ b]` matches the serial
            // reference exactly (bitwise contract).
            let scales = &w.scales[col..col + tw];
            for li in 0..rc {
                let acc = &scratch[li * tw..][..tw];
                let orow = &mut out[(r + li) * n + col..][..tw];
                match bias {
                    Some(b) => {
                        let bt = &b[col..col + tw];
                        for ((o, (&a, &sc)), &bv) in
                            orow.iter_mut().zip(acc.iter().zip(scales)).zip(bt)
                        {
                            let y = a as f32 * (sx * sc);
                            *o = act.apply(y + bv);
                        }
                    }
                    None => {
                        for (o, (&a, &sc)) in orow.iter_mut().zip(acc.iter().zip(scales)) {
                            let y = a as f32 * (sx * sc);
                            *o = act.apply(y);
                        }
                    }
                }
            }
            col += tw;
        }
        r += rc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::matmul::{dense_mm, spmm};
    use crate::sparse::quant::qspmm;

    fn case(m: usize, k: usize, n: usize, s: usize, seed: u64) -> (Dense2, BlockBalanced) {
        let x = Dense2::randn(m, k, seed);
        let w = BlockBalanced::from_dense(&Dense2::randn(k, n, seed + 1), s).unwrap();
        (x, w)
    }

    #[test]
    fn pack_preserves_every_slot() {
        let (_, w) = case(1, 96, 37, 4, 1);
        for n_tile in [1usize, 8, 16, 37, 64, 128] {
            let p = w.pack_tiled(n_tile);
            assert_eq!(p.values.len(), w.values.len());
            assert_eq!(p.offsets.len(), w.offsets.len());
            // reconstruct slot (cr, c) from the tile layout
            for cr in 0..w.kc() {
                for c in 0..w.n {
                    let t = c / n_tile;
                    let tw = n_tile.min(w.n - t * n_tile);
                    let at = p.kc() * t * n_tile + cr * tw + (c - t * n_tile);
                    assert_eq!(p.values[at], w.values[cr * w.n + c], "({cr},{c}) tile {n_tile}");
                    assert_eq!(p.offsets[at], w.offsets[cr * w.n + c]);
                }
            }
        }
    }

    #[test]
    fn tiled_matches_serial_bitwise_all_sparsities() {
        for &s in &crate::sparse::SUPPORTED_SPARSITIES {
            let (x, w) = case(7, 64, 43, s, 100 + s as u64);
            let serial = spmm(&x, &w, None, Act::None);
            for threads in [1usize, 2, 4] {
                let tiled = spmm_tiled(&x, &w.pack(), None, Act::None, threads);
                assert_eq!(serial.data, tiled.data, "s={s} threads={threads}");
            }
        }
    }

    #[test]
    fn tiled_matches_serial_across_tile_seams() {
        // n straddles tile boundaries for small widths; row count exceeds
        // ROW_CHUNK so the row-chunking path is exercised too
        let (x, w) = case(37, 96, 29, 8, 7);
        let serial = spmm(&x, &w, None, Act::None);
        for n_tile in [1usize, 5, 16, 29, 64] {
            let tiled = spmm_tiled(&x, &w.pack_tiled(n_tile), None, Act::None, 3);
            assert_eq!(serial.data, tiled.data, "n_tile={n_tile}");
        }
    }

    #[test]
    fn tiled_bias_and_act_epilogue() {
        let (x, w) = case(5, 64, 11, 4, 21);
        let bias: Vec<f32> = (0..11).map(|i| i as f32 * 0.25 - 1.0).collect();
        for act in [Act::None, Act::Relu, Act::Gelu] {
            let serial = spmm(&x, &w, Some(&bias), act);
            let tiled = spmm_tiled(&x, &w.pack(), Some(&bias), act, 2);
            assert_eq!(serial.data, tiled.data, "{act:?}");
            let dense = dense_mm(&x, &w.to_dense(), Some(&bias), act);
            assert!(tiled.max_abs_diff(&dense) < 1e-4, "{act:?} vs dense");
        }
    }

    #[test]
    fn more_threads_than_rows_is_fine() {
        let (x, w) = case(2, 32, 8, 2, 31);
        let serial = spmm(&x, &w, None, Act::None);
        let tiled = spmm_tiled(&x, &w.pack(), None, Act::None, 16);
        assert_eq!(serial.data, tiled.data);
    }

    #[test]
    fn empty_input_rows() {
        let (_, w) = case(1, 32, 8, 2, 41);
        let x = Dense2::zeros(0, 32);
        let y = spmm_tiled(&x, &w.pack(), None, Act::None, 4);
        assert_eq!(y.rows, 0);
        assert_eq!(y.cols, 8);
    }

    #[test]
    #[should_panic(expected = "reduction dim mismatch")]
    fn shape_checked() {
        let (x, _) = case(2, 32, 4, 2, 51);
        let w = BlockBalanced::from_dense(&Dense2::randn(64, 4, 52), 2).unwrap();
        spmm_tiled(&x, &w.pack(), None, Act::None, 2);
    }

    // ------------------------- INT8 packed path --------------------------

    #[test]
    fn qpack_preserves_every_slot_and_scales() {
        let (_, w) = case(1, 96, 37, 4, 61);
        let qb = w.quantize();
        for n_tile in [1usize, 8, 37, 128] {
            let p = qb.pack_tiled(n_tile);
            assert_eq!(p.values.len(), qb.values.len());
            assert_eq!(p.scales, qb.scales, "scales stay in column order");
            for cr in 0..qb.kc() {
                for c in 0..qb.n {
                    let t = c / n_tile;
                    let tw = n_tile.min(qb.n - t * n_tile);
                    let at = p.kc() * t * n_tile + cr * tw + (c - t * n_tile);
                    assert_eq!(p.values[at], qb.values[cr * qb.n + c], "({cr},{c})");
                    assert_eq!(p.offsets[at], qb.offsets[cr * qb.n + c]);
                }
            }
        }
    }

    #[test]
    fn qtiled_matches_serial_bitwise_all_sparsities_and_threads() {
        // the qspmm_tiled == qspmm bitwise contract at every supported
        // sparsity × thread count
        for &s in &crate::sparse::SUPPORTED_SPARSITIES {
            let (x, w) = case(7, 64, 43, s, 200 + s as u64);
            let qb = w.quantize();
            let serial = qspmm(&x, &qb, None, Act::None);
            for threads in [1usize, 2, 4] {
                let tiled = qspmm_tiled(&x, &qb.pack(), None, Act::None, threads);
                assert_eq!(serial.data, tiled.data, "s={s} threads={threads}");
            }
        }
    }

    #[test]
    fn qtiled_matches_serial_across_tile_seams() {
        let (x, w) = case(37, 96, 29, 8, 67);
        let qb = w.quantize();
        let serial = qspmm(&x, &qb, None, Act::None);
        for n_tile in [1usize, 5, 16, 29, 64] {
            let tiled = qspmm_tiled(&x, &qb.pack_tiled(n_tile), None, Act::None, 3);
            assert_eq!(serial.data, tiled.data, "n_tile={n_tile}");
        }
    }

    #[test]
    fn qtiled_bias_act_epilogue_and_f32_proximity() {
        let (x, w) = case(5, 64, 11, 4, 71);
        let qb = w.quantize();
        let bias: Vec<f32> = (0..11).map(|i| i as f32 * 0.25 - 1.0).collect();
        for act in [Act::None, Act::Relu, Act::Gelu] {
            let serial = qspmm(&x, &qb, Some(&bias), act);
            let tiled = qspmm_tiled(&x, &qb.pack(), Some(&bias), act, 2);
            assert_eq!(serial.data, tiled.data, "{act:?}");
            // int8 result tracks the f32 kernel within quantization noise
            let f32_ref = spmm_tiled(&x, &w.pack(), Some(&bias), act, 2);
            let ymax = f32_ref.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            assert!(
                tiled.max_abs_diff(&f32_ref) < 0.05 * ymax.max(1.0),
                "{act:?} drifted from f32"
            );
        }
    }

    #[test]
    fn qtiled_empty_input_rows() {
        let (_, w) = case(1, 32, 8, 2, 81);
        let x = Dense2::zeros(0, 32);
        let y = qspmm_tiled(&x, &w.quantize().pack(), None, Act::None, 4);
        assert_eq!(y.rows, 0);
        assert_eq!(y.cols, 8);
    }

    #[test]
    fn q_rel_error_bound_is_half_lsb() {
        let (_, w) = case(1, 64, 8, 8, 82);
        let p = w.quantize().pack();
        assert!((p.rel_error_bound() - 0.5 / 127.0).abs() < 1e-9);
        assert!(p.max_error_bound() > 0.0);
    }

    // ------------------------ repack / plan dispatch ------------------------

    #[test]
    fn repacked_equals_fresh_pack_at_target_tile() {
        // repacked() must be indistinguishable from having packed at the
        // target tile in the first place — both value/offset orders and
        // the recorded n_tile
        let (_, w) = case(1, 96, 37, 4, 301);
        let qb = w.quantize();
        for from in [8usize, 37, 128] {
            for to in [1usize, 8, 16, 37, 64, 128, 256] {
                let p = w.pack_tiled(from).repacked(to);
                assert_eq!(p, w.pack_tiled(to), "f32 {from}->{to}");
                let q = qb.pack_tiled(from).repacked(to);
                assert_eq!(q, qb.pack_tiled(to), "int8 {from}->{to}");
            }
        }
    }

    #[test]
    fn repacked_same_tile_is_identity() {
        let (_, w) = case(1, 64, 24, 8, 303);
        let p = w.pack_tiled(16);
        assert_eq!(p.repacked(16), p);
        let q = w.quantize().pack_tiled(16);
        assert_eq!(q.repacked(16), q);
    }

    #[test]
    fn plan_dispatch_matches_serial_bitwise() {
        let pool = ExecPool::new(2);
        let (x, w) = case(9, 96, 33, 4, 305);
        let qb = w.quantize();
        let serial = spmm(&x, &w, None, Act::None);
        let qserial = qspmm(&x, &qb, None, Act::None);
        let packed = w.pack();
        let qpacked = qb.pack();
        let mut out = Dense2::zeros(0, 0);
        let mut qout = Dense2::zeros(0, 0);
        let mut qbuf = Vec::new();
        for plan in [
            DispatchPlan { tile_n: 16, max_stripes: 1 },
            DispatchPlan { tile_n: 33, max_stripes: 2 },
            DispatchPlan { tile_n: 128, max_stripes: 3 },
        ] {
            let wt = packed.repacked(plan.tile_n);
            spmm_tiled_into_plan(&pool, &x, &wt, None, Act::None, plan, &mut out);
            assert_eq!(serial.data, out.data, "f32 {plan:?}");
            let qwt = qpacked.repacked(plan.tile_n);
            qspmm_tiled_into_plan(&pool, &x, &qwt, None, Act::None, plan, &mut qbuf, &mut qout);
            assert_eq!(qserial.data, qout.data, "int8 {plan:?}");
        }
    }

    #[test]
    #[should_panic(expected = "repack at tune time")]
    fn plan_dispatch_rejects_tile_mismatch() {
        let pool = ExecPool::new(1);
        let (x, w) = case(2, 32, 8, 2, 307);
        let mut out = Dense2::zeros(0, 0);
        let plan = DispatchPlan { tile_n: 64, max_stripes: 1 };
        spmm_tiled_into_plan(&pool, &x, &w.pack_tiled(16), None, Act::None, plan, &mut out);
    }

    // --------------------- pooled dispatch / _into path ---------------------

    #[test]
    fn pool_into_variants_reuse_buffers_and_stay_bitwise() {
        // the zero-alloc serving contract: repeated _into calls reuse the
        // caller's allocations (pointer-stable once grown) and every call
        // is bitwise equal to the serial references
        let pool = ExecPool::new(2);
        let (x, w) = case(19, 96, 31, 4, 91);
        let packed = w.pack();
        let qpacked = w.quantize().pack();
        let serial = spmm(&x, &w, None, Act::None);
        let qserial = qspmm(&x, &w.quantize(), None, Act::None);

        let mut out = Dense2::zeros(0, 0);
        let mut qout = Dense2::zeros(0, 0);
        let mut qbuf = Vec::new();
        spmm_tiled_into(&pool, &x, &packed, None, Act::None, 3, &mut out);
        qspmm_tiled_into(&pool, &x, &qpacked, None, Act::None, 3, &mut qbuf, &mut qout);
        let (p_out, p_qout, p_qbuf) = (out.data.as_ptr(), qout.data.as_ptr(), qbuf.as_ptr());
        for _ in 0..3 {
            spmm_tiled_into(&pool, &x, &packed, None, Act::None, 3, &mut out);
            qspmm_tiled_into(&pool, &x, &qpacked, None, Act::None, 3, &mut qbuf, &mut qout);
            assert_eq!(serial.data, out.data, "pooled f32 != serial");
            assert_eq!(qserial.data, qout.data, "pooled int8 != serial");
            assert_eq!(out.data.as_ptr(), p_out, "f32 out reallocated");
            assert_eq!(qout.data.as_ptr(), p_qout, "int8 out reallocated");
            assert_eq!(qbuf.as_ptr(), p_qbuf, "quant staging reallocated");
        }
    }

    #[test]
    fn pool_scoped_baselines_bitwise_equal_to_pooled() {
        // the spawn-per-call baselines the pool bench compares against
        // must compute the exact same thing
        let (x, w) = case(13, 64, 27, 8, 93);
        let bias: Vec<f32> = (0..27).map(|i| (i as f32).cos()).collect();
        let qb = w.quantize();
        for threads in [1usize, 2, 4] {
            assert_eq!(
                spmm_tiled(&x, &w.pack(), Some(&bias), Act::Gelu, threads).data,
                spmm_tiled_scoped(&x, &w.pack(), Some(&bias), Act::Gelu, threads).data,
                "f32 threads={threads}"
            );
            assert_eq!(
                qspmm_tiled(&x, &qb.pack(), Some(&bias), Act::Relu, threads).data,
                qspmm_tiled_scoped(&x, &qb.pack(), Some(&bias), Act::Relu, threads).data,
                "int8 threads={threads}"
            );
        }
    }

    #[test]
    fn pool_into_handles_empty_and_reshape() {
        // a reused output buffer must follow shape changes exactly
        let pool = ExecPool::new(1);
        let mut out = Dense2::zeros(0, 0);
        let (x1, w1) = case(5, 64, 11, 4, 95);
        spmm_tiled_into(&pool, &x1, &w1.pack(), None, Act::None, 2, &mut out);
        assert_eq!((out.rows, out.cols), (5, 11));
        let (x2, w2) = case(2, 32, 40, 2, 96);
        spmm_tiled_into(&pool, &x2, &w2.pack(), None, Act::None, 2, &mut out);
        assert_eq!((out.rows, out.cols), (2, 40));
        assert_eq!(out.data, spmm(&x2, &w2, None, Act::None).data);
        let empty = Dense2::zeros(0, 64);
        let (_, w3) = case(1, 64, 8, 2, 97);
        spmm_tiled_into(&pool, &empty, &w3.pack(), None, Act::None, 4, &mut out);
        assert_eq!((out.rows, out.cols), (0, 8));
    }
}
