//! Sparse tensor substrate — formats, pruning, and reference sparse ops.
//!
//! This mirrors the Python-side `compile/kernels/pack.py` layout exactly
//! (the two are cross-validated by `rust/tests/integration.rs` against
//! goldens) and additionally provides the storage-accounting the Antoum
//! simulator and the paper's memory-footprint claims are computed from.
//!
//! Execution side: [`pack`] holds the tiled f32/int8 kernels, [`pool`]
//! the persistent stripe-execution pool ([`ExecPool`]) they dispatch on,
//! and [`tune`] the roofline-guided autotuner that picks per-shape
//! dispatch plans (tile width × stripe cap) from measured points.

pub mod conv;
pub mod format;
pub mod matmul;
pub mod pack;
pub mod pool;
pub mod prune;
pub mod quant;
pub mod tensor;
pub mod tune;

pub use format::{BlockBalanced, Csr, BLOCK};
pub use pack::{
    qspmm_tiled, qspmm_tiled_into, qspmm_tiled_into_plan, spmm_tiled, spmm_tiled_into,
    spmm_tiled_into_plan, PackedBlockBalanced, QPackedBlockBalanced, N_TILE,
};
pub use pool::{partition_rows, ExecPool};
pub use prune::{magnitude_prune, PruneSchedule};
pub use quant::{qspmm, QBlockBalanced};
pub use tensor::{DType, Dense2};
pub use tune::{DispatchPlan, TuneConfig, TunePlan, Tuner};

/// Sparsity factors the SPU natively supports (paper: "up to 32x").
pub const SUPPORTED_SPARSITIES: [usize; 6] = [1, 2, 4, 8, 16, 32];

/// True iff `s` is a hardware-supported sparsity factor.
pub fn is_supported_sparsity(s: usize) -> bool {
    SUPPORTED_SPARSITIES.contains(&s)
}
