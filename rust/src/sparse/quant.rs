//! INT8 quantization — paper §2 item (iii): the SPU fuses "bias addition,
//! elementwise operations, **quantization**, and certain activation
//! functions", and the chip's headline 944 TOPS figure is the INT8 path.
//!
//! Symmetric per-tensor / per-channel affine quantization with the
//! max-abs calibrator the SparseRT toolchain would run at export time.
//! The simulator costs INT8 ops at the full MAC rate (`arch::spu`); this
//! module supplies the numerics so the CPU fallback path and tests can
//! check accuracy claims (quantization error bounds below).
//!
//! [`QBlockBalanced`] is where sparsity *composes with* quantization —
//! the `prune → per-channel calibrate → quantize` pipeline that turns a
//! [`BlockBalanced`] matrix into i8 values + per-output-channel scales
//! (same `[k/s, n]` construction layout, same offsets). [`qspmm`] is the
//! serial INT8 reference the parallel tiled kernel
//! ([`crate::sparse::pack::qspmm_tiled`]) is pinned bitwise against:
//! i32 accumulation per output element in ascending compressed-row
//! order, then a fused `dequant → bias → activation` f32 epilogue.

use super::format::{BlockBalanced, BLOCK};
use super::matmul::Act;
use super::tensor::Dense2;

/// Quantization parameters: `real = scale * (q - zero_point)`; symmetric
/// (zero_point = 0) because the SPU datapath is signed-symmetric.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QParams {
    pub scale: f32,
}

impl QParams {
    /// Max-abs calibration over a sample of values.
    pub fn calibrate(values: &[f32]) -> QParams {
        let max = values.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        QParams { scale: if max == 0.0 { 1.0 } else { max / 127.0 } }
    }

    #[inline]
    pub fn quantize(&self, x: f32) -> i8 {
        (x / self.scale).round().clamp(-127.0, 127.0) as i8
    }

    #[inline]
    pub fn dequantize(&self, q: i8) -> f32 {
        q as f32 * self.scale
    }
}

/// INT8 matrix with its quantization params.
#[derive(Clone, Debug)]
pub struct QMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i8>,
    /// per-column (output-channel) scales — per-channel quantization keeps
    /// the accuracy loss sub-0.5% that makes INT8 a "standard option"
    pub scales: Vec<f32>,
}

impl QMatrix {
    /// Per-channel (column) symmetric quantization of a weight matrix.
    pub fn quantize_per_channel(w: &Dense2) -> QMatrix {
        let mut scales = Vec::with_capacity(w.cols);
        for c in 0..w.cols {
            let max = (0..w.rows).fold(0.0f32, |m, r| m.max(w.at(r, c).abs()));
            scales.push(if max == 0.0 { 1.0 } else { max / 127.0 });
        }
        let mut data = vec![0i8; w.rows * w.cols];
        for r in 0..w.rows {
            for c in 0..w.cols {
                data[r * w.cols + c] =
                    (w.at(r, c) / scales[c]).round().clamp(-127.0, 127.0) as i8;
            }
        }
        QMatrix { rows: w.rows, cols: w.cols, data, scales }
    }

    pub fn dequantize(&self) -> Dense2 {
        let mut out = Dense2::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                *out.at_mut(r, c) =
                    self.data[r * self.cols + c] as f32 * self.scales[c];
            }
        }
        out
    }

    /// Worst-case absolute error of this quantization (½ LSB per channel).
    pub fn max_error_bound(&self) -> f32 {
        self.scales.iter().fold(0.0f32, |m, &s| m.max(0.5 * s))
    }
}

/// Block-balanced sparse matrix quantized to INT8: the deployed
/// `prune → quantize` composition (Mishra et al. 2021; the paper's
/// headline 944 TOPS is this path). Same `[k/s, n]` row-major
/// values/offsets construction layout as [`BlockBalanced`], values as i8
/// against symmetric per-output-channel scales.
#[derive(Clone, Debug, PartialEq)]
pub struct QBlockBalanced {
    pub k: usize,
    pub n: usize,
    pub sparsity: usize,
    /// `[k/s * n]` i8 values, row-major over `[k/s, n]`
    pub values: Vec<i8>,
    /// block-relative offsets in `[0, BLOCK)`, same layout as `values`
    pub offsets: Vec<u8>,
    /// per-output-column dequantization scales (`real = scale * q`)
    pub scales: Vec<f32>,
}

impl BlockBalanced {
    /// Per-channel calibrate + quantize the pruned matrix — step two of
    /// the `prune → calibrate → pack` pipeline (pack with
    /// [`QBlockBalanced::pack`](crate::sparse::pack)).
    pub fn quantize(&self) -> QBlockBalanced {
        QBlockBalanced::from_block_balanced(self)
    }
}

impl QBlockBalanced {
    /// Rows kept per block per column.
    pub fn keep(&self) -> usize {
        BLOCK / self.sparsity
    }

    /// Compressed row count `k/s`.
    pub fn kc(&self) -> usize {
        self.k / self.sparsity
    }

    /// Max-abs calibration over each output column's stored non-zeros,
    /// then symmetric quantization. Calibrating *after* pruning matters:
    /// the scale only has to cover surviving weights, so high sparsity
    /// tightens the quantization grid for free.
    pub fn from_block_balanced(bb: &BlockBalanced) -> QBlockBalanced {
        let (kc, n) = (bb.kc(), bb.n);
        let mut scales = Vec::with_capacity(n);
        for c in 0..n {
            let max = (0..kc).fold(0.0f32, |m, cr| m.max(bb.values[cr * n + c].abs()));
            scales.push(if max == 0.0 { 1.0 } else { max / 127.0 });
        }
        let mut values = vec![0i8; kc * n];
        for cr in 0..kc {
            for c in 0..n {
                values[cr * n + c] =
                    (bb.values[cr * n + c] / scales[c]).round().clamp(-127.0, 127.0) as i8;
            }
        }
        QBlockBalanced {
            k: bb.k,
            n,
            sparsity: bb.sparsity,
            values,
            offsets: bb.offsets.clone(),
            scales,
        }
    }

    /// Dequantize back to the f32 block-balanced format (tests/inspection).
    pub fn dequantize(&self) -> BlockBalanced {
        let values = self
            .values
            .iter()
            .enumerate()
            .map(|(i, &q)| q as f32 * self.scales[i % self.n])
            .collect();
        BlockBalanced {
            k: self.k,
            n: self.n,
            sparsity: self.sparsity,
            values,
            offsets: self.offsets.clone(),
        }
    }

    /// Worst-case absolute weight error (½ LSB of the coarsest channel).
    pub fn max_error_bound(&self) -> f32 {
        self.scales.iter().fold(0.0f32, |m, &s| m.max(0.5 * s))
    }
}

/// Serial INT8 SpMM reference: `y = act(dequant(x_q @ W_q) + b)` with `W`
/// block-balanced INT8. Activations are quantized per-tensor (max-abs,
/// symmetric) at call time — the dynamic-quantization mode of the SPU's
/// INT8 pipeline. Accumulates in i32 (exact, order-independent), then a
/// single f32 `dequant → bias → activation` epilogue per output element;
/// [`crate::sparse::pack::qspmm_tiled`] must match this bitwise.
pub fn qspmm(x: &Dense2, w: &QBlockBalanced, bias: Option<&[f32]>, act: Act) -> Dense2 {
    assert_eq!(x.cols, w.k, "reduction dim mismatch");
    if let Some(b) = bias {
        assert_eq!(b.len(), w.n, "bias length");
    }
    let xq = QParams::calibrate(&x.data);
    let xdata: Vec<i8> = x.data.iter().map(|&v| xq.quantize(v)).collect();
    let (m, n, kc) = (x.rows, w.n, w.kc());
    let keep = w.keep();
    let mut out = Dense2::zeros(m, n);
    let mut acc = vec![0i32; n];
    for i in 0..m {
        acc.fill(0);
        let xrow = &xdata[i * x.cols..(i + 1) * x.cols];
        for cr in 0..kc {
            let vrow = &w.values[cr * n..(cr + 1) * n];
            let offs = &w.offsets[cr * n..(cr + 1) * n];
            let xblock: &[i8; BLOCK] =
                xrow[(cr / keep) * BLOCK..][..BLOCK].try_into().unwrap();
            for ((a, &v), &off) in acc.iter_mut().zip(vrow).zip(offs) {
                // same provably-in-bounds gather as the f32 kernels
                *a += xblock[(off & 31) as usize] as i32 * v as i32;
            }
        }
        let orow = &mut out.data[i * n..(i + 1) * n];
        for (c, (o, &a)) in orow.iter_mut().zip(&acc).enumerate() {
            // NOTE: expression shape is part of the contract — the tiled
            // kernel evaluates the identical `acc·(sx·sw) [+ b]` tree so
            // the two agree bitwise
            let y = a as f32 * (xq.scale * w.scales[c]);
            *o = act.apply(match bias {
                Some(b) => y + b[c],
                None => y,
            });
        }
    }
    out
}

/// Worst-case `|int8 spmm − f32 spmm|` for one activation-free SpMM:
/// each of the `kc` kept terms errs by at most
/// `|x|·½sw + |w|·½sx + ¼·sx·sw` (weight, activation, and cross
/// rounding). Callers wrap activations by scaling with the act's
/// Lipschitz constant. One definition shared by the bench correctness
/// gate (`qspmm_scaling`) and the differential property test so the two
/// always enforce the same bound.
pub fn quant_drift_bound(x: &Dense2, w: &BlockBalanced, qw: &QBlockBalanced) -> f32 {
    let xmax = x.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let wmax = w.values.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let sx = if xmax == 0.0 { 1.0 } else { xmax / 127.0 };
    let sw = qw.scales.iter().fold(0.0f32, |m, &v| m.max(v));
    w.kc() as f32 * (xmax * 0.5 * sw + wmax * 0.5 * sx + 0.25 * sx * sw) + 1e-5
}

/// INT8 GEMM with f32 dequant epilogue: `y = (x_q @ w_q) * sx * sw[c]` —
/// the numeric path of the SPU's INT8 mode (accumulate in i32, rescale in
/// the output pipeline).
pub fn qgemm(x: &Dense2, w: &QMatrix) -> Dense2 {
    assert_eq!(x.cols, w.rows, "reduction dim mismatch");
    let xq = QParams::calibrate(&x.data);
    let xdata: Vec<i8> = x.data.iter().map(|&v| xq.quantize(v)).collect();
    let mut out = Dense2::zeros(x.rows, w.cols);
    for i in 0..x.rows {
        for c in 0..w.cols {
            let mut acc: i32 = 0;
            for k in 0..x.cols {
                acc += xdata[i * x.cols + k] as i32
                    * w.data[k * w.cols + c] as i32;
            }
            *out.at_mut(i, c) = acc as f32 * xq.scale * w.scales[c];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrate_and_roundtrip() {
        let q = QParams::calibrate(&[-2.0, 0.5, 1.27]);
        assert!((q.scale - 2.0 / 127.0).abs() < 1e-7);
        let v = 1.0f32;
        let err = (q.dequantize(q.quantize(v)) - v).abs();
        assert!(err <= 0.5 * q.scale + 1e-7);
    }

    #[test]
    fn zero_tensor_is_safe() {
        let q = QParams::calibrate(&[0.0; 8]);
        assert_eq!(q.scale, 1.0);
        assert_eq!(q.quantize(0.0), 0);
    }

    #[test]
    fn per_channel_bounds_error() {
        let w = Dense2::randn(64, 16, 77);
        let qm = QMatrix::quantize_per_channel(&w);
        let wd = qm.dequantize();
        let max_err = w.max_abs_diff(&wd);
        assert!(max_err <= qm.max_error_bound() + 1e-6, "{max_err}");
    }

    #[test]
    fn qgemm_close_to_f32_gemm() {
        let x = Dense2::randn(8, 64, 78);
        let w = Dense2::randn(64, 16, 79);
        let qm = QMatrix::quantize_per_channel(&w);
        let yq = qgemm(&x, &qm);
        let yf = x.matmul(&w);
        // relative Frobenius error of INT8 GEMM on gaussian data ≲ 2%
        let num: f32 = yq.data.iter().zip(&yf.data).map(|(a, b)| (a - b) * (a - b)).sum();
        let den: f32 = yf.data.iter().map(|v| v * v).sum();
        let rel = (num / den).sqrt();
        assert!(rel < 0.02, "rel err {rel}");
    }

    #[test]
    fn qblock_balanced_roundtrip_bounds_error() {
        use crate::sparse::format::BlockBalanced;
        for &s in &crate::sparse::SUPPORTED_SPARSITIES {
            let w = Dense2::randn(64, 16, 90 + s as u64);
            let bb = BlockBalanced::from_dense(&w, s).unwrap();
            let qb = bb.quantize();
            assert_eq!(qb.offsets, bb.offsets, "s={s}: offsets must be untouched");
            let back = qb.dequantize();
            back.validate().unwrap();
            let max_err = bb
                .values
                .iter()
                .zip(&back.values)
                .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
            assert!(max_err <= qb.max_error_bound() + 1e-6, "s={s}: {max_err}");
            // structural zeros stay exactly zero (symmetric quantization)
            for (a, b) in bb.values.iter().zip(&back.values) {
                if *a == 0.0 {
                    assert_eq!(*b, 0.0, "s={s}");
                }
            }
        }
    }

    #[test]
    fn calibration_covers_exactly_the_surviving_weights() {
        use crate::sparse::format::BlockBalanced;
        let w = Dense2::randn(96, 8, 91);
        let bb = BlockBalanced::from_dense(&w, 8).unwrap();
        let qb = bb.quantize();
        for c in 0..qb.n {
            let col_max =
                (0..bb.kc()).fold(0.0f32, |m, cr| m.max(bb.values[cr * bb.n + c].abs()));
            assert!((qb.scales[c] - col_max / 127.0).abs() <= 1e-9, "col {c}");
        }
        // the largest-magnitude slot of each column saturates the grid
        for (i, &q) in qb.values.iter().enumerate() {
            assert!((-127..=127).contains(&(q as i32)), "slot {i}");
        }
    }

    #[test]
    fn qspmm_close_to_f32_spmm() {
        use crate::sparse::format::BlockBalanced;
        use crate::sparse::matmul::spmm;
        for &s in &[1usize, 4, 16] {
            let x = Dense2::randn(8, 64, 92 + s as u64);
            let w = BlockBalanced::from_dense(&Dense2::randn(64, 16, 93 + s as u64), s)
                .unwrap();
            let yq = qspmm(&x, &w.quantize(), None, Act::None);
            let yf = spmm(&x, &w, None, Act::None);
            // same relative-Frobenius criterion as qgemm_close_to_f32_gemm
            // (2%), with headroom for the few-term reductions at s=16
            let num: f32 =
                yq.data.iter().zip(&yf.data).map(|(a, b)| (a - b) * (a - b)).sum();
            let den: f32 = yf.data.iter().map(|v| v * v).sum();
            let rel = (num / den).sqrt();
            let bound = if s >= 16 { 0.03 } else { 0.02 };
            assert!(rel < bound, "s={s}: rel err {rel}");
        }
    }

    #[test]
    fn qspmm_bias_and_act_epilogue() {
        use crate::sparse::format::BlockBalanced;
        use crate::sparse::matmul::spmm;
        let x = Dense2::randn(5, 64, 94);
        let w = BlockBalanced::from_dense(&Dense2::randn(64, 11, 95), 4).unwrap();
        let qw = w.quantize();
        let bias: Vec<f32> = (0..11).map(|i| i as f32 * 0.25 - 1.0).collect();
        for act in [Act::None, Act::Relu, Act::Gelu] {
            let yq = qspmm(&x, &qw, Some(&bias), act);
            let yf = spmm(&x, &w, Some(&bias), act);
            // ~½ LSB weight + ½ LSB activation noise through a k=64
            // reduction: bound relative to the output magnitude
            let ymax = yf.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            assert!(yq.max_abs_diff(&yf) < 0.05 * ymax.max(1.0), "{act:?}");
        }
        let yr = qspmm(&x, &qw, Some(&bias), Act::Relu);
        assert!(yr.data.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn qspmm_zero_input_is_safe() {
        use crate::sparse::format::BlockBalanced;
        let w = BlockBalanced::from_dense(&Dense2::randn(32, 4, 96), 2).unwrap();
        let x = Dense2::zeros(3, 32);
        let y = qspmm(&x, &w.quantize(), None, Act::None);
        assert!(y.data.iter().all(|&v| v == 0.0));
        let empty = Dense2::zeros(0, 32);
        let y0 = qspmm(&empty, &w.quantize(), None, Act::None);
        assert_eq!(y0.rows, 0);
    }

    #[test]
    fn quantization_composes_with_sparsity() {
        // prune → quantize: the deployed pipeline. Error stays bounded.
        use crate::sparse::format::BlockBalanced;
        let w = Dense2::randn(64, 16, 80);
        let pruned = BlockBalanced::from_dense(&w, 8).unwrap().to_dense();
        let qm = QMatrix::quantize_per_channel(&pruned);
        let back = qm.dequantize();
        assert!(pruned.max_abs_diff(&back) <= qm.max_error_bound() + 1e-6);
        // zeros stay exactly zero (symmetric quantization)
        for (a, b) in pruned.data.iter().zip(&back.data) {
            if *a == 0.0 {
                assert_eq!(*b, 0.0);
            }
        }
    }
}
