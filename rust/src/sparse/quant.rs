//! INT8 quantization — paper §2 item (iii): the SPU fuses "bias addition,
//! elementwise operations, **quantization**, and certain activation
//! functions", and the chip's headline 944 TOPS figure is the INT8 path.
//!
//! Symmetric per-tensor / per-channel affine quantization with the
//! max-abs calibrator the SparseRT toolchain would run at export time.
//! The simulator costs INT8 ops at the full MAC rate (`arch::spu`); this
//! module supplies the numerics so the CPU fallback path and tests can
//! check accuracy claims (quantization error bounds below).

use super::tensor::Dense2;

/// Quantization parameters: `real = scale * (q - zero_point)`; symmetric
/// (zero_point = 0) because the SPU datapath is signed-symmetric.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QParams {
    pub scale: f32,
}

impl QParams {
    /// Max-abs calibration over a sample of values.
    pub fn calibrate(values: &[f32]) -> QParams {
        let max = values.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        QParams { scale: if max == 0.0 { 1.0 } else { max / 127.0 } }
    }

    #[inline]
    pub fn quantize(&self, x: f32) -> i8 {
        (x / self.scale).round().clamp(-127.0, 127.0) as i8
    }

    #[inline]
    pub fn dequantize(&self, q: i8) -> f32 {
        q as f32 * self.scale
    }
}

/// INT8 matrix with its quantization params.
#[derive(Clone, Debug)]
pub struct QMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i8>,
    /// per-column (output-channel) scales — per-channel quantization keeps
    /// the accuracy loss sub-0.5% that makes INT8 a "standard option"
    pub scales: Vec<f32>,
}

impl QMatrix {
    /// Per-channel (column) symmetric quantization of a weight matrix.
    pub fn quantize_per_channel(w: &Dense2) -> QMatrix {
        let mut scales = Vec::with_capacity(w.cols);
        for c in 0..w.cols {
            let max = (0..w.rows).fold(0.0f32, |m, r| m.max(w.at(r, c).abs()));
            scales.push(if max == 0.0 { 1.0 } else { max / 127.0 });
        }
        let mut data = vec![0i8; w.rows * w.cols];
        for r in 0..w.rows {
            for c in 0..w.cols {
                data[r * w.cols + c] =
                    (w.at(r, c) / scales[c]).round().clamp(-127.0, 127.0) as i8;
            }
        }
        QMatrix { rows: w.rows, cols: w.cols, data, scales }
    }

    pub fn dequantize(&self) -> Dense2 {
        let mut out = Dense2::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                *out.at_mut(r, c) =
                    self.data[r * self.cols + c] as f32 * self.scales[c];
            }
        }
        out
    }

    /// Worst-case absolute error of this quantization (½ LSB per channel).
    pub fn max_error_bound(&self) -> f32 {
        self.scales.iter().fold(0.0f32, |m, &s| m.max(0.5 * s))
    }
}

/// INT8 GEMM with f32 dequant epilogue: `y = (x_q @ w_q) * sx * sw[c]` —
/// the numeric path of the SPU's INT8 mode (accumulate in i32, rescale in
/// the output pipeline).
pub fn qgemm(x: &Dense2, w: &QMatrix) -> Dense2 {
    assert_eq!(x.cols, w.rows, "reduction dim mismatch");
    let xq = QParams::calibrate(&x.data);
    let xdata: Vec<i8> = x.data.iter().map(|&v| xq.quantize(v)).collect();
    let mut out = Dense2::zeros(x.rows, w.cols);
    for i in 0..x.rows {
        for c in 0..w.cols {
            let mut acc: i32 = 0;
            for k in 0..x.cols {
                acc += xdata[i * x.cols + k] as i32
                    * w.data[k * w.cols + c] as i32;
            }
            *out.at_mut(i, c) = acc as f32 * xq.scale * w.scales[c];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrate_and_roundtrip() {
        let q = QParams::calibrate(&[-2.0, 0.5, 1.27]);
        assert!((q.scale - 2.0 / 127.0).abs() < 1e-7);
        let v = 1.0f32;
        let err = (q.dequantize(q.quantize(v)) - v).abs();
        assert!(err <= 0.5 * q.scale + 1e-7);
    }

    #[test]
    fn zero_tensor_is_safe() {
        let q = QParams::calibrate(&[0.0; 8]);
        assert_eq!(q.scale, 1.0);
        assert_eq!(q.quantize(0.0), 0);
    }

    #[test]
    fn per_channel_bounds_error() {
        let w = Dense2::randn(64, 16, 77);
        let qm = QMatrix::quantize_per_channel(&w);
        let wd = qm.dequantize();
        let max_err = w.max_abs_diff(&wd);
        assert!(max_err <= qm.max_error_bound() + 1e-6, "{max_err}");
    }

    #[test]
    fn qgemm_close_to_f32_gemm() {
        let x = Dense2::randn(8, 64, 78);
        let w = Dense2::randn(64, 16, 79);
        let qm = QMatrix::quantize_per_channel(&w);
        let yq = qgemm(&x, &qm);
        let yf = x.matmul(&w);
        // relative Frobenius error of INT8 GEMM on gaussian data ≲ 2%
        let num: f32 = yq.data.iter().zip(&yf.data).map(|(a, b)| (a - b) * (a - b)).sum();
        let den: f32 = yf.data.iter().map(|v| v * v).sum();
        let rel = (num / den).sqrt();
        assert!(rel < 0.02, "rel err {rel}");
    }

    #[test]
    fn quantization_composes_with_sparsity() {
        // prune → quantize: the deployed pipeline. Error stays bounded.
        use crate::sparse::format::BlockBalanced;
        let w = Dense2::randn(64, 16, 80);
        let pruned = BlockBalanced::from_dense(&w, 8).unwrap().to_dense();
        let qm = QMatrix::quantize_per_channel(&pruned);
        let back = qm.dequantize();
        assert!(pruned.max_abs_diff(&back) <= qm.max_error_bound() + 1e-6);
        // zeros stay exactly zero (symmetric quantization)
        for (a, b) in pruned.data.iter().zip(&back.data) {
            if *a == 0.0 {
                assert_eq!(*b, 0.0);
            }
        }
    }
}
