//! Dense tensor types and element dtypes.
//!
//! The simulator does byte/FLOP accounting per dtype; the runtime moves
//! f32/i32 host buffers. Only what the stack needs — this is not an
//! ndarray clone.

use std::fmt;

/// Element types the S4 datapath supports (paper §2: 944 TOPS INT8,
/// 472 TFLOPS BF16; f32 is the host/reference type).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    Int8,
    Bf16,
    F32,
    Int32,
}

impl DType {
    pub fn bytes(self) -> usize {
        match self {
            DType::Int8 => 1,
            DType::Bf16 => 2,
            DType::F32 | DType::Int32 => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::Int8 => "int8",
            DType::Bf16 => "bf16",
            DType::F32 => "f32",
            DType::Int32 => "int32",
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Row-major dense matrix of f32 — the reference numeric type on the host.
#[derive(Clone, Debug, PartialEq)]
pub struct Dense2 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Dense2 {
    pub fn zeros(rows: usize, cols: usize) -> Dense2 {
        Dense2 { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Dense2 {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Dense2 { rows, cols, data }
    }

    /// Gaussian-random matrix (deterministic from seed).
    pub fn randn(rows: usize, cols: usize, seed: u64) -> Dense2 {
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(seed);
        Dense2 {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.next_gaussian() as f32).collect(),
        }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Count of exact-zero entries.
    pub fn zeros_count(&self) -> usize {
        self.data.iter().filter(|&&x| x == 0.0).count()
    }

    /// Plain dense matmul (reference; not a BLAS).
    pub fn matmul(&self, rhs: &Dense2) -> Dense2 {
        assert_eq!(self.cols, rhs.rows, "matmul shape mismatch");
        let mut out = Dense2::zeros(self.rows, rhs.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.at(i, k);
                if a == 0.0 {
                    continue;
                }
                let rrow = rhs.row(k);
                let orow =
                    &mut out.data[i * rhs.cols..(i + 1) * rhs.cols];
                for (o, &b) in orow.iter_mut().zip(rrow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    pub fn max_abs_diff(&self, other: &Dense2) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_bytes() {
        assert_eq!(DType::Int8.bytes(), 1);
        assert_eq!(DType::Bf16.bytes(), 2);
        assert_eq!(DType::F32.bytes(), 4);
    }

    #[test]
    fn matmul_identity() {
        let mut i2 = Dense2::zeros(2, 2);
        *i2.at_mut(0, 0) = 1.0;
        *i2.at_mut(1, 1) = 1.0;
        let a = Dense2::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.matmul(&i2), a);
    }

    #[test]
    fn matmul_known() {
        let a = Dense2::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let ones = Dense2::from_vec(2, 2, vec![1.0; 4]);
        let y = a.matmul(&ones);
        assert_eq!(y.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn randn_deterministic() {
        assert_eq!(Dense2::randn(4, 4, 9).data, Dense2::randn(4, 4, 9).data);
        assert_ne!(Dense2::randn(4, 4, 9).data, Dense2::randn(4, 4, 10).data);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn matmul_shape_checked() {
        Dense2::zeros(2, 3).matmul(&Dense2::zeros(2, 3));
    }
}
