//! Dense tensor types and element dtypes.
//!
//! The simulator does byte/FLOP accounting per dtype; the runtime moves
//! f32/i32 host buffers. Only what the stack needs — this is not an
//! ndarray clone.

use std::fmt;

/// Element types the S4 datapath supports (paper §2: 944 TOPS INT8,
/// 472 TFLOPS BF16; f32 is the host/reference type). Ordered so it can
/// key sorted containers (e.g. the autotuner's `TunePlan` BTreeMap).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum DType {
    Int8,
    Bf16,
    F32,
    Int32,
}

impl DType {
    pub fn bytes(self) -> usize {
        match self {
            DType::Int8 => 1,
            DType::Bf16 => 2,
            DType::F32 | DType::Int32 => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::Int8 => "int8",
            DType::Bf16 => "bf16",
            DType::F32 => "f32",
            DType::Int32 => "int32",
        }
    }

    /// Inverse of [`name`](DType::name) — used by plan-file parsing.
    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "int8" => Some(DType::Int8),
            "bf16" => Some(DType::Bf16),
            "f32" => Some(DType::F32),
            "int32" => Some(DType::Int32),
            _ => None,
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Row-major dense matrix of f32 — the reference numeric type on the host.
/// `Default` is the empty `0 × 0` matrix (arena buffers start there and
/// grow on first [`reset`](Dense2::reset)).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Dense2 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Dense2 {
    pub fn zeros(rows: usize, cols: usize) -> Dense2 {
        Dense2 { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Dense2 {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Dense2 { rows, cols, data }
    }

    /// Reshape in place to `rows × cols`, zero-filled, reusing the
    /// existing allocation: capacity grows monotonically and is never
    /// released, so a buffer cycled through same-shaped calls keeps a
    /// stable data pointer — the activation-arena contract the serving
    /// backend's zero-alloc forward pass is built on.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// [`reset`](Dense2::reset) without the zero-fill: element values
    /// are unspecified afterwards, so this is only for callers that
    /// overwrite every element before reading any (the tiled kernels'
    /// fused epilogue writes each output exactly once). Skipping the
    /// fill matters on the per-layer hot path — a steady-state reshape
    /// to the same or smaller footprint touches no memory at all.
    pub fn reshape_for_overwrite(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        let len = rows * cols;
        if self.data.len() > len {
            self.data.truncate(len);
        } else if self.data.len() < len {
            self.data.resize(len, 0.0);
        }
    }

    /// Gaussian-random matrix (deterministic from seed).
    pub fn randn(rows: usize, cols: usize, seed: u64) -> Dense2 {
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(seed);
        Dense2 {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.next_gaussian() as f32).collect(),
        }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Count of exact-zero entries.
    pub fn zeros_count(&self) -> usize {
        self.data.iter().filter(|&&x| x == 0.0).count()
    }

    /// Plain dense matmul (reference; not a BLAS).
    pub fn matmul(&self, rhs: &Dense2) -> Dense2 {
        assert_eq!(self.cols, rhs.rows, "matmul shape mismatch");
        let mut out = Dense2::zeros(self.rows, rhs.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.at(i, k);
                if a == 0.0 {
                    continue;
                }
                let rrow = rhs.row(k);
                let orow =
                    &mut out.data[i * rhs.cols..(i + 1) * rhs.cols];
                for (o, &b) in orow.iter_mut().zip(rrow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    pub fn max_abs_diff(&self, other: &Dense2) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_bytes() {
        assert_eq!(DType::Int8.bytes(), 1);
        assert_eq!(DType::Bf16.bytes(), 2);
        assert_eq!(DType::F32.bytes(), 4);
    }

    #[test]
    fn dtype_parse_inverts_name() {
        for d in [DType::Int8, DType::Bf16, DType::F32, DType::Int32] {
            assert_eq!(DType::parse(d.name()), Some(d));
        }
        assert_eq!(DType::parse("f64"), None);
    }

    #[test]
    fn matmul_identity() {
        let mut i2 = Dense2::zeros(2, 2);
        *i2.at_mut(0, 0) = 1.0;
        *i2.at_mut(1, 1) = 1.0;
        let a = Dense2::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.matmul(&i2), a);
    }

    #[test]
    fn matmul_known() {
        let a = Dense2::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let ones = Dense2::from_vec(2, 2, vec![1.0; 4]);
        let y = a.matmul(&ones);
        assert_eq!(y.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn reset_reuses_allocation_with_stable_pointer() {
        let mut d = Dense2::zeros(4, 8);
        d.data.fill(3.5);
        let p = d.data.as_ptr();
        d.reset(2, 8); // shrink: same allocation, zeroed
        assert_eq!((d.rows, d.cols), (2, 8));
        assert_eq!(d.data.len(), 16);
        assert!(d.data.iter().all(|&v| v == 0.0));
        assert_eq!(d.data.as_ptr(), p, "shrinking reset must not reallocate");
        d.reset(4, 8); // regrow within original capacity
        assert_eq!(d.data.as_ptr(), p, "regrow within capacity must not reallocate");
        assert_eq!(d.data.len(), 32);
    }

    #[test]
    fn reshape_for_overwrite_skips_the_fill() {
        let mut d = Dense2::zeros(4, 8);
        d.data.fill(3.5);
        let p = d.data.as_ptr();
        d.reshape_for_overwrite(2, 8);
        assert_eq!((d.rows, d.cols, d.data.len()), (2, 8, 16));
        assert_eq!(d.data[0], 3.5, "no dead memset on the shrink path");
        d.reshape_for_overwrite(4, 8);
        assert_eq!(d.data.len(), 32);
        assert_eq!(d.data.as_ptr(), p, "reshape reuses the allocation");
        assert_eq!(d.data[0], 3.5, "prefix untouched on regrow");
    }

    #[test]
    fn randn_deterministic() {
        assert_eq!(Dense2::randn(4, 4, 9).data, Dense2::randn(4, 4, 9).data);
        assert_ne!(Dense2::randn(4, 4, 9).data, Dense2::randn(4, 4, 10).data);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn matmul_shape_checked() {
        Dense2::zeros(2, 3).matmul(&Dense2::zeros(2, 3));
    }
}
