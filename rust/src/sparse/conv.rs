//! Reference sparse convolution (im2col onto `spmm`) — numerical twin of
//! the Pallas `sparse_conv2d` kernel and the conv path the simulator costs.

use super::format::BlockBalanced;
use super::matmul::{spmm, Act};
use super::tensor::Dense2;

/// NHWC activation tensor (f32 host buffer).
#[derive(Clone, Debug)]
pub struct Nhwc {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<f32>,
}

impl Nhwc {
    pub fn zeros(n: usize, h: usize, w: usize, c: usize) -> Nhwc {
        Nhwc { n, h, w, c, data: vec![0.0; n * h * w * c] }
    }

    pub fn randn(n: usize, h: usize, w: usize, c: usize, seed: u64) -> Nhwc {
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(seed);
        Nhwc {
            n,
            h,
            w,
            c,
            data: (0..n * h * w * c).map(|_| rng.next_gaussian() as f32).collect(),
        }
    }

    #[inline]
    pub fn at(&self, n: usize, y: usize, x: usize, c: usize) -> f32 {
        self.data[((n * self.h + y) * self.w + x) * self.c + c]
    }

    #[inline]
    pub fn at_mut(&mut self, n: usize, y: usize, x: usize, c: usize) -> &mut f32 {
        &mut self.data[((n * self.h + y) * self.w + x) * self.c + c]
    }
}

/// Conv hyperparameters (square kernel, symmetric padding).
#[derive(Clone, Copy, Debug)]
pub struct ConvSpec {
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub padding: usize,
}

impl ConvSpec {
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        (
            (h + 2 * self.padding - self.kh) / self.stride + 1,
            (w + 2 * self.padding - self.kw) / self.stride + 1,
        )
    }
}

/// im2col: NHWC input → [N·Ho·Wo, kh·kw·C] patch matrix; reduction-dim
/// order (kh, kw, C) matches `pack_conv_weight` on the Python side.
pub fn im2col(x: &Nhwc, spec: &ConvSpec) -> (Dense2, usize, usize) {
    let (ho, wo) = spec.out_hw(x.h, x.w);
    let kdim = spec.kh * spec.kw * x.c;
    let mut out = Dense2::zeros(x.n * ho * wo, kdim);
    for n in 0..x.n {
        for oy in 0..ho {
            for ox in 0..wo {
                let row = (n * ho + oy) * wo + ox;
                let orow = &mut out.data[row * kdim..(row + 1) * kdim];
                let mut idx = 0;
                for ky in 0..spec.kh {
                    for kx in 0..spec.kw {
                        let iy = (oy * spec.stride + ky) as isize - spec.padding as isize;
                        let ix = (ox * spec.stride + kx) as isize - spec.padding as isize;
                        if iy >= 0 && ix >= 0 && (iy as usize) < x.h && (ix as usize) < x.w
                        {
                            for c in 0..x.c {
                                orow[idx + c] = x.at(n, iy as usize, ix as usize, c);
                            }
                        }
                        // else: zero padding (already zero)
                        idx += x.c;
                    }
                }
            }
        }
    }
    (out, ho, wo)
}

/// Sparse conv: `act(conv(x, W) + b)` with `W` block-balanced over the
/// flattened [kh·kw·Cin, Cout] reduction. Returns NHWC.
pub fn sparse_conv2d(
    x: &Nhwc,
    w: &BlockBalanced,
    bias: Option<&[f32]>,
    spec: &ConvSpec,
    act: Act,
) -> Nhwc {
    assert_eq!(w.k, spec.kh * spec.kw * x.c, "weight reduction dim");
    let (patches, ho, wo) = im2col(x, spec);
    let y = spmm(&patches, w, bias, act);
    Nhwc { n: x.n, h: ho, w: wo, c: w.n, data: y.data }
}

/// Dense direct conv reference (validates the im2col path).
pub fn dense_conv2d(
    x: &Nhwc,
    w: &Dense2, // [kh·kw·Cin, Cout]
    bias: Option<&[f32]>,
    spec: &ConvSpec,
    act: Act,
) -> Nhwc {
    let (ho, wo) = spec.out_hw(x.h, x.w);
    let cout = w.cols;
    let mut out = Nhwc::zeros(x.n, ho, wo, cout);
    for n in 0..x.n {
        for oy in 0..ho {
            for ox in 0..wo {
                for co in 0..cout {
                    let mut acc = bias.map(|b| b[co]).unwrap_or(0.0);
                    let mut kidx = 0;
                    for ky in 0..spec.kh {
                        for kx in 0..spec.kw {
                            let iy =
                                (oy * spec.stride + ky) as isize - spec.padding as isize;
                            let ix =
                                (ox * spec.stride + kx) as isize - spec.padding as isize;
                            if iy >= 0
                                && ix >= 0
                                && (iy as usize) < x.h
                                && (ix as usize) < x.w
                            {
                                for c in 0..x.c {
                                    acc += x.at(n, iy as usize, ix as usize, c)
                                        * w.at(kidx + c, co);
                                }
                            }
                            kidx += x.c;
                        }
                    }
                    *out.at_mut(n, oy, ox, co) = act.apply(acc);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn max_diff(a: &Nhwc, b: &Nhwc) -> f32 {
        a.data
            .iter()
            .zip(&b.data)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f32::max)
    }

    #[test]
    fn sparse_conv_matches_dense_direct() {
        let x = Nhwc::randn(1, 6, 6, 32, 50);
        let spec = ConvSpec { kh: 3, kw: 3, stride: 1, padding: 1 };
        for &s in &[1usize, 2, 8] {
            let w =
                BlockBalanced::from_dense(&Dense2::randn(9 * 32, 16, 51), s).unwrap();
            let ys = sparse_conv2d(&x, &w, None, &spec, Act::None);
            let yd = dense_conv2d(&x, &w.to_dense(), None, &spec, Act::None);
            assert_eq!((ys.h, ys.w, ys.c), (6, 6, 16));
            assert!(max_diff(&ys, &yd) < 1e-3, "s={s}");
        }
    }

    #[test]
    fn strided_output_shape() {
        let spec = ConvSpec { kh: 3, kw: 3, stride: 2, padding: 1 };
        assert_eq!(spec.out_hw(8, 8), (4, 4));
        let x = Nhwc::randn(2, 8, 8, 32, 52);
        let w = BlockBalanced::from_dense(&Dense2::randn(9 * 32, 8, 53), 2).unwrap();
        let y = sparse_conv2d(&x, &w, None, &spec, Act::None);
        assert_eq!((y.n, y.h, y.w, y.c), (2, 4, 4, 8));
    }

    #[test]
    fn conv1x1_equals_pointwise_matmul() {
        let x = Nhwc::randn(1, 4, 4, 32, 54);
        let spec = ConvSpec { kh: 1, kw: 1, stride: 1, padding: 0 };
        let w = BlockBalanced::from_dense(&Dense2::randn(32, 8, 55), 4).unwrap();
        let y = sparse_conv2d(&x, &w, None, &spec, Act::None);
        let (patches, _, _) = im2col(&x, &spec);
        let ym = spmm(&patches, &w, None, Act::None);
        assert_eq!(y.data, ym.data);
    }

    #[test]
    fn bias_and_relu_fused() {
        let x = Nhwc::randn(1, 4, 4, 32, 56);
        let spec = ConvSpec { kh: 3, kw: 3, stride: 1, padding: 1 };
        let w = BlockBalanced::from_dense(&Dense2::randn(9 * 32, 8, 57), 2).unwrap();
        let bias = vec![0.5f32; 8];
        let y = sparse_conv2d(&x, &w, Some(&bias), &spec, Act::Relu);
        assert!(y.data.iter().all(|&v| v >= 0.0));
        let yd = dense_conv2d(&x, &w.to_dense(), Some(&bias), &spec, Act::Relu);
        assert!(max_diff(&y, &yd) < 1e-3);
    }

    #[test]
    fn im2col_zero_padding_rows() {
        // all-ones input: corner patch rows contain zeros from padding
        let mut x = Nhwc::zeros(1, 3, 3, 32);
        x.data.iter_mut().for_each(|v| *v = 1.0);
        let spec = ConvSpec { kh: 3, kw: 3, stride: 1, padding: 1 };
        let (p, ho, wo) = im2col(&x, &spec);
        assert_eq!((ho, wo), (3, 3));
        // center patch fully inside → all ones; corner patch has 5 zero taps
        let center = p.row(4);
        assert!(center.iter().all(|&v| v == 1.0));
        let corner = p.row(0);
        let zeros = corner.iter().filter(|&&v| v == 0.0).count();
        assert_eq!(zeros, 5 * 32);
    }
}
