//! Reference sparse matmul over the hardware format.
//!
//! This is the *numerical twin* of the Pallas SPU kernel: gather-based,
//! touching only stored non-zeros, with a fused bias + activation epilogue.
//! [`spmm`] is the **serial reference**: the golden numerics the simulator,
//! the parallel tiled engine ([`super::pack::spmm_tiled`] — what
//! [`crate::backend::cpu::CpuSparseBackend`] actually serves batches
//! through), and the balanced-vs-CSR ablation bench are all validated
//! against (differential tests in `rust/tests/properties.rs`).

use super::format::{BlockBalanced, Csr};
use super::tensor::Dense2;

/// Fused epilogue activations (subset the SPU fuses; the full engine list
/// lives in `arch::activation`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    None,
    Relu,
    Gelu,
}

impl Act {
    #[inline]
    pub fn apply(self, x: f32) -> f32 {
        match self {
            Act::None => x,
            Act::Relu => x.max(0.0),
            Act::Gelu => {
                // tanh approximation, same constants as the Pallas kernel
                let c = (2.0f32 / std::f32::consts::PI).sqrt();
                0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
            }
        }
    }
}

/// `y = act(x @ W + b)` with `W` block-balanced compressed.
/// `x`: [m, k]; returns [m, n]. Accumulates in f32.
pub fn spmm(x: &Dense2, w: &BlockBalanced, bias: Option<&[f32]>, act: Act) -> Dense2 {
    assert_eq!(x.cols, w.k, "reduction dim mismatch");
    if let Some(b) = bias {
        assert_eq!(b.len(), w.n, "bias length");
    }
    let (m, n, kc) = (x.rows, w.n, w.kc());
    let keep = w.keep();
    let mut out = Dense2::zeros(m, n);
    // Per compressed slot: out[i, c] += x[i, abs_row(cr, c)] * v
    // Loop order (i, cr, c) keeps out-row and weight-row accesses
    // streaming; the inner loop is written as a fused slice zip so the
    // compiler elides bounds checks (see EXPERIMENTS.md §Perf: 2.6x).
    for i in 0..m {
        let xrow = x.row(i);
        let orow = &mut out.data[i * n..(i + 1) * n];
        for cr in 0..kc {
            let vrow = &w.values[cr * n..(cr + 1) * n];
            let offs = &w.offsets[cr * n..(cr + 1) * n];
            let xblock: &[f32; super::format::BLOCK] = xrow
                [(cr / keep) * super::format::BLOCK..][..super::format::BLOCK]
                .try_into()
                .unwrap();
            for ((o, &v), &off) in orow.iter_mut().zip(vrow).zip(offs) {
                // gather through the in-block crossbar; the fixed-size
                // block slice + `off & 31` make the access provably in
                // bounds, so the loop vectorizes without panicking paths
                // (offsets are validated < BLOCK at construction).
                *o += xblock[(off & 31) as usize] * v;
            }
        }
        if let Some(b) = bias {
            for (o, &bv) in orow.iter_mut().zip(b) {
                *o += bv;
            }
        }
        for o in orow.iter_mut() {
            *o = act.apply(*o);
        }
    }
    out
}

/// Dense reference: `y = act(x @ W_dense + b)` — used to validate `spmm`.
pub fn dense_mm(x: &Dense2, w: &Dense2, bias: Option<&[f32]>, act: Act) -> Dense2 {
    let mut y = x.matmul(w);
    for i in 0..y.rows {
        for c in 0..y.cols {
            let mut v = y.at(i, c);
            if let Some(b) = bias {
                v += b[c];
            }
            *y.at_mut(i, c) = act.apply(v);
        }
    }
    y
}

/// CSR-based `x @ W` (W as CSR over [k, n]): the unstructured comparison.
/// Irregular inner length per row — the memory-access pattern a
/// load-balanced systolic array cannot exploit; the ablation bench
/// measures the throughput gap vs `spmm`.
pub fn csr_mm(x: &Dense2, w: &Csr) -> Dense2 {
    assert_eq!(x.cols, w.rows, "reduction dim mismatch");
    let (m, n) = (x.rows, w.cols);
    let mut out = Dense2::zeros(m, n);
    for i in 0..m {
        let xrow = x.row(i);
        let orow = &mut out.data[i * n..(i + 1) * n];
        for r in 0..w.rows {
            let xv = xrow[r];
            if xv == 0.0 {
                continue;
            }
            for j in w.row_ptr[r]..w.row_ptr[r + 1] {
                orow[w.col_idx[j] as usize] += xv * w.values[j];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn case(m: usize, k: usize, n: usize, s: usize, seed: u64) -> (Dense2, BlockBalanced) {
        let x = Dense2::randn(m, k, seed);
        let w = BlockBalanced::from_dense(&Dense2::randn(k, n, seed + 1), s).unwrap();
        (x, w)
    }

    #[test]
    fn spmm_matches_dense_on_pruned_weights() {
        for &s in &[1usize, 2, 4, 8, 16, 32] {
            let (x, w) = case(8, 64, 16, s, 10 + s as u64);
            let y = spmm(&x, &w, None, Act::None);
            let yd = dense_mm(&x, &w.to_dense(), None, Act::None);
            assert!(y.max_abs_diff(&yd) < 1e-4, "s={s}");
        }
    }

    #[test]
    fn spmm_bias_and_act() {
        let (x, w) = case(4, 32, 8, 4, 20);
        let bias: Vec<f32> = (0..8).map(|i| i as f32 * 0.1).collect();
        for act in [Act::None, Act::Relu, Act::Gelu] {
            let y = spmm(&x, &w, Some(&bias), act);
            let yd = dense_mm(&x, &w.to_dense(), Some(&bias), act);
            assert!(y.max_abs_diff(&yd) < 1e-4, "{act:?}");
        }
        let yr = spmm(&x, &w, Some(&bias), Act::Relu);
        assert!(yr.data.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn csr_matches_dense() {
        let (x, w) = case(4, 64, 8, 8, 30);
        let pruned = w.to_dense();
        let csr = Csr::from_dense(&pruned);
        let y = csr_mm(&x, &csr);
        let yd = dense_mm(&x, &pruned, None, Act::None);
        assert!(y.max_abs_diff(&yd) < 1e-4);
    }

    #[test]
    fn gelu_reference_values() {
        // gelu(0) = 0, gelu(large) ≈ identity, gelu(-large) ≈ 0
        assert_eq!(Act::Gelu.apply(0.0), 0.0);
        assert!((Act::Gelu.apply(10.0) - 10.0).abs() < 1e-3);
        assert!(Act::Gelu.apply(-10.0).abs() < 1e-3);
    }

    #[test]
    #[should_panic(expected = "reduction dim mismatch")]
    fn spmm_shape_checked() {
        let (x, _) = case(2, 32, 4, 2, 40);
        let w = BlockBalanced::from_dense(&Dense2::randn(64, 4, 41), 2).unwrap();
        spmm(&x, &w, None, Act::None);
    }
}
