//! Table/figure emitters: the exact rows/series the paper reports, as
//! aligned text tables plus JSON export for plotting.

use crate::util::json::Json;

use super::cost::SimResult;

/// One Fig. 2 row: speedup (and absolute throughput) at a sparsity level.
#[derive(Clone, Debug)]
pub struct Fig2Row {
    pub sparsity: usize,
    pub resnet50_tput: f64,
    pub resnet50_speedup: f64,
    pub bert_tput: f64,
    pub bert_speedup: f64,
}

/// Fig. 2: "Speedup (throughput) achieved on Moffett S4 at different levels
/// of sparsity, and a reference throughput of Nvidia T4".
pub fn fig2_table(rows: &[Fig2Row], t4_resnet: f64, t4_bert: f64) -> String {
    let mut s = String::new();
    s.push_str("Figure 2 — S4 speedup vs sparsity (T4 dense reference)\n");
    s.push_str(&format!(
        "{:>8} | {:>16} {:>9} | {:>16} {:>9}\n",
        "sparsity", "ResNet50 img/s", "speedup", "BERT seq/s", "speedup"
    ));
    s.push_str(&"-".repeat(70));
    s.push('\n');
    for r in rows {
        s.push_str(&format!(
            "{:>8} | {:>16.0} {:>8.2}x | {:>16.0} {:>8.2}x\n",
            r.sparsity, r.resnet50_tput, r.resnet50_speedup, r.bert_tput, r.bert_speedup
        ));
    }
    s.push_str(&"-".repeat(70));
    s.push('\n');
    s.push_str(&format!(
        "{:>8} | {:>16.0} {:>9} | {:>16.0} {:>9}\n",
        "T4 ref", t4_resnet, "", t4_bert, ""
    ));
    s
}

pub fn fig2_json(rows: &[Fig2Row], t4_resnet: f64, t4_bert: f64) -> Json {
    Json::obj(vec![
        ("figure", Json::Str("fig2".into())),
        ("t4_resnet50", Json::Num(t4_resnet)),
        ("t4_bert", Json::Num(t4_bert)),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("sparsity", Json::Num(r.sparsity as f64)),
                            ("resnet50_tput", Json::Num(r.resnet50_tput)),
                            ("resnet50_speedup", Json::Num(r.resnet50_speedup)),
                            ("bert_tput", Json::Num(r.bert_tput)),
                            ("bert_speedup", Json::Num(r.bert_speedup)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// One Fig. 3 point: a (model, platform, sparsity) with accuracy+speed.
#[derive(Clone, Debug)]
pub struct Fig3Point {
    pub model: String,
    pub platform: String,
    pub sparsity: usize,
    pub accuracy: f64,
    pub throughput: f64,
}

/// Fig. 3: accuracy & throughput of dense models on T4 vs their sparse
/// equivalents on S4. The insight the table must show: a larger sparse
/// model dominates a smaller dense one on BOTH axes.
pub fn fig3_table(points: &[Fig3Point]) -> String {
    let mut s = String::new();
    s.push_str("Figure 3 — accuracy & throughput: dense-on-T4 vs sparse-on-S4\n");
    s.push_str(&format!(
        "{:<12} {:<12} {:>8} {:>10} {:>14}\n",
        "model", "platform", "sparsity", "accuracy", "throughput/s"
    ));
    s.push_str(&"-".repeat(60));
    s.push('\n');
    for p in points {
        s.push_str(&format!(
            "{:<12} {:<12} {:>8} {:>9.2}% {:>14.0}\n",
            p.model, p.platform, p.sparsity, 100.0 * p.accuracy, p.throughput
        ));
    }
    s
}

pub fn fig3_json(points: &[Fig3Point]) -> Json {
    Json::obj(vec![
        ("figure", Json::Str("fig3".into())),
        (
            "points",
            Json::Arr(
                points
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("model", Json::Str(p.model.clone())),
                            ("platform", Json::Str(p.platform.clone())),
                            ("sparsity", Json::Num(p.sparsity as f64)),
                            ("accuracy", Json::Num(p.accuracy)),
                            ("throughput", Json::Num(p.throughput)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Pareto check used by tests and the frontier example: does `a` dominate
/// `b` (≥ accuracy AND ≥ throughput, one strictly)?
pub fn dominates(a: &Fig3Point, b: &Fig3Point) -> bool {
    a.accuracy >= b.accuracy
        && a.throughput >= b.throughput
        && (a.accuracy > b.accuracy || a.throughput > b.throughput)
}

/// Engine-time breakdown of a `SimResult` (diagnostics in examples/CLI).
pub fn breakdown_table(r: &SimResult) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{} on {}: {:.3} ms/batch, {:.0} samples/s, {:.1} W avg\n",
        r.model, r.target, r.latency_ms, r.throughput, r.energy.avg_watts
    ));
    let total: f64 = r.engine_seconds.iter().map(|(_, t)| t).sum();
    for (e, t) in &r.engine_seconds {
        s.push_str(&format!(
            "  {:<8} {:>10.3} ms  {:>5.1}%\n",
            e.name(),
            t * 1e3,
            100.0 * t / total.max(1e-12)
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_table_contains_all_rows() {
        let rows = vec![
            Fig2Row { sparsity: 1, resnet50_tput: 1000.0, resnet50_speedup: 1.0, bert_tput: 100.0, bert_speedup: 1.0 },
            Fig2Row { sparsity: 8, resnet50_tput: 7800.0, resnet50_speedup: 7.8, bert_tput: 520.0, bert_speedup: 5.2 },
        ];
        let t = fig2_table(&rows, 4000.0, 400.0);
        assert!(t.contains("7.80x"));
        assert!(t.contains("T4 ref"));
        assert!(t.lines().count() >= 6);
    }

    #[test]
    fn fig2_json_parses_back() {
        let rows = vec![Fig2Row {
            sparsity: 4, resnet50_tput: 1.0, resnet50_speedup: 1.0,
            bert_tput: 1.0, bert_speedup: 1.0,
        }];
        let j = fig2_json(&rows, 2.0, 3.0);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("t4_resnet50").as_f64(), Some(2.0));
        assert_eq!(parsed.get("rows").as_arr().unwrap().len(), 1);
    }

    #[test]
    fn dominance() {
        let a = Fig3Point { model: "r152".into(), platform: "s4".into(), sparsity: 8, accuracy: 0.78, throughput: 5000.0 };
        let b = Fig3Point { model: "r50".into(), platform: "t4".into(), sparsity: 1, accuracy: 0.76, throughput: 4000.0 };
        assert!(dominates(&a, &b));
        assert!(!dominates(&b, &a));
        assert!(!dominates(&a, &a), "no self-domination");
    }
}
