//! Graph → chip simulation: mapping, scheduling, cost, baselines, reports.
//!
//! Two fidelity levels, cross-checked against each other in tests:
//!
//! * **analytic** ([`cost`]): per-op roofline (compute vs weight/activation
//!   traffic) summed along the graph — fast enough for the Fig. 2/3
//!   parameter sweeps (thousands of points);
//! * **event-driven** ([`schedule`]): the same per-op costs executed on
//!   `arch::event::EventSim` with real engine/DRAM-channel/NoC-link
//!   contention and cross-subsystem pipelining.
//!
//! [`t4`] is the dense-GPU comparison the paper plots against.

pub mod cost;
pub mod report;
pub mod schedule;
pub mod t4;

pub use cost::{simulate, SimResult, Target};
pub use schedule::{simulate_event, Parallelism};
