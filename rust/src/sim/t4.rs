//! Nvidia T4 dense baseline (analytic roofline).
//!
//! The paper compares S4 against *published* T4 throughput (its ref [11],
//! the NVIDIA inference performance page) rather than measurements, so an
//! analytic model calibrated to the same public datasheet is a faithful
//! substitute (DESIGN.md §Substitutions item 2).
//!
//! Datasheet: 65 TFLOPS FP16, 130 TOPS INT8 (tensor cores), 320 GB/s
//! GDDR6, 70 W. Sustained efficiency on real graphs is far below peak; the
//! per-op-class efficiency factors below are set so the model lands in the
//! ballpark of NVIDIA's published ResNet-50 (~4–5k img/s INT8) and
//! BERT-base (~400–900 seq/s) numbers, and are ablated in
//! `benches/fig2_speedup.rs --ablate-t4-eff`.

use crate::arch::chip::EnergyReport;
use crate::arch::engines::{self, Engine};
use crate::graph::{Graph, OpKind};
use crate::sparse::tensor::DType;

use super::cost::{OpCost, SimResult};

#[derive(Clone, Debug)]
pub struct T4Config {
    pub name: &'static str,
    pub fp16_tflops: f64,
    pub int8_tops: f64,
    pub dram_gbps: f64,
    pub tdp_w: f64,
    /// sustained fraction of peak for dense conv/matmul (tensor cores)
    pub eff_gemm: f64,
    /// sustained fraction for attention-style batched matmul
    pub eff_batched: f64,
    /// elementwise/normalization ops run on CUDA cores, bandwidth-bound:
    /// fraction of peak DRAM bandwidth they sustain
    pub eff_mem: f64,
}

impl T4Config {
    pub fn t4() -> T4Config {
        T4Config {
            name: "nvidia-t4",
            fp16_tflops: 65.0,
            int8_tops: 130.0,
            dram_gbps: 320.0,
            tdp_w: 70.0,
            eff_gemm: 0.35,
            eff_batched: 0.20,
            eff_mem: 0.60,
        }
    }

    fn peak_flops(&self, dt: DType) -> f64 {
        match dt {
            DType::Int8 => self.int8_tops * 1e12,
            DType::Bf16 => self.fp16_tflops * 1e12,
            DType::F32 | DType::Int32 => self.fp16_tflops * 1e12 / 4.0,
        }
    }
}

/// Cost one op on the T4 model: max(compute at class efficiency, memory).
pub fn t4_op_cost(cfg: &T4Config, kind: &OpKind, dt: DType) -> OpCost {
    let flops = kind.flops_dense();
    let eff = match kind {
        OpKind::Conv2d { .. } | OpKind::MatMul { .. } => cfg.eff_gemm,
        OpKind::BatchMatMul { .. } => cfg.eff_batched,
        _ => 1.0, // non-GEMM ops are costed by memory below
    };
    let compute_s = match kind {
        OpKind::Conv2d { .. } | OpKind::MatMul { .. } | OpKind::BatchMatMul { .. } => {
            flops / (cfg.peak_flops(dt) * eff)
        }
        // CUDA-core elementwise: ~2 FLOPs/B at peak bw → memory dominates
        _ => 0.0,
    };
    let bytes = (kind.weight_bytes(1, dt)
        + kind.input_bytes(dt)
        + kind.output_bytes(dt)) as f64;
    let mem_s = bytes / (cfg.dram_gbps * 1e9 * cfg.eff_mem);
    OpCost {
        compute_s,
        weight_stream_s: 0.0,
        act_traffic_s: mem_s,
        total_s: compute_s.max(mem_s),
        macs: flops / 2.0,
        dram_bytes: bytes,
    }
}

/// Simulate a graph on the T4 model. Dense only: the T4 has no sparse
/// tensor path (the paper's premise — only A100 began 2:4 support).
pub fn simulate_t4(g: &Graph, cfg: &T4Config, dt: DType) -> SimResult {
    let mut total_s = 0.0;
    let mut per_op = Vec::with_capacity(g.len());
    let mut engine_secs: Vec<(Engine, f64)> = Vec::new();
    let mut weighted_s = 0.0;
    for op in &g.ops {
        let c = t4_op_cost(cfg, &op.kind, dt);
        total_s += c.total_s;
        if op.kind.sparsifiable() {
            weighted_s += c.total_s;
        }
        let e = engines::engine_for(&op.kind);
        match engine_secs.iter_mut().find(|(k, _)| *k == e) {
            Some((_, v)) => *v += c.total_s,
            None => engine_secs.push((e, c.total_s)),
        }
        per_op.push(c);
    }
    // GPU energy: sustained near TDP under inference load
    let joules = 0.85 * cfg.tdp_w * total_s;
    SimResult {
        target: format!("{} dense {}", cfg.name, dt.name()),
        model: g.name.clone(),
        batch: g.batch,
        sparsity: 1,
        latency_ms: total_s * 1e3,
        throughput: g.batch as f64 / total_s,
        engine_seconds: engine_secs,
        weighted_fraction: if total_s > 0.0 { weighted_s / total_s } else { 0.0 },
        energy: EnergyReport {
            mac_joules: 0.0,
            dram_joules: 0.0,
            static_joules: joules,
            total_joules: joules,
            avg_watts: 0.85 * cfg.tdp_w,
        },
        per_op,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn t4_resnet50_in_published_ballpark() {
        // NVIDIA's public page: ResNet-50 v1.5 INT8 ≈ 4–6k img/s
        let g = models::resnet50(32, 224);
        let r = simulate_t4(&g, &T4Config::t4(), DType::Int8);
        assert!(
            (2_500.0..8_000.0).contains(&r.throughput),
            "T4 resnet50: {:.0} img/s",
            r.throughput
        );
    }

    #[test]
    fn t4_bert_base_in_published_ballpark() {
        // published BERT-base seq128: several hundred seq/s
        let g = models::bert(models::BERT_BASE, 32, 128);
        let r = simulate_t4(&g, &T4Config::t4(), DType::Int8);
        assert!(
            (300.0..2_500.0).contains(&r.throughput),
            "T4 bert_base: {:.0} seq/s",
            r.throughput
        );
    }

    #[test]
    fn fp16_slower_than_int8() {
        let g = models::resnet50(32, 224);
        let i8 = simulate_t4(&g, &T4Config::t4(), DType::Int8).throughput;
        let fp = simulate_t4(&g, &T4Config::t4(), DType::Bf16).throughput;
        assert!(i8 > fp);
    }

    #[test]
    fn larger_model_slower() {
        let r50 = simulate_t4(&models::resnet50(32, 224), &T4Config::t4(), DType::Int8);
        let r152 = simulate_t4(&models::resnet152(32, 224), &T4Config::t4(), DType::Int8);
        let ratio = r50.throughput / r152.throughput;
        assert!((2.0..3.5).contains(&ratio), "ratio={ratio}");
    }
}
