//! Analytic cost engine: per-op roofline on Antoum (or T4), summed along
//! the graph. This is the model behind Fig. 2 and Fig. 3.

use crate::arch::chip::{energy, EnergyReport};
use crate::arch::engines::{self, Engine};
use crate::arch::memory::DramModel;
use crate::arch::{spu, AntoumConfig};
use crate::graph::Graph;
use crate::sparse::tensor::DType;

use super::t4::T4Config;

/// What to simulate a graph on.
#[derive(Clone, Debug)]
pub enum Target {
    /// Antoum at a given SPU sparsity factor and datapath dtype, running
    /// data-parallel across its subsystems.
    Antoum { cfg: AntoumConfig, sparsity: usize, dtype: DType },
    /// Nvidia T4 dense baseline.
    T4 { cfg: T4Config, dtype: DType },
}

impl Target {
    pub fn antoum(cfg: &AntoumConfig, sparsity: usize) -> Target {
        Target::Antoum { cfg: cfg.clone(), sparsity, dtype: DType::Int8 }
    }

    pub fn t4() -> Target {
        Target::T4 { cfg: T4Config::t4(), dtype: DType::Int8 }
    }
}

/// Per-op cost decomposition (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct OpCost {
    pub compute_s: f64,
    pub weight_stream_s: f64,
    pub act_traffic_s: f64,
    /// max of the three — the roofline time actually charged
    pub total_s: f64,
    pub macs: f64,
    pub dram_bytes: f64,
}

/// Whole-graph simulation result.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub target: String,
    pub model: String,
    pub batch: usize,
    pub sparsity: usize,
    /// end-to-end latency of one batch (ms)
    pub latency_ms: f64,
    /// samples/s at that latency
    pub throughput: f64,
    /// seconds spent per engine class (compute-side)
    pub engine_seconds: Vec<(Engine, f64)>,
    /// fraction of total time in weighted (sparsifiable) ops
    pub weighted_fraction: f64,
    pub energy: EnergyReport,
    pub per_op: Vec<OpCost>,
}

impl SimResult {
    /// Samples per joule — the TCO-ish metric the paper's 70 W pitch implies.
    pub fn samples_per_joule(&self) -> f64 {
        if self.energy.total_joules <= 0.0 {
            return 0.0;
        }
        self.batch as f64 / self.energy.total_joules
    }
}

/// Cost one op on Antoum. `par` = number of subsystems sharing the batch
/// (data parallel): compute and activation traffic split `par` ways, but
/// weights must stream to every subsystem (weight traffic is replicated —
/// the data-parallel tax the scheduler weighs against pipelining).
pub fn antoum_op_cost(
    cfg: &AntoumConfig,
    kind: &crate::graph::OpKind,
    sparsity: usize,
    dt: DType,
    par: usize,
    batch: usize,
) -> OpCost {
    let dram = DramModel::from_config(cfg);
    let par = par.clamp(1, cfg.subsystems) as f64;
    let (compute_s, macs) = match engines::engine_for(kind) {
        Engine::Spu => {
            let c = spu::cost(cfg, kind, sparsity, dt);
            (spu::seconds(cfg, &c) / par, c.macs)
        }
        _ => (engines::engine_seconds(cfg, kind) / par, 0.0),
    };
    // weight streaming: one DRAM fetch, multicast to all subsystems over
    // the ring (weights are read-only; the ring makes replication free in
    // DRAM-bandwidth terms).
    let wbytes = kind.weight_bytes(sparsity, dt) as f64;
    let weight_stream_s = wbytes / dram.total_bps();
    // activation + lookup traffic (split across subsystems)
    let abytes = (engines::lookup_dram_bytes(kind, dt)
        + spillover_bytes(cfg, kind, dt, batch)) as f64;
    let act_traffic_s = abytes / par / dram.total_bps();
    let total = compute_s.max(weight_stream_s).max(act_traffic_s);
    OpCost {
        compute_s,
        weight_stream_s,
        act_traffic_s,
        total_s: total,
        macs,
        dram_bytes: wbytes + abytes,
    }
}

/// Activation bytes that do NOT fit in the subsystem's activation SRAM and
/// must round-trip DRAM. Spatial/batch tiling keeps the working set to one
/// sample at a time (weight-stationary dataflow), so only the *per-sample*
/// excess over the activation buffer spills.
fn spillover_bytes(
    cfg: &AntoumConfig,
    kind: &crate::graph::OpKind,
    dt: DType,
    batch: usize,
) -> usize {
    let traffic = kind.input_bytes(dt) + kind.output_bytes(dt);
    let per_sample = traffic / batch.max(1);
    per_sample.saturating_sub(cfg.act_buffer_bytes) * batch.max(1)
}

/// Simulate a full graph analytically.
///
/// The fusion pass (paper §2 item iii) runs first: conv/matmul + bias +
/// elementwise + activation chains execute in the SPU's output pipeline at
/// zero marginal cost, on S4 and (via cuDNN/TensorRT fusion) on the T4
/// baseline alike.
pub fn simulate(g0: &Graph, target: Target) -> SimResult {
    let (g, _) = crate::graph::fusion::fuse(g0);
    let g = &g;
    match target {
        Target::Antoum { cfg, sparsity, dtype } => {
            // data parallel across subsystems when batch allows
            let par = g.batch.min(cfg.subsystems).max(1);
            let mut per_op = Vec::with_capacity(g.len());
            let mut engine_secs: Vec<(Engine, f64)> = Vec::new();
            let mut weighted_s = 0.0;
            let mut total_s = 0.0;
            let mut macs = 0.0;
            let mut dram_bytes = 0.0;
            for op in &g.ops {
                let c = antoum_op_cost(&cfg, &op.kind, sparsity, dtype, par, g.batch);
                total_s += c.total_s;
                macs += c.macs;
                dram_bytes += c.dram_bytes;
                if op.kind.sparsifiable() {
                    weighted_s += c.total_s;
                }
                let e = engines::engine_for(&op.kind);
                match engine_secs.iter_mut().find(|(k, _)| *k == e) {
                    Some((_, v)) => *v += c.total_s,
                    None => engine_secs.push((e, c.total_s)),
                }
                per_op.push(c);
            }
            let en = energy(&cfg, macs, dram_bytes, total_s);
            SimResult {
                target: format!("{} s={} {}", cfg.name, sparsity, dtype.name()),
                model: g.name.clone(),
                batch: g.batch,
                sparsity,
                latency_ms: total_s * 1e3,
                throughput: g.batch as f64 / total_s,
                engine_seconds: engine_secs,
                weighted_fraction: if total_s > 0.0 { weighted_s / total_s } else { 0.0 },
                energy: en,
                per_op,
            }
        }
        Target::T4 { cfg, dtype } => super::t4::simulate_t4(g, &cfg, dtype),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    fn s4() -> AntoumConfig {
        AntoumConfig::s4()
    }

    #[test]
    fn resnet_speedup_near_linear() {
        // Fig. 2 left: ResNet-50 speedup ≈ sparsity (conv-dominated)
        let g = models::resnet50(16, 224);
        let base = simulate(&g, Target::antoum(&s4(), 1)).throughput;
        for &s in &[2usize, 4, 8, 16] {
            let r = simulate(&g, Target::antoum(&s4(), s));
            let sp = r.throughput / base;
            assert!(
                sp > 0.7 * s as f64 && sp <= 1.02 * s as f64,
                "s={s}: speedup {sp:.2}"
            );
        }
    }

    #[test]
    fn bert_speedup_sublinear() {
        // Fig. 2 right: BERT bends (attention/softmax/LN don't sparsify)
        let g = models::bert(models::BERT_BASE, 16, 128);
        let base = simulate(&g, Target::antoum(&s4(), 1)).throughput;
        let r32 = simulate(&g, Target::antoum(&s4(), 32));
        let sp32 = r32.throughput / base;
        assert!(sp32 < 24.0, "BERT at 32x must be sublinear, got {sp32:.1}");
        assert!(sp32 > 4.0, "but still a large win, got {sp32:.1}");
        // and monotone in s
        let mut prev = base;
        for &s in &[2usize, 4, 8, 16, 32] {
            let t = simulate(&g, Target::antoum(&s4(), s)).throughput;
            assert!(t > prev, "s={s}");
            prev = t;
        }
    }

    #[test]
    fn resnet_scales_better_than_bert() {
        let gr = models::resnet50(16, 224);
        let gb = models::bert(models::BERT_BASE, 16, 128);
        let sp = |g: &Graph, s| {
            simulate(g, Target::antoum(&s4(), s)).throughput
                / simulate(g, Target::antoum(&s4(), 1)).throughput
        };
        assert!(sp(&gr, 16) > sp(&gb, 16));
    }

    #[test]
    fn latency_throughput_consistent() {
        let g = models::bert(models::BERT_BASE, 8, 128);
        let r = simulate(&g, Target::antoum(&s4(), 8));
        let implied = 8.0 / (r.latency_ms / 1e3);
        assert!((implied - r.throughput).abs() / r.throughput < 1e-9);
    }

    #[test]
    fn energy_stays_under_tdp() {
        for g in [models::resnet50(16, 224), models::bert(models::BERT_LARGE, 16, 128)] {
            for &s in &[1usize, 8, 32] {
                let r = simulate(&g, Target::antoum(&s4(), s));
                assert!(
                    r.energy.avg_watts < 71.0,
                    "{} s={s}: {:.1} W",
                    g.name,
                    r.energy.avg_watts
                );
            }
        }
    }

    #[test]
    fn weighted_fraction_tracks_model_structure() {
        let r = simulate(&models::resnet50(8, 224), Target::antoum(&s4(), 1));
        let b = simulate(&models::bert(models::BERT_BASE, 8, 128), Target::antoum(&s4(), 1));
        assert!(r.weighted_fraction > b.weighted_fraction);
    }

    #[test]
    fn batch_one_uses_single_subsystem() {
        let g1 = models::bert(models::BERT_BASE, 1, 128);
        let g4 = models::bert(models::BERT_BASE, 4, 128);
        let r1 = simulate(&g1, Target::antoum(&s4(), 8));
        let r4 = simulate(&g4, Target::antoum(&s4(), 8));
        // batch 4 splits across subsystems: latency should not be 4x
        assert!(r4.latency_ms < 2.5 * r1.latency_ms);
    }
}
