//! Event-driven scheduling of a graph onto the chip.
//!
//! Builds an `arch::event::EventSim` task DAG from a graph: one engine
//! task per op (on the op's mapped engine in its assigned subsystem), one
//! DRAM task per op's weight stream (round-robined over channels, overlap-
//! able with the *previous* op's compute — double buffering), and NoC-link
//! tasks for cross-subsystem activations under model parallelism.
//!
//! Parallelism modes (paper §2: "flexibly supports model parallelism and
//! data parallelism"):
//! * [`Parallelism::DataParallel`] — batch split across subsystems,
//!   weights replicated (each subsystem streams its own copy).
//! * [`Parallelism::ModelParallel`] — graph partitioned into contiguous
//!   stages by FLOPs, one subsystem per stage, activations ride the ring;
//!   with multiple in-flight batches this pipelines.

use crate::arch::chip::{energy, ChipResources};
use crate::arch::engines::{self, Engine};
use crate::arch::memory::DramModel;
use crate::arch::noc::RingNoc;
use crate::arch::{spu, AntoumConfig, EventSim, TaskId};
use crate::graph::{Graph, OpId};
use crate::sparse::tensor::DType;

use super::cost::SimResult;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Parallelism {
    /// batch split across all subsystems
    DataParallel,
    /// graph split into `stages` contiguous stages (≤ subsystems),
    /// `inflight` batches pipelined through them
    ModelParallel { stages: usize, inflight: usize },
}

/// Build + run the event simulation. Returns (result, events/sec processed)
/// — the latter is the §Perf metric for the simulator itself.
pub fn simulate_event(
    g0: &Graph,
    cfg: &AntoumConfig,
    sparsity: usize,
    dt: DType,
    par: Parallelism,
) -> SimResult {
    let (g, _) = crate::graph::fusion::fuse(g0);
    let g = &g;
    let res = ChipResources::from_config(cfg);
    let dram = DramModel::from_config(cfg);
    let noc = RingNoc::from_config(cfg);
    let mut sim = EventSim::new(res.total());

    let mut total_macs = 0.0;
    let mut total_dram = 0.0;

    // engine service time of one op at a batch fraction `frac`
    let op_secs = |kind: &crate::graph::OpKind, frac: f64| -> (f64, f64) {
        match engines::engine_for(kind) {
            Engine::Spu => {
                let c = spu::cost(cfg, kind, sparsity, dt);
                (spu::seconds(cfg, &c) * frac, c.macs * frac)
            }
            _ => (engines::engine_seconds(cfg, kind) * frac, 0.0),
        }
    };

    match par {
        Parallelism::DataParallel => {
            let replicas = g.batch.min(cfg.subsystems).max(1);
            let frac = 1.0 / replicas as f64;
            for ss in 0..replicas {
                let mut op_task: Vec<Option<TaskId>> = vec![None; g.len()];
                let mut ch = ss; // round-robin DRAM channel start per replica
                for (i, op) in g.ops.iter().enumerate() {
                    let deps: Vec<TaskId> = op
                        .inputs
                        .iter()
                        .filter_map(|&OpId(j)| op_task[j])
                        .collect();
                    // weight stream task (channel resource); depends on
                    // nothing (prefetch) — double buffering means it only
                    // gates the op itself.
                    let wbytes = op.kind.weight_bytes(sparsity, dt);
                    let mut all_deps = deps;
                    if wbytes > 0 {
                        let t = dram.transfer(wbytes, 1).seconds;
                        let wtask =
                            sim.add_task(res.dram(ch % res.dram_channels), t, &[], i as u64);
                        ch += 1;
                        all_deps.push(wtask);
                        total_dram += wbytes as f64;
                    }
                    let (secs, macs) = op_secs(&op.kind, frac);
                    total_macs += macs;
                    let lookup = engines::lookup_dram_bytes(&op.kind, dt) as f64 * frac;
                    total_dram += lookup;
                    let engine = res.engine(ss, engines::engine_for(&op.kind));
                    let t = sim.add_task(engine, secs, &all_deps, i as u64);
                    op_task[i] = Some(t);
                }
            }
        }
        Parallelism::ModelParallel { stages, inflight } => {
            let stages = stages.clamp(1, cfg.subsystems);
            let assign = partition_by_flops(g, stages);
            for b in 0..inflight.max(1) {
                let mut op_task: Vec<Option<TaskId>> = vec![None; g.len()];
                let mut ch = b;
                for (i, op) in g.ops.iter().enumerate() {
                    let ss = assign[i];
                    let mut deps: Vec<TaskId> = Vec::new();
                    for &OpId(j) in &op.inputs {
                        let Some(dep_task) = op_task[j] else { continue };
                        if assign[j] != ss {
                            // activation crosses the ring: one task per link
                            let bytes = g.ops[j].kind.output_bytes(dt);
                            let links = noc.links_used(assign[j], ss);
                            let mut prev = dep_task;
                            for l in links {
                                let t = bytes as f64 / (cfg.noc_link_gbps * 1e9)
                                    + cfg.noc_hop_ns * 1e-9;
                                prev = sim.add_task_prio(res.noc_link(l), t, &[prev], i as u64, b as u32);
                            }
                            deps.push(prev);
                        } else {
                            deps.push(dep_task);
                        }
                    }
                    let wbytes = op.kind.weight_bytes(sparsity, dt);
                    if wbytes > 0 && b == 0 {
                        // weights stream once (stay resident per stage)
                        let t = dram.transfer(wbytes, 1).seconds;
                        let wtask =
                            sim.add_task(res.dram(ch % res.dram_channels), t, &[], i as u64);
                        ch += 1;
                        deps.push(wtask);
                        total_dram += wbytes as f64;
                    }
                    let (secs, macs) = op_secs(&op.kind, 1.0);
                    total_macs += macs;
                    total_dram += engines::lookup_dram_bytes(&op.kind, dt) as f64;
                    let engine = res.engine(ss, engines::engine_for(&op.kind));
                    let t = sim.add_task_prio(engine, secs, &deps, i as u64, b as u32);
                    op_task[i] = Some(t);
                }
            }
        }
    }

    let trace = sim.run();
    let total_s = trace.makespan;
    let batches = match par {
        Parallelism::DataParallel => 1,
        Parallelism::ModelParallel { inflight, .. } => inflight.max(1),
    };
    let samples = (g.batch * batches) as f64;
    let mut engine_secs: Vec<(Engine, f64)> = Vec::new();
    for ss in 0..cfg.subsystems {
        for e in crate::arch::chip::ENGINE_ORDER {
            let busy = trace.busy[res.engine(ss, e).0];
            if busy > 0.0 {
                match engine_secs.iter_mut().find(|(k, _)| *k == e) {
                    Some((_, v)) => *v += busy,
                    None => engine_secs.push((e, busy)),
                }
            }
        }
    }
    SimResult {
        target: format!("{} s={} {} event/{:?}", cfg.name, sparsity, dt.name(), par),
        model: g.name.clone(),
        batch: g.batch,
        sparsity,
        latency_ms: total_s * 1e3 / batches as f64,
        throughput: samples / total_s,
        engine_seconds: engine_secs,
        weighted_fraction: f64::NAN, // not decomposed in event mode
        energy: energy(cfg, total_macs, total_dram, total_s),
        per_op: Vec::new(),
    }
}

/// Contiguous FLOPs-balanced partition of ops into `stages` groups.
pub fn partition_by_flops(g: &Graph, stages: usize) -> Vec<usize> {
    let total = g.flops_dense().max(1.0);
    let per_stage = total / stages as f64;
    let mut assign = vec![0usize; g.len()];
    let mut acc = 0.0;
    let mut stage = 0usize;
    for (i, op) in g.ops.iter().enumerate() {
        assign[i] = stage;
        acc += op.kind.flops_dense();
        if acc > per_stage * (stage + 1) as f64 && stage + 1 < stages {
            stage += 1;
        }
    }
    assign
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::sim::cost::{simulate, Target};

    fn s4() -> AntoumConfig {
        AntoumConfig::s4()
    }

    #[test]
    fn event_close_to_analytic_data_parallel() {
        // the two fidelity levels must agree within 2x (event adds
        // contention; analytic adds none)
        let g = models::bert(models::BERT_BASE, 8, 128);
        let a = simulate(&g, Target::antoum(&s4(), 8));
        let e = simulate_event(&g, &s4(), 8, DType::Int8, Parallelism::DataParallel);
        let ratio = e.latency_ms / a.latency_ms;
        assert!((0.5..2.0).contains(&ratio), "event/analytic latency ratio {ratio}");
    }

    #[test]
    fn pipelining_beats_single_stage_on_throughput() {
        let g = models::bert(models::BERT_BASE, 4, 128);
        let one = simulate_event(
            &g, &s4(), 8, DType::Int8,
            Parallelism::ModelParallel { stages: 1, inflight: 8 },
        );
        let four = simulate_event(
            &g, &s4(), 8, DType::Int8,
            Parallelism::ModelParallel { stages: 4, inflight: 8 },
        );
        assert!(
            four.throughput > 1.5 * one.throughput,
            "4-stage {} vs 1-stage {}",
            four.throughput,
            one.throughput
        );
    }

    #[test]
    fn partition_contiguous_and_balanced() {
        let g = models::resnet50(1, 224);
        let a = partition_by_flops(&g, 4);
        // contiguous + uses all stages
        for w in a.windows(2) {
            assert!(w[1] == w[0] || w[1] == w[0] + 1);
        }
        assert_eq!(*a.last().unwrap(), 3);
        // each stage gets 10–40% of FLOPs
        let total = g.flops_dense();
        for s in 0..4 {
            let f: f64 = g
                .ops
                .iter()
                .enumerate()
                .filter(|(i, _)| a[*i] == s)
                .map(|(_, o)| o.kind.flops_dense())
                .sum();
            assert!((0.1..0.4).contains(&(f / total)), "stage {s}: {}", f / total);
        }
    }

    #[test]
    fn event_sim_sparsity_still_speeds_up() {
        let g = models::resnet50(8, 224);
        let t1 = simulate_event(&g, &s4(), 1, DType::Int8, Parallelism::DataParallel);
        let t8 = simulate_event(&g, &s4(), 8, DType::Int8, Parallelism::DataParallel);
        let sp = t8.throughput / t1.throughput;
        assert!(sp > 4.0, "event-mode 8x sparsity speedup {sp}");
    }
}
