//! Descriptive statistics used by the bench harness and serving metrics.

/// Summary of a sample (times, latencies, ...). All values in the unit of
/// the input.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    /// the open-loop serving tail the net harness reports: at 1k rps even
    /// a 10 s run has ~10 samples past this point, so it only means
    /// something on exact sample sets like these, not on log histograms
    pub p999: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of(empty)");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n.max(2).saturating_sub(1) as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p99: percentile_sorted(&sorted, 99.0),
            p999: percentile_sorted(&sorted, 99.9),
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice, q in [0,100].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Online mean/variance (Welford) — allocation-free metric accumulation for
/// the serving hot path.
#[derive(Clone, Debug)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

/// Manual impl: a derived `Default` would zero `min`/`max`, making an
/// accumulator built via `Default::default()` report `min = 0` for
/// all-positive samples. Delegating to [`Welford::new`] keeps the ±∞
/// sentinels.
impl Default for Welford {
    fn default() -> Self {
        Welford::new()
    }
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Fixed-bucket latency histogram (log-spaced), cheap enough for per-request
/// recording; powers the p50/p99 the serving reports print.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// bucket i covers [base * growth^i, base * growth^(i+1))
    base_us: f64,
    growth: f64,
    counts: Vec<u64>,
    total: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// 1 µs .. ~17 min in 64 log buckets (×1.5).
    pub fn new() -> Self {
        LatencyHistogram { base_us: 1.0, growth: 1.5, counts: vec![0; 64], total: 0 }
    }

    #[inline]
    pub fn record_us(&mut self, us: f64) {
        let idx = if us <= self.base_us {
            0
        } else {
            ((us / self.base_us).ln() / self.growth.ln()).floor() as usize
        };
        let idx = idx.min(self.counts.len() - 1);
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Upper edge of the bucket holding quantile `q` (0..1); 0 if empty.
    pub fn quantile_us(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        // clamp to ≥ 1: at q=0 a zero target made `acc >= target` hold at
        // bucket 0 even when that bucket was empty — the minimum quantile
        // must land in the first *occupied* bucket
        let target = ((q * self.total as f64).ceil() as u64).max(1);
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.base_us * self.growth.powi(i as i32 + 1);
            }
        }
        self.base_us * self.growth.powi(self.counts.len() as i32)
    }

    /// Batch form of [`quantile_us`](Self::quantile_us): one call for all
    /// requested quantiles, in input order. This is what lock-guarded
    /// consumers ([`Metrics`](crate::coordinator::Metrics)) call so a
    /// p50/p99/p999 snapshot costs one lock acquisition, not one per
    /// quantile.
    pub fn quantiles(&self, qs: &[f64]) -> Vec<f64> {
        qs.iter().map(|&q| self.quantile_us(q)).collect()
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 50.0), 5.0);
        assert_eq!(percentile_sorted(&v, 100.0), 10.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs);
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.std() - s.std).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 9.0);
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000 {
            h.record_us(i as f64);
        }
        let p50 = h.quantile_us(0.5);
        let p99 = h.quantile_us(0.99);
        assert!(p50 < p99);
        // log buckets: answer within one growth factor of truth
        assert!(p50 >= 500.0 / 1.5 && p50 <= 500.0 * 1.5 * 1.5, "p50={p50}");
    }

    #[test]
    fn welford_default_matches_new() {
        // regression: the old derived Default zeroed min/max
        let mut w = Welford::default();
        w.push(3.0);
        w.push(5.0);
        assert_eq!(w.min(), 3.0);
        assert_eq!(w.max(), 5.0);
        let mut neg = Welford::default();
        neg.push(-2.0);
        assert_eq!(neg.max(), -2.0);
    }

    #[test]
    fn histogram_quantile_zero_lands_in_occupied_bucket() {
        // regression: q=0 used to return bucket 0's upper edge (~1.5 µs)
        // even when only a 1000 µs sample was recorded
        let mut h = LatencyHistogram::new();
        h.record_us(1000.0);
        let q0 = h.quantile_us(0.0);
        assert!(q0 >= 1000.0 / 1.5 && q0 <= 1000.0 * 1.5 * 1.5, "q0={q0}");
        assert_eq!(h.quantile_us(0.0), h.quantile_us(1.0));
    }

    #[test]
    fn summary_p999_sits_in_the_tail() {
        // 1..=10000: p999 must land between p99 and max, near 9991
        let xs: Vec<f64> = (1..=10_000).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert!(s.p99 < s.p999 && s.p999 <= s.max, "p99={} p999={} max={}", s.p99, s.p999, s.max);
        assert!((s.p999 - 9991.0).abs() < 1.0, "p999={}", s.p999);
        // degenerate n=1: every quantile collapses to the sample
        let one = Summary::of(&[42.0]);
        assert_eq!(one.p999, 42.0);
    }

    #[test]
    fn histogram_quantiles_batch_matches_individual_at_tail_indices() {
        let mut h = LatencyHistogram::new();
        for i in 1..=10_000 {
            h.record_us(i as f64);
        }
        let qs = [0.0, 0.5, 0.99, 0.999, 1.0];
        let batch = h.quantiles(&qs);
        assert_eq!(batch.len(), qs.len());
        for (b, &q) in batch.iter().zip(&qs) {
            assert_eq!(*b, h.quantile_us(q), "q={q}");
        }
        // tail ordering holds through the log buckets
        assert!(batch[1] <= batch[2] && batch[2] <= batch[3] && batch[3] <= batch[4]);
        // empty histogram: batch accessor mirrors the scalar 0.0 answers
        assert_eq!(LatencyHistogram::new().quantiles(&qs), vec![0.0; qs.len()]);
    }

    #[test]
    fn histogram_merge_adds() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record_us(10.0);
        b.record_us(1000.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn summary_empty_panics() {
        Summary::of(&[]);
    }
}
