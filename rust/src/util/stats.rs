//! Descriptive statistics used by the bench harness and serving metrics,
//! plus the seeded [`Zipf`] sampler the traffic generators draw hot-key
//! distributions from.

use crate::util::rng::Xoshiro256;

/// Summary of a sample (times, latencies, ...). All values in the unit of
/// the input.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    /// the open-loop serving tail the net harness reports: at 1k rps even
    /// a 10 s run has ~10 samples past this point, so it only means
    /// something on exact sample sets like these, not on log histograms
    pub p999: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of(empty)");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n.max(2).saturating_sub(1) as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p99: percentile_sorted(&sorted, 99.0),
            p999: percentile_sorted(&sorted, 99.9),
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice, q in [0,100].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Online mean/variance (Welford) — allocation-free metric accumulation for
/// the serving hot path.
#[derive(Clone, Debug)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

/// Manual impl: a derived `Default` would zero `min`/`max`, making an
/// accumulator built via `Default::default()` report `min = 0` for
/// all-positive samples. Delegating to [`Welford::new`] keeps the ±∞
/// sentinels.
impl Default for Welford {
    fn default() -> Self {
        Welford::new()
    }
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Fixed-bucket latency histogram (log-spaced), cheap enough for per-request
/// recording; powers the p50/p99 the serving reports print.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// bucket i covers [base * growth^i, base * growth^(i+1))
    base_us: f64,
    growth: f64,
    counts: Vec<u64>,
    total: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// 1 µs .. ~17 min in 64 log buckets (×1.5).
    pub fn new() -> Self {
        LatencyHistogram { base_us: 1.0, growth: 1.5, counts: vec![0; 64], total: 0 }
    }

    #[inline]
    pub fn record_us(&mut self, us: f64) {
        let idx = if us <= self.base_us {
            0
        } else {
            ((us / self.base_us).ln() / self.growth.ln()).floor() as usize
        };
        let idx = idx.min(self.counts.len() - 1);
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Upper edge of the bucket holding quantile `q` (0..1); 0 if empty.
    pub fn quantile_us(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        // clamp to ≥ 1: at q=0 a zero target made `acc >= target` hold at
        // bucket 0 even when that bucket was empty — the minimum quantile
        // must land in the first *occupied* bucket
        let target = ((q * self.total as f64).ceil() as u64).max(1);
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.base_us * self.growth.powi(i as i32 + 1);
            }
        }
        self.base_us * self.growth.powi(self.counts.len() as i32)
    }

    /// Batch form of [`quantile_us`](Self::quantile_us): one call for all
    /// requested quantiles, in input order. This is what lock-guarded
    /// consumers ([`Metrics`](crate::coordinator::Metrics)) call so a
    /// p50/p99/p999 snapshot costs one lock acquisition, not one per
    /// quantile.
    pub fn quantiles(&self, qs: &[f64]) -> Vec<f64> {
        qs.iter().map(|&q| self.quantile_us(q)).collect()
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
    }
}

/// Zipf(s) distribution over ranks `0..n` — the shape of hot-key traffic
/// from a large user population (rank k drawn with probability
/// ∝ 1/(k+1)^s). Sampling is a binary search over the precomputed CDF,
/// driven by any [`Xoshiro256`], so generated traffic is seeded and
/// reproducible. `s = 0` degenerates to uniform; `s ≈ 1` is the classic
/// web-traffic skew the response-cache bench sweeps.
#[derive(Clone, Debug)]
pub struct Zipf {
    /// cdf[k] = P(rank ≤ k); last element pinned to exactly 1.0
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Zipf {
        assert!(n > 0, "Zipf over an empty universe");
        let mut cdf: Vec<f64> = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 0..n {
            acc += 1.0 / ((k + 1) as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        // pin the top so u ∈ [0,1) can never fall past the last bucket
        *cdf.last_mut().unwrap() = 1.0;
        Zipf { cdf }
    }

    /// Number of ranks.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        false // n > 0 by construction
    }

    /// Draw one rank in `0..len()`.
    pub fn sample(&self, rng: &mut Xoshiro256) -> usize {
        let u = rng.next_f64();
        self.cdf
            .partition_point(|&c| c < u)
            .min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 50.0), 5.0);
        assert_eq!(percentile_sorted(&v, 100.0), 10.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs);
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.std() - s.std).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 9.0);
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000 {
            h.record_us(i as f64);
        }
        let p50 = h.quantile_us(0.5);
        let p99 = h.quantile_us(0.99);
        assert!(p50 < p99);
        // log buckets: answer within one growth factor of truth
        assert!(p50 >= 500.0 / 1.5 && p50 <= 500.0 * 1.5 * 1.5, "p50={p50}");
    }

    #[test]
    fn welford_default_matches_new() {
        // regression: the old derived Default zeroed min/max
        let mut w = Welford::default();
        w.push(3.0);
        w.push(5.0);
        assert_eq!(w.min(), 3.0);
        assert_eq!(w.max(), 5.0);
        let mut neg = Welford::default();
        neg.push(-2.0);
        assert_eq!(neg.max(), -2.0);
    }

    #[test]
    fn histogram_quantile_zero_lands_in_occupied_bucket() {
        // regression: q=0 used to return bucket 0's upper edge (~1.5 µs)
        // even when only a 1000 µs sample was recorded
        let mut h = LatencyHistogram::new();
        h.record_us(1000.0);
        let q0 = h.quantile_us(0.0);
        assert!(q0 >= 1000.0 / 1.5 && q0 <= 1000.0 * 1.5 * 1.5, "q0={q0}");
        assert_eq!(h.quantile_us(0.0), h.quantile_us(1.0));
    }

    #[test]
    fn summary_p999_sits_in_the_tail() {
        // 1..=10000: p999 must land between p99 and max, near 9991
        let xs: Vec<f64> = (1..=10_000).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert!(s.p99 < s.p999 && s.p999 <= s.max, "p99={} p999={} max={}", s.p99, s.p999, s.max);
        assert!((s.p999 - 9991.0).abs() < 1.0, "p999={}", s.p999);
        // degenerate n=1: every quantile collapses to the sample
        let one = Summary::of(&[42.0]);
        assert_eq!(one.p999, 42.0);
    }

    #[test]
    fn histogram_quantiles_batch_matches_individual_at_tail_indices() {
        let mut h = LatencyHistogram::new();
        for i in 1..=10_000 {
            h.record_us(i as f64);
        }
        let qs = [0.0, 0.5, 0.99, 0.999, 1.0];
        let batch = h.quantiles(&qs);
        assert_eq!(batch.len(), qs.len());
        for (b, &q) in batch.iter().zip(&qs) {
            assert_eq!(*b, h.quantile_us(q), "q={q}");
        }
        // tail ordering holds through the log buckets
        assert!(batch[1] <= batch[2] && batch[2] <= batch[3] && batch[3] <= batch[4]);
        // empty histogram: batch accessor mirrors the scalar 0.0 answers
        assert_eq!(LatencyHistogram::new().quantiles(&qs), vec![0.0; qs.len()]);
    }

    #[test]
    fn histogram_merge_adds() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record_us(10.0);
        b.record_us(1000.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn summary_empty_panics() {
        Summary::of(&[]);
    }

    #[test]
    fn zipf_shape_matches_the_power_law() {
        // s = 1 over 50 ranks: P(0)/P(1) = 2 exactly; check the empirical
        // ratio and the qualitative shape on a large seeded draw
        let z = Zipf::new(50, 1.0);
        let mut rng = Xoshiro256::seed_from_u64(42);
        let mut counts = [0u64; 50];
        let n = 200_000;
        for _ in 0..n {
            counts[z.sample(&mut rng)] += 1;
        }
        let ratio = counts[0] as f64 / counts[1] as f64;
        assert!((1.8..=2.2).contains(&ratio), "rank0/rank1 = {ratio}");
        assert!(
            counts[0] > counts[1] && counts[1] > counts[2] && counts[2] > counts[3],
            "head must be strictly ordered: {:?}",
            &counts[..4]
        );
        assert!(
            counts[0] > 10 * counts[49],
            "head must dwarf the tail: {} vs {}",
            counts[0],
            counts[49]
        );
        assert!(counts.iter().all(|&c| c > 0), "every rank reachable in 200k draws");
    }

    #[test]
    fn zipf_s_zero_is_uniform() {
        let z = Zipf::new(10, 0.0);
        let mut rng = Xoshiro256::seed_from_u64(7);
        let mut counts = [0u64; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[z.sample(&mut rng)] += 1;
        }
        let expect = n as f64 / 10.0;
        for (k, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expect).abs() < 0.1 * expect,
                "rank {k}: {c} vs uniform {expect}"
            );
        }
    }

    #[test]
    fn zipf_is_seed_deterministic_and_in_range() {
        let z = Zipf::new(17, 1.3);
        assert_eq!(z.len(), 17);
        let draw = |seed| {
            let mut rng = Xoshiro256::seed_from_u64(seed);
            (0..1000).map(|_| z.sample(&mut rng)).collect::<Vec<_>>()
        };
        let a = draw(123);
        assert_eq!(a, draw(123), "same seed, same stream");
        assert_ne!(a, draw(124), "different seed, different stream");
        assert!(a.iter().all(|&k| k < 17));
        // single-rank universe: every draw is rank 0
        let one = Zipf::new(1, 2.0);
        let mut rng = Xoshiro256::seed_from_u64(5);
        assert!((0..100).all(|_| one.sample(&mut rng) == 0));
    }
}
