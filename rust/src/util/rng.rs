//! Deterministic pseudo-random generators (xoshiro256** + SplitMix64).
//!
//! Everything stochastic in this crate — workload generators, Poisson
//! arrivals, synthetic weights, property-test case generation — draws from
//! [`Xoshiro256`] so every experiment is reproducible from a seed that is
//! printed in the report header.

/// SplitMix64: seeds xoshiro and serves as a cheap one-shot mixer.
#[derive(Clone, Debug)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality, 2^256-1 period. Not cryptographic.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the reference implementation.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire reduction).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.next_f64();
            return (-2.0 * u1.ln()).sqrt()
                * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Exponential with rate `lambda` (inter-arrival times of a Poisson
    /// process — the serving benchmarks' arrival model).
    pub fn next_exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Xoshiro256::seed_from_u64(7);
        let mut b = Xoshiro256::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Xoshiro256::seed_from_u64(4);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.next_below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256::seed_from_u64(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Xoshiro256::seed_from_u64(6);
        let n = 50_000;
        let m = (0..n).map(|_| r.next_exp(4.0)).sum::<f64>() / n as f64;
        assert!((m - 0.25).abs() < 0.01, "mean={m}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(8);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "seed 8 shuffles");
    }
}
