//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup, calibrated iteration counts, outlier-robust summaries
//! and a stable text format the `rust/benches/*.rs` binaries (registered
//! with `harness = false`) print. Paper-table benches additionally emit the
//! rows the paper reports via [`crate::sim::report`].
//!
//! [`JsonReport`] is the machine-readable side: benches push entries into
//! it and [`JsonReport::write`] emits `BENCH_<topic>.json` (schema
//! `s4-bench-v1`, see EXPERIMENTS.md §Perf) — the per-PR perf trajectory
//! CI uploads as an artifact.

use std::path::{Path, PathBuf};
use std::time::Instant;

use super::json::Json;
use super::stats::Summary;

/// One measured benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// per-iteration wall time, seconds
    pub summary: Summary,
    pub iters_per_sample: u64,
    pub samples: usize,
}

impl BenchResult {
    /// Machine-readable form (seconds; consumed by [`JsonReport`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("mean_s", Json::Num(self.summary.mean)),
            ("p50_s", Json::Num(self.summary.p50)),
            ("p99_s", Json::Num(self.summary.p99)),
            ("std_s", Json::Num(self.summary.std)),
            ("iters_per_sample", Json::Num(self.iters_per_sample as f64)),
            ("samples", Json::Num(self.samples as f64)),
        ])
    }

    pub fn print(&self) {
        let s = &self.summary;
        println!(
            "bench {:<44} {:>12}/iter  p50 {:>12}  p99 {:>12}  (n={}, k={})",
            self.name,
            fmt_time(s.mean),
            fmt_time(s.p50),
            fmt_time(s.p99),
            self.samples,
            self.iters_per_sample,
        );
    }
}

/// Human time formatting: 1.234 µs / 12.3 ms / 1.2 s.
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// Harness configuration.
#[derive(Clone, Debug)]
pub struct Bench {
    /// minimum wall time to spend per sample (drives iteration calibration)
    pub min_sample_secs: f64,
    pub samples: usize,
    pub warmup_secs: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { min_sample_secs: 0.05, samples: 12, warmup_secs: 0.2 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { min_sample_secs: 0.02, samples: 6, warmup_secs: 0.05 }
    }

    /// Measure `f`, which must perform ONE logical iteration per call.
    /// A `std::hint::black_box` around inputs/outputs is the caller's job.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup until the clock says so (fills caches, JITs nothing here
        // but stabilizes frequency scaling).
        let w0 = Instant::now();
        while w0.elapsed().as_secs_f64() < self.warmup_secs {
            f();
        }
        // Calibrate iterations per sample.
        let mut iters: u64 = 1;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let dt = t0.elapsed().as_secs_f64();
            if dt >= self.min_sample_secs || iters >= 1 << 24 {
                break;
            }
            let scale = (self.min_sample_secs / dt.max(1e-9) * 1.2).ceil();
            iters = (iters as f64 * scale.clamp(2.0, 100.0)) as u64;
        }
        // Measure.
        let mut per_iter = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            per_iter.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        let r = BenchResult {
            name: name.to_string(),
            summary: Summary::of(&per_iter),
            iters_per_sample: iters,
            samples: self.samples,
        };
        r.print();
        r
    }

    /// Measure a function that reports its own units of work per call
    /// (e.g. simulated events); returns (result, units/sec at p50).
    pub fn run_throughput<F: FnMut() -> u64>(
        &self,
        name: &str,
        mut f: F,
    ) -> (BenchResult, f64) {
        let mut units = 0u64;
        let r = self.run(name, || {
            units = f();
        });
        let ups = units as f64 / r.summary.p50;
        println!("      {:<44} {:>14.0} units/s", "", ups);
        (r, ups)
    }
}

/// Collector for one `BENCH_<topic>.json` trajectory file.
///
/// Convention (schema `s4-bench-v1`): top-level metadata set via
/// [`set`](JsonReport::set), one object per measurement pushed into
/// `entries`. Files land in `$S4_BENCH_DIR` (default: the process
/// working directory), named `BENCH_<topic>.json`, so successive PRs
/// produce a comparable perf trajectory.
pub struct JsonReport {
    topic: String,
    fields: Vec<(String, Json)>,
    entries: Vec<Json>,
    /// worker/thread count the bench actually dispatched on (see
    /// [`set_effective_workers`](JsonReport::set_effective_workers))
    effective_workers: Option<usize>,
}

impl JsonReport {
    pub fn new(topic: &str) -> JsonReport {
        JsonReport {
            topic: topic.to_string(),
            fields: Vec::new(),
            entries: Vec::new(),
            effective_workers: None,
        }
    }

    /// Set a top-level metadata field (shape, smoke flag, host info, ...).
    pub fn set(&mut self, key: &str, v: Json) {
        self.fields.push((key.to_string(), v));
    }

    /// Record the parallelism the bench *actually used* (pool
    /// participants, max thread sweep point, backend thread cap) —
    /// emitted under `host.effective_workers`. Unset, it defaults to
    /// [`configured_participants`](crate::sparse::pool::configured_participants)
    /// (machine width, or the `S4_POOL_WORKERS` override), so every
    /// report names the parallelism a default-pooled bench dispatched on.
    pub fn set_effective_workers(&mut self, n: usize) {
        self.effective_workers = Some(n);
    }

    /// Mark this report as skipped: the bench could not run its
    /// measurement (single-core host, missing fixture, ...) but still
    /// emits its `BENCH_<topic>.json` with a `"skipped"` reason — an
    /// absent file is indistinguishable from a broken bench, and CI
    /// treats it as exactly that.
    pub fn set_skipped(&mut self, reason: &str) {
        self.set("skipped", Json::Str(reason.to_string()));
    }

    /// Append one measurement entry.
    pub fn push(&mut self, entry: Json) {
        self.entries.push(entry);
    }

    pub fn to_json(&self) -> Json {
        // every BENCH_*.json carries the host's parallelism next to the
        // worker count the bench dispatched on, so perf trajectories are
        // comparable across machines (a 2-core CI runner's "speedup at 8
        // threads" is not a 64-core box's)
        let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let effective = self
            .effective_workers
            .unwrap_or_else(crate::sparse::pool::configured_participants);
        let mut pairs = vec![
            ("schema", Json::Str("s4-bench-v1".into())),
            ("bench", Json::Str(self.topic.clone())),
            (
                "host",
                Json::obj(vec![
                    ("available_parallelism", Json::Num(avail as f64)),
                    ("effective_workers", Json::Num(effective as f64)),
                ]),
            ),
        ];
        for (k, v) in &self.fields {
            pairs.push((k.as_str(), v.clone()));
        }
        pairs.push(("entries", Json::Arr(self.entries.clone())));
        Json::obj(pairs)
    }

    /// Write `BENCH_<topic>.json` into `dir`; returns the path.
    pub fn write_to(&self, dir: &Path) -> anyhow::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.topic));
        std::fs::write(&path, format!("{}\n", self.to_json()))?;
        Ok(path)
    }

    /// Write to `$S4_BENCH_DIR` (default `.`).
    pub fn write(&self) -> anyhow::Result<PathBuf> {
        let dir = std::env::var("S4_BENCH_DIR").unwrap_or_else(|_| ".".into());
        self.write_to(Path::new(&dir))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bench { min_sample_secs: 0.001, samples: 3, warmup_secs: 0.0 };
        let mut x = 0u64;
        let r = b.run("spin", || {
            for i in 0..100 {
                x = x.wrapping_add(std::hint::black_box(i));
            }
        });
        assert!(r.summary.mean > 0.0);
        assert!(r.iters_per_sample >= 1);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(3e-9).ends_with("ns"));
        assert!(fmt_time(3e-6).ends_with("µs"));
        assert!(fmt_time(3e-3).ends_with("ms"));
        assert!(fmt_time(3.0).ends_with('s'));
    }

    #[test]
    fn json_report_roundtrips_and_writes() {
        let mut r = JsonReport::new("unit_test");
        r.set("smoke", Json::Bool(true));
        r.push(Json::obj(vec![("sparsity", Json::Num(8.0)), ("gflops", Json::Num(1.5))]));
        let j = r.to_json();
        assert_eq!(j.get("schema").as_str(), Some("s4-bench-v1"));
        assert_eq!(j.get("bench").as_str(), Some("unit_test"));
        assert_eq!(j.get("entries").as_arr().unwrap().len(), 1);
        // host comparability fields are present in every report
        assert!(j.get("host").get("available_parallelism").as_u64().unwrap() >= 1);
        assert!(j.get("host").get("effective_workers").as_u64().unwrap() >= 1);
        // serialized form parses back identically
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        let dir = std::env::temp_dir();
        let path = r.write_to(&dir).unwrap();
        assert!(path.file_name().unwrap().to_str().unwrap() == "BENCH_unit_test.json");
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(Json::parse(text.trim()).unwrap(), j);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn json_report_effective_workers_override() {
        let mut r = JsonReport::new("unit_test_workers");
        r.set_effective_workers(3);
        let j = r.to_json();
        assert_eq!(j.get("host").get("effective_workers").as_u64(), Some(3));
        // unset, it stamps the configured pool width (S4_POOL_WORKERS
        // aware) rather than blindly re-deriving available_parallelism
        let j2 = JsonReport::new("unit_test_workers_default").to_json();
        assert_eq!(
            j2.get("host").get("effective_workers").as_u64(),
            Some(crate::sparse::pool::configured_participants() as u64)
        );
    }

    #[test]
    fn json_report_skipped_field() {
        let mut r = JsonReport::new("unit_test_skip");
        r.set_skipped("single-core host");
        let j = r.to_json();
        assert_eq!(j.get("skipped").as_str(), Some("single-core host"));
        // a non-skipped report has no such field
        let j2 = JsonReport::new("unit_test_noskip").to_json();
        assert_eq!(*j2.get("skipped"), Json::Null);
    }

    #[test]
    fn bench_result_to_json_has_core_fields() {
        let b = Bench { min_sample_secs: 0.001, samples: 3, warmup_secs: 0.0 };
        let r = b.run("spin", || {
            std::hint::black_box(1 + 1);
        });
        let j = r.to_json();
        assert_eq!(j.get("name").as_str(), Some("spin"));
        assert!(j.get("p50_s").as_f64().unwrap() >= 0.0);
        assert!(j.get("samples").as_u64().unwrap() == 3);
    }
}
