//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args,
//! with typed accessors and an auto-generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — `argv[0]` must be skipped
    /// by the caller.
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), String::from("true"));
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments (skips argv[0]).
    pub fn from_env() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    /// Comma-separated integer list, e.g. `--sparsities 1,2,8`.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> anyhow::Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--{key}: bad integer {t:?}"))
                })
                .collect(),
        }
    }

    /// Comma-separated `key=value` numeric pairs, e.g.
    /// `--mix interactive=0.2,standard=0.5,bulk=0.3`. Returns the pairs
    /// in input order; key validity is the caller's concern.
    pub fn get_kv_f64(&self, key: &str) -> anyhow::Result<Option<Vec<(String, f64)>>> {
        let Some(v) = self.get(key) else { return Ok(None) };
        let mut out = Vec::new();
        for pair in v.split(',') {
            let pair = pair.trim();
            if pair.is_empty() {
                continue;
            }
            let (k, val) = pair.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("--{key}: expected name=number, got {pair:?}")
            })?;
            let val: f64 = val
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: bad number in {pair:?}"))?;
            out.push((k.trim().to_string(), val));
        }
        anyhow::ensure!(!out.is_empty(), "--{key}: no name=number pairs given");
        Ok(Some(out))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn flags_and_values() {
        let a = parse("serve --batch 8 --verbose --rate=100.5 input.txt");
        assert_eq!(a.positional(), &["serve".to_string(), "input.txt".to_string()]);
        assert_eq!(a.get_usize("batch", 1).unwrap(), 8);
        assert!(a.has("verbose"));
        assert_eq!(a.get_f64("rate", 0.0).unwrap(), 100.5);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn integer_list() {
        let a = parse("--sparsities 1,2,8,32");
        assert_eq!(a.get_usize_list("sparsities", &[]).unwrap(), vec![1, 2, 8, 32]);
        let b = parse("");
        assert_eq!(b.get_usize_list("sparsities", &[4]).unwrap(), vec![4]);
    }

    #[test]
    fn bad_value_is_error() {
        let a = parse("--batch eight");
        assert!(a.get_usize("batch", 1).is_err());
    }

    #[test]
    fn kv_pairs_parse_in_order() {
        let a = parse("--mix interactive=0.2,standard=0.5,bulk=0.3");
        let kv = a.get_kv_f64("mix").unwrap().unwrap();
        assert_eq!(
            kv,
            vec![
                ("interactive".to_string(), 0.2),
                ("standard".to_string(), 0.5),
                ("bulk".to_string(), 0.3)
            ]
        );
        assert!(parse("").get_kv_f64("mix").unwrap().is_none());
        assert!(parse("--mix interactive").get_kv_f64("mix").is_err());
        assert!(parse("--mix interactive=lots").get_kv_f64("mix").is_err());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--x --y 3");
        assert!(a.has("x"));
        assert_eq!(a.get_usize("y", 0).unwrap(), 3);
    }
}
