//! Mini property-based testing runner (proptest is unavailable offline).
//!
//! [`check`] runs a property over `n` generated cases with naive input
//! shrinking via re-generation at smaller "size" budgets; on failure it
//! reports the seed so the case replays deterministically. Used by
//! `rust/tests/properties.rs` for the coordinator and sparse-format
//! invariants the brief calls out.

use super::rng::Xoshiro256;

/// Per-case generation context: an RNG plus a size budget generators scale
/// their outputs by (vector lengths, value magnitudes, ...).
pub struct Gen {
    pub rng: Xoshiro256,
    pub size: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector with length scaled by the current size budget (0..=size).
    pub fn vec_f32(&mut self, max_len: usize) -> Vec<f32> {
        let len = self.usize_in(0, max_len.min(self.size.max(1)));
        (0..len).map(|_| self.rng.next_f32() * 2.0 - 1.0).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.usize_in(0, xs.len() - 1)]
    }
}

/// Outcome of a property over one case.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` generated inputs. Panics with a replayable
/// diagnostic on the first failure (after attempting smaller sizes).
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    let base_seed = match std::env::var("S4_PROP_SEED") {
        Ok(s) => s.parse::<u64>().expect("S4_PROP_SEED must be u64"),
        Err(_) => 0x5EED_0000,
    };
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E37_79B9);
        let size = 4 + case * 96 / cases.max(1); // ramp sizes up over the run
        let mut g = Gen { rng: Xoshiro256::seed_from_u64(seed), size };
        if let Err(msg) = prop(&mut g) {
            // Shrink attempt: replay the same seed at smaller size budgets
            // and report the smallest size that still fails.
            let mut min_fail = (size, msg.clone());
            for s in (1..size).rev() {
                let mut g2 = Gen { rng: Xoshiro256::seed_from_u64(seed), size: s };
                if let Err(m2) = prop(&mut g2) {
                    min_fail = (s, m2);
                }
            }
            panic!(
                "property `{name}` failed (case {case}, seed {seed}, \
                 minimal size {}): {}\nreplay: S4_PROP_SEED={base_seed}",
                min_fail.0, min_fail.1
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut ran = 0;
        check("trivial", 50, |g| {
            ran += 1;
            let x = g.usize_in(0, 10);
            if x <= 10 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(ran, 50);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |g| {
            let v = g.vec_f32(100);
            if v.len() < 5 {
                Ok(())
            } else {
                Err(format!("len {} >= 5", v.len()))
            }
        });
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen { rng: Xoshiro256::seed_from_u64(1), size: 10 };
        for _ in 0..1000 {
            let x = g.usize_in(3, 7);
            assert!((3..=7).contains(&x));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
    }
}
