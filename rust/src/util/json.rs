//! Minimal JSON parser + writer (RFC 8259 subset sufficient for this repo).
//!
//! Used for the artifact manifest, golden-output files, and report export.
//! Supports the full JSON value model; numbers are f64 (adequate: nothing
//! in our manifests exceeds 2^53). No serde in the build environment.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` for deterministic output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with byte position. `Display`/`Error` are implemented
/// by hand: the build environment vendors no derive crates (`anyhow` is
/// the crate's only dependency).
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors (None on type mismatch) ----

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x <= 2f64.powi(53) {
                Some(x as u64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` access; `Json::Null` for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Builder: object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl fmt::Display for Json {
    /// Compact serialization (deterministic key order).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 2f64.powi(53) {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only; surrogate pairs unsupported (unused here).
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 run up to the next `"` or `\`
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"t":true,"f":false,"n":null},"s":"hi\t\"q\""}"#;
        let v = Json::parse(src).unwrap();
        let printed = v.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn u64_accessor() {
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
    }

    #[test]
    fn error_position() {
        let e = Json::parse("[1, x]").unwrap_err();
        assert_eq!(e.pos, 4);
    }
}
