//! In-repo substrates: JSON, RNG, stats, CLI, bench harness, property tests.
//!
//! This build environment ships no serde/clap/criterion/proptest/rand, so
//! the pieces of those the stack needs are implemented here from scratch
//! (per the reproduction brief's "build every substrate" rule). Each module
//! is deliberately small, dependency-free and unit-tested.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
