//! The fault schedule: which backend call misbehaves, and how.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::util::rng::Xoshiro256;

/// One injected misbehavior.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// `panic!` inside `run_batch` — exercises the worker fence and the
    /// supervisor respawn path.
    Panic,
    /// `Err` from `run_batch` — the clean failure path; bursts of these
    /// trip the health breaker.
    Error,
    /// Delay execution by the given duration, then run normally —
    /// exercises deadline shedding and queueing collapse without failing
    /// anything.
    Slow(Duration),
}

impl FaultKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            FaultKind::Panic => "panic",
            FaultKind::Error => "error",
            FaultKind::Slow(_) => "slow",
        }
    }
}

/// A deterministic schedule mapping backend call index (0-based count of
/// `run_batch` invocations) to the fault injected there. Pure data: build
/// it by hand for exact scenarios, or from a seed for coverage. Calls not
/// in the schedule execute normally.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    schedule: BTreeMap<u64, FaultKind>,
}

impl FaultPlan {
    /// Empty plan: injects nothing.
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Seeded plan over the first `calls` backend calls: each call
    /// independently faults with probability `fault_rate`, the kind drawn
    /// uniformly from {panic, error, slow(`slow`)}. Same seed → same
    /// schedule, always.
    pub fn seeded(seed: u64, calls: u64, fault_rate: f64, slow: Duration) -> FaultPlan {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut plan = FaultPlan::new();
        for idx in 0..calls {
            if rng.next_f64() < fault_rate {
                let kind = match rng.next_below(3) {
                    0 => FaultKind::Panic,
                    1 => FaultKind::Error,
                    _ => FaultKind::Slow(slow),
                };
                plan.schedule.insert(idx, kind);
            }
        }
        plan
    }

    pub fn with_panic_at(mut self, idx: u64) -> FaultPlan {
        self.schedule.insert(idx, FaultKind::Panic);
        self
    }

    pub fn with_error_at(mut self, idx: u64) -> FaultPlan {
        self.schedule.insert(idx, FaultKind::Error);
        self
    }

    /// `len` consecutive errors starting at `start` — the shape that
    /// trips a consecutive-failure breaker.
    pub fn with_error_burst(mut self, start: u64, len: u64) -> FaultPlan {
        for idx in start..start + len {
            self.schedule.insert(idx, FaultKind::Error);
        }
        self
    }

    pub fn with_slow_at(mut self, idx: u64, delay: Duration) -> FaultPlan {
        self.schedule.insert(idx, FaultKind::Slow(delay));
        self
    }

    /// The fault scheduled at call `idx`, if any.
    pub fn at(&self, idx: u64) -> Option<&FaultKind> {
        self.schedule.get(&idx)
    }

    /// Number of scheduled faults.
    pub fn len(&self) -> usize {
        self.schedule.len()
    }

    pub fn is_empty(&self) -> bool {
        self.schedule.is_empty()
    }

    /// Scheduled (index, kind) pairs in call order.
    pub fn iter(&self) -> impl Iterator<Item = (u64, &FaultKind)> {
        self.schedule.iter().map(|(i, k)| (*i, k))
    }

    /// Count of scheduled faults matching `kind`'s discriminant name.
    pub fn count_of(&self, name: &str) -> usize {
        self.schedule.values().filter(|k| k.as_str() == name).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hand_built_schedule_places_exactly_what_was_asked() {
        let p = FaultPlan::new()
            .with_panic_at(3)
            .with_error_burst(10, 4)
            .with_slow_at(20, Duration::from_millis(5));
        assert_eq!(p.len(), 6);
        assert_eq!(p.at(3), Some(&FaultKind::Panic));
        for i in 10..14 {
            assert_eq!(p.at(i), Some(&FaultKind::Error), "burst covers {i}");
        }
        assert_eq!(p.at(14), None);
        assert_eq!(p.at(20), Some(&FaultKind::Slow(Duration::from_millis(5))));
        assert_eq!(p.at(0), None);
        assert_eq!(p.count_of("error"), 4);
        assert_eq!(p.count_of("panic"), 1);
        assert_eq!(p.count_of("slow"), 1);
    }

    #[test]
    fn later_insert_overrides_earlier_at_same_index() {
        let p = FaultPlan::new().with_panic_at(5).with_error_at(5);
        assert_eq!(p.at(5), Some(&FaultKind::Error));
        assert_eq!(p.len(), 1);
    }

    #[test]
    fn seeded_plan_is_deterministic_and_seed_sensitive() {
        let a = FaultPlan::seeded(42, 1000, 0.1, Duration::from_millis(1));
        let b = FaultPlan::seeded(42, 1000, 0.1, Duration::from_millis(1));
        let c = FaultPlan::seeded(43, 1000, 0.1, Duration::from_millis(1));
        assert_eq!(a, b, "same seed → identical schedule");
        assert_ne!(a, c, "different seed → different schedule");
        // rate 0.1 over 1000 calls lands in a loose but non-degenerate band
        assert!(a.len() > 40 && a.len() < 250, "got {} faults", a.len());
        assert!(a.iter().all(|(i, _)| i < 1000));
    }

    #[test]
    fn seeded_rate_edges() {
        assert!(FaultPlan::seeded(7, 100, 0.0, Duration::ZERO).is_empty());
        assert_eq!(FaultPlan::seeded(7, 100, 1.1, Duration::ZERO).len(), 100);
    }
}
