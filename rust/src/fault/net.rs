//! Client-side connection chaos against a live listener.
//!
//! These helpers play the misbehaving peers a production front end meets:
//! connections that open and vanish, peers that speak a different (or no)
//! protocol, and frames cut off mid-payload by a dying client. The
//! [`NetServer`](crate::net::NetServer) must contain each to its own
//! connection — `tests/chaos.rs` interleaves these with real traffic and
//! asserts the real traffic never notices.
//!
//! All randomness is seeded ([`Xoshiro256`](crate::util::rng::Xoshiro256));
//! none of the helpers block longer than their socket timeouts.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use crate::net::wire::{encode_frame, Frame, MAGIC};
use crate::util::rng::Xoshiro256;

fn connect(addr: SocketAddr) -> io::Result<TcpStream> {
    let s = TcpStream::connect_timeout(&addr, Duration::from_secs(5))?;
    s.set_write_timeout(Some(Duration::from_secs(5)))?;
    s.set_read_timeout(Some(Duration::from_millis(200)))?;
    Ok(s)
}

/// Open a connection and drop it without sending a byte — the classic
/// port-scanner / crashed-before-first-request peer.
pub fn drop_connection(addr: SocketAddr) -> io::Result<()> {
    let _ = connect(addr)?;
    Ok(())
}

/// Send `len` seeded random bytes that are guaranteed NOT to start with
/// the protocol [`MAGIC`], then linger briefly for the server's reaction
/// (it should reject the frame and close). Returns the bytes the server
/// sent back before closing (usually a rejection frame or nothing).
pub fn send_garbage(addr: SocketAddr, seed: u64, len: usize) -> io::Result<Vec<u8>> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut bytes: Vec<u8> = (0..len.max(4)).map(|_| rng.next_u64() as u8).collect();
    // make the magic check fail deterministically regardless of the draw
    bytes[0] = !MAGIC[0];
    let mut s = connect(addr)?;
    s.write_all(&bytes)?;
    let _ = s.flush();
    let mut reply = Vec::new();
    let mut buf = [0u8; 256];
    // drain until close or read timeout; either way the server survived
    loop {
        match s.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => reply.extend_from_slice(&buf[..n]),
            Err(_) => break,
        }
    }
    Ok(reply)
}

/// Encode a real frame, send only its first `keep_fraction` of bytes
/// (clamped to at least the header so the server commits to reading a
/// payload), then drop the connection mid-frame.
pub fn send_truncated_frame(
    addr: SocketAddr,
    frame: &Frame,
    keep_fraction: f64,
) -> io::Result<()> {
    let full = encode_frame(frame)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))?;
    let keep = ((full.len() as f64 * keep_fraction.clamp(0.0, 1.0)) as usize)
        .clamp(MAGIC.len() + 1, full.len().saturating_sub(1).max(MAGIC.len() + 1));
    let mut s = connect(addr)?;
    s.write_all(&full[..keep])?;
    let _ = s.flush();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    // Protocol-level behavior against a real NetServer lives in
    // tests/chaos.rs; here we only pin the helpers' own contracts against
    // a raw listener.

    fn listener() -> (TcpListener, SocketAddr) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        (l, addr)
    }

    #[test]
    fn garbage_never_starts_with_magic_and_is_seed_stable() {
        let (l, addr) = listener();
        let srv = std::thread::spawn(move || {
            let mut got = Vec::new();
            for _ in 0..2 {
                let (mut s, _) = l.accept().unwrap();
                let mut buf = Vec::new();
                s.read_to_end(&mut buf).unwrap();
                got.push(buf);
            }
            got
        });
        send_garbage(addr, 99, 64).unwrap();
        send_garbage(addr, 99, 64).unwrap();
        let got = srv.join().unwrap();
        assert_eq!(got[0].len(), 64);
        assert_ne!(&got[0][..4], &MAGIC, "must not look like a real frame");
        assert_eq!(got[0], got[1], "same seed → same garbage");
    }

    #[test]
    fn truncated_frame_sends_a_strict_prefix() {
        use crate::backend::Value;
        use crate::net::wire::RequestFrame;
        let f = Frame::Request(RequestFrame {
            id: 7,
            model: "m".into(),
            priority: crate::coordinator::Priority::Standard,
            deadline: None,
            client_tag: None,
            inputs: vec![Value::I32(vec![1, 2, 3, 4])],
        });
        let full = encode_frame(&f).unwrap();
        let (l, addr) = listener();
        let srv = std::thread::spawn(move || {
            let (mut s, _) = l.accept().unwrap();
            let mut buf = Vec::new();
            s.read_to_end(&mut buf).unwrap();
            buf
        });
        send_truncated_frame(addr, &f, 0.5).unwrap();
        let got = srv.join().unwrap();
        assert!(!got.is_empty() && got.len() < full.len(), "strict prefix");
        assert_eq!(&got[..4], &MAGIC, "header intact so the server commits");
        assert_eq!(got[..], full[..got.len()]);
    }

    #[test]
    fn drop_connection_completes_against_a_listener() {
        let (l, addr) = listener();
        let srv = std::thread::spawn(move || {
            let (mut s, _) = l.accept().unwrap();
            let mut buf = Vec::new();
            s.read_to_end(&mut buf).unwrap();
            buf.len()
        });
        drop_connection(addr).unwrap();
        assert_eq!(srv.join().unwrap(), 0, "no bytes were sent");
    }
}
