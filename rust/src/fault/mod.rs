//! Deterministic fault injection for the serving stack.
//!
//! Chaos testing is only trustworthy when the chaos replays: every
//! injection point here is scheduled by *call index* from a seeded
//! [`FaultPlan`] (RNG: [`Xoshiro256`](crate::util::rng::Xoshiro256), no
//! wall-clock anywhere), so a failing chaos run reproduces bit-for-bit
//! from its seed.
//!
//! Three injection surfaces:
//! * [`FaultingBackend`] — wraps any
//!   [`InferenceBackend`](crate::backend::InferenceBackend) and injects
//!   panics, errors, and slow executions at the planned `run_batch` call
//!   indices. This is what exercises the coordinator's supervised worker
//!   fence, the respawn path, and the health breaker.
//! * [`net`] — client-side connection chaos against a live listener:
//!   dropped connections, garbled (non-protocol) bytes, truncated frames.
//!   This is what exercises the net layer's per-connection failure
//!   containment.
//! * [`FaultPlan`] itself — pure data, so tests can also hand-place
//!   faults (`with_panic_at(3)`) when an exact scenario matters more than
//!   seeded coverage.
//!
//! The module is plain library code (no test-only gating): benches
//! (`benches/fault_recovery.rs`) and the chaos suite (`tests/chaos.rs`)
//! both drive it, and operators can reuse it for staging burn-in.

pub mod backend;
pub mod net;
pub mod plan;

pub use backend::FaultingBackend;
pub use plan::{FaultKind, FaultPlan};
