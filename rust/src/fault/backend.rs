//! [`FaultingBackend`]: any [`InferenceBackend`] plus a [`FaultPlan`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::backend::{InferenceBackend, TensorSpec, Value};
use crate::fault::plan::{FaultKind, FaultPlan};

/// Wraps an inner backend and injects the planned fault at each scheduled
/// `run_batch` call index. Spec introspection (`input_specs` /
/// `output_specs`) always delegates cleanly — the plan models *execution*
/// faults, and routing needs working specs to even reach execution.
///
/// The call counter covers every `run_batch` arrival across all worker
/// threads (one atomic increment each), so under a multi-worker
/// coordinator the *set* of injected faults is exactly the plan even
/// though which worker draws which index depends on scheduling.
pub struct FaultingBackend {
    inner: Arc<dyn InferenceBackend>,
    plan: FaultPlan,
    calls: AtomicU64,
    injected_panics: AtomicU64,
    injected_errors: AtomicU64,
    injected_slow: AtomicU64,
}

impl FaultingBackend {
    pub fn new(inner: Arc<dyn InferenceBackend>, plan: FaultPlan) -> FaultingBackend {
        FaultingBackend {
            inner,
            plan,
            calls: AtomicU64::new(0),
            injected_panics: AtomicU64::new(0),
            injected_errors: AtomicU64::new(0),
            injected_slow: AtomicU64::new(0),
        }
    }

    /// Total `run_batch` calls observed so far.
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Injections actually performed so far, as (panics, errors, slows) —
    /// tests assert the storm they scheduled really happened.
    pub fn injected(&self) -> (u64, u64, u64) {
        (
            self.injected_panics.load(Ordering::Relaxed),
            self.injected_errors.load(Ordering::Relaxed),
            self.injected_slow.load(Ordering::Relaxed),
        )
    }
}

impl InferenceBackend for FaultingBackend {
    fn input_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        self.inner.input_specs(artifact)
    }

    fn output_specs(&self, artifact: &str) -> anyhow::Result<&[TensorSpec]> {
        self.inner.output_specs(artifact)
    }

    fn run_batch(&self, artifact: &str, inputs: &[Value]) -> anyhow::Result<Vec<Value>> {
        let idx = self.calls.fetch_add(1, Ordering::Relaxed);
        match self.plan.at(idx) {
            Some(FaultKind::Panic) => {
                self.injected_panics.fetch_add(1, Ordering::Relaxed);
                panic!("injected fault: panic at backend call {idx} ({artifact})");
            }
            Some(FaultKind::Error) => {
                self.injected_errors.fetch_add(1, Ordering::Relaxed);
                anyhow::bail!("injected fault: error at backend call {idx} ({artifact})");
            }
            Some(FaultKind::Slow(d)) => {
                self.injected_slow.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(*d);
                self.inner.run_batch(artifact, inputs)
            }
            None => self.inner.run_batch(artifact, inputs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::EchoBackend;
    use crate::runtime::manifest::Manifest;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::path::Path;
    use std::time::Duration;

    fn echo() -> Arc<dyn InferenceBackend> {
        let text = r#"{"artifacts": [
          {"name": "m_b1", "file": "x", "family": "m", "model": "m",
           "sparsity": 8, "batch": 1, "seq": 4,
           "inputs": [{"name": "ids", "shape": [1, 4], "dtype": "s32"}],
           "outputs": [{"shape": [1, 2], "dtype": "f32"}]}
        ]}"#;
        let m = Manifest::parse(Path::new("/tmp"), text).unwrap();
        Arc::new(EchoBackend::from_manifest(&m))
    }

    fn run(b: &FaultingBackend) -> anyhow::Result<Vec<Value>> {
        b.run_batch("m_b1", &[Value::I32(vec![1, 2, 3, 4])])
    }

    #[test]
    fn faults_fire_at_their_scheduled_call_index_only() {
        let plan = FaultPlan::new()
            .with_error_at(1)
            .with_panic_at(2)
            .with_slow_at(3, Duration::from_millis(1));
        let b = FaultingBackend::new(echo(), plan);
        assert!(run(&b).is_ok(), "call 0 unscheduled → clean");
        let e = run(&b).unwrap_err();
        assert!(e.to_string().contains("injected fault: error at backend call 1"), "{e}");
        let p = catch_unwind(AssertUnwindSafe(|| run(&b)));
        assert!(p.is_err(), "call 2 panics");
        let t = std::time::Instant::now();
        assert!(run(&b).is_ok(), "slow call still succeeds");
        assert!(t.elapsed() >= Duration::from_millis(1));
        assert!(run(&b).is_ok(), "past the schedule → clean again");
        assert_eq!(b.calls(), 5);
        assert_eq!(b.injected(), (1, 1, 1));
    }

    #[test]
    fn specs_delegate_even_under_an_all_fault_plan() {
        let b = FaultingBackend::new(echo(), FaultPlan::new().with_panic_at(0));
        assert!(b.input_specs("m_b1").is_ok());
        assert!(b.output_specs("m_b1").is_ok());
        assert_eq!(b.batch_capacity("m_b1").unwrap(), 1);
        assert!(b.input_specs("nope").is_err(), "unknown artifact still errs");
    }

    #[test]
    fn clean_plan_is_transparent() {
        let b = FaultingBackend::new(echo(), FaultPlan::new());
        let out = run(&b).unwrap();
        assert_eq!(out[0].as_f32().unwrap()[0], 1.0, "echo passes through");
        assert_eq!(b.injected(), (0, 0, 0));
    }
}
