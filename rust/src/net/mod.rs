//! Network serving front end (Layer 4): the socket boundary over the
//! coordinator, plus the load harness that drives it.
//!
//! Pieces, client to server:
//!
//! * [`wire`] — the length-prefixed binary frame codec. Request frames
//!   carry the full QoS submission surface (model, typed [`Value`]
//!   tensors, priority/deadline/client-tag); response frames carry the
//!   typed outcome, output tensors, and server-side timing. f32 payloads
//!   round-trip **bitwise**, so logits served over the socket are
//!   byte-identical to in-process serving.
//! * [`client`] — [`NetClient`], a blocking client supporting both
//!   call-style round trips and pipelined send/recv with correlation
//!   ids, bounded connect timeouts, and seeded capped-exponential
//!   connect retry ([`RetryPolicy`]) for riding out server restarts.
//! * [`server`] — [`NetServer`], a `TcpListener` front end over **any**
//!   [`ServingService`](crate::coordinator::ServingService): one
//!   acceptor thread, two bounded threads per connection (frame reader +
//!   reply pump), per-connection failure containment, drain-on-shutdown.
//! * [`loadgen`] — the open-loop generator: pre-scheduled fixed-rate
//!   arrivals that never wait for responses, per-class p50/p99/p999 from
//!   scheduled (not sent) timestamps, achieved-vs-offered rate, and an
//!   in-process twin ([`run_open_loop_local`]) replaying the identical
//!   schedule for socket-overhead subtraction.
//!
//! CLI entry points: `s4 net-serve` binds a [`NetServer`] over the
//! serving stack; `s4 net-load` points the generator at one. The
//! `net_latency` bench emits `BENCH_net.json` from the same pieces.
//!
//! [`Value`]: crate::backend::Value

pub mod client;
pub mod loadgen;
pub mod server;
pub mod wire;

pub use client::{NetClient, RetryPolicy};
pub use loadgen::{run_open_loop, run_open_loop_local, ClassLoad, LoadReport, LoadSpec};
pub use server::{NetServer, NetServerConfig};
pub use wire::{
    read_frame, write_frame, Frame, ReadEvent, RequestFrame, ResponseFrame, WireError, WireStatus,
    MAGIC, MAX_FRAME_BYTES,
};
