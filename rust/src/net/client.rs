//! Blocking TCP client for the [`NetServer`](crate::net::NetServer)
//! frame protocol.
//!
//! Two usage shapes:
//!
//! * **call** — [`call_with`](NetClient::call_with) writes one request
//!   and blocks for its response (simple request/response callers, the
//!   `s4 net-load` warm-up probe);
//! * **pipelined** — [`send_with`](NetClient::send_with) then
//!   [`recv`](NetClient::recv): keep many requests in flight on one
//!   connection and match responses by correlation id. Responses arrive
//!   **out of order** when the server finishes them out of order (an
//!   Interactive reply overtakes queued Bulk on the same socket) — the
//!   open-loop generator in [`loadgen`](crate::net::loadgen) depends on
//!   exactly this.
//!
//! The client assigns frame ids from a connection-local counter;
//! [`call_with`](NetClient::call_with) skips responses for other
//! (abandoned pipelined) ids rather than mis-attributing them.

use std::io::BufReader;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use super::wire::{
    read_frame, write_frame, Frame, ReadEvent, RequestFrame, ResponseFrame, WireError,
};
use crate::backend::Value;
use crate::coordinator::SubmitOptions;
use crate::util::rng::Xoshiro256;

/// Connect-retry policy for [`NetClient::connect_retrying`]: capped
/// exponential backoff with seeded jitter, so a restarting server (a
/// supervisor respawning the serving process, a deploy rolling the
/// front end) is ridden out instead of surfaced to the caller — and so
/// a thundering herd of reconnecting clients decorrelates.
///
/// All timing is derived from the policy (no wall-clock randomness):
/// the jitter stream comes from `seed`, so a given policy produces the
/// same backoff trace on every run.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Total connect attempts, including the first (clamped to ≥ 1).
    pub attempts: u32,
    /// Backoff before the second attempt; doubles every retry after.
    pub base: Duration,
    /// Ceiling on any single backoff sleep (pre-jitter).
    pub cap: Duration,
    /// Per-attempt TCP connect timeout (see
    /// [`NetClient::connect_timeout`]).
    pub connect_timeout: Duration,
    /// Seed for the jitter stream.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            attempts: 4,
            base: Duration::from_millis(25),
            cap: Duration::from_secs(1),
            connect_timeout: Duration::from_secs(2),
            seed: 0x5eed,
        }
    }
}

impl RetryPolicy {
    /// Sleep before retry number `attempt` (0-based count of failures so
    /// far): `min(cap, base << attempt)` scaled by a jitter factor drawn
    /// from `rng` in `[0.5, 1.0)`. Exposed so tests can pin the exact
    /// deterministic trace [`connect_retrying`](NetClient::connect_retrying)
    /// will sleep.
    pub fn backoff(&self, attempt: u32, rng: &mut Xoshiro256) -> Duration {
        let exp = self.base.saturating_mul(1u32 << attempt.min(20)).min(self.cap);
        exp.mul_f64(0.5 + 0.5 * rng.next_f64())
    }
}

/// Blocking connection to a [`NetServer`](crate::net::NetServer).
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
    recv_timeout: Duration,
}

impl NetClient {
    /// Shared post-connect setup: every `connect*` front door funnels its
    /// freshly opened stream through here.
    fn from_stream(stream: TcpStream, recv_timeout: Duration) -> anyhow::Result<NetClient> {
        let _ = stream.set_nodelay(true);
        // short socket-level tick so recv can poll its own deadline
        stream.set_read_timeout(Some(Duration::from_millis(20)))?;
        stream.set_write_timeout(Some(Duration::from_secs(5)))?;
        let writer = stream.try_clone()?;
        Ok(NetClient { reader: BufReader::new(stream), writer, next_id: 1, recv_timeout })
    }

    /// Connect; `recv_timeout` bounds every [`recv`](NetClient::recv)
    /// (and therefore [`call_with`](NetClient::call_with)).
    pub fn connect(addr: impl ToSocketAddrs, recv_timeout: Duration) -> anyhow::Result<NetClient> {
        NetClient::from_stream(TcpStream::connect(addr)?, recv_timeout)
    }

    /// [`connect`](NetClient::connect) with a bound on the TCP connect
    /// itself — a blackholed address (down host, dropped SYNs) returns an
    /// error after `timeout` per resolved address instead of hanging for
    /// the OS default (minutes). Tries each resolved address in order and
    /// returns the last error if none accepts.
    pub fn connect_timeout(
        addr: impl ToSocketAddrs,
        timeout: Duration,
        recv_timeout: Duration,
    ) -> anyhow::Result<NetClient> {
        let mut last: Option<std::io::Error> = None;
        for sa in addr.to_socket_addrs()? {
            match TcpStream::connect_timeout(&sa, timeout) {
                Ok(stream) => return NetClient::from_stream(stream, recv_timeout),
                Err(e) => last = Some(e),
            }
        }
        Err(match last {
            Some(e) => e.into(),
            None => anyhow::anyhow!("address resolved to no socket addresses"),
        })
    }

    /// [`connect_timeout`](NetClient::connect_timeout) under a
    /// [`RetryPolicy`]: up to `policy.attempts` tries, sleeping
    /// [`policy.backoff`](RetryPolicy::backoff) between them. Returns the
    /// last connect error if every attempt fails.
    pub fn connect_retrying(
        addr: impl ToSocketAddrs,
        policy: &RetryPolicy,
        recv_timeout: Duration,
    ) -> anyhow::Result<NetClient> {
        let mut rng = Xoshiro256::seed_from_u64(policy.seed);
        let attempts = policy.attempts.max(1);
        let mut last = None;
        for attempt in 0..attempts {
            if attempt > 0 {
                std::thread::sleep(policy.backoff(attempt - 1, &mut rng));
            }
            match NetClient::connect_timeout(&addr, policy.connect_timeout, recv_timeout) {
                Ok(c) => return Ok(c),
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| anyhow::anyhow!("zero connect attempts")))
    }

    /// Fire one request without waiting; returns the frame id to match
    /// the eventual response against.
    pub fn send_with(
        &mut self,
        model: &str,
        inputs: Vec<Value>,
        opts: &SubmitOptions,
    ) -> anyhow::Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let frame = Frame::Request(RequestFrame {
            id,
            model: model.to_string(),
            priority: opts.priority,
            deadline: opts.deadline,
            client_tag: opts.client_tag.clone(),
            inputs,
        });
        write_frame(&mut self.writer, &frame)?;
        Ok(id)
    }

    /// Next response frame from the server, whatever its id (pipelined
    /// callers match ids themselves). Errors on timeout or server close.
    pub fn recv(&mut self) -> anyhow::Result<ResponseFrame> {
        let deadline = Instant::now() + self.recv_timeout;
        loop {
            match read_frame(&mut self.reader) {
                Ok(ReadEvent::Frame(Frame::Response(r))) => return Ok(r),
                Ok(ReadEvent::Frame(Frame::Request(_))) => {
                    anyhow::bail!("protocol error: server sent a request frame")
                }
                Ok(ReadEvent::Idle) => {
                    if Instant::now() >= deadline {
                        anyhow::bail!("no response within {:?}", self.recv_timeout);
                    }
                }
                Ok(ReadEvent::Closed) => anyhow::bail!("server closed the connection"),
                Err(WireError::Io(e)) => return Err(e.into()),
                Err(e) => return Err(anyhow::anyhow!(e.to_string())),
            }
        }
    }

    /// One blocking round trip with explicit QoS options; skips stale
    /// responses for older pipelined ids instead of returning them.
    pub fn call_with(
        &mut self,
        model: &str,
        inputs: Vec<Value>,
        opts: &SubmitOptions,
    ) -> anyhow::Result<ResponseFrame> {
        let id = self.send_with(model, inputs, opts)?;
        loop {
            let r = self.recv()?;
            if r.id == id {
                return Ok(r);
            }
        }
    }

    /// [`call_with`](NetClient::call_with) under default options.
    pub fn call(&mut self, model: &str, inputs: Vec<Value>) -> anyhow::Result<ResponseFrame> {
        self.call_with(model, inputs, &SubmitOptions::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    // Full request/response round trips live in tests/net_e2e.rs and
    // tests/chaos.rs; here we pin the connect/retry surface only.

    #[test]
    fn connect_timeout_succeeds_against_a_live_listener() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let c = NetClient::connect_timeout(addr, Duration::from_secs(2), Duration::from_secs(1));
        assert!(c.is_ok(), "{:?}", c.err());
    }

    #[test]
    fn connect_timeout_fails_bounded_when_nothing_listens() {
        // grab a port, then free it so the connect is refused
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let t = Instant::now();
        let c = NetClient::connect_timeout(addr, Duration::from_millis(500), Duration::from_secs(1));
        assert!(c.is_err(), "connect to a freed port must fail");
        // loopback refusal is immediate; the point is we returned promptly
        // instead of hanging for the OS default connect timeout
        assert!(t.elapsed() < Duration::from_secs(5), "took {:?}", t.elapsed());
    }

    #[test]
    fn backoff_trace_is_deterministic_capped_and_jittered() {
        let p = RetryPolicy {
            attempts: 8,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(80),
            ..RetryPolicy::default()
        };
        let trace = |seed: u64| -> Vec<Duration> {
            let mut rng = Xoshiro256::seed_from_u64(seed);
            (0..7).map(|k| p.backoff(k, &mut rng)).collect()
        };
        assert_eq!(trace(p.seed), trace(p.seed), "same seed → same trace");
        let t = trace(p.seed);
        for (k, d) in t.iter().enumerate() {
            let exp = p.base.saturating_mul(1 << k).min(p.cap);
            assert!(*d >= exp.mul_f64(0.5), "retry {k}: {d:?} below half of {exp:?}");
            assert!(*d <= exp, "retry {k}: {d:?} over nominal {exp:?}");
            assert!(*d <= p.cap, "retry {k}: {d:?} over cap");
        }
        // exponent saturates at the cap: late retries sleep ≤ cap, not 2^k
        assert!(t[6] <= p.cap);
    }

    #[test]
    fn connect_retrying_rides_out_a_restarting_server() {
        // bind, learn the port, free it — then resurrect the listener
        // while the client is mid-backoff
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let rebinder = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(40));
            let l = TcpListener::bind(addr).expect("rebind the freed port");
            // hold the listener long enough for the client's retries
            std::thread::sleep(Duration::from_millis(500));
            drop(l);
        });
        let policy = RetryPolicy {
            attempts: 10,
            base: Duration::from_millis(20),
            cap: Duration::from_millis(50),
            ..RetryPolicy::default()
        };
        let c = NetClient::connect_retrying(addr, &policy, Duration::from_secs(1));
        assert!(c.is_ok(), "server came back within the retry budget: {:?}", c.err());
        rebinder.join().unwrap();
    }

    #[test]
    fn connect_retrying_gives_up_with_the_last_error() {
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let policy = RetryPolicy {
            attempts: 3,
            base: Duration::from_millis(1),
            cap: Duration::from_millis(4),
            ..RetryPolicy::default()
        };
        let t = Instant::now();
        let c = NetClient::connect_retrying(addr, &policy, Duration::from_secs(1));
        assert!(c.is_err(), "no listener ever appears → all attempts fail");
        assert!(t.elapsed() < Duration::from_secs(5), "gave up promptly");
    }
}
