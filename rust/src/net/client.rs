//! Blocking TCP client for the [`NetServer`](crate::net::NetServer)
//! frame protocol.
//!
//! Two usage shapes:
//!
//! * **call** — [`call_with`](NetClient::call_with) writes one request
//!   and blocks for its response (simple request/response callers, the
//!   `s4 net-load` warm-up probe);
//! * **pipelined** — [`send_with`](NetClient::send_with) then
//!   [`recv`](NetClient::recv): keep many requests in flight on one
//!   connection and match responses by correlation id. Responses arrive
//!   **out of order** when the server finishes them out of order (an
//!   Interactive reply overtakes queued Bulk on the same socket) — the
//!   open-loop generator in [`loadgen`](crate::net::loadgen) depends on
//!   exactly this.
//!
//! The client assigns frame ids from a connection-local counter;
//! [`call_with`](NetClient::call_with) skips responses for other
//! (abandoned pipelined) ids rather than mis-attributing them.

use std::io::BufReader;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use super::wire::{
    read_frame, write_frame, Frame, ReadEvent, RequestFrame, ResponseFrame, WireError,
};
use crate::backend::Value;
use crate::coordinator::SubmitOptions;

/// Blocking connection to a [`NetServer`](crate::net::NetServer).
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
    recv_timeout: Duration,
}

impl NetClient {
    /// Connect; `recv_timeout` bounds every [`recv`](NetClient::recv)
    /// (and therefore [`call_with`](NetClient::call_with)).
    pub fn connect(addr: impl ToSocketAddrs, recv_timeout: Duration) -> anyhow::Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        // short socket-level tick so recv can poll its own deadline
        stream.set_read_timeout(Some(Duration::from_millis(20)))?;
        stream.set_write_timeout(Some(Duration::from_secs(5)))?;
        let writer = stream.try_clone()?;
        Ok(NetClient { reader: BufReader::new(stream), writer, next_id: 1, recv_timeout })
    }

    /// Fire one request without waiting; returns the frame id to match
    /// the eventual response against.
    pub fn send_with(
        &mut self,
        model: &str,
        inputs: Vec<Value>,
        opts: &SubmitOptions,
    ) -> anyhow::Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let frame = Frame::Request(RequestFrame {
            id,
            model: model.to_string(),
            priority: opts.priority,
            deadline: opts.deadline,
            client_tag: opts.client_tag.clone(),
            inputs,
        });
        write_frame(&mut self.writer, &frame)?;
        Ok(id)
    }

    /// Next response frame from the server, whatever its id (pipelined
    /// callers match ids themselves). Errors on timeout or server close.
    pub fn recv(&mut self) -> anyhow::Result<ResponseFrame> {
        let deadline = Instant::now() + self.recv_timeout;
        loop {
            match read_frame(&mut self.reader) {
                Ok(ReadEvent::Frame(Frame::Response(r))) => return Ok(r),
                Ok(ReadEvent::Frame(Frame::Request(_))) => {
                    anyhow::bail!("protocol error: server sent a request frame")
                }
                Ok(ReadEvent::Idle) => {
                    if Instant::now() >= deadline {
                        anyhow::bail!("no response within {:?}", self.recv_timeout);
                    }
                }
                Ok(ReadEvent::Closed) => anyhow::bail!("server closed the connection"),
                Err(WireError::Io(e)) => return Err(e.into()),
                Err(e) => return Err(anyhow::anyhow!(e.to_string())),
            }
        }
    }

    /// One blocking round trip with explicit QoS options; skips stale
    /// responses for older pipelined ids instead of returning them.
    pub fn call_with(
        &mut self,
        model: &str,
        inputs: Vec<Value>,
        opts: &SubmitOptions,
    ) -> anyhow::Result<ResponseFrame> {
        let id = self.send_with(model, inputs, opts)?;
        loop {
            let r = self.recv()?;
            if r.id == id {
                return Ok(r);
            }
        }
    }

    /// [`call_with`](NetClient::call_with) under default options.
    pub fn call(&mut self, model: &str, inputs: Vec<Value>) -> anyhow::Result<ResponseFrame> {
        self.call_with(model, inputs, &SubmitOptions::default())
    }
}
